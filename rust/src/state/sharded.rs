//! Sharded kernel: N independent [`Kernel`] state machines behind one
//! deterministic router (the ROADMAP's horizontal-scaling step).
//!
//! # Design
//!
//! **Routing.** Every external id belongs to exactly one shard:
//! `shard_of(id) = splitmix64(id) % n_shards` (see
//! [`crate::state::kernel::ShardSpec`]). The routing function is a pure
//! function of the id and the shard count — no directory, no coordination,
//! and any two nodes with the same `n_shards` agree on placement forever.
//! splitmix64 gives avalanche-quality dispersion, so sequential client ids
//! spread evenly instead of hot-spotting one shard.
//!
//! **Determinism.** Each shard is a full [`Kernel`]: a pure state machine
//! whose state is a function of its own command subsequence. Because
//! routing is deterministic, the global command sequence induces one
//! deterministic subsequence per shard, so per-shard states — and their
//! snapshot bytes and hashes — are replayable exactly like the single
//! kernel (paper §3.1, applied per partition).
//!
//! **Search fan-out and bit-exact merge.** A query fans out to every shard
//! (via the shared scan pool above a corpus-size threshold, inline below
//! it); each shard contributes its top-k ordered by `(dist_raw, id)`.
//! Per-shard results are collected *in dispatch order* (never in
//! completion order) and combined through the same bounded
//! [`TopK`](crate::index::TopK) heap the flat index uses, keyed on
//! `(dist_raw, id)`. The merge is therefore a pure function of the
//! per-shard result lists: thread scheduling cannot influence the output,
//! and with an exact (flat) index the merged top-k is bit-identical to a
//! single kernel holding all vectors (integer distances are exact and ids
//! are unique, so the total order has no ties to resolve
//! nondeterministically).
//!
//! **Scan pool and intra-shard parallelism.** One shared pool of
//! `min(cores, scan_workers)` long-lived workers ([`ScanPool`]) serves
//! every parallel operation, created lazily on the first one and fed over
//! a single queue; dropping the kernel disconnects the queue and joins
//! every worker. For flat-index searches each shard's contiguous arena is
//! split into fixed-size sub-range *chunks*
//! ([`ScanConfig::chunk`](crate::state::kernel::ScanConfig) slots); per
//! shard, up to `workers` lane tasks claim chunks off an
//! atomic counter (work stealing: a stalled lane simply claims fewer
//! chunks) and scan them into local `TopK` heaps, which are then merged.
//! Chunk boundaries are a config constant and the bounded top-k is an
//! order-independent reduction over the pushed multiset, so *any*
//! claiming schedule produces bit-identical results — this is what lets
//! a 1-shard collection scale across every core without bit drift
//! (PERFORMANCE.md §9). SQ8 shards parallelize both phases: phase-1 i8
//! chunk scans keep `overscan * k` candidates per shard, phase-2 exact
//! re-rank splits the candidate list into chunk-sized tasks. HNSW shards
//! (no contiguous arena) fall back to one whole-shard search task each.
//! A panicked scan task fails only its own query ([`StateError::ScanPoisoned`])
//! and the pool respawns the worker; queued queries from other clients
//! are unaffected. The pool also runs parallel batch upserts (large
//! `InsertBatch` sub-batches apply on their shards concurrently, one
//! task per shard — writes keep per-shard serialization by construction).
//! None of this can affect results: searches merge on a total order, and
//! the router pre-validates a batch on every target shard before
//! dispatch, so the per-shard sub-batches — disjoint by construction —
//! succeed unconditionally and commute across shards (paper §3.1,
//! applied per partition).
//!
//! **Cross-shard links.** A link `from → to` lives on the shard that owns
//! `from`. The router checks `to` globally before logging the command;
//! per-shard replay then accepts remote `to` ids without a local check
//! (checked-once-upstream, like boundary validation). Deleting an id emits
//! explicit `Unlink` commands to the other shards that point at it, so the
//! no-dangling-links invariant survives sharding *and* stays in the
//! per-shard logs (replay-pure; no hidden side effects).
//!
//! **Root-hash manifest.** Convergence checks compare per-shard FNV state
//! hashes plus a combined root: `root = fnv(n_shards ‖ h_0 ‖ … ‖ h_{n-1})`.
//! Two sharded nodes verify shard-by-shard (pinpointing a diverged shard)
//! and summarize with one root value (paper §8.1's `H_A ≡ H_B`, lifted to
//! the sharded deployment). [`crate::snapshot::ShardedSnapshot`] persists
//! the same manifest with audit-grade SHA-256 digests per shard.

// R5 allowlisted file (see DETERMINISM.md): raw-pointer shard handles for
// the scan pool. Every unsafe site carries a SAFETY comment; `valori lint`
// rejects any that does not.
#![allow(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

use crate::distance::Scalar;
use crate::hash::Fnv1a64;
use crate::index::{Hit as IndexHit, QuantSpec, Quantizer, TopK};
use crate::proof::{combined_root, LeafRecord, MembershipProof};
use crate::state::command::{CanonCommand, Command};
use crate::state::kernel::{Hit, Kernel, KernelConfig, RepairError, StateError};
use crate::vector::FixedVector;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread;

/// One per-shard log record produced by a routed application: `command`
/// was applied on `shard` at that shard's local sequence number `seq`.
/// This is exactly what the node appends to shard `shard`'s WAL.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Routed {
    pub shard: u32,
    /// The shard's logical clock *before* the command applied (i.e. the
    /// command moved the shard from `seq` to `seq + 1`).
    pub seq: u64,
    pub command: CanonCommand,
}

/// Result of applying one external command through the router.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardApply {
    /// The canonical form of the submitted command (what a single-kernel
    /// deployment would log).
    pub canon: CanonCommand,
    /// The per-shard records actually applied, in deterministic order.
    /// Usually one; an `InsertBatch` yields one per participating shard,
    /// and a `Delete` may add cross-shard `Unlink` cleanup records.
    pub applied: Vec<Routed>,
}

/// A job executed by one pool worker thread.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// State shared between the [`ScanPool`] handle and its workers.
struct PoolShared {
    /// The single shared job queue. Workers take turns holding this lock
    /// while blocked in `recv` — a cheap mutex-guarded MPMC: claiming a
    /// job is one lock + one `recv`, and the lock is *not* held while the
    /// job runs.
    queue: Mutex<mpsc::Receiver<Job>>,
    /// Set before the injector drops, so a worker dying during shutdown
    /// does not respawn a replacement.
    shutdown: AtomicBool,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
}

/// Re-arms a replacement worker when a scan job panics: the dying
/// thread's unwind runs this guard's `Drop`, which (unless the pool is
/// shutting down) spawns a fresh worker before the thread exits — one
/// poisoned query never shrinks the pool. The respawn is best-effort: if
/// the spawn itself fails the pool degrades by one worker instead of
/// panicking during unwind (which would abort the process).
struct RespawnGuard {
    shared: Arc<PoolShared>,
}

impl Drop for RespawnGuard {
    fn drop(&mut self) {
        if thread::panicking() && !self.shared.shutdown.load(Ordering::SeqCst) {
            spawn_scan_worker(&self.shared, false);
        }
    }
}

fn spawn_scan_worker(shared: &Arc<PoolShared>, must: bool) {
    let worker_shared = Arc::clone(shared);
    let spawned = thread::Builder::new().name("valori-scan".into()).spawn(move || {
        let _respawn = RespawnGuard { shared: Arc::clone(&worker_shared) };
        loop {
            let job = {
                // A panicking job unwinds *outside* this lock (the guard
                // drops before the job runs), so the queue mutex is never
                // actually poisoned; recover defensively anyway.
                let queue =
                    worker_shared.queue.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
                match queue.recv() {
                    Ok(job) => job,
                    Err(_) => return, // injector dropped: clean shutdown
                }
            };
            job();
        }
    });
    match spawned {
        Ok(handle) => {
            shared.handles.lock().unwrap_or_else(|p| p.into_inner()).push(handle);
        }
        Err(e) => {
            if must {
                panic!("failed to spawn scan worker: {e}");
            }
        }
    }
}

/// One shared pool of `min(cores, scan_workers)` long-lived workers fed
/// over a single FIFO queue — the execution substrate for every parallel
/// read and write path here. Replaces the former one-thread-per-shard
/// pool: aggregate parallelism is no longer capped at `n_shards`, so a
/// 1-shard collection's chunked scans use every worker. Any worker can
/// claim any job; determinism is unaffected because each dispatch site
/// collects its responses in dispatch order and reduces on a total order
/// (module docs). Dropping the pool disconnects the queue (workers drain
/// outstanding jobs, then exit) and joins every worker, so no queued job
/// outlives the pool — and therefore the shards (field order in
/// [`ShardedKernel`]) its jobs point into.
struct ScanPool {
    /// `Some` until drop. Mutex-wrapped so concurrent readers of a
    /// [`ShardedKernel`] (e.g. HTTP workers behind an `RwLock`) can
    /// dispatch; the critical section is one channel send.
    injector: Mutex<Option<mpsc::Sender<Job>>>,
    workers: usize,
    shared: Arc<PoolShared>,
}

impl ScanPool {
    fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(rx),
            shutdown: AtomicBool::new(false),
            handles: Mutex::new(Vec::with_capacity(workers)),
        });
        for _ in 0..workers {
            spawn_scan_worker(&shared, true);
        }
        Self { injector: Mutex::new(Some(tx)), workers, shared }
    }

    fn workers(&self) -> usize {
        self.workers
    }

    /// Enqueue one job; the first idle worker claims it (FIFO). A job
    /// that panics resolves its response channel `Err` (the dispatcher
    /// observes the failure) and the dying worker respawns itself — see
    /// [`RespawnGuard`].
    fn run(&self, job: Job) {
        self.injector
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .as_ref()
            .expect("scan pool is shut down")
            .send(job)
            .expect("scan pool queue disappeared");
    }
}

impl Drop for ScanPool {
    fn drop(&mut self) {
        // Stop respawns first, then disconnect the queue: workers drain
        // outstanding jobs and exit on the recv error.
        self.shared.shutdown.store(true, Ordering::SeqCst);
        *self.injector.get_mut().unwrap_or_else(|p| p.into_inner()) = None;
        // Join until the handle list is empty. A worker that panicked
        // before `shutdown` was set pushes its replacement's handle
        // during its unwind; joining the dead thread happens-after that
        // push, so a fresh drain pass observes the replacement — and the
        // loop converges because `shutdown` stops further respawns.
        loop {
            let drained: Vec<thread::JoinHandle<()>> = {
                let mut handles =
                    self.shared.handles.lock().unwrap_or_else(|p| p.into_inner());
                handles.drain(..).collect()
            };
            if drained.is_empty() {
                return;
            }
            for h in drained {
                let _ = h.join();
            }
        }
    }
}

/// Collects pooled-job responses and guarantees — on the happy path via
/// [`DispatchBarrier::wait_all`] and on *unwind* via `Drop` — that every
/// dispatched job has resolved before the dispatching frame's borrow can
/// end. A receiver resolves when its job sends a result, or with `Err`
/// when the job's sender drops: a panicking job drops it during the
/// worker's unwind, and a job queued behind a dead worker is destroyed
/// (never run) by that worker's channel teardown. This is what makes
/// handing raw shard pointers to `'static` workers sound on every path,
/// not just the non-panicking one — the scoped-thread code this replaces
/// joined its threads even while unwinding, and the barrier preserves
/// that property.
struct DispatchBarrier<T> {
    rxs: Vec<mpsc::Receiver<T>>,
}

impl<T> DispatchBarrier<T> {
    fn new() -> Self {
        Self { rxs: Vec::new() }
    }

    /// Track one dispatched job. Call *before* handing the job to the
    /// pool, so a panic inside the dispatch itself still drains this job.
    fn add(&mut self, rx: mpsc::Receiver<T>) {
        self.rxs.push(rx);
    }

    /// Block until every dispatched job resolves, in dispatch order.
    /// `Err` means the job's worker died (the job panicked, or was torn
    /// down unexecuted).
    fn wait_all(mut self) -> Vec<Result<T, mpsc::RecvError>> {
        self.rxs.drain(..).map(|rx| rx.recv()).collect()
    }
}

impl<T> Drop for DispatchBarrier<T> {
    fn drop(&mut self) {
        // Unwind path: resolve every outstanding job before the borrow
        // that produced the job pointers ends. Results are discarded.
        for rx in self.rxs.drain(..) {
            let _ = rx.recv();
        }
    }
}

/// Send-able `*const Kernel` for pooled search jobs. Safe by protocol:
/// every dispatch site registers each job with a [`DispatchBarrier`]
/// before dispatch and waits on it (explicitly, or via its `Drop` during
/// unwind) until all jobs have resolved, so the pointee (borrowed from
/// `&self`) strictly outlives the job, and search jobs only ever read.
struct SharedShard(*const Kernel);
// SAFETY: dispatch registers every job with a DispatchBarrier and waits on it
// (normally or via Drop during unwind) before the `&self` borrow ends, so the
// pointee outlives the job; jobs only read, so shared access is sound.
unsafe impl Send for SharedShard {}

/// Send-able `*mut Kernel` for pooled upsert jobs. Safe by protocol: the
/// dispatching call holds `&mut self` (exclusive access to every shard),
/// hands each shard index to at most one worker (the split-at-mut
/// pattern), and waits on a [`DispatchBarrier`] until every job has
/// resolved — the disjoint `&mut Kernel`s never alias and never outlive
/// the borrow, on the unwind path included.
struct ExclusiveShard(*mut Kernel);
// SAFETY: the dispatching call holds `&mut self` (exclusive access to all
// shards), hands each shard index to at most one worker (split-at-mut), and
// barrier-waits until every job resolves — the disjoint `&mut Kernel`s never
// alias and never outlive the borrow, unwind path included.
unsafe impl Send for ExclusiveShard {}

/// N independent kernels behind a deterministic router. See the module
/// docs for the design; the unsharded reference contract is `n_shards = 1`,
/// where every operation degenerates to the plain [`Kernel`] behaviour.
pub struct ShardedKernel {
    /// Declared before `shards` so it drops first: pool shutdown joins
    /// every worker, so no queued job can outlive the kernels its raw
    /// pointers reference. Lazily created on the first parallel operation
    /// (pure-replay and snapshot workloads never pay for threads).
    pool: OnceLock<ScanPool>,
    shards: Vec<Kernel>,
}

impl fmt::Debug for ShardedKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ShardedKernel").field("shards", &self.shards).finish()
    }
}

impl Clone for ShardedKernel {
    fn clone(&self) -> Self {
        // The clone gets its own (lazy) pool — worker threads are runtime
        // plumbing, not state.
        Self { pool: OnceLock::new(), shards: self.shards.clone() }
    }
}

impl PartialEq for ShardedKernel {
    fn eq(&self, other: &Self) -> bool {
        // State only: the pool is not part of the replayable state.
        self.shards == other.shards
    }
}

impl ShardedKernel {
    /// Build `n_shards` empty kernels from a base config (the base's own
    /// shard spec is overwritten per shard).
    pub fn new(base: KernelConfig, n_shards: u32) -> Self {
        assert!(n_shards >= 1, "need at least one shard");
        let shards = (0..n_shards)
            .map(|s| Kernel::new(base.clone().with_shard(n_shards, s)))
            .collect();
        Self { pool: OnceLock::new(), shards }
    }

    /// Wrap an existing unsharded kernel as a 1-shard deployment
    /// (bit-compatible with its previous behaviour).
    pub fn from_single(kernel: Kernel) -> Self {
        assert_eq!(
            kernel.config().shard.n_shards,
            1,
            "from_single requires an unsharded kernel config"
        );
        Self { pool: OnceLock::new(), shards: vec![kernel] }
    }

    /// Rebuild from already-sharded kernels (snapshot restore). Shard
    /// specs must form a consistent deployment.
    pub fn from_shards(shards: Vec<Kernel>) -> Self {
        assert!(!shards.is_empty(), "need at least one shard");
        let n = shards.len() as u32;
        for (i, k) in shards.iter().enumerate() {
            assert_eq!(k.config().shard.n_shards, n, "shard {i}: wrong n_shards");
            assert_eq!(k.config().shard.shard_id, i as u32, "shard {i}: wrong shard_id");
        }
        Self { pool: OnceLock::new(), shards }
    }

    pub fn n_shards(&self) -> u32 {
        self.shards.len() as u32
    }

    /// The shard an external id routes to.
    pub fn shard_of(&self, id: u64) -> u32 {
        self.shards[0].config().shard.shard_of(id)
    }

    /// Read access to one shard's kernel.
    pub fn shard(&self, i: u32) -> &Kernel {
        &self.shards[i as usize]
    }

    pub fn shards(&self) -> &[Kernel] {
        &self.shards
    }

    /// The deployment config (shard 0's view; all shards share everything
    /// but `shard.shard_id`).
    pub fn config(&self) -> &KernelConfig {
        self.shards[0].config()
    }

    /// Total live vectors across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(Kernel::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total applied commands across shards. Note: under `n_shards > 1`
    /// this counts per-shard records (a batch splits; a delete may add
    /// cleanup unlinks), so it is the sum of shard clocks, not the count
    /// of client submissions.
    pub fn seq(&self) -> u64 {
        self.shards.iter().map(Kernel::seq).sum()
    }

    /// Resident vector-arena bytes summed across shards:
    /// `(exact Q16.16 arena, derived i8 code arena)` — the per-collection
    /// `memory_bytes` stat (and the observable 4× shrink of the SQ8 tier).
    pub fn arena_bytes(&self) -> (usize, usize) {
        self.shards.iter().fold((0, 0), |(e, c), k| {
            let (ke, kc) = k.arena_bytes();
            (e + ke, c + kc)
        })
    }

    pub fn contains(&self, id: u64) -> bool {
        self.owner(id).contains(id)
    }

    pub fn get_raw(&self, id: u64) -> Option<&[i32]> {
        self.owner(id).get_raw(id)
    }

    // lint: float-boundary — observability read-out, exact dequantization
    pub fn get_f32(&self, id: u64) -> Option<Vec<f32>> {
        self.owner(id).get_f32(id)
    }

    pub fn meta_of(&self, id: u64) -> Option<&std::collections::BTreeMap<String, String>> {
        self.owner(id).meta_of(id)
    }

    /// Whether the directed link exists (links live on `from`'s shard).
    pub fn has_link(&self, from: u64, to: u64) -> bool {
        self.owner(from).links().has_link(from, to)
    }

    fn owner(&self, id: u64) -> &Kernel {
        &self.shards[self.shard_of(id) as usize]
    }

    /// Boundary + routed transition: validate/canonicalize the external
    /// command, route it, and return both the canonical command and the
    /// per-shard records (for per-shard WAL/replication logs).
    pub fn apply(&mut self, cmd: Command) -> Result<ShardApply, StateError> {
        let canon = self.shards[0].canonicalize(cmd)?;
        let applied = self.apply_canon(&canon)?;
        Ok(ShardApply { canon, applied })
    }

    /// Route an already-canonical command (replication ingest). Atomic:
    /// every failure mode is checked before any shard mutates, so an error
    /// leaves all shards untouched.
    pub fn apply_canon(&mut self, canon: &CanonCommand) -> Result<Vec<Routed>, StateError> {
        match canon {
            CanonCommand::Insert { id, .. } => {
                let s = self.shard_of(*id);
                self.route(s, canon.clone())
            }
            CanonCommand::InsertBatch { items } => self.apply_batch(items),
            CanonCommand::Delete { id } => self.apply_delete(*id),
            CanonCommand::Link { from, to } => {
                // Global precondition (single-kernel parity, same error
                // order): both endpoints must be live somewhere.
                if !self.contains(*from) {
                    return Err(StateError::UnknownId(*from));
                }
                if !self.contains(*to) {
                    return Err(StateError::UnknownId(*to));
                }
                let s = self.shard_of(*from);
                self.route(s, canon.clone())
            }
            CanonCommand::Unlink { from, .. } => {
                let s = self.shard_of(*from);
                self.route(s, canon.clone())
            }
            CanonCommand::SetMeta { id, .. } => {
                let s = self.shard_of(*id);
                self.route(s, canon.clone())
            }
        }
    }

    /// Apply a command directly to one shard, bypassing the router — the
    /// per-shard WAL replay / log-shipping ingest path. The shard's own
    /// `WrongShard` check still rejects misrouted records.
    pub fn apply_canon_to_shard(
        &mut self,
        shard: u32,
        canon: &CanonCommand,
    ) -> Result<(), StateError> {
        self.shards[shard as usize].apply_canon(canon)
    }

    fn route(&mut self, shard: u32, command: CanonCommand) -> Result<Vec<Routed>, StateError> {
        let kernel = &mut self.shards[shard as usize];
        let seq = kernel.seq();
        kernel.apply_canon(&command)?;
        Ok(vec![Routed { shard, seq, command }])
    }

    /// Split a canonical (ascending-id) batch by shard and apply the
    /// sub-batches. Pre-validates every item on its target shard first so
    /// the whole batch is atomic across shards.
    fn apply_batch(&mut self, items: &[(u64, Vec<i32>)]) -> Result<Vec<Routed>, StateError> {
        if items.is_empty() || self.shards.len() == 1 {
            // Single-shard deployments (and the degenerate empty batch)
            // keep exact single-kernel semantics: one atomic record.
            return self.route(0, CanonCommand::InsertBatch { items: items.to_vec() });
        }
        // Pre-validate in *batch order* — the same checks, in the same
        // order, a single kernel runs — so the selected error is identical
        // to the unsharded reference, and no shard mutates on rejection.
        for w in items.windows(2) {
            if w[0].0 >= w[1].0 {
                return Err(StateError::DuplicateId(w[1].0));
            }
        }
        let config = self.shards[0].config();
        for (id, raw) in items {
            config.policy.validate_raw(raw, config.dim)?;
            if self.shards[self.shard_of(*id) as usize].ever_contains(*id) {
                return Err(StateError::DuplicateId(*id));
            }
        }
        let n = self.shards.len();
        let mut per_shard: Vec<Vec<(u64, Vec<i32>)>> = vec![Vec::new(); n];
        for (id, raw) in items {
            // Splitting a sorted batch preserves per-shard sortedness.
            per_shard[self.shard_of(*id) as usize].push((*id, raw.clone()));
        }
        if items.len() < Self::PARALLEL_UPSERT_MIN_ITEMS {
            // Small batches: channel dispatch costs more than it saves.
            // Either path applies the identical per-shard sub-batches in
            // the identical shard order, so the threshold — like the
            // search one — can only affect latency, never results.
            let mut applied = Vec::new();
            for (s, sub) in per_shard.into_iter().enumerate() {
                if sub.is_empty() {
                    continue;
                }
                // Cannot fail: exactly the checks above, re-run by the kernel.
                applied.extend(self.route(s as u32, CanonCommand::InsertBatch { items: sub })?);
            }
            return Ok(applied);
        }
        self.apply_batch_parallel(per_shard)
    }

    /// Apply per-shard sub-batches concurrently on the worker pool.
    /// `&mut self` gives this call exclusive access to every shard; each
    /// shard index is dispatched to at most one worker and the call blocks
    /// until every worker reports back, so the disjoint `&mut Kernel`s
    /// never alias and never escape the borrow. Every sub-batch was
    /// pre-validated on its target shard (the batch cannot fail
    /// mid-flight), and the applied records are collected in shard order —
    /// bit-identical to the sequential path no matter how workers are
    /// scheduled.
    fn apply_batch_parallel(
        &mut self,
        per_shard: Vec<Vec<(u64, Vec<i32>)>>,
    ) -> Result<Vec<Routed>, StateError> {
        // Field-precise borrows: the pool is borrowed shared while the
        // shards pointer is taken exclusively, so go through the field
        // (not `pool_ref`, which borrows all of `self`).
        let workers = self.effective_scan_workers();
        let pool = self.pool.get_or_init(|| ScanPool::new(workers));
        let base = self.shards.as_mut_ptr();
        let mut barrier: DispatchBarrier<Result<Routed, StateError>> = DispatchBarrier::new();
        for (s, sub) in per_shard.into_iter().enumerate() {
            if sub.is_empty() {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            barrier.add(rx);
            // SAFETY: `base.add(s)` stays inside the shards allocation and
            // each index is dispatched at most once (split-at-mut across
            // workers) — per-shard write serialization by construction.
            let shard_ptr = ExclusiveShard(unsafe { base.add(s) });
            pool.run(Box::new(move || {
                // SAFETY: see `ExclusiveShard` — exclusive, disjoint,
                // and outlived by the dispatching call's barrier.
                let kernel: &mut Kernel = unsafe { &mut *shard_ptr.0 };
                let seq = kernel.seq();
                let command = CanonCommand::InsertBatch { items: sub };
                let result = kernel
                    .apply_canon(&command)
                    .map(|()| Routed { shard: s as u32, seq, command });
                let _ = tx.send(result);
            }));
        }
        // Barrier FIRST — every job must have resolved (and released its
        // shard pointer) before anything, panic included, can leave this
        // frame — then propagate errors (unreachable after pre-validation).
        let results = barrier.wait_all();
        let mut applied = Vec::with_capacity(results.len());
        for r in results {
            applied.push(r.expect("shard upsert worker died")?);
        }
        Ok(applied)
    }

    /// Delete an id, emitting explicit cross-shard `Unlink` cleanup for
    /// edges on other shards that point at it (deterministic order: shard
    /// index, then ascending `from` id).
    fn apply_delete(&mut self, id: u64) -> Result<Vec<Routed>, StateError> {
        let owner = self.shard_of(id);
        if !self.shards[owner as usize].contains(id) {
            return Err(StateError::UnknownId(id));
        }
        let mut applied = Vec::new();
        for s in 0..self.shards.len() as u32 {
            if s == owner {
                continue; // the owner's remove_node cleans local edges
            }
            for from in self.shards[s as usize].links().links_to(id) {
                applied.extend(self.route(s, CanonCommand::Unlink { from, to: id })?);
            }
        }
        applied.extend(self.route(owner, CanonCommand::Delete { id })?);
        Ok(applied)
    }

    /// Below this many live vectors the per-shard searches run on the
    /// calling thread: even with persistent workers, channel dispatch and
    /// wakeup cost more than the scans they would parallelize. The merge
    /// is a pure function of the per-shard results either way, so the
    /// threshold cannot affect results — only latency.
    const PARALLEL_SEARCH_MIN_VECTORS: usize = 4096;

    /// Below this many items an `InsertBatch` applies its per-shard
    /// sub-batches inline (same rationale, and the same cannot-affect-
    /// results argument, as the search threshold).
    const PARALLEL_UPSERT_MIN_ITEMS: usize = 256;

    /// k-NN over raw quantized values: fan out (the shared chunk-claiming
    /// scan pool for large corpora, inline for small ones) and merge.
    /// Bit-identical to a single kernel holding all vectors when the
    /// index is exact; always identical across runs, platforms, worker
    /// counts and chunk sizes regardless of thread scheduling (results
    /// are collected in dispatch order and every reduction is over the
    /// total order `(dist_raw, id)`).
    pub fn search_raw(&self, query: &[i32], k: usize) -> Result<Vec<Hit>, StateError> {
        if self.shards.len() == 1 && self.len() < Self::PARALLEL_SEARCH_MIN_VECTORS {
            // Small single-shard corpus: the plain kernel path, no
            // dispatch overhead (and trivially bit-identical).
            return self.shards[0].search_raw(query, k);
        }
        self.validate_query(query)?;
        let per_shard = if self.len() < Self::PARALLEL_SEARCH_MIN_VECTORS {
            self.per_shard_inline(query, k)?
        } else {
            self.per_shard_pooled(query, k)?
        };
        Ok(merge_hits(&per_shard, k))
    }

    /// Force the inline (calling-thread) fan-out regardless of corpus
    /// size. Public for the pool-vs-inline equivalence tests and benches;
    /// results are identical to [`Self::search_raw`] by construction.
    pub fn search_raw_inline(&self, query: &[i32], k: usize) -> Result<Vec<Hit>, StateError> {
        if self.shards.len() == 1 {
            return self.shards[0].search_raw(query, k);
        }
        self.validate_query(query)?;
        Ok(merge_hits(&self.per_shard_inline(query, k)?, k))
    }

    /// Force the pooled fan-out regardless of corpus size (counterpart of
    /// [`Self::search_raw_inline`]). No single-shard shortcut here: one
    /// shard parallelizing across the whole pool is the point of the
    /// chunked scan, and the equivalence tests drive this entry directly.
    pub fn search_raw_pooled(&self, query: &[i32], k: usize) -> Result<Vec<Hit>, StateError> {
        self.validate_query(query)?;
        Ok(merge_hits(&self.per_shard_pooled(query, k)?, k))
    }

    /// Override the scan-worker count on every shard and retire the
    /// current pool: the next parallel operation lazily builds one at the
    /// new effective size. Read-path tuning only — results and hashes
    /// are unchanged by construction (see module docs).
    pub fn set_scan_workers(&mut self, workers: u32) {
        for shard in &mut self.shards {
            shard.set_scan_workers(workers);
        }
        self.pool = OnceLock::new();
    }

    /// Override the parallel-scan chunk size (slots) on every shard.
    /// Chunk boundaries move, results cannot (PERFORMANCE.md §9); the
    /// tests pin exactly that.
    pub fn set_scan_chunk(&mut self, chunk: u32) {
        for shard in &mut self.shards {
            shard.set_scan_chunk(chunk);
        }
    }

    /// Effective pool size: `min(cores, scan_workers)`, where a
    /// configured `0` means one worker per core.
    fn effective_scan_workers(&self) -> usize {
        let cores = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let configured = self.config().scan.workers;
        if configured == 0 {
            cores
        } else {
            cores.min(configured as usize)
        }
    }

    /// The shared scan pool, created on first use at the currently
    /// configured size.
    fn pool_ref(&self) -> &ScanPool {
        self.pool.get_or_init(|| ScanPool::new(self.effective_scan_workers()))
    }

    /// Validate once up front (all shards share the contract) so the
    /// fan-out cannot fail per-shard.
    fn validate_query(&self, query: &[i32]) -> Result<(), StateError> {
        let config = self.shards[0].config();
        if query.len() != config.dim {
            return Err(StateError::DimMismatch { expected: config.dim, got: query.len() });
        }
        config.policy.validate_raw(query, config.dim)?;
        Ok(())
    }

    fn per_shard_inline(&self, query: &[i32], k: usize) -> Result<Vec<Vec<Hit>>, StateError> {
        self.shards.iter().map(|shard| shard.search_raw(query, k)).collect()
    }

    /// Pooled fan-out, collected in dispatch order (never completion
    /// order). Flat-index deployments take the chunked intra-shard path;
    /// HNSW (no contiguous arena to sub-range) and degenerate queries
    /// fall back to one whole-shard job per shard — still on the shared
    /// pool, so cross-shard parallelism is preserved.
    fn per_shard_pooled(&self, query: &[i32], k: usize) -> Result<Vec<Vec<Hit>>, StateError> {
        // Config is uniform across shards (only `shard_id` differs), so
        // chunkability is uniform too.
        let chunkable = self.shards[0].flat_index().is_some() && self.config().dim > 0 && k > 0;
        if chunkable {
            self.per_shard_chunked(query, k)
        } else {
            self.per_shard_jobs(query, k)
        }
    }

    /// One whole-shard search job per shard on the shared pool (the
    /// non-chunkable fallback; also the write path's shape).
    fn per_shard_jobs(&self, query: &[i32], k: usize) -> Result<Vec<Vec<Hit>>, StateError> {
        let pool = self.pool_ref();
        // One dim-sized copy per query, shared by every job. Negligible
        // against the ≥ PARALLEL_SEARCH_MIN_VECTORS scan this path is
        // gated on, and it keeps the query owned (`'static`) rather than
        // widening the raw-pointer surface to a second borrow.
        let query: Arc<Vec<i32>> = Arc::new(query.to_vec());
        let mut barrier: DispatchBarrier<Result<Vec<Hit>, StateError>> = DispatchBarrier::new();
        for shard in &self.shards {
            let (tx, rx) = mpsc::channel();
            barrier.add(rx);
            let shard_ptr = SharedShard(shard as *const Kernel);
            let query = Arc::clone(&query);
            pool.run(Box::new(move || {
                // SAFETY: see `SharedShard` — the dispatching call waits
                // on the barrier until this job resolves, so the shard
                // (borrowed from `&self`) outlives the job; searches only
                // read.
                let shard: &Kernel = unsafe { &*shard_ptr.0 };
                maybe_panic(k);
                let _ = tx.send(shard.search_raw(&query, k));
            }));
        }
        // Barrier FIRST — every job must have resolved (and released its
        // shard pointer) before any result, even an error or panic, can
        // leave this frame — then sequence the per-shard results in
        // dispatch (= shard) order.
        let results = barrier.wait_all();
        let mut per_shard = Vec::with_capacity(results.len());
        for r in results {
            per_shard.push(r.map_err(|_| StateError::ScanPoisoned)??);
        }
        Ok(per_shard)
    }

    /// Chunk-claiming parallel scan over every shard's flat arena. Per
    /// shard, `min(workers, n_chunks)` lane tasks claim fixed-size slot
    /// sub-ranges off a shared atomic counter and scan each into a local
    /// `TopK`; the lane heaps then merge into the shard's top-k. *Which*
    /// lane scans which chunk is scheduling-dependent — the result is
    /// not, because the chunks partition the slot space exactly and the
    /// bounded top-k is a pure function of the pushed multiset
    /// (PERFORMANCE.md §9). SQ8 shards run two waves: phase-1 i8 chunk
    /// scans keep `overscan * k` candidates, then phase-2 exact re-rank
    /// splits the (deterministically ordered) candidate list into
    /// chunk-sized tasks. The exact-vs-two-phase decision is made per
    /// shard with the same rule the sequential [`crate::index::FlatIndex`]
    /// path uses, so every worker count — one included — reproduces the
    /// sequential bits.
    fn per_shard_chunked(&self, query: &[i32], k: usize) -> Result<Vec<Vec<Hit>>, StateError> {
        let pool = self.pool_ref();
        let workers = pool.workers();
        let chunk = self.config().scan.chunk.max(1) as usize;
        let query: Arc<Vec<i32>> = Arc::new(query.to_vec());
        // Query codes are computed once and shared by every phase-1 lane
        // (encoding is pure per component, so per-lane encoding would be
        // identical — sharing is just cheaper).
        let qcodes: Option<Arc<Vec<i8>>> = match self.config().quant {
            QuantSpec::Sq8 { .. } => Quantizer::encode_query(query.as_slice()).map(Arc::new),
            QuantSpec::None => None,
        };
        // Per-shard plan, exactly mirroring the sequential decision:
        // two-phase iff the code arena is usable, the query encodes, and
        // `overscan * k` cannot cover the live set (at coverage the exact
        // sweep is cheaper and bit-identical).
        let plans: Vec<ShardPlan> = self
            .shards
            .iter()
            .map(|shard| {
                let flat = shard.flat_index().expect("chunked scan requires a flat index");
                match flat.sq8_ready() {
                    Some(overscan)
                        if qcodes.is_some()
                            && (overscan as u64).saturating_mul(k as u64)
                                < flat.store().live_len() as u64 =>
                    {
                        ShardPlan::Sq8 { overscan }
                    }
                    _ => ShardPlan::Exact,
                }
            })
            .collect();

        // Phase 1: one wave of chunk-claiming lanes across all shards
        // (shard-major dispatch; lanes of different shards interleave
        // freely on the pool).
        let mut barrier: DispatchBarrier<LaneOut> = DispatchBarrier::new();
        let mut lane_counts: Vec<usize> = Vec::with_capacity(self.shards.len());
        for (s, shard) in self.shards.iter().enumerate() {
            let slots =
                shard.flat_index().expect("chunked scan requires a flat index").store().slots();
            let lanes = workers.min(slots.div_ceil(chunk));
            lane_counts.push(lanes);
            let counter = Arc::new(AtomicUsize::new(0));
            let plan = plans[s];
            for _ in 0..lanes {
                let (tx, rx) = mpsc::channel();
                barrier.add(rx);
                let shard_ptr = SharedShard(shard as *const Kernel);
                let query = Arc::clone(&query);
                let qcodes = qcodes.clone();
                let counter = Arc::clone(&counter);
                pool.run(Box::new(move || {
                    // SAFETY: see `SharedShard` — the dispatching call
                    // waits on the barrier until this job resolves, so
                    // the shard outlives the job; scans only read.
                    let flat = unsafe { &*shard_ptr.0 }
                        .flat_index()
                        .expect("chunked job on a non-flat shard");
                    maybe_panic(k);
                    let out = match plan {
                        ShardPlan::Exact => {
                            let mut local = TopK::new(k);
                            loop {
                                let lo = counter
                                    .fetch_add(1, Ordering::Relaxed)
                                    .saturating_mul(chunk);
                                if lo >= slots {
                                    break;
                                }
                                flat.scan_exact_range(
                                    &query,
                                    lo,
                                    (lo + chunk).min(slots),
                                    &mut local,
                                );
                            }
                            LaneOut::Exact(local)
                        }
                        ShardPlan::Sq8 { overscan } => {
                            let qcodes =
                                qcodes.as_deref().expect("sq8 plan without query codes");
                            let mut local = TopK::new((overscan as usize).saturating_mul(k));
                            loop {
                                let lo = counter
                                    .fetch_add(1, Ordering::Relaxed)
                                    .saturating_mul(chunk);
                                if lo >= slots {
                                    break;
                                }
                                flat.scan_sq8_range(
                                    qcodes,
                                    lo,
                                    (lo + chunk).min(slots),
                                    &mut local,
                                );
                            }
                            LaneOut::Approx(local)
                        }
                    };
                    let _ = tx.send(out);
                }));
            }
        }
        // Reduce lanes per shard, in dispatch order. The merge is a pure
        // function of the lane heaps' multiset union — completion order
        // and chunk assignment cannot change it.
        let mut results = barrier.wait_all().into_iter();
        let mut per_shard: Vec<Option<Vec<Hit>>> = vec![None; self.shards.len()];
        let mut rerank: Vec<(usize, Arc<Vec<IndexHit<i32>>>)> = Vec::new();
        for (s, &lanes) in lane_counts.iter().enumerate() {
            match plans[s] {
                ShardPlan::Exact => {
                    let mut merged = TopK::new(k);
                    for _ in 0..lanes {
                        let lane = results
                            .next()
                            .expect("lane accounting")
                            .map_err(|_| StateError::ScanPoisoned)?;
                        match lane {
                            LaneOut::Exact(local) => merged.merge(local),
                            LaneOut::Approx(_) => unreachable!("exact plan produced approx lane"),
                        }
                    }
                    per_shard[s] = Some(exact_hits(merged));
                }
                ShardPlan::Sq8 { overscan } => {
                    let mut merged = TopK::new((overscan as usize).saturating_mul(k));
                    for _ in 0..lanes {
                        let lane = results
                            .next()
                            .expect("lane accounting")
                            .map_err(|_| StateError::ScanPoisoned)?;
                        match lane {
                            LaneOut::Approx(local) => merged.merge(local),
                            LaneOut::Exact(_) => unreachable!("sq8 plan produced exact lane"),
                        }
                    }
                    // Same candidate multiset — and, via `(dist, id)`
                    // sorting, the same candidate *list* — as the
                    // sequential phase 1 over the whole arena.
                    rerank.push((s, Arc::new(merged.into_sorted_hits())));
                }
            }
        }

        // Phase 2 (SQ8 shards only): exact re-rank of the candidates,
        // split into chunk-sized tasks. A static partition is already
        // bit-safe — each candidate's exact key is pure — so no claiming
        // counter is needed here.
        let mut barrier2: DispatchBarrier<TopK<i64>> = DispatchBarrier::new();
        let mut rerank_tasks: Vec<(usize, usize)> = Vec::with_capacity(rerank.len());
        for (s, cands) in &rerank {
            let n_tasks = cands.len().div_ceil(chunk).max(1);
            rerank_tasks.push((*s, n_tasks));
            for t in 0..n_tasks {
                let (tx, rx) = mpsc::channel();
                barrier2.add(rx);
                let shard_ptr = SharedShard(&self.shards[*s] as *const Kernel);
                let query = Arc::clone(&query);
                let cands = Arc::clone(cands);
                let lo = t * chunk;
                pool.run(Box::new(move || {
                    // SAFETY: as above — the second barrier holds this
                    // frame open until the job resolves.
                    let flat = unsafe { &*shard_ptr.0 }
                        .flat_index()
                        .expect("rerank job on a non-flat shard");
                    let hi = (lo + chunk).min(cands.len());
                    let mut local = TopK::new(k);
                    flat.rerank_into(&query, &cands[lo..hi], &mut local);
                    let _ = tx.send(local);
                }));
            }
        }
        let mut results2 = barrier2.wait_all().into_iter();
        for (s, n_tasks) in rerank_tasks {
            let mut merged = TopK::new(k);
            for _ in 0..n_tasks {
                merged.merge(
                    results2
                        .next()
                        .expect("rerank accounting")
                        .map_err(|_| StateError::ScanPoisoned)?,
                );
            }
            per_shard[s] = Some(exact_hits(merged));
        }
        Ok(per_shard.into_iter().map(|hits| hits.expect("every shard resolved")).collect())
    }

    /// k-NN over a float query (same boundary as inserts, then integer
    /// search — see [`Kernel::search_f32`]).
    // lint: float-boundary — query entry point, floats stop at from_f32
    pub fn search_f32(&self, query: &[f32], k: usize) -> Result<Vec<Hit>, StateError> {
        let config = self.shards[0].config();
        let fv = FixedVector::from_f32(query, config.dim, &config.policy)?;
        self.search_raw(fv.raw(), k)
    }

    /// Per-shard FNV state hashes (the manifest replicas compare
    /// shard-by-shard to pinpoint divergence).
    pub fn shard_hashes(&self) -> Vec<u64> {
        self.shards.iter().map(Kernel::state_hash).collect()
    }

    /// Combined root hash: `fnv(n_shards ‖ h_0 ‖ … ‖ h_{n-1})`. A pure
    /// function of the per-shard hashes, so two nodes that agree on every
    /// shard agree on the root, and any single-shard divergence flips it.
    pub fn root_hash(&self) -> u64 {
        root_hash_of(&self.shard_hashes())
    }

    // ------------------------------------------------------------------
    // Verifiable state receipts (PR-10): per-shard Merkle roots and
    // record-level proofs/repair, alongside the fast FNV manifest above.
    // See `crate::proof` for the tree and encoding definitions.
    // ------------------------------------------------------------------

    /// Per-shard Merkle roots — audit-grade SHA-256 companions to
    /// [`ShardedKernel::shard_hashes`]. Each is maintained incrementally
    /// by its kernel (O(log n) per applied command).
    pub fn merkle_shard_roots(&self) -> Vec<[u8; 32]> {
        self.shards.iter().map(Kernel::merkle_root).collect()
    }

    /// Combined Merkle root over the ordered per-shard roots
    /// ([`crate::proof::combined_root`]) — the receipt's headline value.
    pub fn merkle_root(&self) -> [u8; 32] {
        combined_root(&self.merkle_shard_roots())
    }

    /// Membership proof for `id` on its owning shard (live records and
    /// tombstones alike). `None` if the id was never inserted.
    pub fn merkle_proof(&self, id: u64) -> Option<MembershipProof> {
        self.shards[self.shard_of(id) as usize].merkle_proof(id)
    }

    /// Bisection access for Merkle-diff: `count` node hashes of `shard`'s
    /// tree at `level` (0 = leaves) starting at `from`. `None` if the
    /// shard, level, or range is out of bounds.
    pub fn merkle_level(
        &self,
        shard: u32,
        level: usize,
        from: usize,
        count: usize,
    ) -> Option<Vec<[u8; 32]>> {
        self.shards.get(shard as usize)?.merkle_level(level, from, count)
    }

    /// Canonical leaf encoding of `slot` on `shard` (`None` beyond the
    /// shard's arena) — the byte string a repairer transfers for a
    /// diverged record.
    pub fn merkle_leaf_encoding(&self, shard: u32, slot: u32) -> Option<Vec<u8>> {
        self.shards.get(shard as usize)?.merkle_leaf_encoding(slot)
    }

    /// Record-level divergence repair on one shard: un-logged state
    /// surgery that overwrites `slot` with the canonical record (see
    /// [`Kernel::repair_slot`]; the shard's logical clock is untouched).
    /// A shard index out of range reports as `SlotOutOfRange`.
    pub fn repair_slot(
        &mut self,
        shard: u32,
        slot: u32,
        rec: &LeafRecord,
    ) -> Result<(), RepairError> {
        let kernel =
            self.shards.get_mut(shard as usize).ok_or(RepairError::SlotOutOfRange)?;
        kernel.repair_slot(slot, rec)
    }
}

/// Root hash over an ordered list of per-shard state hashes (exposed so
/// snapshot manifests and remote verification can recompute it without a
/// kernel).
pub fn root_hash_of(shard_hashes: &[u64]) -> u64 {
    let mut h = Fnv1a64::new();
    h.update_u32(shard_hashes.len() as u32);
    for &hash in shard_hashes {
        h.update_u64(hash);
    }
    h.finish()
}

/// Per-shard execution plan for the chunked scan (mirrors the sequential
/// exact-vs-two-phase decision in the flat index's `search`).
#[derive(Clone, Copy)]
enum ShardPlan {
    Exact,
    Sq8 { overscan: u32 },
}

/// One phase-1 lane's local reduction: exact `(dist_raw, id)` keys, or
/// SQ8 `(approx_dist, id)` keys awaiting the exact re-rank.
enum LaneOut {
    Exact(TopK<i64>),
    Approx(TopK<i32>),
}

/// Render a merged exact `TopK` into kernel [`Hit`]s — the same mapping
/// [`Kernel::search_raw`] applies, so pooled and sequential results are
/// byte-identical.
fn exact_hits(topk: TopK<i64>) -> Vec<Hit> {
    topk.into_sorted_hits()
        .into_iter()
        .map(|h| Hit { id: h.id, dist_raw: h.dist, dist: <i32 as Scalar>::dist_to_f64(h.dist) })
        .collect()
}

/// Test-only fault injection: a scan job panics iff the armed sentinel
/// matches its `k`. Keyed on an improbable exact `k` so concurrent tests
/// sharing the process can never trip each other's injection.
#[cfg(test)]
static PANIC_ON_K: AtomicUsize = AtomicUsize::new(0);

#[cfg(test)]
fn maybe_panic(k: usize) {
    let armed = PANIC_ON_K.load(Ordering::SeqCst);
    if armed != 0 && armed == k {
        panic!("injected scan-task panic (k = {k})");
    }
}

#[cfg(not(test))]
fn maybe_panic(_k: usize) {}

/// Deterministic merge of per-shard hit lists (each already its shard's
/// top-k under `(dist_raw, id)`) into the global top-k: every candidate
/// streams through the same bounded [`TopK`] heap the index read paths
/// use, keyed on the same total order. A pure function of the per-shard
/// result *multiset* — list order, shard order and thread scheduling
/// cannot change the output — and bit-identical to the former k-way
/// cursor merge (both select the k smallest keys and emit them
/// ascending; `dist` is a pure function of `dist_raw`).
fn merge_hits(per_shard: &[Vec<Hit>], k: usize) -> Vec<Hit> {
    let mut topk = TopK::new(k);
    for hits in per_shard {
        for h in hits {
            topk.push(h.dist_raw, h.id);
        }
    }
    topk.into_sorted_hits()
        .into_iter()
        .map(|h| Hit { id: h.id, dist_raw: h.dist, dist: <i32 as Scalar>::dist_to_f64(h.dist) })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_config(dim: usize) -> KernelConfig {
        KernelConfig::default_q16(dim).with_flat_index()
    }

    fn vecs(n: u64, dim: usize) -> Vec<(u64, Vec<f32>)> {
        (0..n)
            .map(|i| {
                let v: Vec<f32> = (0..dim)
                    .map(|j| ((i * dim as u64 + j as u64) as f32 * 0.113).sin() * 0.8)
                    .collect();
                (i, v)
            })
            .collect()
    }

    #[test]
    fn routing_is_total_and_stable() {
        let sk = ShardedKernel::new(flat_config(4), 4);
        for id in 0..1000u64 {
            let s = sk.shard_of(id);
            assert!(s < 4);
            assert_eq!(s, sk.shard_of(id), "routing must be a pure function");
        }
        // splitmix64 disperses: every shard owns a decent share
        let mut counts = [0usize; 4];
        for id in 0..1000u64 {
            counts[sk.shard_of(id) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 150), "skewed routing: {counts:?}");
    }

    #[test]
    fn sharded_search_matches_single_kernel_exactly() {
        for n_shards in [1u32, 2, 4, 8] {
            let mut single = Kernel::new(flat_config(8));
            let mut sharded = ShardedKernel::new(flat_config(8), n_shards);
            for (id, v) in vecs(200, 8) {
                single.apply(Command::insert(id, v.clone())).unwrap();
                sharded.apply(Command::insert(id, v)).unwrap();
            }
            for t in 0..20 {
                let q: Vec<f32> =
                    (0..8).map(|j| ((t * 8 + j) as f32 * 0.07).cos() * 0.7).collect();
                assert_eq!(
                    sharded.search_f32(&q, 10).unwrap(),
                    single.search_f32(&q, 10).unwrap(),
                    "n_shards={n_shards} query {t}"
                );
            }
        }
    }

    #[test]
    fn merge_is_pure_function_of_shard_results() {
        let a = vec![
            Hit { id: 1, dist_raw: 5, dist: 0.0 },
            Hit { id: 9, dist_raw: 20, dist: 0.0 },
        ];
        let b = vec![
            Hit { id: 2, dist_raw: 5, dist: 0.0 },
            Hit { id: 3, dist_raw: 7, dist: 0.0 },
        ];
        let merged = merge_hits(&[a.clone(), b.clone()], 3);
        // ties on dist_raw resolve by id: 1 before 2
        assert_eq!(merged.iter().map(|h| h.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        // k larger than total yields everything, still ordered
        let all = merge_hits(&[a, b], 10);
        assert_eq!(all.iter().map(|h| h.id).collect::<Vec<_>>(), vec![1, 2, 3, 9]);
        assert!(merge_hits(&[], 5).is_empty());
    }

    #[test]
    fn batch_splits_and_stays_atomic_across_shards() {
        let mut sk = ShardedKernel::new(flat_config(2), 4);
        let items: Vec<(u64, Vec<f32>)> =
            (0..40).map(|i| (i, vec![i as f32 * 0.01, 0.5])).collect();
        let result = sk.apply(Command::InsertBatch { items }).unwrap();
        assert!(result.applied.len() > 1, "40 ids should hit several shards");
        assert_eq!(sk.len(), 40);

        // one duplicate poisons the whole batch on every shard
        let hashes_before = sk.shard_hashes();
        let err = sk
            .apply(Command::InsertBatch {
                items: vec![(100, vec![0.0, 0.0]), (7, vec![0.0, 0.0])],
            })
            .unwrap_err();
        assert_eq!(err, StateError::DuplicateId(7));
        assert_eq!(sk.shard_hashes(), hashes_before, "failed batch must not touch any shard");
        assert!(!sk.contains(100));
    }

    #[test]
    fn cross_shard_links_and_delete_cleanup() {
        let mut sk = ShardedKernel::new(flat_config(2), 4);
        // find two ids on different shards
        let a = 0u64;
        let b = (1..64).find(|&i| sk.shard_of(i) != sk.shard_of(a)).unwrap();
        sk.apply(Command::insert(a, vec![0.1, 0.2])).unwrap();
        sk.apply(Command::insert(b, vec![0.3, 0.4])).unwrap();
        sk.apply(Command::Link { from: a, to: b }).unwrap();
        assert!(sk.has_link(a, b));

        // linking to a dead id fails with single-kernel error semantics
        let err = sk.apply(Command::Link { from: a, to: 9999 }).unwrap_err();
        assert_eq!(err, StateError::UnknownId(9999));

        // deleting b emits an unlink on a's shard before the delete
        let result = sk.apply(Command::Delete { id: b }).unwrap();
        let kinds: Vec<&str> = result.applied.iter().map(|r| r.command.name()).collect();
        assert_eq!(kinds, vec!["unlink", "delete"]);
        assert!(!sk.has_link(a, b), "dangling link must be cleaned up");
        assert!(!sk.contains(b));
    }

    #[test]
    fn replaying_per_shard_logs_reproduces_root_hash() {
        let mut sk = ShardedKernel::new(flat_config(4), 4);
        let mut logs: Vec<Vec<CanonCommand>> = vec![Vec::new(); 4];
        for (id, v) in vecs(120, 4) {
            let r = sk.apply(Command::insert(id, v)).unwrap();
            for routed in r.applied {
                logs[routed.shard as usize].push(routed.command);
            }
        }
        for id in [3u64, 17, 40] {
            let r = sk.apply(Command::Delete { id }).unwrap();
            for routed in r.applied {
                logs[routed.shard as usize].push(routed.command);
            }
        }
        let mut replayed = ShardedKernel::new(flat_config(4), 4);
        for (s, log) in logs.iter().enumerate() {
            for cmd in log {
                replayed.apply_canon_to_shard(s as u32, cmd).unwrap();
            }
        }
        assert_eq!(replayed.shard_hashes(), sk.shard_hashes());
        assert_eq!(replayed.root_hash(), sk.root_hash());
        assert_eq!(replayed, sk);
    }

    #[test]
    fn misrouted_log_entry_is_rejected() {
        let mut sk = ShardedKernel::new(flat_config(2), 4);
        let id = 5u64;
        let wrong = (sk.shard_of(id) + 1) % 4;
        let canon = CanonCommand::Insert { id, raw: vec![100, 200] };
        let err = sk.apply_canon_to_shard(wrong, &canon).unwrap_err();
        assert!(matches!(err, StateError::WrongShard { .. }), "got {err:?}");
    }

    #[test]
    fn root_hash_covers_every_shard() {
        let mut a = ShardedKernel::new(flat_config(2), 4);
        let mut b = ShardedKernel::new(flat_config(2), 4);
        for (id, v) in vecs(60, 2) {
            a.apply(Command::insert(id, v.clone())).unwrap();
            b.apply(Command::insert(id, v)).unwrap();
        }
        assert_eq!(a.root_hash(), b.root_hash());
        // perturb one shard only
        let id = (0..u64::MAX).find(|&i| !b.contains(i) && b.shard_of(i) == 2).unwrap();
        b.apply(Command::insert(id, vec![0.9, 0.9])).unwrap();
        assert_ne!(a.root_hash(), b.root_hash());
        let (ha, hb) = (a.shard_hashes(), b.shard_hashes());
        let diverged: Vec<usize> =
            (0..4).filter(|&s| ha[s] != hb[s]).collect();
        assert_eq!(diverged, vec![2], "manifest must pinpoint the diverged shard");
    }

    #[test]
    fn merkle_roots_pinpoint_and_repair_single_record_divergence() {
        let mut a = ShardedKernel::new(flat_config(2), 4);
        let mut b = ShardedKernel::new(flat_config(2), 4);
        for (id, v) in vecs(60, 2) {
            a.apply(Command::insert(id, v.clone())).unwrap();
            b.apply(Command::insert(id, v)).unwrap();
        }
        assert_eq!(a.merkle_root(), b.merkle_root());
        assert_eq!(a.merkle_shard_roots(), b.merkle_shard_roots());

        // corrupt exactly one record on b via the repair path (seq-neutral)
        let id = 7u64;
        let shard = b.shard_of(id);
        let proof = b.merkle_proof(id).unwrap();
        assert_eq!(proof.shard, shard as u64);
        let mut rec = crate::proof::leaf::decode(&proof.record).unwrap();
        if let crate::proof::LeafBody::Live { vector, .. } = &mut rec.body {
            vector[0] ^= 1;
        }
        b.repair_slot(shard, proof.slot as u32, &rec).unwrap();
        let (ra, rb) = (a.merkle_shard_roots(), b.merkle_shard_roots());
        let diverged: Vec<usize> = (0..4).filter(|&s| ra[s] != rb[s]).collect();
        assert_eq!(diverged, vec![shard as usize], "roots must pinpoint the shard");
        assert_ne!(a.merkle_root(), b.merkle_root());

        // transfer the canonical leaf from a and repair: full convergence
        let good_slot = a.merkle_proof(id).unwrap().slot as u32;
        let good = crate::proof::leaf::decode(
            &a.merkle_leaf_encoding(shard, good_slot).unwrap(),
        )
        .unwrap();
        b.repair_slot(shard, good_slot, &good).unwrap();
        assert_eq!(a.merkle_root(), b.merkle_root());
        assert_eq!(a.shard_hashes(), b.shard_hashes());
        assert_eq!(a.root_hash(), b.root_hash());

        // bisection accessors agree with the proof path
        let cap = proof.capacity as usize;
        let leaves = b.merkle_level(shard, 0, 0, cap).unwrap();
        assert_eq!(leaves.len(), cap);
        assert!(b.merkle_level(99, 0, 0, 1).is_none());
    }

    #[test]
    fn pooled_and_inline_fanout_agree() {
        for n_shards in [2u32, 4] {
            let mut sk = ShardedKernel::new(flat_config(8), n_shards);
            for (id, v) in vecs(300, 8) {
                sk.apply(Command::insert(id, v)).unwrap();
            }
            let config = sk.config().clone();
            for t in 0..10 {
                let q: Vec<f32> =
                    (0..8).map(|j| ((t * 8 + j) as f32 * 0.19).sin() * 0.6).collect();
                let fv = FixedVector::from_f32(&q, config.dim, &config.policy).unwrap();
                let inline = sk.search_raw_inline(fv.raw(), 10).unwrap();
                let pooled = sk.search_raw_pooled(fv.raw(), 10).unwrap();
                assert_eq!(inline, pooled, "n_shards={n_shards} query {t}");
            }
        }
    }

    #[test]
    fn parallel_batch_upsert_is_replay_invariant() {
        // Above PARALLEL_UPSERT_MIN_ITEMS the sub-batches apply on the
        // worker pool. Scheduling must be invisible: the applied records
        // (collected in shard order) replayed per shard reproduce the
        // exact state, and search agrees with an unsharded reference.
        let n = ShardedKernel::PARALLEL_UPSERT_MIN_ITEMS as u64 + 50;
        let items: Vec<(u64, Vec<f32>)> =
            (0..n).map(|i| (i, vec![(i as f32 * 0.003).sin(), 0.25])).collect();
        let mut big = ShardedKernel::new(flat_config(2), 4);
        let result = big.apply(Command::InsertBatch { items: items.clone() }).unwrap();

        // One record per participating shard, in shard order.
        let mut shards_seen: Vec<u32> = result.applied.iter().map(|r| r.shard).collect();
        let sorted = {
            let mut v = shards_seen.clone();
            v.sort_unstable();
            v
        };
        assert_eq!(shards_seen, sorted, "records must be in shard order");
        shards_seen.dedup();
        assert_eq!(shards_seen.len(), 4, "every shard should participate");

        // Replaying the per-shard records reproduces the state bit-for-bit.
        let mut replayed = ShardedKernel::new(flat_config(2), 4);
        for r in &result.applied {
            replayed.apply_canon_to_shard(r.shard, &r.command).unwrap();
        }
        assert_eq!(replayed.shard_hashes(), big.shard_hashes());
        assert_eq!(replayed, big);

        // And search agrees with a single unsharded kernel fed the same batch.
        let mut single = Kernel::new(flat_config(2));
        single.apply(Command::InsertBatch { items }).unwrap();
        let q = [0.1f32, 0.2];
        assert_eq!(big.search_f32(&q, 15).unwrap(), single.search_f32(&q, 15).unwrap());

        // A delete afterwards still behaves (pool stays healthy).
        big.apply(Command::Delete { id: 3 }).unwrap();
        assert!(!big.contains(3));
    }

    #[test]
    fn clone_and_eq_ignore_the_worker_pool() {
        let mut sk = ShardedKernel::new(flat_config(4), 4);
        for (id, v) in vecs(5000, 4) {
            sk.apply(Command::insert(id, v)).unwrap();
        }
        // Force pool creation on the original…
        let fv = FixedVector::from_f32(&[0.1, 0.2, 0.3, 0.4], 4, &sk.config().policy).unwrap();
        let expect = sk.search_raw_pooled(fv.raw(), 10).unwrap();
        // …then clone (fresh lazy pool) and compare.
        let cloned = sk.clone();
        assert_eq!(sk, cloned);
        assert_eq!(cloned.search_raw(fv.raw(), 10).unwrap(), expect);
        assert_eq!(cloned.root_hash(), sk.root_hash());
    }

    #[test]
    fn scan_pool_survives_a_panicking_job() {
        // One worker, so the follow-up job *must* run on the respawned
        // replacement — a hang here means respawn is broken.
        let pool = ScanPool::new(1);
        let (tx, rx) = mpsc::channel::<i32>();
        pool.run(Box::new(move || {
            let _tx = tx; // dropped without sending, during the unwind
            panic!("injected job panic");
        }));
        assert!(rx.recv().is_err(), "panicked job must resolve its channel with Err");
        let (tx2, rx2) = mpsc::channel::<i32>();
        pool.run(Box::new(move || {
            let _ = tx2.send(42);
        }));
        assert_eq!(rx2.recv(), Ok(42));
        drop(pool); // shutdown joins cleanly even after a respawn
    }

    #[test]
    fn panicked_scan_task_poisons_only_that_query() {
        // The injection sentinel: a k no other test uses, so concurrent
        // tests sharing the process-wide hook can never trip it.
        const SENTINEL_K: usize = 31337;
        let mut sk = ShardedKernel::new(flat_config(4), 1);
        for (id, v) in vecs(600, 4) {
            sk.apply(Command::insert(id, v)).unwrap();
        }
        sk.set_scan_chunk(64);
        let fv =
            FixedVector::from_f32(&[0.2, -0.1, 0.3, 0.05], 4, &sk.config().policy).unwrap();
        let expect = sk.search_raw_pooled(fv.raw(), 10).unwrap();

        PANIC_ON_K.store(SENTINEL_K, Ordering::SeqCst);
        let err = sk.search_raw_pooled(fv.raw(), SENTINEL_K).unwrap_err();
        PANIC_ON_K.store(0, Ordering::SeqCst);
        assert_eq!(err, StateError::ScanPoisoned, "panicked task must fail its own query");

        // Only that query: the pool recovered and the same search returns
        // the original bits.
        assert_eq!(sk.search_raw_pooled(fv.raw(), 10).unwrap(), expect);
    }

    #[test]
    fn one_shard_pooled_scan_matches_inline() {
        // The point of the chunked scan: a single shard parallelizes, and
        // the pooled result is bit-identical to the plain kernel's.
        let mut sk = ShardedKernel::new(flat_config(4), 1);
        for (id, v) in vecs(5000, 4) {
            sk.apply(Command::insert(id, v)).unwrap();
        }
        let fv =
            FixedVector::from_f32(&[0.3, -0.2, 0.1, 0.4], 4, &sk.config().policy).unwrap();
        // Above the corpus threshold search_raw itself takes the pooled path.
        let pooled = sk.search_raw(fv.raw(), 10).unwrap();
        assert_eq!(pooled, sk.shard(0).search_raw(fv.raw(), 10).unwrap());
        assert_eq!(pooled, sk.search_raw_pooled(fv.raw(), 10).unwrap());
    }

    #[test]
    fn hnsw_shards_use_whole_shard_jobs() {
        // No contiguous arena to chunk: the pooled path falls back to one
        // job per shard and still agrees with the inline fan-out.
        let mut sk = ShardedKernel::new(KernelConfig::default_q16(8), 2);
        for (id, v) in vecs(300, 8) {
            sk.apply(Command::insert(id, v)).unwrap();
        }
        let fv = FixedVector::from_f32(&[0.1f32; 8], 8, &sk.config().policy).unwrap();
        assert_eq!(
            sk.search_raw_pooled(fv.raw(), 10).unwrap(),
            sk.search_raw_inline(fv.raw(), 10).unwrap()
        );
    }

    #[test]
    fn single_shard_matches_plain_kernel_bit_for_bit() {
        let mut plain = Kernel::new(KernelConfig::default_q16(4));
        let mut sk = ShardedKernel::new(KernelConfig::default_q16(4), 1);
        for (id, v) in vecs(50, 4) {
            plain.apply(Command::insert(id, v.clone())).unwrap();
            sk.apply(Command::insert(id, v)).unwrap();
        }
        plain.apply(Command::Delete { id: 7 }).unwrap();
        sk.apply(Command::Delete { id: 7 }).unwrap();
        assert_eq!(sk.shard(0).state_hash(), plain.state_hash());
        assert_eq!(sk.shard(0).to_state_bytes(), plain.to_state_bytes());
    }
}
