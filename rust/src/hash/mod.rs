//! Deterministic hashing substrate.
//!
//! Valori needs hashes in three places, all of which must be stable across
//! platforms, processes and releases (std's `DefaultHasher` guarantees none
//! of that):
//!
//! 1. **State hashes** (paper §8.1, §9): FNV-1a 64 over the canonical
//!    snapshot byte stream, compared across machines/nodes.
//! 2. **HNSW level assignment** (paper §7.2 "data-dependent ordering"):
//!    splitmix64 of the vector id.
//! 3. **Tokenization**: hashing words into the embedder vocabulary.
//!
//! A small deterministic PRNG (xorshift) is also provided for the test and
//! workload-generation substrates.

#![forbid(unsafe_code)]

pub mod crc32;
pub mod sha256;

pub use crc32::crc32;
pub use sha256::{sha256, sha256_hex, Sha256};

/// FNV-1a 64-bit streaming hasher. Stable, dependency-free, fast enough for
/// snapshot-sized inputs; SHA-256 (in-tree, [`sha256`]) is additionally
/// recorded for audit contexts — see [`crate::snapshot`].
#[derive(Debug, Clone)]
pub struct Fnv1a64 {
    state: u64,
}

pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;
pub const FNV_PRIME: u64 = 0x100000001b3;

impl Default for Fnv1a64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a64 {
    pub fn new() -> Self {
        Self { state: FNV_OFFSET }
    }

    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.state = h;
    }

    #[inline]
    pub fn update_u32(&mut self, v: u32) {
        self.update(&v.to_le_bytes());
    }

    #[inline]
    pub fn update_u64(&mut self, v: u64) {
        self.update(&v.to_le_bytes());
    }

    #[inline]
    pub fn update_i32(&mut self, v: i32) {
        self.update(&v.to_le_bytes());
    }

    #[inline]
    pub fn update_i64(&mut self, v: i64) {
        self.update(&v.to_le_bytes());
    }

    #[inline]
    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot FNV-1a 64.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a64::new();
    h.update(bytes);
    h.finish()
}

/// Lowercase hex encoding of arbitrary bytes. The canonical byte-string
/// wire format for receipts and membership proofs ([`crate::proof`]).
pub fn hex_lower(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        out.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        out.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    out
}

/// Parse a hex string (either case) into bytes. `None` on odd length or
/// non-hex characters.
pub fn hex_to_bytes(s: &str) -> Option<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        let hi = (pair[0] as char).to_digit(16)?;
        let lo = (pair[1] as char).to_digit(16)?;
        out.push(((hi << 4) | lo) as u8);
    }
    Some(out)
}

/// Parse a 64-character hex string into a 32-byte digest.
pub fn hex_to_digest(s: &str) -> Option<[u8; 32]> {
    if s.len() != 64 {
        return None;
    }
    let v = hex_to_bytes(s)?;
    let mut out = [0u8; 32];
    out.copy_from_slice(&v);
    Some(out)
}

/// splitmix64 — the finalizer used for data-dependent HNSW level assignment.
/// Excellent avalanche behaviour; integer-only.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Deterministic xorshift64* PRNG for tests, corpora and workload
/// generation. NOT cryptographic. Never used inside the kernel state
/// machine (the kernel has no randomness at all, per paper §7).
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Seed must be non-zero; zero is mapped to a fixed constant.
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0xdeadbeefcafef00d } else { seed } }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545f4914f6cdd1d)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        // Modulo bias is irrelevant for workload generation.
        self.next_u64() % n
    }

    /// Uniform f64 in `[0, 1)`.
    // lint: float-boundary — seeded test-corpus generator, never feeds hashed state directly
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[lo, hi)`.
    // lint: float-boundary — seeded test-corpus generator, never feeds hashed state directly
    #[inline]
    pub fn next_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.next_f64() as f32) * (hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv_streaming_matches_oneshot() {
        let mut h = Fnv1a64::new();
        h.update(b"foo");
        h.update(b"bar");
        assert_eq!(h.finish(), fnv1a64(b"foobar"));
    }

    #[test]
    fn fnv_int_helpers_are_le() {
        let mut a = Fnv1a64::new();
        a.update_u32(0x01020304);
        let mut b = Fnv1a64::new();
        b.update(&[0x04, 0x03, 0x02, 0x01]);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn hex_roundtrip() {
        assert_eq!(hex_lower(&[0x00, 0xab, 0xff]), "00abff");
        assert_eq!(hex_to_bytes("00abFF"), Some(vec![0x00, 0xab, 0xff]));
        assert_eq!(hex_to_bytes("0"), None);
        assert_eq!(hex_to_bytes("zz"), None);
        let d = [7u8; 32];
        assert_eq!(hex_to_digest(&hex_lower(&d)), Some(d));
        assert_eq!(hex_to_digest("ab"), None);
    }

    #[test]
    fn splitmix_avalanche() {
        // Adjacent inputs produce very different outputs.
        let a = splitmix64(1);
        let b = splitmix64(2);
        assert_ne!(a, b);
        assert!((a ^ b).count_ones() > 10);
        // Known value regression pin (stability across releases matters:
        // it feeds HNSW level assignment, which feeds the state hash).
        assert_eq!(splitmix64(0), 0xe220a8397b1dcdaf);
    }

    #[test]
    fn xorshift_deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xorshift_f64_in_range() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn xorshift_zero_seed_ok() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
