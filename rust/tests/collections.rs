//! Integration: the /v2 multi-tenant collections surface (ISSUE 4
//! acceptance criteria).
//!
//! 1. Per-collection root hashes are **bit-identical** between the v2
//!    server path (real sockets, typed envelope) and a sequential local
//!    mirror — and interleaving two tenants' writes cannot perturb
//!    either tenant's root.
//! 2. `/v2/hash` (the combined root) is invariant under
//!    creation-order permutation of the collections.
//! 3. Every `ApiError` variant has a stable `(code, name, status)`
//!    pinned by the golden fixture `tests/fixtures/api_error_codes.json`.
//! 4. The legacy `/v1` adapter is byte-identical to a standalone
//!    pre-collections node.
//! 5. `Transfer-Encoding: chunked` is rejected `501 + close` with the
//!    same bytes on the wire from both front ends.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;
use valori::api::ApiCode;
use valori::http::{client, Server};
use valori::index::QuantSpec;
use valori::json::{parse, Json};
use valori::node::{
    serve, serve_collections, CollectionManager, CollectionSpec, ManagerConfig, NodeConfig,
    NodeState,
};
use valori::replication::sync_all_collections;
use valori::state::{Command, Kernel, KernelConfig, ShardedKernel};

fn manager_with(spec: CollectionSpec) -> Arc<CollectionManager> {
    Arc::new(
        CollectionManager::new(
            ManagerConfig {
                spec,
                workers: 4,
                data_dir: None,
                default_wal: None,
                governor: Default::default(),
            },
            None,
        )
        .unwrap(),
    )
}

fn spawn_manager(spec: CollectionSpec) -> (Arc<CollectionManager>, Server) {
    let manager = manager_with(spec);
    let server = serve_collections(Arc::clone(&manager), "127.0.0.1:0", 4).unwrap();
    (manager, server)
}

fn vec_for(collection_salt: u64, i: u64, dim: usize) -> Vec<f32> {
    (0..dim as u64)
        .map(|j| (((collection_salt * 7919 + i * dim as u64 + j) as f32) * 0.0137).sin() * 0.8)
        .collect()
}

fn insert_body(id: u64, v: &[f32]) -> Json {
    Json::object(vec![
        ("id", Json::Int(id as i64)),
        ("vector", Json::Array(v.iter().map(|&x| Json::Float(x as f64)).collect())),
    ])
}

/// Server-side root of one collection, via the typed /v2 envelope.
fn server_root(addr: &SocketAddr, collection: &str) -> String {
    let (st, h) =
        client::get_json(addr, &format!("/v2/collections/{collection}/hash")).unwrap();
    assert_eq!(st, 200, "{h}");
    h.get("data").get("root").as_str().unwrap().to_string()
}

#[test]
fn api_error_codes_match_golden_fixture() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/api_error_codes.json");
    let fixture = parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    let table = fixture.as_object().expect("fixture is an object");
    assert_eq!(
        table.len(),
        ApiCode::ALL.len(),
        "fixture and taxonomy must cover exactly the same codes"
    );
    for code in ApiCode::ALL {
        let entry = fixture.get(&code.code().to_string());
        assert!(
            !matches!(entry, Json::Null),
            "code {} ({}) missing from golden fixture — codes are append-only",
            code.code(),
            code.name()
        );
        assert_eq!(
            entry.get("name").as_str(),
            Some(code.name()),
            "code {} renamed — names are a wire contract",
            code.code()
        );
        assert_eq!(
            entry.get("status").as_i64(),
            Some(code.http_status() as i64),
            "code {} changed HTTP status",
            code.code()
        );
    }
}

#[test]
fn interleaved_tenants_match_sequential_mirrors_bit_for_bit() {
    // Two tenants with different shapes on one server.
    let (manager, server) =
        spawn_manager(CollectionSpec::new(4, 1, false, QuantSpec::None));
    let addr = server.addr();
    let spec_a = CollectionSpec::new(8, 2, true, QuantSpec::None);
    let spec_b = CollectionSpec::new(8, 4, true, QuantSpec::None);
    manager.create("tenant_a", spec_a).unwrap();
    manager.create("tenant_b", spec_b).unwrap();

    // Sequential local mirrors: each fed ONLY its own workload, as if the
    // other tenant did not exist.
    let mut mirror_a = ShardedKernel::new(KernelConfig::default_q16(8).with_flat_index(), 2);
    let mut mirror_b = ShardedKernel::new(KernelConfig::default_q16(8).with_flat_index(), 4);

    let mut conn = client::Connection::connect(&addr).unwrap();
    for i in 0..60u64 {
        // interleave: a, then b, every iteration — over one keep-alive
        // socket so the server sees a strictly alternating stream
        let va = vec_for(1, i, 8);
        let (st, resp) =
            conn.post_json("/v2/collections/tenant_a/insert", &insert_body(i, &va)).unwrap();
        assert_eq!(st, 200, "{resp}");
        mirror_a.apply(Command::Insert { id: i, vector: va }).unwrap();

        let vb = vec_for(2, i, 8);
        let (st, resp) =
            conn.post_json("/v2/collections/tenant_b/insert", &insert_body(i, &vb)).unwrap();
        assert_eq!(st, 200, "{resp}");
        mirror_b.apply(Command::Insert { id: i, vector: vb }).unwrap();

        if i % 10 == 7 {
            // deletes (with their cross-shard cleanup) on tenant_a only
            let body = Json::object(vec![("id", Json::Int((i - 3) as i64))]);
            let (st, _) = conn.post_json("/v2/collections/tenant_a/delete", &body).unwrap();
            assert_eq!(st, 200);
            mirror_a.apply(Command::Delete { id: i - 3 }).unwrap();
        }
        if i % 15 == 4 && i > 0 {
            let body =
                Json::object(vec![("from", Json::Int(i as i64)), ("to", Json::Int(0))]);
            let (st, _) = conn.post_json("/v2/collections/tenant_b/link", &body).unwrap();
            assert_eq!(st, 200);
            mirror_b.apply(Command::Link { from: i, to: 0 }).unwrap();
        }
    }

    // Per-collection roots: server (concurrent-capable path, typed
    // envelope, interleaved tenants) == sequential isolated mirror.
    assert_eq!(
        server_root(&addr, "tenant_a"),
        format!("{:016x}", mirror_a.root_hash()),
        "tenant_a diverged from its isolated sequential mirror"
    );
    assert_eq!(
        server_root(&addr, "tenant_b"),
        format!("{:016x}", mirror_b.root_hash()),
        "tenant_b diverged from its isolated sequential mirror"
    );

    // And search through the envelope agrees with the mirror's kernel.
    let q = vec_for(3, 0, 8);
    let body = Json::object(vec![
        ("vector", Json::Array(q.iter().map(|&x| Json::Float(x as f64)).collect())),
        ("k", Json::Int(5)),
    ]);
    let (st, resp) = conn.post_json("/v2/collections/tenant_a/query", &body).unwrap();
    assert_eq!(st, 200);
    let hits = resp.get("data").get("hits").as_array().unwrap();
    let expect = mirror_a.search_f32(&q, 5).unwrap();
    assert_eq!(hits.len(), expect.len());
    for (h, e) in hits.iter().zip(&expect) {
        assert_eq!(h.get("id").as_u64(), Some(e.id));
        assert_eq!(h.get("dist_raw").as_i64(), Some(e.dist_raw));
    }
    server.stop();
}

#[test]
fn combined_hash_invariant_under_creation_order_permutation() {
    let spec = CollectionSpec::new(4, 2, true, QuantSpec::None);
    let (m1, s1) = spawn_manager(spec.clone());
    let (m2, s2) = spawn_manager(spec.clone());
    // m1 creates zeta then alpha; m2 creates alpha then zeta.
    m1.create("zeta", spec.clone()).unwrap();
    m1.create("alpha", spec.clone()).unwrap();
    m2.create("alpha", spec.clone()).unwrap();
    m2.create("zeta", spec).unwrap();

    for addr in [s1.addr(), s2.addr()] {
        // identical per-collection contents on both nodes; only the
        // collection *creation* order differs between them
        for name in ["alpha", "zeta", "default"] {
            let salt = name.len() as u64;
            for i in 0..20u64 {
                let v = vec_for(salt, i, 4);
                let (st, resp) = client::post_json(
                    &addr,
                    &format!("/v2/collections/{name}/insert"),
                    &insert_body(i, &v),
                )
                .unwrap();
                assert_eq!(st, 200, "{resp}");
            }
        }
    }

    let (st1, h1) = client::get_json(&s1.addr(), "/v2/hash").unwrap();
    let (st2, h2) = client::get_json(&s2.addr(), "/v2/hash").unwrap();
    assert_eq!((st1, st2), (200, 200));
    assert_eq!(
        h1, h2,
        "combined /v2/hash must be invariant under collection creation order"
    );
    assert_eq!(h1.get("data").get("count").as_i64(), Some(3));
    assert_eq!(m1.combined_root(), m2.combined_root());

    // Perturb one collection on one node: the combined root must flip.
    let (st, _) = client::post_json(
        &s2.addr(),
        "/v2/collections/alpha/insert",
        &insert_body(999, &vec_for(9, 999, 4)),
    )
    .unwrap();
    assert_eq!(st, 200);
    let (_, h2b) = client::get_json(&s2.addr(), "/v2/hash").unwrap();
    assert_ne!(
        h1.get("data").get("root").as_str(),
        h2b.get("data").get("root").as_str()
    );
    s1.stop();
    s2.stop();
}

/// Read one full raw response (status line + headers + body) off a
/// buffered keep-alive stream; returns its exact bytes.
fn read_raw_response(reader: &mut BufReader<TcpStream>) -> std::io::Result<Vec<u8>> {
    let mut raw = Vec::new();
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            return Err(std::io::Error::other("eof before response end"));
        }
        raw.extend_from_slice(line.as_bytes());
        let t = line.trim_end();
        if let Some((k, v)) = t.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().unwrap_or(0);
            }
        }
        if t.is_empty() && raw.len() > 2 {
            break;
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    raw.extend_from_slice(&body);
    Ok(raw)
}

/// Send each raw request over one keep-alive socket and concatenate the
/// exact response bytes.
fn raw_exchange(addr: &SocketAddr, requests: &[Vec<u8>]) -> Vec<u8> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut captured = Vec::new();
    for req in requests {
        stream.write_all(req).unwrap();
        stream.flush().unwrap();
        captured.extend_from_slice(&read_raw_response(&mut reader).unwrap());
    }
    captured
}

fn raw_request(method: &str, target: &str, body: &str) -> Vec<u8> {
    format!("{method} {target} HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}", body.len())
        .into_bytes()
}

#[test]
fn v1_adapter_is_byte_identical_to_standalone_node() {
    // Standalone pre-collections node…
    let kernel = Kernel::new(KernelConfig::default_q16(4));
    let standalone_state =
        Arc::new(NodeState::new(kernel, &NodeConfig::default(), None).unwrap());
    let standalone = serve(Arc::clone(&standalone_state), "127.0.0.1:0", 2).unwrap();
    // …and a collection manager whose `default` has the same spec.
    let (_manager, managed) =
        spawn_manager(CollectionSpec::new(4, 1, false, QuantSpec::None));

    // Deterministic /v1 battery (health and stats excluded: health
    // truthfully reports the manager's backend/collection count, stats
    // carries wall-clock latency figures).
    let battery: Vec<Vec<u8>> = vec![
        raw_request("POST", "/v1/insert", r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#),
        raw_request("POST", "/v1/insert", r#"{"id":2,"vector":[0.9,0.8,0.7,0.6]}"#),
        raw_request("POST", "/v1/insert", r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#), // 409
        raw_request("POST", "/v1/query", r#"{"vector":[0.1,0.2,0.3,0.4],"k":2}"#),
        raw_request(
            "POST",
            "/v1/insert_batch",
            r#"{"items":[{"id":10,"vector":[0,0,0,0.1]},{"id":11,"vector":[0,0,0.1,0]}]}"#,
        ),
        raw_request("POST", "/v1/insert", "{oops"),        // 400
        raw_request("POST", "/v1/delete", r#"{"id":99}"#), // 404
        raw_request("POST", "/v1/link", r#"{"from":1,"to":2}"#),
        raw_request("POST", "/v1/meta", r#"{"id":1,"key":"k","value":"v"}"#),
        raw_request("POST", "/v1/unlink", r#"{"from":1,"to":2}"#),
        raw_request("POST", "/v1/embed", r#"{"texts":["x"]}"#), // 503, no embedder
        raw_request("GET", "/v1/hash", ""),
        raw_request("GET", "/v1/log?from=0", ""),
        raw_request("GET", "/v3/nowhere", ""), // unversioned 404
    ];
    let from_standalone = raw_exchange(&standalone.addr(), &battery);
    let from_adapter = raw_exchange(&managed.addr(), &battery);
    assert!(
        from_standalone == from_adapter,
        "/v1 adapter diverged from the standalone node:\n--- standalone ---\n{}\n--- adapter ---\n{}",
        String::from_utf8_lossy(&from_standalone),
        String::from_utf8_lossy(&from_adapter),
    );
    standalone.stop();
    managed.stop();
}

/// Send partial/odd request bytes, half-close, and collect everything the
/// server puts on the wire until it closes.
fn one_shot_exchange(addr: &SocketAddr, raw: &[u8]) -> Vec<u8> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(raw).unwrap();
    stream.flush().unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut out = Vec::new();
    let _ = stream.read_to_end(&mut out);
    out
}

#[test]
fn chunked_transfer_encoding_rejected_501_identically_on_both_front_ends() {
    let make_state = || {
        let kernel = Kernel::new(KernelConfig::default_q16(4));
        Arc::new(NodeState::new(kernel, &NodeConfig::default(), None).unwrap())
    };
    let blocking_state = make_state();
    let reactor_state = make_state();
    let blocking = Server::start_blocking("127.0.0.1:0", 2, {
        let s = Arc::clone(&blocking_state);
        Arc::new(move |req| valori::node::route(&s, req))
    })
    .unwrap();
    let reactor = serve(Arc::clone(&reactor_state), "127.0.0.1:0", 2).unwrap();
    assert_eq!(blocking.backend_name(), "blocking");

    let cases: [&[u8]; 3] = [
        // classic chunked upload
        b"POST /v1/insert HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n5\r\nhello\r\n0\r\n\r\n",
        // TE alongside content-length: TE still wins (checked first)
        b"POST /v1/insert HTTP/1.1\r\ncontent-length: 5\r\ntransfer-encoding: chunked\r\n\r\nhello",
        // any TE value is unsupported, not just chunked
        b"GET /v1/hash HTTP/1.1\r\ntransfer-encoding: gzip\r\n\r\n",
    ];
    for raw in cases {
        let a = one_shot_exchange(&blocking.addr(), raw);
        let b = one_shot_exchange(&reactor.addr(), raw);
        assert!(
            a == b,
            "chunked rejection diverged for {raw:?}:\n--- blocking ---\n{}\n--- reactor ---\n{}",
            String::from_utf8_lossy(&a),
            String::from_utf8_lossy(&b),
        );
        let text = String::from_utf8_lossy(&a);
        assert!(text.starts_with("HTTP/1.1 501 Not Implemented\r\n"), "{text}");
        assert!(text.contains("connection: close"), "{text}");
        assert!(text.contains(r#"{"error":"not implemented: transfer-encoding"}"#), "{text}");
        // the body was never interpreted as a request
        assert!(!text.contains("duplicate"), "{text}");
    }
    // the kernel was never touched
    assert_eq!(blocking_state.log_len(), 0);
    assert_eq!(reactor_state.log_len(), 0);
    blocking.stop();
    reactor.stop();
}

#[test]
fn sync_all_collections_converges_a_fresh_follower() {
    let spec = CollectionSpec::new(4, 2, true, QuantSpec::None);
    let (p_manager, primary) = spawn_manager(spec.clone());
    let (f_manager, follower) = spawn_manager(spec.clone());
    p_manager
        .create("t1", CollectionSpec::new(4, 2, true, QuantSpec::None))
        .unwrap();
    p_manager
        .create("t2", CollectionSpec::new(4, 4, true, QuantSpec::None))
        .unwrap();

    // data in default + both tenants, via the live server
    let p_addr = primary.addr();
    for (name, salt, n) in [("default", 11u64, 30u64), ("t1", 22, 50), ("t2", 33, 40)] {
        let mut conn = client::Connection::connect(&p_addr).unwrap();
        for i in 0..n {
            let v = vec_for(salt, i, 4);
            let (st, resp) = conn
                .post_json(&format!("/v2/collections/{name}/insert"), &insert_body(i, &v))
                .unwrap();
            assert_eq!(st, 200, "{resp}");
        }
        // a delete with cross-shard cleanup rides along
        let (st, _) = conn
            .post_json(
                &format!("/v2/collections/{name}/delete"),
                &Json::object(vec![("id", Json::Int(3))]),
            )
            .unwrap();
        assert_eq!(st, 200);
    }

    let shipped = sync_all_collections(&p_addr, &follower.addr()).unwrap();
    let names: Vec<&str> = shipped.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names, vec!["default", "t1", "t2"]);
    for (name, per_shard) in &shipped {
        assert!(
            per_shard.iter().sum::<usize>() > 0,
            "collection {name} shipped nothing"
        );
    }

    // per-collection roots AND the combined root converge
    for name in ["default", "t1", "t2"] {
        assert_eq!(
            server_root(&p_addr, name),
            server_root(&follower.addr(), name),
            "collection {name} did not converge"
        );
    }
    assert_eq!(p_manager.combined_root(), f_manager.combined_root());
    primary.stop();
    follower.stop();
}
