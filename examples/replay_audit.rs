//! Regulatory-audit replay (paper §9: "Financial and medical AI agents can
//! be audited by replaying their entire command log to verify why a
//! decision was reached").
//!
//! Scenario: an agent served a risky answer last quarter. The auditor has
//! (a) the command log and (b) the state hash recorded at decision time.
//! They replay the log on their own machine, verify the hash matches —
//! proving the memory state is exactly what the agent saw — and re-run the
//! retrieval to inspect what evidence the agent had. Finally the example
//! shows tampering detection: a single flipped bit in the log changes the
//! hash.
//!
//! Run: `cargo run --release --example replay_audit`

use valori::replication::{log_from_text, log_to_text};
use valori::state::{CanonCommand, Command, Kernel, KernelConfig};

fn main() {
    // ---------------- production side: the agent's life ------------------
    let mut agent = Kernel::new(KernelConfig::default_q16(8));
    let mut audit_log: Vec<CanonCommand> = Vec::new();
    let mut record = |k: &mut Kernel, log: &mut Vec<CanonCommand>, cmd: Command| {
        let canon = k.apply(cmd).expect("command");
        log.push(canon);
    };

    // the agent ingests facts over its lifetime...
    let facts: &[(u64, [f32; 8], &str)] = &[
        (1, [0.9, 0.1, 0.0, 0.2, 0.1, 0.0, 0.3, 0.1], "Q1 revenue was $10M"),
        (2, [0.8, 0.2, 0.1, 0.3, 0.1, 0.0, 0.2, 0.0], "Q1 costs were $7M"),
        (3, [0.1, 0.9, 0.2, 0.0, 0.4, 0.1, 0.0, 0.2], "New drone fleet deployed"),
        (4, [0.85, 0.15, 0.05, 0.25, 0.1, 0.05, 0.25, 0.05], "Q2 revenue projected $12M"),
        (5, [0.2, 0.1, 0.9, 0.1, 0.0, 0.3, 0.1, 0.0], "Patient trial enrolled 40 subjects"),
    ];
    for (id, v, desc) in facts {
        record(&mut agent, &mut audit_log, Command::insert(*id, v.to_vec()));
        record(
            &mut agent,
            &mut audit_log,
            Command::SetMeta { id: *id, key: "text".into(), value: desc.to_string() },
        );
    }
    // the agent links derived facts and retires one
    record(&mut agent, &mut audit_log, Command::Link { from: 4, to: 1 });
    record(&mut agent, &mut audit_log, Command::Delete { id: 3 });

    // decision time: the agent answered a financial question using k-NN
    let question = [0.88f32, 0.12, 0.02, 0.22, 0.1, 0.02, 0.28, 0.04];
    let evidence = agent.search_f32(&question, 3).unwrap();
    let decision_hash = agent.state_hash();
    println!("agent decision used evidence: {:?}", evidence.iter().map(|h| h.id).collect::<Vec<_>>());
    println!("recorded state hash at decision time: {decision_hash:016x}");

    // the log is archived as hex lines (the audit-file format)
    let archived = log_to_text(&audit_log);
    println!("archived {} commands ({} bytes)", audit_log.len(), archived.len());

    // ---------------- auditor side: independent replay -------------------
    let recovered = log_from_text(&archived).expect("parse archive");
    let mut audit_kernel = Kernel::new(KernelConfig::default_q16(8));
    for cmd in &recovered {
        audit_kernel.apply_canon(cmd).expect("replay");
    }
    let replay_hash = audit_kernel.state_hash();
    println!("auditor replay hash:                  {replay_hash:016x}");
    assert_eq!(replay_hash, decision_hash, "replay must reproduce the exact state");

    // the auditor can now re-run the agent's query and see the same evidence
    let audit_evidence = audit_kernel.search_f32(&question, 3).unwrap();
    assert_eq!(audit_evidence, evidence);
    println!("re-ran the decision query: identical evidence ids, identical raw distances");
    for h in &audit_evidence {
        let text = audit_kernel
            .meta_of(h.id)
            .and_then(|m| m.get("text").cloned())
            .unwrap_or_default();
        println!("  evidence id {} (dist {:.4}): {}", h.id, h.dist, text);
    }

    // ---------------- tampering detection --------------------------------
    let mut tampered = recovered.clone();
    for c in tampered.iter_mut() {
        if let CanonCommand::Insert { id: 1, raw } = c {
            raw[0] ^= 1; // one bit, one component
            break;
        }
    }
    let mut tampered_kernel = Kernel::new(KernelConfig::default_q16(8));
    for cmd in &tampered {
        tampered_kernel.apply_canon(cmd).expect("replay tampered");
    }
    let tampered_hash = tampered_kernel.state_hash();
    println!("tampered-log replay hash:             {tampered_hash:016x}");
    assert_ne!(tampered_hash, decision_hash, "single-bit tampering must change the hash");
    println!("single flipped bit in the archive detected via hash mismatch");

    println!("replay_audit OK");
}
