//! Floating-point distance baselines + reduction-order variants.
//!
//! The paper's §2.1 names three sources of cross-platform float divergence:
//! FMA contraction, non-associative reduction order, and SIMD width. This
//! module implements the *same* mathematical dot product under several
//! legal IEEE-754 evaluation orders. On identical inputs they generally
//! return different bits — that is the failure mode Valori's integer kernel
//! eliminates, and it is what the Table 1 / divergence benches demonstrate
//! (DESIGN §2 substitution: different evaluation orders on one host stand
//! in for different ISAs).

#![forbid(unsafe_code)]

/// Plain sequential left-to-right accumulation — what a scalar x86 build
/// without FMA does.
#[inline]
pub fn dot_f32_seq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Same sum, reversed iteration order — a different (equally legal)
/// association, standing in for a different compiler/ISA choice.
#[inline]
pub fn dot_f32_rev(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in (0..a.len()).rev() {
        acc += a[i] * b[i];
    }
    acc
}

/// Pairwise (tree) reduction — the association SIMD/parallel reductions
/// produce (e.g. AVX horizontal adds, GPU warp reductions).
pub fn dot_f32_pairwise(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    fn rec(prod: &[f32]) -> f32 {
        match prod.len() {
            0 => 0.0,
            1 => prod[0],
            n => {
                let mid = n / 2;
                rec(&prod[..mid]) + rec(&prod[mid..])
            }
        }
    }
    let prods: Vec<f32> = a.iter().zip(b).map(|(x, y)| x * y).collect();
    rec(&prods)
}

/// 8-lane strided accumulation — models an AVX2-width vectorized loop
/// (8 independent partial sums combined at the end).
pub fn dot_f32_lanes8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0f32; 8];
    for i in 0..a.len() {
        lanes[i % 8] += a[i] * b[i];
    }
    // horizontal combine, fixed order
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
}

/// FMA-contracted sequential accumulation (`mul_add`: one rounding instead
/// of two) — what an ARM64/NEON or `-ffp-contract=fast` build does.
#[inline]
pub fn dot_f32_fma(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in 0..a.len() {
        acc = a[i].mul_add(b[i], acc);
    }
    acc
}

/// Sequential squared L2 distance.
#[inline]
pub fn l2sq_f32_seq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Reversed-order squared L2 distance.
#[inline]
pub fn l2sq_f32_rev(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in (0..a.len()).rev() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Count how many of the evaluation-order variants disagree with the
/// sequential baseline at the bit level (used by divergence experiments).
pub fn divergent_variants(a: &[f32], b: &[f32]) -> usize {
    let base = dot_f32_seq(a, b).to_bits();
    [
        dot_f32_rev(a, b),
        dot_f32_pairwise(a, b),
        dot_f32_lanes8(a, b),
        dot_f32_fma(a, b),
    ]
    .iter()
    .filter(|v| v.to_bits() != base)
    .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::XorShift64;

    fn random_pair(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = XorShift64::new(seed);
        let a = (0..n).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        let b = (0..n).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        (a, b)
    }

    #[test]
    fn variants_agree_mathematically() {
        let (a, b) = random_pair(384, 1);
        let s = dot_f32_seq(&a, &b);
        for v in [dot_f32_rev(&a, &b), dot_f32_pairwise(&a, &b), dot_f32_lanes8(&a, &b), dot_f32_fma(&a, &b)] {
            assert!((v - s).abs() < 1e-3, "v={v} s={s}");
        }
    }

    #[test]
    fn variants_diverge_at_bit_level() {
        // This is the paper's §2.1 claim, reproduced in-process: at least
        // one legal evaluation order gives different bits. Over many random
        // vectors, divergence is essentially certain at dim 384.
        let mut any = 0;
        for seed in 1..=20 {
            let (a, b) = random_pair(384, seed);
            any += divergent_variants(&a, &b).min(1);
        }
        assert!(any >= 18, "only {any}/20 random pairs showed divergence");
    }

    #[test]
    fn small_dims_can_agree() {
        // dim-1 products have a single evaluation order: all variants equal.
        let a = vec![0.5f32];
        let b = vec![0.25f32];
        assert_eq!(divergent_variants(&a, &b), 0);
    }

    #[test]
    fn l2_variants() {
        let (a, b) = random_pair(128, 9);
        let s = l2sq_f32_seq(&a, &b);
        let r = l2sq_f32_rev(&a, &b);
        assert!((s - r).abs() < 1e-3);
        assert!(s >= 0.0 && r >= 0.0);
    }

    #[test]
    fn pairwise_empty_and_single() {
        assert_eq!(dot_f32_pairwise(&[], &[]), 0.0);
        assert_eq!(dot_f32_pairwise(&[2.0], &[3.0]), 6.0);
    }
}
