"""Python client for a running Valori node — the "Python FFI" interface
layer of the paper's Figure 1, implemented over the node's HTTP API.

Stdlib-only (urllib), so it works in any environment the node runs in.

    from valori_client import ValoriClient
    c = ValoriClient("http://127.0.0.1:7431")
    c.insert(1, text="Revenue for April")
    hits = c.query(text="profit in april", k=5)
    print(c.state_hash())

Determinism note: the client is *outside* the boundary; everything it
submits is validated and quantized by the kernel, and `state_hash()` /
`log()` expose the replica-comparison surface.
"""

from __future__ import annotations

import json
import urllib.error
import urllib.request
from typing import Any, Optional


class ValoriError(RuntimeError):
    """Server-side rejection (4xx/5xx) with the decoded error message."""

    def __init__(self, status: int, message: str):
        super().__init__(f"valori: HTTP {status}: {message}")
        self.status = status
        self.message = message


class ValoriClient:
    def __init__(self, base_url: str = "http://127.0.0.1:7431", timeout: float = 30.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout

    # ------------------------------------------------------------- http
    def _request(self, method: str, path: str, body: Optional[dict] = None) -> Any:
        data = json.dumps(body).encode() if body is not None else None
        req = urllib.request.Request(
            self.base_url + path, data=data, method=method,
            headers={"content-type": "application/json"},
        )
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return json.loads(resp.read().decode())
        except urllib.error.HTTPError as e:
            try:
                payload = json.loads(e.read().decode())
                msg = payload.get("error", str(payload))
            except Exception:
                msg = e.reason
            raise ValoriError(e.code, msg) from None

    # ------------------------------------------------------------ writes
    def insert(self, id: int, vector: Optional[list] = None, text: Optional[str] = None) -> int:
        """Insert a vector (or a text, embedded server-side). Returns seq."""
        body: dict = {"id": id}
        if vector is not None:
            body["vector"] = vector
        elif text is not None:
            body["text"] = text
        else:
            raise ValueError("need vector or text")
        return self._request("POST", "/v1/insert", body)["seq"]

    def insert_batch(self, items: list) -> int:
        """Insert [(id, vector), ...] atomically (canonical id order)."""
        body = {"items": [{"id": i, "vector": v} for i, v in items]}
        return self._request("POST", "/v1/insert_batch", body)["seq"]

    def delete(self, id: int) -> None:
        self._request("POST", "/v1/delete", {"id": id})

    def link(self, from_id: int, to_id: int) -> None:
        self._request("POST", "/v1/link", {"from": from_id, "to": to_id})

    def unlink(self, from_id: int, to_id: int) -> None:
        self._request("POST", "/v1/unlink", {"from": from_id, "to": to_id})

    def set_meta(self, id: int, key: str, value: str) -> None:
        self._request("POST", "/v1/meta", {"id": id, "key": key, "value": value})

    # ------------------------------------------------------------- reads
    def query(self, vector: Optional[list] = None, text: Optional[str] = None, k: int = 10) -> list:
        """k-NN search; returns [{id, dist, dist_raw}, ...]."""
        body: dict = {"k": k}
        if vector is not None:
            body["vector"] = vector
        elif text is not None:
            body["text"] = text
        else:
            raise ValueError("need vector or text")
        return self._request("POST", "/v1/query", body)["hits"]

    def embed(self, texts: list) -> list:
        """Embed texts through the node's AOT model (no insertion)."""
        return self._request("POST", "/v1/embed", {"texts": texts})["embeddings"]

    def stats(self) -> dict:
        return self._request("GET", "/v1/stats")

    def state_hash(self) -> dict:
        """{'fnv': hex, 'sha256': hex, 'seq': int} — compare across nodes."""
        return self._request("GET", "/v1/hash")

    def log(self, from_seq: int = 0) -> dict:
        """Canonical command feed (hex-encoded) for replication/audit."""
        return self._request("GET", f"/v1/log?from={from_seq}")

    def apply(self, hex_commands: list) -> dict:
        """Apply canonical commands (follower ingest)."""
        return self._request("POST", "/v1/apply", {"commands": hex_commands})

    def health(self) -> bool:
        try:
            return bool(self._request("GET", "/v1/health").get("ok"))
        except Exception:
            return False


def replicate(primary: "ValoriClient", follower: "ValoriClient", from_seq: int = 0) -> str:
    """Ship the primary's log to a follower; returns the follower's hash.

    The §9 convergence protocol in four lines of Python.
    """
    feed = primary.log(from_seq)
    cmds = feed["commands"]
    if cmds:
        return follower.apply(cmds)["hash"]
    return follower.state_hash()["fnv"]
