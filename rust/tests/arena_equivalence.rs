//! Bit-exactness properties for the arena + streaming-top-k + worker-pool
//! refactor (ISSUE 2 tentpole).
//!
//! The refactor's contract is "not a single output bit changes":
//!
//! 1. Arena-backed `FlatIndex` search must equal a straightforward
//!    reference (per-vector scalar distance, collect every hit, full
//!    `(dist, id)` sort, truncate) — the pre-refactor algorithm — for
//!    random corpora, deletes included.
//! 2. `HnswIndex`/`FlatIndex` snapshot bytes must be unchanged by the
//!    in-memory layout: canonical encode → decode → re-encode is
//!    byte-stable, and two builds from the same commands agree byte for
//!    byte. (`tests/golden_snapshot.rs` additionally pins the exact
//!    pre-refactor bytes via the committed fixture, which this PR does
//!    not regenerate.)
//! 3. The persistent worker-pool fan-out must return exactly what the
//!    inline fan-out returns for n_shards ∈ {1, 2, 4, 8}.

use valori::distance::{Metric, Scalar};
use valori::hash::XorShift64;
use valori::index::{FlatIndex, Hit, Hnsw, HnswParams, VectorIndex};
use valori::state::{Command, Kernel, KernelConfig, ShardedKernel};
use valori::testing::{check, Gen};

/// Under Miri the same properties run on reduced corpora/trial counts
/// (the interpreter is ~1000x slower; the aliasing coverage is the same).
const MIRI: bool = cfg!(miri);

/// Pre-refactor flat search semantics, reimplemented independently of the
/// index internals: score every live vector, sort by `(dist, id)`,
/// truncate to k.
fn reference_search<S: Scalar>(
    index: &FlatIndex<S>,
    query: &[S],
    k: usize,
) -> Vec<Hit<S::Dist>> {
    let mut hits: Vec<Hit<S::Dist>> = index
        .store()
        .iter_live()
        .map(|(_, id, v)| Hit { id, dist: S::distance(index.metric(), query, v) })
        .collect();
    hits.sort_by(|a, b| a.dist.cmp(&b.dist).then(a.id.cmp(&b.id)));
    hits.truncate(k);
    hits
}

fn random_raw(rng: &mut XorShift64, dim: usize) -> Vec<i32> {
    // Inside the boundary contract (|raw| ≤ 2^18 for max_abs = 4.0).
    (0..dim).map(|_| (rng.next_below(131_072) as i64 - 65_536) as i32).collect()
}

#[test]
fn flat_arena_search_matches_reference_sort() {
    // Dims chosen to exercise block-kernel edge cases: smaller than one
    // block row, not a power of two, and larger than the 64-slot block.
    for dim in [1usize, 3, 17, 64] {
        for metric in [Metric::L2, Metric::InnerProduct] {
            let mut rng = XorShift64::new(0xA11E_u64 + dim as u64);
            let mut idx: FlatIndex<i32> = FlatIndex::new(dim, metric);
            // 150 slots: spans two+ score blocks with a ragged tail.
            for id in 0..150u64 {
                idx.insert(id, random_raw(&mut rng, dim));
            }
            // Tombstone a scattering of slots, including block boundaries.
            for id in [0u64, 5, 63, 64, 65, 127, 128, 149] {
                assert!(idx.delete(id));
            }
            let trials = if MIRI { 3 } else { 20 };
            for trial in 0..trials {
                let q = random_raw(&mut rng, dim);
                for k in [0usize, 1, 7, 64, 142, 150, 500] {
                    assert_eq!(
                        idx.search(&q, k),
                        reference_search(&idx, &q, k),
                        "dim={dim} metric={metric:?} trial={trial} k={k}"
                    );
                }
            }
        }
    }
}

#[test]
fn flat_arena_search_matches_reference_property() {
    // Property form over random (corpus, query) pairs: ties included —
    // components are drawn from a tiny alphabet so equal distances are
    // common and the (dist, id) tie-break is genuinely exercised.
    check(
        "arena flat search == collect+sort reference",
        if MIRI { 8 } else { 60 },
        Gen::pair(
            Gen::vec_len(Gen::vec_of(Gen::i32_range(-3, 3), 4), 1, 80),
            Gen::vec_of(Gen::i32_range(-3, 3), 4),
        ),
        |(rows, q)| {
            let mut idx: FlatIndex<i32> = FlatIndex::new(4, Metric::L2);
            for (id, row) in rows.iter().enumerate() {
                idx.insert(id as u64, row.clone());
            }
            // delete every third row
            for id in (0..rows.len() as u64).step_by(3) {
                idx.delete(id);
            }
            let k = (rows.len() / 2).max(1);
            idx.search(q, k) == reference_search(&idx, q, k)
        },
    );
}

#[test]
fn f32_baseline_keeps_reference_semantics() {
    // The generic (non-specialized) block path must also be exact.
    let mut rng = XorShift64::new(77);
    let mut idx: FlatIndex<f32> = FlatIndex::new(8, Metric::L2);
    for id in 0..200u64 {
        let v: Vec<f32> = (0..8).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        idx.insert(id, v);
    }
    idx.delete(13);
    for _ in 0..10 {
        let q: Vec<f32> = (0..8).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        assert_eq!(idx.search(&q, 12), reference_search(&idx, &q, 12));
    }
}

/// Build a deterministic kernel workload (inserts, deletes, links, meta)
/// and return its canonical state bytes.
fn build_state_bytes(config: KernelConfig, seed: u64) -> Vec<u8> {
    let mut rng = XorShift64::new(seed);
    let mut k = Kernel::new(config);
    for id in 0..120u64 {
        let v: Vec<f32> = (0..4).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        k.apply(Command::insert(id, v)).unwrap();
    }
    for id in [7u64, 30, 31, 99] {
        k.apply(Command::Delete { id }).unwrap();
    }
    k.apply(Command::Link { from: 1, to: 2 }).unwrap();
    k.apply(Command::SetMeta { id: 3, key: "s".into(), value: "v".into() }).unwrap();
    k.to_state_bytes()
}

#[test]
fn snapshot_bytes_are_layout_independent_and_stable() {
    for config in [KernelConfig::default_q16(4), KernelConfig::default_q16(4).with_flat_index()] {
        // Same commands → same bytes (arena cannot leak into the stream).
        let a = build_state_bytes(config.clone(), 42);
        let b = build_state_bytes(config.clone(), 42);
        assert_eq!(a, b, "index {:?}", config.index);
        // decode → re-encode is canonical (byte-stable round-trip).
        let restored = Kernel::from_state_bytes(&a).unwrap();
        assert_eq!(a, restored.to_state_bytes(), "index {:?}", config.index);
    }
}

#[test]
fn hnsw_arena_graph_is_bit_deterministic() {
    let build = || {
        let mut rng = XorShift64::new(9001);
        let mut h: Hnsw<i32> = Hnsw::new(8, Metric::L2, HnswParams::default());
        let n = if MIRI { 60u64 } else { 300 };
        for id in 0..n {
            h.insert(id, random_raw(&mut rng, 8));
        }
        h
    };
    let h1 = build();
    let h2 = build();
    let mut e1 = valori::codec::Encoder::new();
    let mut e2 = valori::codec::Encoder::new();
    h1.encode(&mut e1);
    h2.encode(&mut e2);
    assert_eq!(e1.as_slice(), e2.as_slice());
    // Read path: streaming top-k returns the (dist, id)-ascending contract.
    let mut rng = XorShift64::new(17);
    for _ in 0..10 {
        let q = random_raw(&mut rng, 8);
        let hits = h1.search(&q, 10);
        assert_eq!(hits, h2.search(&q, 10));
        for w in hits.windows(2) {
            assert!(
                (w[0].dist, w[0].id) < (w[1].dist, w[1].id),
                "hits must ascend strictly on (dist, id)"
            );
        }
    }
}

#[test]
fn pooled_fanout_equals_inline_fanout_across_shard_counts() {
    for n_shards in [1u32, 2, 4, 8] {
        let config = KernelConfig::default_q16(6).with_flat_index();
        let mut sk = ShardedKernel::new(config, n_shards);
        let mut single = Kernel::new(KernelConfig::default_q16(6).with_flat_index());
        let mut rng = XorShift64::new(1234 + n_shards as u64);
        let n = if MIRI { 120u64 } else { 500 };
        for id in 0..n {
            let v: Vec<f32> = (0..6).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
            sk.apply(Command::insert(id, v.clone())).unwrap();
            single.apply(Command::insert(id, v)).unwrap();
        }
        for id in (0..n).step_by(11) {
            sk.apply(Command::Delete { id }).unwrap();
            single.apply(Command::Delete { id }).unwrap();
        }
        let trials = if MIRI { 4 } else { 15 };
        for trial in 0..trials {
            let q: Vec<f32> =
                (0..6).map(|j| ((trial * 6 + j) as f32 * 0.11).sin() * 0.9).collect();
            let fv = valori::vector::FixedVector::from_f32(
                &q,
                6,
                &valori::vector::ValidationPolicy::default(),
            )
            .unwrap();
            let inline = sk.search_raw_inline(fv.raw(), 10).unwrap();
            let pooled = sk.search_raw_pooled(fv.raw(), 10).unwrap();
            assert_eq!(inline, pooled, "n_shards={n_shards} trial={trial}");
            // And both equal the unsharded reference (flat index ⇒ exact).
            let reference = single.search_raw(fv.raw(), 10).unwrap();
            assert_eq!(pooled, reference, "n_shards={n_shards} trial={trial}");
        }
    }
}

#[test]
fn pooled_fanout_is_stable_across_repeated_queries() {
    // Scheduling stress: the same pooled query repeated must never change
    // (collection is in shard order, merge is a pure function).
    let mut sk = ShardedKernel::new(KernelConfig::default_q16(4).with_flat_index(), 4);
    let mut rng = XorShift64::new(5);
    let n = if MIRI { 100u64 } else { 400 };
    for id in 0..n {
        let v: Vec<f32> = (0..4).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        sk.apply(Command::insert(id, v)).unwrap();
    }
    let fv = valori::vector::FixedVector::from_f32(
        &[0.2, -0.4, 0.6, -0.8],
        4,
        &valori::vector::ValidationPolicy::default(),
    )
    .unwrap();
    let first = sk.search_raw_pooled(fv.raw(), 20).unwrap();
    let repeats = if MIRI { 10 } else { 50 };
    for _ in 0..repeats {
        assert_eq!(sk.search_raw_pooled(fv.raw(), 20).unwrap(), first);
    }
}
