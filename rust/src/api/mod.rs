//! The typed `/v2` API: request envelope, result envelope, and the
//! closed error taxonomy. **This module is the single place the error
//! surface is defined** — every `/v2` handler serializes success through
//! [`ok_response`] and failure through [`ApiError::response`], so there
//! is exactly one way any payload reaches the wire.
//!
//! ## Envelope
//!
//! - success → HTTP 200, body `{"data": <payload>, "ok": true}`;
//! - failure → the taxonomy's HTTP status, body
//!   `{"error": {"code": N, "message": "...", "name": "..."}, "ok": false}`.
//!
//! (Keys appear in sorted order — [`crate::json::Json`] objects are
//! `BTreeMap`s, so serialization is canonical and replayable.)
//!
//! ## Error taxonomy (closed, numbered, wire-stable)
//!
//! | code | name | HTTP |
//! |---|---|---|
//! | 1000 | `bad_request` | 400 |
//! | 1001 | `duplicate_id` | 409 |
//! | 1002 | `unknown_id` | 404 |
//! | 1003 | `dim_mismatch` | 400 |
//! | 1004 | `boundary` | 400 |
//! | 1005 | `meta_key_too_long` | 400 |
//! | 1006 | `wrong_shard` | 400 |
//! | 1007 | `shard_out_of_range` | 400 |
//! | 1100 | `unknown_collection` | 404 |
//! | 1101 | `collection_exists` | 409 |
//! | 1102 | `invalid_collection_name` | 400 |
//! | 1103 | `reserved_collection` | 400 |
//! | 1200 | `no_embedder` | 503 |
//! | 1201 | `embed_failed` | 500 |
//! | 1300 | `route_not_found` | 404 |
//! | 1301 | `method_not_allowed` | 405 |
//! | 1400 | `stream_corrupt` | 400 |
//! | 1401 | `stream_offset_mismatch` | 409 |
//! | 1402 | `stream_digest_mismatch` | 400 |
//! | 1403 | `restore_busy` | 503 |
//! | 1500 | `internal` | 500 |
//! | 1600 | `rate_limited` | 429 |
//! | 1601 | `quota_exceeded` | 429 |
//! | 1602 | `memory_quota_exceeded` | 429 |
//! | 1700 | `proof_invalid` | 400 |
//! | 1701 | `proof_out_of_range` | 400 |
//! | 1702 | `repair_mismatch` | 409 |
//!
//! Codes are a compatibility contract: they may be *added*, never
//! renumbered or reused (`tests/fixtures/api_error_codes.json` is the
//! golden copy `tests/collections.rs` asserts against). Numbering is
//! grouped: 10xx state-machine rejections, 11xx collection lifecycle,
//! 12xx embedder, 13xx routing, 14xx snapshot streaming, 15xx internal,
//! 16xx admission control (per-collection governance), 17xx verifiable
//! state receipts (Merkle proofs and divergence repair).
//!
//! The 1600/1601 codes are issued by the front end *before* a request
//! reaches the dispatch pool: admission decisions come from
//! front-end-local state only (monotonic clocks, in-flight counters),
//! are never logged and never hashed, so a throttled-and-retried
//! workload replays to a root hash bit-identical to an unthrottled run.
//! A `rate_limited` error object additionally carries a
//! `retry_after_ms` detail field (the only taxonomy error with an extra
//! key). 1602 `memory_quota_exceeded` rejects an insert whose projected
//! arena footprint would exceed the collection's `memory_quota` budget;
//! unlike its 16xx siblings it is a pure function of replicated state
//! (arena bytes + spec), so all replicas admit and reject identically.
//! Replication ingest (`apply`) and /v1 are exempt — quota governs new
//! client writes, never replay convergence.
//!
//! ## Collection specs and the quantized scan tier
//!
//! `PUT /v2/collections/{name}` accepts a spec body of `dim`, `shards`,
//! `index` (`"flat"` | `"hnsw"`), `quant` (`"none"` | `"sq8"`) and
//! `overscan` (SQ8 candidate multiplier, integer >= 1, only with
//! `"quant": "sq8"`). The i8 codes are *derived* state — rebuilt from
//! the exact vectors on decode, never serialized — so query payloads are
//! bit-identical to an unquantized collection fed the same commands, and
//! snapshots grow only by the fixed-size spec (STATE_VERSION 3), never
//! by the code arena. The spec is configuration, though: like `index` or
//! `shards`, enabling it changes the collection's state root. Quant-free
//! collections keep their pre-quantization (version 2) bytes and roots.
//! `GET /v2/collections/{name}/stats` reports its footprint under
//! `memory_bytes` (`exact_arena` / `code_arena` / `total`), plus the
//! per-tenant `governor` block (`available_tokens`, `in_flight`,
//! `rate_limited`, `quota_rejected`, `enabled`) and an `evicted` flag
//! (true when this request itself rehydrated a cold tenant).
//!
//! ## Typed commands
//!
//! [`ApiRequest`] is the parsed, validated form of a `/v2` mutation or
//! query — handlers never poke at raw JSON. [`execute`] runs a typed
//! request against one collection's [`NodeState`] and returns the
//! success payload; all validation errors surface as [`ApiError`]s from
//! [`ApiRequest::parse`], all state-machine rejections from the kernel's
//! own [`StateError`], mapped 1:1 onto the taxonomy.

#![forbid(unsafe_code)]

use crate::http::Response;
use crate::json::{parse, Json};
use crate::node::{hex_decode, hex_encode, Metrics, NodeState};
use crate::state::{CanonCommand, Command, StateError};
use std::time::Instant;

/// The closed error code taxonomy. See the module docs for the table;
/// [`ApiCode::ALL`] enumerates every variant for the golden-fixture test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApiCode {
    /// Malformed body, missing/mistyped field, bad hex, invalid JSON.
    BadRequest = 1000,
    /// Insert with an id that already exists (tombstones included).
    DuplicateId = 1001,
    /// Command references an id that does not exist (or was deleted).
    UnknownId = 1002,
    /// Vector has the wrong dimensionality for the collection.
    DimMismatch = 1003,
    /// Rejected at the quantization boundary (non-finite, out of range).
    Boundary = 1004,
    /// Metadata key exceeds the kernel's bound.
    MetaKeyTooLong = 1005,
    /// Per-shard ingest received a command routed to a different shard.
    WrongShard = 1006,
    /// `shard` query/body parameter exceeds the collection's shard count.
    ShardOutOfRange = 1007,
    /// Named collection does not exist.
    UnknownCollection = 1100,
    /// PUT of a collection name that is already taken.
    CollectionExists = 1101,
    /// Collection name outside `[a-z0-9_-]{1,64}` (ASCII, lower).
    InvalidCollectionName = 1102,
    /// Operation refused on a reserved collection (`default` backs /v1).
    ReservedCollection = 1103,
    /// Text input but no embedder loaded.
    NoEmbedder = 1200,
    /// The embedder failed on this input.
    EmbedFailed = 1201,
    /// No /v2 route matches the method + path.
    RouteNotFound = 1300,
    /// The path exists but not with this method.
    MethodNotAllowed = 1301,
    /// Snapshot-stream bytes failed structural/CRC verification in
    /// transit (retry the transfer).
    StreamCorrupt = 1400,
    /// Restore ingest arrived at an offset the session does not expect
    /// (resume from the session's reported offset, or restart at 0).
    StreamOffsetMismatch = 1401,
    /// Stream survived transport intact but a reassembled shard's
    /// digest disagrees with its manifest — a determinism violation on
    /// the sender, not line noise.
    StreamDigestMismatch = 1402,
    /// Too many concurrent restore sessions on this node — retry later
    /// (sessions also expire after an idle TTL).
    RestoreBusy = 1403,
    /// I/O or other non-deterministic failure (WAL append, runtime).
    Internal = 1500,
    /// Admission control: the collection's token bucket is empty. The
    /// error object carries a `retry_after_ms` hint; the rejection is
    /// issued by the front end before the request reaches the dispatch
    /// pool and is never logged or hashed, so retried workloads replay
    /// bit-identically.
    RateLimited = 1600,
    /// Admission control: the collection is already at its in-flight
    /// request cap (quota/bulkhead) — retry once an in-flight request
    /// completes.
    QuotaExceeded = 1601,
    /// Admission control: the insert's projected arena footprint would
    /// exceed the collection's `memory_quota` byte budget. Deterministic
    /// (a pure function of replicated state + spec) — delete vectors or
    /// raise the quota, then retry.
    MemoryQuotaExceeded = 1602,
    /// Malformed proof/repair payload: bad leaf-encoding hex, a leaf
    /// that fails canonical decode, or missing proof fields.
    ProofInvalid = 1700,
    /// Proof/repair request addresses a shard, level, slot, or hash
    /// range beyond the collection's Merkle tree.
    ProofOutOfRange = 1701,
    /// Repair payload disagrees with the addressed slot (wrong external
    /// id, or vector dimensionality) — repairing it would corrupt, not
    /// converge.
    RepairMismatch = 1702,
}

impl ApiCode {
    /// Every variant, in code order (the golden-fixture test iterates
    /// this, so adding a variant without extending the fixture fails CI).
    pub const ALL: [ApiCode; 27] = [
        ApiCode::BadRequest,
        ApiCode::DuplicateId,
        ApiCode::UnknownId,
        ApiCode::DimMismatch,
        ApiCode::Boundary,
        ApiCode::MetaKeyTooLong,
        ApiCode::WrongShard,
        ApiCode::ShardOutOfRange,
        ApiCode::UnknownCollection,
        ApiCode::CollectionExists,
        ApiCode::InvalidCollectionName,
        ApiCode::ReservedCollection,
        ApiCode::NoEmbedder,
        ApiCode::EmbedFailed,
        ApiCode::RouteNotFound,
        ApiCode::MethodNotAllowed,
        ApiCode::StreamCorrupt,
        ApiCode::StreamOffsetMismatch,
        ApiCode::StreamDigestMismatch,
        ApiCode::RestoreBusy,
        ApiCode::Internal,
        ApiCode::RateLimited,
        ApiCode::QuotaExceeded,
        ApiCode::MemoryQuotaExceeded,
        ApiCode::ProofInvalid,
        ApiCode::ProofOutOfRange,
        ApiCode::RepairMismatch,
    ];

    /// The stable numeric code (the discriminant).
    pub fn code(self) -> u32 {
        self as u32
    }

    /// The stable wire name (lower_snake identifier).
    pub fn name(self) -> &'static str {
        match self {
            ApiCode::BadRequest => "bad_request",
            ApiCode::DuplicateId => "duplicate_id",
            ApiCode::UnknownId => "unknown_id",
            ApiCode::DimMismatch => "dim_mismatch",
            ApiCode::Boundary => "boundary",
            ApiCode::MetaKeyTooLong => "meta_key_too_long",
            ApiCode::WrongShard => "wrong_shard",
            ApiCode::ShardOutOfRange => "shard_out_of_range",
            ApiCode::UnknownCollection => "unknown_collection",
            ApiCode::CollectionExists => "collection_exists",
            ApiCode::InvalidCollectionName => "invalid_collection_name",
            ApiCode::ReservedCollection => "reserved_collection",
            ApiCode::NoEmbedder => "no_embedder",
            ApiCode::EmbedFailed => "embed_failed",
            ApiCode::RouteNotFound => "route_not_found",
            ApiCode::MethodNotAllowed => "method_not_allowed",
            ApiCode::StreamCorrupt => "stream_corrupt",
            ApiCode::StreamOffsetMismatch => "stream_offset_mismatch",
            ApiCode::StreamDigestMismatch => "stream_digest_mismatch",
            ApiCode::RestoreBusy => "restore_busy",
            ApiCode::Internal => "internal",
            ApiCode::RateLimited => "rate_limited",
            ApiCode::QuotaExceeded => "quota_exceeded",
            ApiCode::MemoryQuotaExceeded => "memory_quota_exceeded",
            ApiCode::ProofInvalid => "proof_invalid",
            ApiCode::ProofOutOfRange => "proof_out_of_range",
            ApiCode::RepairMismatch => "repair_mismatch",
        }
    }

    /// The HTTP status every response carrying this code uses.
    pub fn http_status(self) -> u16 {
        match self {
            ApiCode::BadRequest
            | ApiCode::DimMismatch
            | ApiCode::Boundary
            | ApiCode::MetaKeyTooLong
            | ApiCode::WrongShard
            | ApiCode::ShardOutOfRange
            | ApiCode::InvalidCollectionName
            | ApiCode::ReservedCollection
            | ApiCode::StreamCorrupt
            | ApiCode::StreamDigestMismatch
            | ApiCode::ProofInvalid
            | ApiCode::ProofOutOfRange => 400,
            ApiCode::UnknownId | ApiCode::UnknownCollection | ApiCode::RouteNotFound => 404,
            ApiCode::MethodNotAllowed => 405,
            ApiCode::DuplicateId
            | ApiCode::CollectionExists
            | ApiCode::StreamOffsetMismatch
            | ApiCode::RepairMismatch => 409,
            ApiCode::EmbedFailed | ApiCode::Internal => 500,
            ApiCode::NoEmbedder | ApiCode::RestoreBusy => 503,
            ApiCode::RateLimited | ApiCode::QuotaExceeded | ApiCode::MemoryQuotaExceeded => 429,
        }
    }
}

/// A typed API error: taxonomy code + human message. `retry_after_ms`
/// is the one optional detail field in the taxonomy, carried only by
/// `rate_limited` rejections (the front end's refill estimate).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ApiError {
    pub code: ApiCode,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

impl ApiError {
    pub fn new(code: ApiCode, message: impl Into<String>) -> Self {
        Self { code, message: message.into(), retry_after_ms: None }
    }

    pub fn bad_request(message: impl Into<String>) -> Self {
        Self::new(ApiCode::BadRequest, message)
    }

    /// Attach the client-facing backoff hint (1600 `rate_limited` only).
    pub fn with_retry_after_ms(mut self, ms: u64) -> Self {
        self.retry_after_ms = Some(ms);
        self
    }

    /// The wire form of the error object (inside the envelope).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("code", Json::Int(self.code.code() as i64)),
            ("message", Json::str(self.message.clone())),
            ("name", Json::str(self.code.name())),
        ];
        if let Some(ms) = self.retry_after_ms {
            fields.push(("retry_after_ms", Json::Int(ms as i64)));
        }
        Json::object(fields)
    }

    /// The full enveloped HTTP response — the only error serializer any
    /// /v2 handler is allowed to use.
    pub fn response(&self) -> Response {
        let body = Json::object(vec![("error", self.to_json()), ("ok", Json::Bool(false))]);
        Response::json(self.code.http_status(), body.to_string())
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}: {}", self.code.code(), self.code.name(), self.message)
    }
}

impl std::error::Error for ApiError {}

/// Result alias for everything inside the /v2 boundary.
pub type ApiResult<T> = Result<T, ApiError>;

/// The success envelope (HTTP 200 always; partial failures are errors).
pub fn ok_response(data: Json) -> Response {
    let body = Json::object(vec![("data", data), ("ok", Json::Bool(true))]);
    Response::json(200, body.to_string())
}

impl From<StateError> for ApiError {
    fn from(se: StateError) -> Self {
        let code = match &se {
            StateError::DuplicateId(_) => ApiCode::DuplicateId,
            StateError::UnknownId(_) => ApiCode::UnknownId,
            StateError::Boundary(_) => ApiCode::Boundary,
            StateError::DimMismatch { .. } => ApiCode::DimMismatch,
            StateError::MetaKeyTooLong(_) => ApiCode::MetaKeyTooLong,
            StateError::WrongShard { .. } => ApiCode::WrongShard,
            // A panicked scan task is a runtime fault, not a state
            // rejection: the query (and only the query) failed.
            StateError::ScanPoisoned => ApiCode::Internal,
        };
        // The message is the kernel's own Display text, so /v1 and /v2
        // describe a rejection with the same words.
        ApiError::new(code, se.to_string())
    }
}

impl From<crate::Error> for ApiError {
    fn from(e: crate::Error) -> Self {
        match e {
            crate::Error::State(se) => ApiError::from(se),
            crate::Error::Boundary(be) => {
                ApiError::new(ApiCode::Boundary, format!("boundary: {be}"))
            }
            other => ApiError::new(ApiCode::Internal, other.to_string()),
        }
    }
}

impl From<crate::snapshot::StreamError> for ApiError {
    fn from(e: crate::snapshot::StreamError) -> Self {
        let code = if e.is_digest_violation() {
            ApiCode::StreamDigestMismatch
        } else {
            ApiCode::StreamCorrupt
        };
        ApiError::new(code, e.to_string())
    }
}

/// A vector-valued input: literal components, or text for the embedder.
#[derive(Debug, Clone, PartialEq)]
pub enum VectorInput {
    Vector(Vec<f32>),
    Text(String),
}

/// The typed command envelope: one variant per collection-scoped POST
/// operation. Parsing is total — any malformed body is an [`ApiError`],
/// never a partially-filled request.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiRequest {
    Insert { id: u64, vector: VectorInput },
    InsertBatch { items: Vec<(u64, Vec<f32>)> },
    Query { vector: VectorInput, k: usize },
    Delete { id: u64 },
    Link { from: u64, to: u64 },
    Unlink { from: u64, to: u64 },
    SetMeta { id: u64, key: String, value: String },
    /// Canonical-command ingest (replication): with `shard`, the feed
    /// applies replay-style to that shard; without, commands route fresh.
    Apply { shard: Option<u32>, commands: Vec<CanonCommand> },
}

fn need_u64(body: &Json, field: &str) -> ApiResult<u64> {
    body.get(field)
        .as_u64()
        .ok_or_else(|| ApiError::bad_request(format!("need numeric '{field}'")))
}

fn vector_input(body: &Json) -> ApiResult<VectorInput> {
    if let Some(arr) = body.get("vector").as_array() {
        let v = arr
            .iter()
            .map(|x| x.as_f64().map(|f| f as f32))
            .collect::<Option<Vec<f32>>>()
            .ok_or_else(|| ApiError::bad_request("vector must be an array of numbers"))?;
        Ok(VectorInput::Vector(v))
    } else if let Some(t) = body.get("text").as_str() {
        Ok(VectorInput::Text(t.to_string()))
    } else {
        Err(ApiError::bad_request("need 'vector' or 'text'"))
    }
}

impl ApiRequest {
    /// Parse one operation's body into its typed request. `op` is the
    /// final path segment of `/v2/collections/{name}/{op}`.
    pub fn parse(op: &str, body: &Json) -> ApiResult<ApiRequest> {
        match op {
            "insert" => Ok(ApiRequest::Insert {
                id: need_u64(body, "id")?,
                vector: vector_input(body)?,
            }),
            "insert_batch" => {
                let items_json = body.get("items").as_array().ok_or_else(|| {
                    ApiError::bad_request("need 'items' array of {id, vector}")
                })?;
                let mut items = Vec::with_capacity(items_json.len());
                for it in items_json {
                    let id = it
                        .get("id")
                        .as_u64()
                        .ok_or_else(|| ApiError::bad_request("item needs 'id'"))?;
                    let vector = it
                        .get("vector")
                        .as_array()
                        .ok_or_else(|| ApiError::bad_request("item needs 'vector'"))?
                        .iter()
                        .map(|v| v.as_f64().map(|x| x as f32))
                        .collect::<Option<Vec<f32>>>()
                        .ok_or_else(|| ApiError::bad_request("vector must be numbers"))?;
                    items.push((id, vector));
                }
                Ok(ApiRequest::InsertBatch { items })
            }
            "query" => Ok(ApiRequest::Query {
                vector: vector_input(body)?,
                k: body.get("k").as_u64().unwrap_or(10) as usize,
            }),
            "delete" => Ok(ApiRequest::Delete { id: need_u64(body, "id")? }),
            "link" => Ok(ApiRequest::Link {
                from: need_u64(body, "from")?,
                to: need_u64(body, "to")?,
            }),
            "unlink" => Ok(ApiRequest::Unlink {
                from: need_u64(body, "from")?,
                to: need_u64(body, "to")?,
            }),
            "meta" => Ok(ApiRequest::SetMeta {
                id: need_u64(body, "id")?,
                key: body
                    .get("key")
                    .as_str()
                    .ok_or_else(|| ApiError::bad_request("need 'key'"))?
                    .to_string(),
                value: body
                    .get("value")
                    .as_str()
                    .ok_or_else(|| ApiError::bad_request("need 'value'"))?
                    .to_string(),
            }),
            "apply" => {
                let cmds = body.get("commands").as_array().ok_or_else(|| {
                    ApiError::bad_request("need 'commands' array of hex strings")
                })?;
                let mut commands = Vec::with_capacity(cmds.len());
                for c in cmds {
                    let hex = c
                        .as_str()
                        .ok_or_else(|| ApiError::bad_request("command must be hex string"))?;
                    let bytes =
                        hex_decode(hex).ok_or_else(|| ApiError::bad_request("invalid hex"))?;
                    let canon = CanonCommand::from_bytes(&bytes)
                        .map_err(|e| ApiError::bad_request(format!("bad command: {e}")))?;
                    commands.push(canon);
                }
                // Checked narrowing: a shard beyond u32 must reject, not
                // silently alias onto `shard % 2^32` (= replay onto the
                // wrong shard).
                let shard = match body.get("shard").as_u64() {
                    None => None,
                    Some(s) => Some(u32::try_from(s).map_err(|_| {
                        ApiError::new(
                            ApiCode::ShardOutOfRange,
                            format!("shard {s} out of range"),
                        )
                    })?),
                };
                Ok(ApiRequest::Apply { shard, commands })
            }
            other => Err(ApiError::new(
                ApiCode::RouteNotFound,
                format!("unknown operation '{other}'"),
            )),
        }
    }
}

/// Parse request-body bytes as JSON (the shared front door for every
/// body-carrying /v2 handler).
pub fn body_json(body: &[u8]) -> ApiResult<Json> {
    let text = std::str::from_utf8(body).map_err(|_| ApiError::bad_request("body is not utf-8"))?;
    parse(text).map_err(|e| ApiError::bad_request(format!("invalid json: {e}")))
}

/// One collection's root hash, rendered for the wire. Always the
/// sharded-kernel root (well defined for 1-shard collections too), so
/// `/v2` hashes compose into the combined root uniformly.
pub fn root_hex(state: &NodeState) -> String {
    state.with_sharded(|sk| format!("{:016x}", sk.root_hash()))
}

fn resolve_vector(state: &NodeState, input: VectorInput) -> ApiResult<Vec<f32>> {
    match input {
        VectorInput::Vector(v) => Ok(v),
        VectorInput::Text(text) => {
            let embed = state.embedder().ok_or_else(|| {
                ApiError::new(ApiCode::NoEmbedder, "no embedder loaded (run `make artifacts`)")
            })?;
            let t0 = Instant::now();
            let v = embed
                .embed(&text)
                .map_err(|e| ApiError::new(ApiCode::EmbedFailed, format!("embed failed: {e}")))?;
            state.metrics.embed_latency.record_us(t0.elapsed().as_micros() as u64);
            Metrics::inc(&state.metrics.embeds);
            Ok(v)
        }
    }
}

fn seq_of(state: &NodeState) -> i64 {
    state.with_sharded(|k| k.seq()) as i64
}

/// Reject an insert of `n_new` vectors if the projected arena footprint
/// would exceed the collection's `memory_quota` (0 = unlimited). The
/// projection is exact for accepted inserts — `dim * 4` Q16.16 bytes per
/// vector, plus `dim` derived i8 code bytes under SQ8 — and a pure
/// function of replicated state, so every replica admits identically.
/// Only called on the client write paths; replication ingest is exempt.
fn check_memory_quota(state: &NodeState, n_new: usize) -> ApiResult<()> {
    let quota = state.memory_quota();
    if quota == 0 {
        return Ok(());
    }
    let (current, per_vec) = state.with_sharded(|sk| {
        let (exact, codes) = sk.arena_bytes();
        let dim = sk.config().dim;
        let sq8 = !matches!(sk.config().quant, crate::index::QuantSpec::None);
        ((exact + codes) as u64, (dim * 4 + if sq8 { dim } else { 0 }) as u64)
    });
    let projected = current.saturating_add(per_vec.saturating_mul(n_new as u64));
    if projected > quota {
        return Err(ApiError::new(
            ApiCode::MemoryQuotaExceeded,
            format!(
                "memory quota exceeded: {current} bytes resident + {n_new} vector(s) \
                 would reach {projected} bytes (quota {quota})"
            ),
        ));
    }
    Ok(())
}

/// Execute one typed request against one collection's node state and
/// return the success payload (the `data` object). Every handler in the
/// /v2 route tree funnels through here, which is what makes the response
/// surface uniform: same metrics, same error mapping, same shapes.
pub fn execute(state: &NodeState, request: ApiRequest) -> ApiResult<Json> {
    match request {
        ApiRequest::Insert { id, vector } => {
            let v = resolve_vector(state, vector)?;
            check_memory_quota(state, 1)?;
            state.apply(Command::Insert { id, vector: v })?;
            Metrics::inc(&state.metrics.inserts);
            Ok(Json::object(vec![
                ("inserted", Json::Int(id as i64)),
                ("seq", Json::Int(seq_of(state))),
            ]))
        }
        ApiRequest::InsertBatch { items } => {
            let n = items.len();
            check_memory_quota(state, n)?;
            state.apply(Command::InsertBatch { items })?;
            Metrics::inc(&state.metrics.inserts);
            Ok(Json::object(vec![
                ("inserted", Json::Int(n as i64)),
                ("seq", Json::Int(seq_of(state))),
            ]))
        }
        ApiRequest::Query { vector, k } => {
            let v = resolve_vector(state, vector)?;
            let t0 = Instant::now();
            let hits = state.with_sharded(|kern| kern.search_f32(&v, k))?;
            state.metrics.query_latency.record_us(t0.elapsed().as_micros() as u64);
            Metrics::inc(&state.metrics.queries);
            let hits_json: Vec<Json> = hits
                .iter()
                .map(|h| {
                    Json::object(vec![
                        ("id", Json::Int(h.id as i64)),
                        ("dist_raw", Json::Int(h.dist_raw)),
                        ("dist", Json::Float(h.dist)),
                    ])
                })
                .collect();
            Ok(Json::object(vec![("hits", Json::Array(hits_json))]))
        }
        ApiRequest::Delete { id } => {
            state.apply(Command::Delete { id })?;
            Metrics::inc(&state.metrics.deletes);
            Ok(Json::object(vec![("deleted", Json::Int(id as i64))]))
        }
        ApiRequest::Link { from, to } => {
            state.apply(Command::Link { from, to })?;
            Metrics::inc(&state.metrics.links);
            Ok(Json::object(vec![
                ("from", Json::Int(from as i64)),
                ("linked", Json::Bool(true)),
                ("to", Json::Int(to as i64)),
            ]))
        }
        ApiRequest::Unlink { from, to } => {
            state.apply(Command::Unlink { from, to })?;
            Metrics::inc(&state.metrics.links);
            Ok(Json::object(vec![
                ("from", Json::Int(from as i64)),
                ("linked", Json::Bool(false)),
                ("to", Json::Int(to as i64)),
            ]))
        }
        ApiRequest::SetMeta { id, key, value } => {
            state.apply(Command::SetMeta { id, key, value })?;
            Ok(Json::object(vec![("id", Json::Int(id as i64))]))
        }
        ApiRequest::Apply { shard, commands } => {
            if let Some(s) = shard {
                if s >= state.n_shards() {
                    return Err(ApiError::new(
                        ApiCode::ShardOutOfRange,
                        format!("shard {s} out of range (n_shards = {})", state.n_shards()),
                    ));
                }
            }
            let mut applied = 0i64;
            for canon in &commands {
                match shard {
                    Some(s) => state.apply_canon_to_shard(s, canon)?,
                    None => state.apply_canon(canon)?,
                }
                applied += 1;
            }
            Ok(Json::object(vec![
                ("applied", Json::Int(applied)),
                ("root", Json::str(root_hex(state))),
                ("seq", Json::Int(seq_of(state))),
            ]))
        }
    }
}

/// One shard's canonical log feed (the /v2 replication surface; same
/// paging contract as /v1 but enveloped and with a typed out-of-range
/// error).
pub fn log_feed(state: &NodeState, shard: u32, from: usize) -> ApiResult<Json> {
    if shard >= state.n_shards() {
        // An empty 200 would read as "fully caught up" to a sync driver
        // configured with the wrong shard count — reject loudly.
        return Err(ApiError::new(
            ApiCode::ShardOutOfRange,
            format!("shard {shard} out of range (n_shards = {})", state.n_shards()),
        ));
    }
    let cmds = state.log_slice_shard(shard, from, 1000);
    let arr: Vec<Json> = cmds.iter().map(|c| Json::str(hex_encode(&c.to_bytes()))).collect();
    Ok(Json::object(vec![
        ("commands", Json::Array(arr)),
        ("from", Json::Int(from as i64)),
        ("n_shards", Json::Int(state.n_shards() as i64)),
        ("shard", Json::Int(shard as i64)),
        ("total", Json::Int(state.shard_log_len(shard) as i64)),
    ]))
}

/// Per-shard hash manifest of one collection (audit-grade: FNV for the
/// cheap compare, SHA-256 per shard for the paper's §8.1 verification,
/// and — since PR-10 — the incrementally-maintained Merkle roots that
/// anchor record-level membership proofs, see [`crate::proof`]).
pub fn hash_manifest(state: &NodeState) -> Json {
    state.with_sharded(|sk| {
        let snap = crate::snapshot::ShardedSnapshot::capture(sk);
        let merkle_roots = sk.merkle_shard_roots();
        let shards: Vec<Json> = snap
            .manifest()
            .iter()
            .zip(&merkle_roots)
            .map(|(m, root)| {
                Json::object(vec![
                    ("fnv", Json::str(format!("{:016x}", m.fnv))),
                    ("merkle", Json::str(crate::hash::hex_lower(root))),
                    ("sha256", Json::str(crate::hash::sha256_hex(&m.sha256))),
                    ("shard", Json::Int(m.shard as i64)),
                ])
            })
            .collect();
        Json::object(vec![
            ("merkle_root", Json::str(crate::hash::hex_lower(&sk.merkle_root()))),
            ("root", Json::str(format!("{:016x}", snap.root_hash()))),
            ("seq", Json::Int(sk.seq() as i64)),
            ("shards", Json::Array(shards)),
        ])
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeConfig;
    use crate::state::{Kernel, KernelConfig};

    fn test_state() -> NodeState {
        let kernel = Kernel::new(KernelConfig::default_q16(4));
        NodeState::new(kernel, &NodeConfig::default(), None).unwrap()
    }

    #[test]
    fn codes_are_unique_stable_and_total() {
        let mut seen = std::collections::BTreeSet::new();
        for c in ApiCode::ALL {
            assert!(seen.insert(c.code()), "duplicate code {}", c.code());
            assert!(!c.name().is_empty());
            assert!(matches!(c.http_status(), 400 | 404 | 405 | 409 | 429 | 500 | 503));
        }
        assert_eq!(ApiCode::ALL.len(), seen.len());
        // Spot-pin a few numbers: renumbering is a wire break.
        assert_eq!(ApiCode::BadRequest.code(), 1000);
        assert_eq!(ApiCode::DuplicateId.code(), 1001);
        assert_eq!(ApiCode::UnknownCollection.code(), 1100);
        assert_eq!(ApiCode::Internal.code(), 1500);
        assert_eq!(ApiCode::RateLimited.code(), 1600);
        assert_eq!(ApiCode::QuotaExceeded.code(), 1601);
        assert_eq!(ApiCode::MemoryQuotaExceeded.code(), 1602);
        assert_eq!(ApiCode::ProofInvalid.code(), 1700);
        assert_eq!(ApiCode::ProofOutOfRange.code(), 1701);
        assert_eq!(ApiCode::RepairMismatch.code(), 1702);
        assert_eq!(ApiCode::RepairMismatch.http_status(), 409);
    }

    #[test]
    fn memory_quota_rejects_projected_overflow() {
        let kernel = Kernel::new(KernelConfig::default_q16(4));
        let config = NodeConfig { memory_quota: 20, ..NodeConfig::default() };
        let state = NodeState::new(kernel, &config, None).unwrap();
        // dim 4 → 16 arena bytes per vector: the first insert fits the
        // 20-byte budget…
        let body = parse(r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#).unwrap();
        execute(&state, ApiRequest::parse("insert", &body).unwrap()).unwrap();
        // …the second projects 32 > 20 bytes and must reject *before*
        // the state machine sees it.
        let body = parse(r#"{"id":2,"vector":[0.1,0.2,0.3,0.4]}"#).unwrap();
        let err = execute(&state, ApiRequest::parse("insert", &body).unwrap()).unwrap_err();
        assert_eq!(err.code, ApiCode::MemoryQuotaExceeded);
        assert_eq!(err.code.http_status(), 429);
        assert!(!state.with_sharded(|sk| sk.contains(2)), "rejected insert must not apply");

        // Batches project as a whole.
        let body = parse(
            r#"{"items":[{"id":2,"vector":[0.0,0.0,0.0,0.0]},{"id":3,"vector":[0.0,0.0,0.0,0.0]}]}"#,
        )
        .unwrap();
        let err =
            execute(&state, ApiRequest::parse("insert_batch", &body).unwrap()).unwrap_err();
        assert_eq!(err.code, ApiCode::MemoryQuotaExceeded);

        // Replication ingest is exempt: convergence wins over quota.
        let canon = state
            .with_sharded(|sk| sk.shards()[0].canonicalize(Command::insert(2, vec![0.1; 4])))
            .unwrap();
        let data = execute(
            &state,
            ApiRequest::Apply { shard: None, commands: vec![canon] },
        )
        .unwrap();
        assert_eq!(data.get("applied").as_i64(), Some(1));
        assert!(state.with_sharded(|sk| sk.contains(2)));
    }

    #[test]
    fn rate_limited_envelope_carries_retry_after_ms() {
        let e = ApiError::new(ApiCode::RateLimited, "rate limit exceeded for 'demo'")
            .with_retry_after_ms(17);
        let resp = e.response();
        assert_eq!(resp.status, 429);
        let body = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(body.get("error").get("code").as_i64(), Some(1600));
        assert_eq!(body.get("error").get("name").as_str(), Some("rate_limited"));
        assert_eq!(body.get("error").get("retry_after_ms").as_i64(), Some(17));
        // Every other error keeps the exact three-key shape the golden
        // api-surface fixture pins — retry_after_ms is strictly additive.
        let plain = ApiError::new(ApiCode::QuotaExceeded, "quota").to_json();
        assert!(plain.get("retry_after_ms").as_i64().is_none());
    }

    #[test]
    fn error_envelope_shape() {
        let e = ApiError::new(ApiCode::DuplicateId, "duplicate id 7");
        let resp = e.response();
        assert_eq!(resp.status, 409);
        let body = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(body.get("ok").as_bool(), Some(false));
        assert_eq!(body.get("error").get("code").as_i64(), Some(1001));
        assert_eq!(body.get("error").get("name").as_str(), Some("duplicate_id"));
        assert_eq!(body.get("error").get("message").as_str(), Some("duplicate id 7"));
    }

    #[test]
    fn state_errors_map_onto_the_taxonomy() {
        let e = ApiError::from(StateError::DuplicateId(3));
        assert_eq!(e.code, ApiCode::DuplicateId);
        assert_eq!(e.message, "duplicate id 3");
        let e = ApiError::from(StateError::UnknownId(9));
        assert_eq!(e.code, ApiCode::UnknownId);
        let e = ApiError::from(StateError::DimMismatch { expected: 4, got: 2 });
        assert_eq!(e.code, ApiCode::DimMismatch);
    }

    #[test]
    fn typed_parse_then_execute_roundtrip() {
        let state = test_state();
        let body = parse(r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#).unwrap();
        let req = ApiRequest::parse("insert", &body).unwrap();
        assert_eq!(
            req,
            ApiRequest::Insert {
                id: 1,
                vector: VectorInput::Vector(vec![0.1, 0.2, 0.3, 0.4])
            }
        );
        let data = execute(&state, req).unwrap();
        assert_eq!(data.get("inserted").as_i64(), Some(1));
        assert_eq!(data.get("seq").as_i64(), Some(1));

        // duplicate -> taxonomy error
        let body = parse(r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#).unwrap();
        let err = execute(&state, ApiRequest::parse("insert", &body).unwrap()).unwrap_err();
        assert_eq!(err.code, ApiCode::DuplicateId);

        // query returns the hit
        let body = parse(r#"{"vector":[0.1,0.2,0.3,0.4],"k":1}"#).unwrap();
        let data = execute(&state, ApiRequest::parse("query", &body).unwrap()).unwrap();
        let hits = data.get("hits").as_array().unwrap();
        assert_eq!(hits[0].get("id").as_u64(), Some(1));
        assert_eq!(hits[0].get("dist_raw").as_i64(), Some(0));
    }

    #[test]
    fn parse_rejects_malformed_bodies_with_bad_request() {
        for (op, body) in [
            ("insert", r#"{"vector":[0,0,0,0]}"#),           // no id
            ("insert", r#"{"id":1}"#),                        // no vector/text
            ("query", r#"{"k":3}"#),                          // no vector/text
            ("delete", r#"{}"#),                              // no id
            ("link", r#"{"from":1}"#),                        // no to
            ("meta", r#"{"id":1,"key":"k"}"#),                // no value
            ("insert_batch", r#"{"items":[{"id":1}]}"#),      // item w/o vector
            ("apply", r#"{"commands":["zz"]}"#),              // bad hex
        ] {
            let err = ApiRequest::parse(op, &parse(body).unwrap()).unwrap_err();
            assert_eq!(err.code, ApiCode::BadRequest, "op={op} body={body}");
        }
        let err = ApiRequest::parse("frobnicate", &Json::Null).unwrap_err();
        assert_eq!(err.code, ApiCode::RouteNotFound);
        // a shard beyond u32 rejects instead of truncating onto shard 0
        let big = parse(r#"{"commands":[],"shard":4294967296}"#).unwrap();
        let err = ApiRequest::parse("apply", &big).unwrap_err();
        assert_eq!(err.code, ApiCode::ShardOutOfRange);
    }

    #[test]
    fn log_feed_rejects_out_of_range_shard() {
        let state = test_state();
        let err = log_feed(&state, 5, 0).unwrap_err();
        assert_eq!(err.code, ApiCode::ShardOutOfRange);
        let feed = log_feed(&state, 0, 0).unwrap();
        assert_eq!(feed.get("total").as_i64(), Some(0));
        assert_eq!(feed.get("n_shards").as_i64(), Some(1));
    }

    #[test]
    fn hash_manifest_has_per_shard_digests() {
        let state = test_state();
        let body = parse(r#"{"id":1,"vector":[0.5,0,0,0]}"#).unwrap();
        execute(&state, ApiRequest::parse("insert", &body).unwrap()).unwrap();
        let m = hash_manifest(&state);
        assert_eq!(m.get("root").as_str().unwrap().len(), 16);
        assert_eq!(m.get("seq").as_i64(), Some(1));
        let shards = m.get("shards").as_array().unwrap();
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].get("sha256").as_str().unwrap().len(), 64);
        // PR-10: the manifest carries the Merkle receipt roots too
        assert_eq!(shards[0].get("merkle").as_str().unwrap().len(), 64);
        let combined = m.get("merkle_root").as_str().unwrap();
        assert_eq!(combined.len(), 64);
        let expected = state.with_sharded(|sk| crate::hash::hex_lower(&sk.merkle_root()));
        assert_eq!(combined, expected);
    }
}
