#!/usr/bin/env python3
"""Generate the golden snapshot fixture for tests/golden_snapshot.rs.

This script mirrors, byte for byte, the Rust deterministic codec
(`rust/src/codec/mod.rs`), the kernel state layout
(`Kernel::encode_state`, STATE_VERSION 2) and the snapshot framing
(`Snapshot::to_bytes`). It exists so the fixture can be regenerated (and
independently audited) without a Rust toolchain; the Rust test *also*
rebuilds the same state through `Kernel::apply_canon` and asserts both
byte streams agree, so a drift in either implementation fails loudly.

Run:  python3 make_golden.py   (from this directory)

Fixture state (dim=2, flat index, L2, default policy, unsharded):
    insert id=1 raw=[ 65536, -32768]
    insert id=2 raw=[ 13107,  26214]
    insert id=7 raw=[     0, 196608]
    delete id=2
    link   1 -> 7
    set_meta id=1 "src" = "golden"
"""

import hashlib
import struct
import zlib
from pathlib import Path

HERE = Path(__file__).parent


def u8(v):
    return struct.pack("<B", v)


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def i32(v):
    return struct.pack("<i", v)


def f32(v):
    return struct.pack("<f", v)


def put_str(s):
    b = s.encode("utf-8")
    return u32(len(b)) + b


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for byte in data:
        h ^= byte
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def state_bytes() -> bytes:
    out = b""
    out += u32(0x564C4F52)  # STATE_MAGIC "VLOR"
    out += u32(2)  # STATE_VERSION
    # KernelConfig: dim, metric tag, index tag, hnsw params, policy, shard
    out += u32(2)  # dim
    out += u8(0)  # Metric::L2
    out += u8(1)  # IndexKind::Flat
    out += u32(16) + u32(32) + u32(150) + u32(128) + u32(8)  # HnswParams default
    out += f32(4.0)  # policy.max_abs
    out += u8(0)  # policy.normalize
    out += u32(1) + u32(0)  # ShardSpec { n_shards: 1, shard_id: 0 }
    out += u64(6)  # seq (6 applied commands)
    # FlatIndex: metric tag + VecStore
    out += u8(0)  # Metric::L2
    out += u32(2)  # store dim
    out += u32(3)  # slots
    # slot 0: id 1, alive
    out += u64(1) + u8(1) + u32(2) + i32(65536) + i32(-32768)
    # slot 1: id 2, tombstoned
    out += u64(2) + u8(0) + u32(2) + i32(13107) + i32(26214)
    # slot 2: id 7, alive
    out += u64(7) + u8(1) + u32(2) + i32(0) + i32(196608)
    # LinkGraph: 1 from-entry: 1 -> {7}
    out += u32(1) + u64(1) + u32(1) + u64(7)
    # meta: { 1: { "src": "golden" } }
    out += u32(1) + u64(1) + u32(1) + put_str("src") + put_str("golden")
    return out


def snapshot_bytes(state: bytes) -> bytes:
    out = b""
    out += u32(0x56534E50)  # SNAP_MAGIC "VSNP"
    out += u32(1)  # SNAP_VERSION
    out += u32(len(state)) + state  # put_bytes
    out += u64(fnv1a64(state))
    out += hashlib.sha256(state).digest()
    out += u32(zlib.crc32(out) & 0xFFFFFFFF)
    return out


def crc32(data: bytes) -> bytes:
    return u32(zlib.crc32(data) & 0xFFFFFFFF)


STREAM_CHUNK = 64  # deliberately tiny so the fixture exercises multi-chunk frames


def stream_bytes(state: bytes, chunk: int = STREAM_CHUNK) -> bytes:
    """Mirror of the Rust VSTREAM1 writer (`rust/src/snapshot/stream.rs`)
    over the same single-shard golden state: header (spec + manifest +
    crc), then per-chunk `shard ‖ seq ‖ len ‖ payload ‖ crc32` frames."""
    frame = snapshot_bytes(state)
    body = u32(2)  # dim
    body += u8(1)  # IndexKind::Flat tag
    body += u32(1)  # n_shards
    body += u64(len(frame))  # manifest: frame_len
    body += u64(fnv1a64(state))  # manifest: fnv (over state, like VSNP)
    body += hashlib.sha256(state).digest()  # manifest: sha256
    head = b"VSTREAM1" + u32(len(body)) + body
    out = head + crc32(head)
    for seq, off in enumerate(range(0, len(frame), chunk)):
        payload = frame[off : off + chunk]
        c = u32(0) + u32(seq) + u32(len(payload)) + payload
        out += c + crc32(c)
    return out


def main():
    state = state_bytes()
    snap = snapshot_bytes(state)
    (HERE / "golden_snapshot_v2.bin").write_bytes(snap)
    digests = "fnv {:016x}\nsha256 {}\n".format(
        fnv1a64(state), hashlib.sha256(state).hexdigest()
    )
    (HERE / "golden_snapshot_v2.digests").write_text(digests)
    stream = stream_bytes(state)
    (HERE / "golden_stream_v1.bin").write_bytes(stream)
    print(f"state: {len(state)} bytes, snapshot: {len(snap)} bytes, stream: {len(stream)} bytes")
    print(digests, end="")


if __name__ == "__main__":
    main()
