"""Layer-1 Pallas kernel: fused masked scaled-dot-product attention.

This is the compute hot-spot of the embedding encoder (Layer 2). One grid
step handles one (batch, head) pair; Q, K, V tiles for that pair live in
VMEM for the whole step, so HBM traffic is one read of Q/K/V and one write
of O per pair — the FlashAttention-style schedule expressed with BlockSpec
instead of CUDA threadblocks (DESIGN §3 Hardware-Adaptation).

VMEM footprint per grid step (S=64, Dh=32, f32):
  Q,K,V,O: 4 * 64*32*4 B = 32 KiB;  scores: 64*64*4 B = 16 KiB  -> ~48 KiB,
  a comfortable fit in the ~16 MiB TPU VMEM budget; the MXU sees
  (64x32)@(32x64) and (64x64)@(64x32) matmuls in f32 (bf16-ready).

CPU note: lowered with interpret=True — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Numerics are validated
against `ref.attention_ref` in python/tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale):
    """One (batch, head): softmax(Q K^T * scale + bias) V, all in VMEM."""
    q = q_ref[0, 0]          # [S, Dh]
    k = k_ref[0, 0]          # [S, Dh]
    v = v_ref[0, 0]          # [S, Dh]
    bias = bias_ref[0]       # [S]  additive key bias

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = scores + bias[None, :]
    # numerically-stable row softmax
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def attention(q, k, v, bias, interpret=True):
    """Fused attention via Pallas.

    Args:
      q, k, v: f32[B, H, S, Dh]
      bias:    f32[B, S]
      interpret: keep True on CPU (see module docstring).

    Returns:
      f32[B, H, S, Dh]
    """
    b, h, s, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    grid = (b, h)
    qkv_spec = pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0))
    bias_spec = pl.BlockSpec((1, s), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[qkv_spec, qkv_spec, qkv_spec, bias_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32),
        interpret=interpret,
    )(q, k, v, bias)
