//! Integration: multi-node convergence (paper §9) over the in-process
//! cluster AND over real HTTP nodes, with fault scenarios.

use std::sync::Arc;
use valori::node::{serve, NodeConfig, NodeState};
use valori::replication::{sync_follower, Cluster};
use valori::state::{Command, Kernel, KernelConfig};

#[test]
fn large_cluster_converges() {
    let mut c = Cluster::new(KernelConfig::default_q16(16), 7);
    for i in 0..400u64 {
        let v: Vec<f32> = (0..16).map(|j| ((i * 16 + j) as f32 * 0.007).sin() * 0.8).collect();
        c.submit(Command::insert(i, v)).unwrap();
        if i % 13 == 5 {
            c.submit(Command::Delete { id: i - 3 }).unwrap();
        }
    }
    c.sync_all().unwrap();
    assert!(c.converged());
    // every node answers queries identically
    let q: Vec<f32> = (0..16).map(|j| (j as f32 * 0.11).cos() * 0.4).collect();
    let expect = c.node(0).search_f32(&q, 10).unwrap();
    for i in 1..c.len() {
        assert_eq!(c.node(i).search_f32(&q, 10).unwrap(), expect, "node {i}");
    }
}

#[test]
fn straggler_catches_up_in_stages() {
    let mut c = Cluster::new(KernelConfig::default_q16(8), 2);
    for phase in 0..5 {
        for i in 0..50u64 {
            let id = phase * 50 + i;
            let v: Vec<f32> = (0..8).map(|j| ((id + j) as f32 * 0.01).sin()).collect();
            c.submit(Command::insert(id, v)).unwrap();
        }
        // follower syncs only every other phase (staggered)
        if phase % 2 == 1 {
            c.sync_node(1).unwrap();
        }
    }
    assert!(!c.converged());
    c.sync_node(1).unwrap();
    assert!(c.converged());
}

#[test]
fn divergence_detection_pinpoints_corrupt_node() {
    let mut c = Cluster::new(KernelConfig::default_q16(8), 5);
    for i in 0..100u64 {
        c.submit(Command::insert(i, vec![0.1, 0.2, 0.3, 0.4, 0.5, -0.1, -0.2, i as f32 * 0.001]))
            .unwrap();
    }
    c.sync_all().unwrap();
    assert!(c.corrupt_node_for_test(2, 42));
    let reports = c.verify();
    let bad: Vec<usize> = reports.iter().filter(|r| !r.converged).map(|r| r.node).collect();
    assert_eq!(bad, vec![2]);
}

#[test]
fn http_replication_with_concurrent_primary_writes() {
    let make = || {
        let kernel = Kernel::new(KernelConfig::default_q16(8));
        let state = Arc::new(NodeState::new(kernel, &NodeConfig::default(), None).unwrap());
        let server = serve(Arc::clone(&state), "127.0.0.1:0", 4).unwrap();
        (state, server)
    };
    let (p_state, primary) = make();
    let (_f_state, follower) = make();

    // writer thread hammers the primary while we sync in rounds
    let p_addr = primary.addr();
    let writer = {
        let p_state = Arc::clone(&p_state);
        std::thread::spawn(move || {
            for i in 0..300u64 {
                let v: Vec<f32> =
                    (0..8).map(|j| ((i * 3 + j) as f32 * 0.004).cos() * 0.6).collect();
                p_state.apply(Command::insert(i, v)).unwrap();
            }
        })
    };
    // sync rounds race the writer without any pacing sleep: the reactor
    // front end serves each round as fast as the sockets allow
    let mut from = 0usize;
    for _ in 0..20 {
        let (n, _) = sync_follower(&p_addr, &follower.addr(), from).unwrap();
        from += n;
    }
    writer.join().unwrap();
    // final catch-up until hashes agree
    loop {
        let (n, h_f) = sync_follower(&p_addr, &follower.addr(), from).unwrap();
        from += n;
        let (_, h_p) = valori::http::client::get_json(&p_addr, "/v1/hash").unwrap();
        if n == 0 {
            assert_eq!(h_p.get("fnv").as_str().unwrap(), h_f);
            break;
        }
    }
    assert_eq!(from, 300);
    primary.stop();
    follower.stop();
}

/// A follower whose state silently diverged in ONE record (same seq, same
/// log — a flipped bit, paper §9's nightmare case) converges again via the
/// Merkle-diff walk: O(log n) hashes plus the one record cross the wire,
/// not the whole state.
#[test]
fn merkle_diff_repairs_single_record_divergence_over_http() {
    use valori::index::QuantSpec;
    use valori::node::{serve_collections, CollectionManager, CollectionSpec, ManagerConfig};
    use valori::proof::LeafBody;
    use valori::replication::merkle_diff_repair;

    let manager = || {
        Arc::new(
            CollectionManager::new(
                ManagerConfig {
                    spec: CollectionSpec::new(8, 4, true, QuantSpec::None),
                    workers: 2,
                    data_dir: None,
                    default_wal: None,
                    governor: Default::default(),
                },
                None,
            )
            .unwrap(),
        )
    };
    let p_mgr = manager();
    let f_mgr = manager();
    let p_state = p_mgr.get("default").unwrap();
    let f_state = f_mgr.get("default").unwrap();
    // Identical history on both nodes: inserts, a link, meta, a delete.
    for state in [&p_state, &f_state] {
        for i in 0..60u64 {
            let v: Vec<f32> = (0..8).map(|j| ((i * 8 + j) as f32 * 0.013).sin() * 0.6).collect();
            state.apply(Command::insert(i, v)).unwrap();
        }
        state.apply(Command::Link { from: 3, to: 7 }).unwrap();
        state
            .apply(Command::SetMeta { id: 7, key: "k".into(), value: "v".into() })
            .unwrap();
        state.apply(Command::Delete { id: 11 }).unwrap();
    }
    assert_eq!(
        p_state.with_sharded(|sk| sk.root_hash()),
        f_state.with_sharded(|sk| sk.root_hash())
    );
    // Corrupt one record on the follower via un-logged state surgery:
    // seq stays equal, so log shipping can never catch this.
    let proof = f_state.with_sharded(|sk| sk.merkle_proof(7)).unwrap();
    let mut rec = valori::proof::leaf::decode(&proof.record).unwrap();
    match &mut rec.body {
        LeafBody::Live { vector, .. } => vector[0] ^= 1,
        LeafBody::Tombstone => panic!("id 7 must be live"),
    }
    f_state.repair_slot(proof.shard as u32, proof.slot as u32, &rec).unwrap();
    assert_ne!(
        p_state.with_sharded(|sk| sk.root_hash()),
        f_state.with_sharded(|sk| sk.root_hash()),
        "corruption must diverge the FNV root"
    );

    let p_srv = serve_collections(Arc::clone(&p_mgr), "127.0.0.1:0", 2).unwrap();
    let f_srv = serve_collections(Arc::clone(&f_mgr), "127.0.0.1:0", 2).unwrap();
    let report = merkle_diff_repair(&p_srv.addr(), &f_srv.addr(), "default").unwrap();
    assert_eq!(report.records_transferred, 1);
    assert_eq!(report.diverged, vec![(proof.shard as u32, proof.slot as u32, 7)]);
    // O(log n) on the wire: 2 shape probes + 2 sides x 2 children per
    // level of the walk — never the full leaf level.
    let depth = proof.path.len();
    assert!(
        report.hashes_transferred <= 2 + 4 * depth.max(1),
        "walk moved {} hashes for a depth-{depth} tree",
        report.hashes_transferred
    );
    // Full convergence: FNV roots and Merkle roots both bit-identical.
    assert_eq!(
        p_state.with_sharded(|sk| sk.root_hash()),
        f_state.with_sharded(|sk| sk.root_hash())
    );
    assert_eq!(
        p_state.with_sharded(|sk| sk.merkle_root()),
        f_state.with_sharded(|sk| sk.merkle_root())
    );
    // A second walk is a no-op: already converged, nothing moves.
    let again = merkle_diff_repair(&p_srv.addr(), &f_srv.addr(), "default").unwrap();
    assert_eq!(again.records_transferred, 0);
    assert_eq!(again.hashes_transferred, 0);
    assert_eq!(again.root, report.root);
    p_srv.stop();
    f_srv.stop();
}

#[test]
fn follower_rejects_conflicting_history() {
    // A follower that already applied a conflicting command must error
    // (deterministically), not silently fork.
    let mut primary = Cluster::new(KernelConfig::default_q16(4), 1);
    primary.submit(Command::insert(1, vec![0.1, 0.2, 0.3, 0.4])).unwrap();

    let mut follower = Kernel::new(KernelConfig::default_q16(4));
    // follower got a different id-1 from somewhere else (split brain)
    follower.apply(Command::insert(1, vec![0.9, 0.9, 0.9, 0.9])).unwrap();

    let canon = primary.node(0).canonicalize(Command::insert(1, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
    let err = follower.apply_canon(&canon).unwrap_err();
    assert_eq!(err, valori::state::StateError::DuplicateId(1));
}
