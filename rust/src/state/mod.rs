//! Memory as a replayable state machine (paper §3.1, §5.2).
//!
//! `S_{t+1} = F(S_t, C_t)`: the [`kernel::Kernel`] is the state `S`, a
//! [`command::CanonCommand`] is `C`, and [`kernel::Kernel::apply_canon`] is
//! the transition function `F`. Determinism means: for any initial state
//! and command sequence, the final state (and therefore its snapshot bytes
//! and hash) is identical on every platform.
//!
//! The float-facing [`command::Command`] API is the *boundary*: it
//! validates and quantizes inputs into canonical commands, which are what
//! the WAL stores and replication ships.

pub mod command;
pub mod kernel;
pub mod sharded;

pub use command::{CanonCommand, Command};
pub use kernel::{
    Hit, IndexKind, Kernel, KernelConfig, RepairError, ScanConfig, ShardSpec, StateError,
    SCAN_CHUNK_SLOTS,
};
pub use sharded::{Routed, ShardApply, ShardedKernel};
