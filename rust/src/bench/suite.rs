//! The `valori bench` performance suite — the repo's perf trajectory.
//!
//! Everything is deterministic up to wall-clock noise: the corpus is a
//! pure function of a splitmix64 seed, queries are fixed, and every
//! benched operation is the bit-exact production path. The suite also
//! runs a faithful *pre-refactor reference* of the flat search hot path
//! (per-slot `Vec<Vec<i32>>` storage, collect-every-hit + full sort) on
//! the same corpus, so one run reports the arena + streaming-top-k
//! speedup without needing an old binary.
//!
//! The result renders as a human table and serializes to JSON
//! (`BENCH_search.json` at the repo root, written by the CLI) for CI
//! trend tracking.

#![forbid(unsafe_code)]

use crate::bench::{bench, BenchConfig, Report, Stats};
use crate::distance::{Metric, Scalar};
use crate::hash::splitmix64;
use crate::index::{FlatIndex, Hnsw, HnswParams, QuantSpec, VectorIndex, SQ8_DEFAULT_OVERSCAN};
use crate::json::Json;
use crate::state::{CanonCommand, Kernel, KernelConfig, ShardedKernel};

/// Suite parameters (all CLI-overridable).
#[derive(Debug, Clone, Copy)]
pub struct SuiteConfig {
    /// Flat / sharded corpus size.
    pub n: usize,
    /// Vector dimensionality.
    pub dim: usize,
    /// Top-k for every search bench.
    pub k: usize,
    /// Shards for the sharded benches.
    pub shards: u32,
    /// Corpus seed.
    pub seed: u64,
    /// Items per benched `InsertBatch`.
    pub batch: usize,
    /// Timing harness settings.
    pub bench: BenchConfig,
}

impl SuiteConfig {
    /// The reference workload from the perf acceptance bar:
    /// 50k × 256-dim Q16.16, top-10.
    pub fn full() -> Self {
        Self {
            n: 50_000,
            dim: 256,
            k: 10,
            shards: 4,
            seed: 0x56414C4F,
            batch: 512,
            bench: BenchConfig::default(),
        }
    }

    /// CI smoke variant: same shape, two orders of magnitude less work.
    pub fn quick() -> Self {
        Self::full().quickened()
    }

    /// Shrink *this* config to its smoke variant: a tenth of the corpus
    /// (floor 100) and the quick timing harness. Applied after CLI
    /// overrides so `--quick --n 2000` means "a 200-vector smoke run",
    /// not "ignore --n" (every row derives from the quickened `n`).
    pub fn quickened(self) -> Self {
        Self { n: (self.n / 10).max(100), bench: BenchConfig::quick(), ..self }
    }
}

/// One benchmark row plus its workload descriptors.
#[derive(Debug, Clone)]
pub struct SuiteRow {
    pub name: String,
    pub n: usize,
    pub stats: Stats,
}

/// The whole suite result (rendered to JSON by [`suite_json`]).
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub config_label: String,
    pub n: usize,
    pub dim: usize,
    pub k: usize,
    pub shards: u32,
    pub seed: u64,
    pub rows: Vec<SuiteRow>,
}

impl SuiteResult {
    pub fn row(&self, name: &str) -> Option<&SuiteRow> {
        self.rows.iter().find(|r| r.name == name)
    }

    /// p50 speedup of the arena flat search over the pre-refactor
    /// reference path (the acceptance metric).
    pub fn flat_speedup_p50(&self) -> Option<f64> {
        let new = self.row("flat_search")?.stats.p50_ns;
        let old = self.row("flat_search_prerefactor_reference")?.stats.p50_ns;
        if new > 0.0 {
            Some(old / new)
        } else {
            None
        }
    }

    /// p50 speedup of the SQ8 quantized scan (default overscan) over the
    /// exact flat search on the same corpus — the quantization tier's
    /// acceptance metric.
    pub fn sq8_speedup_p50(&self) -> Option<f64> {
        let new = self.row("sq8_scan")?.stats.p50_ns;
        let old = self.row("flat_search")?.stats.p50_ns;
        if new > 0.0 {
            Some(old / new)
        } else {
            None
        }
    }

    /// p50 speedup of the every-core chunk-claiming scan over the same
    /// pooled path pinned to one worker — the scan-pool acceptance
    /// metric (both rows are bit-identity-checked before timing).
    pub fn parallel_scan_speedup_p50(&self) -> Option<f64> {
        let new = self.row("parallel_scan")?.stats.p50_ns;
        let old = self.row("parallel_scan_1worker")?.stats.p50_ns;
        if new > 0.0 {
            Some(old / new)
        } else {
            None
        }
    }
}

/// Deterministic raw Q16.16 component: |value| ≤ 2^16, well inside the
/// boundary contract (max_abs = 4.0 ⇒ |raw| ≤ 2^18).
fn raw_component(seed: u64, index: u64) -> i32 {
    ((splitmix64(seed ^ index) % 131_072) as i64 - 65_536) as i32
}

/// One corpus row (row `i`, laid out as dim consecutive components).
fn raw_row(seed: u64, i: u64, dim: usize) -> Vec<i32> {
    (0..dim as u64).map(|j| raw_component(seed, i * dim as u64 + j)).collect()
}

/// Fixed query set (disjoint seed stream from the corpus).
fn queries(seed: u64, count: usize, dim: usize) -> Vec<Vec<i32>> {
    (0..count as u64).map(|i| raw_row(seed ^ 0x5155_4552_59, i, dim)).collect()
}

/// Faithful reconstruction of the pre-refactor flat search: one heap
/// allocation per stored vector, per-row scalar distance through the
/// boxed row, collect *every* hit, full `sort_by`, truncate. Kept as a
/// benchmark-only reference so the suite reports the layout + streaming
/// top-k win on every run. Results are asserted identical to the arena
/// path (same integer math, same `(dist, id)` order).
struct PreRefactorFlat {
    vectors: Vec<Vec<i32>>,
    ids: Vec<u64>,
}

impl PreRefactorFlat {
    fn build(corpus: &[Vec<i32>]) -> Self {
        Self {
            vectors: corpus.to_vec(),
            ids: (0..corpus.len() as u64).collect(),
        }
    }

    fn search(&self, query: &[i32], k: usize) -> Vec<(i64, u64)> {
        let mut hits: Vec<(i64, u64)> = self
            .vectors
            .iter()
            .zip(&self.ids)
            .map(|(v, &id)| (<i32 as Scalar>::distance(Metric::L2, query, v), id))
            .collect();
        hits.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        hits.truncate(k);
        hits
    }
}

/// Run the whole suite. Builds each workload, benches it, then drops it
/// before the next one (bounds peak memory at roughly one corpus).
pub fn run(cfg: &SuiteConfig, label: &str) -> SuiteResult {
    let mut rows: Vec<SuiteRow> = Vec::new();
    let qs = queries(cfg.seed, 16, cfg.dim);
    let mut report = Report::new(format!(
        "valori bench [{label}] n={} dim={} k={} shards={}",
        cfg.n, cfg.dim, cfg.k, cfg.shards
    ));

    // --- flat search: arena + blocked kernels + streaming top-k ---------
    {
        let corpus: Vec<Vec<i32>> =
            (0..cfg.n as u64).map(|i| raw_row(cfg.seed, i, cfg.dim)).collect();
        let mut flat: FlatIndex<i32> = FlatIndex::new(cfg.dim, Metric::L2);
        for (i, v) in corpus.iter().enumerate() {
            flat.insert(i as u64, v.clone());
        }
        let reference = PreRefactorFlat::build(&corpus);
        // Bit-exactness spot check before timing anything.
        for q in &qs {
            let fast: Vec<(i64, u64)> =
                flat.search(q, cfg.k).into_iter().map(|h| (h.dist, h.id)).collect();
            assert_eq!(fast, reference.search(q, cfg.k), "arena search diverged from reference");
        }
        let mut qi = 0usize;
        let stats = bench(&cfg.bench, || {
            qi = (qi + 1) % qs.len();
            flat.search(&qs[qi], cfg.k)
        });
        rows.push(SuiteRow { name: "flat_search".into(), n: cfg.n, stats });
        report.add("flat_search", stats);

        let mut qi = 0usize;
        let stats = bench(&cfg.bench, || {
            qi = (qi + 1) % qs.len();
            reference.search(&qs[qi], cfg.k)
        });
        rows.push(SuiteRow {
            name: "flat_search_prerefactor_reference".into(),
            n: cfg.n,
            stats,
        });
        report.add("flat_search_prerefactor_reference", stats);

        // --- SQ8 quantized scan (blocked i8 phase-1 + exact re-rank) ----
        // Correctness first, at *covering* overscan (overscan·k ≥ n):
        // there the two-phase result is provably bit-identical to the
        // exact scan, so any divergence is a kernel bug, not recall loss.
        let covering = (cfg.n as u32).div_ceil(cfg.k.max(1) as u32) + 1;
        let mut prove: FlatIndex<i32> =
            FlatIndex::with_quant(cfg.dim, Metric::L2, QuantSpec::Sq8 { overscan: covering });
        for (i, v) in corpus.iter().enumerate() {
            prove.insert(i as u64, v.clone());
        }
        for q in &qs {
            let two_phase: Vec<(i64, u64)> = prove
                .search_sq8_two_phase(q, cfg.k)
                .expect("sq8 bench index is quantized")
                .into_iter()
                .map(|h| (h.dist, h.id))
                .collect();
            let exact: Vec<(i64, u64)> =
                flat.search(q, cfg.k).into_iter().map(|h| (h.dist, h.id)).collect();
            assert_eq!(two_phase, exact, "sq8 two-phase diverged from exact scan");
        }
        drop(prove);
        // Then time the production path at the default overscan.
        let mut sq8: FlatIndex<i32> = FlatIndex::with_quant(
            cfg.dim,
            Metric::L2,
            QuantSpec::Sq8 { overscan: SQ8_DEFAULT_OVERSCAN },
        );
        for (i, v) in corpus.iter().enumerate() {
            sq8.insert(i as u64, v.clone());
        }
        let mut qi = 0usize;
        let stats = bench(&cfg.bench, || {
            qi = (qi + 1) % qs.len();
            sq8.search(&qs[qi], cfg.k)
        });
        rows.push(SuiteRow { name: "sq8_scan".into(), n: cfg.n, stats });
        report.add("sq8_scan", stats);
    }

    // --- HNSW search (graph read path over the arena store) -------------
    {
        let n_hnsw = (cfg.n / 10).max(100);
        let mut hnsw: Hnsw<i32> = Hnsw::new(cfg.dim, Metric::L2, HnswParams::default());
        for i in 0..n_hnsw as u64 {
            hnsw.insert(i, raw_row(cfg.seed, i, cfg.dim));
        }
        let mut qi = 0usize;
        let stats = bench(&cfg.bench, || {
            qi = (qi + 1) % qs.len();
            hnsw.search(&qs[qi], cfg.k)
        });
        rows.push(SuiteRow { name: "hnsw_search".into(), n: n_hnsw, stats });
        report.add("hnsw_search", stats);
    }

    // --- sharded search (persistent worker-pool fan-out + merge) --------
    {
        let mut sk =
            ShardedKernel::new(KernelConfig::default_q16(cfg.dim).with_flat_index(), cfg.shards);
        let items: Vec<(u64, Vec<i32>)> =
            (0..cfg.n as u64).map(|i| (i, raw_row(cfg.seed, i, cfg.dim))).collect();
        for chunk in items.chunks(4096) {
            sk.apply_canon(&CanonCommand::InsertBatch { items: chunk.to_vec() })
                .expect("bench corpus insert");
        }
        let mut qi = 0usize;
        let stats = bench(&cfg.bench, || {
            qi = (qi + 1) % qs.len();
            sk.search_raw(&qs[qi], cfg.k).expect("bench search")
        });
        rows.push(SuiteRow { name: "sharded_search".into(), n: cfg.n, stats });
        report.add("sharded_search", stats);
    }

    // --- parallel scan (chunk-claiming pool vs a 1-worker pool) ---------
    // One shard on purpose: before the shared scan pool, a 1-shard
    // collection was a serial scan no matter how many cores the host
    // had. Both rows go through the pooled path, so the speedup isolates
    // the work-stealing fan-out (not pool dispatch overhead).
    {
        let mut sk = ShardedKernel::new(KernelConfig::default_q16(cfg.dim).with_flat_index(), 1);
        let items: Vec<(u64, Vec<i32>)> =
            (0..cfg.n as u64).map(|i| (i, raw_row(cfg.seed, i, cfg.dim))).collect();
        for chunk in items.chunks(4096) {
            sk.apply_canon(&CanonCommand::InsertBatch { items: chunk.to_vec() })
                .expect("bench corpus insert");
        }
        // Bit-identity before timing anything: the inline scan, the
        // 1-worker pool, and the every-core pool must agree exactly.
        let expect: Vec<_> = qs
            .iter()
            .map(|q| sk.search_raw_inline(q, cfg.k).expect("bench reference scan"))
            .collect();
        sk.set_scan_workers(1);
        for (q, e) in qs.iter().zip(&expect) {
            let hits = sk.search_raw_pooled(q, cfg.k).expect("bench 1-worker scan");
            assert_eq!(&hits, e, "1-worker pooled scan diverged from inline scan");
        }
        let mut qi = 0usize;
        let stats = bench(&cfg.bench, || {
            qi = (qi + 1) % qs.len();
            sk.search_raw_pooled(&qs[qi], cfg.k).expect("bench 1-worker scan")
        });
        rows.push(SuiteRow { name: "parallel_scan_1worker".into(), n: cfg.n, stats });
        report.add("parallel_scan_1worker", stats);

        sk.set_scan_workers(0); // 0 = one worker per core
        for (q, e) in qs.iter().zip(&expect) {
            let hits = sk.search_raw_pooled(q, cfg.k).expect("bench parallel scan");
            assert_eq!(&hits, e, "multi-worker scan diverged from inline scan");
        }
        let mut qi = 0usize;
        let stats = bench(&cfg.bench, || {
            qi = (qi + 1) % qs.len();
            sk.search_raw_pooled(&qs[qi], cfg.k).expect("bench parallel scan")
        });
        rows.push(SuiteRow { name: "parallel_scan".into(), n: cfg.n, stats });
        report.add("parallel_scan", stats);
    }

    // --- parallel batch upsert (router + per-shard worker application) --
    {
        let mut sk =
            ShardedKernel::new(KernelConfig::default_q16(cfg.dim).with_flat_index(), cfg.shards);
        // Upserts grow the kernel every call (warmup included), so bound
        // both phases: a token warmup and an iteration cap that ends the
        // bench at roughly one corpus of inserted vectors.
        let upsert_cfg = BenchConfig {
            warmup: std::time::Duration::from_millis(10),
            max_iters: (cfg.n / cfg.batch).max(10),
            ..cfg.bench
        };
        let mut next_id = 0u64;
        let stats = bench(&upsert_cfg, || {
            let items: Vec<(u64, Vec<i32>)> = (0..cfg.batch as u64)
                .map(|j| (next_id + j, raw_row(cfg.seed, next_id + j, cfg.dim)))
                .collect();
            next_id += cfg.batch as u64;
            sk.apply_canon(&CanonCommand::InsertBatch { items }).expect("bench upsert")
        });
        rows.push(SuiteRow { name: "batch_upsert".into(), n: cfg.batch, stats });
        report.add("batch_upsert", stats);
    }

    // --- HTTP round-trip (epoll reactor + keep-alive client) ------------
    {
        use crate::node::{serve, NodeConfig, NodeState};
        let sk =
            ShardedKernel::new(KernelConfig::default_q16(cfg.dim).with_flat_index(), cfg.shards);
        let state = std::sync::Arc::new(
            NodeState::new_sharded(sk, &NodeConfig::default(), None).expect("bench node"),
        );
        let items: Vec<(u64, Vec<i32>)> =
            (0..cfg.n as u64).map(|i| (i, raw_row(cfg.seed, i, cfg.dim))).collect();
        for chunk in items.chunks(4096) {
            state
                .apply_canon(&CanonCommand::InsertBatch { items: chunk.to_vec() })
                .expect("bench corpus insert");
        }
        let server = serve(std::sync::Arc::clone(&state), "127.0.0.1:0", 4).expect("bench serve");
        let bodies: Vec<String> = qs
            .iter()
            .map(|q| {
                let arr: Vec<Json> = q.iter().map(|&r| Json::Float(r as f64 / 65536.0)).collect();
                Json::object(vec![("vector", Json::Array(arr)), ("k", Json::Int(cfg.k as i64))])
                    .to_string()
            })
            .collect();
        let mut conn =
            crate::http::client::Connection::connect(&server.addr()).expect("bench connect");
        let mut qi = 0usize;
        let stats = bench(&cfg.bench, || {
            qi = (qi + 1) % bodies.len();
            let (status, body) =
                conn.request("POST", "/v1/query", bodies[qi].as_bytes()).expect("bench http");
            assert_eq!(status, 200, "bench query failed");
            body
        });
        rows.push(SuiteRow { name: "http_roundtrip".into(), n: cfg.n, stats });
        report.add("http_roundtrip", stats);
        server.stop();
    }

    // --- multi-collection routing (/v2 envelope + per-tenant kernels) ---
    // Keep-alive queries round-robined over 4 collections: measures the
    // collection-manager lookup + typed-envelope overhead on top of the
    // same kernel search path the http_roundtrip row times.
    {
        use crate::node::collections::{
            serve_collections, CollectionManager, CollectionSpec, ManagerConfig,
        };
        let spec = CollectionSpec::new(cfg.dim, 1, true, QuantSpec::None);
        let manager = std::sync::Arc::new(
            CollectionManager::new(
                ManagerConfig {
                    spec: spec.clone(),
                    workers: 4,
                    data_dir: None,
                    default_wal: None,
                    governor: Default::default(),
                },
                None,
            )
            .expect("bench manager"),
        );
        let per = (cfg.n / 4).max(1);
        for c in 0..4u64 {
            let state = manager.create(&format!("b{c}"), spec.clone()).expect("bench collection");
            let items: Vec<(u64, Vec<i32>)> =
                (0..per as u64).map(|i| (i, raw_row(cfg.seed ^ c, i, cfg.dim))).collect();
            for chunk in items.chunks(4096) {
                state
                    .apply_canon(&CanonCommand::InsertBatch { items: chunk.to_vec() })
                    .expect("bench corpus insert");
            }
        }
        let server = serve_collections(std::sync::Arc::clone(&manager), "127.0.0.1:0", 4)
            .expect("bench serve");
        let bodies: Vec<String> = qs
            .iter()
            .map(|q| {
                let arr: Vec<Json> = q.iter().map(|&r| Json::Float(r as f64 / 65536.0)).collect();
                Json::object(vec![("vector", Json::Array(arr)), ("k", Json::Int(cfg.k as i64))])
                    .to_string()
            })
            .collect();
        let mut conn =
            crate::http::client::Connection::connect(&server.addr()).expect("bench connect");
        let mut qi = 0usize;
        let stats = bench(&cfg.bench, || {
            qi += 1;
            let path = format!("/v2/collections/b{}/query", qi % 4);
            let (status, body) = conn
                .request("POST", &path, bodies[qi % bodies.len()].as_bytes())
                .expect("bench http");
            assert_eq!(status, 200, "bench multi-collection query failed");
            body
        });
        rows.push(SuiteRow { name: "multi_collection_route".into(), n: cfg.n, stats });
        report.add("multi_collection_route", stats);
        server.stop();
    }

    // --- snapshot stream (chunked encode → verify-on-arrival decode) ----
    // One iteration = full VSTREAM1 writer→reader round trip at the
    // default 64 KiB chunk, ending in a root-hash equality assertion:
    // the row times the bit-exact transfer path online migration uses,
    // with writer-side memory bounded at one shard frame + one chunk
    // instead of the whole deployment.
    {
        use crate::snapshot::{SnapshotReader, SnapshotWriter};
        let mut sk =
            ShardedKernel::new(KernelConfig::default_q16(cfg.dim).with_flat_index(), cfg.shards);
        let items: Vec<(u64, Vec<i32>)> =
            (0..cfg.n as u64).map(|i| (i, raw_row(cfg.seed, i, cfg.dim))).collect();
        for chunk in items.chunks(4096) {
            sk.apply_canon(&CanonCommand::InsertBatch { items: chunk.to_vec() })
                .expect("bench corpus insert");
        }
        let expected_root = sk.root_hash();
        // A full stream per iteration is heavyweight; cap iterations
        // like the upsert row so `--quick` stays quick.
        let stream_cfg = BenchConfig {
            warmup: std::time::Duration::from_millis(10),
            max_iters: 20,
            ..cfg.bench
        };
        let stats = bench(&stream_cfg, || {
            let mut writer = SnapshotWriter::for_kernel(&sk, 64 * 1024);
            let mut reader = SnapshotReader::new();
            while let Some(block) = writer.next_block() {
                reader.feed(&block.expect("bench stream block")).expect("bench stream feed");
            }
            let snap = reader.finalize().expect("bench stream finalize");
            assert_eq!(snap.root_hash(), expected_root, "streaming changed bits");
            snap
        });
        rows.push(SuiteRow { name: "snapshot_stream".into(), n: cfg.n, stats });
        report.add("snapshot_stream", stats);
    }

    // --- Merkle maintenance + membership proofs (crate::proof) ----------
    // merkle_update: one iteration = one record-level tree refresh —
    // re-encode the slot's canonical leaf and recompute its O(log n) root
    // path, the exact incremental work every applied command adds.
    // Driving it through `repair_slot` with the record's own bytes makes
    // the workload a state no-op, so the timing is steady-state (the
    // corpus never grows) and the root is asserted unchanged after.
    {
        use crate::proof::leaf;
        let mut kernel = Kernel::new(KernelConfig::default_q16(cfg.dim).with_flat_index());
        for i in 0..cfg.n as u64 {
            kernel
                .apply_canon(&CanonCommand::Insert { id: i, raw: raw_row(cfg.seed, i, cfg.dim) })
                .expect("bench corpus insert");
        }
        let rec = leaf::decode(&kernel.merkle_leaf_encoding(0).expect("bench slot 0 leaf"))
            .expect("bench leaf decode");
        let root = kernel.merkle_root();
        let stats = bench(&cfg.bench, || {
            kernel.repair_slot(0, &rec).expect("bench merkle refresh")
        });
        assert_eq!(kernel.merkle_root(), root, "no-op merkle refresh changed the root");
        rows.push(SuiteRow { name: "merkle_update".into(), n: cfg.n, stats });
        report.add("merkle_update", stats);

        // proof_generate: canonical leaf encode + sibling-path walk for a
        // rotating id — the `GET .../proof?id=N` hot path.
        let mut qi = 0u64;
        let stats = bench(&cfg.bench, || {
            qi = (qi + 1) % cfg.n as u64;
            kernel.merkle_proof(qi).expect("bench membership proof")
        });
        rows.push(SuiteRow { name: "proof_generate".into(), n: cfg.n, stats });
        report.add("proof_generate", stats);
    }

    report.print();
    let result = SuiteResult {
        config_label: label.to_string(),
        n: cfg.n,
        dim: cfg.dim,
        k: cfg.k,
        shards: cfg.shards,
        seed: cfg.seed,
        rows,
    };
    if let Some(speedup) = result.flat_speedup_p50() {
        println!("  note: flat search p50 speedup vs pre-refactor reference: {speedup:.2}x");
    }
    if let Some(speedup) = result.sq8_speedup_p50() {
        println!("  note: sq8 scan p50 speedup vs exact flat search: {speedup:.2}x");
    }
    if let Some(speedup) = result.parallel_scan_speedup_p50() {
        println!("  note: parallel scan p50 speedup vs 1-worker pool: {speedup:.2}x");
    }
    result
}

/// Serialize a suite result (the `BENCH_search.json` payload).
pub fn suite_json(r: &SuiteResult) -> Json {
    let rows: Vec<Json> = r
        .rows
        .iter()
        .map(|row| {
            Json::object(vec![
                ("name", Json::str(row.name.clone())),
                ("n", Json::Int(row.n as i64)),
                ("iters", Json::Int(row.stats.iters as i64)),
                ("mean_ns", Json::Float(row.stats.mean_ns)),
                ("p50_ns", Json::Float(row.stats.p50_ns)),
                ("p95_ns", Json::Float(row.stats.p95_ns)),
                ("p99_ns", Json::Float(row.stats.p99_ns)),
                ("ops_per_sec", Json::Float(row.stats.ops_per_sec())),
            ])
        })
        .collect();
    let mut fields = vec![
        ("schema", Json::Int(1)),
        ("suite", Json::str("valori-search")),
        ("config", Json::str(r.config_label.clone())),
        ("n", Json::Int(r.n as i64)),
        ("dim", Json::Int(r.dim as i64)),
        ("k", Json::Int(r.k as i64)),
        ("shards", Json::Int(r.shards as i64)),
        ("seed", Json::Int(r.seed as i64)),
        ("rows", Json::Array(rows)),
    ];
    if let Some(speedup) = r.flat_speedup_p50() {
        fields.push(("flat_speedup_p50_vs_prerefactor", Json::Float(speedup)));
    }
    if let Some(speedup) = r.sq8_speedup_p50() {
        fields.push(("sq8_speedup_p50_vs_flat", Json::Float(speedup)));
    }
    if let Some(speedup) = r.parallel_scan_speedup_p50() {
        fields.push(("parallel_scan_speedup_p50_vs_1worker", Json::Float(speedup)));
    }
    Json::object(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn tiny() -> SuiteConfig {
        SuiteConfig {
            n: 400,
            dim: 16,
            k: 5,
            shards: 2,
            seed: 7,
            batch: 64,
            bench: BenchConfig {
                warmup: Duration::from_millis(1),
                measure: Duration::from_millis(10),
                max_iters: 50,
                min_iters: 3,
            },
        }
    }

    #[test]
    fn corpus_is_deterministic_and_in_contract() {
        let a = raw_row(42, 7, 32);
        let b = raw_row(42, 7, 32);
        assert_eq!(a, b);
        assert_ne!(a, raw_row(42, 8, 32));
        assert!(a.iter().all(|&x| x.abs() <= 65_536));
    }

    #[test]
    fn suite_runs_and_serializes() {
        let r = run(&tiny(), "test");
        for name in [
            "flat_search",
            "flat_search_prerefactor_reference",
            "sq8_scan",
            "hnsw_search",
            "sharded_search",
            "parallel_scan_1worker",
            "parallel_scan",
            "batch_upsert",
            "http_roundtrip",
            "multi_collection_route",
            "snapshot_stream",
            "merkle_update",
            "proof_generate",
        ] {
            assert!(r.row(name).is_some(), "missing row {name}");
            assert!(r.row(name).unwrap().stats.iters >= 3);
        }
        assert!(r.flat_speedup_p50().is_some());
        assert!(r.sq8_speedup_p50().is_some());
        assert!(r.parallel_scan_speedup_p50().is_some());
        let json = suite_json(&r).to_string();
        let parsed = crate::json::parse(&json).expect("bench json parses");
        assert_eq!(parsed.get("suite").as_str(), Some("valori-search"));
        assert_eq!(parsed.get("rows").as_array().map(|a| a.len()), Some(13));
        assert!(parsed.get("sq8_speedup_p50_vs_flat").as_f64().is_some());
        assert!(parsed.get("parallel_scan_speedup_p50_vs_1worker").as_f64().is_some());
    }
}
