//! Deterministic hashing tokenizer for the embedding encoder.
//!
//! Substitute for a learned subword tokenizer (DESIGN §2): words are
//! lower-cased, split on non-alphanumerics, and hashed into the model's
//! vocabulary with FNV-1a. Identical text therefore always produces
//! identical token ids on every platform — the tokenizer is *inside* no
//! boundary (it is exact integer math), so it never contributes divergence;
//! all float nondeterminism in the pipeline comes from the encoder itself,
//! matching the paper's §2.2 claim that divergence enters at embedding
//! generation.

#![forbid(unsafe_code)]

use crate::hash::fnv1a64;

/// Token id 0 is reserved for padding (must match `model.PAD_ID`).
pub const PAD_ID: i32 = 0;

/// Hashing word tokenizer with a fixed vocabulary size.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab_size: u32,
    seq_len: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: u32, seq_len: usize) -> Self {
        assert!(vocab_size > 1, "vocab must leave room for the pad id");
        Self { vocab_size, seq_len }
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn vocab_size(&self) -> u32 {
        self.vocab_size
    }

    /// Split text into lower-cased word strings (unicode alphanumeric runs).
    pub fn words(text: &str) -> Vec<String> {
        let mut out = Vec::new();
        let mut cur = String::new();
        for c in text.chars() {
            if c.is_alphanumeric() {
                for lc in c.to_lowercase() {
                    cur.push(lc);
                }
            } else if !cur.is_empty() {
                out.push(std::mem::take(&mut cur));
            }
        }
        if !cur.is_empty() {
            out.push(cur);
        }
        out
    }

    /// Map one word to a token id in `[1, vocab_size)`.
    pub fn token_id(&self, word: &str) -> i32 {
        let h = fnv1a64(word.as_bytes());
        (1 + (h % (self.vocab_size as u64 - 1))) as i32
    }

    /// Encode text to a fixed-length id sequence (truncate / pad with 0).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids: Vec<i32> =
            Self::words(text).iter().take(self.seq_len).map(|w| self.token_id(w)).collect();
        ids.resize(self.seq_len, PAD_ID);
        ids
    }

    /// Encode a batch, padding with all-pad rows up to `batch` sequences.
    /// Panics if more than `batch` texts are passed.
    pub fn encode_batch(&self, texts: &[&str], batch: usize) -> Vec<i32> {
        assert!(texts.len() <= batch, "batch overflow: {} > {batch}", texts.len());
        let mut out = Vec::with_capacity(batch * self.seq_len);
        for t in texts {
            out.extend_from_slice(&self.encode(t));
        }
        out.resize(batch * self.seq_len, PAD_ID);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(4096, 64)
    }

    #[test]
    fn words_split_and_lowercase() {
        assert_eq!(
            Tokenizer::words("Revenue for April, 2024!"),
            vec!["revenue", "for", "april", "2024"]
        );
        assert_eq!(Tokenizer::words(""), Vec::<String>::new());
        assert_eq!(Tokenizer::words("  .,;  "), Vec::<String>::new());
    }

    #[test]
    fn encode_is_deterministic() {
        let t = tok();
        assert_eq!(t.encode("What is the profit in April?"), t.encode("What is the profit in April?"));
    }

    #[test]
    fn ids_in_range_and_never_pad() {
        let t = tok();
        for w in ["a", "april", "zzz", "42", "ünïcode"] {
            let id = t.token_id(w);
            assert!(id >= 1 && (id as u32) < 4096, "{w} -> {id}");
        }
    }

    #[test]
    fn encode_pads_and_truncates() {
        let t = Tokenizer::new(4096, 4);
        let short = t.encode("one two");
        assert_eq!(short.len(), 4);
        assert_eq!(&short[2..], &[PAD_ID, PAD_ID]);
        let long = t.encode("a b c d e f g");
        assert_eq!(long.len(), 4);
        assert!(long.iter().all(|&id| id != PAD_ID));
    }

    #[test]
    fn same_word_same_id_case_insensitive() {
        let t = tok();
        assert_eq!(t.token_id("april"), t.encode("APRIL")[0]);
    }

    #[test]
    fn batch_layout() {
        let t = Tokenizer::new(4096, 8);
        let out = t.encode_batch(&["hello world", "foo"], 4);
        assert_eq!(out.len(), 4 * 8);
        assert_ne!(out[0], PAD_ID);
        assert_ne!(out[8], PAD_ID);
        assert!(out[16..].iter().all(|&id| id == PAD_ID));
    }

    #[test]
    #[should_panic(expected = "batch overflow")]
    fn batch_overflow_panics() {
        let t = Tokenizer::new(4096, 8);
        t.encode_batch(&["a", "b", "c"], 2);
    }

    #[test]
    fn stability_pin() {
        // Token ids feed AOT-compiled models; pin a few so accidental
        // tokenizer changes are caught.
        let t = tok();
        let ids = t.encode("Revenue for April");
        assert_eq!(&ids[..3], &[t.token_id("revenue"), t.token_id("for"), t.token_id("april")]);
        assert_eq!(t.token_id("revenue"), t.token_id("revenue"));
    }
}
