//! `valori lint` — the determinism auditor.
//!
//! The paper's thesis is that determinism is enforced *at the memory
//! boundary*, not by reviewer vigilance. This module makes the informal
//! zone discipline a checked invariant: every file under `rust/src` is
//! classified into a determinism zone by the checked-in [`zone_of`] map,
//! and a closed, token-level rule set (R1–R6, see [`rules`]) rejects
//! the constructs that historically break bit-reproducibility — floats
//! in the state path, hash-randomized iteration, wall-clock and
//! environment reads feeding state, stray `unsafe`, and platform-width
//! encodes. DETERMINISM.md at the repo root documents the rules, the
//! zones, and the annotation workflow.
//!
//! Zones:
//!
//! - **state** — code the state hash can observe. Everything here must
//!   be integer-only and platform-independent.
//! - **boundary** — the front end: admission control may read the
//!   clock (deliberately unlogged), floats are fine (JSON carries
//!   them), but hash-randomized collections are still banned.
//! - **exempt** — experiments, benches, test support, the float
//!   baseline: measured, never hashed.
//!
//! Legitimate float crossings in the state zone (quantize/dequantize,
//! the boundary contract types) are annotated in place:
//!
//! ```text
//! // lint: float-boundary — quantization entry point, floats stop here
//! pub fn from_f32(v: &[f32], ...) -> Result<FixedVector, BoundaryError>
//! ```
//!
//! A standalone marker covers the next item; a trailing marker covers
//! its own line; a marker without a justification is itself a finding.
//!
//! Findings diff against the committed `lint_baseline.json` (see
//! [`baseline`]): new findings fail, stale baseline entries fail. The
//! repo's committed baseline is empty — keep it that way.

#![forbid(unsafe_code)]

pub mod baseline;
pub mod lexer;
pub mod rules;

use crate::json::Json;
use std::fmt;
use std::path::{Path, PathBuf};

/// Determinism zone of a source file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Zone {
    State,
    Boundary,
    Exempt,
}

impl Zone {
    pub fn name(self) -> &'static str {
        match self {
            Zone::State => "state",
            Zone::Boundary => "boundary",
            Zone::Exempt => "exempt",
        }
    }
}

/// Rule identifiers. The set is closed on purpose: a lint that grows
/// rules silently is a lint nobody trusts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// No floats in the state zone outside annotated boundary items.
    R1,
    /// No hash-randomized collections (state + boundary).
    R2,
    /// No wall-clock reads in the state zone.
    R3,
    /// No randomness / environment reads in the state zone.
    R4,
    /// `unsafe` confined to the allowlist, each site `// SAFETY:`-ed.
    R5,
    /// No platform-width / native-endian encode–decode.
    R6,
}

impl Rule {
    pub fn code(self) -> &'static str {
        match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
            Rule::R6 => "R6",
        }
    }

    pub fn from_code(code: &str) -> Option<Rule> {
        Some(match code {
            "R1" => Rule::R1,
            "R2" => Rule::R2,
            "R3" => Rule::R3,
            "R4" => Rule::R4,
            "R5" => Rule::R5,
            "R6" => Rule::R6,
            _ => return None,
        })
    }
}

/// One audit finding. `key` is the stable identity used by the
/// baseline (`(rule, file, key)` — line numbers deliberately excluded
/// so edits that shift code never churn the baseline).
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub rule: Rule,
    pub file: String,
    pub line: u32,
    pub zone: Zone,
    pub key: String,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {} [{}] {}",
            self.file,
            self.line,
            self.rule.code(),
            self.zone.name(),
            self.message
        )
    }
}

/// Directories (first path segment under `rust/src`) in the state zone.
pub const STATE_DIRS: &[&str] = &[
    "state", "index", "fixed", "hash", "snapshot", "wal", "codec", "vector", "graph", "distance",
    "proof",
];

/// Directories in the boundary zone.
pub const BOUNDARY_DIRS: &[&str] =
    &["api", "node", "http", "replication", "cli", "json", "lint", "tokenizer"];

/// Directories in the exempt zone (measured, never hashed).
pub const EXEMPT_DIRS: &[&str] = &["experiments", "bench", "testing", "corpus", "runtime"];

/// File-granular overrides, consulted before the directory map.
pub const EXEMPT_FILES: &[&str] = &["distance/float.rs"];

/// Top-level files in the boundary zone.
pub const BOUNDARY_FILES: &[&str] = &["lib.rs", "main.rs"];

/// Classify a path (relative to the audit root, `/`-separated) into its
/// determinism zone. Unknown paths default to **state** — a new module
/// gets the strictest rules until someone classifies it here, on
/// purpose.
pub fn zone_of(rel: &str) -> Zone {
    if EXEMPT_FILES.contains(&rel) {
        return Zone::Exempt;
    }
    if BOUNDARY_FILES.contains(&rel) {
        return Zone::Boundary;
    }
    let first = rel.split('/').next().unwrap_or(rel);
    if EXEMPT_DIRS.contains(&first) {
        return Zone::Exempt;
    }
    if BOUNDARY_DIRS.contains(&first) {
        return Zone::Boundary;
    }
    if STATE_DIRS.contains(&first) {
        return Zone::State;
    }
    Zone::State
}

/// Audit one file's source text under an explicit zone (test hook; the
/// walker uses [`audit_file`]).
pub fn audit_source(rel: &str, zone: Zone, src: &str) -> Vec<Finding> {
    let scan = lexer::scan(src);
    let (ctx, mut findings) = rules::RuleContext::new(rel, zone, &scan);
    ctx.check(&mut findings);
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Audit one file's source text, zone-classified by [`zone_of`].
pub fn audit_file(rel: &str, src: &str) -> Vec<Finding> {
    audit_source(rel, zone_of(rel), src)
}

/// Collect every `.rs` file under `root`, sorted by relative path so
/// the finding order (and therefore the JSON output) is deterministic.
pub fn source_files(root: &Path) -> std::io::Result<Vec<(String, PathBuf)>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push((rel, path));
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Walk `root` and audit every source file.
pub fn audit_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for (rel, path) in source_files(root)? {
        let src = std::fs::read_to_string(&path)?;
        findings.extend(audit_file(&rel, &src));
    }
    Ok(findings)
}

/// Machine-readable report: the findings plus the baseline diff.
pub fn report_json(findings: &[Finding], diff: &baseline::Diff) -> Json {
    let finding_json = |f: &Finding| {
        Json::object(vec![
            ("rule", Json::str(f.rule.code())),
            ("file", Json::str(f.file.clone())),
            ("line", Json::Int(f.line as i64)),
            ("zone", Json::str(f.zone.name())),
            ("key", Json::str(f.key.clone())),
            ("message", Json::str(f.message.clone())),
        ])
    };
    Json::object(vec![
        ("version", Json::Int(1)),
        ("findings", Json::Array(findings.iter().map(finding_json).collect())),
        ("new", Json::Array(diff.new.iter().map(finding_json).collect())),
        (
            "stale",
            Json::Array(
                diff.stale
                    .iter()
                    .map(|e| {
                        Json::object(vec![
                            ("rule", Json::str(e.rule.code())),
                            ("file", Json::str(e.file.clone())),
                            ("key", Json::str(e.key.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("clean", Json::Bool(diff.is_clean())),
    ])
}

/// Insert `// SAFETY: TODO` stubs above every `unsafe` in `src` that
/// the auditor reports as missing its comment. Returns the rewritten
/// source and how many stubs were inserted. The stubs still fail the
/// lint (`todo-safety-comment`) — they make the finding actionable,
/// they do not silence it.
pub fn add_safety_stubs(rel: &str, src: &str) -> (String, usize) {
    let missing: Vec<u32> = audit_file(rel, src)
        .into_iter()
        .filter(|f| f.rule == Rule::R5 && f.key == "missing-safety-comment")
        .map(|f| f.line)
        .collect();
    if missing.is_empty() {
        return (src.to_string(), 0);
    }
    let lines: Vec<&str> = src.split('\n').collect();
    let mut out: Vec<String> = Vec::with_capacity(lines.len() + missing.len());
    let mut inserted = 0usize;
    for (idx, text) in lines.iter().enumerate() {
        let lineno = (idx + 1) as u32;
        if missing.contains(&lineno) {
            let indent: String = text.chars().take_while(|c| c.is_whitespace()).collect();
            out.push(format!("{indent}// SAFETY: TODO — document why this is sound"));
            inserted += 1;
        }
        out.push((*text).to_string());
    }
    (out.join("\n"), inserted)
}
