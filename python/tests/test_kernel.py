"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

The integer kernels (quantize, distances) must match bit-exactly — they sit
inside the determinism boundary. Attention is float (outside the boundary)
and is checked to tolerance. Hypothesis sweeps shapes/values.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn
from compile.kernels import fixedpoint as fp
from compile.kernels import ref

Q16_SCALE = 1 << 16


# ---------------------------------------------------------------- attention
class TestAttention:
    def _rand_qkv(self, rng, b=2, h=2, s=16, dh=8):
        shape = (b, h, s, dh)
        q = rng.standard_normal(shape, dtype=np.float32)
        k = rng.standard_normal(shape, dtype=np.float32)
        v = rng.standard_normal(shape, dtype=np.float32)
        bias = np.zeros((b, s), dtype=np.float32)
        return q, k, v, bias

    def test_matches_reference_unmasked(self, rng):
        q, k, v, bias = self._rand_qkv(rng)
        out = np.asarray(attn.attention(q, k, v, bias))
        want = np.asarray(ref.attention_ref(q, k, v, bias))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_matches_reference_masked(self, rng):
        q, k, v, bias = self._rand_qkv(rng, b=3, s=12)
        bias[:, 7:] = -1e9  # pad out the tail keys
        out = np.asarray(attn.attention(q, k, v, bias))
        want = np.asarray(ref.attention_ref(q, k, v, bias))
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_fully_masked_key_gets_no_weight(self, rng):
        q, k, v, bias = self._rand_qkv(rng, b=1, h=1, s=4, dh=4)
        bias[:, 3] = -1e9
        v2 = v.copy()
        v2[:, :, 3, :] = 1e6  # junk in the masked position
        out1 = np.asarray(attn.attention(q, k, v, bias))
        out2 = np.asarray(attn.attention(q, k, v2, bias))
        np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)

    def test_softmax_rows_are_convex_combination(self, rng):
        q, k, v, bias = self._rand_qkv(rng, b=1, h=1, s=8, dh=4)
        out = np.asarray(attn.attention(q, k, v, bias))
        # outputs stay within the convex hull bounds of v rows
        assert out.max() <= v.max() + 1e-4
        assert out.min() >= v.min() - 1e-4

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        s=st.integers(2, 24),
        dh=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, b, h, s, dh, seed):
        rng = np.random.default_rng(seed)
        q = rng.standard_normal((b, h, s, dh), dtype=np.float32)
        k = rng.standard_normal((b, h, s, dh), dtype=np.float32)
        v = rng.standard_normal((b, h, s, dh), dtype=np.float32)
        bias = np.where(rng.random((b, s)) < 0.2, -1e9, 0.0).astype(np.float32)
        # never mask *all* keys of a row (softmax would be degenerate)
        bias[:, 0] = 0.0
        out = np.asarray(attn.attention(q, k, v, bias))
        want = np.asarray(ref.attention_ref(q, k, v, bias))
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- quantize
def quantize_numpy(x):
    """Independent numpy model of the Rust boundary (round-ties-even,
    saturating) — NOT implemented via the jnp reference."""
    scaled = np.asarray(x, np.float64) * Q16_SCALE
    scaled = np.nan_to_num(scaled, nan=0.0, posinf=2**31 - 1, neginf=-(2**31))
    r = np.rint(scaled)  # banker's rounding
    r = np.clip(r, -(2**31), 2**31 - 1)
    return r.astype(np.int32)


class TestQuantize:
    def test_matches_ref_and_numpy(self, rng):
        x = rng.uniform(-2.0, 2.0, size=(8, 128)).astype(np.float32)
        out = np.asarray(fp.quantize(x))
        np.testing.assert_array_equal(out, np.asarray(ref.quantize_ref(x)))
        np.testing.assert_array_equal(out, quantize_numpy(x.astype(np.float64)))

    def test_exact_values(self):
        x = np.array([[0.0, 1.0, -1.0, 0.5, -0.5]], dtype=np.float32)
        out = np.asarray(fp.quantize(x))[0]
        np.testing.assert_array_equal(out, [0, 65536, -65536, 32768, -32768])

    def test_ties_round_to_even(self):
        # 2.5/65536 ties between raw 2 and 3 -> 2 ; 3.5/65536 -> 4
        x = np.array([[2.5 / 65536, 3.5 / 65536, -2.5 / 65536]], dtype=np.float32)
        out = np.asarray(fp.quantize(x))[0]
        np.testing.assert_array_equal(out, [2, 4, -2])

    def test_saturation(self):
        x = np.array([[1e30, -1e30, np.inf, -np.inf]], dtype=np.float32)
        out = np.asarray(fp.quantize(x))[0]
        np.testing.assert_array_equal(out, [2**31 - 1, -(2**31), 2**31 - 1, -(2**31)])

    def test_nan_maps_to_zero(self):
        x = np.array([[np.nan]], dtype=np.float32)
        assert np.asarray(fp.quantize(x))[0, 0] == 0

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([0.01, 1.0, 100.0, 30000.0]))
    def test_hypothesis_matches_numpy(self, seed, scale):
        rng = np.random.default_rng(seed)
        x = (rng.standard_normal((4, 64)) * scale).astype(np.float32)
        out = np.asarray(fp.quantize(x))
        np.testing.assert_array_equal(out, quantize_numpy(x.astype(np.float64)))


# ---------------------------------------------------------------- distances
def rust_model_l2(query, db):
    """Independent numpy model of rust `l2sq_q16` (i64 accumulate)."""
    q = query.astype(np.int64)
    d = db.astype(np.int64)
    diff = d - q[None, :]
    return np.sum(diff * diff, axis=1)


def rust_model_dot(query, db):
    q = query.astype(np.int64)
    d = db.astype(np.int64)
    return np.sum(d * q[None, :], axis=1)


class TestDistances:
    def _rand_q16(self, rng, n, d, bound=2**18):
        return rng.integers(-bound, bound, size=(n, d), dtype=np.int64).astype(np.int32)

    def test_l2_bit_exact(self, rng):
        db = self._rand_q16(rng, fp.TILE_N * 2, 128)
        q = self._rand_q16(rng, 1, 128)[0]
        out = np.asarray(fp.l2sq_q16(q, db))
        assert out.dtype == np.int64
        np.testing.assert_array_equal(out, np.asarray(ref.l2sq_q16_ref(q, db)))
        np.testing.assert_array_equal(out, rust_model_l2(q, db))

    def test_dot_bit_exact(self, rng):
        db = self._rand_q16(rng, fp.TILE_N, 128)
        q = self._rand_q16(rng, 1, 128)[0]
        out = np.asarray(fp.dot_q16(q, db))
        np.testing.assert_array_equal(out, np.asarray(ref.dot_q16_ref(q, db)))
        np.testing.assert_array_equal(out, rust_model_dot(q, db))

    def test_zero_distance_to_self(self, rng):
        db = self._rand_q16(rng, fp.TILE_N, 64)
        out = np.asarray(fp.l2sq_q16(db[0], db))
        assert out[0] == 0
        assert (out >= 0).all()

    def test_rejects_non_tile_multiple(self, rng):
        db = self._rand_q16(rng, fp.TILE_N + 1, 64)
        q = db[0]
        with pytest.raises(AssertionError):
            fp.l2sq_q16(q, db)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        d=st.sampled_from([8, 64, 128, 384]),
        tiles=st.integers(1, 3),
    )
    def test_hypothesis_bit_exact(self, seed, d, tiles):
        rng = np.random.default_rng(seed)
        db = self._rand_q16(rng, fp.TILE_N * tiles, d)
        q = self._rand_q16(rng, 1, d)[0]
        np.testing.assert_array_equal(np.asarray(fp.l2sq_q16(q, db)), rust_model_l2(q, db))
        np.testing.assert_array_equal(np.asarray(fp.dot_q16(q, db)), rust_model_dot(q, db))

    def test_determinism_across_runs(self, rng):
        db = self._rand_q16(rng, fp.TILE_N, 128)
        q = self._rand_q16(rng, 1, 128)[0]
        a = np.asarray(fp.l2sq_q16(q, db))
        for _ in range(3):
            np.testing.assert_array_equal(a, np.asarray(fp.l2sq_q16(q, db)))
