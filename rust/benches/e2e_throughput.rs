//! End-to-end serving benchmark (Figure 1's full stack): HTTP node +
//! dynamic batcher + AOT embedder + deterministic kernel, measured from a
//! client's point of view.
//!
//! Run: `make artifacts && cargo bench --bench e2e_throughput`

use std::sync::Arc;
use std::time::{Duration, Instant};
use valori::corpus::CorpusGen;
use valori::http::client;
use valori::json::Json;
use valori::node::{serve, EmbedBatcher, NodeConfig, NodeState};
use valori::runtime::{artifacts_available, artifacts_dir, embedder::Env, Embedder, Engine};
use valori::state::{Kernel, KernelConfig};

fn main() {
    let quick = std::env::var("VALORI_BENCH_QUICK").is_ok();
    let n_docs = if quick { 64 } else { 256 };
    let n_queries = if quick { 64 } else { 256 };

    // ---- vector-only serving (no embedder needed) -----------------------
    vector_api_throughput(n_docs * 4, n_queries * 4);

    // ---- full text path (needs artifacts) --------------------------------
    if !artifacts_available() {
        println!("\n(artifacts not built — skipping the text/embedding path)");
        return;
    }
    text_api_throughput(n_docs, n_queries);
}

fn vector_api_throughput(n_docs: usize, n_queries: usize) {
    let kernel = Kernel::new(KernelConfig::default_q16(128));
    let state =
        Arc::new(NodeState::new(kernel, &NodeConfig { workers: 8, wal_path: None }, None).unwrap());
    let server = serve(Arc::clone(&state), "127.0.0.1:0", 8).unwrap();
    let addr = server.addr();

    let vectors = valori::experiments::synthetic_embeddings(n_docs, 128, 16, 5);
    let t0 = Instant::now();
    for (id, v) in vectors.iter().enumerate() {
        let body = Json::object(vec![
            ("id", Json::Int(id as i64)),
            ("vector", Json::Array(v.iter().map(|&x| Json::Float(x as f64)).collect())),
        ]);
        let (status, _) = client::post_json(&addr, "/v1/insert", &body).unwrap();
        assert_eq!(status, 200);
    }
    let insert_s = t0.elapsed().as_secs_f64();

    let queries = valori::experiments::synthetic_embeddings(n_queries, 128, 16, 9);
    let t0 = Instant::now();
    let mut lat = Vec::with_capacity(n_queries);
    for q in &queries {
        let body = Json::object(vec![
            ("vector", Json::Array(q.iter().map(|&x| Json::Float(x as f64)).collect())),
            ("k", Json::Int(10)),
        ]);
        let tq = Instant::now();
        let (status, _) = client::post_json(&addr, "/v1/query", &body).unwrap();
        lat.push(tq.elapsed().as_secs_f64() * 1e6);
        assert_eq!(status, 200);
    }
    let query_s = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());

    println!("\n=== e2e vector API over HTTP ({n_docs} inserts, {n_queries} queries) ===");
    println!(
        "inserts: {:.0}/s | queries: {:.0}/s | query p50 {:.0} µs p99 {:.0} µs (incl. HTTP + JSON)",
        n_docs as f64 / insert_s,
        n_queries as f64 / query_s,
        lat[lat.len() / 2],
        lat[(lat.len() * 99 / 100).min(lat.len() - 1)]
    );
    server.stop();
}

fn text_api_throughput(n_docs: usize, n_queries: usize) {
    let batcher = EmbedBatcher::start(
        || {
            let engine = Engine::cpu()?;
            Embedder::load(&engine, artifacts_dir(), Env::A)
        },
        Duration::from_millis(2),
    )
    .expect("embedder");
    let kernel = Kernel::new(KernelConfig::default_q16(128));
    let state = Arc::new(
        NodeState::new(kernel, &NodeConfig { workers: 8, wal_path: None }, Some(batcher.handle()))
            .unwrap(),
    );
    let server = serve(Arc::clone(&state), "127.0.0.1:0", 8).unwrap();
    let addr = server.addr();

    let mut gen = CorpusGen::new(17);
    let docs = gen.docs(n_docs);

    // Concurrent text ingest: 8 client threads → the batcher fuses
    // embedding calls into full batches.
    let t0 = Instant::now();
    let threads: Vec<_> = docs
        .chunks(n_docs.div_ceil(8))
        .map(|chunk| {
            let chunk = chunk.to_vec();
            std::thread::spawn(move || {
                for d in chunk {
                    let body = Json::object(vec![
                        ("id", Json::Int(d.id as i64)),
                        ("text", Json::str(d.text.clone())),
                    ]);
                    let (status, _) = client::post_json(&addr, "/v1/insert", &body).unwrap();
                    assert_eq!(status, 200);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let ingest_s = t0.elapsed().as_secs_f64();

    // Concurrent text queries.
    let queries: Vec<String> = (0..n_queries).map(|i| gen.query_for_topic(i)).collect();
    let t0 = Instant::now();
    let threads: Vec<_> = queries
        .chunks(n_queries.div_ceil(8))
        .map(|chunk| {
            let chunk = chunk.to_vec();
            std::thread::spawn(move || {
                let mut lat = Vec::new();
                for q in chunk {
                    let body =
                        Json::object(vec![("text", Json::str(q)), ("k", Json::Int(10))]);
                    let tq = Instant::now();
                    let (status, _) = client::post_json(&addr, "/v1/query", &body).unwrap();
                    lat.push(tq.elapsed().as_secs_f64() * 1e3);
                    assert_eq!(status, 200);
                }
                lat
            })
        })
        .collect();
    let mut lat: Vec<f64> = threads.into_iter().flat_map(|t| t.join().unwrap()).collect();
    let query_s = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let (_, stats) = client::get_json(&addr, "/v1/stats").unwrap();
    println!("\n=== e2e text API over HTTP ({n_docs} docs, {n_queries} queries, 8 clients) ===");
    println!(
        "text ingest: {:.1}/s | text queries: {:.1}/s | query p50 {:.1} ms p99 {:.1} ms \
         (embed + search)",
        n_docs as f64 / ingest_s,
        n_queries as f64 / query_s,
        lat[lat.len() / 2],
        lat[(lat.len() * 99 / 100).min(lat.len() - 1)]
    );
    println!(
        "batcher efficiency: {} embeds in {} batches",
        stats.get("batched_requests").as_i64().unwrap_or(0),
        stats.get("batches").as_i64().unwrap_or(0)
    );
    server.stop();
}
