//! `valori` — the leader binary: serve the deterministic memory node, run
//! paper experiments, snapshot/restore/replay state.
//!
//! ```text
//! valori serve      [--addr 127.0.0.1:7431] [--dim 128] [--wal valori.wal]
//!                   [--env b] [--no-embedder] [--flat] [--shards N]
//!                   [--collections N] [--data DIR]
//!                   [--rate-limit R] [--quota Q] [--bulkhead B]
//!                   [--idle-ttl SECS] [--stream-bps BYTES]
//!                   [--scan-workers W] [--memory-quota BYTES]
//!                   # /v1 = the `default` collection; /v2 = multi-tenant
//!                   # rate-limit/quota/bulkhead/idle-ttl/stream-bps are
//!                   # per-tenant governance knobs (0 = off, the default)
//!                   # scan-workers caps the shared scan pool (0 = one
//!                   # per core); memory-quota bounds arena bytes per
//!                   # tenant (0 = unlimited) — both per-collection
//!                   # overridable via the PUT body
//! valori soak       [--addr 127.0.0.1:7431] [--dim 32] [--shards N]
//!                   [--n 256] [--requests 1000] [--clients 8]
//!                   [--collection NAME] [--expect-backend epoll|blocking]
//!                   [--expect-throttle]
//!                   # keep-alive load + sequential-vs-concurrent hash check
//!                   # (--collection drives the /v2 surface instead of /v1;
//!                   # --expect-throttle retries on 429 and requires >= 1
//!                   # rejection — proving throttling never changes bits)
//! valori bench      [--quick] [--n 50000] [--dim 256] [--k 10] [--shards 4]
//!                   [--batch 512] [--seed S] [--out BENCH_search.json]
//! valori experiment <table1|table2|table3|transfer|latency|all> [--quick]
//! valori snapshot   --wal <file> --out <file> [--dim N] [--shards N] [--flat]
//!                   # or --data DIR --collection NAME: shape read from the
//!                   # collection's spec.json, no path surgery
//! valori snapshot stream   (same layout opts) --out <file> [--chunk N]
//!                   # write the chunked VSTREAM1 format (per-chunk CRCs)
//! valori snapshot restore  --in <stream> [--out <snapshot>]
//!                   # verify a VSTREAM1 file chunk by chunk
//! valori snapshot migrate  --src A:P --dst B:P --collection NAME
//!                   # online tenant migration over /v2 + root-hash check
//! valori restore    --snapshot <file>           # verify + print hashes
//!                                               # (plain or sharded file)
//! valori replay     --log <file> [--dim N]      # audit replay from hex log
//! valori verify     --a <snap> --b <snap>       # compare two snapshots
//! valori verify     --receipt <file> [--proof <file>]
//!                   # offline receipt + membership-proof verification:
//!                   # files hold the `GET .../proof` wire JSON (enveloped
//!                   # or bare); exit 0 = verified, 1 = rejected
//! valori verify     --addr A:P [--collection NAME] [--id N]
//!                   # fetch a live receipt (and --id's membership proof)
//!                   # and run the same offline verification against it
//! valori lint       [--format json] [--baseline FILE] [--root DIR]
//!                   [--fix-safety-stubs]
//!                   # determinism auditor: zone-classified R1-R6 scan of
//!                   # the Rust sources, diffed against the committed
//!                   # baseline (see DETERMINISM.md); --fix-safety-stubs
//!                   # inserts `// SAFETY: TODO` stubs at uncommented
//!                   # unsafe sites (stubs still fail the lint)
//! valori quickstart
//! ```

#![forbid(unsafe_code)]

use std::sync::Arc;
use std::time::Duration;
use valori::bench::BenchConfig;
use valori::cli::Args;
use valori::index::QuantSpec;
use valori::node::{
    serve_collections, CollectionManager, CollectionSpec, EmbedBatcher, GovernorConfig,
    ManagerConfig,
};
use valori::runtime::{artifacts_available, artifacts_dir, embedder::Env, Embedder, Engine};
use valori::snapshot::{ShardedSnapshot, Snapshot};
use valori::state::{Command, Kernel, KernelConfig, ShardedKernel};
use valori::{experiments, replication, wal};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("soak") => cmd_soak(&args),
        Some("bench") => cmd_bench(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("snapshot") => cmd_snapshot(&args),
        Some("restore") => cmd_restore(&args),
        Some("replay") => cmd_replay(&args),
        Some("verify") => cmd_verify(&args),
        Some("dump") => cmd_dump(&args),
        Some("lint") => cmd_lint(&args),
        Some("quickstart") => cmd_quickstart(),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            print_usage();
            2
        }
        None => {
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

/// Shared `--shards N` parsing for serve/snapshot (default 1, must be >= 1).
fn parse_shards(args: &Args) -> Result<u32, String> {
    match args.opt_parse("shards", 1u32) {
        Ok(n) if n >= 1 => Ok(n),
        Ok(_) => Err("--shards must be >= 1".into()),
        Err(e) => Err(e),
    }
}

fn print_usage() {
    eprintln!(
        "usage: valori <serve|soak|bench|experiment|snapshot|restore|replay|verify|lint|\
         quickstart> [options]\n\
         see `rust/src/main.rs` header or README.md for details"
    );
}

/// `valori soak` — the bundled determinism soak client. Against a fresh
/// `valori serve` node it (1) streams sequential inserts over one
/// keep-alive connection while mirroring them into a local kernel,
/// (2) fires concurrent keep-alive query clients and asserts every
/// response is byte-identical to a sequential reference pass, and
/// (3) asserts the served node's state hash equals the local mirror's —
/// i.e. concurrent HTTP load reached the exact state a sequential run
/// reaches. The server must be started with the same --dim/--shards
/// (and default index config) or the hashes will differ by construction.
fn cmd_soak(args: &Args) -> i32 {
    use valori::hash::splitmix64;
    use valori::http::client::Connection;
    use valori::json::Json;

    let addr_s = args.opt_or("addr", "127.0.0.1:7431");
    let addr: std::net::SocketAddr = match addr_s.parse() {
        Ok(a) => a,
        Err(e) => return fail(&format!("bad --addr {addr_s}: {e}")),
    };
    let dim: usize = match args.opt_parse("dim", 32) {
        Ok(d) if d > 0 => d,
        Ok(_) => return fail("--dim must be > 0"),
        Err(e) => return fail(&e),
    };
    let n_shards = match parse_shards(args) {
        Ok(n) => n,
        Err(e) => return fail(&e),
    };
    let inserts: u64 = match args.opt_parse("n", 256) {
        Ok(n) => n,
        Err(e) => return fail(&e),
    };
    let requests: usize = match args.opt_parse("requests", 1000) {
        Ok(r) => r,
        Err(e) => return fail(&e),
    };
    let clients: usize = match args.opt_parse("clients", 8) {
        Ok(c) if c > 0 => c,
        Ok(_) => return fail("--clients must be > 0"),
        Err(e) => return fail(&e),
    };
    let seed: u64 = match args.opt_parse("seed", 0x534F414Bu64) {
        Ok(s) => s,
        Err(e) => return fail(&e),
    };
    // --collection drives the /v2 surface (typed envelope) against a
    // named tenant; without it the soak exercises the legacy /v1 routes
    // (the `default` collection when a manager is serving).
    let collection: Option<String> = args.opt("collection").map(String::from);
    let expect_backend: Option<String> = args.opt("expect-backend").map(String::from);
    // --expect-throttle: the target is governed (serve --rate-limit /
    // --quota); retry every 429 with its retry_after_ms hint and require
    // at least one rejection — the final hash check then proves that a
    // throttled-and-retried workload reaches a root bit-identical to an
    // unthrottled sequential mirror.
    let expect_throttle = args.flag("expect-throttle");
    let throttled = std::sync::atomic::AtomicU64::new(0);

    // Which front end is serving, and how many tenants it holds — lets
    // CI pin the epoll reactor instead of silently testing the fallback.
    let health = match valori::http::client::get_json(&addr, "/v1/health") {
        Ok((200, h)) => h,
        Ok((st, _)) => return fail(&format!("GET /v1/health -> {st}")),
        Err(e) => return fail(&format!("cannot reach {addr}: {e}")),
    };
    let backend = health.get("backend").as_str().unwrap_or("unknown").to_string();
    println!(
        "soak: server backend={backend} collections={}",
        health.get("collections").as_i64().unwrap_or(-1)
    );
    if let Some(expect) = &expect_backend {
        if &backend != expect {
            return fail(&format!("expected backend {expect}, server reports {backend}"));
        }
    }

    let (stats_path, insert_path, query_path, hash_path) = match &collection {
        Some(c) => (
            format!("/v2/collections/{c}/stats"),
            format!("/v2/collections/{c}/insert"),
            format!("/v2/collections/{c}/query"),
            format!("/v2/collections/{c}/hash"),
        ),
        None => (
            "/v1/stats".to_string(),
            "/v1/insert".to_string(),
            "/v1/query".to_string(),
            "/v1/hash".to_string(),
        ),
    };

    // the server must be fresh, or the mirror hash cannot match
    let stats = match valori::http::client::get_json(&addr, &stats_path) {
        Ok((200, s)) => s,
        Ok((st, _)) => return fail(&format!("GET {stats_path} -> {st}")),
        Err(e) => return fail(&format!("cannot reach {addr}: {e}")),
    };
    // /v2 responses wrap the payload in the typed envelope.
    let stats = if collection.is_some() { stats.get("data").clone() } else { stats };
    if stats.get("vectors").as_i64() != Some(0) {
        return fail("server is not empty; soak needs a fresh node");
    }
    if stats.get("n_shards").as_i64() != Some(n_shards as i64) {
        return fail(&format!(
            "server reports n_shards={:?}, soak was given --shards {n_shards}",
            stats.get("n_shards").as_i64()
        ));
    }
    // Scan-pool width is read-path tuning: whatever the server was
    // started with, the mirror-hash check below must still pass.
    if let Some(w) = stats.get("scan_workers").as_i64() {
        println!("soak: server scan_workers={w} (0 = one per core)");
    }

    // deterministic f32 corpus: values round-trip exactly through the
    // node's JSON (shortest-repr float printing), so mirror and server
    // quantize identical inputs
    let component = |i: u64, j: u64| -> f32 {
        ((splitmix64(seed ^ (i * dim as u64 + j)) % 2001) as i64 - 1000) as f32 / 1000.0
    };

    // phase 1: sequential keep-alive inserts, mirrored locally
    let mut mirror = ShardedKernel::new(KernelConfig::default_q16(dim), n_shards);
    let mut conn = match Connection::connect(&addr) {
        Ok(c) => c,
        Err(e) => return fail(&format!("connect: {e}")),
    };
    for i in 0..inserts {
        let v: Vec<f32> = (0..dim as u64).map(|j| component(i, j)).collect();
        let body = Json::object(vec![
            ("id", Json::Int(i as i64)),
            ("vector", Json::Array(v.iter().map(|&x| Json::Float(x as f64)).collect())),
        ]);
        loop {
            match conn.post_json(&insert_path, &body) {
                Ok((200, _)) => break,
                Ok((429, resp)) => {
                    throttled.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    std::thread::sleep(retry_after(&resp));
                }
                Ok((st, resp)) => return fail(&format!("insert {i} -> {st}: {resp}")),
                Err(e) => return fail(&format!("insert {i}: {e}")),
            }
        }
        // Mirror only the accepted command — rejected attempts never
        // reached the state machine, which is the whole point.
        if let Err(e) = mirror.apply(Command::Insert { id: i, vector: v }) {
            return fail(&format!("mirror insert {i}: {e}"));
        }
    }
    println!("soak: inserted {inserts} vectors over one keep-alive connection");

    // phase 2: sequential reference responses, then concurrent clients
    let query_bodies: Vec<String> = (0..16u64)
        .map(|q| {
            let v: Vec<Json> = (0..dim as u64)
                .map(|j| Json::Float(component(q ^ 0x5155_4552_59, j) as f64))
                .collect();
            Json::object(vec![("vector", Json::Array(v)), ("k", Json::Int(10))]).to_string()
        })
        .collect();
    let mut reference: Vec<Vec<u8>> = Vec::with_capacity(query_bodies.len());
    for body in &query_bodies {
        loop {
            match conn.request("POST", &query_path, body.as_bytes()) {
                Ok((200, bytes)) => {
                    reference.push(bytes);
                    break;
                }
                Ok((429, bytes)) => {
                    throttled.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    std::thread::sleep(retry_after_bytes(&bytes));
                }
                Ok((st, _)) => return fail(&format!("reference query -> {st}")),
                Err(e) => return fail(&format!("reference query: {e}")),
            }
        }
    }
    let per_client = requests.div_ceil(clients);
    let mismatches = std::thread::scope(|scope| {
        let reference = &reference;
        let query_bodies = &query_bodies;
        let query_path = &query_path;
        let throttled = &throttled;
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                scope.spawn(move || -> Result<usize, String> {
                    let mut conn =
                        Connection::connect(&addr).map_err(|e| format!("connect: {e}"))?;
                    let mut bad = 0usize;
                    for r in 0..per_client {
                        let qi = r % query_bodies.len();
                        // 429s are retried, not counted as mismatches: an
                        // admission rejection carries no kernel state, so
                        // the eventual 200 must still be byte-identical
                        // to the sequential reference.
                        let (st, bytes) = loop {
                            let (st, bytes) = conn
                                .request("POST", query_path, query_bodies[qi].as_bytes())
                                .map_err(|e| format!("query: {e}"))?;
                            if st != 429 {
                                break (st, bytes);
                            }
                            throttled.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            std::thread::sleep(retry_after_bytes(&bytes));
                        };
                        if st != 200 || bytes != reference[qi] {
                            bad += 1;
                        }
                    }
                    Ok(bad)
                })
            })
            .collect();
        let mut total: Result<usize, String> = Ok(0);
        for h in handles {
            match h.join().expect("soak client panicked") {
                Ok(bad) => {
                    if let Ok(t) = &mut total {
                        *t += bad;
                    }
                }
                Err(e) => {
                    if total.is_ok() {
                        total = Err(e); // first error wins
                    }
                }
            }
        }
        total
    });
    let mismatches = match mismatches {
        Ok(m) => m,
        Err(e) => return fail(&e),
    };
    println!(
        "soak: {clients} keep-alive clients x {per_client} queries, {mismatches} mismatched responses"
    );
    if mismatches > 0 {
        return fail("concurrent responses diverged from the sequential reference");
    }

    // phase 3: the served node must hold exactly the mirror's state
    let server_hash = loop {
        match valori::http::client::get_json(&addr, &hash_path) {
            Ok((200, h)) => {
                if collection.is_some() {
                    // /v2 reports the sharded root uniformly (1-shard included).
                    break h.get("data").get("root").as_str().unwrap_or("").to_string();
                }
                break h.get("fnv").as_str().unwrap_or("").to_string();
            }
            Ok((429, resp)) => {
                throttled.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                std::thread::sleep(retry_after(&resp));
            }
            Ok((st, _)) => return fail(&format!("GET {hash_path} -> {st}")),
            Err(e) => return fail(&format!("hash fetch: {e}")),
        }
    };
    let local_hash = if collection.is_some() {
        format!("{:016x}", mirror.root_hash())
    } else if n_shards == 1 {
        format!("{:016x}", mirror.shard(0).state_hash())
    } else {
        format!("{:016x}", mirror.root_hash())
    };
    println!("soak: server hash {server_hash} | local mirror {local_hash}");
    if server_hash != local_hash {
        return fail("HASH MISMATCH: concurrent HTTP load diverged from the sequential mirror");
    }
    let throttle_count = throttled.load(std::sync::atomic::Ordering::Relaxed);
    if throttle_count > 0 {
        println!(
            "soak: absorbed {throttle_count} 429 rejections via retry — root still \
             bit-identical to the ungoverned sequential mirror"
        );
    }
    if expect_throttle && throttle_count == 0 {
        return fail(
            "--expect-throttle: the server never answered 429; is it running with \
             --rate-limit/--quota?",
        );
    }
    println!("soak: OK — byte-identical responses and identical root hash under concurrency");
    0
}

/// Back-off hint from a parsed 429 body: the typed envelope puts
/// `retry_after_ms` inside `error`, the legacy /v1 shape at top level.
fn retry_after(resp: &valori::json::Json) -> Duration {
    let ms = resp
        .get("error")
        .get("retry_after_ms")
        .as_u64()
        .or_else(|| resp.get("retry_after_ms").as_u64())
        .unwrap_or(10);
    Duration::from_millis(ms.clamp(1, 1000))
}

/// Back-off hint from a raw 429 body.
fn retry_after_bytes(bytes: &[u8]) -> Duration {
    std::str::from_utf8(bytes)
        .ok()
        .and_then(|s| valori::json::parse(s).ok())
        .map(|j| retry_after(&j))
        .unwrap_or(Duration::from_millis(10))
}

/// `valori bench` — the deterministic search/upsert performance suite.
/// Prints the human table and writes the machine-readable trajectory file
/// (default `BENCH_search.json`, the repo-root perf record CI smokes).
fn cmd_bench(args: &Args) -> i32 {
    use valori::bench::suite::SuiteConfig;
    let quick = args.flag("quick");
    // CLI overrides parse against the full config; the quick divisor is
    // applied *after* them so every row (HNSW included) honors it —
    // `--quick --n 2000` is a 200-vector smoke run, not a full-size one.
    let mut cfg = SuiteConfig::full();
    cfg.n = match args.opt_parse("n", cfg.n) {
        Ok(v) if v > 0 => v,
        Ok(_) => return fail("--n must be > 0"),
        Err(e) => return fail(&e),
    };
    cfg.dim = match args.opt_parse("dim", cfg.dim) {
        Ok(v) if v > 0 => v,
        Ok(_) => return fail("--dim must be > 0"),
        Err(e) => return fail(&e),
    };
    cfg.k = match args.opt_parse("k", cfg.k) {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    cfg.shards = match args.opt_parse("shards", cfg.shards) {
        Ok(v) if v >= 1 => v,
        Ok(_) => return fail("--shards must be >= 1"),
        Err(e) => return fail(&e),
    };
    cfg.seed = match args.opt_parse("seed", cfg.seed) {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    cfg.batch = match args.opt_parse("batch", cfg.batch) {
        Ok(v) if v > 0 => v,
        Ok(_) => return fail("--batch must be > 0"),
        Err(e) => return fail(&e),
    };
    if quick {
        cfg = cfg.quickened();
    }
    let out = args.opt_or("out", "BENCH_search.json");
    let label = if quick { "quick" } else { "full" };
    let result = valori::bench::suite::run(&cfg, label);
    let json = valori::bench::suite::suite_json(&result).to_string();
    if let Err(e) = std::fs::write(&out, json + "\n") {
        return fail(&format!("write {out}: {e}"));
    }
    println!("wrote {out}");
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let addr = args.opt_or("addr", "127.0.0.1:7431");
    let dim: usize = match args.opt_parse("dim", 128) {
        Ok(d) => d,
        Err(e) => return fail(&e),
    };
    let n_shards = match parse_shards(args) {
        Ok(n) => n,
        Err(e) => return fail(&e),
    };
    let n_collections: u32 = match args.opt_parse("collections", 1u32) {
        Ok(n) if n >= 1 => n,
        Ok(_) => return fail("--collections must be >= 1"),
        Err(e) => return fail(&e),
    };
    let workers: usize = args.opt_parse("workers", 4).unwrap_or(4);

    // Embedder is optional: without artifacts the node still serves the
    // vector API (text endpoints return 503).
    let batcher = if args.flag("no-embedder") || !artifacts_available() {
        if !args.flag("no-embedder") {
            eprintln!("note: artifacts not found; text endpoints disabled (run `make artifacts`)");
        }
        None
    } else {
        let env = if args.opt("env") == Some("b") { Env::B } else { Env::A };
        let loader = move || {
            let engine = Engine::cpu()?;
            Embedder::load(&engine, artifacts_dir(), env)
        };
        match EmbedBatcher::start(loader, Duration::from_millis(2)) {
            Ok(b) => Some(b),
            Err(e) => return fail(&format!("embedder: {e}")),
        }
    };

    // Every deployment is a collection manager now: the `default`
    // collection serves the legacy /v1 surface byte-for-byte (recovering
    // a legacy --wal file exactly as before), `--collections N`
    // pre-creates N-1 extra tenants (`c1`..`c{N-1}`) on top, and
    // `--data DIR` makes dynamically created collections durable under
    // `DIR/<name>/`.
    // Per-tenant governance: 0 (the default) leaves each knob off, so an
    // ungoverned `serve` is bit-for-bit the pre-governance server.
    let nonzero_u32 = |name: &str| -> Result<Option<u32>, String> {
        args.opt_parse(name, 0u32).map(|v| if v == 0 { None } else { Some(v) })
    };
    let rate_limit = match nonzero_u32("rate-limit") {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    let quota = match nonzero_u32("quota") {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    let bulkhead = match nonzero_u32("bulkhead") {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    let idle_ttl = match args.opt_parse("idle-ttl", 0u64) {
        Ok(0) => None,
        Ok(secs) => Some(Duration::from_secs(secs)),
        Err(e) => return fail(&e),
    };
    let stream_bytes_per_sec = match args.opt_parse("stream-bps", 0u64) {
        Ok(0) => None,
        Ok(bps) => Some(bps),
        Err(e) => return fail(&e),
    };
    // Scan-pool width for the default spec (0 = one worker per core) and
    // arena-byte insert budget (0 = unlimited). Both are per-collection
    // overridable through the PUT body.
    let scan_workers = match args.opt_parse("scan-workers", 0u32) {
        Ok(w) => w,
        Err(e) => return fail(&e),
    };
    let memory_quota = match args.opt_parse("memory-quota", 0u64) {
        Ok(q) => q,
        Err(e) => return fail(&e),
    };
    let mut default_spec = CollectionSpec::new(dim, n_shards, args.flag("flat"), QuantSpec::None);
    default_spec.memory_quota = memory_quota;
    default_spec.scan_workers = scan_workers;
    let collections_config = ManagerConfig {
        spec: default_spec,
        workers,
        data_dir: args.opt("data").map(Into::into),
        default_wal: args.opt("wal").map(Into::into),
        governor: GovernorConfig { rate_limit, quota, bulkhead, idle_ttl, stream_bytes_per_sec },
    };
    let manager =
        match CollectionManager::new(collections_config, batcher.as_ref().map(|b| b.handle())) {
            Ok(m) => Arc::new(m),
            Err(e) => return fail(&e.to_string()),
        };
    for i in 1..n_collections {
        if let Err(e) = manager.ensure(&format!("c{i}")) {
            return fail(&format!("create collection c{i}: {}", e.message));
        }
    }
    let server = match serve_collections(Arc::clone(&manager), &addr, workers) {
        Ok(s) => s,
        Err(e) => return fail(&format!("bind {addr}: {e}")),
    };
    println!("valori node listening on http://{}", server.addr());
    if rate_limit.is_some()
        || quota.is_some()
        || bulkhead.is_some()
        || idle_ttl.is_some()
        || stream_bytes_per_sec.is_some()
    {
        println!(
            "  governance: rate-limit={rate_limit:?}/s quota={quota:?} bulkhead={bulkhead:?} \
             idle-ttl={idle_ttl:?} stream-bps={stream_bytes_per_sec:?}"
        );
    }
    if scan_workers != 0 || memory_quota != 0 {
        println!("  scan-workers={scan_workers} (0 = per core) memory-quota={memory_quota} bytes");
    }
    println!(
        "  dim={dim} shards={n_shards} collections={:?} backend={} wal={:?} data={:?} embedder={}",
        manager.names(),
        server.backend_name(),
        args.opt("wal"),
        args.opt("data"),
        batcher.is_some()
    );
    println!(
        "  try: curl -s -X POST http://{}/v1/query -d '{{\"text\":\"revenue for april\",\"k\":5}}'",
        server.addr()
    );

    // Serve until killed.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_experiment(args: &Args) -> i32 {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let quick = args.flag("quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let run_one = |name: &str| match name {
        "table1" => {
            let r = experiments::divergence::run(5);
            experiments::divergence::print_table(&r);
            0
        }
        "table2" => {
            let rows = experiments::precision::run();
            experiments::precision::print_table(&rows);
            0
        }
        "table3" => {
            let (docs, queries) = if quick { (400, 20) } else { (2000, 100) };
            let r = experiments::recall::run(docs, queries, 10);
            experiments::recall::print_table(&r);
            0
        }
        "transfer" => {
            let n = if quick { 1000 } else { 10_000 };
            let r = experiments::transfer::run(n, 128);
            experiments::transfer::print_result(&r);
            if r.hashes_equal && r.knn_identical {
                0
            } else {
                1
            }
        }
        "latency" => {
            let n = if quick { 2000 } else { 10_000 };
            let r = experiments::latency::run(n, 128, 10, &cfg);
            experiments::latency::print_result(&r);
            0
        }
        other => {
            eprintln!("unknown experiment '{other}'");
            2
        }
    };
    if which == "all" {
        for name in ["table1", "table2", "table3", "transfer", "latency"] {
            let code = run_one(name);
            if code != 0 {
                return code;
            }
        }
        0
    } else {
        run_one(which)
    }
}

/// Resolved offline WAL layout for `valori snapshot`: where the
/// per-shard WAL files live and the kernel shape to replay them into.
struct OfflineLayout {
    wal_base: String,
    dim: usize,
    shards: u32,
    flat: bool,
}

impl OfflineLayout {
    fn kernel_config(&self) -> KernelConfig {
        let config = KernelConfig::default_q16(self.dim);
        if self.flat {
            config.with_flat_index()
        } else {
            config
        }
    }
}

/// Resolve the WAL layout from either `--wal <base>` or the managed
/// `--data DIR --collection NAME` form. The managed form reads the
/// collection's persisted `<data>/<name>/spec.json` so dim/shards/index
/// come from the collection itself — no `--wal` path surgery and no
/// hand-copied shape flags (which, when wrong, silently produce a
/// different state hash). Explicit `--dim`/`--shards`/`--flat` still
/// override.
fn resolve_offline_layout(args: &Args) -> Result<OfflineLayout, String> {
    let (wal_base, spec_defaults) = if let Some(w) = args.opt("wal") {
        (w.to_string(), None)
    } else {
        match (args.opt("data"), args.opt("collection")) {
            (Some(d), Some(c)) => {
                let spec_path = format!("{d}/{c}/spec.json");
                let spec = match std::fs::read_to_string(&spec_path) {
                    Ok(text) => match valori::json::parse(&text) {
                        Ok(json) => Some(json),
                        Err(e) => return Err(format!("bad {spec_path}: {e}")),
                    },
                    Err(_) => None, // legacy layout without a spec manifest
                };
                (format!("{d}/{c}/wal"), spec)
            }
            _ => {
                return Err(
                    "need --wal <file> (or --data <dir> --collection <name>)".to_string()
                )
            }
        }
    };
    let spec_dim = spec_defaults.as_ref().and_then(|s| s.get("dim").as_u64());
    let spec_shards = spec_defaults.as_ref().and_then(|s| s.get("shards").as_u64());
    let spec_flat =
        spec_defaults.as_ref().map(|s| s.get("index").as_str() == Some("flat"));
    let dim = match args.opt("dim") {
        Some(_) => args.opt_parse("dim", 128)?,
        None => spec_dim.unwrap_or(128) as usize,
    };
    if dim == 0 {
        return Err("--dim must be > 0".into());
    }
    let shards = match args.opt("shards") {
        Some(_) => parse_shards(args)?,
        None => {
            let s = spec_shards.unwrap_or(1);
            if s == 0 {
                return Err("spec.json shards must be >= 1".into());
            }
            s as u32
        }
    };
    let flat = args.flag("flat") || spec_flat.unwrap_or(false);
    Ok(OfflineLayout { wal_base, dim, shards, flat })
}

/// Replay the layout's per-shard WALs into a fresh sharded kernel.
/// Returns the kernel and the replayed command count.
fn replay_offline_kernel(layout: &OfflineLayout) -> Result<(ShardedKernel, usize), String> {
    let mut kernel = ShardedKernel::new(layout.kernel_config(), layout.shards);
    let mut total = 0usize;
    for s in 0..layout.shards {
        let path = valori::node::shard_wal_path(
            std::path::Path::new(&layout.wal_base),
            s,
            layout.shards,
        );
        let rec = wal::recover(&path).map_err(|e| format!("wal shard {s} ({path:?}): {e}"))?;
        if rec.truncated_tail {
            eprintln!("warning: shard {s}: torn tail truncated at byte {}", rec.valid_bytes);
        }
        for entry in &rec.entries {
            kernel
                .apply_canon_to_shard(s, &entry.command)
                .map_err(|e| format!("replay shard {s} seq {}: {e}", entry.seq))?;
        }
        total += rec.entries.len();
    }
    Ok((kernel, total))
}

fn cmd_snapshot(args: &Args) -> i32 {
    match args.positional.first().map(String::as_str) {
        None => cmd_snapshot_offline(args),
        Some("stream") => cmd_snapshot_stream(args),
        Some("restore") => cmd_snapshot_restore(args),
        Some("migrate") => cmd_snapshot_migrate(args),
        Some(other) => fail(&format!(
            "unknown snapshot subcommand '{other}' (want stream, restore or migrate)"
        )),
    }
}

/// Classic offline snapshot: replay WALs, write a VSNP/VSHM file.
fn cmd_snapshot_offline(args: &Args) -> i32 {
    let layout = match resolve_offline_layout(args) {
        Ok(l) => l,
        Err(e) => return fail(&e),
    };
    let Some(out) = args.opt("out") else { return fail("need --out <file>") };
    if layout.shards == 1 {
        // Single-shard layout keeps the seed-compatible plain-VSNP file.
        let rec = match wal::recover(&layout.wal_base) {
            Ok(r) => r,
            Err(e) => return fail(&format!("wal: {e}")),
        };
        if rec.truncated_tail {
            eprintln!("warning: torn tail truncated at byte {}", rec.valid_bytes);
        }
        let mut kernel = Kernel::new(layout.kernel_config());
        if let Err(e) = wal::replay(&mut kernel, &rec.entries) {
            return fail(&format!("replay: {e}"));
        }
        let snap = Snapshot::capture(&kernel);
        if let Err(e) = snap.write_file(out) {
            return fail(&format!("write: {e}"));
        }
        println!(
            "replayed {} commands -> seq {} | fnv {:016x} | sha256 {}",
            rec.entries.len(),
            kernel.seq(),
            snap.fnv,
            snap.sha256_hex()
        );
        return 0;
    }
    // Sharded layout: one WAL per shard at <wal>.shard<N> (the layout the
    // node writes for --shards N); replay each into its own shard so the
    // digests match the node's /v1/hash manifest exactly.
    let (kernel, total) = match replay_offline_kernel(&layout) {
        Ok(k) => k,
        Err(e) => return fail(&e),
    };
    let snap = ShardedSnapshot::capture(&kernel);
    if let Err(e) = snap.write_file(out) {
        return fail(&format!("write: {e}"));
    }
    println!(
        "replayed {total} commands across {} shards -> root {:016x}",
        layout.shards,
        snap.root_hash()
    );
    for m in snap.manifest() {
        println!("  shard {}: fnv {:016x}", m.shard, m.fnv);
    }
    0
}

/// `valori snapshot stream`: replay WALs offline and write the chunked
/// `VSTREAM1` format — the file a `restore` endpoint (or `valori
/// snapshot restore`) verifies chunk by chunk. Peak memory is one shard
/// frame + one chunk, so it works where the whole-state VSHM writer
/// would not.
fn cmd_snapshot_stream(args: &Args) -> i32 {
    use valori::snapshot::SnapshotWriter;
    let layout = match resolve_offline_layout(args) {
        Ok(l) => l,
        Err(e) => return fail(&e),
    };
    let Some(out) = args.opt("out") else { return fail("need --out <file>") };
    let chunk: usize = match args.opt_parse("chunk", valori::snapshot::DEFAULT_CHUNK) {
        Ok(c) if (64..=16 << 20).contains(&c) => c,
        Ok(_) => return fail("--chunk must be in [64 bytes, 16 MiB]"),
        Err(e) => return fail(&e),
    };
    let (kernel, total) = match replay_offline_kernel(&layout) {
        Ok(k) => k,
        Err(e) => return fail(&e),
    };
    let mut writer = SnapshotWriter::for_kernel(&kernel, chunk);
    let expected = writer.total_len();
    let root = writer.root_hash();
    let file = match std::fs::File::create(out) {
        Ok(f) => f,
        Err(e) => return fail(&format!("create {out}: {e}")),
    };
    let mut file = std::io::BufWriter::new(file);
    let mut written = 0u64;
    while let Some(block) = writer.next_block() {
        let block = match block {
            Ok(b) => b,
            Err(e) => return fail(&format!("stream: {e}")),
        };
        if let Err(e) = std::io::Write::write_all(&mut file, &block) {
            return fail(&format!("write {out}: {e}"));
        }
        written += block.len() as u64;
    }
    if let Err(e) = std::io::Write::flush(&mut file) {
        return fail(&format!("flush {out}: {e}"));
    }
    if written != expected {
        return fail(&format!("stream wrote {written} bytes, expected {expected}"));
    }
    println!(
        "replayed {total} commands across {} shards -> {written} stream bytes \
         (chunk {chunk}) | root {root:016x}",
        layout.shards
    );
    0
}

/// `valori snapshot restore --in <stream>`: verify a `VSTREAM1` file
/// chunk by chunk (exactly as the HTTP ingest does) and print the
/// restored digests; `--out` additionally writes the classic VSHM file.
fn cmd_snapshot_restore(args: &Args) -> i32 {
    use valori::snapshot::SnapshotReader;
    let Some(input) = args.opt("in") else { return fail("need --in <stream file>") };
    let file = match std::fs::File::open(input) {
        Ok(f) => f,
        Err(e) => return fail(&format!("open {input}: {e}")),
    };
    let mut file = std::io::BufReader::new(file);
    let mut reader = SnapshotReader::new();
    let mut buf = vec![0u8; 64 * 1024];
    loop {
        match std::io::Read::read(&mut file, &mut buf) {
            Ok(0) => break,
            Ok(n) => {
                if let Err(e) = reader.feed(&buf[..n]) {
                    return fail(&format!("stream: {e}"));
                }
            }
            Err(e) => return fail(&format!("read {input}: {e}")),
        }
    }
    let chunks = reader.chunks_verified();
    let snap = match reader.finalize() {
        Ok(s) => s,
        Err(e) => return fail(&format!("finalize: {e}")),
    };
    let kernel = match snap.restore() {
        Ok(k) => k,
        Err(e) => return fail(&format!("restore: {e}")),
    };
    println!(
        "verified {chunks} chunks -> {} vectors across {} shards at seq {} | root {:016x}",
        kernel.len(),
        kernel.n_shards(),
        kernel.seq(),
        snap.root_hash()
    );
    for m in snap.manifest() {
        println!("  shard {}: fnv {:016x}", m.shard, m.fnv);
    }
    if let Some(out) = args.opt("out") {
        if let Err(e) = snap.write_file(out) {
            return fail(&format!("write {out}: {e}"));
        }
        println!("wrote {out}");
    }
    0
}

/// `valori snapshot migrate --src A --dst B --collection NAME`: online
/// tenant migration over the /v2 streaming endpoints, with the final
/// root-hash equality check.
fn cmd_snapshot_migrate(args: &Args) -> i32 {
    let (Some(src_s), Some(dst_s)) = (args.opt("src"), args.opt("dst")) else {
        return fail("need --src <addr> --dst <addr>");
    };
    let Some(collection) = args.opt("collection") else {
        return fail("need --collection <name>");
    };
    let src: std::net::SocketAddr = match src_s.parse() {
        Ok(a) => a,
        Err(e) => return fail(&format!("bad --src {src_s}: {e}")),
    };
    let dst: std::net::SocketAddr = match dst_s.parse() {
        Ok(a) => a,
        Err(e) => return fail(&format!("bad --dst {dst_s}: {e}")),
    };
    match replication::migrate_collection(&src, &dst, collection) {
        Ok(report) => {
            println!(
                "migrated '{collection}' {src} -> {dst}: {} stream bytes in {} windowed \
                 PUTs | root {} identical on both nodes",
                report.bytes, report.puts, report.root
            );
            0
        }
        Err(e) => fail(&format!("migrate: {e}")),
    }
}

fn cmd_restore(args: &Args) -> i32 {
    let Some(path) = args.opt("snapshot") else { return fail("need --snapshot <file>") };
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) => return fail(&format!("read: {e}")),
    };
    if ShardedSnapshot::looks_sharded(&bytes) {
        let snap = match ShardedSnapshot::from_bytes(&bytes) {
            Ok(s) => s,
            Err(e) => return fail(&format!("read: {e}")),
        };
        let kernel = match snap.restore() {
            Ok(k) => k,
            Err(e) => return fail(&format!("restore: {e}")),
        };
        // H_B: recompute per shard from the restored state (§8.1 step 4,
        // per partition).
        println!(
            "restored {} vectors across {} shards at seq {}",
            kernel.len(),
            kernel.n_shards(),
            kernel.seq()
        );
        let recomputed = kernel.shard_hashes();
        let mut ok = true;
        for m in snap.manifest() {
            let h_b = recomputed[m.shard as usize];
            let verdict = if h_b == m.fnv { "ok" } else { "MISMATCH" };
            println!("  shard {}: H_A {:016x} H_B {h_b:016x} {verdict}", m.shard, m.fnv);
            ok &= h_b == m.fnv;
        }
        println!("root = {:016x}", snap.root_hash());
        if ok {
            println!("H_A == H_B on every shard: memory state perfectly preserved");
            return 0;
        }
        println!("HASH MISMATCH — determinism violation!");
        return 1;
    }
    let snap = match Snapshot::from_bytes(&bytes) {
        Ok(s) => s,
        Err(e) => return fail(&format!("read: {e}")),
    };
    let kernel = match snap.restore() {
        Ok(k) => k,
        Err(e) => return fail(&format!("restore: {e}")),
    };
    // H_B: recompute from the restored state (paper §8.1 step 4)
    let h_b = kernel.state_hash();
    println!("restored {} vectors at seq {}", kernel.len(), kernel.seq());
    println!("H_A (stored)     = {:016x}", snap.fnv);
    println!("H_B (recomputed) = {h_b:016x}");
    println!("sha256 = {}", snap.sha256_hex());
    if snap.fnv == h_b {
        println!("H_A == H_B: memory state perfectly preserved");
        0
    } else {
        println!("HASH MISMATCH — determinism violation!");
        1
    }
}

fn cmd_replay(args: &Args) -> i32 {
    let Some(path) = args.opt("log") else { return fail("need --log <file> (hex lines)") };
    let dim: usize = args.opt_parse("dim", 128).unwrap_or(128);
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return fail(&format!("read: {e}")),
    };
    let cmds = match replication::log_from_text(&text) {
        Ok(c) => c,
        Err(e) => return fail(&e),
    };
    let mut kernel = Kernel::new(KernelConfig::default_q16(dim));
    for (i, c) in cmds.iter().enumerate() {
        if let Err(e) = kernel.apply_canon(c) {
            return fail(&format!("command {i} ({}) rejected: {e}", c.name()));
        }
    }
    println!(
        "replayed {} commands | seq {} | {} vectors | state hash {:016x}",
        cmds.len(),
        kernel.seq(),
        kernel.len(),
        kernel.state_hash()
    );
    0
}

/// `valori verify` — three offline-verifiable "same truth?" checks (§9):
/// `--a/--b` compares two snapshot files; `--receipt [--proof]` verifies
/// a state receipt (and a membership proof against it) from captured
/// `GET .../proof` wire JSON, with no server and no state; `--addr`
/// fetches a live receipt first and then runs the identical offline
/// verification. Exit 0 = verified, 1 = rejected.
fn cmd_verify(args: &Args) -> i32 {
    if args.opt("receipt").is_some() {
        return cmd_verify_receipt(args);
    }
    if args.opt("addr").is_some() {
        return cmd_verify_live(args);
    }
    let (Some(a), Some(b)) = (args.opt("a"), args.opt("b")) else {
        return fail(
            "need --a <snapshot> --b <snapshot>, --receipt <file> [--proof <file>], \
             or --addr <host:port> [--collection NAME] [--id N]",
        );
    };
    let (bytes_a, bytes_b) = match (std::fs::read(a), std::fs::read(b)) {
        (Ok(x), Ok(y)) => (x, y),
        (Err(e), _) => return fail(&format!("{a}: {e}")),
        (_, Err(e)) => return fail(&format!("{b}: {e}")),
    };
    match (ShardedSnapshot::looks_sharded(&bytes_a), ShardedSnapshot::looks_sharded(&bytes_b)) {
        (true, true) => return verify_sharded(a, &bytes_a, b, &bytes_b),
        (false, false) => {}
        _ => return fail("cannot compare a sharded snapshot with an unsharded one"),
    }
    let sa = match Snapshot::from_bytes(&bytes_a) {
        Ok(s) => s,
        Err(e) => return fail(&format!("{a}: {e}")),
    };
    let sb = match Snapshot::from_bytes(&bytes_b) {
        Ok(s) => s,
        Err(e) => return fail(&format!("{b}: {e}")),
    };
    println!("A: fnv {:016x} sha256 {}", sa.fnv, sa.sha256_hex());
    println!("B: fnv {:016x} sha256 {}", sb.fnv, sb.sha256_hex());
    if sa.fnv == sb.fnv && sa.sha256 == sb.sha256 {
        println!("IDENTICAL: both nodes hold the same memory state");
        0
    } else {
        // where do they diverge? decode both and compare coarse stats
        if let (Ok(ka), Ok(kb)) = (sa.restore(), sb.restore()) {
            println!(
                "DIVERGED: A has {} vectors @ seq {}, B has {} vectors @ seq {}",
                ka.len(),
                ka.seq(),
                kb.len(),
                kb.seq()
            );
        } else {
            println!("DIVERGED (and at least one snapshot fails to restore)");
        }
        1
    }
}

/// Sharded leg of `valori verify`: compare root hashes, then the
/// manifests shard-by-shard so a divergence names the forked partition.
fn verify_sharded(a: &str, bytes_a: &[u8], b: &str, bytes_b: &[u8]) -> i32 {
    let sa = match ShardedSnapshot::from_bytes(bytes_a) {
        Ok(s) => s,
        Err(e) => return fail(&format!("{a}: {e}")),
    };
    let sb = match ShardedSnapshot::from_bytes(bytes_b) {
        Ok(s) => s,
        Err(e) => return fail(&format!("{b}: {e}")),
    };
    println!("A: root {:016x} ({} shards)", sa.root_hash(), sa.shards.len());
    println!("B: root {:016x} ({} shards)", sb.root_hash(), sb.shards.len());
    let diverged = ShardedSnapshot::diverged_shards(&sa.manifest(), &sb.manifest());
    if diverged.is_empty() {
        println!("IDENTICAL: both nodes hold the same memory state on every shard");
        0
    } else {
        println!("DIVERGED at shard(s) {diverged:?}");
        1
    }
}

/// Read a `GET .../proof` capture: accepts both the bare payload and the
/// `/v2` typed envelope (`{"data": ..., "ok": true}` — what a curl of the
/// route actually saves).
fn read_proof_wire(path: &str) -> Result<valori::json::Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let json = valori::json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    Ok(if json.get("ok").as_bool().is_some() { json.get("data").clone() } else { json })
}

/// `valori verify --receipt <file> [--proof <file>]` — the fully offline
/// leg: re-derive the combined Merkle root from the receipt's shard
/// roots, then (with `--proof`) fold the membership path from the leaf
/// encoding up and require it to land on the receipt. A single flipped
/// bit anywhere — leaf, path, claimed position, shard roots — rejects.
fn cmd_verify_receipt(args: &Args) -> i32 {
    use valori::proof::{leaf, verify_membership, verify_receipt, LeafBody, MembershipProof, Receipt};

    let Some(receipt_path) = args.opt("receipt") else { return fail("need --receipt <file>") };
    let receipt = match read_proof_wire(receipt_path) {
        Ok(j) => match Receipt::from_json(&j) {
            Some(r) => r,
            None => return fail(&format!("{receipt_path}: not a receipt (bad wire shape)")),
        },
        Err(e) => return fail(&e),
    };
    println!(
        "receipt: state_version {} seq {} shards {} wal {:016x}",
        receipt.state_version,
        receipt.seq,
        receipt.shard_roots.len(),
        receipt.wal_hash
    );
    println!("  merkle_root {}", valori::hash::hex_lower(&receipt.merkle_root));
    if let Err(e) = verify_receipt(&receipt) {
        println!("REJECTED: {e}");
        return 1;
    }
    let Some(proof_path) = args.opt("proof") else {
        println!("VERIFIED: shard roots fold to the combined merkle_root");
        return 0;
    };
    let proof = match read_proof_wire(proof_path) {
        Ok(j) => match MembershipProof::from_json(&j) {
            Some(p) => p,
            None => return fail(&format!("{proof_path}: not a membership proof (bad wire shape)")),
        },
        Err(e) => return fail(&e),
    };
    let kind = match leaf::decode(&proof.record) {
        Ok(rec) if rec.id != proof.id => {
            println!("REJECTED: leaf encodes id {}, proof claims id {}", rec.id, proof.id);
            return 1;
        }
        Ok(rec) => match rec.body {
            LeafBody::Live { .. } => "live",
            LeafBody::Tombstone => "tombstone",
        },
        Err(e) => {
            println!("REJECTED: bad leaf encoding: {e}");
            return 1;
        }
    };
    println!(
        "proof: id {} ({kind}) shard {} slot {} path {} hashes",
        proof.id,
        proof.shard,
        proof.slot,
        proof.path.len()
    );
    match verify_membership(&proof, &receipt) {
        Ok(()) => {
            println!("VERIFIED: record is provably part of the receipt's state");
            0
        }
        Err(e) => {
            println!("REJECTED: {e}");
            1
        }
    }
}

/// `valori verify --addr A:P [--collection NAME] [--id N]` — fetch the
/// live receipt (and `--id`'s membership proof) over HTTP, then run the
/// exact offline verification a third party would.
fn cmd_verify_live(args: &Args) -> i32 {
    use valori::proof::{verify_membership, verify_receipt, MembershipProof, Receipt};

    let addr_s = args.opt_or("addr", "127.0.0.1:7431");
    let addr: std::net::SocketAddr = match addr_s.parse() {
        Ok(a) => a,
        Err(e) => return fail(&format!("bad --addr {addr_s}: {e}")),
    };
    let collection = args.opt_or("collection", "default");
    let proof_path = format!("/v2/collections/{collection}/proof");
    let body = match valori::http::client::get_json(&addr, &proof_path) {
        Ok((200, b)) => b,
        Ok((st, b)) => return fail(&format!("GET {proof_path} -> {st}: {b}")),
        Err(e) => return fail(&format!("cannot reach {addr}: {e}")),
    };
    let Some(receipt) = Receipt::from_json(body.get("data")) else {
        return fail("receipt: bad wire shape");
    };
    println!(
        "receipt: state_version {} seq {} shards {} merkle_root {}",
        receipt.state_version,
        receipt.seq,
        receipt.shard_roots.len(),
        valori::hash::hex_lower(&receipt.merkle_root)
    );
    if let Err(e) = verify_receipt(&receipt) {
        println!("REJECTED: {e}");
        return 1;
    }
    if args.opt("id").is_none() {
        println!("VERIFIED: shard roots fold to the combined merkle_root");
        return 0;
    }
    let id: u64 = match args.opt_parse("id", 0u64) {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    let body = match valori::http::client::get_json(&addr, &format!("{proof_path}?id={id}")) {
        Ok((200, b)) => b,
        Ok((st, b)) => return fail(&format!("GET {proof_path}?id={id} -> {st}: {b}")),
        Err(e) => return fail(&format!("proof fetch: {e}")),
    };
    let Some(proof) = MembershipProof::from_json(body.get("data")) else {
        return fail("membership proof: bad wire shape");
    };
    if proof.id != id {
        return fail(&format!("server answered a proof for id {}, asked for {id}", proof.id));
    }
    println!("proof: id {id} shard {} slot {} path {} hashes", proof.shard, proof.slot, proof.path.len());
    match verify_membership(&proof, &receipt) {
        Ok(()) => {
            println!("VERIFIED: id {id} is provably part of the receipt's state");
            0
        }
        Err(e) => {
            println!("REJECTED: {e}");
            1
        }
    }
}

/// `valori dump --snapshot <file>` — human-readable snapshot inspection
/// (audit tooling: what exactly does this memory contain?).
fn cmd_dump(args: &Args) -> i32 {
    let Some(path) = args.opt("snapshot") else { return fail("need --snapshot <file>") };
    let snap = match Snapshot::read_file(path) {
        Ok(s) => s,
        Err(e) => return fail(&format!("read: {e}")),
    };
    let kernel = match snap.restore() {
        Ok(k) => k,
        Err(e) => return fail(&format!("restore: {e}")),
    };
    let cfg = kernel.config();
    println!("snapshot {path}");
    println!("  fnv64    {:016x}", snap.fnv);
    println!("  sha256   {}", snap.sha256_hex());
    println!("  seq      {}", kernel.seq());
    println!("  vectors  {} (dim {})", kernel.len(), cfg.dim);
    println!("  metric   {} | index {:?} | normalize {}", cfg.metric.name(), cfg.index, cfg.policy.normalize);
    println!("  links    {}", kernel.links().edge_count());
    let limit: usize = args.opt_parse("limit", 10).unwrap_or(10);
    let mut shown = 0;
    // ids are not directly iterable from the kernel API; probe via links +
    // meta + a scan of small id space as a best-effort preview
    for id in 0..u64::MAX {
        if shown >= limit || id > 1_000_000 {
            break;
        }
        if let Some(raw) = kernel.get_raw(id) {
            let head: Vec<String> =
                raw.iter().take(4).map(|&r| format!("{:.4}", r as f64 / 65536.0)).collect();
            let meta = kernel
                .meta_of(id)
                .map(|m| format!(" meta={m:?}"))
                .unwrap_or_default();
            println!("  id {id}: [{}...]{meta}", head.join(", "));
            shown += 1;
        }
    }
    0
}

/// `valori lint` — the determinism auditor (see `valori::lint` and
/// DETERMINISM.md). Walks the source tree, classifies every file into
/// its determinism zone, runs the closed R1-R6 rule set, and diffs the
/// findings against the committed baseline. Exit 0 = clean at the
/// baseline, 1 = new findings or stale baseline entries, 2 = usage.
fn cmd_lint(args: &Args) -> i32 {
    use valori::lint;

    // Default root: rust/src from the repo root, src/ when invoked from
    // rust/ (how `cargo run` lands), explicit --root for anything else.
    let root = match args.opt("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => {
            let repo = std::path::Path::new("rust/src");
            let local = std::path::Path::new("src");
            if repo.is_dir() {
                repo.to_path_buf()
            } else if local.is_dir() {
                local.to_path_buf()
            } else {
                eprintln!("error: neither rust/src nor src exists here; pass --root DIR");
                return 2;
            }
        }
    };
    if !root.is_dir() {
        eprintln!("error: --root {}: not a directory", root.display());
        return 2;
    }
    let format = args.opt_or("format", "human");
    if format != "human" && format != "json" {
        eprintln!("error: --format must be human or json");
        return 2;
    }

    if args.flag("fix-safety-stubs") {
        return cmd_lint_fix_stubs(&root);
    }

    // Default baseline: the committed lint_baseline.json next to the
    // audit root's repo checkout, when present; otherwise empty.
    let baseline_path: Option<std::path::PathBuf> = match args.opt("baseline") {
        Some(p) => Some(std::path::PathBuf::from(p)),
        None => ["lint_baseline.json", "../lint_baseline.json"]
            .iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.is_file()),
    };
    let baseline = match &baseline_path {
        Some(p) => match std::fs::read_to_string(p) {
            Ok(text) => match lint::baseline::Baseline::from_json_text(&text) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("error: {}: {e}", p.display());
                    return 2;
                }
            },
            Err(e) => {
                eprintln!("error: read {}: {e}", p.display());
                return 2;
            }
        },
        None => lint::baseline::Baseline::default(),
    };

    let findings = match lint::audit_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: walk {}: {e}", root.display());
            return 2;
        }
    };
    let diff = lint::baseline::diff(&findings, &baseline);

    if format == "json" {
        println!("{}", lint::report_json(&findings, &diff));
        return if diff.is_clean() { 0 } else { 1 };
    }

    for f in &diff.new {
        println!("{f}");
    }
    for e in &diff.stale {
        println!(
            "{}: stale baseline entry {} [{}] — finding no longer exists, delete it",
            e.file,
            e.rule.code(),
            e.key
        );
    }
    let grandfathered = findings.len() - diff.new.len();
    match (&baseline_path, diff.is_clean()) {
        (_, true) => {
            println!(
                "lint: clean — {} findings, all {grandfathered} grandfathered by baseline",
                findings.len()
            );
            0
        }
        (Some(p), false) => {
            println!(
                "lint: {} new finding(s), {} stale baseline entr(ies) vs {}",
                diff.new.len(),
                diff.stale.len(),
                p.display()
            );
            1
        }
        (None, false) => {
            println!("lint: {} finding(s), no baseline", diff.new.len());
            1
        }
    }
}

/// `valori lint --fix-safety-stubs`: rewrite allowlisted unsafe files,
/// inserting `// SAFETY: TODO` stubs above uncommented unsafe sites.
fn cmd_lint_fix_stubs(root: &std::path::Path) -> i32 {
    use valori::lint;
    let files = match lint::source_files(root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: walk {}: {e}", root.display());
            return 2;
        }
    };
    let mut total = 0usize;
    for (rel, path) in files {
        if !lint::rules::UNSAFE_ALLOWLIST.contains(&rel.as_str()) {
            continue;
        }
        let src = match std::fs::read_to_string(&path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: read {}: {e}", path.display());
                return 2;
            }
        };
        let (rewritten, inserted) = lint::add_safety_stubs(&rel, &src);
        if inserted > 0 {
            if let Err(e) = std::fs::write(&path, rewritten) {
                eprintln!("error: write {}: {e}", path.display());
                return 2;
            }
            println!("{rel}: inserted {inserted} SAFETY stub(s)");
            total += inserted;
        }
    }
    if total == 0 {
        println!("lint: every unsafe site already has a SAFETY comment");
        0
    } else {
        println!(
            "lint: {total} stub(s) inserted — fill them in; TODO stubs still fail the audit"
        );
        1
    }
}

fn cmd_quickstart() -> i32 {
    println!("Valori quickstart (in-process; see examples/ for more)");
    let mut kernel = Kernel::new(KernelConfig::default_q16(4));
    kernel.apply(Command::insert(1, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
    kernel.apply(Command::insert(2, vec![0.9, 0.8, 0.7, 0.6])).unwrap();
    kernel.apply(Command::Link { from: 1, to: 2 }).unwrap();
    let hits = kernel.search_f32(&[0.1, 0.2, 0.3, 0.4], 2).unwrap();
    println!("query -> {:?}", hits.iter().map(|h| (h.id, h.dist)).collect::<Vec<_>>());
    println!("state hash = {:016x}", kernel.state_hash());
    println!("replaying the same commands always gives this exact hash.");
    0
}

fn fail(msg: &str) -> i32 {
    eprintln!("error: {msg}");
    1
}
