//! Property-based tests over kernel invariants, using the in-crate
//! mini-proptest framework (`valori::testing`).

use valori::codec::{Decoder, Encoder};
use valori::distance::{dot_q16, l2sq_q16};
use valori::fixed::{isqrt_u64, FixedFormat, Q16_16, Q32_32};
use valori::snapshot::Snapshot;
use valori::state::{CanonCommand, Command, Kernel, KernelConfig};
use valori::testing::{check, Gen, Strategy};

// Contract bound: |raw| <= 2^18 (DESIGN §6)
const RAW: i32 = 1 << 18;

#[test]
fn prop_quantize_dequantize_error_bounded() {
    check("quantize error <= resolution/2", 2000, Gen::f32_range(-4.0, 4.0), |&x| {
        let q = Q16_16::quantize(x as f64);
        (x as f64 - Q16_16::dequantize(q)).abs() <= Q16_16::resolution() / 2.0 + 1e-12
    });
}

#[test]
fn prop_quantize_monotone() {
    check(
        "quantize is monotone",
        2000,
        Gen::pair(Gen::f32_range(-4.0, 4.0), Gen::f32_range(-4.0, 4.0)),
        |&(a, b)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            Q16_16::quantize(lo as f64) <= Q16_16::quantize(hi as f64)
        },
    );
}

#[test]
fn prop_dot_symmetric_l2_psd() {
    let vecs = Gen::pair(
        Gen::vec_of(Gen::i32_range(-RAW, RAW), 64),
        Gen::vec_of(Gen::i32_range(-RAW, RAW), 64),
    );
    check("dot symmetric & l2 >= 0 & identity", 500, vecs, |(a, b)| {
        dot_q16(a, b) == dot_q16(b, a) && l2sq_q16(a, b) >= 0 && l2sq_q16(a, a) == 0
    });
}

#[test]
fn prop_l2_symmetry_and_expansion() {
    // ||a-b||² = ||a||² + ||b||² - 2<a,b> holds EXACTLY in integer math
    // (the identity floats only approximate — the crux of the paper).
    let vecs = Gen::pair(
        Gen::vec_of(Gen::i32_range(-RAW, RAW), 48),
        Gen::vec_of(Gen::i32_range(-RAW, RAW), 48),
    );
    check("integer l2 expansion identity is exact", 500, vecs, |(a, b)| {
        let l2 = l2sq_q16(a, b);
        let expanded = dot_q16(a, a) + dot_q16(b, b) - 2 * dot_q16(a, b);
        l2 == expanded && l2 == l2sq_q16(b, a)
    });
}

#[test]
fn prop_sat_ops_stay_in_range() {
    let pairs = Gen::pair(
        Gen::i32_range(i32::MIN + 1, i32::MAX),
        Gen::i32_range(i32::MIN + 1, i32::MAX),
    );
    check("saturating ops never wrap", 2000, pairs, |&(a, b)| {
        let s = Q16_16::sat_add(a, b);
        let m = Q16_16::sat_mul(a, b);
        let d = Q16_16::sat_div(a, b);
        // wrap would flip signs incoherently; check arithmetic sanity
        let add_ok = if a > 0 && b > 0 { s >= a.max(b) || s == i32::MAX } else { true };
        let mul_sign_ok = if a != 0 && b != 0 && m != 0 && m != i32::MAX && m != i32::MIN {
            (m > 0) == ((a > 0) == (b > 0))
        } else {
            true
        };
        let _ = d;
        add_ok && mul_sign_ok
    });
}

#[test]
fn prop_isqrt_is_floor_sqrt() {
    check("isqrt floor property", 2000, Gen::u64_below(u64::MAX / 2), |&n| {
        let r = isqrt_u64(n);
        r.checked_mul(r).map_or(false, |rr| rr <= n)
            && (r + 1).checked_mul(r + 1).map_or(true, |rr| rr > n)
    });
}

#[test]
fn prop_q32_quantize_roundtrip_region() {
    check("Q32.32 error bounded", 1000, Gen::f32_range(-1000.0, 1000.0), |&x| {
        let q = Q32_32::quantize(x as f64);
        (x as f64 - Q32_32::dequantize(q)).abs() <= Q32_32::resolution() / 2.0 + 1e-15
    });
}

#[test]
fn prop_codec_roundtrip_i32_slices() {
    check("codec roundtrip", 500, Gen::vec_len(Gen::i32_range(i32::MIN + 1, i32::MAX), 0, 64), |v| {
        let mut e = Encoder::new();
        e.put_i32_slice(v);
        let bytes = e.into_vec();
        let mut d = Decoder::new(&bytes);
        let back = d.get_i32_vec().unwrap();
        d.finish().unwrap();
        back == *v
    });
}

#[test]
fn prop_canon_command_roundtrip() {
    let strat = Gen::pair(Gen::u64_below(1 << 40), Gen::vec_len(Gen::i32_range(-RAW, RAW), 1, 32));
    check("canonical command roundtrip", 500, strat, |(id, raw)| {
        let c = CanonCommand::Insert { id: *id, raw: raw.clone() };
        CanonCommand::from_bytes(&c.to_bytes()).unwrap() == c
    });
}

#[test]
fn prop_snapshot_roundtrip_random_states() {
    let strat = Gen::vec_len(
        Gen::pair(Gen::u64_below(500), Gen::vec_of(Gen::f32_range(-1.0, 1.0), 6)),
        1,
        60,
    );
    check("snapshot roundtrip for random command logs", 60, strat, |cmds| {
        let mut k = Kernel::new(KernelConfig::default_q16(6));
        for (id, v) in cmds {
            let _ = k.apply(Command::insert(*id, v.clone())); // dup ids rejected: fine
        }
        let snap = Snapshot::capture(&k);
        let restored = Snapshot::from_bytes(&snap.to_bytes()).unwrap().restore().unwrap();
        restored.state_hash() == k.state_hash() && restored == k
    });
}

#[test]
fn prop_replay_determinism_random_logs() {
    // Random mixed logs: two kernels fed the same accepted command
    // sequence always hash identically.
    let strat = Gen::vec_len(
        Gen::pair(Gen::u64_below(40), Gen::vec_of(Gen::f32_range(-1.0, 1.0), 4)),
        1,
        80,
    );
    check("replay determinism", 60, strat, |ops| {
        let mut a = Kernel::new(KernelConfig::default_q16(4));
        let mut b = Kernel::new(KernelConfig::default_q16(4));
        for (i, (id, v)) in ops.iter().enumerate() {
            // derive a command mix from the data itself (deterministic)
            let cmd = if i % 7 == 6 {
                Command::Delete { id: *id }
            } else if i % 11 == 10 {
                Command::Link { from: *id, to: id.wrapping_add(1) % 40 }
            } else {
                Command::Insert { id: *id, vector: v.clone() }
            };
            let ra = a.apply(cmd.clone());
            let rb = b.apply(cmd);
            if ra.is_ok() != rb.is_ok() {
                return false; // rejection must also be deterministic
            }
        }
        a.state_hash() == b.state_hash()
    });
}

#[test]
fn prop_hnsw_top1_exact_on_inserted_points() {
    // Searching for an inserted vector always returns it as top-1 (its
    // distance is exactly 0 and ids tie-break deterministically).
    use valori::distance::Metric;
    use valori::index::{Hnsw, HnswParams, VectorIndex};
    let strat = Gen::vec_len(Gen::vec_of(Gen::i32_range(-RAW, RAW), 8), 2, 120);
    check("hnsw self-query returns self", 40, strat, |vecs| {
        let mut h: Hnsw<i32> = Hnsw::new(8, Metric::L2, HnswParams::default());
        let mut unique = std::collections::BTreeSet::new();
        let mut stored: Vec<(u64, Vec<i32>)> = Vec::new();
        for (i, v) in vecs.iter().enumerate() {
            if unique.insert(v.clone()) {
                h.insert(i as u64, v.clone());
                stored.push((i as u64, v.clone()));
            }
        }
        stored.iter().all(|(id, v)| {
            let hits = h.search(v, 1);
            hits.len() == 1 && hits[0].dist == 0 && hits[0].id == *id
        })
    });
}

#[test]
fn prop_fnv_hash_sensitivity() {
    // different single-byte perturbations give different state bytes hash
    check(
        "fnv sensitive to any byte change",
        500,
        Gen::pair(Gen::vec_len(Gen::i32_range(0, 255), 1, 64), Gen::u64_below(64)),
        |(bytes, pos)| {
            let data: Vec<u8> = bytes.iter().map(|&b| b as u8).collect();
            let pos = (*pos as usize) % data.len();
            let mut tampered = data.clone();
            tampered[pos] ^= 0x01;
            valori::hash::fnv1a64(&data) != valori::hash::fnv1a64(&tampered)
        },
    );
}

#[test]
fn prop_shrinking_produces_minimal_failures() {
    // meta-test: the framework's shrinker finds small counterexamples
    let result = std::panic::catch_unwind(|| {
        check("vec sums stay small", 500, Gen::vec_len(Gen::i32_range(0, 100), 0, 50), |v| {
            v.iter().sum::<i32>() < 2000
        });
    });
    assert!(result.is_err(), "property should fail for long large vectors");
}
