//! Concurrency test for the embed micro-batcher (`node/batcher.rs`):
//! many threads submitting at once, every response must arrive, responses
//! must belong to their own request (no cross-wiring under batching), and
//! the `BatchCounters` must stay consistent — `requests` equals the sum of
//! executed batch sizes and the number of client calls.
//!
//! Uses a deterministic mock `EmbedBackend` (the batching machinery is
//! model-agnostic), so this runs without PJRT artifacts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;
use valori::hash::fnv1a64;
use valori::node::{EmbedBackend, EmbedBatcher};

/// Deterministic mock model: v = f(text), with a tiny stall to force
/// batches to fill under concurrency. Counts how many texts it embeds so
/// the test can cross-check the batcher's own counters.
struct MockBackend {
    batch: usize,
    dim: usize,
    embedded: Arc<AtomicU64>,
    calls: Arc<AtomicU64>,
}

fn mock_vector(text: &str, dim: usize) -> Vec<f32> {
    let h = fnv1a64(text.as_bytes());
    (0..dim)
        .map(|j| ((h.rotate_left(j as u32 * 7) & 0xFFFF) as f32) / 65536.0)
        .collect()
}

impl EmbedBackend for MockBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn embed_texts(&self, texts: &[&str]) -> valori::Result<Vec<Vec<f32>>> {
        assert!(texts.len() <= self.batch, "batcher overflowed the model batch");
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.embedded.fetch_add(texts.len() as u64, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(1));
        Ok(texts.iter().map(|t| mock_vector(t, self.dim)).collect())
    }
}

#[test]
fn many_threads_all_responses_arrive_and_counters_balance() {
    const THREADS: u64 = 16;
    const PER_THREAD: u64 = 25;

    let embedded = Arc::new(AtomicU64::new(0));
    let calls = Arc::new(AtomicU64::new(0));
    let (embedded_l, calls_l) = (Arc::clone(&embedded), Arc::clone(&calls));
    let batcher = EmbedBatcher::start_with_backend(
        move || Ok(MockBackend { batch: 8, dim: 16, embedded: embedded_l, calls: calls_l }),
        Duration::from_millis(5),
    )
    .unwrap();
    let handle = batcher.handle();

    let workers: Vec<_> = (0..THREADS)
        .map(|w| {
            let h = handle.clone();
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    let text = format!("doc {w}/{i}");
                    let v = h.embed(&text).unwrap();
                    // response integrity: each caller gets *its* vector
                    assert_eq!(v, mock_vector(&text, 16), "cross-wired response for {text}");
                }
            })
        })
        .collect();
    for t in workers {
        t.join().expect("worker must not die: every response must arrive");
    }

    let (batches, requests) = handle.counters();
    let stats = batcher.stop();
    let total = THREADS * PER_THREAD;
    // every request was served and counted exactly once
    assert_eq!(requests, total, "requests counter");
    assert_eq!(stats.requests, total, "stats.requests");
    assert_eq!(stats.batches, batches, "stats/counters must agree");
    // requests == sum of batch sizes, as observed by the model itself
    assert_eq!(embedded.load(Ordering::Relaxed), total, "model saw every text once");
    assert_eq!(calls.load(Ordering::Relaxed), batches, "one model call per batch");
    // batching actually happened under load (window 5ms, batch 8):
    // upper bound is trivially total; require real fan-in.
    assert!(batches < total, "no batching occurred ({batches} batches for {total} requests)");
    assert!(batches >= total / 8, "cannot fit more than 8 per batch");
}

#[test]
fn embed_many_interleaved_with_singles() {
    let batcher = EmbedBatcher::start_with_backend(
        move || {
            Ok(MockBackend {
                batch: 4,
                dim: 8,
                embedded: Arc::new(AtomicU64::new(0)),
                calls: Arc::new(AtomicU64::new(0)),
            })
        },
        Duration::from_millis(2),
    )
    .unwrap();
    let handle = batcher.handle();

    let bulk = {
        let h = handle.clone();
        std::thread::spawn(move || {
            let texts: Vec<String> = (0..30).map(|i| format!("bulk {i}")).collect();
            let refs: Vec<&str> = texts.iter().map(String::as_str).collect();
            let out = h.embed_many(&refs).unwrap();
            assert_eq!(out.len(), 30);
            for (t, v) in refs.iter().zip(&out) {
                assert_eq!(v, &mock_vector(t, 8));
            }
        })
    };
    let singles: Vec<_> = (0..4)
        .map(|w| {
            let h = handle.clone();
            std::thread::spawn(move || {
                for i in 0..10 {
                    let text = format!("single {w}/{i}");
                    assert_eq!(h.embed(&text).unwrap(), mock_vector(&text, 8));
                }
            })
        })
        .collect();
    bulk.join().unwrap();
    for t in singles {
        t.join().unwrap();
    }
    let stats = batcher.stop();
    assert_eq!(stats.requests, 30 + 40);
    assert!(stats.batches >= (30 + 40) / 4, "batch size 4 bounds the fan-in");
}

#[test]
fn backend_error_propagates_to_every_waiter_without_hanging() {
    struct FailingBackend;
    impl EmbedBackend for FailingBackend {
        fn batch_size(&self) -> usize {
            8
        }
        fn embed_texts(&self, _texts: &[&str]) -> valori::Result<Vec<Vec<f32>>> {
            Err(valori::Error::Runtime("model exploded".into()))
        }
    }
    let batcher =
        EmbedBatcher::start_with_backend(|| Ok(FailingBackend), Duration::from_millis(5))
            .unwrap();
    let handle = batcher.handle();
    let workers: Vec<_> = (0..8)
        .map(|_| {
            let h = handle.clone();
            std::thread::spawn(move || h.embed("boom").unwrap_err().to_string())
        })
        .collect();
    for t in workers {
        let msg = t.join().unwrap();
        assert!(msg.contains("model exploded"), "got: {msg}");
    }
    let stats = batcher.stop();
    assert_eq!(stats.requests, 8, "failed requests still count");
}

#[test]
fn loader_failure_surfaces_at_start() {
    let err = EmbedBatcher::start_with_backend(
        || -> valori::Result<MockBackend> { Err(valori::Error::Runtime("no artifacts".into())) },
        Duration::from_millis(1),
    )
    .unwrap_err();
    assert!(err.to_string().contains("no artifacts"));
}
