//! Integration + property tests for the sharded kernel (ISSUE 1 tentpole).
//!
//! The acceptance bar: for random command sequences and
//! `n_shards ∈ {1, 2, 4, 8}`, sharded search returns exactly the same
//! `(dist, id)`-ordered hits as a single reference kernel, and replaying
//! the per-shard logs reproduces the root hash. Exactness is asserted on
//! the flat (exact) index — per-shard exact top-k merged under the
//! `(dist_raw, id)` total order *is* the global exact top-k. HNSW gets its
//! own run-to-run/replay determinism properties (approximate recall is not
//! preserved under partitioning, determinism is).

use valori::state::{CanonCommand, Command, Kernel, KernelConfig, ShardedKernel};
use valori::testing::{check, Gen};

const DIM: usize = 4;
const N_SHARDS: [u32; 4] = [1, 2, 4, 8];

fn flat_config() -> KernelConfig {
    KernelConfig::default_q16(DIM).with_flat_index()
}

/// Derive a deterministic mixed command from one generated op (same trick
/// as the seed `property.rs`: the mix is a function of the data itself).
fn op_to_command(i: usize, id: u64, v: &[f32]) -> Command {
    match i % 13 {
        6 => Command::Delete { id },
        9 => Command::Link { from: id, to: (id + 1) % 48 },
        11 => Command::SetMeta { id, key: format!("k{}", i % 3), value: format!("v{id}") },
        12 => Command::InsertBatch {
            items: vec![
                (id + 100, v.to_vec()),
                (id + 200, v.iter().map(|x| -x).collect()),
            ],
        },
        _ => Command::Insert { id, vector: v.to_vec() },
    }
}

/// Apply one command to the reference kernel and every sharded kernel;
/// acceptance/rejection must agree everywhere.
fn apply_everywhere(
    reference: &mut Kernel,
    sharded: &mut [(ShardedKernel, Vec<Vec<CanonCommand>>)],
    cmd: &Command,
) -> bool {
    let expect = reference.apply(cmd.clone());
    for (sk, logs) in sharded.iter_mut() {
        match sk.apply(cmd.clone()) {
            Ok(result) => {
                if expect.is_err() {
                    return false;
                }
                for routed in result.applied {
                    logs[routed.shard as usize].push(routed.command);
                }
            }
            Err(e) => {
                // Same decision — and for primary-id errors, the same error.
                match &expect {
                    Err(expected) => {
                        if *expected != e {
                            return false;
                        }
                    }
                    Ok(_) => return false,
                }
            }
        }
    }
    true
}

#[test]
fn prop_sharded_search_bit_identical_to_reference() {
    let strat = Gen::vec_len(
        Gen::pair(Gen::u64_below(48), Gen::vec_of(Gen::f32_range(-1.0, 1.0), DIM)),
        1,
        60,
    );
    check("sharded flat search == single-kernel search", 30, strat, |ops| {
        let mut reference = Kernel::new(flat_config());
        let mut sharded: Vec<(ShardedKernel, Vec<Vec<CanonCommand>>)> = N_SHARDS
            .iter()
            .map(|&n| {
                (ShardedKernel::new(flat_config(), n), vec![Vec::new(); n as usize])
            })
            .collect();
        for (i, (id, v)) in ops.iter().enumerate() {
            let cmd = op_to_command(i, *id, v);
            if !apply_everywhere(&mut reference, &mut sharded, &cmd) {
                return false;
            }
        }
        // Every inserted vector and a few synthetic probes, full-depth and
        // truncated: hit lists must be (dist_raw, id)-identical.
        let queries: Vec<Vec<f32>> = ops
            .iter()
            .take(8)
            .map(|(_, v)| v.clone())
            .chain([vec![0.0; DIM], vec![0.5; DIM]])
            .collect();
        for q in &queries {
            for k in [1usize, 5, 100] {
                let expect = reference.search_f32(q, k).unwrap();
                for (sk, _) in &sharded {
                    if sk.search_f32(q, k).unwrap() != expect {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_replaying_shard_logs_reproduces_root_hash() {
    let strat = Gen::vec_len(
        Gen::pair(Gen::u64_below(48), Gen::vec_of(Gen::f32_range(-1.0, 1.0), DIM)),
        1,
        50,
    );
    check("per-shard log replay reproduces the root hash", 30, strat, |ops| {
        let mut reference = Kernel::new(flat_config());
        let mut sharded: Vec<(ShardedKernel, Vec<Vec<CanonCommand>>)> = N_SHARDS
            .iter()
            .map(|&n| {
                (ShardedKernel::new(flat_config(), n), vec![Vec::new(); n as usize])
            })
            .collect();
        for (i, (id, v)) in ops.iter().enumerate() {
            let cmd = op_to_command(i, *id, v);
            if !apply_everywhere(&mut reference, &mut sharded, &cmd) {
                return false;
            }
        }
        for (sk, logs) in &sharded {
            let mut replayed = ShardedKernel::new(flat_config(), sk.n_shards());
            for (s, log) in logs.iter().enumerate() {
                for canon in log {
                    if replayed.apply_canon_to_shard(s as u32, canon).is_err() {
                        return false;
                    }
                }
            }
            if replayed.root_hash() != sk.root_hash()
                || replayed.shard_hashes() != sk.shard_hashes()
                || replayed != *sk
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_hnsw_sharded_runs_are_deterministic() {
    // HNSW is approximate, so we don't compare against a single kernel —
    // we compare a sharded deployment against an identically-fed clone:
    // thread scheduling in the fan-out must never leak into results.
    let strat = Gen::vec_len(
        Gen::pair(Gen::u64_below(64), Gen::vec_of(Gen::f32_range(-1.0, 1.0), DIM)),
        1,
        40,
    );
    check("sharded hnsw is run-to-run deterministic", 20, strat, |ops| {
        let build = || {
            let mut sk = ShardedKernel::new(KernelConfig::default_q16(DIM), 4);
            for (i, (id, v)) in ops.iter().enumerate() {
                let _ = sk.apply(op_to_command(i, *id, v));
            }
            sk
        };
        let a = build();
        let b = build();
        if a.root_hash() != b.root_hash() {
            return false;
        }
        for (_, v) in ops.iter().take(5) {
            for _ in 0..3 {
                if a.search_f32(v, 10).unwrap() != b.search_f32(v, 10).unwrap() {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn sharded_search_under_concurrent_readers() {
    // 5000 vectors puts the corpus above PARALLEL_SEARCH_MIN_VECTORS, so
    // the scoped-thread fan-out path runs; hammer it from many reader
    // threads at once and require every reader to see the same answer
    // (search is a pure function of state).
    let mut sk = ShardedKernel::new(flat_config(), 4);
    for i in 0..5000u64 {
        let v: Vec<f32> =
            (0..DIM).map(|j| ((i * DIM as u64 + j as u64) as f32 * 0.017).sin() * 0.9).collect();
        sk.apply(Command::insert(i, v)).unwrap();
    }
    let q = vec![0.1f32, -0.2, 0.3, 0.0];
    let expect = sk.search_f32(&q, 20).unwrap();
    // threaded fan-out must still equal the single-kernel reference
    let mut single = Kernel::new(flat_config());
    for i in 0..5000u64 {
        let v: Vec<f32> =
            (0..DIM).map(|j| ((i * DIM as u64 + j as u64) as f32 * 0.017).sin() * 0.9).collect();
        single.apply(Command::insert(i, v)).unwrap();
    }
    assert_eq!(expect, single.search_f32(&q, 20).unwrap());
    std::thread::scope(|scope| {
        for _ in 0..8 {
            let sk = &sk;
            let q = &q;
            let expect = &expect;
            scope.spawn(move || {
                for _ in 0..25 {
                    assert_eq!(&sk.search_f32(q, 20).unwrap(), expect);
                }
            });
        }
    });
}

#[test]
fn sharded_node_end_to_end_over_http() {
    // A 4-shard node: insert over HTTP, query over HTTP, per-shard stats,
    // per-shard log feeds, and replication to a second 4-shard node with
    // root-hash convergence.
    use std::sync::Arc;
    use valori::http::client;
    use valori::json::{parse, Json};
    use valori::node::{serve, NodeConfig, NodeState};
    use valori::replication::sync_all_shards;

    let make = || {
        let kernel = ShardedKernel::new(KernelConfig::default_q16(4), 4);
        let state =
            Arc::new(NodeState::new_sharded(kernel, &NodeConfig::default(), None).unwrap());
        let server = serve(Arc::clone(&state), "127.0.0.1:0", 4).unwrap();
        (state, server)
    };
    let (p_state, primary) = make();
    let (f_state, follower) = make();

    for i in 0..60u64 {
        let v: Vec<f32> = (0..4).map(|j| ((i + j) as f32 * 0.05).sin() * 0.6).collect();
        let body = Json::object(vec![
            ("id", Json::Int(i as i64)),
            ("vector", Json::Array(v.iter().map(|&x| Json::Float(x as f64)).collect())),
        ]);
        let (st, _) = client::post_json(&primary.addr(), "/v1/insert", &body).unwrap();
        assert_eq!(st, 200);
    }

    // stats expose per-shard counts and hashes
    let (st, stats) = client::get_json(&primary.addr(), "/v1/stats").unwrap();
    assert_eq!(st, 200);
    assert_eq!(stats.get("n_shards").as_i64(), Some(4));
    assert_eq!(stats.get("vectors").as_i64(), Some(60));
    let shards = stats.get("shards").as_array().unwrap();
    assert_eq!(shards.len(), 4);
    let total: i64 = shards.iter().map(|s| s.get("vectors").as_i64().unwrap()).sum();
    assert_eq!(total, 60);
    assert!(shards.iter().all(|s| s.get("fnv").as_str().unwrap().len() == 16));

    // query fans out and merges: top hit is the exact inserted vector
    let q = parse(r#"{"vector":[0.0,0.0,0.0,0.0],"k":60}"#).unwrap();
    let (st, resp) = client::post_json(&primary.addr(), "/v1/query", &q).unwrap();
    assert_eq!(st, 200);
    let hits = resp.get("hits").as_array().unwrap();
    assert_eq!(hits.len(), 60, "k >= corpus returns every live vector");

    // cross-shard links + a delete: the per-shard feeds now contain a
    // link whose `to` lives on another shard AND the delete's synthesized
    // cleanup unlink — feeds must still ship independently (regression
    // guard: replication ingest must replay per shard, not re-route).
    let a = 0u64;
    let b = (1..60u64)
        .find(|&i| p_state.with_sharded(|sk| sk.shard_of(i) != sk.shard_of(a)))
        .unwrap();
    for body in [
        format!(r#"{{"from":{a},"to":{b}}}"#),
        format!(r#"{{"from":{b},"to":{a}}}"#),
    ] {
        let (st, _) =
            client::post_json(&primary.addr(), "/v1/link", &parse(&body).unwrap()).unwrap();
        assert_eq!(st, 200);
    }
    let (st, _) = client::post_json(
        &primary.addr(),
        "/v1/delete",
        &parse(&format!(r#"{{"id":{b}}}"#)).unwrap(),
    )
    .unwrap();
    assert_eq!(st, 200);

    // ship every shard's log; the follower converges to the same root
    let (shipped, follower_hash) =
        sync_all_shards(&primary.addr(), &follower.addr(), &[0, 0, 0, 0]).unwrap();
    // 60 inserts + 2 links + 1 cleanup unlink + 1 delete
    assert_eq!(shipped.iter().sum::<usize>(), 64);
    let (_, p_hash) = client::get_json(&primary.addr(), "/v1/hash").unwrap();
    assert_eq!(p_hash.get("fnv").as_str().unwrap(), follower_hash);
    assert_eq!(
        p_state.with_sharded(|sk| sk.root_hash()),
        f_state.with_sharded(|sk| sk.root_hash())
    );
    // and the per-shard manifests agree entry by entry
    let pm = p_state.with_sharded(|sk| sk.shard_hashes());
    let fm = f_state.with_sharded(|sk| sk.shard_hashes());
    assert_eq!(pm, fm);
    // the delete (and its cross-shard cleanup) replicated faithfully
    assert_eq!(f_state.with_sharded(|sk| sk.len()), 59);
    assert!(!f_state.with_sharded(|sk| sk.has_link(a, b)));

    primary.stop();
    follower.stop();
}

#[test]
fn sharded_node_recovers_from_per_shard_wals() {
    use valori::node::{NodeConfig, NodeState};

    let base = std::env::temp_dir()
        .join(format!("valori_it_shard_{}.wal", std::process::id()));
    // clean slate
    for s in 0..4u32 {
        std::fs::remove_file(valori::node::shard_wal_path(&base, s, 4)).ok();
    }
    let config = NodeConfig { workers: 2, wal_path: Some(base.clone()), ..NodeConfig::default() };
    let root = {
        let kernel = ShardedKernel::new(KernelConfig::default_q16(4), 4);
        let state = NodeState::new_sharded(kernel, &config, None).unwrap();
        for i in 0..50u64 {
            let x = i as f32 / 50.0;
            state.apply(Command::insert(i, vec![x, 1.0 - x, 0.5, -x])).unwrap();
        }
        state.apply(Command::Delete { id: 3 }).unwrap();
        state.with_sharded(|sk| sk.root_hash())
    };
    // every shard wrote its own WAL file
    for s in 0..4u32 {
        let p = valori::node::shard_wal_path(&base, s, 4);
        assert!(p.exists(), "missing shard WAL {p:?}");
    }
    // fresh boot recovers the identical root hash
    let kernel = ShardedKernel::new(KernelConfig::default_q16(4), 4);
    let state2 = NodeState::new_sharded(kernel, &config, None).unwrap();
    assert_eq!(state2.with_sharded(|sk| sk.root_hash()), root);
    assert_eq!(state2.with_sharded(|sk| sk.len()), 49);
    for s in 0..4u32 {
        std::fs::remove_file(valori::node::shard_wal_path(&base, s, 4)).ok();
    }
}
