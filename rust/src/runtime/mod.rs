//! Runtime: load and execute AOT-compiled XLA computations via PJRT.
//!
//! This is the bridge between Layer 3 (this crate) and the build-time
//! Layers 1/2 (python/compile): `make artifacts` lowers the JAX/Pallas
//! programs to HLO *text*, and this module loads them with
//! `HloModuleProto::from_text_file`, compiles them on the PJRT CPU client,
//! and executes them with concrete inputs. Python never runs at request
//! time.
//!
//! Everything here is *outside* the determinism boundary (float model
//! compute); results cross the boundary in [`crate::state`].

#![forbid(unsafe_code)]

pub mod embedder;
pub mod engine;
pub mod manifest;
pub mod xla_stub;

pub use embedder::Embedder;
pub use engine::{DistanceEngine, Engine, LoadedComputation};
pub use manifest::{Manifest, ModelDims, ParamSpec};

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$VALORI_ARTIFACTS` or ./artifacts
/// relative to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("VALORI_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Try cwd, then the crate manifest dir (useful under `cargo test`).
    for base in [
        PathBuf::from("artifacts"),
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ] {
        if base.join("manifest.json").exists() {
            return base;
        }
    }
    PathBuf::from("artifacts")
}

/// True if `make artifacts` has been run (used by tests/benches that need
/// the AOT outputs to skip gracefully with a loud message otherwise).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
