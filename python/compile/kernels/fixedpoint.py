"""Layer-1 Pallas kernels: the Q16.16 boundary + integer distance scan.

These are the *deterministic* kernels: integer-only math past the quantize
boundary, designed to bit-match the Rust kernel (rust/src/distance) under
the boundary contract (|raw| <= 2^18, D <= 16384 -> i64 accumulation never
saturates). Experiment E9 (rust/tests/cross_impl.rs) verifies the bit
identity end-to-end through PJRT.

TPU mapping: integer ops run on the VPU (8x128 lanes). The distance kernel
tiles the database into (TILE_N, D) VMEM blocks; the query tile is
broadcast to every grid step. Requires jax_enable_x64 for the i64
accumulators (enabled in aot.py and the tests; build-time only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Q16_SCALE = 1 << 16
I32_MIN = -(1 << 31)
I32_MAX = (1 << 31) - 1

# Database tile rows per grid step. 512 rows x 128 dims x 4 B = 256 KiB in
# VMEM (plus the i64 accumulator tile) — well under budget.
TILE_N = 512


def _quantize_kernel(x_ref, o_ref):
    """f32 -> Q16.16 raw int32 (round-ties-even, saturating)."""
    x = x_ref[...]
    scaled = x * jnp.float32(Q16_SCALE)
    scaled = jnp.nan_to_num(scaled, nan=0.0, posinf=float(I32_MAX), neginf=float(I32_MIN))
    r = jnp.rint(scaled)
    r = jnp.clip(r, float(I32_MIN), float(I32_MAX))
    o_ref[...] = r.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x, interpret=True):
    """Quantize a batch of float vectors to Q16.16 raw. f32[B,D] -> i32[B,D]."""
    return pl.pallas_call(
        _quantize_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int32),
        interpret=interpret,
    )(x)


def _l2sq_kernel(q_ref, db_ref, o_ref):
    """One DB tile: int64 squared-L2 distances against the shared query."""
    q = q_ref[...].astype(jnp.int64)          # [D]
    db = db_ref[...].astype(jnp.int64)        # [TILE_N, D]
    diff = db - q[None, :]
    o_ref[...] = jnp.sum(diff * diff, axis=1)  # [TILE_N] i64


def _dot_kernel(q_ref, db_ref, o_ref):
    """One DB tile: int64 dot products against the shared query."""
    q = q_ref[...].astype(jnp.int64)
    db = db_ref[...].astype(jnp.int64)
    o_ref[...] = jnp.sum(db * q[None, :], axis=1)


def _distance_call(kernel, query, db, interpret):
    n, d = db.shape
    assert n % TILE_N == 0, f"db rows ({n}) must be a multiple of TILE_N ({TILE_N})"
    grid = (n // TILE_N,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),          # query: same block each step
            pl.BlockSpec((TILE_N, d), lambda i: (i, 0)),  # db: tile i
        ],
        out_specs=pl.BlockSpec((TILE_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int64),
        interpret=interpret,
    )(query, db)


@functools.partial(jax.jit, static_argnames=("interpret",))
def l2sq_q16(query, db, interpret=True):
    """Integer squared-L2 distances. i32[D], i32[N,D] -> i64[N]."""
    return _distance_call(_l2sq_kernel, query, db, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dot_q16(query, db, interpret=True):
    """Integer dot products. i32[D], i32[N,D] -> i64[N]."""
    return _distance_call(_dot_kernel, query, db, interpret)
