"""Layer-2 correctness: the embedding encoder.

Checks shapes, masking semantics, run-to-run determinism, and the env A vs
env B bit-divergence that powers the Table 1 reproduction (same maths,
different evaluation order => different bits, near-identical cosine).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def weights():
    return model.init_weights(0)


def tokens(rng, b=model.BATCH, s=model.SEQ_LEN, n_real=None):
    """Random token batch; id 0 is padding."""
    ids = rng.integers(1, model.VOCAB, size=(b, s), dtype=np.int64).astype(np.int32)
    if n_real is not None:
        ids[:, n_real:] = model.PAD_ID
    return ids


class TestEncoder:
    def test_output_shape_and_norm(self, weights, rng):
        ids = tokens(rng, n_real=20)
        out = np.asarray(model.encoder(weights, ids, env="a"))
        assert out.shape == (model.BATCH, model.D_MODEL)
        norms = np.linalg.norm(out, axis=1)
        np.testing.assert_allclose(norms, 1.0, rtol=1e-4)

    def test_deterministic_across_calls(self, weights, rng):
        ids = tokens(rng)
        a = np.asarray(model.encoder(weights, ids, env="a"))
        b = np.asarray(model.encoder(weights, ids, env="a"))
        np.testing.assert_array_equal(a, b)  # bit-identical on one host

    def test_padding_does_not_change_embedding(self, weights, rng):
        # same real tokens, different amounts of trailing padding
        ids1 = tokens(rng, n_real=10)
        ids2 = ids1.copy()
        # ids1 already padded after 10; re-pad ids2 identically then diverge pad content
        assert (ids2[:, 10:] == model.PAD_ID).all()
        out1 = np.asarray(model.encoder(weights, ids1, env="a"))
        out2 = np.asarray(model.encoder(weights, ids2, env="a"))
        np.testing.assert_array_equal(out1, out2)

    def test_different_tokens_different_embeddings(self, weights, rng):
        ids1 = tokens(rng, n_real=12)
        ids2 = ids1.copy()
        ids2[:, 0] = (ids2[:, 0] % (model.VOCAB - 2)) + 1  # perturb first token
        out1 = np.asarray(model.encoder(weights, ids1, env="a"))
        out2 = np.asarray(model.encoder(weights, ids2, env="a"))
        assert np.abs(out1 - out2).max() > 1e-4

    def test_env_a_env_b_mathematically_close(self, weights, rng):
        ids = tokens(rng, n_real=32)
        a = np.asarray(model.encoder(weights, ids, env="a"), dtype=np.float64)
        b = np.asarray(model.encoder(weights, ids, env="b"), dtype=np.float64)
        cos = np.sum(a * b, axis=1) / (np.linalg.norm(a, axis=1) * np.linalg.norm(b, axis=1))
        # the paper's observation: cosine similarity > 0.9999 ...
        assert (cos > 0.9999).all(), cos

    def test_env_a_env_b_bit_divergence(self, weights, rng):
        # ... while the raw bits differ (Table 1's mechanism).
        ids = tokens(rng, n_real=32)
        a = np.asarray(model.encoder(weights, ids, env="a"))
        b = np.asarray(model.encoder(weights, ids, env="b"))
        bits_a = a.view(np.uint32)
        bits_b = b.view(np.uint32)
        frac_diff = (bits_a != bits_b).mean()
        assert frac_diff > 0.5, f"only {frac_diff:.1%} of dims diverged"

    def test_weights_are_deterministic(self):
        w1 = model.init_weights(0)
        w2 = model.init_weights(0)
        for a, b in zip(w1, w2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_embed_fn_wraps_encoder(self, weights, rng):
        ids = tokens(rng, n_real=8)
        fn = model.embed_fn("a")
        (out,) = fn(*weights, jnp.asarray(ids))
        direct = model.encoder(weights, ids, env="a")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(direct))
