//! Integration: the paper's core determinism guarantees, end to end.
//!
//! `S_{t+1} = F(S_t, C_t)` — identical command sequences must produce
//! bit-identical states, hashes and search results (paper §3.1), across
//! index kinds, command mixes, and interleavings of reads.

use valori::distance::Metric;
use valori::state::{Command, Kernel, KernelConfig, StateError};

fn mixed_workload(kernel: &mut Kernel, n: usize) {
    for i in 0..n as u64 {
        let x = (i as f32 * 0.137).sin() * 0.8;
        let y = (i as f32 * 0.071).cos() * 0.8;
        let v: Vec<f32> = (0..kernel.config().dim)
            .map(|j| if j % 2 == 0 { x } else { y } * (1.0 + j as f32 * 0.01))
            .collect();
        kernel.apply(Command::insert(i, v)).unwrap();
        if i % 7 == 3 && i > 10 {
            kernel.apply(Command::Delete { id: i - 10 }).unwrap();
        }
        if i % 5 == 2 && i > 2 {
            // link to an id guaranteed alive (i-1 unless it was deleted)
            let target = i - 1;
            if kernel.contains(target) {
                kernel.apply(Command::Link { from: i, to: target }).unwrap();
            }
        }
        if i % 11 == 0 {
            kernel
                .apply(Command::SetMeta {
                    id: i,
                    key: "batch".into(),
                    value: format!("b{}", i / 11),
                })
                .unwrap();
        }
    }
}

#[test]
fn identical_logs_identical_hashes_hnsw() {
    let mut a = Kernel::new(KernelConfig::default_q16(16));
    let mut b = Kernel::new(KernelConfig::default_q16(16));
    mixed_workload(&mut a, 300);
    mixed_workload(&mut b, 300);
    assert_eq!(a.state_hash(), b.state_hash());
    assert_eq!(a.to_state_bytes(), b.to_state_bytes());
}

#[test]
fn identical_logs_identical_hashes_flat() {
    let mut a = Kernel::new(KernelConfig::default_q16(16).with_flat_index());
    let mut b = Kernel::new(KernelConfig::default_q16(16).with_flat_index());
    mixed_workload(&mut a, 300);
    mixed_workload(&mut b, 300);
    assert_eq!(a.state_hash(), b.state_hash());
}

#[test]
fn reads_do_not_mutate_state() {
    let mut k = Kernel::new(KernelConfig::default_q16(16));
    mixed_workload(&mut k, 100);
    let before = k.state_hash();
    let q: Vec<f32> = (0..16).map(|i| (i as f32 * 0.2).sin()).collect();
    for _ in 0..50 {
        k.search_f32(&q, 10).unwrap();
        k.get_raw(5);
        k.meta_of(0);
        k.links().links_from(7);
    }
    assert_eq!(k.state_hash(), before, "reads must be pure");
}

#[test]
fn failed_commands_do_not_mutate_state() {
    let mut k = Kernel::new(KernelConfig::default_q16(4));
    k.apply(Command::insert(1, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
    let before = k.state_hash();
    // every class of rejection
    assert!(k.apply(Command::insert(1, vec![0.0; 4])).is_err()); // dup
    assert!(k.apply(Command::insert(2, vec![0.0; 3])).is_err()); // dim
    assert!(k.apply(Command::insert(3, vec![f32::NAN, 0.0, 0.0, 0.0])).is_err()); // NaN
    assert!(k.apply(Command::Delete { id: 99 }).is_err()); // unknown
    assert!(k.apply(Command::Link { from: 1, to: 99 }).is_err()); // dangling
    assert_eq!(k.state_hash(), before, "failed transitions must be no-ops");
    assert_eq!(k.seq(), 1);
}

#[test]
fn search_is_deterministic_under_repetition() {
    let mut k = Kernel::new(KernelConfig::default_q16(32));
    mixed_workload(&mut k, 500);
    let q: Vec<f32> = (0..32).map(|i| (i as f32 * 0.05).cos() * 0.5).collect();
    let first = k.search_f32(&q, 20).unwrap();
    for _ in 0..10 {
        assert_eq!(k.search_f32(&q, 20).unwrap(), first);
    }
    // raw distances are exact integers — compare them too
    assert!(first.iter().all(|h| h.dist_raw >= 0));
}

#[test]
fn cosine_config_normalizes_at_boundary() {
    let mut k = Kernel::new(KernelConfig::embedding_cosine(8));
    // unnormalized inserts land normalized
    k.apply(Command::insert(1, vec![3.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0])).unwrap();
    let raw = k.get_raw(1).unwrap();
    let norm2: i64 = raw.iter().map(|&x| (x as i64) * (x as i64)).sum();
    let real = norm2 as f64 / 4294967296.0;
    assert!((real - 1.0).abs() < 1e-3, "norm² = {real}");
}

#[test]
fn metric_is_part_of_state_identity() {
    let mut cfg_l2 = KernelConfig::default_q16(4);
    cfg_l2.metric = Metric::L2;
    let mut cfg_ip = KernelConfig::default_q16(4);
    cfg_ip.metric = Metric::InnerProduct;
    let mut a = Kernel::new(cfg_l2);
    let mut b = Kernel::new(cfg_ip);
    a.apply(Command::insert(1, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
    b.apply(Command::insert(1, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
    assert_ne!(a.state_hash(), b.state_hash(), "config differences must be visible in the hash");
}

#[test]
fn full_delete_then_empty_search() {
    let mut k = Kernel::new(KernelConfig::default_q16(4));
    for i in 0..20u64 {
        k.apply(Command::insert(i, vec![i as f32 * 0.01; 4])).unwrap();
    }
    for i in 0..20u64 {
        k.apply(Command::Delete { id: i }).unwrap();
    }
    assert_eq!(k.len(), 0);
    let hits = k.search_f32(&[0.0; 4], 5).unwrap();
    assert!(hits.is_empty(), "tombstoned graph must yield no live results");
    // and inserts continue to work afterwards (fresh ids only)
    assert_eq!(
        k.apply(Command::insert(5, vec![0.0; 4])).unwrap_err(),
        StateError::DuplicateId(5)
    );
    k.apply(Command::insert(100, vec![0.5; 4])).unwrap();
    assert_eq!(k.search_f32(&[0.5; 4], 1).unwrap()[0].id, 100);
}

#[test]
fn hnsw_and_flat_agree_exactly_at_small_scale() {
    // With n < ef_construction the HNSW beam is exhaustive: the two index
    // kinds must return byte-identical hit lists for every query.
    let mut h = Kernel::new(KernelConfig::default_q16(8));
    let mut f = Kernel::new(KernelConfig::default_q16(8).with_flat_index());
    for i in 0..60u64 {
        let v: Vec<f32> = (0..8).map(|j| ((i + j as u64) as f32 * 0.1).sin() * 0.7).collect();
        h.apply(Command::insert(i, v.clone())).unwrap();
        f.apply(Command::insert(i, v)).unwrap();
    }
    for t in 0..20 {
        let q: Vec<f32> = (0..8).map(|j| ((t * 8 + j) as f32 * 0.07).cos() * 0.7).collect();
        assert_eq!(h.search_f32(&q, 10).unwrap(), f.search_f32(&q, 10).unwrap(), "query {t}");
    }
}
