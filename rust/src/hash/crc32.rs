//! CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320), implemented
//! in-tree for the WAL record frames and the snapshot file trailer.
//!
//! Matches zlib's `crc32` (init `0xFFFF_FFFF`, final xor `0xFFFF_FFFF`),
//! so fixtures can be generated and verified by any standard tool.

#![forbid(unsafe_code)]

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const TABLE: [u32; 256] = build_table();

/// One-shot CRC-32 of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The classic check value, plus zlib-verified pins.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sensitive_to_any_flip() {
        let data = b"deterministic memory substrate";
        let base = crc32(data);
        for i in 0..data.len() {
            let mut tampered = data.to_vec();
            tampered[i] ^= 0x01;
            assert_ne!(crc32(&tampered), base, "flip at {i}");
        }
    }
}
