//! Deterministic HNSW (paper §7).
//!
//! HNSW is traditionally stochastic: level assignment samples a geometric
//! distribution and entry points / tie-breaks depend on RNG and iteration
//! order. Valori removes every source of nondeterminism:
//!
//! 1. **Fixed ordering** (§7.1): the state machine applies inserts in
//!    command-log order, so slot numbering is a pure function of the log.
//! 2. **Data-dependent level assignment** (§7.2): instead of sampling,
//!    `level(id) = trailing_zeros(splitmix64(id)) / log2(M)` — a geometric
//!    distribution with ratio 1/M derived deterministically from the id.
//! 3. **Deterministic entry point** (§7.2): the entry is the first inserted
//!    node, promoted only when a strictly higher-level node arrives (a
//!    data-dependent rule, no RNG; ties keep the earlier node).
//! 4. **Deterministic neighbor selection** (§7.3): distances are integers
//!    (total order) and every comparison is on `(dist, slot)`, so graph
//!    topology is identical across runs and platforms.
//!
//! The same generic code instantiates the `f32` baseline (via
//! [`crate::distance::OrderedF32`] keys), which keeps Table 3's control:
//! identical parameters, identical insertion order, different arithmetic.

#![forbid(unsafe_code)]

use super::store::VecStore;
use super::topk::TopK;
use super::{Hit, VectorIndex};
use crate::codec::{DecodeError, Decoder, Encoder};
use crate::distance::{Metric, Scalar};
use crate::hash::splitmix64;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// HNSW construction/search parameters (part of the collection config and
/// of the snapshot, so two nodes can verify they run the same graph).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HnswParams {
    /// Max neighbors per node on layers >= 1.
    pub m: usize,
    /// Max neighbors on layer 0 (typically 2*M).
    pub m0: usize,
    /// Beam width during construction.
    pub ef_construction: usize,
    /// Default beam width during search (raised to k when k is larger).
    pub ef_search: usize,
    /// Hard cap on levels (bounds memory; 2^(4*8) points at M=16).
    pub max_level: usize,
}

impl Default for HnswParams {
    fn default() -> Self {
        Self { m: 16, m0: 32, ef_construction: 150, ef_search: 128, max_level: 8 }
    }
}

impl HnswParams {
    pub fn encode(&self, e: &mut Encoder) {
        e.put_u32(self.m as u32);
        e.put_u32(self.m0 as u32);
        e.put_u32(self.ef_construction as u32);
        e.put_u32(self.ef_search as u32);
        e.put_u32(self.max_level as u32);
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        Ok(Self {
            m: d.get_u32()? as usize,
            m0: d.get_u32()? as usize,
            ef_construction: d.get_u32()? as usize,
            ef_search: d.get_u32()? as usize,
            max_level: d.get_u32()? as usize,
        })
    }
}

/// Per-slot graph node: adjacency per layer `0..=level`.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Node {
    level: usize,
    /// `neighbors[l]` = slots adjacent at layer `l`.
    neighbors: Vec<Vec<u32>>,
}

/// Deterministic HNSW index over a [`VecStore`].
#[derive(Debug, Clone, PartialEq)]
pub struct Hnsw<S: Scalar> {
    params: HnswParams,
    metric: Metric,
    store: VecStore<S>,
    nodes: Vec<Node>,
    /// Entry slot (first inserted; promoted on strictly-higher level).
    entry: Option<u32>,
}

impl<S: Scalar> Hnsw<S> {
    pub fn new(dim: usize, metric: Metric, params: HnswParams) -> Self {
        Self { params, metric, store: VecStore::new(dim), nodes: Vec::new(), entry: None }
    }

    pub fn params(&self) -> &HnswParams {
        &self.params
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    pub fn store(&self) -> &VecStore<S> {
        &self.store
    }

    pub fn entry_slot(&self) -> Option<u32> {
        self.entry
    }

    /// Divergence repair (see [`crate::proof`]): overwrite one slot's
    /// arena row and/or liveness in place. The graph is untouched —
    /// tombstones are already valid routing waypoints, and a repaired
    /// vector restores exactly the value the adjacency was built against
    /// (repair ships the *correct* record, never a new one).
    pub(crate) fn repair_slot(&mut self, slot: u32, vector: Option<&[S]>, alive: bool) {
        self.store.overwrite_slot(slot, vector, alive);
    }

    /// Deterministic data-dependent level (paper §7.2): geometric with
    /// ratio 1/M via trailing zeros of a splitmix64 of the external id.
    pub fn assign_level(&self, id: u64) -> usize {
        let log2m = (usize::BITS - 1 - self.params.m.leading_zeros() as u32).max(1);
        let h = splitmix64(id);
        let tz = h.trailing_zeros(); // 64 for h == 0
        ((tz / log2m) as usize).min(self.params.max_level)
    }

    #[inline]
    fn dist_to_slot(&self, query: &[S], slot: u32) -> S::Dist {
        S::distance(self.metric, query, self.store.vec_at(slot))
    }

    /// Greedy closest-point walk on one layer (used on layers above the
    /// target during descent).
    fn greedy_closest(&self, query: &[S], start: u32, layer: usize) -> u32 {
        let mut cur = start;
        let mut cur_d = self.dist_to_slot(query, cur);
        loop {
            let mut improved = false;
            for &nb in &self.nodes[cur as usize].neighbors[layer] {
                let d = self.dist_to_slot(query, nb);
                // strict improvement with (dist, slot) tiebreak keeps the
                // walk deterministic and terminating
                if (d, nb) < (cur_d, cur) {
                    cur = nb;
                    cur_d = d;
                    improved = true;
                }
            }
            if !improved {
                return cur;
            }
        }
    }

    /// Beam search on one layer. Returns up to `ef` (dist, slot) pairs,
    /// sorted ascending. Includes tombstoned slots (they are valid routing
    /// waypoints); callers filter.
    fn search_layer(&self, query: &[S], entry: u32, ef: usize, layer: usize) -> Vec<(S::Dist, u32)> {
        let mut visited = vec![false; self.nodes.len()];
        visited[entry as usize] = true;
        let d0 = self.dist_to_slot(query, entry);

        // min-heap of candidates to expand
        let mut candidates: BinaryHeap<Reverse<(S::Dist, u32)>> = BinaryHeap::new();
        candidates.push(Reverse((d0, entry)));
        // max-heap of current best results (worst on top)
        let mut results: BinaryHeap<(S::Dist, u32)> = BinaryHeap::new();
        results.push((d0, entry));

        while let Some(Reverse((d, slot))) = candidates.pop() {
            let worst = results.peek().copied().expect("results never empty");
            if results.len() >= ef && (d, slot) > worst {
                break;
            }
            for &nb in &self.nodes[slot as usize].neighbors[layer] {
                if visited[nb as usize] {
                    continue;
                }
                visited[nb as usize] = true;
                let dn = self.dist_to_slot(query, nb);
                let worst = results.peek().copied().expect("results never empty");
                if results.len() < ef || (dn, nb) < worst {
                    candidates.push(Reverse((dn, nb)));
                    results.push((dn, nb));
                    if results.len() > ef {
                        results.pop();
                    }
                }
            }
        }
        let mut out: Vec<(S::Dist, u32)> = results.into_vec();
        out.sort();
        out
    }

    /// Neighbor selection: Malkov's diversity heuristic (Alg. 4), made
    /// deterministic — candidates are visited in ascending `(dist, slot)`
    /// order and kept only if they are closer to the base point than to
    /// every already-selected neighbor. All comparisons are on total
    /// orders, so the selected set is a pure function of the inputs
    /// (paper §7.3: "graph topology is identical across runs").
    ///
    /// The diversity condition is what keeps clustered data navigable
    /// (pure M-closest selection strands clusters with no long-range
    /// links and recall collapses — see index_consistency tests).
    fn select_neighbors_heuristic(
        &self,
        cands: &[(S::Dist, u32)],
        m: usize,
    ) -> Vec<(S::Dist, u32)> {
        let mut selected: Vec<(S::Dist, u32)> = Vec::with_capacity(m);
        for &(d, c) in cands {
            if selected.len() >= m {
                break;
            }
            let cv = self.store.vec_at(c);
            let diverse = selected.iter().all(|&(_, s)| {
                let d_cs = S::distance(self.metric, cv, self.store.vec_at(s));
                d_cs >= d // c is closer to base than to any selected neighbor
            });
            if diverse {
                selected.push((d, c));
            }
        }
        // backfill with the closest skipped candidates if the heuristic
        // under-fills (standard keepPrunedConnections behaviour)
        if selected.len() < m {
            for &(d, c) in cands {
                if selected.len() >= m {
                    break;
                }
                if !selected.iter().any(|&(_, s)| s == c) {
                    selected.push((d, c));
                }
            }
        }
        selected
    }

    fn max_neighbors(&self, layer: usize) -> usize {
        if layer == 0 {
            self.params.m0
        } else {
            self.params.m
        }
    }

    /// Re-prune a node's adjacency at `layer` to the cap via the same
    /// diversity heuristic (keeps pruning consistent with selection).
    fn shrink_neighbors(&mut self, slot: u32, layer: usize) {
        let cap = self.max_neighbors(layer);
        let list = &self.nodes[slot as usize].neighbors[layer];
        if list.len() <= cap {
            return;
        }
        let base = self.store.vec_at(slot);
        let mut scored: Vec<(S::Dist, u32)> = list
            .iter()
            .map(|&nb| (S::distance(self.metric, base, self.store.vec_at(nb)), nb))
            .collect();
        scored.sort();
        let kept = self.select_neighbors_heuristic(&scored, cap);
        self.nodes[slot as usize].neighbors[layer] = kept.into_iter().map(|(_, s)| s).collect();
    }

    pub fn encode(&self, e: &mut Encoder) {
        e.put_u8(self.metric.tag());
        self.params.encode(e);
        self.store.encode(e);
        e.put_u32(self.nodes.len() as u32);
        for n in &self.nodes {
            e.put_u32(n.level as u32);
            for l in 0..=n.level {
                let nb = &n.neighbors[l];
                e.put_u32(nb.len() as u32);
                for &s in nb {
                    e.put_u32(s);
                }
            }
        }
        match self.entry {
            Some(s) => {
                e.put_u8(1);
                e.put_u32(s);
            }
            None => e.put_u8(0),
        }
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let tag = d.get_u8()?;
        let metric = Metric::from_tag(tag)
            .ok_or(DecodeError::InvalidTag { what: "metric", tag: tag as u64 })?;
        let params = HnswParams::decode(d)?;
        let store = VecStore::decode(d)?;
        let n = d.get_u32()? as usize;
        if n != store.slots() {
            return Err(DecodeError::InvalidTag { what: "node count", tag: n as u64 });
        }
        let mut nodes = Vec::with_capacity(n);
        for _ in 0..n {
            let level = d.get_u32()? as usize;
            if level > params.max_level {
                return Err(DecodeError::InvalidTag { what: "level", tag: level as u64 });
            }
            let mut neighbors = Vec::with_capacity(level + 1);
            for _ in 0..=level {
                let cnt = d.get_u32()? as usize;
                let mut list = Vec::with_capacity(cnt);
                for _ in 0..cnt {
                    let s = d.get_u32()?;
                    if s as usize >= n {
                        return Err(DecodeError::InvalidTag { what: "neighbor slot", tag: s as u64 });
                    }
                    list.push(s);
                }
                neighbors.push(list);
            }
            nodes.push(Node { level, neighbors });
        }
        let entry = match d.get_u8()? {
            0 => None,
            1 => Some(d.get_u32()?),
            t => return Err(DecodeError::InvalidTag { what: "entry flag", tag: t as u64 }),
        };
        Ok(Self { params, metric, store, nodes, entry })
    }
}

impl<S: Scalar> VectorIndex<S> for Hnsw<S> {
    fn insert(&mut self, id: u64, vector: Vec<S>) {
        let level = self.assign_level(id);
        let slot = self.store.insert(id, vector);
        self.nodes.push(Node { level, neighbors: vec![Vec::new(); level + 1] });

        let Some(entry) = self.entry else {
            // First node: becomes the fixed entry point (paper §7.2).
            self.entry = Some(slot);
            return;
        };

        let entry_level = self.nodes[entry as usize].level;
        let query: Vec<S> = self.store.vec_at(slot).to_vec();

        // Descend from the entry's top layer to just above our level.
        let mut ep = entry;
        let mut layer = entry_level;
        while layer > level {
            ep = self.greedy_closest(&query, ep, layer);
            layer -= 1;
        }

        // Connect on each layer from min(level, entry_level) down to 0.
        let top = level.min(entry_level);
        for l in (0..=top).rev() {
            let cands = self.search_layer(&query, ep, self.params.ef_construction, l);
            ep = cands.first().map(|&(_, s)| s).unwrap_or(ep);
            let selected = self.select_neighbors_heuristic(&cands, self.max_neighbors(l));
            for &(_, nb) in &selected {
                self.nodes[slot as usize].neighbors[l].push(nb);
                self.nodes[nb as usize].neighbors[l].push(slot);
                self.shrink_neighbors(nb, l);
            }
        }

        // Promote entry only on strictly higher level (deterministic,
        // data-dependent; ties keep the earlier node).
        if level > entry_level {
            self.entry = Some(slot);
        }
    }

    fn delete(&mut self, id: u64) -> bool {
        // Tombstone: the slot stays in the graph as a routing waypoint
        // (standard mark-delete), searches filter it from results. This
        // keeps deletion O(1) and — critically — keeps the graph topology
        // a pure function of the full command history.
        self.store.delete(id).is_some()
    }

    fn search(&self, query: &[S], k: usize) -> Vec<Hit<S::Dist>> {
        // Same boundary as FlatIndex::search: one loud dim check per
        // query discharges the distance kernels' equal-length contract.
        assert_eq!(
            query.len(),
            self.store.dim(),
            "query dimension mismatch: {} != {}",
            query.len(),
            self.store.dim()
        );
        let Some(entry) = self.entry else {
            return Vec::new();
        };
        if k == 0 {
            return Vec::new();
        }
        let entry_level = self.nodes[entry as usize].level;
        let mut ep = entry;
        for l in (1..=entry_level).rev() {
            ep = self.greedy_closest(query, ep, l);
        }
        // Over-fetch to survive tombstones among the ef best.
        let dead = self.store.slots() - self.store.live_len();
        let ef = self.params.ef_search.max(k) + dead.min(256);
        let cands = self.search_layer(query, ep, ef, 0);
        // Stream the beam's candidates through a bounded top-k under the
        // same (dist, id) total order the former sort used — bit-identical
        // ranking, no O(ef) re-sort allocation.
        let mut topk = TopK::new(k);
        for (d, s) in cands {
            if self.store.is_alive(s) {
                topk.push(d, self.store.external_id(s));
            }
        }
        topk.into_sorted_hits()
    }

    fn len(&self) -> usize {
        self.store.live_len()
    }

    fn get(&self, id: u64) -> Option<&[S]> {
        self.store.get(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{FixedFormat, Q16_16};
    use crate::hash::XorShift64;
    use crate::index::flat::FlatIndex;

    fn q(x: f64) -> i32 {
        Q16_16::quantize(x)
    }

    fn random_q16(rng: &mut XorShift64, dim: usize) -> Vec<i32> {
        (0..dim).map(|_| q(rng.next_f64() * 2.0 - 1.0)).collect()
    }

    fn build_random(n: usize, dim: usize, seed: u64) -> (Hnsw<i32>, FlatIndex<i32>) {
        let mut rng = XorShift64::new(seed);
        let mut h = Hnsw::new(dim, Metric::L2, HnswParams::default());
        let mut f = FlatIndex::new(dim, Metric::L2);
        for id in 0..n as u64 {
            let v = random_q16(&mut rng, dim);
            h.insert(id, v.clone());
            f.insert(id, v);
        }
        (h, f)
    }

    #[test]
    fn empty_search() {
        let h: Hnsw<i32> = Hnsw::new(4, Metric::L2, HnswParams::default());
        assert!(h.search(&[0, 0, 0, 0], 5).is_empty());
        assert_eq!(h.len(), 0);
    }

    #[test]
    fn single_node() {
        let mut h = Hnsw::new(2, Metric::L2, HnswParams::default());
        h.insert(42, vec![q(1.0), q(1.0)]);
        let hits = h.search(&[q(0.9), q(1.1)], 3);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 42);
        assert_eq!(h.entry_slot(), Some(0));
    }

    #[test]
    fn levels_are_deterministic_and_geometric() {
        let h: Hnsw<i32> = Hnsw::new(2, Metric::L2, HnswParams::default());
        // Pure function of id.
        for id in 0..100 {
            assert_eq!(h.assign_level(id), h.assign_level(id));
        }
        // Roughly geometric: the vast majority of ids land on level 0.
        let l0 = (0..10_000u64).filter(|&id| h.assign_level(id) == 0).count();
        assert!(l0 > 8_500, "level-0 fraction too low: {l0}");
        // And some do not (upper layers exist).
        assert!(l0 < 10_000);
    }

    #[test]
    fn exact_recall_on_small_set() {
        // With n <= ef_construction the beam covers everything: recall 1.0.
        let (h, f) = build_random(80, 16, 7);
        let mut rng = XorShift64::new(99);
        for _ in 0..20 {
            let query = random_q16(&mut rng, 16);
            let hh = h.search(&query, 10);
            let fh = f.search(&query, 10);
            assert_eq!(
                hh.iter().map(|x| x.id).collect::<Vec<_>>(),
                fh.iter().map(|x| x.id).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn good_recall_on_larger_set() {
        let (h, f) = build_random(1500, 16, 3);
        let mut rng = XorShift64::new(5);
        let mut overlap = 0usize;
        let mut total = 0usize;
        for _ in 0..30 {
            let query = random_q16(&mut rng, 16);
            let hh: Vec<u64> = h.search(&query, 10).iter().map(|x| x.id).collect();
            let fh: Vec<u64> = f.search(&query, 10).iter().map(|x| x.id).collect();
            overlap += hh.iter().filter(|id| fh.contains(id)).count();
            total += 10;
        }
        let recall = overlap as f64 / total as f64;
        assert!(recall > 0.9, "recall@10 = {recall}");
    }

    #[test]
    fn construction_is_bit_deterministic() {
        let (h1, _) = build_random(400, 8, 11);
        let (h2, _) = build_random(400, 8, 11);
        let mut e1 = Encoder::new();
        let mut e2 = Encoder::new();
        h1.encode(&mut e1);
        h2.encode(&mut e2);
        assert_eq!(e1.as_slice(), e2.as_slice());
    }

    #[test]
    fn insertion_order_changes_graph() {
        // The graph is a function of the command sequence — a *different*
        // order is a different sequence and may yield different topology.
        // (Determinism != order-independence; the paper fixes the order.)
        let mut rng = XorShift64::new(21);
        let vecs: Vec<Vec<i32>> = (0..200).map(|_| random_q16(&mut rng, 8)).collect();
        let mut fwd = Hnsw::new(8, Metric::L2, HnswParams::default());
        for (id, v) in vecs.iter().enumerate() {
            fwd.insert(id as u64, v.clone());
        }
        let mut bwd = Hnsw::new(8, Metric::L2, HnswParams::default());
        for (id, v) in vecs.iter().enumerate().rev() {
            bwd.insert(id as u64, v.clone());
        }
        // Both must still return the same *top-1* for an exact-match query.
        let hits_f = fwd.search(&vecs[17], 1);
        let hits_b = bwd.search(&vecs[17], 1);
        assert_eq!(hits_f[0].id, 17);
        assert_eq!(hits_b[0].id, 17);
    }

    #[test]
    fn delete_removes_from_results_but_routes() {
        let (mut h, _) = build_random(300, 8, 13);
        let v = h.get(5).unwrap().to_vec();
        assert!(h.delete(5));
        let hits = h.search(&v, 10);
        assert!(hits.iter().all(|x| x.id != 5));
        assert_eq!(h.len(), 299);
    }

    #[test]
    fn encode_decode_roundtrip_preserves_search() {
        let (h, _) = build_random(250, 8, 17);
        let mut e = Encoder::new();
        h.encode(&mut e);
        let bytes = e.into_vec();
        let h2 = Hnsw::<i32>::decode(&mut Decoder::new(&bytes)).unwrap();
        let mut rng = XorShift64::new(1);
        for _ in 0..10 {
            let query = random_q16(&mut rng, 8);
            assert_eq!(h.search(&query, 10), h2.search(&query, 10));
        }
        // canonical: re-encode gives identical bytes
        let mut e2 = Encoder::new();
        h2.encode(&mut e2);
        assert_eq!(bytes, e2.into_vec());
    }

    #[test]
    fn decode_rejects_corrupt_neighbor() {
        let (h, _) = build_random(10, 4, 1);
        let mut e = Encoder::new();
        h.encode(&mut e);
        let mut bytes = e.into_vec();
        // flip a late byte to a huge neighbor slot — decoder must not panic
        let n = bytes.len();
        bytes[n - 20] = 0xff;
        bytes[n - 19] = 0xff;
        bytes[n - 18] = 0xff;
        bytes[n - 17] = 0xff;
        let _ = Hnsw::<i32>::decode(&mut Decoder::new(&bytes)); // Err or Ok, no panic
    }

    #[test]
    fn f32_instantiation_builds_and_searches() {
        let mut rng = XorShift64::new(31);
        let mut h: Hnsw<f32> = Hnsw::new(8, Metric::L2, HnswParams::default());
        for id in 0..200u64 {
            let v: Vec<f32> = (0..8).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
            h.insert(id, v);
        }
        let v0 = h.get(0).unwrap().to_vec();
        let hits = h.search(&v0, 5);
        assert_eq!(hits[0].id, 0);
    }

    #[test]
    fn inner_product_metric_search() {
        let mut h = Hnsw::new(2, Metric::InnerProduct, HnswParams::default());
        h.insert(1, vec![q(1.0), q(0.0)]);
        h.insert(2, vec![q(0.0), q(1.0)]);
        h.insert(3, vec![q(-1.0), q(0.0)]);
        let hits = h.search(&[q(1.0), q(0.0)], 3);
        assert_eq!(hits[0].id, 1);
        assert_eq!(hits[2].id, 3);
    }
}
