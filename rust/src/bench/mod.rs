//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, calibrated iteration counts, and robust statistics
//! (p50/p95/p99/mean) over per-iteration wall time. Used by every target
//! under `rust/benches/` and by the experiment drivers that report the
//! paper's latency numbers (§8.2).

#![forbid(unsafe_code)]

pub mod suite;

use std::time::{Duration, Instant};

/// Summary statistics for one benchmark, all in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    /// Compute stats from raw per-iteration samples.
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        // nearest-rank percentile: ceil(p*n)-th smallest sample
        let pct = |p: f64| -> f64 {
            let rank = (p * n as f64).ceil() as usize;
            samples[rank.clamp(1, n) - 1]
        };
        Stats {
            iters: n,
            mean_ns: samples.iter().sum::<f64>() / n as f64,
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            p99_ns: pct(0.99),
            min_ns: samples[0],
            max_ns: samples[n - 1],
        }
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    pub fn p50(&self) -> Duration {
        Duration::from_nanos(self.p50_ns as u64)
    }

    /// Throughput in ops/sec at the mean.
    pub fn ops_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Human-friendly duration rendering (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.2} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Wall-clock budget for warmup.
    pub warmup: Duration,
    /// Wall-clock budget for measurement.
    pub measure: Duration,
    /// Hard cap on measured iterations (keeps huge-op benches bounded).
    pub max_iters: usize,
    /// Minimum measured iterations (ensures stats make sense).
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 100_000,
            min_iters: 10,
        }
    }
}

impl BenchConfig {
    /// Faster config for CI-style smoke runs.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

/// Run one benchmark: warm up, then measure per-iteration latency until the
/// time budget or iteration cap is reached. The closure's return value is
/// passed through `std::hint::black_box` to defeat dead-code elimination.
pub fn bench<T>(config: &BenchConfig, mut f: impl FnMut() -> T) -> Stats {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < config.warmup {
        std::hint::black_box(f());
    }
    // Measure.
    let mut samples = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < config.measure || samples.len() < config.min_iters)
        && samples.len() < config.max_iters
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Stats::from_samples(samples)
}

/// A named group of benchmark rows, rendered as an aligned table — one
/// group per paper table/figure.
pub struct Report {
    title: String,
    rows: Vec<(String, Stats)>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), rows: Vec::new(), notes: Vec::new() }
    }

    pub fn add(&mut self, name: impl Into<String>, stats: Stats) {
        self.rows.push((name.into(), stats));
    }

    pub fn note(&mut self, note: impl Into<String>) {
        self.notes.push(note.into());
    }

    pub fn rows(&self) -> &[(String, Stats)] {
        &self.rows
    }

    /// Render the report to stdout.
    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let name_w = self.rows.iter().map(|(n, _)| n.len()).max().unwrap_or(4).max(4);
        println!(
            "{:<name_w$}  {:>12} {:>12} {:>12} {:>12} {:>12}",
            "name", "mean", "p50", "p95", "p99", "ops/s"
        );
        for (name, s) in &self.rows {
            println!(
                "{:<name_w$}  {:>12} {:>12} {:>12} {:>12} {:>12.0}",
                name,
                fmt_ns(s.mean_ns),
                fmt_ns(s.p50_ns),
                fmt_ns(s.p95_ns),
                fmt_ns(s.p99_ns),
                s.ops_per_sec()
            );
        }
        for n in &self.notes {
            println!("  note: {n}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.iters, 100);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
        assert_eq!(s.p50_ns, 50.0);
        assert_eq!(s.p99_ns, 99.0);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
    }

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            max_iters: 1000,
            min_iters: 5,
        };
        let mut acc = 0u64;
        let s = bench(&cfg, || {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            acc
        });
        assert!(s.iters >= 5);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p99_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.00 s");
    }

    #[test]
    fn report_renders() {
        let mut r = Report::new("test table");
        r.add("row1", Stats::from_samples(vec![10.0, 20.0, 30.0]));
        r.note("shape only");
        r.print(); // smoke: must not panic
        assert_eq!(r.rows().len(), 1);
    }

    #[test]
    fn min_iters_honored_even_past_budget() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(0),
            measure: Duration::from_nanos(1),
            max_iters: 1000,
            min_iters: 7,
        };
        let s = bench(&cfg, || std::thread::sleep(Duration::from_micros(10)));
        assert!(s.iters >= 7);
    }
}
