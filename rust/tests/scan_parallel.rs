//! Tier-1 bit-identity matrix for the shared scan pool.
//!
//! The chunk-claiming scan pool races workers over arena sub-ranges, so
//! which worker scans which chunk — and in which order per-task top-ks
//! arrive — is nondeterministic. These tests pin the substrate's
//! determinism contract over that nondeterminism: for every worker
//! count, shard count, and quant tier, pooled results are bit-identical
//! to the sequential in-thread scan, and root hashes never move.
//!
//! Coverage: `scan_workers ∈ {1, 2, 4, 8}` × `n_shards ∈ {1, 4}` ×
//! `{exact, sq8}`, a tie-heavy corpus (id tiebreak under equal
//! distances), and chunk-boundary edges (corpus smaller than one chunk,
//! corpus exactly ±1 around a chunk multiple, deleted-slot holes
//! spanning a chunk edge).

use valori::hash::splitmix64;
use valori::index::QuantSpec;
use valori::state::{CanonCommand, Command, KernelConfig, ShardedKernel, SCAN_CHUNK_SLOTS};

const WORKER_COUNTS: [u32; 4] = [1, 2, 4, 8];

/// Miri interprets every instruction (~1000x slower) but needs only the
/// aliasing/atomics coverage, not thousands of rows — the same matrix
/// runs at a fraction of the corpus size under `cargo miri test`.
const MIRI: bool = cfg!(miri);

/// Deterministic raw Q16.16 component, well inside the boundary
/// contract (|raw| ≤ 2^17 < the 2^18 bound for max_abs = 4.0).
fn raw_component(seed: u64, index: u64) -> i32 {
    ((splitmix64(seed ^ index) % 131_072) as i64 - 65_536) as i32
}

fn raw_row(seed: u64, i: u64, dim: usize) -> Vec<i32> {
    (0..dim as u64).map(|j| raw_component(seed, i * dim as u64 + j)).collect()
}

fn build(n: usize, dim: usize, shards: u32, quant: QuantSpec) -> ShardedKernel {
    let config = KernelConfig::default_q16(dim).with_flat_index().with_quant(quant);
    let mut sk = ShardedKernel::new(config, shards);
    let items: Vec<(u64, Vec<i32>)> = (0..n as u64).map(|i| (i, raw_row(7, i, dim))).collect();
    for chunk in items.chunks(1024) {
        sk.apply_canon(&CanonCommand::InsertBatch { items: chunk.to_vec() })
            .expect("corpus insert");
    }
    sk
}

/// Assert pooled results equal the sequential in-thread scan for every
/// worker count, and that retuning the pool never moves the root.
fn assert_worker_invariance(sk: &mut ShardedKernel, dim: usize, label: &str) {
    let k = 10;
    let n_queries = if MIRI { 2u64 } else { 8 };
    let queries: Vec<Vec<i32>> =
        (0..n_queries).map(|q| raw_row(q ^ 0xC0FFEE, q, dim)).collect();
    let expect: Vec<_> = queries
        .iter()
        .map(|q| sk.search_raw_inline(q, k).expect("sequential reference scan"))
        .collect();
    let root = sk.root_hash();
    for &workers in &WORKER_COUNTS {
        sk.set_scan_workers(workers);
        assert_eq!(sk.root_hash(), root, "{label}: scan tuning moved the root");
        for (q, e) in queries.iter().zip(&expect) {
            let hits = sk.search_raw_pooled(q, k).expect("pooled scan");
            assert_eq!(&hits, e, "{label}: {workers}-worker scan diverged from sequential");
        }
        // the public entry point must agree too, whichever path it picks
        for (q, e) in queries.iter().zip(&expect) {
            assert_eq!(&sk.search_raw(q, k).expect("search"), e, "{label}: search_raw diverged");
        }
    }
}

#[test]
fn worker_count_never_changes_bits_exact_and_sq8() {
    // Big enough that every shard spans multiple chunks at the reduced
    // chunk size, small enough to stay a fast tier-1 test.
    let (n, dim) = if MIRI { (96, 8) } else { (3000, 16) };
    for &shards in &[1u32, 4] {
        for quant in [QuantSpec::None, QuantSpec::sq8_default()] {
            let mut sk = build(n, dim, shards, quant);
            // 256-slot chunks force real multi-task fan-out per shard on
            // both the phase-1 scan and the sq8 phase-2 re-rank.
            sk.set_scan_chunk(if MIRI { 16 } else { 256 });
            let label = format!("shards={shards} quant={quant:?}");
            assert_worker_invariance(&mut sk, dim, &label);
        }
    }
}

#[test]
fn tie_heavy_corpus_breaks_ties_by_id_under_any_worker_count() {
    // Only 8 distinct vectors over 2000 ids: almost every distance is
    // tied, so any reduction that is not strictly `(dist, id)`-ordered
    // (e.g. one sensitive to task completion order) scrambles the tail.
    let dim = 8;
    let bases: Vec<Vec<i32>> = (0..8u64).map(|b| raw_row(b, 99, dim)).collect();
    for quant in [QuantSpec::None, QuantSpec::sq8_default()] {
        let config = KernelConfig::default_q16(dim).with_flat_index().with_quant(quant);
        let mut sk = ShardedKernel::new(config, 2);
        let ids = if MIRI { 200u64 } else { 2000 };
        let items: Vec<(u64, Vec<i32>)> =
            (0..ids).map(|i| (i, bases[(i % 8) as usize].clone())).collect();
        sk.apply_canon(&CanonCommand::InsertBatch { items }).expect("corpus insert");
        sk.set_scan_chunk(if MIRI { 32 } else { 128 });
        let k = if MIRI { 16 } else { 64 };
        let expect = sk.search_raw_inline(&bases[0], k).expect("sequential reference scan");
        // ties resolved ascending-id within each distance class
        for pair in expect.windows(2) {
            assert!(
                (pair[0].dist_raw, pair[0].id) < (pair[1].dist_raw, pair[1].id),
                "reference order is not strict (dist, id)"
            );
        }
        for &workers in &WORKER_COUNTS {
            sk.set_scan_workers(workers);
            let hits = sk.search_raw_pooled(&bases[0], k).expect("pooled scan");
            assert_eq!(hits, expect, "tie-heavy quant={quant:?} workers={workers}");
        }
    }
}

#[test]
fn chunk_boundary_edges_are_bit_identical() {
    let dim = 8;
    let chunk = if MIRI { 16usize } else { 64 };
    // n < chunk, n == chunk ± 1, exact multiples, multiples ± 1.
    for n in [7, chunk - 1, chunk, chunk + 1, 3 * chunk - 1, 3 * chunk, 3 * chunk + 1] {
        let mut sk = build(n, dim, 1, QuantSpec::None);
        sk.set_scan_chunk(chunk as u32);
        assert_worker_invariance(&mut sk, dim, &format!("edge n={n} chunk={chunk}"));
    }
}

#[test]
fn deleted_slot_holes_spanning_chunk_edges_are_bit_identical() {
    let dim = 8;
    let chunk = if MIRI { 16u32 } else { 64 };
    let n = if MIRI { 80u64 } else { 300 };
    for quant in [QuantSpec::None, QuantSpec::sq8_default()] {
        let config = KernelConfig::default_q16(dim).with_flat_index().with_quant(quant);
        let mut sk = ShardedKernel::new(config, 1);
        for i in 0..n {
            sk.apply_canon(&CanonCommand::Insert { id: i, raw: raw_row(3, i, dim) })
                .expect("insert");
        }
        // Tombstone a run straddling the first chunk edge (slots
        // chunk-2..=chunk+2 in insertion order), one exactly at the
        // second edge, and the last slot — a claimed range must skip
        // holes identically to the sequential scan.
        let edge = chunk as u64;
        for id in [edge - 2, edge - 1, edge, edge + 1, edge + 2, 2 * edge, n - 1] {
            sk.apply(Command::Delete { id }).expect("delete");
        }
        sk.set_scan_chunk(chunk);
        assert_worker_invariance(&mut sk, dim, &format!("holes quant={quant:?}"));
    }
}

#[test]
fn default_chunk_constant_is_what_the_docs_promise() {
    // Task boundaries are part of the determinism argument only in the
    // sense that they must be config, not machine-derived; pin the
    // default so a silent change shows up in review.
    assert_eq!(SCAN_CHUNK_SLOTS, 4096);
}
