//! The Valori node: HTTP API + request routing + embed batching
//! (paper Fig. 1's interface layer; §5.3 "Node ('std')").
//!
//! The node *wraps* the kernel but never alters its logic: every mutation
//! goes through `Kernel::apply`, is WAL-logged in canonical form, and is
//! observable through the hash endpoints for replica comparison.
//!
//! ## API surface
//!
//! The public boundary is **versioned**. `/v2` is the multi-tenant
//! collections surface (see [`collections`] for the manager and
//! [`crate::api`] for the typed envelope + the closed error-code
//! taxonomy); `/v1` is the legacy single-tenant surface, served as a
//! thin adapter onto the reserved `default` collection when a
//! [`collections::CollectionManager`] is in front (byte-identical to a
//! pre-collections node), or directly off a bare [`NodeState`].
//!
//! ### `/v2` — typed envelope `{"data":…,"ok":true}` / taxonomy errors
//!
//! | Route | Effect |
//! |---|---|
//! | `PUT /v2/collections/{name}` | create collection (`{"dim":N,"shards":N,"index":"flat"\|"hnsw"}`, all optional) |
//! | `GET /v2/collections/{name}` | collection summary (dim, shards, vectors, seq, root) |
//! | `DELETE /v2/collections/{name}` | drop collection (`default` is reserved) |
//! | `GET /v2/collections` | list collections, lexicographic |
//! | `POST /v2/collections/{name}/insert` | `{"id":1,"vector":[…]}` or `{"id":1,"text":"…"}` |
//! | `POST /v2/collections/{name}/insert_batch` | `{"items":[{"id":…,"vector":[…]},…]}` |
//! | `POST /v2/collections/{name}/query` | `{"vector":[…],"k":10}` or `{"text":"…","k":10}` |
//! | `POST /v2/collections/{name}/delete` | `{"id":1}` |
//! | `POST /v2/collections/{name}/link` / `unlink` | `{"from":1,"to":2}` |
//! | `POST /v2/collections/{name}/meta` | `{"id":1,"key":"k","value":"v"}` |
//! | `POST /v2/collections/{name}/apply` | `{"commands":["<hex>",…],"shard":S?}` (follower ingest) |
//! | `GET /v2/collections/{name}/log?shard=S&from=N` | per-shard canonical feed |
//! | `GET /v2/collections/{name}/hash` | per-shard FNV/SHA-256/Merkle manifest + roots |
//! | `GET /v2/collections/{name}/proof` | state receipt (`state_version`, `seq`, `snapshot_hash`, `wal_hash`, `merkle_root`, per-shard roots); `?id=N` → membership proof; `?shard=S&level=L&from=A&count=K` → bisection hashes; `?shard=S&slot=N` → canonical leaf encoding |
//! | `POST /v2/collections/{name}/repair` | `{"shard":S,"slot":N,"record":"<hex leaf>"}` record-level divergence repair (un-logged state surgery; seq untouched) |
//! | `GET /v2/collections/{name}/stats` | metrics + kernel info |
//! | `GET /v2/collections/{name}/snapshot?chunk=N` | chunked `VSTREAM1` snapshot stream (raw body, per-chunk CRCs, seq-pinned consistency) |
//! | `PUT /v2/collections/{name}/restore?offset=N` | windowed `VSTREAM1` ingest into a fresh collection (resumable; offset = bytes already fed) |
//! | `GET /v2/hash` | combined root over all collections (lexicographic fold) |
//! | `GET /v2/health` | `{"ok":true,"backend":"epoll"\|"blocking","collections":N}` |
//!
//! The error-code taxonomy (`1000 bad_request` … `1500 internal`) is
//! enumerated **once**, in [`crate::api`]'s module docs, and pinned by
//! `tests/fixtures/api_error_codes.json`.
//!
//! ### `/v1` — legacy ad-hoc JSON (kept bit-for-bit)
//!
//! | Route | Body | Effect |
//! |---|---|---|
//! | `POST /v1/insert` | `{"id":1,"vector":[...]}` or `{"id":1,"text":"..."}` | insert (text is embedded via the batcher) |
//! | `POST /v1/insert_batch` | `{"items":[...]}` | batch insert |
//! | `POST /v1/query` | `{"vector":[...]}` or `{"text":"...","k":10}` | k-NN search |
//! | `POST /v1/delete` | `{"id":1}` | tombstone |
//! | `POST /v1/link` / `unlink` | `{"from":1,"to":2}` | link graph edit |
//! | `POST /v1/meta` | `{"id":1,"key":"k","value":"v"}` | metadata |
//! | `POST /v1/embed` | `{"texts":["..."]}` | embeddings only |
//! | `GET /v1/stats` | — | metrics + kernel info |
//! | `GET /v1/hash` | — | state hash (fnv + sha256) |
//! | `GET /v1/log?shard=S&from=N` | — | per-shard canonical feed (replication) |
//! | `POST /v1/apply` | `{"commands":["<hex>"...]}` | apply canonical commands (follower ingest) |
//! | `GET /v1/health` | — | `{"ok":true,"backend":…,"collections":…}` |

#![forbid(unsafe_code)]

pub mod batcher;
pub mod collections;
pub mod governor;
pub mod metrics;

pub use batcher::{BatcherHandle, EmbedBackend, EmbedBatcher};
pub use collections::{
    route_collections, serve_collections, CollectionManager, CollectionSpec, DEFAULT_COLLECTION,
    ManagerConfig,
};
pub use governor::{Admission, Governor, GovernorConfig, TenantSnapshot};
pub use metrics::Metrics;

use crate::http::{Handler, Request, Response, Server};
use crate::json::{parse, Json};
use crate::snapshot::Snapshot;
use crate::state::{CanonCommand, Command, Kernel, Routed, ShardedKernel};
use crate::wal::WalWriter;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Node configuration.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// HTTP workers.
    pub workers: usize,
    /// Base path for the WAL (None = in-memory only). Single-shard nodes
    /// use the path verbatim; an `n_shards`-wide node writes one WAL per
    /// shard at `<path>.shard<N>` (see [`shard_wal_path`]).
    pub wal_path: Option<std::path::PathBuf>,
    /// Arena-byte budget for client inserts (0 = unlimited). Enforced at
    /// the /v2 boundary as taxonomy code 1602 `memory_quota_exceeded`;
    /// replication ingest and /v1 are exempt (see [`crate::api`]).
    pub memory_quota: u64,
}

impl Default for NodeConfig {
    fn default() -> Self {
        Self { workers: 4, wal_path: None, memory_quota: 0 }
    }
}

/// WAL file for one shard: the base path itself for unsharded nodes
/// (seed-compatible), `<base>.shard<N>` otherwise.
pub fn shard_wal_path(base: &Path, shard: u32, n_shards: u32) -> PathBuf {
    if n_shards <= 1 {
        base.to_path_buf()
    } else {
        PathBuf::from(format!("{}.shard{shard}", base.display()))
    }
}

/// Shared node state behind the HTTP handler.
///
/// The node wraps a [`ShardedKernel`] (a 1-shard deployment for the
/// classic single-kernel node). Mutations route through the kernel and are
/// recorded **per shard**: each shard has its own in-memory canonical log
/// (replication feed) and its own WAL file, so recovery, log shipping and
/// replay all happen partition-by-partition.
pub struct NodeState {
    /// `RwLock`, not `Mutex`: searches (and every other read endpoint)
    /// take the read lock, so concurrent queries proceed in parallel and
    /// each one can still fan out across the kernel's persistent per-shard
    /// worker pool. Mutations take the write lock — the command order the
    /// WAL records stays a single total order per shard.
    kernel: RwLock<ShardedKernel>,
    /// Per-shard canonical logs (replication feed + audit).
    logs: Vec<Mutex<Vec<CanonCommand>>>,
    /// Per-shard WALs (empty when running in-memory only).
    wals: Vec<Mutex<WalWriter>>,
    embed: Option<BatcherHandle>,
    /// Arena-byte budget for /v2 inserts (0 = unlimited); from
    /// [`NodeConfig::memory_quota`] / the collection spec.
    memory_quota: u64,
    pub metrics: Metrics,
}

impl NodeState {
    /// Build a classic single-kernel node (1-shard deployment). If the
    /// configured WAL file already exists, the kernel is **recovered from
    /// it first** (replay; torn tail repaired), then the WAL is opened for
    /// append — restart durability. Bit-compatible with pre-sharding
    /// nodes: same WAL path, same record framing, same hashes.
    pub fn new(
        kernel: Kernel,
        config: &NodeConfig,
        embed: Option<BatcherHandle>,
    ) -> crate::Result<Self> {
        Self::new_sharded(ShardedKernel::from_single(kernel), config, embed)
    }

    /// Build a sharded node: per-shard WAL recovery, per-shard logs.
    pub fn new_sharded(
        mut kernel: ShardedKernel,
        config: &NodeConfig,
        embed: Option<BatcherHandle>,
    ) -> crate::Result<Self> {
        let n = kernel.n_shards();
        let mut logs: Vec<Vec<CanonCommand>> = (0..n).map(|_| Vec::new()).collect();
        let mut wals = Vec::new();
        if let Some(base) = &config.wal_path {
            // Changing --shards changes the WAL file layout; silently
            // starting empty next to a populated old layout would look
            // like total data loss. Refuse loudly instead.
            let stale: Option<String> = if n == 1 {
                let p = shard_wal_path(base, 0, 2);
                p.exists().then(|| format!("sharded WAL {p:?} exists"))
            } else if base.exists() {
                Some(format!("unsharded WAL {base:?} exists"))
            } else {
                let p = shard_wal_path(base, n, n + 1);
                p.exists().then(|| format!("WAL {p:?} from a larger deployment exists"))
            };
            if let Some(what) = stale {
                return Err(crate::Error::Runtime(format!(
                    "{what}, but this node is configured with {n} shard(s); refusing to \
                     start empty over existing data — remove the old WAL files or match \
                     the original shard count"
                )));
            }
            for s in 0..n {
                let path = shard_wal_path(base, s, n);
                if path.exists() {
                    let rec = crate::wal::recover(&path).map_err(|e| {
                        crate::Error::Runtime(format!("wal recovery {path:?}: {e}"))
                    })?;
                    if rec.truncated_tail {
                        crate::wal::truncate_to_valid(&path, rec.valid_bytes)?;
                    }
                    for entry in &rec.entries {
                        kernel.apply_canon_to_shard(s, &entry.command).map_err(|e| {
                            // A WrongShard rejection here almost always
                            // means the WAL was written under a different
                            // --shards count (the layout guard above can't
                            // catch every resize by filename alone).
                            let hint = if matches!(
                                e,
                                crate::state::StateError::WrongShard { .. }
                            ) {
                                "; the WAL was likely written with a different --shards \
                                 count — restart with the original shard count"
                            } else {
                                ""
                            };
                            crate::Error::Runtime(format!(
                                "wal replay shard {s}: command at seq {} rejected: {e}{hint}",
                                entry.seq
                            ))
                        })?;
                        logs[s as usize].push(entry.command.clone());
                    }
                    wals.push(Mutex::new(WalWriter::append_to(
                        &path,
                        rec.entries.len() as u64,
                    )?));
                } else {
                    wals.push(Mutex::new(WalWriter::create(&path)?));
                }
            }
        }
        Ok(Self {
            kernel: RwLock::new(kernel),
            logs: logs.into_iter().map(Mutex::new).collect(),
            wals,
            embed,
            memory_quota: config.memory_quota,
            metrics: Metrics::default(),
        })
    }

    /// The collection's arena-byte insert budget (0 = unlimited).
    pub fn memory_quota(&self) -> u64 {
        self.memory_quota
    }

    /// Apply an external command: boundary → routed state machine →
    /// per-shard log + WAL.
    ///
    /// The log/WAL appends happen **while the kernel lock is held**: each
    /// shard's application order and its logged order must be the same
    /// sequence, or replaying a shard WAL would reconstruct a different
    /// state (the order *is* the state, paper §3.1).
    pub fn apply(&self, cmd: Command) -> Result<CanonCommand, crate::Error> {
        let mut kernel = self.kernel.write().expect("kernel poisoned");
        let result = kernel.apply(cmd)?;
        self.record(&result.applied)?;
        Ok(result.canon)
    }

    /// Apply an already-canonical command through the router (client-side
    /// canonical ingest). NOT the path for shipped per-shard feeds — the
    /// router re-checks global preconditions (e.g. a cross-shard link
    /// target that may arrive via another shard's feed) and re-expands
    /// deletes into cleanup unlinks that the feeds already contain. Feed
    /// records go through [`Self::apply_canon_to_shard`].
    pub fn apply_canon(&self, canon: &CanonCommand) -> Result<(), crate::Error> {
        let mut kernel = self.kernel.write().expect("kernel poisoned");
        let applied = kernel.apply_canon(canon)?;
        self.record(&applied)?;
        Ok(())
    }

    /// Apply one record of shard `shard`'s canonical feed, exactly as a
    /// WAL replay would: no routing, no cross-shard checks, no cleanup
    /// expansion. This is what makes per-shard feeds independently
    /// shippable — each shard's subsequence replays on the peer's same
    /// shard regardless of how the feeds interleave.
    pub fn apply_canon_to_shard(
        &self,
        shard: u32,
        canon: &CanonCommand,
    ) -> Result<(), crate::Error> {
        let mut kernel = self.kernel.write().expect("kernel poisoned");
        if shard >= kernel.n_shards() {
            return Err(crate::Error::Runtime(format!(
                "shard {shard} out of range (n_shards = {})",
                kernel.n_shards()
            )));
        }
        let seq = kernel.shard(shard).seq();
        kernel.apply_canon_to_shard(shard, canon)?;
        self.record(&[Routed { shard, seq, command: canon.clone() }])?;
        Ok(())
    }

    /// Append routed records to their shards' logs + WALs (caller holds
    /// the kernel lock).
    fn record(&self, applied: &[Routed]) -> Result<(), crate::Error> {
        for r in applied {
            self.logs[r.shard as usize]
                .lock()
                .expect("log poisoned")
                .push(r.command.clone());
            if let Some(w) = self.wals.get(r.shard as usize) {
                let mut w = w.lock().expect("wal poisoned");
                w.append(r.seq, &r.command)?;
                w.flush()?;
            }
        }
        Ok(())
    }

    /// Single-shard compatibility view: runs `f` against shard 0's kernel.
    /// Exact for 1-shard nodes (shard 0 *is* the node); for sharded nodes
    /// prefer [`Self::with_sharded`].
    pub fn with_kernel<T>(&self, f: impl FnOnce(&Kernel) -> T) -> T {
        f(self.kernel.read().expect("kernel poisoned").shard(0))
    }

    /// Run `f` against the whole sharded kernel.
    pub fn with_sharded<T>(&self, f: impl FnOnce(&ShardedKernel) -> T) -> T {
        f(&self.kernel.read().expect("kernel poisoned"))
    }

    pub fn n_shards(&self) -> u32 {
        self.logs.len() as u32
    }

    /// Total canonical log records across shards.
    pub fn log_len(&self) -> usize {
        self.logs.iter().map(|l| l.lock().expect("log poisoned").len()).sum()
    }

    /// One shard's log length.
    pub fn shard_log_len(&self, shard: u32) -> usize {
        self.logs
            .get(shard as usize)
            .map(|l| l.lock().expect("log poisoned").len())
            .unwrap_or(0)
    }

    /// Shard 0's log feed (the whole feed for single-shard nodes).
    pub fn log_slice(&self, from: usize, max: usize) -> Vec<CanonCommand> {
        self.log_slice_shard(0, from, max)
    }

    /// One shard's log feed.
    pub fn log_slice_shard(&self, shard: u32, from: usize, max: usize) -> Vec<CanonCommand> {
        match self.logs.get(shard as usize) {
            Some(l) => {
                let log = l.lock().expect("log poisoned");
                log.iter().skip(from).take(max).cloned().collect()
            }
            None => Vec::new(),
        }
    }

    /// Node-level state hash, rendered for the wire: the shard-0 FNV for
    /// single-shard nodes (seed-compatible), the root hash otherwise.
    pub fn hash_hex(&self) -> String {
        self.with_sharded(|sk| {
            if sk.n_shards() == 1 {
                format!("{:016x}", sk.shard(0).state_hash())
            } else {
                format!("{:016x}", sk.root_hash())
            }
        })
    }

    pub fn embedder(&self) -> Option<&BatcherHandle> {
        self.embed.as_ref()
    }

    /// Record-level divergence repair (see [`crate::proof`]): overwrite
    /// one slot on one shard with its canonical record, under the write
    /// lock. Deliberately **not** recorded to the log or WAL — repair is
    /// state surgery that reconciles a replica *outside* the command
    /// history, and the shard's logical clock is untouched (both sides
    /// already agree on the sequence; they disagree on one record).
    pub fn repair_slot(
        &self,
        shard: u32,
        slot: u32,
        rec: &crate::proof::LeafRecord,
    ) -> Result<(), crate::state::RepairError> {
        let mut kernel = self.kernel.write().expect("kernel poisoned");
        kernel.repair_slot(shard, slot, rec)
    }
}

/// Start the HTTP server for a node (epoll reactor front end). The
/// node's HTTP connection gauges are shared into the server config so
/// `/v1/stats` reports live reactor state.
pub fn serve(state: Arc<NodeState>, addr: &str, workers: usize) -> std::io::Result<Server> {
    let config = crate::http::ServerConfig {
        workers,
        metrics: Arc::clone(&state.metrics.http),
        ..Default::default()
    };
    let handler: Handler = Arc::new(move |req| route(&state, req));
    Server::start_with(addr, config, handler)
}

fn ok_json(value: Json) -> Response {
    Response::json(200, value.to_string())
}

fn err_json(status: u16, msg: &str) -> Response {
    Response::json(status, Json::object(vec![("error", Json::str(msg))]).to_string())
}

/// The health payload (shared by the /v1 and /v2 health routes). A bare
/// [`NodeState`] does not know which front end serves it — and must not:
/// the blocking/reactor equivalence proof requires handler output to be
/// front-end-independent — so standalone routing reports `"unknown"`.
/// The [`collections::CollectionManager`] adapter substitutes the real
/// backend name and collection count.
pub(crate) fn health_json(backend: &str, collections: usize) -> Json {
    Json::object(vec![
        ("backend", Json::str(backend)),
        ("collections", Json::Int(collections as i64)),
        ("ok", Json::Bool(true)),
    ])
}

/// Route one request (pure function of state + request; exposed for tests).
pub fn route(state: &NodeState, req: Request) -> Response {
    let m = &state.metrics;
    let result = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/insert") => handle_insert(state, &req),
        ("POST", "/v1/insert_batch") => handle_insert_batch(state, &req),
        ("POST", "/v1/query") => handle_query(state, &req),
        ("POST", "/v1/delete") => handle_delete(state, &req),
        ("POST", "/v1/link") => handle_link(state, &req, true),
        ("POST", "/v1/unlink") => handle_link(state, &req, false),
        ("POST", "/v1/meta") => handle_meta(state, &req),
        ("POST", "/v1/embed") => handle_embed(state, &req),
        ("POST", "/v1/apply") => handle_apply(state, &req),
        ("GET", "/v1/stats") => Ok(handle_stats(state)),
        ("GET", "/v1/hash") => Ok(handle_hash(state)),
        ("GET", "/v1/log") => Ok(handle_log(state, &req)),
        ("GET", "/v1/health") => Ok(ok_json(health_json("unknown", 1))),
        _ => Ok(Response::not_found()),
    };
    match result {
        Ok(resp) => resp,
        Err(resp) => {
            Metrics::inc(&m.errors);
            resp
        }
    }
}

type RouteResult = Result<Response, Response>;

fn body_json(req: &Request) -> Result<Json, Response> {
    let text = req.body_str().map_err(|_| Response::bad_request("body is not utf-8"))?;
    parse(text).map_err(|e| Response::bad_request(&format!("invalid json: {e}")))
}

fn get_vector(body: &Json, state: &NodeState) -> Result<Vec<f32>, Response> {
    if let Some(arr) = body.get("vector").as_array() {
        arr.iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Option<Vec<f32>>>()
            .ok_or_else(|| Response::bad_request("vector must be an array of numbers"))
    } else if let Some(text) = body.get("text").as_str() {
        let embed = state
            .embedder()
            .ok_or_else(|| err_json(503, "no embedder loaded (run `make artifacts`)"))?;
        let t0 = Instant::now();
        let v = embed
            .embed(text)
            .map_err(|e| err_json(500, &format!("embed failed: {e}")))?;
        state.metrics.embed_latency.record_us(t0.elapsed().as_micros() as u64);
        Metrics::inc(&state.metrics.embeds);
        Ok(v)
    } else {
        Err(Response::bad_request("need 'vector' or 'text'"))
    }
}

fn state_error_response(e: &crate::Error) -> Response {
    use crate::state::StateError;
    match e {
        crate::Error::State(StateError::DuplicateId(id)) => {
            err_json(409, &format!("duplicate id {id}"))
        }
        crate::Error::State(StateError::UnknownId(id)) => {
            err_json(404, &format!("unknown id {id}"))
        }
        crate::Error::State(se) => err_json(400, &se.to_string()),
        other => err_json(500, &other.to_string()),
    }
}

fn handle_insert(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let id = body.get("id").as_u64().ok_or_else(|| Response::bad_request("need numeric 'id'"))?;
    let vector = get_vector(&body, state)?;
    state.apply(Command::Insert { id, vector }).map_err(|e| state_error_response(&e))?;
    Metrics::inc(&state.metrics.inserts);
    Ok(ok_json(Json::object(vec![
        ("inserted", Json::Int(id as i64)),
        ("seq", Json::Int(state.with_sharded(|k| k.seq()) as i64)),
    ])))
}

fn handle_insert_batch(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let items_json = body
        .get("items")
        .as_array()
        .ok_or_else(|| Response::bad_request("need 'items' array of {id, vector}"))?;
    let mut items = Vec::with_capacity(items_json.len());
    for it in items_json {
        let id =
            it.get("id").as_u64().ok_or_else(|| Response::bad_request("item needs 'id'"))?;
        let vector = it
            .get("vector")
            .as_array()
            .ok_or_else(|| Response::bad_request("item needs 'vector'"))?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Option<Vec<f32>>>()
            .ok_or_else(|| Response::bad_request("vector must be numbers"))?;
        items.push((id, vector));
    }
    let n = items.len();
    state.apply(Command::InsertBatch { items }).map_err(|e| state_error_response(&e))?;
    Metrics::inc(&state.metrics.inserts);
    Ok(ok_json(Json::object(vec![
        ("inserted", Json::Int(n as i64)),
        ("seq", Json::Int(state.with_sharded(|k| k.seq()) as i64)),
    ])))
}

fn handle_query(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let k = body.get("k").as_u64().unwrap_or(10) as usize;
    let vector = get_vector(&body, state)?;
    let t0 = Instant::now();
    let hits = state
        .with_sharded(|kern| kern.search_f32(&vector, k))
        .map_err(|e| state_error_response(&crate::Error::State(e)))?;
    state.metrics.query_latency.record_us(t0.elapsed().as_micros() as u64);
    Metrics::inc(&state.metrics.queries);
    let hits_json: Vec<Json> = hits
        .iter()
        .map(|h| {
            Json::object(vec![
                ("id", Json::Int(h.id as i64)),
                ("dist_raw", Json::Int(h.dist_raw)),
                ("dist", Json::Float(h.dist)),
            ])
        })
        .collect();
    Ok(ok_json(Json::object(vec![("hits", Json::Array(hits_json))])))
}

fn handle_delete(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let id = body.get("id").as_u64().ok_or_else(|| Response::bad_request("need numeric 'id'"))?;
    state.apply(Command::Delete { id }).map_err(|e| state_error_response(&e))?;
    Metrics::inc(&state.metrics.deletes);
    Ok(ok_json(Json::object(vec![("deleted", Json::Int(id as i64))])))
}

fn handle_link(state: &NodeState, req: &Request, create: bool) -> RouteResult {
    let body = body_json(req)?;
    let from =
        body.get("from").as_u64().ok_or_else(|| Response::bad_request("need numeric 'from'"))?;
    let to = body.get("to").as_u64().ok_or_else(|| Response::bad_request("need numeric 'to'"))?;
    let cmd = if create { Command::Link { from, to } } else { Command::Unlink { from, to } };
    state.apply(cmd).map_err(|e| state_error_response(&e))?;
    Metrics::inc(&state.metrics.links);
    Ok(ok_json(Json::object(vec![("ok", Json::Bool(true))])))
}

fn handle_meta(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let id = body.get("id").as_u64().ok_or_else(|| Response::bad_request("need numeric 'id'"))?;
    let key = body.get("key").as_str().ok_or_else(|| Response::bad_request("need 'key'"))?;
    let value = body.get("value").as_str().ok_or_else(|| Response::bad_request("need 'value'"))?;
    state
        .apply(Command::SetMeta { id, key: key.to_string(), value: value.to_string() })
        .map_err(|e| state_error_response(&e))?;
    Ok(ok_json(Json::object(vec![("ok", Json::Bool(true))])))
}

fn handle_embed(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let texts = body
        .get("texts")
        .as_array()
        .ok_or_else(|| Response::bad_request("need 'texts' array"))?
        .iter()
        .map(|t| t.as_str())
        .collect::<Option<Vec<&str>>>()
        .ok_or_else(|| Response::bad_request("'texts' must be strings"))?;
    let embed =
        state.embedder().ok_or_else(|| err_json(503, "no embedder loaded"))?;
    let vectors = embed.embed_many(&texts).map_err(|e| err_json(500, &e.to_string()))?;
    Metrics::inc(&state.metrics.embeds);
    let arr: Vec<Json> = vectors
        .into_iter()
        .map(|v| Json::Array(v.into_iter().map(|x| Json::Float(x as f64)).collect()))
        .collect();
    Ok(ok_json(Json::object(vec![("embeddings", Json::Array(arr))])))
}

fn handle_apply(state: &NodeState, req: &Request) -> RouteResult {
    let body = body_json(req)?;
    let cmds = body
        .get("commands")
        .as_array()
        .ok_or_else(|| Response::bad_request("need 'commands' array of hex strings"))?;
    // With a "shard" field the commands are a per-shard feed and apply
    // replay-style to that shard; without it they route like fresh
    // canonical submissions. The range check runs on the raw u64 so a
    // value beyond u32 rejects instead of aliasing onto `shard % 2^32`.
    let shard = match body.get("shard").as_u64() {
        Some(s) if s >= state.n_shards() as u64 => {
            // Client misconfiguration (wrong shard count), same contract
            // as GET /v1/log: a 400, not a retryable server error.
            return Err(Response::bad_request(&format!(
                "shard {s} out of range (n_shards = {})",
                state.n_shards()
            )));
        }
        s => s.map(|s| s as u32),
    };
    let mut applied = 0;
    for c in cmds {
        let hex = c.as_str().ok_or_else(|| Response::bad_request("command must be hex string"))?;
        let bytes = hex_decode(hex).ok_or_else(|| Response::bad_request("invalid hex"))?;
        let canon = CanonCommand::from_bytes(&bytes)
            .map_err(|e| Response::bad_request(&format!("bad command: {e}")))?;
        match shard {
            Some(s) => {
                state.apply_canon_to_shard(s, &canon).map_err(|e| state_error_response(&e))?
            }
            None => state.apply_canon(&canon).map_err(|e| state_error_response(&e))?,
        }
        applied += 1;
    }
    Ok(ok_json(Json::object(vec![
        ("applied", Json::Int(applied)),
        ("seq", Json::Int(state.with_sharded(|k| k.seq()) as i64)),
        ("hash", Json::str(state.hash_hex())),
    ])))
}

// Note: the per-shard `fnv` entries re-encode each shard's full state
// (same cost class as /v1/hash, which always worked this way); a cached
// state hash invalidated on apply is a ROADMAP follow-on for nodes that
// poll stats at high frequency.
fn handle_stats(state: &NodeState) -> Response {
    ok_json(stats_json(state))
}

/// The stats payload (shared by `/v1/stats` and the per-collection
/// `/v2/collections/{name}/stats`, which adds collection fields on top).
pub(crate) fn stats_json(state: &NodeState) -> Json {
    let (len, seq, dim, n_shards, per_shard) = state.with_sharded(|sk| {
        let per: Vec<Json> = sk
            .shards()
            .iter()
            .enumerate()
            .map(|(s, k)| {
                Json::object(vec![
                    ("shard", Json::Int(s as i64)),
                    ("vectors", Json::Int(k.len() as i64)),
                    ("seq", Json::Int(k.seq() as i64)),
                    ("fnv", Json::str(format!("{:016x}", k.state_hash()))),
                    ("merkle", Json::str(crate::hash::hex_lower(&k.merkle_root()))),
                ])
            })
            .collect();
        (sk.len(), sk.seq(), sk.config().dim, sk.n_shards(), per)
    });
    let mut obj = match state.metrics.to_json() {
        Json::Object(o) => o,
        _ => unreachable!(),
    };
    obj.insert("vectors".into(), Json::Int(len as i64));
    obj.insert("seq".into(), Json::Int(seq as i64));
    obj.insert("dim".into(), Json::Int(dim as i64));
    obj.insert("log_len".into(), Json::Int(state.log_len() as i64));
    obj.insert("n_shards".into(), Json::Int(n_shards as i64));
    obj.insert("shards".into(), Json::Array(per_shard));
    if let Some(b) = state.embedder() {
        let (batches, requests) = b.counters();
        obj.insert("batches".into(), Json::Int(batches as i64));
        obj.insert("batched_requests".into(), Json::Int(requests as i64));
    }
    Json::Object(obj)
}

fn handle_hash(state: &NodeState) -> Response {
    // Single-shard nodes keep the seed wire shape (fnv/sha256 of the one
    // kernel); sharded nodes report the root plus the per-shard manifest
    // so peers can verify convergence shard-by-shard.
    state.with_sharded(|sk| {
        if sk.n_shards() == 1 {
            let snap = Snapshot::capture(sk.shard(0));
            ok_json(Json::object(vec![
                ("fnv", Json::str(format!("{:016x}", snap.fnv))),
                ("sha256", Json::str(snap.sha256_hex())),
                ("seq", Json::Int(sk.seq() as i64)),
                ("root", Json::str(format!("{:016x}", sk.root_hash()))),
                ("merkle_root", Json::str(crate::hash::hex_lower(&sk.merkle_root()))),
            ]))
        } else {
            let snap = crate::snapshot::ShardedSnapshot::capture(sk);
            let merkle_roots = sk.merkle_shard_roots();
            let shards: Vec<Json> = snap
                .manifest()
                .iter()
                .zip(&merkle_roots)
                .map(|(m, root)| {
                    Json::object(vec![
                        ("shard", Json::Int(m.shard as i64)),
                        ("fnv", Json::str(format!("{:016x}", m.fnv))),
                        ("sha256", Json::str(crate::hash::sha256_hex(&m.sha256))),
                        ("merkle", Json::str(crate::hash::hex_lower(root))),
                    ])
                })
                .collect();
            ok_json(Json::object(vec![
                ("fnv", Json::str(format!("{:016x}", snap.root_hash()))),
                ("root", Json::str(format!("{:016x}", snap.root_hash()))),
                ("seq", Json::Int(sk.seq() as i64)),
                ("merkle_root", Json::str(crate::hash::hex_lower(&sk.merkle_root()))),
                ("shards", Json::Array(shards)),
            ]))
        }
    })
}

fn handle_log(state: &NodeState, req: &Request) -> Response {
    let query_param = |name: &str| {
        req.query.as_deref().and_then(|q| {
            q.split('&').find_map(|kv| {
                kv.strip_prefix(name)
                    .and_then(|v| v.strip_prefix('='))
                    .and_then(|v| v.parse::<usize>().ok())
            })
        })
    };
    let from = query_param("from").unwrap_or(0);
    // Range-check before narrowing so a shard beyond u32 rejects rather
    // than aliasing onto `shard % 2^32`.
    let shard = query_param("shard").unwrap_or(0);
    if shard >= state.n_shards() as usize {
        // An empty 200 here would read as "fully caught up" to a sync
        // driver configured with the wrong shard count.
        return err_json(
            400,
            &format!("shard {shard} out of range (n_shards = {})", state.n_shards()),
        );
    }
    let shard = shard as u32;
    let cmds = state.log_slice_shard(shard, from, 1000);
    let arr: Vec<Json> =
        cmds.iter().map(|c| Json::str(hex_encode(&c.to_bytes()))).collect();
    ok_json(Json::object(vec![
        ("from", Json::Int(from as i64)),
        ("shard", Json::Int(shard as i64)),
        ("n_shards", Json::Int(state.n_shards() as i64)),
        ("total", Json::Int(state.shard_log_len(shard) as i64)),
        ("commands", Json::Array(arr)),
    ]))
}

/// Lower-case hex encoding (command wire format for replication).
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Hex decoding; None on malformed input.
pub fn hex_decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len() / 2)
        .map(|i| u8::from_str_radix(s.get(i * 2..i * 2 + 2)?, 16).ok())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::KernelConfig;

    fn test_state() -> Arc<NodeState> {
        let kernel = Kernel::new(KernelConfig::default_q16(4));
        Arc::new(NodeState::new(kernel, &NodeConfig::default(), None).unwrap())
    }

    fn post(state: &NodeState, path: &str, body: &str) -> (u16, Json) {
        let req = Request {
            method: "POST".into(),
            path: path.into(),
            query: None,
            headers: Default::default(),
            body: body.as_bytes().to_vec(),
        };
        let resp = route(state, req);
        let json = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap_or(Json::Null);
        (resp.status, json)
    }

    fn get(state: &NodeState, path: &str, query: Option<&str>) -> (u16, Json) {
        let req = Request {
            method: "GET".into(),
            path: path.into(),
            query: query.map(|s| s.to_string()),
            headers: Default::default(),
            body: vec![],
        };
        let resp = route(state, req);
        let json = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap_or(Json::Null);
        (resp.status, json)
    }

    #[test]
    fn insert_then_query() {
        let s = test_state();
        let (st, _) = post(&s, "/v1/insert", r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#);
        assert_eq!(st, 200);
        let (st, _) = post(&s, "/v1/insert", r#"{"id":2,"vector":[0.9,0.9,0.9,0.9]}"#);
        assert_eq!(st, 200);
        let (st, body) = post(&s, "/v1/query", r#"{"vector":[0.1,0.2,0.3,0.4],"k":2}"#);
        assert_eq!(st, 200);
        let hits = body.get("hits").as_array().unwrap();
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].get("id").as_u64(), Some(1));
        assert_eq!(hits[0].get("dist_raw").as_i64(), Some(0));
    }

    #[test]
    fn duplicate_insert_conflicts() {
        let s = test_state();
        post(&s, "/v1/insert", r#"{"id":1,"vector":[0,0,0,0]}"#);
        let (st, body) = post(&s, "/v1/insert", r#"{"id":1,"vector":[0,0,0,0]}"#);
        assert_eq!(st, 409);
        assert!(body.get("error").as_str().unwrap().contains("duplicate"));
    }

    #[test]
    fn delete_unknown_is_404() {
        let s = test_state();
        let (st, _) = post(&s, "/v1/delete", r#"{"id":99}"#);
        assert_eq!(st, 404);
    }

    #[test]
    fn link_and_meta_flow() {
        let s = test_state();
        post(&s, "/v1/insert", r#"{"id":1,"vector":[0,0,0,0]}"#);
        post(&s, "/v1/insert", r#"{"id":2,"vector":[1,0,0,0]}"#);
        let (st, _) = post(&s, "/v1/link", r#"{"from":1,"to":2}"#);
        assert_eq!(st, 200);
        let (st, _) = post(&s, "/v1/meta", r#"{"id":1,"key":"src","value":"api"}"#);
        assert_eq!(st, 200);
        assert!(s.with_kernel(|k| k.links().has_link(1, 2)));
        let (st, _) = post(&s, "/v1/unlink", r#"{"from":1,"to":2}"#);
        assert_eq!(st, 200);
        assert!(!s.with_kernel(|k| k.links().has_link(1, 2)));
    }

    #[test]
    fn bad_json_is_400() {
        let s = test_state();
        let (st, _) = post(&s, "/v1/insert", "{nope");
        assert_eq!(st, 400);
        let (st, _) = post(&s, "/v1/insert", r#"{"vector":[0,0,0,0]}"#); // no id
        assert_eq!(st, 400);
        let (st, _) = post(&s, "/v1/query", r#"{"k":3}"#); // no vector/text
        assert_eq!(st, 400);
    }

    #[test]
    fn text_without_embedder_is_503() {
        let s = test_state();
        let (st, _) = post(&s, "/v1/insert", r#"{"id":1,"text":"hello"}"#);
        assert_eq!(st, 503);
        let (st, _) = post(&s, "/v1/embed", r#"{"texts":["x"]}"#);
        assert_eq!(st, 503);
    }

    #[test]
    fn stats_and_hash() {
        let s = test_state();
        post(&s, "/v1/insert", r#"{"id":1,"vector":[0.5,0,0,0]}"#);
        let (st, stats) = get(&s, "/v1/stats", None);
        assert_eq!(st, 200);
        assert_eq!(stats.get("vectors").as_i64(), Some(1));
        assert_eq!(stats.get("inserts").as_i64(), Some(1));
        let (st, hash) = get(&s, "/v1/hash", None);
        assert_eq!(st, 200);
        assert_eq!(hash.get("fnv").as_str().unwrap().len(), 16);
        assert_eq!(hash.get("sha256").as_str().unwrap().len(), 64);
    }

    #[test]
    fn log_feed_and_apply_replicate() {
        let primary = test_state();
        post(&primary, "/v1/insert", r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#);
        post(&primary, "/v1/insert", r#"{"id":2,"vector":[0.5,0.6,0.7,0.8]}"#);
        post(&primary, "/v1/link", r#"{"from":1,"to":2}"#);

        let (st, feed) = get(&primary, "/v1/log", Some("from=0"));
        assert_eq!(st, 200);
        let cmds = feed.get("commands").as_array().unwrap();
        assert_eq!(cmds.len(), 3);

        // ship to a follower via /v1/apply
        let follower = test_state();
        let body = Json::object(vec![(
            "commands",
            Json::Array(cmds.to_vec()),
        )]);
        let (st, result) = post(&follower, "/v1/apply", &body.to_string());
        assert_eq!(st, 200);
        assert_eq!(result.get("applied").as_i64(), Some(3));

        // paper §9: identical state hashes after processing the same log
        let h_a = primary.with_kernel(|k| k.state_hash());
        let h_b = follower.with_kernel(|k| k.state_hash());
        assert_eq!(h_a, h_b);
    }

    #[test]
    fn hex_roundtrip() {
        let data = vec![0x00, 0xff, 0x12, 0xab];
        assert_eq!(hex_decode(&hex_encode(&data)), Some(data));
        assert_eq!(hex_decode("zz"), None);
        assert_eq!(hex_decode("abc"), None);
        assert_eq!(hex_decode(""), Some(vec![]));
    }

    #[test]
    fn over_http_end_to_end() {
        let s = test_state();
        let server = serve(Arc::clone(&s), "127.0.0.1:0", 2).unwrap();
        let addr = server.addr();
        let body = parse(r#"{"id":5,"vector":[0.1,0.1,0.1,0.1]}"#).unwrap();
        let (st, _) = crate::http::client::post_json(&addr, "/v1/insert", &body).unwrap();
        assert_eq!(st, 200);
        let q = parse(r#"{"vector":[0.1,0.1,0.1,0.1],"k":1}"#).unwrap();
        let (st, resp) = crate::http::client::post_json(&addr, "/v1/query", &q).unwrap();
        assert_eq!(st, 200);
        assert_eq!(resp.get("hits").as_array().unwrap()[0].get("id").as_u64(), Some(5));
        server.stop();
    }
}
