//! Multi-tenant collections: N independent [`NodeState`]s behind one
//! HTTP front end (the `/v2` surface).
//!
//! A **collection** is a named, fully independent deterministic memory:
//! its own sharded kernel, its own per-shard canonical logs and WALs
//! (under `<data>/<name>/` when a data directory is configured), its own
//! config and root hash. Nothing is shared between collections except
//! the process, the HTTP front end and (optionally) the embedder — so
//! each tenant's memory is its own replayable state machine, exactly as
//! replayable and hash-verifiable as a single-tenant node (paper §3.1,
//! applied per tenant).
//!
//! ## Determinism across tenants
//!
//! - Per-collection state is a pure function of that collection's own
//!   command sequence: interleaving traffic to other collections cannot
//!   perturb a collection's root hash (proved by
//!   `tests/collections.rs`).
//! - The **combined root** (`GET /v2/hash`) folds per-collection roots
//!   in lexicographic name order:
//!   `fnv(count ‖ (len(name) ‖ name ‖ root)*)` — a pure function of the
//!   name→root map, invariant under creation order.
//!
//! ## Legacy surface
//!
//! `/v1/*` requests are thin adapters onto the reserved `default`
//! collection: they are delegated verbatim to [`super::route`], so the
//! bytes on the wire are identical to a pre-collections node and every
//! existing /v1 client (the replication driver included) keeps working.

#![forbid(unsafe_code)]

use crate::api::{
    body_json, execute, hash_manifest, log_feed, ok_response, root_hex, ApiCode, ApiError,
    ApiRequest, ApiResult,
};
use crate::hash::Fnv1a64;
use crate::http::{
    AdmissionHook, Handler, Request, Response, Server, ServerConfig, ServerMetrics, StreamingBody,
};
use crate::index::QuantSpec;
use crate::json::Json;
use crate::node::governor::{Admission, Governor, GovernorConfig};
use crate::node::{hex_decode, hex_encode, route, stats_json, BatcherHandle, NodeConfig, NodeState};
use crate::proof::Receipt;
use crate::snapshot::{
    FrameSource, ShardedSnapshot, Snapshot, SnapshotReader, SnapshotWriter, StreamError,
    StreamManifestEntry, StreamSpec,
};
use crate::state::{IndexKind, Kernel, KernelConfig, ShardedKernel};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::{Duration, Instant};

/// The collection every deployment has: it backs the `/v1` adapter and
/// cannot be deleted.
pub const DEFAULT_COLLECTION: &str = "default";

/// Base-state file for a collection installed via snapshot restore
/// (`<data>/<name>/restored.snap`): rediscovery restores it first, then
/// replays the (post-restore) WALs on top.
const RESTORED_SNAP: &str = "restored.snap";

/// Default / floor / ceiling for the `?chunk=` parameter of
/// `GET /v2/collections/{name}/snapshot`. The ceiling keeps one *framed*
/// chunk (payload + 16 B of framing) within the front end's `MAX_BODY`,
/// so a forwarder can always ship whole chunks one restore PUT each;
/// the floor keeps framing overhead under 2%.
const SNAPSHOT_CHUNK_DEFAULT: usize = crate::snapshot::DEFAULT_CHUNK;
const SNAPSHOT_CHUNK_MIN: usize = 1024;
const SNAPSHOT_CHUNK_MAX: usize = crate::http::MAX_BODY - 16;

/// Per-collection kernel shape (the PUT body can override any field).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollectionSpec {
    /// Vector dimensionality.
    pub dim: usize,
    /// Shard count (fixed at creation, like a standalone node's).
    pub shards: u32,
    /// Exact flat index instead of HNSW.
    pub flat: bool,
    /// Quantized scan tier for the flat index (`none` | `sq8`). Ignored
    /// by HNSW collections. The i8 codes are derived state (never
    /// serialized), so query results are bit-identical to an
    /// unquantized twin; the spec itself is config, though, and like
    /// `index` or `shards` it participates in the state root.
    pub quant: QuantSpec,
    /// Arena-byte budget for client inserts (0 = unlimited). Enforced at
    /// the /v2 boundary as 1602 `memory_quota_exceeded`; runtime
    /// governance, not state — never encoded, never hashed.
    pub memory_quota: u64,
    /// Scan-pool worker override for this collection (0 = one worker per
    /// core). Read-path tuning only: results and roots are unchanged by
    /// construction, so — unlike `index` or `quant` — it is excluded
    /// from the state bytes and the root.
    pub scan_workers: u32,
}

impl CollectionSpec {
    /// Spec with the given shape and every tuning field (quota, scan
    /// workers) at its default.
    pub fn new(dim: usize, shards: u32, flat: bool, quant: QuantSpec) -> Self {
        CollectionSpec { dim, shards, flat, quant, memory_quota: 0, scan_workers: 0 }
    }

    fn kernel_config(&self) -> KernelConfig {
        let mut config = KernelConfig::default_q16(self.dim).with_quant(self.quant);
        config.scan.workers = self.scan_workers;
        if self.flat {
            config.with_flat_index()
        } else {
            config
        }
    }
}

/// Manager-level configuration.
#[derive(Debug, Clone)]
pub struct ManagerConfig {
    /// Default spec for collections created without explicit overrides
    /// (and for the `default` collection itself).
    pub spec: CollectionSpec,
    /// HTTP worker threads (shared front end).
    pub workers: usize,
    /// Durable root: collection `c`'s WAL base is `<data>/<c>/wal`
    /// (per-shard files via [`super::shard_wal_path`]). `None` = every
    /// collection is in-memory.
    pub data_dir: Option<PathBuf>,
    /// Legacy `--wal` path: used verbatim as the `default` collection's
    /// WAL base so pre-collections deployments recover their data
    /// byte-for-byte. Takes precedence over `data_dir` for `default`.
    pub default_wal: Option<PathBuf>,
    /// Per-tenant governance knobs (rate limits, quotas, bulkheads,
    /// idle TTL, stream budgets). All-`None` (the default) disables
    /// governance entirely: no admission hook, no sweeper, no per-request
    /// bookkeeping.
    pub governor: GovernorConfig,
}

/// N independent collections behind one front end. Cheap to share
/// (`Arc`); collection CRUD takes the map's write lock, request routing
/// only the read lock plus the target collection's own locks.
pub struct CollectionManager {
    config: ManagerConfig,
    embed: Option<BatcherHandle>,
    collections: RwLock<BTreeMap<String, Arc<NodeState>>>,
    /// Serializes collection create/drop against each other *without*
    /// holding the `collections` lock: building a `NodeState` can replay
    /// a large WAL, and doing that under the map's write lock would
    /// stall request routing on every tenant for the duration. Lock
    /// order: `create_lock` first, then `collections` — never nested the
    /// other way.
    create_lock: Mutex<()>,
    /// One front-end metrics sink shared by every collection's
    /// `/v1/stats`-style gauges (connections belong to the server, not
    /// to a tenant).
    http_metrics: Arc<ServerMetrics>,
    /// Which front end serves this manager ("epoll"/"blocking"); set by
    /// [`serve_collections`] once the server has chosen.
    backend: OnceLock<&'static str>,
    /// In-progress snapshot-restore sessions keyed by target collection
    /// name (see [`Self::restore_ingest`]): each holds a resumable
    /// [`SnapshotReader`] fed by successive `PUT …/restore` bodies, so a
    /// whole-deployment transfer never has to fit one HTTP body.
    restores: Mutex<BTreeMap<String, RestoreSession>>,
    /// Front-end-local admission controller (tentpole of ISSUE 6): token
    /// buckets, in-flight caps, idle tracking, stream budgets. Decisions
    /// happen before dispatch and are never logged or hashed, so a
    /// throttled-and-retried workload replays to the same root as an
    /// unthrottled one.
    governor: Arc<Governor>,
    /// Collections evicted by the idle sweep, with their root hash at
    /// eviction time. The cached root keeps `/v2/hash` (and `names`/
    /// `len`) stable while a tenant is cold; the entry is cleared when
    /// the tenant is rehydrated (lazily, on next touch) or dropped.
    evicted: Mutex<BTreeMap<String, u64>>,
}

/// One resumable restore in progress.
struct RestoreSession {
    reader: SnapshotReader,
    /// Last time a window landed — sessions idle past
    /// [`RESTORE_SESSION_TTL`] are evicted (abandoned transfers must
    /// not pin reassembled frames forever).
    last_fed: std::time::Instant,
}

/// Bound on concurrent restore sessions (each can hold up to a full
/// deployment's reassembled frames) — beyond it, offset-0 PUTs answer
/// `restore_busy` (503) instead of letting a client walk the node into
/// an OOM one abandoned session at a time.
const MAX_RESTORE_SESSIONS: usize = 16;

/// Idle TTL for restore sessions.
const RESTORE_SESSION_TTL: std::time::Duration = std::time::Duration::from_secs(600);

fn validate_collection_name(name: &str) -> ApiResult<()> {
    let ok = !name.is_empty()
        && name.len() <= 64
        && name
            .bytes()
            .all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'-' || b == b'_');
    if ok {
        Ok(())
    } else {
        Err(ApiError::new(
            ApiCode::InvalidCollectionName,
            format!("invalid collection name '{name}' (want [a-z0-9_-]{{1,64}})"),
        ))
    }
}

impl CollectionManager {
    /// Build a manager, create the `default` collection (recovering its
    /// WAL if one exists at the configured location), then **rediscover
    /// durable collections**: every `<data>/<name>/spec.json` written by
    /// a previous run is re-created with its persisted spec, replaying
    /// its per-shard WALs — restart durability for dynamically created
    /// tenants, not just `default`.
    pub fn new(config: ManagerConfig, embed: Option<BatcherHandle>) -> crate::Result<Self> {
        let http_metrics = Arc::new(ServerMetrics::default());
        let governor =
            Arc::new(Governor::new(config.governor.clone(), Arc::clone(&http_metrics)));
        let manager = Self {
            config,
            embed,
            collections: RwLock::new(BTreeMap::new()),
            create_lock: Mutex::new(()),
            http_metrics,
            backend: OnceLock::new(),
            restores: Mutex::new(BTreeMap::new()),
            governor,
            evicted: Mutex::new(BTreeMap::new()),
        };
        let spec = manager.config.spec.clone();
        manager.create(DEFAULT_COLLECTION, spec).map_err(|e| {
            crate::Error::Runtime(format!("create default collection: {}", e.message))
        })?;
        manager.rediscover_durable()?;
        Ok(manager)
    }

    /// Scan the data dir for previously created collections (identified
    /// by their persisted `spec.json`) and re-create each one. Names are
    /// taken in sorted order so recovery is deterministic; a directory
    /// without a readable spec is a hard error — silently skipping it
    /// would present a durable tenant as empty.
    fn rediscover_durable(&self) -> crate::Result<()> {
        let Some(dir) = &self.config.data_dir else { return Ok(()) };
        if !dir.exists() {
            return Ok(());
        }
        let mut names: Vec<String> = Vec::new();
        for entry in std::fs::read_dir(dir).map_err(crate::Error::Io)? {
            let entry = entry.map_err(crate::Error::Io)?;
            if entry.path().join("spec.json").exists() {
                if let Ok(name) = entry.file_name().into_string() {
                    names.push(name);
                }
            }
        }
        names.sort();
        for name in names {
            {
                let collections = self.collections.read().expect("collections poisoned");
                if collections.contains_key(&name) {
                    continue; // `default` (or a pre-created tenant)
                }
            }
            let path = dir.join(&name).join("spec.json");
            let bytes = std::fs::read(&path).map_err(crate::Error::Io)?;
            let spec = parse_spec(&bytes, &self.config.spec).map_err(|e| {
                crate::Error::Runtime(format!("collection '{name}': bad {path:?}: {}", e.message))
            })?;
            self.create(&name, spec).map_err(|e| {
                crate::Error::Runtime(format!("recover collection '{name}': {}", e.message))
            })?;
        }
        Ok(())
    }

    /// The default spec new collections inherit.
    pub fn default_spec(&self) -> &CollectionSpec {
        &self.config.spec
    }

    /// Storage locations for a collection: `(WAL base, durable dir)`.
    /// The durable dir (when a data dir is configured) holds the
    /// per-shard WALs and the persisted `spec.json`; the legacy
    /// `default_wal` override keeps `default` on its pre-collections
    /// path with no spec manifest.
    fn storage_paths(&self, name: &str) -> ApiResult<(Option<PathBuf>, Option<PathBuf>)> {
        if name == DEFAULT_COLLECTION {
            if let Some(w) = &self.config.default_wal {
                return Ok((Some(w.clone()), None));
            }
        }
        match &self.config.data_dir {
            Some(dir) => {
                let d = dir.join(name);
                std::fs::create_dir_all(&d).map_err(|e| {
                    ApiError::new(ApiCode::Internal, format!("create {d:?}: {e}"))
                })?;
                Ok((Some(d.join("wal")), Some(d)))
            }
            None => Ok((None, None)),
        }
    }

    /// Create a collection. Fails with `collection_exists` if the name
    /// is taken; recovers per-shard WALs when a data dir is configured
    /// and files already exist, and persists the spec as
    /// `<data>/<name>/spec.json` so the tenant survives restarts with
    /// the exact shape it was created with.
    ///
    /// Creates are serialized on `create_lock`; the `collections` map is
    /// write-locked only for the final insert, so a slow WAL replay
    /// never stalls routing to other tenants.
    pub fn create(&self, name: &str, spec: CollectionSpec) -> ApiResult<Arc<NodeState>> {
        validate_collection_name(name)?;
        if spec.dim == 0 {
            return Err(ApiError::bad_request("dim must be > 0"));
        }
        if spec.shards == 0 {
            return Err(ApiError::bad_request("shards must be >= 1"));
        }
        let _creating = self.create_lock.lock().expect("create lock poisoned");
        {
            let collections = self.collections.read().expect("collections poisoned");
            if collections.contains_key(name) {
                return Err(ApiError::new(
                    ApiCode::CollectionExists,
                    format!("collection '{name}' already exists"),
                ));
            }
        }
        let (wal_path, durable_dir) = self.storage_paths(name)?;
        let node_config = NodeConfig {
            workers: self.config.workers,
            wal_path,
            memory_quota: spec.memory_quota,
        };
        // A collection installed by snapshot restore persists its base
        // state as `<dir>/restored.snap` (its WALs only hold mutations
        // applied *after* the restore). Rediscovery must start from that
        // base, or WAL replay would rebuild a fraction of the state.
        let mut kernel = match &durable_dir {
            Some(d) if d.join(RESTORED_SNAP).exists() => {
                let path = d.join(RESTORED_SNAP);
                let snap = ShardedSnapshot::read_file(&path).map_err(|e| {
                    ApiError::new(ApiCode::Internal, format!("read {path:?}: {e}"))
                })?;
                let kernel = snap.restore().map_err(|e| {
                    ApiError::new(ApiCode::Internal, format!("restore {path:?}: {e}"))
                })?;
                if kernel.n_shards() != spec.shards || kernel.config().dim != spec.dim {
                    return Err(ApiError::new(
                        ApiCode::Internal,
                        format!(
                            "collection '{name}': {RESTORED_SNAP} shape ({} shards, dim {}) \
                             disagrees with spec ({} shards, dim {})",
                            kernel.n_shards(),
                            kernel.config().dim,
                            spec.shards,
                            spec.dim
                        ),
                    ));
                }
                kernel
            }
            _ => ShardedKernel::new(spec.kernel_config(), spec.shards),
        };
        // Restored snapshots carry the encoded config, which never
        // includes scan tuning; apply the spec's override on every path.
        kernel.set_scan_workers(spec.scan_workers);
        let mut state = NodeState::new_sharded(kernel, &node_config, self.embed.clone())
            .map_err(|e| {
                ApiError::new(ApiCode::Internal, format!("collection '{name}': {e}"))
            })?;
        // Every collection reports the one shared front end's gauges.
        state.metrics.http = Arc::clone(&self.http_metrics);
        if let Some(d) = &durable_dir {
            // Persist the spec — rediscovery must recreate this exact
            // shape or WAL replay would reject every record.
            let path = d.join("spec.json");
            std::fs::write(&path, spec_json(&spec)).map_err(|e| {
                ApiError::new(ApiCode::Internal, format!("write {path:?}: {e}"))
            })?;
        }
        let state = Arc::new(state);
        self.collections
            .write()
            .expect("collections poisoned")
            .insert(name.to_string(), Arc::clone(&state));
        // The tenant is live again: clear any eviction cache entry (its
        // WALs were just replayed) and mark it touched so the sweeper's
        // idle clock starts now.
        self.evicted.lock().expect("evicted poisoned").remove(name);
        if self.governor.config().is_active() {
            self.governor.touch(name, Instant::now());
        }
        // A dangling restore session for this name is now moot.
        if self.restores.lock().expect("restores poisoned").remove(name).is_some() {
            self.http_metrics.streams_in_flight.fetch_sub(1, Ordering::Relaxed);
        }
        Ok(state)
    }

    /// Create-if-missing with the default spec.
    pub fn ensure(&self, name: &str) -> ApiResult<Arc<NodeState>> {
        {
            let collections = self.collections.read().expect("collections poisoned");
            if let Some(state) = collections.get(name) {
                return Ok(Arc::clone(state));
            }
        }
        match self.create(name, self.config.spec.clone()) {
            // Raced another creator: theirs wins.
            Err(e) if e.code == ApiCode::CollectionExists => self.get(name),
            other => other,
        }
    }

    /// Whether `name` is currently cold (evicted by the idle sweep and
    /// not yet rehydrated). Read *before* a [`Self::get`] when the
    /// caller wants to report that its own request found the tenant
    /// cold — `get` rehydrates lazily, so afterwards this is false.
    pub fn is_evicted(&self, name: &str) -> bool {
        self.evicted.lock().expect("evicted poisoned").contains_key(name)
    }

    /// Look up a collection. A tenant evicted by the idle sweep is
    /// **rehydrated lazily** here: its persisted `spec.json` is re-read
    /// and [`Self::create`] replays `restored.snap` + WALs — the same
    /// restart-rediscovery path that already proves rehydration
    /// preserves the root hash.
    pub fn get(&self, name: &str) -> ApiResult<Arc<NodeState>> {
        {
            let collections = self.collections.read().expect("collections poisoned");
            if let Some(state) = collections.get(name) {
                if self.governor.config().is_active() {
                    self.governor.touch(name, Instant::now());
                }
                return Ok(Arc::clone(state));
            }
        }
        if self.evicted.lock().expect("evicted poisoned").contains_key(name) {
            return self.rehydrate(name);
        }
        Err(ApiError::new(ApiCode::UnknownCollection, format!("unknown collection '{name}'")))
    }

    /// Bring an evicted tenant back: re-read its persisted spec and run
    /// it through [`Self::create`] (which replays `restored.snap` + the
    /// per-shard WALs and clears the eviction cache entry).
    fn rehydrate(&self, name: &str) -> ApiResult<Arc<NodeState>> {
        let Some(dir) = &self.config.data_dir else {
            // Unreachable in practice: only durable tenants are evicted.
            return Err(ApiError::new(
                ApiCode::Internal,
                format!("collection '{name}' evicted without a data dir"),
            ));
        };
        let path = dir.join(name).join("spec.json");
        let bytes = std::fs::read(&path).map_err(|e| {
            ApiError::new(ApiCode::Internal, format!("rehydrate '{name}': read {path:?}: {e}"))
        })?;
        let spec = parse_spec(&bytes, &self.config.spec).map_err(|e| {
            ApiError::new(ApiCode::Internal, format!("rehydrate '{name}': bad spec: {}", e.message))
        })?;
        match self.create(name, spec) {
            Ok(state) => {
                ServerMetrics::add(&self.http_metrics.collections_rehydrated, 1);
                Ok(state)
            }
            // Raced another rehydrator (or an explicit re-create): theirs
            // won and the tenant is live.
            Err(e) if e.code == ApiCode::CollectionExists => self.get(name),
            Err(e) => Err(e),
        }
    }

    /// Drop a collection (its WAL directory too, when durable). The
    /// `default` collection is reserved — it backs the /v1 adapter.
    pub fn drop_collection(&self, name: &str) -> ApiResult<()> {
        if name == DEFAULT_COLLECTION {
            return Err(ApiError::new(
                ApiCode::ReservedCollection,
                "the 'default' collection backs the /v1 adapter and cannot be deleted",
            ));
        }
        // Same serialization as create: a drop racing a create of the
        // same name must not leave a half-registered tenant behind.
        let _creating = self.create_lock.lock().expect("create lock poisoned");
        let mut collections = self.collections.write().expect("collections poisoned");
        let was_live = collections.remove(name).is_some();
        drop(collections);
        // An evicted tenant can be dropped without rehydrating it first —
        // its cached root and on-disk directory just go away.
        let was_evicted = self.evicted.lock().expect("evicted poisoned").remove(name).is_some();
        if !was_live && !was_evicted {
            return Err(ApiError::new(
                ApiCode::UnknownCollection,
                format!("unknown collection '{name}'"),
            ));
        }
        if let Some(dir) = &self.config.data_dir {
            // Best-effort: open WAL handles keep writing into unlinked
            // files until the last Arc drops, which is fine on Linux.
            let _ = std::fs::remove_dir_all(dir.join(name));
        }
        Ok(())
    }

    /// Collection names, lexicographic (the `BTreeMap` order — also the
    /// combined-root fold order). Evicted-but-durable tenants count: they
    /// are still part of the deployment, just cold.
    pub fn names(&self) -> Vec<String> {
        let mut names: BTreeMap<String, ()> = self
            .collections
            .read()
            .expect("collections poisoned")
            .keys()
            .map(|n| (n.clone(), ()))
            .collect();
        for name in self.evicted.lock().expect("evicted poisoned").keys() {
            names.entry(name.clone()).or_insert(());
        }
        names.into_keys().collect()
    }

    /// Number of collections (live + evicted).
    pub fn len(&self) -> usize {
        self.names().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-collection roots in lexicographic name order (the one place
    /// the roots are computed for both the fold and the wire payload).
    /// Evicted tenants contribute their root cached at eviction time —
    /// nothing mutated them while cold (mutations rehydrate first), so
    /// `/v2/hash` is invariant across evict→rehydrate round trips.
    fn collection_roots(&self) -> Vec<(String, u64)> {
        let mut roots: BTreeMap<String, u64> =
            self.evicted.lock().expect("evicted poisoned").clone();
        let collections = self.collections.read().expect("collections poisoned");
        for (name, state) in collections.iter() {
            roots.insert(name.clone(), state.with_sharded(|sk| sk.root_hash()));
        }
        roots.into_iter().collect()
    }

    /// Deterministic combined root over all collections, folded in
    /// lexicographic name order: a pure function of the name→root map,
    /// so two deployments holding the same collections with the same
    /// contents agree regardless of creation order.
    pub fn combined_root(&self) -> u64 {
        fold_combined_root(&self.collection_roots())
    }

    /// `GET /v2/hash` payload: combined root + per-collection roots
    /// (same fold as [`Self::combined_root`], by construction — both
    /// run over [`Self::collection_roots`]).
    pub fn combined_hash_json(&self) -> Json {
        let roots = self.collection_roots();
        let per: Vec<Json> = roots
            .iter()
            .map(|(name, root)| {
                Json::object(vec![
                    ("name", Json::str(name.clone())),
                    ("root", Json::str(format!("{root:016x}"))),
                ])
            })
            .collect();
        Json::object(vec![
            ("collections", Json::Array(per)),
            ("count", Json::Int(roots.len() as i64)),
            ("root", Json::str(format!("{:016x}", fold_combined_root(&roots)))),
        ])
    }

    /// `GET /v2/collections` payload. Listing reports live kernel detail
    /// (seq, log_len, vectors), so evicted tenants are rehydrated first —
    /// a list is an explicit touch of every tenant.
    pub fn list_json(&self) -> Json {
        let cold: Vec<String> =
            self.evicted.lock().expect("evicted poisoned").keys().cloned().collect();
        for name in cold {
            let _ = self.get(&name); // rehydrates; errors surface on direct access
        }
        let collections = self.collections.read().expect("collections poisoned");
        let per: Vec<Json> = collections
            .iter()
            .map(|(name, state)| collection_summary(name, state))
            .collect();
        Json::object(vec![
            ("collections", Json::Array(per)),
            ("count", Json::Int(collections.len() as i64)),
        ])
    }

    /// Which front end serves this manager ("unknown" until serving).
    pub fn backend_name(&self) -> &'static str {
        self.backend.get().copied().unwrap_or("unknown")
    }

    /// The admission controller (exposed for tests and the CLI).
    pub fn governor(&self) -> &Arc<Governor> {
        &self.governor
    }

    /// One pass of the idle sweep: reap abandoned restore sessions, evict
    /// durable tenants idle past the configured TTL, prune governor
    /// bookkeeping. `now` is a parameter so tests can drive time.
    ///
    /// Eviction closes a tenant's WALs and drops its worker pool by
    /// removing the `NodeState` from the map (the WAL files close when
    /// the last `Arc` drops — after any in-flight request finishes). The
    /// root hash is cached so `/v2/hash` stays stable while the tenant
    /// is cold; the next touch rehydrates from `spec.json` +
    /// `restored.snap` + WAL replay (see [`Self::get`]).
    pub fn sweep_idle(&self, now: Instant) {
        self.reap_restores(now);
        if let (Some(ttl), Some(_)) = (self.governor.config().idle_ttl, &self.config.data_dir) {
            let candidates: Vec<String> = {
                let collections = self.collections.read().expect("collections poisoned");
                collections
                    .keys()
                    // `default` backs the /v1 adapter and is never
                    // evicted (it may not even have a spec.json when it
                    // lives on a legacy --wal path).
                    .filter(|n| n.as_str() != DEFAULT_COLLECTION)
                    .cloned()
                    .collect()
            };
            for name in candidates {
                match self.governor.idle_for(&name, now) {
                    Some(idle) if idle > ttl => {
                        self.evict(&name, ttl, now);
                    }
                    Some(_) => {}
                    // Never touched (e.g. rediscovered before governance
                    // saw traffic): start its idle clock now.
                    None => self.governor.touch(&name, now),
                }
            }
        }
        self.governor.prune(now);
    }

    /// Evict one idle tenant. Serialized on `create_lock` against
    /// create/drop/rehydrate; idleness is re-checked under the lock so a
    /// request admitted after the sweep's scan blocks the eviction.
    fn evict(&self, name: &str, ttl: Duration, now: Instant) -> bool {
        let _creating = self.create_lock.lock().expect("create lock poisoned");
        match self.governor.idle_for(name, now) {
            Some(idle) if idle > ttl => {}
            _ => return false,
        }
        let mut collections = self.collections.write().expect("collections poisoned");
        let Some(state) = collections.remove(name) else { return false };
        let root = state.with_sharded(|sk| sk.root_hash());
        drop(collections);
        self.evicted.lock().expect("evicted poisoned").insert(name.to_string(), root);
        ServerMetrics::add(&self.http_metrics.collections_evicted, 1);
        // `state` drops here — WAL handles close (while we still hold the
        // create lock, so a rehydration cannot replay a half-closed WAL).
        true
    }

    /// The admission hook both front ends consult **before** a request
    /// is queued to the dispatch pool. `None` when governance is off —
    /// the server then behaves bit-for-bit as an ungoverned build.
    ///
    /// Rejections never touch the state machine: nothing is logged,
    /// nothing is hashed, and the decision clock is front-end-local — so
    /// a throttled-and-retried workload replays to a root bit-identical
    /// to an unthrottled run.
    pub fn admission_hook(self: &Arc<Self>) -> Option<AdmissionHook> {
        if !self.governor.config().is_active() {
            return None;
        }
        let governor = Arc::clone(&self.governor);
        Some(Arc::new(move |req: &Request| {
            let name = governed_collection(&req.path)?;
            match governor.admit(name, Instant::now()) {
                Admission::Admit => None,
                Admission::RateLimited { retry_after_ms } => {
                    Some(admission_rejection(
                        &req.path,
                        ApiError::new(
                            ApiCode::RateLimited,
                            format!("collection '{name}': rate limit exceeded"),
                        )
                        .with_retry_after_ms(retry_after_ms),
                    ))
                }
                Admission::QuotaExceeded => Some(admission_rejection(
                    &req.path,
                    ApiError::new(
                        ApiCode::QuotaExceeded,
                        format!("collection '{name}': too many requests in flight"),
                    ),
                )),
            }
        }))
    }

    /// The shared front-end metrics sink.
    pub fn http_metrics(&self) -> &Arc<ServerMetrics> {
        &self.http_metrics
    }

    /// `GET /v2/collections/{name}/snapshot`: a `VSTREAM1` response whose
    /// body is pulled chunk by chunk from the live collection.
    ///
    /// Memory stays bounded at one shard frame + one chunk: the manifest
    /// pass digests shards one at a time under a single read lock, and
    /// the streaming source re-encodes each shard lazily as the socket
    /// drains. Consistency is **seq-pinned**: every shard's sequence
    /// number is recorded at header time and re-checked on every lazy
    /// re-encode; if any mutation lands mid-stream the source aborts,
    /// the connection tears short of its `content-length`, and the
    /// client fails loudly — a stream never silently mixes two states.
    fn snapshot_stream_response(&self, name: &str, chunk: usize) -> ApiResult<Response> {
        let state = self.get(name)?;
        let (spec, pinned, manifest) = state.with_sharded(|sk| {
            let spec = StreamSpec {
                dim: sk.config().dim as u32,
                index: sk.config().index,
                n_shards: sk.n_shards(),
            };
            let pinned: Vec<u64> = sk.shards().iter().map(Kernel::seq).collect();
            let manifest: Vec<StreamManifestEntry> = sk
                .shards()
                .iter()
                .map(|k| StreamManifestEntry::of(&Snapshot::capture(k)))
                .collect();
            (spec, pinned, manifest)
        });
        let source = PinnedFrames { state, pinned };
        let mut writer = SnapshotWriter::new(spec, manifest, source, chunk);
        let total = writer.total_len();
        let metrics = Arc::clone(&self.http_metrics);
        metrics.streams_in_flight.fetch_add(1, Ordering::Relaxed);
        let guard = StreamFlightGuard { metrics: Arc::clone(&metrics) };
        // Per-tenant transfer cap: each produced block charges the
        // tenant's stream budget; the pacer below makes the front end
        // defer the *next* refill until the debt has decayed. Pacing
        // changes only the timing of the bytes, never the bytes.
        let charge = self
            .governor
            .config()
            .stream_bytes_per_sec
            .map(|_| (Arc::clone(&self.governor), name.to_string()));
        let body = StreamingBody::new(total, move || {
            let _held_until_stream_drops = &guard;
            match writer.next_block() {
                Some(Ok(block)) => {
                    metrics.stream_bytes_streamed.fetch_add(block.len() as u64, Ordering::Relaxed);
                    if let Some((governor, tenant)) = &charge {
                        governor.stream_consume(tenant, block.len() as u64, Instant::now());
                    }
                    Some(block)
                }
                // An abort yields fewer than `total` bytes; the front end
                // tears the connection and the client sees a short body.
                // Never substitute bytes.
                Some(Err(_)) | None => None,
            }
        });
        let body = match self.governor.config().stream_bytes_per_sec {
            Some(_) => {
                let governor = Arc::clone(&self.governor);
                let tenant = name.to_string();
                body.with_pacer(move || governor.stream_defer(&tenant, Instant::now()))
            }
            None => body,
        };
        Ok(Response::streaming(200, "application/octet-stream", body))
    }

    /// `PUT /v2/collections/{name}/restore?offset=N`: feed one window of
    /// a `VSTREAM1` stream into the (resumable) restore session for
    /// `name`; when the stream completes, verify it end to end and
    /// install it as a brand-new collection. Windowing exists because
    /// request bodies are capped at [`crate::http::MAX_BODY`] — the
    /// stream format is self-framing and [`SnapshotReader`] is resumable,
    /// so a transfer of any size is just many body-sized PUTs whose
    /// `offset` must match the session's byte count (exactly-once,
    /// in-order ingest; a retry of the same window is rejected loudly
    /// instead of silently double-fed).
    pub fn restore_ingest(&self, name: &str, offset: u64, bytes: &[u8]) -> ApiResult<Json> {
        validate_collection_name(name)?;
        let now = Instant::now();
        // Reap idle sessions first: abandoned transfers must not pin
        // their reassembled frames (or the in-flight gauge) forever.
        self.reap_restores(now);
        let mut sessions = self.restores.lock().expect("restores poisoned");
        let exists = self.collections.read().expect("collections poisoned").contains_key(name)
            || self.evicted.lock().expect("evicted poisoned").contains_key(name);
        if exists {
            // An orphaned session for a name that got created by other
            // means is moot — drop it with the rejection.
            if sessions.remove(name).is_some() {
                self.http_metrics.streams_in_flight.fetch_sub(1, Ordering::Relaxed);
            }
            return Err(ApiError::new(
                ApiCode::CollectionExists,
                format!("collection '{name}' already exists; restore targets a fresh name"),
            ));
        }
        if offset == 0 {
            // Offset 0 (re)starts the transfer; a stale half-session for
            // the same name is discarded.
            if !sessions.contains_key(name) && sessions.len() >= MAX_RESTORE_SESSIONS {
                return Err(ApiError::new(
                    ApiCode::RestoreBusy,
                    format!(
                        "{MAX_RESTORE_SESSIONS} restore sessions already in progress; \
                         retry later"
                    ),
                ));
            }
            if sessions
                .insert(
                    name.to_string(),
                    RestoreSession { reader: SnapshotReader::new(), last_fed: now },
                )
                .is_none()
            {
                self.http_metrics.streams_in_flight.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Take the session OUT of the map and feed it with the map lock
        // released: `SnapshotReader::feed` does up to a full MAX_BODY
        // window of CRC/SHA work, and holding the global lock across it
        // would serialize every tenant's restore behind this one. A
        // concurrent PUT for the *same* name while we hold the session
        // sees "no session" (1401) — in-order windows per name is already
        // the contract.
        let Some(mut session) = sessions.remove(name) else {
            return Err(ApiError::new(
                ApiCode::StreamOffsetMismatch,
                format!("no restore session for '{name}' (start at offset 0)"),
            ));
        };
        drop(sessions);
        if session.reader.bytes_fed() != offset {
            let expected = session.reader.bytes_fed();
            self.put_back_session(name, session);
            return Err(ApiError::new(
                ApiCode::StreamOffsetMismatch,
                format!("restore session for '{name}' expects offset {expected}, got {offset}"),
            ));
        }
        let verified_before = session.reader.chunks_verified();
        if let Err(e) = session.reader.feed(bytes) {
            // Session dies with the bad window (we own it; it never goes
            // back into the map).
            self.http_metrics.streams_in_flight.fetch_sub(1, Ordering::Relaxed);
            return Err(ApiError::from(e));
        }
        session.last_fed = Instant::now();
        let delta = session.reader.chunks_verified() - verified_before;
        self.http_metrics.stream_chunks_verified.fetch_add(delta, Ordering::Relaxed);
        if !session.reader.is_complete() {
            let received = session.reader.bytes_fed();
            self.put_back_session(name, session);
            return Ok(Json::object(vec![
                ("complete", Json::Bool(false)),
                ("name", Json::str(name)),
                ("received", Json::Int(received as i64)),
            ]));
        }
        self.http_metrics.streams_in_flight.fetch_sub(1, Ordering::Relaxed);
        let snapshot = session.reader.finalize().map_err(ApiError::from)?;
        self.install_restored(name, snapshot)
    }

    /// Re-insert a session taken out for an unlocked feed. If an
    /// offset-0 restart raced in while the session was out, the restart
    /// wins (offset 0 means "start over") and the stale session — which
    /// the gauge still counts — is dropped.
    fn put_back_session(&self, name: &str, session: RestoreSession) {
        let mut sessions = self.restores.lock().expect("restores poisoned");
        if let std::collections::btree_map::Entry::Vacant(slot) = sessions.entry(name.to_string())
        {
            slot.insert(session);
        } else {
            self.http_metrics.streams_in_flight.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Drop restore sessions idle past [`RESTORE_SESSION_TTL`], releasing
    /// their reassembled frames and the in-flight gauge. Called from
    /// every restore PUT, from the stats routes and from the idle sweep —
    /// so abandoned transfers are reaped even with zero restore traffic.
    pub fn reap_restores(&self, now: Instant) -> u64 {
        let mut sessions = self.restores.lock().expect("restores poisoned");
        let before = sessions.len();
        sessions.retain(|_, s| now.duration_since(s.last_fed) < RESTORE_SESSION_TTL);
        let reaped = (before - sessions.len()) as u64;
        if reaped > 0 {
            self.http_metrics.streams_in_flight.fetch_sub(reaped, Ordering::Relaxed);
        }
        reaped
    }

    /// Install a fully verified restored snapshot as a new collection —
    /// the receiving half of online tenant migration. When durable, the
    /// base state persists as `restored.snap` (rediscovery restores it
    /// first, then replays the post-restore WALs on top).
    fn install_restored(&self, name: &str, snapshot: ShardedSnapshot) -> ApiResult<Json> {
        let kernel = snapshot.restore().map_err(|e| {
            ApiError::new(
                ApiCode::StreamDigestMismatch,
                format!("restored snapshot failed verification: {e}"),
            )
        })?;
        let root = snapshot.root_hash();
        let spec = CollectionSpec {
            dim: kernel.config().dim,
            shards: kernel.n_shards(),
            flat: matches!(kernel.config().index, IndexKind::Flat),
            quant: kernel.config().quant,
            // Runtime tuning and budgets are node policy, not state; a
            // migrated tenant starts with the destination's defaults.
            memory_quota: 0,
            scan_workers: 0,
        };
        let _creating = self.create_lock.lock().expect("create lock poisoned");
        {
            let collections = self.collections.read().expect("collections poisoned");
            if collections.contains_key(name) {
                return Err(ApiError::new(
                    ApiCode::CollectionExists,
                    format!("collection '{name}' was created while the restore was in flight"),
                ));
            }
        }
        let (wal_path, durable_dir) = self.storage_paths(name)?;
        if let Some(d) = &durable_dir {
            // Base state before spec.json: rediscovery only picks up
            // directories with a spec, so a crash between the two writes
            // leaves an inert directory, never a half-restored tenant.
            snapshot.write_file(d.join(RESTORED_SNAP)).map_err(|e| {
                ApiError::new(ApiCode::Internal, format!("write {RESTORED_SNAP}: {e}"))
            })?;
            std::fs::write(d.join("spec.json"), spec_json(&spec)).map_err(|e| {
                ApiError::new(ApiCode::Internal, format!("write spec.json: {e}"))
            })?;
        }
        let node_config = NodeConfig {
            workers: self.config.workers,
            wal_path,
            memory_quota: spec.memory_quota,
        };
        let mut state =
            NodeState::new_sharded(kernel, &node_config, self.embed.clone()).map_err(|e| {
                ApiError::new(ApiCode::Internal, format!("collection '{name}': {e}"))
            })?;
        state.metrics.http = Arc::clone(&self.http_metrics);
        let state = Arc::new(state);
        let (vectors, seq) = state.with_sharded(|sk| (sk.len(), sk.seq()));
        self.collections
            .write()
            .expect("collections poisoned")
            .insert(name.to_string(), state);
        Ok(Json::object(vec![
            ("complete", Json::Bool(true)),
            ("dim", Json::Int(spec.dim as i64)),
            ("name", Json::str(name)),
            ("root", Json::str(format!("{root:016x}"))),
            ("seq", Json::Int(seq as i64)),
            ("shards", Json::Int(spec.shards as i64)),
            ("vectors", Json::Int(vectors as i64)),
        ]))
    }
}

/// Lazily re-encodes shard frames for a streaming snapshot, refusing to
/// produce a frame whose shard moved past its pinned sequence number
/// (see [`CollectionManager::snapshot_stream_response`]).
struct PinnedFrames {
    state: Arc<NodeState>,
    pinned: Vec<u64>,
}

impl FrameSource for PinnedFrames {
    fn frame(&mut self, shard: u32) -> Result<Vec<u8>, StreamError> {
        self.state.with_sharded(|sk| {
            let k = sk.shard(shard);
            if k.seq() != self.pinned[shard as usize] {
                return Err(StreamError::Aborted(format!(
                    "shard {shard} mutated during the snapshot stream (seq {} -> {})",
                    self.pinned[shard as usize],
                    k.seq()
                )));
            }
            Ok(Snapshot::capture(k).to_bytes())
        })
    }
}

/// Decrements the in-flight stream gauge when the streaming source is
/// dropped (stream complete, aborted, or the connection died).
struct StreamFlightGuard {
    metrics: Arc<ServerMetrics>,
}

impl Drop for StreamFlightGuard {
    fn drop(&mut self) {
        self.metrics.streams_in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Which tenant a request path is governed under: `/v1/*` adapts onto
/// `default`; `/v2/collections/{name}...` onto `{name}`. Manager-level
/// routes (health, `/v2/hash`, the collection list) are ungoverned —
/// throttling a health check would defeat its purpose.
fn governed_collection(path: &str) -> Option<&str> {
    if path == "/v1/health" || path == "/v2/health" {
        return None;
    }
    if path == "/v1" || path.starts_with("/v1/") {
        return Some(DEFAULT_COLLECTION);
    }
    let tail = path.strip_prefix("/v2/collections/")?;
    let name = tail.split('/').next().unwrap_or("");
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Serialize an admission rejection in the shape the surface expects:
/// the typed taxonomy envelope on `/v2`, the legacy ad-hoc shape on
/// `/v1` (with `retry_after_ms` riding along for 1600 so legacy clients
/// can still back off precisely).
fn admission_rejection(path: &str, err: ApiError) -> Response {
    if path.starts_with("/v2") {
        return err.response();
    }
    let mut fields = vec![("error", Json::str(err.message.clone()))];
    if let Some(ms) = err.retry_after_ms {
        fields.push(("retry_after_ms", Json::Int(ms as i64)));
    }
    Response::json(err.code.http_status(), Json::object(fields).to_string())
}

/// The combined-root fold: `fnv(count ‖ (len(name) ‖ name ‖ root)*)`
/// over lexicographically ordered `(name, root)` pairs. One
/// implementation serves both the in-process value and the `/v2/hash`
/// wire payload so the two can never drift.
fn fold_combined_root(roots: &[(String, u64)]) -> u64 {
    let mut h = Fnv1a64::new();
    h.update_u32(roots.len() as u32);
    for (name, root) in roots {
        h.update_u32(name.len() as u32);
        h.update(name.as_bytes());
        h.update_u64(*root);
    }
    h.finish()
}

/// The persisted form of a collection's spec (`<data>/<name>/spec.json`;
/// same field names the PUT body accepts, so [`parse_spec`] reads it).
fn spec_json(spec: &CollectionSpec) -> String {
    let mut fields = vec![
        ("dim", Json::Int(spec.dim as i64)),
        ("index", Json::str(if spec.flat { "flat" } else { "hnsw" })),
    ];
    // Default-valued optional fields are omitted, so spec.json files
    // written by older builds and newer ones stay interchangeable in
    // both directions (quant-free specs keep the pre-quantization
    // manifest bytes, untuned specs keep the pre-scan-pool bytes).
    if spec.memory_quota != 0 {
        fields.push(("memory_quota", Json::Int(spec.memory_quota as i64)));
    }
    if let QuantSpec::Sq8 { overscan } = spec.quant {
        fields.push(("overscan", Json::Int(i64::from(overscan))));
        fields.push(("quant", Json::str(spec.quant.name())));
    }
    if spec.scan_workers != 0 {
        fields.push(("scan_workers", Json::Int(i64::from(spec.scan_workers))));
    }
    fields.push(("shards", Json::Int(spec.shards as i64)));
    Json::object(fields).to_string()
}

/// One collection's summary object (list entries and single GET share it).
fn collection_summary(name: &str, state: &NodeState) -> Json {
    let (dim, index, quant, shards, vectors, seq, root) = state.with_sharded(|sk| {
        (
            sk.config().dim,
            sk.config().index,
            sk.config().quant,
            sk.n_shards(),
            sk.len(),
            sk.seq(),
            sk.root_hash(),
        )
    });
    Json::object(vec![
        ("dim", Json::Int(dim as i64)),
        (
            "index",
            Json::str(match index {
                IndexKind::Flat => "flat",
                IndexKind::Hnsw => "hnsw",
            }),
        ),
        ("log_len", Json::Int(state.log_len() as i64)),
        ("name", Json::str(name)),
        ("quant", Json::str(quant.name())),
        ("root", Json::str(format!("{root:016x}"))),
        ("seq", Json::Int(seq as i64)),
        ("shards", Json::Int(shards as i64)),
        ("vectors", Json::Int(vectors as i64)),
    ])
}

/// The per-tenant governor block for `stats`. Tenants the governor has
/// never seen (or has pruned as idle) report exactly the state they
/// would start from on first admission: a full burst bucket, nothing in
/// flight, zero rejection counters.
fn governor_json(manager: &CollectionManager, name: &str) -> Json {
    let snap = manager
        .governor
        .tenant_snapshot(name, Instant::now())
        .unwrap_or_else(|| manager.governor.fresh_tenant_snapshot());
    Json::object(vec![
        ("available_tokens", Json::Int(snap.available_tokens as i64)),
        ("enabled", Json::Bool(manager.governor.config().is_active())),
        ("in_flight", Json::Int(i64::from(snap.in_flight))),
        ("quota_rejected", Json::Int(snap.quota_rejected as i64)),
        ("rate_limited", Json::Int(snap.rate_limited as i64)),
    ])
}

/// Start the HTTP server for a collection manager; `/v1/*` adapts onto
/// the `default` collection, `/v2/*` is the typed multi-tenant surface.
pub fn serve_collections(
    manager: Arc<CollectionManager>,
    addr: &str,
    workers: usize,
) -> std::io::Result<Server> {
    let config = ServerConfig {
        workers,
        metrics: Arc::clone(&manager.http_metrics),
        admission: manager.admission_hook(),
        ..Default::default()
    };
    let governed = manager.governor.config().is_active();
    let m = Arc::clone(&manager);
    let handler: Handler = Arc::new(move |req| {
        // Every admitted request pairs its `Governor::admit` with exactly
        // one `release` once the pool worker is done with it — that
        // counter IS the quota and the bulkhead.
        let tenant =
            if governed { governed_collection(&req.path).map(str::to_string) } else { None };
        let resp = route_collections(&m, req);
        if let Some(name) = tenant {
            m.governor.release(&name);
        }
        resp
    });
    let server = Server::start_with(addr, config, handler)?;
    let _ = manager.backend.set(server.backend_name());
    if let Some(ttl) = manager.governor.config().idle_ttl {
        // Periodic sweep: holds only a Weak so the manager (and its WALs)
        // can die normally; the thread exits on the first failed upgrade.
        let weak = Arc::downgrade(&manager);
        let interval = (ttl / 4).clamp(Duration::from_millis(50), Duration::from_secs(30));
        std::thread::Builder::new()
            .name("valori-idle-sweep".into())
            .spawn(move || loop {
                std::thread::sleep(interval);
                let Some(m) = weak.upgrade() else { return };
                m.sweep_idle(Instant::now());
            })?;
    }
    Ok(server)
}

/// Route one request against the manager (pure function of state +
/// request, like [`super::route`]; exposed for tests).
pub fn route_collections(manager: &CollectionManager, req: Request) -> Response {
    // Health is manager-level (the only /v1 route that is not a pure
    // delegation: the adapter knows the real collection count and which
    // front end is serving, a bare NodeState does not).
    if req.method == "GET" && (req.path == "/v1/health" || req.path == "/v2/health") {
        let body = super::health_json(manager.backend_name(), manager.len());
        return Response::json(200, body.to_string());
    }
    // Stats requests double as a reap opportunity: abandoned restore
    // sessions are released even on deployments with no idle sweeper
    // (and the gauges a stats call reports are accurate as of the call).
    if req.method == "GET" && (req.path == "/v1/stats" || req.path.ends_with("/stats")) {
        manager.reap_restores(Instant::now());
    }
    if req.path == "/v1" || req.path.starts_with("/v1/") {
        // Thin adapter: the default collection IS the /v1 node, so every
        // legacy client sees byte-identical behavior.
        return match manager.get(DEFAULT_COLLECTION) {
            Ok(state) => route(&state, req),
            Err(_) => Response::not_found(), // unreachable: default is reserved
        };
    }
    if req.path == "/v2" || req.path.starts_with("/v2/") {
        // The snapshot stream is the one /v2 route that does not speak
        // the JSON envelope (its success body is the raw VSTREAM1 wire
        // format); errors still use the taxonomy envelope.
        if let Some(result) = v2_snapshot_route(manager, &req) {
            return match result {
                Ok(resp) => resp,
                Err(e) => e.response(),
            };
        }
        return match v2_dispatch(manager, &req) {
            Ok(data) => ok_response(data),
            Err(e) => e.response(),
        };
    }
    Response::not_found()
}

/// `GET /v2/collections/{name}/snapshot[?chunk=N]` — `None` when the
/// request is not for a snapshot path at all.
fn v2_snapshot_route(manager: &CollectionManager, req: &Request) -> Option<ApiResult<Response>> {
    let name = req
        .path
        .strip_prefix("/v2/collections/")
        .and_then(|tail| tail.strip_suffix("/snapshot"))?;
    if name.is_empty() || name.contains('/') {
        return None;
    }
    Some(snapshot_route_inner(manager, req, name))
}

fn snapshot_route_inner(
    manager: &CollectionManager,
    req: &Request,
    name: &str,
) -> ApiResult<Response> {
    if req.method != "GET" {
        return Err(method_not_allowed(req, "GET"));
    }
    validate_collection_name(name)?;
    let chunk = match query_param::<usize>(req, "chunk") {
        None => SNAPSHOT_CHUNK_DEFAULT,
        Some(Ok(c)) if (SNAPSHOT_CHUNK_MIN..=SNAPSHOT_CHUNK_MAX).contains(&c) => c,
        Some(_) => {
            return Err(ApiError::bad_request(format!(
                "chunk must be an integer in [{SNAPSHOT_CHUNK_MIN}, {SNAPSHOT_CHUNK_MAX}]"
            )))
        }
    };
    manager.snapshot_stream_response(name, chunk)
}

/// One `?key=value` query parameter, parsed: `None` = absent,
/// `Some(Err(()))` = present but unparsable.
fn query_param<T: std::str::FromStr>(req: &Request, param: &str) -> Option<Result<T, ()>> {
    let q = req.query.as_deref()?;
    q.split('&').find_map(|kv| {
        kv.strip_prefix(param)
            .and_then(|v| v.strip_prefix('='))
            .map(|v| v.parse::<T>().map_err(|_| ()))
    })
}

fn route_not_found(req: &Request) -> ApiError {
    ApiError::new(ApiCode::RouteNotFound, format!("no route {} {}", req.method, req.path))
}

fn method_not_allowed(req: &Request, allowed: &str) -> ApiError {
    ApiError::new(
        ApiCode::MethodNotAllowed,
        format!("{} not allowed on {} (use {allowed})", req.method, req.path),
    )
}

/// The /v2 route tree. Every arm returns the success payload (`data`)
/// or a taxonomy error — serialization happens in exactly one place,
/// [`route_collections`].
fn v2_dispatch(manager: &CollectionManager, req: &Request) -> ApiResult<Json> {
    let rest = &req.path["/v2".len()..];
    match rest {
        "/hash" => match req.method.as_str() {
            "GET" => Ok(manager.combined_hash_json()),
            _ => Err(method_not_allowed(req, "GET")),
        },
        "/collections" => match req.method.as_str() {
            "GET" => Ok(manager.list_json()),
            _ => Err(method_not_allowed(req, "GET")),
        },
        _ => {
            let Some(tail) = rest.strip_prefix("/collections/") else {
                return Err(route_not_found(req));
            };
            match tail.split_once('/') {
                None => collection_entry(manager, req, tail),
                Some((name, op)) => collection_op(manager, req, name, op),
            }
        }
    }
}

/// `PUT|GET|DELETE /v2/collections/{name}`.
fn collection_entry(manager: &CollectionManager, req: &Request, name: &str) -> ApiResult<Json> {
    match req.method.as_str() {
        "PUT" => {
            let spec = parse_spec(&req.body, manager.default_spec())?;
            let state = manager.create(name, spec)?;
            let (dim, shards) = state.with_sharded(|sk| (sk.config().dim, sk.n_shards()));
            Ok(Json::object(vec![
                ("created", Json::str(name)),
                ("dim", Json::Int(dim as i64)),
                ("shards", Json::Int(shards as i64)),
            ]))
        }
        "GET" => {
            let state = manager.get(name)?;
            Ok(collection_summary(name, &state))
        }
        "DELETE" => {
            manager.drop_collection(name)?;
            Ok(Json::object(vec![("deleted", Json::str(name))]))
        }
        _ => Err(method_not_allowed(req, "PUT, GET or DELETE")),
    }
}

/// Parse a PUT body into a spec (empty body = the manager's defaults).
fn parse_spec(body: &[u8], default: &CollectionSpec) -> ApiResult<CollectionSpec> {
    if body.is_empty() {
        return Ok(default.clone());
    }
    let json = body_json(body)?;
    let mut spec = default.clone();
    match json.get("dim") {
        Json::Null => {}
        v => {
            spec.dim = v
                .as_u64()
                .filter(|&d| d > 0)
                .ok_or_else(|| ApiError::bad_request("dim must be a positive integer"))?
                as usize;
        }
    }
    match json.get("shards") {
        Json::Null => {}
        v => {
            spec.shards = v
                .as_u64()
                .filter(|&s| s >= 1)
                .ok_or_else(|| ApiError::bad_request("shards must be an integer >= 1"))?
                as u32;
        }
    }
    match json.get("index") {
        Json::Null => {}
        v => {
            spec.flat = match v.as_str() {
                Some("flat") => true,
                Some("hnsw") => false,
                _ => return Err(ApiError::bad_request("index must be \"flat\" or \"hnsw\"")),
            };
        }
    }
    match json.get("quant") {
        Json::Null => {}
        v => {
            spec.quant = match v.as_str() {
                Some("none") => QuantSpec::None,
                Some("sq8") => QuantSpec::sq8_default(),
                _ => return Err(ApiError::bad_request("quant must be \"none\" or \"sq8\"")),
            };
        }
    }
    match json.get("overscan") {
        Json::Null => {}
        v => {
            let overscan = match v.as_u64() {
                Some(o) if (1..=u64::from(u32::MAX)).contains(&o) => o as u32,
                _ => return Err(ApiError::bad_request("overscan must be an integer >= 1")),
            };
            match &mut spec.quant {
                QuantSpec::Sq8 { overscan: o } => *o = overscan,
                QuantSpec::None => {
                    return Err(ApiError::bad_request("overscan requires quant \"sq8\""))
                }
            }
        }
    }
    match json.get("memory_quota") {
        Json::Null => {}
        v => {
            spec.memory_quota = v.as_u64().ok_or_else(|| {
                ApiError::bad_request("memory_quota must be a non-negative integer (0 = unlimited)")
            })?;
        }
    }
    match json.get("scan_workers") {
        Json::Null => {}
        v => {
            spec.scan_workers = match v.as_u64() {
                Some(w) if w <= u64::from(u32::MAX) => w as u32,
                _ => {
                    return Err(ApiError::bad_request(
                        "scan_workers must be a non-negative integer (0 = one per core)",
                    ))
                }
            };
        }
    }
    Ok(spec)
}

/// `/v2/collections/{name}/{op}`.
fn collection_op(
    manager: &CollectionManager,
    req: &Request,
    name: &str,
    op: &str,
) -> ApiResult<Json> {
    const POST_OPS: [&str; 9] =
        ["insert", "insert_batch", "query", "delete", "link", "unlink", "meta", "apply", "repair"];
    const GET_OPS: [&str; 4] = ["log", "hash", "stats", "proof"];
    validate_collection_name(name)?;
    // Restore targets a collection that does not exist yet, so it
    // resolves before the existence check every other op performs.
    if op == "restore" {
        return match req.method.as_str() {
            "PUT" => {
                let offset = match query_param::<u64>(req, "offset") {
                    None => 0,
                    Some(Ok(o)) => o,
                    Some(Err(())) => {
                        return Err(ApiError::bad_request("offset must be a non-negative integer"))
                    }
                };
                manager.restore_ingest(name, offset, &req.body)
            }
            _ => Err(method_not_allowed(req, "PUT")),
        };
    }
    // Captured before `get` (which rehydrates lazily): the stats route
    // reports whether *this* request found the tenant cold.
    let was_evicted = manager.is_evicted(name);
    let state = manager.get(name)?;
    match (req.method.as_str(), op) {
        // Repair carries a raw leaf encoding, not a typed command — it
        // must never flow through `execute` (it is state surgery, not a
        // logged mutation), so it gets its own arm ahead of the generic
        // POST dispatch.
        ("POST", "repair") => repair_route(&state, &req.body),
        ("POST", _) if POST_OPS.contains(&op) => {
            let body = body_json(&req.body)?;
            let typed = ApiRequest::parse(op, &body)?;
            execute(&state, typed)
        }
        ("GET", "log") => {
            let query_param = |param: &str| {
                req.query.as_deref().and_then(|q| {
                    q.split('&').find_map(|kv| {
                        kv.strip_prefix(param)
                            .and_then(|v| v.strip_prefix('='))
                            .and_then(|v| v.parse::<usize>().ok())
                    })
                })
            };
            let shard = query_param("shard").unwrap_or(0);
            let from = query_param("from").unwrap_or(0);
            // Checked narrowing: a shard beyond u32 must reject, not
            // silently alias onto `shard % 2^32`.
            match u32::try_from(shard) {
                Ok(s) => log_feed(&state, s, from),
                Err(_) => Err(ApiError::new(
                    ApiCode::ShardOutOfRange,
                    format!("shard {shard} out of range (n_shards = {})", state.n_shards()),
                )),
            }
        }
        ("GET", "hash") => Ok(hash_manifest(&state)),
        ("GET", "proof") => proof_route(&state, req),
        ("GET", "stats") => {
            let mut obj = match stats_json(&state) {
                Json::Object(o) => o,
                _ => unreachable!("stats_json returns an object"),
            };
            obj.insert("collection".into(), Json::str(name));
            obj.insert("root".into(), Json::str(root_hex(&state)));
            // Resource accounting: exact Q16.16 arena vs the derived SQ8
            // code arena (0 unless the collection has a quant tier).
            let (exact_arena, code_arena) = state.with_sharded(|sk| sk.arena_bytes());
            obj.insert(
                "memory_bytes".into(),
                Json::object(vec![
                    ("code_arena", Json::Int(code_arena as i64)),
                    ("exact_arena", Json::Int(exact_arena as i64)),
                    ("quota", Json::Int(state.memory_quota() as i64)),
                    ("total", Json::Int((exact_arena + code_arena) as i64)),
                ]),
            );
            obj.insert("evicted".into(), Json::Bool(was_evicted));
            obj.insert("governor".into(), governor_json(manager, name));
            // Configured override (0 = one worker per core), not the
            // resolved pool width — stats stay machine-independent.
            obj.insert(
                "scan_workers".into(),
                Json::Int(i64::from(state.with_sharded(|sk| sk.config().scan.workers))),
            );
            Ok(Json::Object(obj))
        }
        (_, _) if POST_OPS.contains(&op) => Err(method_not_allowed(req, "POST")),
        (_, _) if GET_OPS.contains(&op) => Err(method_not_allowed(req, "GET")),
        _ => Err(route_not_found(req)),
    }
}

/// Response-size bound for one bisection window of tree hashes (64
/// bytes of hex each on the wire). The Merkle-diff walk only ever needs
/// sibling pairs; the cap exists for clients dumping whole levels.
const PROOF_HASHES_MAX: usize = 4096;

/// Build one collection's verifiable state receipt (see [`crate::proof`]
/// for field semantics). `snapshot_hash` and `merkle_root` are pure
/// functions of the replicated state; `wal_hash` is an advisory FNV fold
/// over the canonical per-shard logs (two replicas that shipped the same
/// history agree, but log truncation would change it without changing
/// state — which is why it is not part of membership verification).
fn build_receipt(state: &NodeState) -> Receipt {
    let (state_version, seq, snapshot_hash, merkle_root, shard_roots) =
        state.with_sharded(|sk| {
            let snap = ShardedSnapshot::capture(sk);
            (
                sk.shard(0).state_version(),
                sk.seq(),
                snap.receipt_snapshot_hash(),
                sk.merkle_root(),
                sk.merkle_shard_roots(),
            )
        });
    let mut h = Fnv1a64::new();
    h.update_u32(state.n_shards());
    for s in 0..state.n_shards() {
        let cmds = state.log_slice_shard(s, 0, usize::MAX);
        h.update_u32(cmds.len() as u32);
        for c in &cmds {
            let bytes = c.to_bytes();
            h.update_u32(bytes.len() as u32);
            h.update(&bytes);
        }
    }
    Receipt { state_version, seq, snapshot_hash, wal_hash: h.finish(), merkle_root, shard_roots }
}

/// `GET /v2/collections/{name}/proof`: with no parameters the state
/// receipt; `?id=N` a membership proof (tombstones included; 1002 for
/// never-inserted ids); `?shard=S[&level=L&from=A&count=K]` a window of
/// tree hashes (the Merkle-diff bisection primitive; level 0 = leaves);
/// `?shard=S&slot=N` one canonical leaf encoding.
fn proof_route(state: &NodeState, req: &Request) -> ApiResult<Json> {
    fn parsed<T: std::str::FromStr>(req: &Request, name: &str) -> ApiResult<Option<T>> {
        match query_param::<T>(req, name) {
            None => Ok(None),
            Some(Ok(v)) => Ok(Some(v)),
            Some(Err(())) => Err(ApiError::bad_request(format!(
                "'{name}' must be a non-negative integer"
            ))),
        }
    }
    if let Some(id) = parsed::<u64>(req, "id")? {
        let proof = state
            .with_sharded(|sk| sk.merkle_proof(id))
            .ok_or_else(|| ApiError::new(ApiCode::UnknownId, format!("unknown id {id}")))?;
        return Ok(proof.to_json());
    }
    let Some(shard) = parsed::<u32>(req, "shard")? else {
        return Ok(build_receipt(state).to_json());
    };
    let slot = parsed::<u32>(req, "slot")?;
    let level = parsed::<usize>(req, "level")?.unwrap_or(0);
    let from = parsed::<usize>(req, "from")?.unwrap_or(0);
    let count = parsed::<usize>(req, "count")?.unwrap_or(PROOF_HASHES_MAX);
    if count == 0 || count > PROOF_HASHES_MAX {
        return Err(ApiError::bad_request(format!("count must be in [1, {PROOF_HASHES_MAX}]")));
    }
    state.with_sharded(|sk| {
        if shard >= sk.n_shards() {
            return Err(ApiError::new(
                ApiCode::ProofOutOfRange,
                format!("shard {shard} out of range (n_shards = {})", sk.n_shards()),
            ));
        }
        let kernel = sk.shard(shard);
        if let Some(slot) = slot {
            let record = kernel.merkle_leaf_encoding(slot).ok_or_else(|| {
                ApiError::new(
                    ApiCode::ProofOutOfRange,
                    format!("slot {slot} beyond shard {shard}'s arena"),
                )
            })?;
            return Ok(Json::object(vec![
                ("record", Json::str(hex_encode(&record))),
                ("shard", Json::Int(i64::from(shard))),
                ("slot", Json::Int(i64::from(slot))),
            ]));
        }
        let levels = kernel.merkle_levels();
        let capacity = kernel.merkle_capacity();
        if level >= levels {
            return Err(ApiError::new(
                ApiCode::ProofOutOfRange,
                format!("level {level} out of range (tree has {levels} levels)"),
            ));
        }
        let level_len = capacity >> level;
        if from >= level_len {
            return Err(ApiError::new(
                ApiCode::ProofOutOfRange,
                format!("from {from} out of range (level {level} has {level_len} hashes)"),
            ));
        }
        let count = count.min(level_len - from);
        let hashes = kernel.merkle_level(level, from, count).ok_or_else(|| {
            ApiError::new(ApiCode::ProofOutOfRange, "hash range out of bounds")
        })?;
        Ok(Json::object(vec![
            ("capacity", Json::Int(capacity as i64)),
            ("count", Json::Int(hashes.len() as i64)),
            ("from", Json::Int(from as i64)),
            (
                "hashes",
                Json::Array(
                    hashes.iter().map(|h| Json::str(crate::hash::hex_lower(h))).collect(),
                ),
            ),
            ("level", Json::Int(level as i64)),
            ("levels", Json::Int(levels as i64)),
            ("shard", Json::Int(i64::from(shard))),
        ]))
    })
}

/// `POST /v2/collections/{name}/repair`: overwrite one slot with its
/// canonical leaf record — un-logged divergence repair driven by a
/// Merkle diff (see [`crate::proof`] and [`NodeState::repair_slot`]).
/// Body: `{"shard": S, "slot": N, "record": "<hex leaf encoding>"}`.
fn repair_route(state: &NodeState, body: &[u8]) -> ApiResult<Json> {
    let json = body_json(body)?;
    let proof_invalid = |msg: &str| ApiError::new(ApiCode::ProofInvalid, msg.to_string());
    let shard_raw = json
        .get("shard")
        .as_u64()
        .ok_or_else(|| proof_invalid("need numeric 'shard'"))?;
    let slot_raw = json.get("slot").as_u64().ok_or_else(|| proof_invalid("need numeric 'slot'"))?;
    let hex = json
        .get("record")
        .as_str()
        .ok_or_else(|| proof_invalid("need 'record' (hex leaf encoding)"))?;
    let bytes = hex_decode(hex).ok_or_else(|| proof_invalid("'record' is not valid hex"))?;
    let rec = crate::proof::leaf::decode(&bytes)
        .map_err(|e| ApiError::new(ApiCode::ProofInvalid, format!("bad leaf encoding: {e}")))?;
    let (Ok(shard), Ok(slot)) = (u32::try_from(shard_raw), u32::try_from(slot_raw)) else {
        return Err(ApiError::new(
            ApiCode::ProofOutOfRange,
            format!("shard {shard_raw} / slot {slot_raw} out of range"),
        ));
    };
    if shard >= state.n_shards() {
        return Err(ApiError::new(
            ApiCode::ProofOutOfRange,
            format!("shard {shard} out of range (n_shards = {})", state.n_shards()),
        ));
    }
    state.repair_slot(shard, slot, &rec).map_err(|e| match e {
        crate::state::RepairError::SlotOutOfRange => ApiError::new(
            ApiCode::ProofOutOfRange,
            format!("slot {slot} beyond shard {shard}'s arena"),
        ),
        crate::state::RepairError::IdMismatch => ApiError::new(
            ApiCode::RepairMismatch,
            format!("record id {} does not own shard {shard} slot {slot}", rec.id),
        ),
        crate::state::RepairError::DimMismatch => ApiError::new(
            ApiCode::RepairMismatch,
            "record vector dimensionality disagrees with the collection",
        ),
    })?;
    let (merkle_root, root) = state.with_sharded(|sk| {
        (crate::hash::hex_lower(&sk.merkle_root()), format!("{:016x}", sk.root_hash()))
    });
    Ok(Json::object(vec![
        ("merkle_root", Json::str(merkle_root)),
        ("repaired", Json::Bool(true)),
        ("root", Json::str(root)),
        ("shard", Json::Int(i64::from(shard))),
        ("slot", Json::Int(i64::from(slot))),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;
    use crate::state::Command;

    fn manager() -> CollectionManager {
        CollectionManager::new(
            ManagerConfig {
                spec: CollectionSpec::new(4, 2, true, QuantSpec::None),
                workers: 2,
                data_dir: None,
                default_wal: None,
                governor: GovernorConfig::default(),
            },
            None,
        )
        .unwrap()
    }

    fn send(m: &CollectionManager, method: &str, target: &str, body: &str) -> (u16, Json) {
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), Some(q.to_string())),
            None => (target.to_string(), None),
        };
        let req = Request {
            method: method.into(),
            path,
            query,
            headers: Default::default(),
            body: body.as_bytes().to_vec(),
        };
        let resp = route_collections(m, req);
        let json = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap_or(Json::Null);
        (resp.status, json)
    }

    #[test]
    fn default_collection_exists_and_v1_adapts() {
        let m = manager();
        assert_eq!(m.names(), vec!["default".to_string()]);
        let (st, body) = send(&m, "POST", "/v1/insert", r#"{"id":1,"vector":[0.1,0.2,0.3,0.4]}"#);
        assert_eq!(st, 200);
        // legacy shape: no envelope
        assert_eq!(body.get("inserted").as_i64(), Some(1));
        assert_eq!(body.get("ok"), &Json::Null);
        let (st, h) = send(&m, "GET", "/v1/health", "");
        assert_eq!(st, 200);
        assert_eq!(h.get("ok").as_bool(), Some(true));
        assert_eq!(h.get("collections").as_i64(), Some(1));
        assert_eq!(h.get("backend").as_str(), Some("unknown")); // not serving
    }

    #[test]
    fn collection_crud_lifecycle() {
        let m = manager();
        let (st, body) = send(&m, "PUT", "/v2/collections/tenant_a", r#"{"dim":8,"shards":1}"#);
        assert_eq!(st, 200, "{body}");
        assert_eq!(body.get("data").get("created").as_str(), Some("tenant_a"));
        assert_eq!(body.get("data").get("dim").as_i64(), Some(8));
        assert_eq!(body.get("ok").as_bool(), Some(true));

        let (st, body) = send(&m, "PUT", "/v2/collections/tenant_a", "");
        assert_eq!(st, 409);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1101));

        let (st, body) = send(&m, "GET", "/v2/collections/tenant_a", "");
        assert_eq!(st, 200);
        assert_eq!(body.get("data").get("shards").as_i64(), Some(1));
        assert_eq!(body.get("data").get("vectors").as_i64(), Some(0));

        let (st, body) = send(&m, "GET", "/v2/collections", "");
        assert_eq!(st, 200);
        assert_eq!(body.get("data").get("count").as_i64(), Some(2));
        let names: Vec<&str> = body
            .get("data")
            .get("collections")
            .as_array()
            .unwrap()
            .iter()
            .map(|c| c.get("name").as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["default", "tenant_a"]); // lexicographic

        let (st, body) = send(&m, "DELETE", "/v2/collections/tenant_a", "");
        assert_eq!(st, 200);
        assert_eq!(body.get("data").get("deleted").as_str(), Some("tenant_a"));
        let (st, body) = send(&m, "GET", "/v2/collections/tenant_a", "");
        assert_eq!(st, 404);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1100));
    }

    #[test]
    fn taxonomy_errors_on_the_wire() {
        let m = manager();
        // invalid name
        let (st, body) = send(&m, "PUT", "/v2/collections/Bad!Name", "");
        assert_eq!(st, 400);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1102));
        // reserved default
        let (st, body) = send(&m, "DELETE", "/v2/collections/default", "");
        assert_eq!(st, 400);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1103));
        // unknown route
        let (st, body) = send(&m, "GET", "/v2/nope", "");
        assert_eq!(st, 404);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1300));
        assert_eq!(body.get("error").get("name").as_str(), Some("route_not_found"));
        // wrong method
        let (st, body) = send(&m, "POST", "/v2/collections", "");
        assert_eq!(st, 405);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1301));
        let (st, body) = send(&m, "PUT", "/v2/hash", "");
        assert_eq!(st, 405);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1301));
        // bad json through the typed envelope
        let (st, body) = send(&m, "POST", "/v2/collections/default/insert", "{oops");
        assert_eq!(st, 400);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1000));
        // unknown collection on an op route
        let (st, body) = send(&m, "POST", "/v2/collections/ghost/insert", "{}");
        assert_eq!(st, 404);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1100));
        // state errors surface with their codes
        send(&m, "POST", "/v2/collections/default/insert", r#"{"id":1,"vector":[0,0,0,0]}"#);
        let (st, body) =
            send(&m, "POST", "/v2/collections/default/insert", r#"{"id":1,"vector":[0,0,0,0]}"#);
        assert_eq!(st, 409);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1001));
        let (st, body) =
            send(&m, "POST", "/v2/collections/default/delete", r#"{"id":42}"#);
        assert_eq!(st, 404);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1002));
        // shard out of range on the log feed
        let (st, body) = send(&m, "GET", "/v2/collections/default/log?shard=7", "");
        assert_eq!(st, 400);
        assert_eq!(body.get("error").get("code").as_i64(), Some(1007));
    }

    #[test]
    fn per_collection_state_is_isolated() {
        let m = manager();
        let spec = CollectionSpec::new(4, 2, true, QuantSpec::None);
        m.create("a", spec.clone()).unwrap();
        m.create("b", spec).unwrap();
        // same id in two collections: independent namespaces
        let (st, _) =
            send(&m, "POST", "/v2/collections/a/insert", r#"{"id":1,"vector":[0.1,0,0,0]}"#);
        assert_eq!(st, 200);
        let (st, _) =
            send(&m, "POST", "/v2/collections/b/insert", r#"{"id":1,"vector":[0.9,0,0,0]}"#);
        assert_eq!(st, 200);
        let a = m.get("a").unwrap();
        let b = m.get("b").unwrap();
        assert_eq!(a.with_sharded(|sk| sk.len()), 1);
        assert_eq!(b.with_sharded(|sk| sk.len()), 1);
        assert_ne!(
            a.with_sharded(|sk| sk.root_hash()),
            b.with_sharded(|sk| sk.root_hash()),
            "different contents, different roots"
        );
        // a's root equals a lone kernel fed the same sequence
        let mut lone = ShardedKernel::new(KernelConfig::default_q16(4).with_flat_index(), 2);
        lone.apply(Command::insert(1, vec![0.1, 0.0, 0.0, 0.0])).unwrap();
        assert_eq!(a.with_sharded(|sk| sk.root_hash()), lone.root_hash());
    }

    #[test]
    fn combined_root_is_order_invariant_and_content_sensitive() {
        let m1 = manager();
        let m2 = manager();
        let spec = CollectionSpec::new(4, 1, true, QuantSpec::None);
        m1.create("alpha", spec.clone()).unwrap();
        m1.create("beta", spec.clone()).unwrap();
        // reverse creation order on m2
        m2.create("beta", spec.clone()).unwrap();
        m2.create("alpha", spec.clone()).unwrap();
        for m in [&m1, &m2] {
            send(m, "POST", "/v2/collections/alpha/insert", r#"{"id":1,"vector":[0.1,0,0,0]}"#);
            send(m, "POST", "/v2/collections/beta/insert", r#"{"id":2,"vector":[0.2,0,0,0]}"#);
        }
        assert_eq!(m1.combined_root(), m2.combined_root());
        let (_, h1) = send(&m1, "GET", "/v2/hash", "");
        let (_, h2) = send(&m2, "GET", "/v2/hash", "");
        assert_eq!(h1, h2);
        assert_eq!(h1.get("data").get("count").as_i64(), Some(3));
        // content change flips the combined root
        send(&m2, "POST", "/v2/collections/beta/insert", r#"{"id":3,"vector":[0.3,0,0,0]}"#);
        assert_ne!(m1.combined_root(), m2.combined_root());
        // name is part of the fold: same contents under a different name
        // is a different deployment
        let m3 = manager();
        m3.create("gamma", spec.clone()).unwrap();
        let m4 = manager();
        m4.create("delta", spec).unwrap();
        assert_ne!(m3.combined_root(), m4.combined_root());
    }

    #[test]
    fn typed_ops_roundtrip_through_the_route_tree() {
        let m = manager();
        send(&m, "POST", "/v2/collections/default/insert", r#"{"id":1,"vector":[0.5,0,0,0]}"#);
        send(&m, "POST", "/v2/collections/default/insert", r#"{"id":2,"vector":[0,0.5,0,0]}"#);
        let (st, body) =
            send(&m, "POST", "/v2/collections/default/query", r#"{"vector":[0.5,0,0,0],"k":2}"#);
        assert_eq!(st, 200);
        let hits = body.get("data").get("hits").as_array().unwrap();
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].get("id").as_u64(), Some(1));
        assert_eq!(hits[0].get("dist_raw").as_i64(), Some(0));

        let (st, body) =
            send(&m, "POST", "/v2/collections/default/link", r#"{"from":1,"to":2}"#);
        assert_eq!(st, 200);
        assert_eq!(body.get("data").get("linked").as_bool(), Some(true));
        let state = m.get("default").unwrap();
        assert!(state.with_sharded(|sk| sk.has_link(1, 2)));

        let (st, body) = send(&m, "GET", "/v2/collections/default/hash", "");
        assert_eq!(st, 200);
        assert_eq!(body.get("data").get("root").as_str().unwrap().len(), 16);
        assert_eq!(body.get("data").get("shards").as_array().unwrap().len(), 2);

        let (st, body) = send(&m, "GET", "/v2/collections/default/stats", "");
        assert_eq!(st, 200);
        assert_eq!(body.get("data").get("collection").as_str(), Some("default"));
        assert_eq!(body.get("data").get("vectors").as_i64(), Some(2));

        let (st, body) = send(&m, "GET", "/v2/collections/default/log?from=0", "");
        assert_eq!(st, 200);
        assert_eq!(body.get("data").get("n_shards").as_i64(), Some(2));
    }

    #[test]
    fn durable_collections_survive_restart_with_their_specs() {
        let dir = std::env::temp_dir()
            .join(format!("valori_collections_restart_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let config = ManagerConfig {
            spec: CollectionSpec::new(4, 2, true, QuantSpec::None),
            workers: 2,
            data_dir: Some(dir.clone()),
            default_wal: None,
            governor: GovernorConfig::default(),
        };
        let root_before = {
            let m = CollectionManager::new(config.clone(), None).unwrap();
            // a tenant whose spec differs from the manager default in
            // every field — rediscovery must restore THIS shape
            let spec = CollectionSpec::new(8, 3, false, QuantSpec::None);
            m.create("tenant", spec).unwrap();
            for i in 0..20 {
                let body = format!(
                    r#"{{"id":{i},"vector":[0.1,0.2,0.3,0.4,0.5,0.6,0.7,{}]}}"#,
                    i as f32 * 0.01
                );
                let (st, resp) = send(&m, "POST", "/v2/collections/tenant/insert", &body);
                assert_eq!(st, 200, "{resp}");
            }
            let (st, _) = send(
                &m,
                "POST",
                "/v2/collections/default/insert",
                r#"{"id":1,"vector":[0.1,0,0,0]}"#,
            );
            assert_eq!(st, 200);
            m.get("tenant").unwrap().with_sharded(|sk| sk.root_hash())
            // manager dropped here: WAL files closed
        };
        let m2 = CollectionManager::new(config, None).unwrap();
        let tenant = m2.get("tenant").expect("tenant rediscovered from spec.json");
        assert_eq!(
            tenant.with_sharded(|sk| (sk.config().dim, sk.n_shards())),
            (8, 3),
            "persisted spec must win over the manager default"
        );
        assert_eq!(
            tenant.with_sharded(|sk| sk.root_hash()),
            root_before,
            "replayed WALs must reproduce the exact pre-restart root"
        );
        assert_eq!(m2.get("default").unwrap().with_sharded(|sk| sk.len()), 1);
        // dropping the tenant removes its directory; a third boot no
        // longer rediscovers it
        m2.drop_collection("tenant").unwrap();
        assert!(!dir.join("tenant").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn v2_log_apply_replicates_collection_to_collection() {
        let primary = manager();
        let follower = manager();
        let spec = CollectionSpec::new(4, 2, true, QuantSpec::None);
        primary.create("t", spec.clone()).unwrap();
        follower.create("t", spec).unwrap();
        for i in 0..20u64 {
            let body = format!(
                r#"{{"id":{i},"vector":[{},0.1,0.2,0.3]}}"#,
                (i as f32) * 0.01
            );
            let (st, _) = send(&primary, "POST", "/v2/collections/t/insert", &body);
            assert_eq!(st, 200);
        }
        // ship each shard's feed independently
        let n_shards = 2u32;
        for shard in 0..n_shards {
            let (st, feed) = send(
                &primary,
                "GET",
                &format!("/v2/collections/t/log?shard={shard}&from=0"),
                "",
            );
            assert_eq!(st, 200);
            let cmds = feed.get("data").get("commands").as_array().unwrap().to_vec();
            let body = Json::object(vec![
                ("commands", Json::Array(cmds)),
                ("shard", Json::Int(shard as i64)),
            ]);
            let (st, resp) =
                send(&follower, "POST", "/v2/collections/t/apply", &body.to_string());
            assert_eq!(st, 200, "{resp}");
        }
        let p = primary.get("t").unwrap();
        let f = follower.get("t").unwrap();
        assert_eq!(
            p.with_sharded(|sk| sk.root_hash()),
            f.with_sharded(|sk| sk.root_hash()),
            "shipped feeds must converge bit-for-bit"
        );
    }

    #[test]
    fn parse_spec_accepts_quant_and_overscan() {
        let default = CollectionSpec::new(4, 2, true, QuantSpec::None);
        let spec = parse_spec(br#"{"quant":"sq8"}"#, &default).unwrap();
        assert_eq!(spec.quant, QuantSpec::sq8_default());
        let spec = parse_spec(br#"{"quant":"sq8","overscan":8}"#, &default).unwrap();
        assert_eq!(spec.quant, QuantSpec::Sq8 { overscan: 8 });
        let spec = parse_spec(br#"{"quant":"none"}"#, &default).unwrap();
        assert_eq!(spec.quant, QuantSpec::None);
        // overscan is meaningless without the sq8 tier
        let err = parse_spec(br#"{"overscan":3}"#, &default).unwrap_err();
        assert_eq!(err.code, ApiCode::BadRequest);
        let err = parse_spec(br#"{"quant":"fp4"}"#, &default).unwrap_err();
        assert_eq!(err.code, ApiCode::BadRequest);
        let err = parse_spec(br#"{"quant":"sq8","overscan":0}"#, &default).unwrap_err();
        assert_eq!(err.code, ApiCode::BadRequest);
    }

    #[test]
    fn spec_json_round_trips_quant_and_keeps_quant_free_bytes() {
        let default = CollectionSpec::new(4, 2, true, QuantSpec::None);
        // quant-free manifests keep the exact pre-quantization bytes
        assert_eq!(spec_json(&default), r#"{"dim":4,"index":"flat","shards":2}"#);
        let sq8 = CollectionSpec::new(8, 4, true, QuantSpec::Sq8 { overscan: 6 });
        let manifest = spec_json(&sq8);
        let back = parse_spec(manifest.as_bytes(), &default).unwrap();
        assert_eq!(back, sq8);
    }

    #[test]
    fn spec_json_round_trips_tuning_fields_and_omits_defaults() {
        let default = CollectionSpec::new(4, 2, true, QuantSpec::None);
        // untuned manifests keep the exact pre-scan-pool bytes
        assert!(!spec_json(&default).contains("scan_workers"));
        assert!(!spec_json(&default).contains("memory_quota"));
        let mut tuned = CollectionSpec::new(4, 2, true, QuantSpec::None);
        tuned.memory_quota = 1 << 20;
        tuned.scan_workers = 4;
        assert_eq!(
            spec_json(&tuned),
            r#"{"dim":4,"index":"flat","memory_quota":1048576,"scan_workers":4,"shards":2}"#
        );
        let back = parse_spec(spec_json(&tuned).as_bytes(), &default).unwrap();
        assert_eq!(back, tuned);
        // explicit zeros are accepted (they mean "unlimited" / "auto")
        let spec = parse_spec(br#"{"memory_quota":0,"scan_workers":0}"#, &tuned).unwrap();
        assert_eq!(spec.memory_quota, 0);
        assert_eq!(spec.scan_workers, 0);
        let err = parse_spec(br#"{"scan_workers":-1}"#, &default).unwrap_err();
        assert_eq!(err.code, ApiCode::BadRequest);
        let err = parse_spec(br#"{"memory_quota":"big"}"#, &default).unwrap_err();
        assert_eq!(err.code, ApiCode::BadRequest);
    }

    #[test]
    fn sq8_collection_serves_exact_results_over_v2() {
        let m = manager();
        let (st, body) = send(
            &m,
            "PUT",
            "/v2/collections/q8",
            r#"{"dim":4,"index":"flat","quant":"sq8","overscan":4}"#,
        );
        assert_eq!(st, 200, "{body}");
        send(&m, "PUT", "/v2/collections/plain", r#"{"dim":4,"index":"flat"}"#);
        for i in 0..12u64 {
            let body = format!(r#"{{"id":{i},"vector":[{},0.5,-0.25,1.0]}}"#, (i as f32) * 0.125);
            let (st, _) = send(&m, "POST", "/v2/collections/q8/insert", &body);
            assert_eq!(st, 200);
            let (st, _) = send(&m, "POST", "/v2/collections/plain/insert", &body);
            assert_eq!(st, 200);
        }
        // the quant spec is configuration: like index kind or shard
        // count it is encoded in the state bytes, so the roots differ —
        // deterministically (the derived codes never reach the bytes)
        let rq = m.get("q8").unwrap().with_sharded(|sk| sk.root_hash());
        let rp = m.get("plain").unwrap().with_sharded(|sk| sk.root_hash());
        assert_ne!(rq, rp, "quant spec is config and must be part of the root");
        // ...and identical query results (two-phase re-rank is exact)
        let q = r#"{"vector":[0.25,0.5,-0.25,1.0],"k":3}"#;
        let (st, hq) = send(&m, "POST", "/v2/collections/q8/query", q);
        assert_eq!(st, 200);
        let (_, hp) = send(&m, "POST", "/v2/collections/plain/query", q);
        assert_eq!(hq.get("data"), hp.get("data"), "sq8 hits diverged from exact");
        // summary advertises the tier
        let (_, s) = send(&m, "GET", "/v2/collections/q8", "");
        assert_eq!(s.get("data").get("quant").as_str(), Some("sq8"));
        let (_, s) = send(&m, "GET", "/v2/collections/plain", "");
        assert_eq!(s.get("data").get("quant").as_str(), Some("none"));
    }

    #[test]
    fn stats_reports_governor_memory_and_eviction() {
        let m = manager();
        send(&m, "POST", "/v2/collections/default/insert", r#"{"id":1,"vector":[0,0,0,0]}"#);
        send(&m, "POST", "/v2/collections/default/insert", r#"{"id":2,"vector":[1,0,0,0]}"#);
        let (st, body) = send(&m, "GET", "/v2/collections/default/stats", "");
        assert_eq!(st, 200);
        let data = body.get("data");
        assert_eq!(data.get("evicted").as_bool(), Some(false));
        // 2 vectors x dim 4 x 4 bytes, no code arena on a quant-free tenant
        let mem = data.get("memory_bytes");
        assert_eq!(mem.get("exact_arena").as_i64(), Some(32));
        assert_eq!(mem.get("code_arena").as_i64(), Some(0));
        assert_eq!(mem.get("total").as_i64(), Some(32));
        // governor is off: fresh-burst bucket, zero counters
        let gov = data.get("governor");
        assert_eq!(gov.get("enabled").as_bool(), Some(false));
        assert_eq!(gov.get("available_tokens").as_i64(), Some(1));
        assert_eq!(gov.get("in_flight").as_i64(), Some(0));
        assert_eq!(gov.get("rate_limited").as_i64(), Some(0));
        assert_eq!(gov.get("quota_rejected").as_i64(), Some(0));
        // a quantized tenant reports both arenas
        send(&m, "PUT", "/v2/collections/q8", r#"{"dim":4,"quant":"sq8"}"#);
        send(&m, "POST", "/v2/collections/q8/insert", r#"{"id":1,"vector":[0.5,0,0,0]}"#);
        let (_, body) = send(&m, "GET", "/v2/collections/q8/stats", "");
        let mem = body.get("data").get("memory_bytes");
        assert_eq!(mem.get("exact_arena").as_i64(), Some(16));
        assert_eq!(mem.get("code_arena").as_i64(), Some(4));
        assert_eq!(mem.get("total").as_i64(), Some(20));
        // untuned tenants advertise the defaults
        assert_eq!(mem.get("quota").as_i64(), Some(0));
        assert_eq!(body.get("data").get("scan_workers").as_i64(), Some(0));
    }

    #[test]
    fn scan_workers_and_memory_quota_ride_the_put_body() {
        let m = manager();
        let (st, body) = send(
            &m,
            "PUT",
            "/v2/collections/tuned",
            r#"{"dim":4,"memory_quota":100,"scan_workers":2}"#,
        );
        assert_eq!(st, 200, "{body}");
        // dim 4 => 16 arena bytes per vector: six fit under 100 bytes
        for i in 1..=6u64 {
            let body = format!(r#"{{"id":{i},"vector":[{},0.5,-0.25,1.0]}}"#, (i as f32) * 0.125);
            let (st, _) = send(&m, "POST", "/v2/collections/tuned/insert", &body);
            assert_eq!(st, 200);
        }
        let (st, body) =
            send(&m, "POST", "/v2/collections/tuned/insert", r#"{"id":7,"vector":[0,0,0,0]}"#);
        assert_eq!(st, 429, "{body}");
        assert_eq!(body.get("error").get("code").as_i64(), Some(1602));
        assert_eq!(body.get("error").get("name").as_str(), Some("memory_quota_exceeded"));
        // stats surface both knobs
        let (_, s) = send(&m, "GET", "/v2/collections/tuned/stats", "");
        assert_eq!(s.get("data").get("memory_bytes").get("quota").as_i64(), Some(100));
        assert_eq!(s.get("data").get("scan_workers").as_i64(), Some(2));
        // the scan override is read-path tuning: queries still serve
        let (st, hits) =
            send(&m, "POST", "/v2/collections/tuned/query", r#"{"vector":[0.2,0.5,-0.25,1],"k":3}"#);
        assert_eq!(st, 200);
        assert_eq!(hits.get("data").as_array().map(|a| a.len()), Some(3));
    }

    #[test]
    fn proof_route_receipt_membership_and_repair() {
        use crate::proof::{verify_membership, verify_receipt, MembershipProof, Receipt};
        let m = manager();
        for i in 1..=10u64 {
            let body = format!(r#"{{"id":{i},"vector":[{},0.5,-0.25,1.0]}}"#, (i as f32) * 0.125);
            let (st, _) = send(&m, "POST", "/v2/collections/default/insert", &body);
            assert_eq!(st, 200);
        }
        // bare proof = the state receipt, internally consistent offline
        let (st, body) = send(&m, "GET", "/v2/collections/default/proof", "");
        assert_eq!(st, 200, "{body}");
        let receipt = Receipt::from_json(body.get("data")).expect("receipt wire shape");
        assert!(verify_receipt(&receipt).is_ok());
        assert_eq!(receipt.seq, 10);
        assert_eq!(receipt.shard_roots.len(), 4);
        // ?id → membership proof that verifies against the receipt
        let (st, body) = send(&m, "GET", "/v2/collections/default/proof?id=3", "");
        assert_eq!(st, 200, "{body}");
        let proof = MembershipProof::from_json(body.get("data")).expect("proof wire shape");
        assert!(verify_membership(&proof, &receipt).is_ok());
        // single-bit tamper in the leaf must be rejected
        let mut bad = proof.clone();
        bad.record[1] ^= 0x01;
        assert!(verify_membership(&bad, &receipt).is_err());
        // never-inserted id → 1002
        let (st, body) = send(&m, "GET", "/v2/collections/default/proof?id=999", "");
        assert_eq!(st, 404, "{body}");
        assert_eq!(body.get("error").get("code").as_i64(), Some(1002));
        // bisection window: leaf level of the proof's own shard
        let target = format!("/v2/collections/default/proof?shard={}&level=0", proof.shard);
        let (st, body) = send(&m, "GET", &target, "");
        assert_eq!(st, 200, "{body}");
        let data = body.get("data");
        assert_eq!(data.get("capacity").as_u64(), Some(proof.capacity));
        assert_eq!(data.get("count").as_u64(), Some(proof.capacity));
        assert_eq!(
            data.get("hashes").as_array().map(|a| a.len()),
            Some(proof.capacity as usize)
        );
        let (st, body) = send(&m, "GET", "/v2/collections/default/proof?shard=99", "");
        assert_eq!(st, 400, "{body}");
        assert_eq!(body.get("error").get("code").as_i64(), Some(1701));
        // ?shard&slot serves the canonical leaf encoding the proof carries
        let target =
            format!("/v2/collections/default/proof?shard={}&slot={}", proof.shard, proof.slot);
        let (st, body) = send(&m, "GET", &target, "");
        assert_eq!(st, 200, "{body}");
        assert_eq!(body.get("data").get("record").as_str(), Some(hex_encode(&proof.record).as_str()));
        // repair round-trip with the record's own canonical bytes is a no-op
        let repair = format!(
            r#"{{"shard":{},"slot":{},"record":"{}"}}"#,
            proof.shard,
            proof.slot,
            hex_encode(&proof.record)
        );
        let (st, body) = send(&m, "POST", "/v2/collections/default/repair", &repair);
        assert_eq!(st, 200, "{body}");
        let data = body.get("data");
        assert_eq!(data.get("repaired").as_bool(), Some(true));
        assert_eq!(
            data.get("merkle_root").as_str(),
            Some(crate::hash::hex_lower(&receipt.merkle_root).as_str())
        );
        // malformed record hex → 1700, id/slot mismatch → 1702
        let (st, body) = send(
            &m,
            "POST",
            "/v2/collections/default/repair",
            r#"{"shard":0,"slot":0,"record":"zz"}"#,
        );
        assert_eq!(st, 400, "{body}");
        assert_eq!(body.get("error").get("code").as_i64(), Some(1700));
        let wrong_id = format!(
            r#"{{"shard":{},"slot":{},"record":"{}"}}"#,
            proof.shard,
            proof.slot,
            hex_encode(
                &crate::proof::LeafRecord { id: 999, body: crate::proof::LeafBody::Tombstone }
                    .encode()
            )
        );
        let (st, body) = send(&m, "POST", "/v2/collections/default/repair", &wrong_id);
        assert_eq!(st, 409, "{body}");
        assert_eq!(body.get("error").get("code").as_i64(), Some(1702));
    }
}
