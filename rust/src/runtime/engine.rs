//! PJRT engine: HLO-text loading, compilation and execution.
//!
//! Thin, typed wrapper over the `xla` crate following the pattern in
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! The engine is deliberately **not** `Sync`: PJRT client handles are raw
//! pointers. The node layer gives the engine to a dedicated model thread
//! and feeds it through the batcher's channel (see [`crate::node`]), which
//! is also the right serving shape — one compiled executable, one queue.

#![forbid(unsafe_code)]

use super::xla_stub as xla;
use crate::Error;
use std::path::Path;

/// A compiled computation ready to execute.
pub struct LoadedComputation {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl LoadedComputation {
    /// Execute with literal inputs; returns the first element of the
    /// result tuple (aot.py lowers with return_tuple=True).
    pub fn run(&self, args: &[xla::Literal]) -> crate::Result<xla::Literal> {
        self.run_impl(self.exe.execute::<xla::Literal>(args))
    }

    /// Same as [`Self::run`] for borrowed literals (weights reused across
    /// calls without cloning).
    pub fn run_borrowed(&self, args: &[&xla::Literal]) -> crate::Result<xla::Literal> {
        self.run_impl(self.exe.execute::<&xla::Literal>(args))
    }

    fn run_impl(
        &self,
        result: Result<Vec<Vec<xla::PjRtBuffer>>, xla::Error>,
    ) -> crate::Result<xla::Literal> {
        let result = result.map_err(|e| Error::Runtime(format!("{}: execute: {e}", self.name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("{}: to_literal: {e}", self.name)))?;
        lit.to_tuple1().map_err(|e| Error::Runtime(format!("{}: tuple: {e}", self.name)))
    }
}

/// PJRT CPU client + loader.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> crate::Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt cpu: {e}")))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> crate::Result<LoadedComputation> {
        let path = path.as_ref();
        let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("hlo").to_string();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| Error::Runtime(format!("{name}: parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("{name}: compile: {e}")))?;
        Ok(LoadedComputation { exe, name })
    }
}

/// Literal construction helpers (shape-checked).
pub fn literal_f32(data: &[f32], dims: &[usize]) -> crate::Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| Error::Runtime(format!("literal f32 reshape: {e}")))
}

pub fn literal_i32(data: &[i32], dims: &[usize]) -> crate::Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| Error::Runtime(format!("literal i32 reshape: {e}")))
}

/// The integer distance executables (E9 / hot-path offload).
///
/// Wraps `distance_q16_l2.hlo.txt` / `distance_q16_dot.hlo.txt`: fixed
/// AOT shape `(query: i32[D], db: i32[N, D]) -> i64[N]`; callers pad the
/// database to N rows.
pub struct DistanceEngine {
    l2: LoadedComputation,
    dot: LoadedComputation,
    f32_l2: LoadedComputation,
    pub dim: usize,
    pub db_rows: usize,
}

impl DistanceEngine {
    pub fn load(engine: &Engine, artifacts_dir: impl AsRef<Path>, dim: usize, db_rows: usize) -> crate::Result<Self> {
        let dir = artifacts_dir.as_ref();
        Ok(Self {
            l2: engine.load_hlo(dir.join("distance_q16_l2.hlo.txt"))?,
            dot: engine.load_hlo(dir.join("distance_q16_dot.hlo.txt"))?,
            f32_l2: engine.load_hlo(dir.join("distance_f32_l2.hlo.txt"))?,
            dim,
            db_rows,
        })
    }

    fn pad_db_i32(&self, db: &[i32]) -> Vec<i32> {
        let mut padded = db.to_vec();
        padded.resize(self.db_rows * self.dim, 0);
        padded
    }

    /// Q16.16 squared-L2 distances of `query` against up to `db_rows`
    /// database vectors (row-major `db`, n = db.len()/dim rows). Returns
    /// one i64 per real row.
    pub fn l2sq_q16(&self, query: &[i32], db: &[i32]) -> crate::Result<Vec<i64>> {
        self.run_int(&self.l2, query, db)
    }

    /// Q16.16 dot products (same layout as [`Self::l2sq_q16`]).
    pub fn dot_q16(&self, query: &[i32], db: &[i32]) -> crate::Result<Vec<i64>> {
        self.run_int(&self.dot, query, db)
    }

    fn run_int(
        &self,
        comp: &LoadedComputation,
        query: &[i32],
        db: &[i32],
    ) -> crate::Result<Vec<i64>> {
        assert_eq!(query.len(), self.dim);
        assert!(db.len() % self.dim == 0 && db.len() <= self.db_rows * self.dim);
        let n = db.len() / self.dim;
        let q = literal_i32(query, &[self.dim])?;
        let d = literal_i32(&self.pad_db_i32(db), &[self.db_rows, self.dim])?;
        let out = comp.run(&[q, d])?;
        let mut v = out
            .to_vec::<i64>()
            .map_err(|e| Error::Runtime(format!("distance output: {e}")))?;
        v.truncate(n);
        Ok(v)
    }

    /// Float baseline distances (the divergence-prone path).
    pub fn l2sq_f32(&self, query: &[f32], db: &[f32]) -> crate::Result<Vec<f32>> {
        assert_eq!(query.len(), self.dim);
        let n = db.len() / self.dim;
        let mut padded = db.to_vec();
        padded.resize(self.db_rows * self.dim, 0.0);
        let q = literal_f32(query, &[self.dim])?;
        let d = literal_f32(&padded, &[self.db_rows, self.dim])?;
        let out = self.f32_l2.run(&[q, d])?;
        let mut v =
            out.to_vec::<f32>().map_err(|e| Error::Runtime(format!("distance output: {e}")))?;
        v.truncate(n);
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir};

    #[test]
    fn engine_boots_cpu() {
        // With a real PJRT client linked, the CPU platform must boot; with
        // the offline stub, the failure must be loud and descriptive so
        // callers can degrade gracefully (vector-only serving).
        match Engine::cpu() {
            Ok(e) => assert!(!e.platform().is_empty()),
            Err(e) => assert!(e.to_string().contains("PJRT"), "unexpected: {e}"),
        }
    }

    #[test]
    fn literal_helpers_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let l = literal_i32(&[1, -2, 3, -4, 5, -6], &[3, 2]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, -2, 3, -4, 5, -6]);
    }

    #[test]
    fn distance_engine_matches_native_rust() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let dir = artifacts_dir();
        let m = crate::runtime::Manifest::load(&dir).unwrap();
        let engine = Engine::cpu().unwrap();
        let de = DistanceEngine::load(&engine, &dir, m.model.d_model, m.model.db_rows).unwrap();

        // deterministic pseudo-random Q16.16 vectors within the contract
        let mut rng = crate::hash::XorShift64::new(42);
        let dim = m.model.d_model;
        let n = 100;
        let db: Vec<i32> =
            (0..n * dim).map(|_| (rng.next_f64() * 131072.0 - 65536.0) as i32).collect();
        let query: Vec<i32> =
            (0..dim).map(|_| (rng.next_f64() * 131072.0 - 65536.0) as i32).collect();

        let xla_l2 = de.l2sq_q16(&query, &db).unwrap();
        let xla_dot = de.dot_q16(&query, &db).unwrap();
        assert_eq!(xla_l2.len(), n);
        for row in 0..n {
            let r = &db[row * dim..(row + 1) * dim];
            // E9: BIT-IDENTICAL across implementations (Rust vs XLA/Pallas)
            assert_eq!(xla_l2[row], crate::distance::l2sq_q16(&query, r), "l2 row {row}");
            assert_eq!(xla_dot[row], crate::distance::dot_q16(&query, r), "dot row {row}");
        }
    }
}
