//! Minimal JSON parser + writer (no serde offline).
//!
//! Used for two things: reading `artifacts/manifest.json` (the weight
//! manifest aot.py writes) and the node's HTTP API bodies. Supports the
//! full JSON value model; numbers are kept as f64 (plus an exact i64 fast
//! path for integers, which the API uses for ids).

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is canonical.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integer that fits i64 exactly (ids, counts).
    Int(i64),
    /// Any other number.
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Float(f) if f.fract() == 0.0 && f.abs() < 9.0e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_object().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    pub fn object(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Int(i) => write!(f, "{i}"),
            Json::Float(x) => {
                if x.is_finite() {
                    write!(f, "{x}")
                } else {
                    write!(f, "null") // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Object(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage is an error).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { offset: self.pos, message: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(format!("invalid literal (expected {word})")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(out));
        }
        loop {
            let val = self.value(depth + 1)?;
            out.push(val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    if self.peek() != Some(b'u') {
                                        return Err(self.err("expected low surrogate"));
                                    }
                                    self.pos += 1;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00));
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("invalid surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                            };
                            out.push(c);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(s).map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("unescaped control character"));
                    }
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Float)
            .map_err(|_| JsonError { offset: start, message: "invalid number".into() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("1.5").unwrap(), Json::Float(1.5));
        assert_eq!(parse("1e3").unwrap(), Json::Float(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").as_array().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"c\" \\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" \\ A 😀");
    }

    #[test]
    fn parse_unicode_escape_and_surrogates() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
        assert_eq!(parse(r#""😀""#).unwrap().as_str(), Some("😀"));
        assert!(parse(r#""\ud83d""#).is_err()); // lone high surrogate
    }

    #[test]
    fn parse_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("\"\u{0001}\"").is_err()); // raw control char
    }

    #[test]
    fn deep_nesting_bounded() {
        let s = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&s).is_err());
        let ok = "[".repeat(50) + &"]".repeat(50);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"arr":[1,2.5,null,true,"s"],"n":-3}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn display_escapes() {
        let v = Json::str("a\"b\\c\nd");
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn object_builder_and_accessors() {
        let v = Json::object(vec![
            ("id", Json::Int(7)),
            ("score", Json::Float(0.5)),
            ("name", Json::str("x")),
        ]);
        assert_eq!(v.get("id").as_u64(), Some(7));
        assert_eq!(v.get("score").as_f64(), Some(0.5));
        assert_eq!(v.get("id").as_f64(), Some(7.0));
        assert_eq!(v.get("name").as_bool(), None);
    }

    #[test]
    fn non_finite_floats_serialize_as_null() {
        assert_eq!(Json::Float(f64::NAN).to_string(), "null");
        assert_eq!(Json::Float(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn i64_bounds() {
        assert_eq!(parse("9223372036854775807").unwrap(), Json::Int(i64::MAX));
        // overflows i64 -> becomes float
        assert!(matches!(parse("9223372036854775808").unwrap(), Json::Float(_)));
    }
}
