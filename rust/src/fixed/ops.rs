//! Vector-level fixed-point helpers shared by the kernel hot paths.
//!
//! These are the operations the paper's §5.1 "Operations" paragraph
//! describes: element-wise saturating arithmetic plus wide-accumulator
//! reductions. They are deliberately written as plain indexed loops over
//! slices — LLVM auto-vectorizes them with *integer* SIMD, which is exact
//! and order-independent (integer addition is associative), so the
//! vectorized code is still bit-identical to the scalar loop. This is the
//! crucial asymmetry with floats the paper exploits.

#![forbid(unsafe_code)]

use super::format::FixedFormat;
use super::isqrt::{isqrt_u128, isqrt_u64};

/// Element-wise saturating addition `out[i] = a[i] + b[i]`.
pub fn add_into<F: FixedFormat>(a: &[F::Raw], b: &[F::Raw], out: &mut [F::Raw]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    for i in 0..a.len() {
        out[i] = F::sat_add(a[i], b[i]);
    }
}

/// Element-wise saturating subtraction.
pub fn sub_into<F: FixedFormat>(a: &[F::Raw], b: &[F::Raw], out: &mut [F::Raw]) {
    debug_assert!(a.len() == b.len() && a.len() == out.len());
    for i in 0..a.len() {
        out[i] = F::sat_sub(a[i], b[i]);
    }
}

/// Scale every element by a fixed-point factor.
pub fn scale_into<F: FixedFormat>(a: &[F::Raw], k: F::Raw, out: &mut [F::Raw]) {
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = F::sat_mul(a[i], k);
    }
}

/// Squared L2 norm as a wide Q(2m).(2n) value.
pub fn norm_sq_wide<F: FixedFormat>(v: &[F::Raw]) -> F::Wide {
    F::dot_wide(v, v)
}

/// Fixed-point L2 norm of a Q16.16-family vector, returned in raw Qm.n.
///
/// `norm = isqrt(Σ vᵢ²)` — the sum is Q(2m).(2n), whose integer square root
/// is exactly a Qm.n value. Integer-only, hence deterministic.
pub fn norm_q16(v: &[i32]) -> i32 {
    let mut acc: i64 = 0;
    for &x in v {
        acc = acc.saturating_add((x as i64) * (x as i64));
    }
    // acc >= 0 always (sum of squares, saturating at i64::MAX)
    let r = isqrt_u64(acc as u64);
    if r > i32::MAX as u64 {
        i32::MAX
    } else {
        r as i32
    }
}

/// Fixed-point L2 norm for the Q32.32 contract.
pub fn norm_q32(v: &[i64]) -> i64 {
    let mut acc: i128 = 0;
    for &x in v {
        acc = acc.saturating_add((x as i128) * (x as i128));
    }
    let r = isqrt_u128(acc as u128);
    if r > i64::MAX as u128 {
        i64::MAX
    } else {
        r as i64
    }
}

/// In-place fixed-point L2 normalization for 32-bit formats
/// (`v[i] = (v[i] << FRAC) / norm`). No-op on the zero vector.
///
/// After normalization `Σ vᵢ² ≈ 1.0` with error bounded by the format
/// resolution times the dimension (each element suffers one truncating
/// division).
pub fn normalize_q16(v: &mut [i32]) {
    let n = norm_q16(v);
    if n == 0 {
        return;
    }
    for x in v.iter_mut() {
        let num = (*x as i64) << 16;
        let q = num / (n as i64);
        *x = if q > i32::MAX as i64 {
            i32::MAX
        } else if q < i32::MIN as i64 {
            i32::MIN
        } else {
            q as i32
        };
    }
}

/// Generic saturating sum of raw values in the wide domain (useful for
/// metadata aggregation and tests).
pub fn sum_wide<F: FixedFormat>(v: &[F::Raw]) -> F::Wide {
    let mut acc = F::wide_zero();
    for &x in v {
        acc = F::wide_add(acc, F::widening_mul(x, F::raw_one()));
    }
    // The product x * one is x << FRAC_BITS, i.e. the raw value promoted to
    // the wide Q(2m).(2n) representation.
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::format::{Q16_16, Q32_32};

    fn q(x: f64) -> i32 {
        Q16_16::quantize(x)
    }

    #[test]
    fn add_sub_roundtrip() {
        let a = vec![q(1.0), q(-2.0), q(0.5)];
        let b = vec![q(0.5), q(2.0), q(0.25)];
        let mut s = vec![0; 3];
        let mut d = vec![0; 3];
        add_into::<Q16_16>(&a, &b, &mut s);
        sub_into::<Q16_16>(&s, &b, &mut d);
        assert_eq!(d, a);
    }

    #[test]
    fn scale_by_half() {
        let a = vec![q(2.0), q(-4.0)];
        let mut out = vec![0; 2];
        scale_into::<Q16_16>(&a, q(0.5), &mut out);
        assert_eq!(out, vec![q(1.0), q(-2.0)]);
    }

    #[test]
    fn norm_of_unit_axis() {
        let v = vec![q(1.0), 0, 0];
        assert_eq!(norm_q16(&v), q(1.0));
    }

    #[test]
    fn norm_345() {
        let v = vec![q(3.0), q(4.0)];
        assert_eq!(norm_q16(&v), q(5.0));
    }

    #[test]
    fn normalize_makes_unit_norm() {
        let mut v = vec![q(3.0), q(4.0), q(0.0), q(-12.0)];
        normalize_q16(&mut v);
        let n2 = Q16_16::wide_to_f64(norm_sq_wide::<Q16_16>(&v));
        assert!((n2 - 1.0).abs() < 1e-3, "norm² = {n2}");
    }

    #[test]
    fn normalize_zero_vector_is_noop() {
        let mut v = vec![0i32; 8];
        normalize_q16(&mut v);
        assert!(v.iter().all(|&x| x == 0));
    }

    #[test]
    fn norm_q32_345() {
        let q32 = |x: f64| Q32_32::quantize(x);
        let v = vec![q32(3.0), q32(4.0)];
        assert_eq!(norm_q32(&v), q32(5.0));
    }

    #[test]
    fn normalize_is_deterministic_replay() {
        // Same input normalized twice from scratch gives identical bits.
        let base: Vec<i32> = (0..128).map(|i| q(((i * 37) % 100) as f64 / 100.0 - 0.5)).collect();
        let mut a = base.clone();
        let mut b = base.clone();
        normalize_q16(&mut a);
        normalize_q16(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn sum_wide_promotes() {
        let v = vec![q(1.0), q(2.0), q(-0.5)];
        let s = sum_wide::<Q16_16>(&v);
        assert_eq!(Q16_16::wide_to_f64(s), 2.5);
    }
}
