//! Integration: per-tenant resource governance (ISSUE 6).
//!
//! 1. Admission control lives strictly *outside* the state machine: a
//!    throttled-and-retried workload replays to a root hash
//!    bit-identical to an unthrottled sequential mirror, and the 1600
//!    envelope carries a usable `retry_after_ms`.
//! 2. Rate-limit and quota rejections surface in the right shape on
//!    both API versions (typed `/v2` envelope, legacy `/v1` object) and
//!    never govern the health routes.
//! 3. Idle-collection eviction closes a durable tenant and rehydrates
//!    it lazily on next touch with `/v2/hash` stable throughout.
//! 4. Restore ingest for distinct tenants proceeds concurrently, and
//!    abandoned restore sessions are reaped by the idle sweep.
//! 5. Per-tenant transfer caps pace a snapshot stream without changing
//!    a single byte of it.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};
use valori::api::ApiCode;
use valori::http::{client, Request};
use valori::index::QuantSpec;
use valori::json::{parse, Json};
use valori::node::{
    route_collections, serve_collections, Admission, CollectionManager, CollectionSpec,
    GovernorConfig, ManagerConfig,
};
use valori::state::{Command, KernelConfig, ShardedKernel};

fn spec(dim: usize, shards: u32) -> CollectionSpec {
    CollectionSpec::new(dim, shards, true, QuantSpec::None)
}

fn governed(
    spec: CollectionSpec,
    governor: GovernorConfig,
    data_dir: Option<std::path::PathBuf>,
) -> Arc<CollectionManager> {
    Arc::new(
        CollectionManager::new(
            ManagerConfig { spec, workers: 2, data_dir, default_wal: None, governor },
            None,
        )
        .unwrap(),
    )
}

fn vec_for(salt: u64, i: u64, dim: usize) -> Vec<f32> {
    (0..dim as u64)
        .map(|j| (((salt * 7919 + i * dim as u64 + j) as f32) * 0.0137).sin() * 0.8)
        .collect()
}

fn insert_body(id: u64, v: &[f32]) -> Json {
    Json::object(vec![
        ("id", Json::Int(id as i64)),
        ("vector", Json::Array(v.iter().map(|&x| Json::Float(x as f64)).collect())),
    ])
}

/// Route a request in-process (bypasses the front end — and therefore
/// admission; used where governance itself is not under test).
fn send(m: &CollectionManager, method: &str, target: &str, body: Vec<u8>) -> (u16, Json) {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    let req = Request { method: method.into(), path, query, headers: Default::default(), body };
    let resp = route_collections(m, req);
    let json = std::str::from_utf8(&resp.body)
        .ok()
        .and_then(|t| parse(t).ok())
        .unwrap_or(Json::Null);
    (resp.status, json)
}

/// Drain a snapshot route's streaming response into one byte vector.
fn snapshot_stream_via_route(m: &CollectionManager, target: &str) -> Vec<u8> {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    let req = Request {
        method: "GET".into(),
        path,
        query,
        headers: Default::default(),
        body: Vec::new(),
    };
    let resp = route_collections(m, req);
    assert_eq!(resp.status, 200);
    let stream = resp.stream.expect("snapshot responses stream their body");
    let mut out = Vec::new();
    while let Some(block) = stream.next_block() {
        out.extend_from_slice(&block);
    }
    out
}

/// POST over a fresh connection, retrying 429s with the server-provided
/// backoff until admitted. Returns how many throttles were absorbed and
/// whether every 1600 rejection carried a positive `retry_after_ms`.
fn post_until_admitted(addr: &std::net::SocketAddr, path: &str, body: &Json) -> (u64, bool) {
    let mut throttles = 0u64;
    loop {
        let (st, resp) = client::post_json(addr, path, body).unwrap();
        if st == 200 {
            return (throttles, true);
        }
        assert_eq!(st, 429, "unexpected rejection: {resp}");
        throttles += 1;
        let err = resp.get("error");
        assert_eq!(err.get("code").as_i64(), Some(1600), "{resp}");
        let ms = err.get("retry_after_ms").as_u64();
        let Some(ms) = ms else {
            return (throttles, false);
        };
        assert!(ms >= 1, "retry_after_ms must be at least 1ms");
        std::thread::sleep(Duration::from_millis(ms.clamp(1, 1000)));
    }
}

#[test]
fn throttled_and_retried_workload_replays_bit_identical() {
    // A rate small enough that a burst of 60 inserts must absorb many
    // 429s, large enough that the test converges in a couple of seconds.
    let manager = governed(
        spec(4, 2),
        GovernorConfig { rate_limit: Some(30), ..Default::default() },
        None,
    );
    let server = serve_collections(Arc::clone(&manager), "127.0.0.1:0", 2).unwrap();
    let addr = server.addr();

    // The unthrottled reference: the same commands, applied sequentially
    // with no admission control anywhere near them.
    let mut mirror = ShardedKernel::new(KernelConfig::default_q16(4).with_flat_index(), 2);
    let mut throttled = 0u64;
    for i in 0..60u64 {
        let v = vec_for(5, i, 4);
        let (absorbed, retry_after_present) =
            post_until_admitted(&addr, "/v2/collections/default/insert", &insert_body(i, &v));
        throttled += absorbed;
        assert!(retry_after_present, "every 1600 envelope must carry retry_after_ms");
        mirror.apply(Command::Insert { id: i, vector: v }).unwrap();
    }
    assert!(throttled >= 1, "workload was never throttled — the rate limiter is not engaging");
    assert!(
        manager.http_metrics().requests_rate_limited.load(std::sync::atomic::Ordering::Relaxed)
            >= throttled
    );

    // Throttling changed the *timing* of the workload, never its bits:
    // rejections are not logged, not hashed, and not replayed.
    let root = manager.get("default").unwrap().with_sharded(|sk| sk.root_hash());
    assert_eq!(
        root,
        mirror.root_hash(),
        "throttled-and-retried workload diverged from the unthrottled mirror"
    );
    assert_eq!(
        manager.get("default").unwrap().with_sharded(|sk| sk.len()),
        60,
        "every retried command must land exactly once"
    );
    server.stop();
}

#[test]
fn quota_rejections_surface_on_both_api_versions() {
    let manager = governed(
        spec(4, 1),
        GovernorConfig { quota: Some(1), ..Default::default() },
        None,
    );
    let server = serve_collections(Arc::clone(&manager), "127.0.0.1:0", 2).unwrap();
    let addr = server.addr();

    // Pin the single in-flight slot from the outside, exactly as a
    // stalled admitted request would.
    assert_eq!(manager.governor().admit("default", Instant::now()), Admission::Admit);

    // /v2: typed 1601 envelope, no retry_after_ms (the client must wait
    // for capacity, not a clock).
    let body = insert_body(1, &vec_for(1, 1, 4));
    let (st, resp) = client::post_json(&addr, "/v2/collections/default/insert", &body).unwrap();
    assert_eq!(st, 429, "{resp}");
    assert_eq!(resp.get("error").get("code").as_i64(), Some(1601));
    assert_eq!(resp.get("error").get("name").as_str(), Some("quota_exceeded"));
    assert!(resp.get("error").get("retry_after_ms").as_u64().is_none());

    // /v1: the legacy ad-hoc shape — a plain string error, no taxonomy.
    let (st, resp) = client::post_json(&addr, "/v1/insert", &body).unwrap();
    assert_eq!(st, 429, "{resp}");
    assert!(resp.get("error").as_str().is_some(), "{resp}");
    assert!(resp.get("error").get("code").as_i64().is_none());

    // Health stays reachable while a tenant is saturated.
    for path in ["/v1/health", "/v2/health"] {
        let (st, _) = client::get_json(&addr, path).unwrap();
        assert_eq!(st, 200, "{path} must never be governed");
    }
    assert!(
        manager.http_metrics().requests_quota_rejected.load(std::sync::atomic::Ordering::Relaxed)
            >= 2
    );

    // Releasing the slot readmits immediately — no token clock involved.
    manager.governor().release("default");
    let (st, resp) = client::post_json(&addr, "/v2/collections/default/insert", &body).unwrap();
    assert_eq!(st, 200, "{resp}");
    server.stop();
}

#[test]
fn rate_limit_rejection_carries_backoff_on_the_legacy_surface() {
    let manager = governed(
        spec(4, 1),
        GovernorConfig { rate_limit: Some(1), ..Default::default() },
        None,
    );
    let server = serve_collections(Arc::clone(&manager), "127.0.0.1:0", 2).unwrap();
    let addr = server.addr();

    // Burst is one request at rate 1/s: the first is admitted…
    let body = insert_body(1, &vec_for(2, 1, 4));
    let (st, resp) = client::post_json(&addr, "/v1/insert", &body).unwrap();
    assert_eq!(st, 200, "{resp}");
    // …and an immediate second one is throttled with a legacy-shaped
    // body that still tells the client how long to back off.
    let (st, resp) = client::get_json(&addr, "/v1/hash").unwrap();
    assert_eq!(st, 429, "{resp}");
    assert!(resp.get("error").as_str().is_some(), "{resp}");
    let ms = resp.get("retry_after_ms").as_u64().expect("legacy 429 carries retry_after_ms");
    assert!((1..=1000).contains(&ms), "rate 1/s deficit is at most one second, got {ms}");
    // Honouring the backoff readmits.
    std::thread::sleep(Duration::from_millis(ms + 50));
    let (st, resp) = client::get_json(&addr, "/v1/hash").unwrap();
    assert_eq!(st, 200, "{resp}");
    server.stop();
}

#[test]
fn idle_tenant_evicts_then_rehydrates_with_root_intact() {
    let dir = std::env::temp_dir().join(format!("valori_governance_evict_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let manager = governed(
        spec(4, 2),
        GovernorConfig { idle_ttl: Some(Duration::from_secs(1)), ..Default::default() },
        Some(dir.clone()),
    );
    manager.create("t", spec(4, 2)).unwrap();
    let root_before = {
        let state = manager.get("t").unwrap();
        for i in 0..25u64 {
            state.apply(Command::insert(i, vec![0.3, i as f32 * 0.02, 0.0, 0.0])).unwrap();
        }
        state.with_sharded(|sk| sk.root_hash())
        // the Arc drops here: the WAL handle must not be shared with a
        // later rehydration replay
    };
    let combined_before = manager.combined_root();
    let (st, hash_before) = send(&manager, "GET", "/v2/hash", Vec::new());
    assert_eq!(st, 200);

    // Drive the sweep with a clock far past the TTL.
    let gauge = |c: &std::sync::atomic::AtomicU64| c.load(std::sync::atomic::Ordering::Relaxed);
    manager.sweep_idle(Instant::now() + Duration::from_secs(120));
    assert_eq!(gauge(&manager.http_metrics().collections_evicted), 1, "only 't' evicts");
    assert_eq!(gauge(&manager.http_metrics().collections_rehydrated), 0);

    // Cold state is externally invisible: the tenant still lists, and
    // the combined root is served from the cached per-tenant root.
    assert!(manager.names().contains(&"t".to_string()));
    assert_eq!(manager.len(), 2);
    assert_eq!(manager.combined_root(), combined_before);
    let (st, hash_cold) = send(&manager, "GET", "/v2/hash", Vec::new());
    assert_eq!(st, 200);
    assert_eq!(hash_cold, hash_before, "/v2/hash must be stable across eviction");

    // `default` is never evicted, no matter how idle.
    manager.sweep_idle(Instant::now() + Duration::from_secs(240));
    assert_eq!(gauge(&manager.http_metrics().collections_evicted), 1);

    // First touch rehydrates from spec.json + WAL replay, bit-exact.
    let state = manager.get("t").expect("cold tenant rehydrates on touch");
    assert_eq!(gauge(&manager.http_metrics().collections_rehydrated), 1);
    assert_eq!(state.with_sharded(|sk| sk.root_hash()), root_before);
    assert_eq!(state.with_sharded(|sk| sk.len()), 25);
    assert_eq!(manager.combined_root(), combined_before);

    // The rehydrated tenant is fully live: mutations land in its WAL.
    state.apply(Command::insert(1000, vec![0.9, 0.9, 0.9, 0.9])).unwrap();
    assert_ne!(manager.combined_root(), combined_before);
    drop(state);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_restores_for_distinct_tenants_complete_independently() {
    // Two differently-shaped sources…
    let sources: Vec<(String, Vec<u8>, u64)> = [("alpha", 7u64, 40u64), ("beta", 13, 70)]
        .into_iter()
        .map(|(name, salt, n)| {
            let src = governed(spec(4, 2), GovernorConfig::default(), None);
            let state = src.get("default").unwrap();
            for i in 0..n {
                state.apply(Command::insert(i, vec_for(salt, i, 4))).unwrap();
            }
            let stream =
                snapshot_stream_via_route(&src, "/v2/collections/default/snapshot?chunk=512");
            let root = state.with_sharded(|sk| sk.root_hash());
            (name.to_string(), stream, root)
        })
        .collect();

    // …restored into one manager from two threads at once, in small
    // windows, with a barrier per window to force genuine interleaving.
    let dst = governed(spec(4, 2), GovernorConfig::default(), None);
    let windows = sources.iter().map(|(_, stream, _)| stream.chunks(1500).count()).max().unwrap();
    let rendezvous = Barrier::new(sources.len());
    std::thread::scope(|s| {
        let rendezvous = &rendezvous;
        let dst = &dst;
        for (name, stream, _) in &sources {
            s.spawn(move || {
                let mut offset = 0usize;
                let mut complete = false;
                for round in 0..windows {
                    // every thread hits every rendezvous, fed or not, so
                    // the windows really overlap instead of serializing
                    rendezvous.wait();
                    let window = &stream[offset..(offset + 1500).min(stream.len())];
                    if window.is_empty() {
                        continue;
                    }
                    let body = dst
                        .restore_ingest(name, offset as u64, window)
                        .unwrap_or_else(|e| panic!("{name} window {round}: {e:?}"));
                    offset += window.len();
                    complete = body.get("complete").as_bool() == Some(true);
                }
                assert!(complete, "{name} never completed");
            });
        }
    });
    for (name, _, root) in &sources {
        assert_eq!(
            dst.get(name).unwrap().with_sharded(|sk| sk.root_hash()),
            *root,
            "{name} restored with the wrong root"
        );
    }
    assert_eq!(dst.http_metrics().streams_in_flight.load(std::sync::atomic::Ordering::Relaxed), 0);
}

#[test]
fn abandoned_restore_session_is_reaped_by_the_idle_sweep() {
    let src = governed(spec(4, 1), GovernorConfig::default(), None);
    let stream = snapshot_stream_via_route(&src, "/v2/collections/default/snapshot");
    let m = governed(spec(4, 1), GovernorConfig::default(), None);

    // A clean-but-incomplete prefix leaves a live session behind…
    let body = m.restore_ingest("ghost", 0, &stream[..16]).unwrap();
    assert_eq!(body.get("complete").as_bool(), Some(false));
    let gauge = || m.http_metrics().streams_in_flight.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(gauge(), 1);

    // …which the idle sweep reaps once it ages past the session TTL.
    m.sweep_idle(Instant::now() + Duration::from_secs(601));
    assert_eq!(gauge(), 0, "abandoned session must release the in-flight gauge");

    // The reaped session is really gone: its continuation offset is
    // refused, and the name is free for a fresh offset-0 transfer.
    let err = m.restore_ingest("ghost", 16, &stream[16..]).unwrap_err();
    assert_eq!(err.code, ApiCode::StreamOffsetMismatch);
    let body = m.restore_ingest("ghost", 0, &stream).unwrap();
    assert_eq!(body.get("complete").as_bool(), Some(true));
    assert_eq!(gauge(), 0);
}

#[test]
fn paced_snapshot_stream_is_byte_identical_and_slower() {
    const RATE: u64 = 64 * 1024; // bytes/sec

    // Identical contents behind a paced and an unpaced manager.
    let fill = |m: &CollectionManager| {
        let state = m.get("default").unwrap();
        for i in 0..2000u64 {
            state.apply(Command::insert(i, vec_for(3, i, 8))).unwrap();
        }
    };
    let plain = governed(spec(8, 2), GovernorConfig::default(), None);
    fill(&plain);
    // The chunk size is part of the wire framing — pin it so the paced
    // and unpaced streams are comparable byte for byte.
    let reference =
        snapshot_stream_via_route(&plain, "/v2/collections/default/snapshot?chunk=8192");

    let paced = governed(
        spec(8, 2),
        GovernorConfig { stream_bytes_per_sec: Some(RATE), ..Default::default() },
        None,
    );
    fill(&paced);
    let server = serve_collections(Arc::clone(&paced), "127.0.0.1:0", 2).unwrap();

    // Fetch over a real socket so the front end's pacing engages.
    let mut fetched = Vec::new();
    let started = Instant::now();
    let (status, total, _) = {
        let mut conn = client::Connection::connect(&server.addr()).unwrap();
        let mut sink = |block: &[u8]| -> std::io::Result<()> {
            fetched.extend_from_slice(block);
            Ok(())
        };
        conn.request_streaming("GET", "/v2/collections/default/snapshot?chunk=8192", &[], &mut sink)
            .unwrap()
    };
    let elapsed = started.elapsed();
    assert_eq!(status, 200);
    assert_eq!(total, fetched.len() as u64);

    // Pacing changes only when the bytes arrive, never which bytes.
    assert!(
        fetched == reference,
        "paced stream diverged from the unpaced stream ({} vs {} bytes)",
        fetched.len(),
        reference.len()
    );
    // The transfer cap actually bit: a very generous lower bound (a
    // quarter of the ideal schedule) keeps this robust on slow CI while
    // still catching a pacer that never defers.
    let floor = Duration::from_millis(fetched.len() as u64 * 1000 / RATE / 4);
    assert!(
        elapsed >= floor,
        "{} bytes at {RATE} B/s finished in {elapsed:?} (floor {floor:?}) — pacing is off",
        fetched.len()
    );
    server.stop();
}
