//! The quantization boundary (paper §5.3).
//!
//! "All external inputs — whether originating from Python, HTTP clients, or
//! distributed nodes — are normalized at the kernel boundary into a
//! fixed-point representation with a well-defined precision contract."
//!
//! This module is that boundary: float vectors are validated against a
//! [`ValidationPolicy`] and converted to [`FixedVector`]s. Everything past
//! this point is integer math.

#![forbid(unsafe_code)]

use crate::fixed::{ops, FixedFormat, Q16_16};
use std::fmt;

/// Why a vector was rejected at the boundary.
// lint: float-boundary — rejection reasons echo the offending float back to the client
#[derive(Debug, Clone, PartialEq)]
pub enum BoundaryError {
    /// NaN component at the given index.
    NaN { index: usize },
    /// ±Inf component at the given index.
    Infinite { index: usize },
    /// Component magnitude exceeds the policy bound.
    OutOfRange { index: usize, value: f32, max_abs: f32 },
    /// Vector dimensionality differs from the kernel's configured dim.
    DimensionMismatch { expected: usize, got: usize },
    /// Empty vector.
    Empty,
}

impl fmt::Display for BoundaryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BoundaryError::NaN { index } => write!(f, "NaN at index {index}"),
            BoundaryError::Infinite { index } => write!(f, "non-finite value at index {index}"),
            BoundaryError::OutOfRange { index, value, max_abs } => {
                write!(f, "value {value} at index {index} exceeds |x| <= {max_abs}")
            }
            BoundaryError::DimensionMismatch { expected, got } => {
                write!(f, "dimension mismatch: expected {expected}, got {got}")
            }
            BoundaryError::Empty => write!(f, "empty vector"),
        }
    }
}

impl std::error::Error for BoundaryError {}

/// Boundary validation policy — part of the precision contract (DESIGN §6).
///
/// The magnitude bound is what makes the i64 accumulator contract sound:
/// with `max_abs = 4.0` in Q16.16, raw values are ≤ 2^18, each product is
/// ≤ 2^36, and a dot product over dim ≤ 16384 is ≤ 2^50 ≪ i64::MAX. The
/// same bound is what lets the Pallas int64 kernel match the Rust kernel
/// bit-for-bit (experiment E9).
// lint: float-boundary — admission policy is stated in client units (f32 magnitude)
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ValidationPolicy {
    /// Maximum absolute component value accepted.
    pub max_abs: f32,
    /// If true, the kernel L2-normalizes (fixed-point) after quantization.
    pub normalize: bool,
}

// lint: float-boundary — default admission bound in client units
impl Default for ValidationPolicy {
    fn default() -> Self {
        Self { max_abs: 4.0, normalize: false }
    }
}

// lint: float-boundary — validation IS the boundary: floats are inspected here, then quantized
impl ValidationPolicy {
    /// Policy for pipelines that already normalize embeddings (typical
    /// sentence-transformer deployments, paper §5.1 rationale).
    pub fn normalized_embeddings() -> Self {
        Self { max_abs: 4.0, normalize: true }
    }

    /// Maximum accepted raw Q16.16 magnitude under this policy. Applied to
    /// the canonical/replication ingest path too, so the i64-accumulator
    /// contract (DESIGN §6) holds for every vector in the kernel no matter
    /// how it arrived.
    pub fn max_raw_q16(&self) -> i32 {
        let bound = (self.max_abs as f64 * 65536.0).ceil();
        if bound >= i32::MAX as f64 {
            i32::MAX
        } else {
            bound as i32
        }
    }

    /// Validate an already-quantized vector (canonical ingest path).
    pub fn validate_raw(&self, raw: &[i32], expected_dim: usize) -> Result<(), BoundaryError> {
        if raw.is_empty() {
            return Err(BoundaryError::Empty);
        }
        if raw.len() != expected_dim {
            return Err(BoundaryError::DimensionMismatch { expected: expected_dim, got: raw.len() });
        }
        let bound = self.max_raw_q16();
        for (i, &r) in raw.iter().enumerate() {
            if r.saturating_abs() > bound {
                return Err(BoundaryError::OutOfRange {
                    index: i,
                    value: (r as f64 / 65536.0) as f32,
                    max_abs: self.max_abs,
                });
            }
        }
        Ok(())
    }

    /// Validate a float vector against the policy (dim check included).
    pub fn validate(&self, v: &[f32], expected_dim: usize) -> Result<(), BoundaryError> {
        if v.is_empty() {
            return Err(BoundaryError::Empty);
        }
        if v.len() != expected_dim {
            return Err(BoundaryError::DimensionMismatch { expected: expected_dim, got: v.len() });
        }
        for (i, &x) in v.iter().enumerate() {
            if x.is_nan() {
                return Err(BoundaryError::NaN { index: i });
            }
            if x.is_infinite() {
                return Err(BoundaryError::Infinite { index: i });
            }
            if x.abs() > self.max_abs {
                return Err(BoundaryError::OutOfRange { index: i, value: x, max_abs: self.max_abs });
            }
        }
        Ok(())
    }
}

/// A Q16.16 fixed-point vector — the kernel's canonical vector type.
///
/// (The index and state machine are generic over [`FixedFormat`]; Q16.16 is
/// the reference contract so it gets the concrete convenience type.)
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FixedVector {
    raw: Vec<i32>,
}

// lint: float-boundary — from_f32/to_f32 are the quantization entry and observability exit
impl FixedVector {
    /// Quantize a float vector through the boundary: validate, convert
    /// (round-ties-even, saturating), optionally fixed-point-normalize.
    pub fn from_f32(
        v: &[f32],
        dim: usize,
        policy: &ValidationPolicy,
    ) -> Result<Self, BoundaryError> {
        policy.validate(v, dim)?;
        let mut raw: Vec<i32> = v.iter().map(|&x| Q16_16::quantize(x as f64)).collect();
        if policy.normalize {
            ops::normalize_q16(&mut raw);
        }
        Ok(Self { raw })
    }

    /// Build directly from raw Q16.16 values (trusted path: snapshot
    /// restore, replication — the values were validated when first
    /// inserted).
    pub fn from_raw(raw: Vec<i32>) -> Self {
        Self { raw }
    }

    pub fn raw(&self) -> &[i32] {
        &self.raw
    }

    pub fn dim(&self) -> usize {
        self.raw.len()
    }

    /// Dequantize for observability/debugging (never used in kernel math).
    pub fn to_f32(&self) -> Vec<f32> {
        self.raw.iter().map(|&r| Q16_16::dequantize(r) as f32).collect()
    }

    /// Wide (Q32.32) dot product with another fixed vector.
    pub fn dot_wide(&self, other: &Self) -> i64 {
        Q16_16::dot_wide(&self.raw, &other.raw)
    }

    /// Wide (Q32.32) squared L2 distance.
    pub fn l2sq_wide(&self, other: &Self) -> i64 {
        Q16_16::l2sq_wide(&self.raw, &other.raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_accepts_normalized() {
        let v = vec![0.5f32, -0.5, 0.1, 0.0];
        let fv = FixedVector::from_f32(&v, 4, &ValidationPolicy::default()).unwrap();
        assert_eq!(fv.dim(), 4);
        assert_eq!(fv.raw()[0], 32768);
        assert_eq!(fv.raw()[1], -32768);
    }

    #[test]
    fn boundary_rejects_nan() {
        let v = vec![0.0f32, f32::NAN];
        let err = FixedVector::from_f32(&v, 2, &ValidationPolicy::default()).unwrap_err();
        assert_eq!(err, BoundaryError::NaN { index: 1 });
    }

    #[test]
    fn boundary_rejects_inf() {
        let v = vec![f32::INFINITY, 0.0];
        let err = FixedVector::from_f32(&v, 2, &ValidationPolicy::default()).unwrap_err();
        assert_eq!(err, BoundaryError::Infinite { index: 0 });
    }

    #[test]
    fn boundary_rejects_out_of_range() {
        let v = vec![0.0f32, 5.0];
        let err = FixedVector::from_f32(&v, 2, &ValidationPolicy::default()).unwrap_err();
        assert!(matches!(err, BoundaryError::OutOfRange { index: 1, .. }));
    }

    #[test]
    fn boundary_rejects_dim_mismatch() {
        let v = vec![0.0f32; 3];
        let err = FixedVector::from_f32(&v, 4, &ValidationPolicy::default()).unwrap_err();
        assert_eq!(err, BoundaryError::DimensionMismatch { expected: 4, got: 3 });
    }

    #[test]
    fn boundary_rejects_empty() {
        let err = FixedVector::from_f32(&[], 0, &ValidationPolicy::default()).unwrap_err();
        assert_eq!(err, BoundaryError::Empty);
    }

    #[test]
    fn normalize_policy_normalizes() {
        let v = vec![3.0f32, 4.0];
        let fv = FixedVector::from_f32(&v, 2, &ValidationPolicy::normalized_embeddings()).unwrap();
        let n2 = Q16_16::wide_to_f64(Q16_16::dot_wide(fv.raw(), fv.raw()));
        assert!((n2 - 1.0).abs() < 1e-3);
    }

    #[test]
    fn quantization_is_deterministic() {
        let v: Vec<f32> = (0..256).map(|i| ((i as f32) * 0.013).sin()).collect();
        let a = FixedVector::from_f32(&v, 256, &ValidationPolicy::default()).unwrap();
        let b = FixedVector::from_f32(&v, 256, &ValidationPolicy::default()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn dot_and_l2_basics() {
        let p = ValidationPolicy::default();
        let a = FixedVector::from_f32(&[1.0, 0.0], 2, &p).unwrap();
        let b = FixedVector::from_f32(&[0.0, 1.0], 2, &p).unwrap();
        assert_eq!(a.dot_wide(&b), 0);
        assert_eq!(Q16_16::wide_to_f64(a.l2sq_wide(&b)), 2.0);
        assert_eq!(Q16_16::wide_to_f64(a.dot_wide(&a)), 1.0);
    }

    #[test]
    fn to_f32_roundtrips_exact_values() {
        let p = ValidationPolicy::default();
        let v = vec![0.5f32, -0.25, 1.0];
        let fv = FixedVector::from_f32(&v, 3, &p).unwrap();
        assert_eq!(fv.to_f32(), v);
    }
}
