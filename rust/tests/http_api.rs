//! Integration: the node's HTTP API over real sockets, concurrent clients,
//! and the node-level determinism story (two nodes fed the same requests
//! expose the same hash).

use std::sync::Arc;
use valori::http::client;
use valori::json::{parse, Json};
use valori::node::{serve, NodeConfig, NodeState};
use valori::state::{Kernel, KernelConfig};

fn spawn_node(dim: usize) -> (Arc<NodeState>, valori::http::Server) {
    let kernel = Kernel::new(KernelConfig::default_q16(dim));
    let state = Arc::new(NodeState::new(kernel, &NodeConfig::default(), None).unwrap());
    let server = serve(Arc::clone(&state), "127.0.0.1:0", 4).unwrap();
    (state, server)
}

fn vec_json(v: &[f32]) -> Json {
    Json::Array(v.iter().map(|&x| Json::Float(x as f64)).collect())
}

#[test]
fn full_crud_cycle_over_http() {
    let (_state, server) = spawn_node(4);
    let addr = server.addr();

    // insert
    for (id, v) in [(1u64, [0.1f32, 0.2, 0.3, 0.4]), (2, [0.9, 0.8, 0.7, 0.6])] {
        let body = Json::object(vec![("id", Json::Int(id as i64)), ("vector", vec_json(&v))]);
        let (st, _) = client::post_json(&addr, "/v1/insert", &body).unwrap();
        assert_eq!(st, 200);
    }
    // link + meta
    let (st, _) = client::post_json(
        &addr,
        "/v1/link",
        &parse(r#"{"from":1,"to":2}"#).unwrap(),
    )
    .unwrap();
    assert_eq!(st, 200);
    let (st, _) = client::post_json(
        &addr,
        "/v1/meta",
        &parse(r#"{"id":1,"key":"kind","value":"fact"}"#).unwrap(),
    )
    .unwrap();
    assert_eq!(st, 200);

    // query
    let q = Json::object(vec![("vector", vec_json(&[0.1, 0.2, 0.3, 0.4])), ("k", Json::Int(2))]);
    let (st, resp) = client::post_json(&addr, "/v1/query", &q).unwrap();
    assert_eq!(st, 200);
    let hits = resp.get("hits").as_array().unwrap();
    assert_eq!(hits[0].get("id").as_u64(), Some(1));

    // delete then query again
    let (st, _) =
        client::post_json(&addr, "/v1/delete", &parse(r#"{"id":1}"#).unwrap()).unwrap();
    assert_eq!(st, 200);
    let (_, resp) = client::post_json(&addr, "/v1/query", &q).unwrap();
    assert_eq!(resp.get("hits").as_array().unwrap()[0].get("id").as_u64(), Some(2));

    // stats reflect everything
    let (st, stats) = client::get_json(&addr, "/v1/stats").unwrap();
    assert_eq!(st, 200);
    assert_eq!(stats.get("vectors").as_i64(), Some(1));
    assert_eq!(stats.get("inserts").as_i64(), Some(2));
    assert_eq!(stats.get("deletes").as_i64(), Some(1));
    assert_eq!(stats.get("queries").as_i64(), Some(2));
    assert_eq!(stats.get("seq").as_i64(), Some(5));

    server.stop();
}

#[test]
fn concurrent_writers_and_readers() {
    let (_state, server) = spawn_node(8);
    let addr = server.addr();
    let writers: Vec<_> = (0..4)
        .map(|w| {
            std::thread::spawn(move || {
                for i in 0..25u64 {
                    let id = w * 1000 + i;
                    let v: Vec<f32> = (0..8).map(|j| ((id + j) as f32 * 0.01).sin()).collect();
                    let body = Json::object(vec![
                        ("id", Json::Int(id as i64)),
                        ("vector", Json::Array(v.iter().map(|&x| Json::Float(x as f64)).collect())),
                    ]);
                    let (st, _) = client::post_json(&addr, "/v1/insert", &body).unwrap();
                    assert_eq!(st, 200);
                }
            })
        })
        .collect();
    let readers: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                for _ in 0..20 {
                    let q = Json::object(vec![
                        ("vector", Json::Array((0..8).map(|_| Json::Float(0.1)).collect())),
                        ("k", Json::Int(5)),
                    ]);
                    let (st, _) = client::post_json(&addr, "/v1/query", &q).unwrap();
                    assert_eq!(st, 200);
                }
            })
        })
        .collect();
    for t in writers.into_iter().chain(readers) {
        t.join().unwrap();
    }
    let (_, stats) = client::get_json(&addr, "/v1/stats").unwrap();
    assert_eq!(stats.get("vectors").as_i64(), Some(100));
    server.stop();
}

#[test]
fn two_nodes_same_requests_same_hash() {
    let (_s1, n1) = spawn_node(4);
    let (_s2, n2) = spawn_node(4);
    for addr in [n1.addr(), n2.addr()] {
        for i in 0..30u64 {
            let v: Vec<f32> = (0..4).map(|j| ((i + j) as f32 * 0.1).cos() * 0.5).collect();
            let body = Json::object(vec![
                ("id", Json::Int(i as i64)),
                ("vector", Json::Array(v.iter().map(|&x| Json::Float(x as f64)).collect())),
            ]);
            let (st, _) = client::post_json(&addr, "/v1/insert", &body).unwrap();
            assert_eq!(st, 200);
        }
    }
    let (_, h1) = client::get_json(&n1.addr(), "/v1/hash").unwrap();
    let (_, h2) = client::get_json(&n2.addr(), "/v1/hash").unwrap();
    assert_eq!(h1.get("fnv").as_str(), h2.get("fnv").as_str());
    assert_eq!(h1.get("sha256").as_str(), h2.get("sha256").as_str());
    n1.stop();
    n2.stop();
}

#[test]
fn error_surface() {
    let (_state, server) = spawn_node(4);
    let addr = server.addr();
    // wrong dim
    let body = parse(r#"{"id":1,"vector":[0.1,0.2]}"#).unwrap();
    let (st, resp) = client::post_json(&addr, "/v1/insert", &body).unwrap();
    assert_eq!(st, 400, "{resp}");
    // NaN-free JSON but out-of-policy value
    let body = parse(r#"{"id":1,"vector":[99.0,0,0,0]}"#).unwrap();
    let (st, _) = client::post_json(&addr, "/v1/insert", &body).unwrap();
    assert_eq!(st, 400);
    // unknown route
    let (st, _) = client::request(&addr, "GET", "/v2/nope", b"").unwrap();
    assert_eq!(st, 404);
    // malformed body
    let (st, _) = client::request(&addr, "POST", "/v1/insert", b"{oops").unwrap();
    assert_eq!(st, 400);
    // health
    let (st, h) = client::get_json(&addr, "/v1/health").unwrap();
    assert_eq!(st, 200);
    assert_eq!(h.get("ok").as_bool(), Some(true));
    server.stop();
}

#[test]
fn log_pagination() {
    let (state, server) = spawn_node(4);
    let addr = server.addr();
    for i in 0..10u64 {
        state
            .apply(valori::state::Command::insert(i, vec![0.1, 0.1, 0.1, 0.1 + i as f32 * 0.001]))
            .unwrap();
    }
    let (_, page1) = client::get_json(&addr, "/v1/log?from=0").unwrap();
    assert_eq!(page1.get("commands").as_array().unwrap().len(), 10);
    let (_, page2) = client::get_json(&addr, "/v1/log?from=7").unwrap();
    assert_eq!(page2.get("commands").as_array().unwrap().len(), 3);
    assert_eq!(page2.get("total").as_i64(), Some(10));
    let (_, page3) = client::get_json(&addr, "/v1/log?from=99").unwrap();
    assert_eq!(page3.get("commands").as_array().unwrap().len(), 0);
    server.stop();
}
