//! Integration tests for the determinism auditor (`valori::lint`).
//!
//! Three layers:
//!
//! 1. inline good/bad source fixtures pinning every rule (R1–R6) and
//!    the annotation / `#[cfg(test)]` semantics to exact findings,
//! 2. the zone map against the *real* source tree (every file must be
//!    classified by an explicit table entry, and a spot-check table
//!    pins the zone of load-bearing files),
//! 3. a self-audit: the repo at the committed `lint_baseline.json`
//!    must be clean, both through the library API and through the
//!    `valori lint` CLI (which must also exit nonzero on seeded
//!    violations for each rule).

use std::path::Path;
use std::process::Command;

use valori::lint::baseline::{diff, Baseline};
use valori::lint::{
    self, audit_source, zone_of, Finding, Rule, Zone, BOUNDARY_DIRS, BOUNDARY_FILES, EXEMPT_DIRS,
    EXEMPT_FILES, STATE_DIRS,
};

fn keys(findings: &[Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.key.as_str()).collect()
}

fn rules(findings: &[Finding]) -> Vec<Rule> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// R1: floats in the state zone

#[test]
fn r1_flags_float_types_and_literals_in_state_zone() {
    let src = "pub fn scale(x: f32) -> f64 {\n    x as f64 * 2.5\n}\n";
    let f = audit_source("state/bad.rs", Zone::State, src);
    assert_eq!(keys(&f), ["f32", "f64", "f64", "float-literal"], "{f:?}");
    assert!(f.iter().all(|x| x.rule == Rule::R1));
    // the same source is fine outside the state zone
    assert!(audit_source("http/ok.rs", Zone::Boundary, src).is_empty());
    assert!(audit_source("bench/ok.rs", Zone::Exempt, src).is_empty());
}

#[test]
fn r1_suffixed_integers_are_not_float_literals() {
    // `0usize` / `7e` lookalikes: the `e` in a suffix is not an exponent
    let src = "pub fn n() -> usize {\n    let k = 0usize;\n    k + 10_000usize\n}\n";
    assert!(audit_source("state/ok.rs", Zone::State, src).is_empty());
}

#[test]
fn r1_standalone_annotation_covers_the_next_item() {
    let src = "// lint: float-boundary — quantization entry point, floats stop here\n\
               pub fn from_f32(x: f32) -> i32 {\n    (x * 65536.0) as i32\n}\n\
               pub fn leak(x: f32) -> f32 {\n    x\n}\n";
    let f = audit_source("state/mixed.rs", Zone::State, src);
    // only the *second* (unannotated) item is flagged
    assert_eq!(keys(&f), ["f32", "f32"], "{f:?}");
    assert!(f.iter().all(|x| x.line == 5));
}

#[test]
fn r1_trailing_annotation_covers_its_own_line_only() {
    let src = "pub struct Hit {\n    pub dist: f64, // lint: float-boundary — display only\n    pub raw: f64,\n}\n";
    let f = audit_source("state/hit.rs", Zone::State, src);
    assert_eq!(keys(&f), ["f64"], "{f:?}");
    assert_eq!(f[0].line, 3);
}

#[test]
fn r1_annotation_without_justification_is_a_finding() {
    let src = "// lint: float-boundary\npub fn f(x: f32) -> f32 {\n    x\n}\n";
    let f = audit_source("state/bad_ann.rs", Zone::State, src);
    // the bad annotation itself, plus the now-unsuppressed floats
    assert_eq!(keys(&f), ["bad-annotation", "f32", "f32"], "{f:?}");
}

#[test]
fn r1_unknown_marker_is_a_finding() {
    let src = "// lint: allow-everything — nice try\npub fn f() {}\n";
    let f = audit_source("state/unknown.rs", Zone::State, src);
    assert_eq!(keys(&f), ["bad-annotation"], "{f:?}");
    assert!(f[0].message.contains("allow-everything"), "{}", f[0].message);
}

#[test]
fn r1_prose_mention_of_the_marker_does_not_activate() {
    // "lint:" not directly after a comment leader is prose, not an
    // annotation — it must neither suppress nor be a bad-annotation
    let src = "// The auditor accepts `// lint: float-boundary — why` markers.\n\
               pub fn f(x: f32) -> f32 {\n    x\n}\n";
    let f = audit_source("state/prose.rs", Zone::State, src);
    assert_eq!(keys(&f), ["f32", "f32"], "{f:?}");
}

#[test]
fn cfg_test_blocks_are_exempt_from_r1_but_not_cfg_not_test() {
    let gated = "#[cfg(test)]\nmod tests {\n    fn approx(x: f32) -> f32 {\n        x + 0.5\n    }\n}\n";
    assert!(audit_source("state/t.rs", Zone::State, gated).is_empty());
    let inverted = "#[cfg(not(test))]\nfn live(x: f32) -> f32 {\n    x\n}\n";
    let f = audit_source("state/nt.rs", Zone::State, inverted);
    assert_eq!(keys(&f), ["f32", "f32"], "cfg(not(test)) must stay audited: {f:?}");
}

// ---------------------------------------------------------------------------
// R2–R4, R6

#[test]
fn r2_flags_hash_collections_in_state_and_boundary_but_not_exempt() {
    let src = "use std::collections::{HashMap, HashSet};\n";
    let f = audit_source("state/m.rs", Zone::State, src);
    assert_eq!(keys(&f), ["HashMap", "HashSet"], "{f:?}");
    assert_eq!(rules(&f), [Rule::R2, Rule::R2]);
    assert_eq!(keys(&audit_source("http/m.rs", Zone::Boundary, src)), ["HashMap", "HashSet"]);
    assert!(audit_source("experiments/m.rs", Zone::Exempt, src).is_empty());
}

#[test]
fn r3_flags_wall_clock_in_state_zone_only() {
    let src = "pub fn t() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    let f = audit_source("state/t.rs", Zone::State, src);
    assert_eq!(keys(&f), ["Instant", "Instant"], "{f:?}");
    assert!(f.iter().all(|x| x.rule == Rule::R3));
    // boundary admission control may read the clock (deliberately unlogged)
    assert!(audit_source("http/t.rs", Zone::Boundary, src).is_empty());
}

#[test]
fn r4_flags_randomness_and_env_reads_in_state_zone() {
    let src = "pub fn bad() -> u64 {\n    let _ = std::env::var(\"SEED\");\n    rand::random()\n}\n";
    let f = audit_source("state/r.rs", Zone::State, src);
    assert_eq!(keys(&f), ["env", "rand"], "{f:?}");
    assert!(f.iter().all(|x| x.rule == Rule::R4));
    // a field named `env` or `rand` without `::` is not a finding
    let fields = "pub struct S {\n    env: u32,\n    rand: u32,\n}\n";
    assert!(audit_source("state/s.rs", Zone::State, fields).is_empty());
}

#[test]
fn r6_flags_platform_width_and_native_endian_encodes() {
    let src = "pub fn enc(n: usize, x: u32) -> Vec<u8> {\n\
               let mut v = usize::to_le_bytes(n).to_vec();\n\
               v.extend(x.to_ne_bytes());\n    v\n}\n";
    let f = audit_source("codec/e.rs", Zone::State, src);
    assert_eq!(keys(&f), ["to_le_bytes", "to_ne_bytes"], "{f:?}");
    assert!(f.iter().all(|x| x.rule == Rule::R6));
    // explicit-width little-endian is the sanctioned path
    let ok = "pub fn enc(n: usize) -> [u8; 8] {\n    (n as u64).to_le_bytes()\n}\n";
    assert!(audit_source("codec/ok.rs", Zone::State, ok).is_empty());
}

// ---------------------------------------------------------------------------
// R5: unsafe confinement

#[test]
fn r5_flags_unsafe_outside_the_allowlist_even_in_tests() {
    let src = "#[cfg(test)]\nmod tests {\n    fn f() {\n        let _ = unsafe { DANGER };\n    }\n}\n";
    let f = audit_source("codec/u.rs", Zone::State, src);
    assert_eq!(keys(&f), ["unsafe-outside-allowlist"], "{f:?}");
    assert_eq!(rules(&f), [Rule::R5]);
}

#[test]
fn r5_allowlisted_files_need_safety_comments() {
    let bare = "fn f() {\n    let _ = unsafe { danger() };\n}\n";
    let f = audit_source("state/sharded.rs", Zone::State, bare);
    assert_eq!(keys(&f), ["missing-safety-comment"], "{f:?}");

    let commented = "fn f() {\n    // SAFETY: danger() is pure for these inputs\n    let _ = unsafe { danger() };\n}\n";
    assert!(audit_source("state/sharded.rs", Zone::State, commented).is_empty());

    let trailing = "fn f() {\n    let _ = unsafe { danger() }; // SAFETY: pure\n}\n";
    assert!(audit_source("http/reactor.rs", Zone::Boundary, trailing).is_empty());

    let todo = "fn f() {\n    // SAFETY: TODO — document why this is sound\n    let _ = unsafe { danger() };\n}\n";
    let f = audit_source("state/sharded.rs", Zone::State, todo);
    assert_eq!(keys(&f), ["todo-safety-comment"], "TODO stubs must still fail: {f:?}");
}

#[test]
fn safety_stub_insertion_roundtrip() {
    let src = "fn f() {\n    let _ = unsafe { danger() };\n}\n";
    let (stubbed, inserted) = lint::add_safety_stubs("state/sharded.rs", src);
    assert_eq!(inserted, 1);
    assert!(stubbed.contains("// SAFETY: TODO"), "{stubbed}");
    // the stub keeps the finding alive (as todo), it does not silence it
    let f = audit_source("state/sharded.rs", Zone::State, &stubbed);
    assert_eq!(keys(&f), ["todo-safety-comment"], "{f:?}");
    // idempotent: a second pass has nothing left to stub
    let (again, n) = lint::add_safety_stubs("state/sharded.rs", &stubbed);
    assert_eq!(n, 0);
    assert_eq!(again, stubbed);
    // non-allowlisted files never get stubs (the finding is "move the
    // code", not "comment it")
    let (_, n) = lint::add_safety_stubs("codec/u.rs", src);
    assert_eq!(n, 0);
}

// ---------------------------------------------------------------------------
// Zone map

#[test]
fn zone_map_spot_checks() {
    let table: &[(&str, Zone)] = &[
        ("state/kernel.rs", Zone::State),
        ("state/sharded.rs", Zone::State),
        ("index/hnsw.rs", Zone::State),
        ("fixed/format.rs", Zone::State),
        ("hash/mod.rs", Zone::State),
        ("codec/mod.rs", Zone::State),
        ("wal/mod.rs", Zone::State),
        ("distance/mod.rs", Zone::State),
        ("proof/mod.rs", Zone::State),
        ("proof/tree.rs", Zone::State),
        ("distance/float.rs", Zone::Exempt), // file override beats its state dir
        ("http/reactor.rs", Zone::Boundary),
        ("api/mod.rs", Zone::Boundary),
        ("lint/rules.rs", Zone::Boundary),
        ("lib.rs", Zone::Boundary),
        ("main.rs", Zone::Boundary),
        ("experiments/table1.rs", Zone::Exempt),
        ("bench/mod.rs", Zone::Exempt),
        ("testing/mod.rs", Zone::Exempt),
        // unknown modules default to the strictest zone
        ("brand_new_subsystem/mod.rs", Zone::State),
        ("loose_file.rs", Zone::State),
    ];
    for (rel, want) in table {
        assert_eq!(zone_of(rel), *want, "zone_of({rel})");
    }
}

#[test]
fn every_real_source_file_is_explicitly_classified() {
    // Unknown paths *default* to state, which is safe but unaudited
    // intent. This test pins the stronger property: every file in the
    // tree is covered by an explicit zone-map entry, so adding a module
    // forces a conscious classification.
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let files = lint::source_files(&src).expect("walk rust/src");
    assert!(files.len() > 50, "walker found only {} files", files.len());
    for (rel, _) in &files {
        let first = rel.split('/').next().unwrap();
        let known = STATE_DIRS.contains(&first)
            || BOUNDARY_DIRS.contains(&first)
            || EXEMPT_DIRS.contains(&first)
            || BOUNDARY_FILES.contains(&rel.as_str())
            || EXEMPT_FILES.contains(&rel.as_str());
        assert!(known, "{rel}: not covered by the zone map — classify it in lint::zone_of");
    }
}

// ---------------------------------------------------------------------------
// Baseline

#[test]
fn baseline_add_remove_roundtrip() {
    let src = "pub fn f(x: f32) -> f32 {\n    x\n}\n";
    let findings = audit_source("state/f.rs", Zone::State, src);
    assert_eq!(findings.len(), 2);

    // grandfather everything: clean
    let base = Baseline::from_findings(&findings);
    assert!(diff(&findings, &base).is_clean());

    // round-trip through the JSON file format
    let reparsed = Baseline::from_json_text(&base.to_json().to_string()).unwrap();
    assert!(diff(&findings, &reparsed).is_clean());

    // fix one float: the remaining finding is covered, the freed
    // baseline entry goes stale (and must be deleted)
    let fixed = audit_source("state/f.rs", Zone::State, "pub fn f(x: i32) -> f32 {\n    x as f32\n}\n");
    assert_eq!(fixed.len(), 2, "{fixed:?}"); // still two f32 tokens here
    let partially_fixed = audit_source("state/f.rs", Zone::State, "pub fn f(x: i64) -> f32 {\n    0\n}\n");
    assert_eq!(partially_fixed.len(), 1);
    let d = diff(&partially_fixed, &base);
    assert!(d.new.is_empty(), "{:?}", d.new);
    assert_eq!(d.stale.len(), 1, "{:?}", d.stale);

    // a new finding in another file is new even with a fat baseline
    let elsewhere = audit_source("state/g.rs", Zone::State, "pub const E: f64 = 2.7;\n");
    let d = diff(&elsewhere, &base);
    assert_eq!(d.new.len(), elsewhere.len());
}

// ---------------------------------------------------------------------------
// Self-audit: the repo is clean at the committed (empty) baseline

#[test]
fn repo_is_clean_at_committed_baseline() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = lint::audit_tree(&manifest.join("src")).expect("walk rust/src");
    let text = std::fs::read_to_string(manifest.join("../lint_baseline.json"))
        .expect("read committed lint_baseline.json");
    let baseline = Baseline::from_json_text(&text).expect("parse committed baseline");
    let d = diff(&findings, &baseline);
    assert!(
        d.is_clean(),
        "repo is not lint-clean at the committed baseline\nnew findings:\n{}\nstale entries: {:?}",
        d.new.iter().map(|f| format!("  {f}")).collect::<Vec<_>>().join("\n"),
        d.stale,
    );
}

// ---------------------------------------------------------------------------
// CLI: exit codes through the real binary

fn lint_cli(dir: &Path, extra: &[&str]) -> std::process::Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_valori"));
    cmd.arg("lint").arg("--root").arg(dir);
    cmd.args(extra);
    cmd.output().expect("spawn valori lint")
}

#[test]
fn cli_exits_zero_on_the_repo_at_the_committed_baseline() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let out = lint_cli(
        &manifest.join("src"),
        &["--baseline", manifest.join("../lint_baseline.json").to_str().unwrap()],
    );
    assert!(
        out.status.success(),
        "valori lint failed on the repo:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}

#[test]
fn cli_exits_nonzero_on_each_seeded_rule_violation() {
    let fixtures: &[(&str, &str)] = &[
        ("R1", "pub fn f(x: f32) -> f32 {\n    x * 0.5\n}\n"),
        ("R2", "use std::collections::HashMap;\npub type M = HashMap<u64, u64>;\n"),
        ("R3", "pub fn t() -> std::time::Instant {\n    std::time::Instant::now()\n}\n"),
        ("R4", "pub fn s() -> String {\n    std::env::var(\"SEED\").unwrap()\n}\n"),
        ("R5", "pub fn u() {\n    unsafe { core::hint::unreachable_unchecked() }\n}\n"),
        ("R6", "pub fn e(n: usize) -> [u8; 8] {\n    usize::to_le_bytes(n)\n}\n"),
    ];
    let tmp = std::env::temp_dir().join(format!("valori_lint_seeded_{}", std::process::id()));
    for (rule, src) in fixtures {
        let root = tmp.join(rule);
        std::fs::create_dir_all(root.join("state")).unwrap();
        std::fs::write(root.join("state/seeded.rs"), src).unwrap();
        // empty baseline: any finding must fail the run
        let base = root.join("empty_baseline.json");
        std::fs::write(&base, "{\"entries\": [], \"version\": 1}\n").unwrap();
        let out = lint_cli(&root, &["--baseline", base.to_str().unwrap()]);
        assert_eq!(
            out.status.code(),
            Some(1),
            "{rule} fixture: want exit 1, got {:?}\n{}",
            out.status.code(),
            String::from_utf8_lossy(&out.stdout),
        );
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains(rule), "{rule} fixture output missing rule code:\n{stdout}");

        // the same tree is machine-readable with --format json
        let out = lint_cli(&root, &["--baseline", base.to_str().unwrap(), "--format", "json"]);
        assert_eq!(out.status.code(), Some(1));
        let doc = valori::json::parse(&String::from_utf8_lossy(&out.stdout)).expect("json output");
        assert_eq!(doc.get("clean"), &valori::json::Json::Bool(false));
        let new = doc.get("new").as_array().expect("new array");
        assert!(!new.is_empty());
        assert_eq!(new[0].get("rule").as_str(), Some(*rule));

        // grandfathering exactly those findings turns the run green …
        let grandfathered: Vec<valori::json::Json> = new
            .iter()
            .map(|f| {
                valori::json::Json::object(vec![
                    ("rule", f.get("rule").clone()),
                    ("file", f.get("file").clone()),
                    ("key", f.get("key").clone()),
                ])
            })
            .collect();
        let fat = valori::json::Json::object(vec![
            ("version", valori::json::Json::Int(1)),
            ("entries", valori::json::Json::Array(grandfathered)),
        ]);
        let fat_path = root.join("fat_baseline.json");
        std::fs::write(&fat_path, fat.to_string()).unwrap();
        let out = lint_cli(&root, &["--baseline", fat_path.to_str().unwrap()]);
        assert_eq!(out.status.code(), Some(0), "{rule}: grandfathered run should be clean");

        // … and fixing the file then makes those entries stale (exit 1)
        std::fs::write(root.join("state/seeded.rs"), "pub fn ok() {}\n").unwrap();
        let out = lint_cli(&root, &["--baseline", fat_path.to_str().unwrap()]);
        assert_eq!(out.status.code(), Some(1), "{rule}: stale baseline entries must fail");
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains("stale"), "{rule}: expected stale-entry report:\n{stdout}");
    }
    let _ = std::fs::remove_dir_all(&tmp);
}
