//! Commands: the external (float-facing) vocabulary and its canonical
//! (post-boundary, integer-only) form.
//!
//! The canonical form is what gets WAL-logged, replicated and replayed —
//! paper §5.2: "Commands (Insert, Link, Delete) must be serialized and
//! deterministic". Storing the *quantized* vector in the log makes replay
//! purely integer even though quantization itself is already deterministic
//! (single correctly-rounded multiply, DESIGN §6).

#![forbid(unsafe_code)]

use crate::codec::{DecodeError, Decoder, Encoder};

/// External command — what clients (HTTP, FFI, examples) submit. `Insert`
/// carries floats; everything else is already exact.
// lint: float-boundary — client-facing command type; floats are quantized at apply
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    /// Insert a float vector under a fresh id (crosses the boundary).
    Insert { id: u64, vector: Vec<f32> },
    /// Batch insert. Per paper §7.1 ("items are processed in a verified,
    /// sorted order, usually by ID") the batch is canonicalized by
    /// ascending id regardless of submission order, so clients that
    /// assemble batches concurrently still produce one canonical state.
    InsertBatch { items: Vec<(u64, Vec<f32>)> },
    /// Delete (tombstone) an id.
    Delete { id: u64 },
    /// Create a directed link between two stored ids.
    Link { from: u64, to: u64 },
    /// Remove a directed link.
    Unlink { from: u64, to: u64 },
    /// Attach/overwrite a metadata key on a stored id.
    SetMeta { id: u64, key: String, value: String },
}

// lint: float-boundary — constructor takes the client's float payload
impl Command {
    /// Convenience constructor used throughout examples and tests.
    pub fn insert(id: u64, vector: Vec<f32>) -> Self {
        Command::Insert { id, vector }
    }
}

/// Canonical command — integer-only, byte-stable, replayable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CanonCommand {
    /// Vector already quantized to the kernel's precision contract
    /// (Q16.16 raw values; normalization, if the policy asks for it, has
    /// already been applied).
    Insert { id: u64, raw: Vec<i32> },
    /// Batch insert, already sorted ascending by id (paper §7.1); the
    /// encoder enforces sortedness so a forged/corrupt log cannot smuggle
    /// in an order-dependent batch.
    InsertBatch { items: Vec<(u64, Vec<i32>)> },
    Delete { id: u64 },
    Link { from: u64, to: u64 },
    Unlink { from: u64, to: u64 },
    SetMeta { id: u64, key: String, value: String },
}

const TAG_INSERT: u8 = 1;
const TAG_DELETE: u8 = 2;
const TAG_LINK: u8 = 3;
const TAG_UNLINK: u8 = 4;
const TAG_SETMETA: u8 = 5;
const TAG_INSERT_BATCH: u8 = 6;

impl CanonCommand {
    /// Stable human-readable name (metrics, audit output).
    pub fn name(&self) -> &'static str {
        match self {
            CanonCommand::Insert { .. } => "insert",
            CanonCommand::InsertBatch { .. } => "insert_batch",
            CanonCommand::Delete { .. } => "delete",
            CanonCommand::Link { .. } => "link",
            CanonCommand::Unlink { .. } => "unlink",
            CanonCommand::SetMeta { .. } => "set_meta",
        }
    }

    pub fn encode(&self, e: &mut Encoder) {
        match self {
            CanonCommand::Insert { id, raw } => {
                e.put_u8(TAG_INSERT);
                e.put_u64(*id);
                e.put_i32_slice(raw);
            }
            CanonCommand::InsertBatch { items } => {
                e.put_u8(TAG_INSERT_BATCH);
                e.put_u32(items.len() as u32);
                for (id, raw) in items {
                    e.put_u64(*id);
                    e.put_i32_slice(raw);
                }
            }
            CanonCommand::Delete { id } => {
                e.put_u8(TAG_DELETE);
                e.put_u64(*id);
            }
            CanonCommand::Link { from, to } => {
                e.put_u8(TAG_LINK);
                e.put_u64(*from);
                e.put_u64(*to);
            }
            CanonCommand::Unlink { from, to } => {
                e.put_u8(TAG_UNLINK);
                e.put_u64(*from);
                e.put_u64(*to);
            }
            CanonCommand::SetMeta { id, key, value } => {
                e.put_u8(TAG_SETMETA);
                e.put_u64(*id);
                e.put_str(key);
                e.put_str(value);
            }
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        self.encode(&mut e);
        e.into_vec()
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let tag = d.get_u8()?;
        match tag {
            TAG_INSERT => Ok(CanonCommand::Insert { id: d.get_u64()?, raw: d.get_i32_vec()? }),
            TAG_INSERT_BATCH => {
                let n = d.get_u32()? as usize;
                let mut items = Vec::with_capacity(n.min(4096));
                let mut last: Option<u64> = None;
                for _ in 0..n {
                    let id = d.get_u64()?;
                    // enforce canonical (strictly ascending) order on decode
                    if last.is_some_and(|p| p >= id) {
                        return Err(DecodeError::InvalidTag { what: "batch order", tag: id });
                    }
                    last = Some(id);
                    items.push((id, d.get_i32_vec()?));
                }
                Ok(CanonCommand::InsertBatch { items })
            }
            TAG_DELETE => Ok(CanonCommand::Delete { id: d.get_u64()? }),
            TAG_LINK => Ok(CanonCommand::Link { from: d.get_u64()?, to: d.get_u64()? }),
            TAG_UNLINK => Ok(CanonCommand::Unlink { from: d.get_u64()?, to: d.get_u64()? }),
            TAG_SETMETA => Ok(CanonCommand::SetMeta {
                id: d.get_u64()?,
                key: d.get_str()?.to_string(),
                value: d.get_str()?.to_string(),
            }),
            t => Err(DecodeError::InvalidTag { what: "command", tag: t as u64 }),
        }
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self, DecodeError> {
        let mut d = Decoder::new(bytes);
        let c = Self::decode(&mut d)?;
        d.finish()?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(c: CanonCommand) {
        let bytes = c.to_bytes();
        let c2 = CanonCommand::from_bytes(&bytes).unwrap();
        assert_eq!(c, c2);
        assert_eq!(bytes, c2.to_bytes()); // canonical
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(CanonCommand::Insert { id: 7, raw: vec![1, -2, 65536] });
        roundtrip(CanonCommand::InsertBatch {
            items: vec![(1, vec![5, 6]), (2, vec![-7, 8]), (10, vec![0, 0])],
        });
        roundtrip(CanonCommand::Delete { id: u64::MAX });
        roundtrip(CanonCommand::Link { from: 1, to: 2 });
        roundtrip(CanonCommand::Unlink { from: 2, to: 1 });
        roundtrip(CanonCommand::SetMeta {
            id: 0,
            key: "source".into(),
            value: "unit-test ünïcode".into(),
        });
    }

    #[test]
    fn unsorted_batch_rejected_on_decode() {
        let bad = CanonCommand::InsertBatch { items: vec![(5, vec![1]), (5, vec![2])] };
        assert!(CanonCommand::from_bytes(&bad.to_bytes()).is_err(), "equal ids");
        let bad = CanonCommand::InsertBatch { items: vec![(9, vec![1]), (2, vec![2])] };
        assert!(CanonCommand::from_bytes(&bad.to_bytes()).is_err(), "descending ids");
    }

    #[test]
    fn bad_tag_rejected() {
        assert!(matches!(
            CanonCommand::from_bytes(&[99]),
            Err(DecodeError::InvalidTag { what: "command", tag: 99 })
        ));
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = CanonCommand::Delete { id: 3 }.to_bytes();
        bytes.push(0);
        assert!(matches!(
            CanonCommand::from_bytes(&bytes),
            Err(DecodeError::TrailingBytes { .. })
        ));
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(CanonCommand::Delete { id: 1 }.name(), "delete");
        assert_eq!(CanonCommand::Insert { id: 1, raw: vec![] }.name(), "insert");
    }
}
