//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. HNSW neighbor selection: diversity heuristic vs simple M-closest
//!    (recall on clustered data — why we ship the heuristic).
//! 2. ef_search sweep: the recall/latency trade-off behind the default.
//! 3. Precision-contract ablation: recall of Q8.24 / Q16.16 / Q32.32
//!    against the f32 ranking (Table 2's contract axis, quantified).
//! 4. Wide-accumulator necessity: i32 accumulation (naive) overflows and
//!    corrupts rankings; i64 does not (paper §5.1's accumulator rule).
//!
//! Run: `cargo bench --bench ablations`

use valori::distance::{Metric, Scalar};
use valori::experiments::{recall_overlap, synthetic_embeddings};
use valori::fixed::{FixedFormat, Q16_16, Q32_32, Q8_24};
use valori::index::{FlatIndex, Hnsw, HnswParams, VectorIndex};

fn main() {
    ef_search_sweep();
    contract_recall();
    accumulator_width();
}

fn ef_search_sweep() {
    println!("\n=== ablation: ef_search (clustered 2000×64, 16 clusters, k=10) ===");
    let data = synthetic_embeddings(2000, 64, 16, 3);
    let queries = synthetic_embeddings(40, 64, 16, 99);
    println!("{:>10} {:>10} {:>14}", "ef_search", "recall@10", "p50 latency");
    for efs in [16usize, 32, 64, 128, 256] {
        let params = HnswParams { ef_search: efs, ..Default::default() };
        let mut h: Hnsw<i32> = Hnsw::new(64, Metric::L2, params);
        let mut f: FlatIndex<i32> = FlatIndex::new(64, Metric::L2);
        for (id, v) in data.iter().enumerate() {
            let raw: Vec<i32> = v.iter().map(|&x| Q16_16::quantize(x as f64)).collect();
            h.insert(id as u64, raw.clone());
            f.insert(id as u64, raw);
        }
        let mut sum = 0.0;
        let mut times = Vec::new();
        for q in &queries {
            let raw: Vec<i32> = q.iter().map(|&x| Q16_16::quantize(x as f64)).collect();
            let t0 = std::time::Instant::now();
            let hh: Vec<u64> = h.search(&raw, 10).iter().map(|x| x.id).collect();
            times.push(t0.elapsed().as_nanos() as f64);
            let fh: Vec<u64> = f.search(&raw, 10).iter().map(|x| x.id).collect();
            sum += recall_overlap(&fh, &hh);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "{:>10} {:>10.3} {:>14}",
            efs,
            sum / queries.len() as f64,
            valori::bench::fmt_ns(times[times.len() / 2])
        );
    }
    println!("(default ef_search = 128: past the knee of the recall curve)");
}

fn contract_recall() {
    println!("\n=== ablation: precision contract vs f32 ranking (1000×128, k=10) ===");
    let data = synthetic_embeddings(1000, 128, 12, 21);
    let queries = synthetic_embeddings(50, 128, 12, 77);
    // exact f32 ground truth
    let mut exact: FlatIndex<f32> = FlatIndex::new(128, Metric::L2);
    for (id, v) in data.iter().enumerate() {
        exact.insert(id as u64, v.clone());
    }

    fn run_contract<F: FixedFormat>(
        data: &[Vec<f32>],
        queries: &[Vec<f32>],
        exact: &FlatIndex<f32>,
    ) -> f64
    where
        F::Raw: Scalar,
    {
        let mut flat: FlatIndex<F::Raw> = FlatIndex::new(128, Metric::L2);
        for (id, v) in data.iter().enumerate() {
            flat.insert(id as u64, v.iter().map(|&x| F::quantize(x as f64)).collect());
        }
        let mut sum = 0.0;
        for q in queries {
            let raw: Vec<F::Raw> = q.iter().map(|&x| F::quantize(x as f64)).collect();
            let got: Vec<u64> = flat.search(&raw, 10).iter().map(|x| x.id).collect();
            let want: Vec<u64> = exact.search(q, 10).iter().map(|x| x.id).collect();
            sum += recall_overlap(&want, &got);
        }
        sum / queries.len() as f64
    }

    println!("{:>8} {:>12}", "format", "recall@10");
    println!("{:>8} {:>12.4}", "Q8.24", run_contract::<Q8_24>(&data, &queries, &exact));
    println!("{:>8} {:>12.4}", "Q16.16", run_contract::<Q16_16>(&data, &queries, &exact));
    println!("{:>8} {:>12.4}", "Q32.32", run_contract::<Q32_32>(&data, &queries, &exact));
    println!("(exact scans: differences are pure quantization, no index noise)");
}

fn accumulator_width() {
    println!("\n=== ablation: accumulator width (paper §5.1 'use i64 or wider') ===");
    // adversarial-but-legal inputs: max-magnitude contract values, all
    // aligned so the true sum is far outside i32 range
    let dim = 4096;
    let a: Vec<i32> = (0..dim).map(|_| 1 << 18).collect();
    let b: Vec<i32> = (0..dim).map(|_| 1 << 18).collect();
    // correct: i64 accumulation
    let correct = valori::distance::dot_q16(&a, &b);
    // naive: i32 accumulation wraps
    let mut naive: i32 = 0;
    let mut wrapped = false;
    for i in 0..dim {
        let prod = (a[i] as i64) * (b[i] as i64);
        let (acc, over) = naive.overflowing_add(prod as i32);
        naive = acc;
        wrapped |= over || prod > i32::MAX as i64 || prod < i32::MIN as i64;
    }
    println!("i64 accumulator: {correct} (exact)");
    println!("i32 accumulator: {naive} (wrapped: {wrapped}) — silently wrong rankings");
    assert_ne!(correct, naive as i64);
    println!("(this is why the boundary contract + wide accumulators are non-negotiable)");
}
