//! # Valori — a deterministic memory substrate for AI systems
//!
//! Reference reproduction of *"Valori: A Deterministic Memory Substrate for
//! AI Systems"* (Gudur, 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organized around the paper's determinism boundary:
//!
//! - **Inside the boundary (integer-only, bit-deterministic):**
//!   [`fixed`], [`vector`], [`distance`], [`index`], [`state`], [`wal`],
//!   [`snapshot`], [`graph`], [`codec`], [`hash`], [`proof`] (Merkle
//!   receipts over the canonical state).
//! - **Outside the boundary (float, may diverge across platforms):**
//!   [`runtime`] (the AOT-compiled embedding model executed via PJRT) and
//!   the `f32` baseline instantiations used for the paper's comparisons.
//! - **Interface layers (paper Fig. 1):** [`api`] (the typed /v2
//!   envelope + closed error taxonomy), [`node`] (HTTP routing, the
//!   multi-tenant collection manager, embed batching), [`replication`]
//!   (multi-node state convergence), [`cli`].
//! - **Build-every-substrate support:** [`http`], [`json`], [`bench`],
//!   [`testing`], [`tokenizer`], [`corpus`], [`experiments`], and the
//!   determinism auditor [`lint`] (`valori lint`), which enforces this
//!   very zone layout statically (see DETERMINISM.md).
//!
//! ## Quickstart
//!
//! ```no_run
//! use valori::state::{Command, Kernel, KernelConfig};
//!
//! let mut kernel = Kernel::new(KernelConfig::default_q16(4));
//! kernel.apply(Command::insert(0, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
//! let hits = kernel.search_f32(&[0.1, 0.2, 0.3, 0.4], 1).unwrap();
//! assert_eq!(hits[0].id, 0);
//! println!("state hash = {:#018x}", kernel.state_hash());
//! ```

// `unsafe` is confined to the two allowlisted files (state/sharded.rs,
// http/reactor.rs — lint rule R5); everything else forbids it at the
// module level, and this crate-wide deny backstops any file that
// forgets its own attribute. `forbid` cannot live here because the two
// allowlisted files must still opt back in with `allow`.
#![deny(unsafe_code)]

pub mod api;
pub mod bench;
pub mod cli;
pub mod codec;
pub mod corpus;
pub mod distance;
pub mod experiments;
pub mod fixed;
pub mod graph;
pub mod hash;
pub mod http;
pub mod index;
pub mod json;
pub mod lint;
pub mod node;
pub mod proof;
pub mod replication;
pub mod runtime;
pub mod snapshot;
pub mod state;
pub mod testing;
pub mod tokenizer;
pub mod vector;
pub mod wal;

/// Crate-level result alias used by fallible public APIs.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error type for kernel-level operations.
#[derive(Debug)]
pub enum Error {
    /// Rejected at the quantization boundary.
    Boundary(vector::BoundaryError),
    /// State-machine command error (duplicate id, missing id, ...).
    State(state::StateError),
    /// Snapshot/WAL decode error.
    Decode(codec::DecodeError),
    /// I/O error (WAL, snapshot files).
    Io(std::io::Error),
    /// Runtime (PJRT/XLA) error.
    Runtime(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Boundary(e) => write!(f, "boundary: {e}"),
            Error::State(e) => write!(f, "state: {e}"),
            Error::Decode(e) => write!(f, "decode: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Runtime(e) => write!(f, "runtime: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<vector::BoundaryError> for Error {
    fn from(e: vector::BoundaryError) -> Self {
        Error::Boundary(e)
    }
}

impl From<state::StateError> for Error {
    fn from(e: state::StateError) -> Self {
        Error::State(e)
    }
}

impl From<codec::DecodeError> for Error {
    fn from(e: codec::DecodeError) -> Self {
        Error::Decode(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}
