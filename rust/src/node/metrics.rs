//! Node metrics: lock-free counters + a coarse latency histogram.
//!
//! Observability lives strictly *outside* the kernel (metrics are not part
//! of the deterministic state and never enter the snapshot/hash).

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicU64, Ordering};

/// Exponential latency histogram: bucket i covers [2^i, 2^(i+1)) µs.
const BUCKETS: usize = 20;

#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = (n as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }
}

/// All node-level metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub inserts: AtomicU64,
    pub deletes: AtomicU64,
    pub links: AtomicU64,
    pub queries: AtomicU64,
    pub embeds: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub query_latency: Histogram,
    pub embed_latency: Histogram,
    /// Front-end connection gauges, shared with the HTTP server (the
    /// node hands a clone of this `Arc` to [`crate::http::ServerConfig`]
    /// so `/v1/stats` can report reactor state).
    pub http: std::sync::Arc<crate::http::ServerMetrics>,
}

impl Metrics {
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let g = |c: &AtomicU64| Json::Int(c.load(Ordering::Relaxed) as i64);
        Json::object(vec![
            ("inserts", g(&self.inserts)),
            ("deletes", g(&self.deletes)),
            ("links", g(&self.links)),
            ("queries", g(&self.queries)),
            ("embeds", g(&self.embeds)),
            ("errors", g(&self.errors)),
            ("batches", g(&self.batches)),
            ("batched_requests", g(&self.batched_requests)),
            ("query_p50_us", Json::Int(self.query_latency.quantile_us(0.5) as i64)),
            ("query_p99_us", Json::Int(self.query_latency.quantile_us(0.99) as i64)),
            ("query_mean_us", Json::Float(self.query_latency.mean_us())),
            ("embed_mean_us", Json::Float(self.embed_latency.mean_us())),
            ("http_connections_open", g(&self.http.connections_open)),
            ("http_connections_accepted", g(&self.http.connections_accepted)),
            ("http_connections_timed_out", g(&self.http.connections_timed_out)),
            ("http_connections_rejected", g(&self.http.connections_rejected)),
            ("http_requests_served", g(&self.http.requests_served)),
            ("http_pipelined_rejected", g(&self.http.pipelined_rejected)),
            ("stream_bytes_streamed", g(&self.http.stream_bytes_streamed)),
            ("stream_chunks_verified", g(&self.http.stream_chunks_verified)),
            ("streams_in_flight", g(&self.http.streams_in_flight)),
            ("requests_rate_limited", g(&self.http.requests_rate_limited)),
            ("requests_quota_rejected", g(&self.http.requests_quota_rejected)),
            ("collections_evicted", g(&self.http.collections_evicted)),
            ("collections_rehydrated", g(&self.http.collections_rehydrated)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let h = Histogram::default();
        for us in [1u64, 2, 4, 8, 100, 100, 100, 1000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 8);
        assert!(h.mean_us() > 0.0);
        // p50 upper bound must be <= p99 upper bound
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        // all samples <= 1000us < p100 bucket bound
        assert!(h.quantile_us(1.0) >= 1000);
    }

    #[test]
    fn zero_sample_histogram() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn metrics_json_shape() {
        let m = Metrics::default();
        Metrics::inc(&m.inserts);
        Metrics::inc(&m.inserts);
        m.query_latency.record_us(250);
        let j = m.to_json();
        assert_eq!(j.get("inserts").as_i64(), Some(2));
        assert_eq!(j.get("deletes").as_i64(), Some(0));
        assert!(j.get("query_p50_us").as_i64().unwrap() >= 250);
    }

    #[test]
    fn http_gauges_surface_in_json() {
        let m = Metrics::default();
        m.http.connections_open.store(3, Ordering::Relaxed);
        m.http.requests_served.store(17, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("http_connections_open").as_i64(), Some(3));
        assert_eq!(j.get("http_requests_served").as_i64(), Some(17));
        assert_eq!(j.get("http_connections_timed_out").as_i64(), Some(0));
    }
}
