//! Minimal HTTP/1.1 server substrate (tokio/axum unavailable offline).
//!
//! Blocking `std::net` sockets + a fixed thread pool. Supports the subset
//! the Valori node needs: GET/POST, Content-Length bodies, keep-alive,
//! bounded request sizes, graceful shutdown. This is the "Node ('std')"
//! outer layer of the paper's §5.3 split — it wraps the kernel but never
//! alters its logic.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Maximum accepted body size (1 MiB — vectors are ~KB scale).
pub const MAX_BODY: usize = 1 << 20;
/// Maximum header section size.
pub const MAX_HEADER: usize = 16 << 10;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Raw query string (after '?'), if any.
    pub query: Option<String>,
    /// Header names lower-cased.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> Result<&str, std::str::Utf8Error> {
        std::str::from_utf8(&self.body)
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Self {
        Self { status, content_type: "application/json", body: body.into().into_bytes() }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Self { status, content_type: "text/plain; charset=utf-8", body: body.into().into_bytes() }
    }

    pub fn not_found() -> Self {
        Self::json(404, r#"{"error":"not found"}"#)
    }

    pub fn bad_request(msg: &str) -> Self {
        Self::json(400, format!(r#"{{"error":{}}}"#, crate::json::Json::str(msg)))
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Status",
        }
    }

    fn write_to(&self, stream: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        write!(
            stream,
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        )?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// Request parse outcome.
#[derive(Debug)]
pub enum ParseError {
    Io(std::io::Error),
    /// Clean EOF before any bytes (client closed a keep-alive socket).
    Eof,
    Malformed(&'static str),
    TooLarge,
}

/// Parse one request from a buffered stream.
pub fn parse_request(reader: &mut BufReader<impl Read>) -> Result<Request, ParseError> {
    let mut line = String::new();
    let n = reader.read_line(&mut line).map_err(ParseError::Io)?;
    if n == 0 {
        return Err(ParseError::Eof);
    }
    let mut parts = line.trim_end().split(' ');
    let method = parts.next().filter(|s| !s.is_empty()).ok_or(ParseError::Malformed("method"))?;
    let target = parts.next().ok_or(ParseError::Malformed("target"))?;
    let version = parts.next().ok_or(ParseError::Malformed("version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(ParseError::Malformed("http version"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    let mut headers = BTreeMap::new();
    let mut header_bytes = 0usize;
    loop {
        let mut hline = String::new();
        let n = reader.read_line(&mut hline).map_err(ParseError::Io)?;
        if n == 0 {
            return Err(ParseError::Malformed("eof in headers"));
        }
        header_bytes += n;
        if header_bytes > MAX_HEADER {
            return Err(ParseError::TooLarge);
        }
        let t = hline.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse().map_err(|_| ParseError::Malformed("content-length")))
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY {
        return Err(ParseError::TooLarge);
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).map_err(ParseError::Io)?;

    Ok(Request { method: method.to_string(), path, query, headers, body })
}

/// Boxed handler type.
pub type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync>;

/// A running HTTP server.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port) with
    /// `n_workers` handler threads.
    pub fn start(addr: &str, n_workers: usize, handler: Handler) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));

        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let rx = Arc::clone(&rx);
            let handler = Arc::clone(&handler);
            let shutdown = Arc::clone(&shutdown);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("valori-http-{i}"))
                    .spawn(move || worker_loop(rx, handler, shutdown))
                    .expect("spawn worker"),
            );
        }

        let accept_shutdown = Arc::clone(&shutdown);
        let accept_thread = std::thread::Builder::new()
            .name("valori-http-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            let _ = s.set_read_timeout(Some(Duration::from_secs(30)));
                            let _ = tx.send(s);
                        }
                        Err(_) => continue,
                    }
                }
                // dropping tx ends the workers
            })
            .expect("spawn accept");

        Ok(Server { addr: local, shutdown, accept_thread: Some(accept_thread), workers })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal shutdown and join all threads.
    pub fn stop(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock accept() with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.accept_thread.is_some() {
            self.shutdown_impl();
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<TcpStream>>>,
    handler: Handler,
    shutdown: Arc<AtomicBool>,
) {
    loop {
        let stream = {
            let guard = rx.lock().expect("rx poisoned");
            guard.recv()
        };
        let Ok(stream) = stream else { return }; // channel closed
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        let _ = handle_connection(stream, &handler);
    }
}

fn handle_connection(stream: TcpStream, handler: &Handler) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // keep-alive loop: serve up to 1000 requests per connection
    for _ in 0..1000 {
        match parse_request(&mut reader) {
            Ok(req) => {
                let keep_alive = req
                    .headers
                    .get("connection")
                    .map(|v| !v.eq_ignore_ascii_case("close"))
                    .unwrap_or(true);
                let resp = handler(req);
                resp.write_to(&mut writer, keep_alive)?;
                if !keep_alive {
                    return Ok(());
                }
            }
            Err(ParseError::Eof) => return Ok(()),
            Err(ParseError::TooLarge) => {
                let _ = Response::json(413, r#"{"error":"payload too large"}"#)
                    .write_to(&mut writer, false);
                return Ok(());
            }
            Err(ParseError::Malformed(what)) => {
                let _ = Response::bad_request(&format!("malformed request: {what}"))
                    .write_to(&mut writer, false);
                return Ok(());
            }
            Err(ParseError::Io(_)) => return Ok(()), // timeout/reset
        }
    }
    Ok(())
}

/// Tiny blocking HTTP client for tests, examples and replication.
pub mod client {
    use super::*;

    /// One-shot request; returns (status, body).
    pub fn request(
        addr: &SocketAddr,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
            body.len()
        )?;
        stream.write_all(body)?;
        stream.flush()?;

        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| std::io::Error::other("bad status line"))?;
        let mut len = 0usize;
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            let t = line.trim_end();
            if t.is_empty() {
                break;
            }
            if let Some((k, v)) = t.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    len = v.trim().parse().unwrap_or(0);
                }
            }
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body)?;
        Ok((status, body))
    }

    /// POST JSON; returns (status, parsed body if JSON).
    pub fn post_json(
        addr: &SocketAddr,
        path: &str,
        body: &crate::json::Json,
    ) -> std::io::Result<(u16, crate::json::Json)> {
        let (status, bytes) = request(addr, "POST", path, body.to_string().as_bytes())?;
        let text = String::from_utf8_lossy(&bytes);
        let json = crate::json::parse(&text).unwrap_or(crate::json::Json::Null);
        Ok((status, json))
    }

    /// GET; returns (status, parsed body if JSON).
    pub fn get_json(addr: &SocketAddr, path: &str) -> std::io::Result<(u16, crate::json::Json)> {
        let (status, bytes) = request(addr, "GET", path, &[])?;
        let text = String::from_utf8_lossy(&bytes);
        let json = crate::json::parse(&text).unwrap_or(crate::json::Json::Null);
        Ok((status, json))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        let handler: Handler = Arc::new(|req: Request| {
            if req.path == "/echo" {
                Response::text(200, String::from_utf8_lossy(&req.body).to_string())
            } else if req.path == "/method" {
                Response::text(200, req.method.clone())
            } else if req.path == "/query" {
                Response::text(200, req.query.unwrap_or_default())
            } else {
                Response::not_found()
            }
        });
        Server::start("127.0.0.1:0", 2, handler).unwrap()
    }

    #[test]
    fn serves_and_echoes() {
        let server = echo_server();
        let (status, body) = client::request(&server.addr(), "POST", "/echo", b"hello").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"hello");
        server.stop();
    }

    #[test]
    fn not_found_and_method() {
        let server = echo_server();
        let (status, _) = client::request(&server.addr(), "GET", "/nope", b"").unwrap();
        assert_eq!(status, 404);
        let (_, body) = client::request(&server.addr(), "PUT", "/method", b"").unwrap();
        assert_eq!(body, b"PUT");
        server.stop();
    }

    #[test]
    fn query_string_split() {
        let server = echo_server();
        let (_, body) = client::request(&server.addr(), "GET", "/query?k=10&x=1", b"").unwrap();
        assert_eq!(body, b"k=10&x=1");
        server.stop();
    }

    #[test]
    fn concurrent_requests() {
        let server = echo_server();
        let addr = server.addr();
        let threads: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let msg = format!("msg-{i}");
                    let (s, b) = client::request(&addr, "POST", "/echo", msg.as_bytes()).unwrap();
                    assert_eq!(s, 200);
                    assert_eq!(b, msg.as_bytes());
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        server.stop();
    }

    #[test]
    fn oversized_body_rejected() {
        let server = echo_server();
        let addr = server.addr();
        let mut stream = TcpStream::connect(addr).unwrap();
        write!(stream, "POST /echo HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY + 1).unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("413"), "{line}");
        server.stop();
    }

    #[test]
    fn malformed_request_rejected() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"NONSENSE\r\n\r\n").unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("400"), "{line}");
        server.stop();
    }

    #[test]
    fn keep_alive_multiple_requests_one_connection() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        for i in 0..3 {
            let msg = format!("ka-{i}");
            write!(
                stream,
                "POST /echo HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
                msg.len()
            )
            .unwrap();
            stream.write_all(msg.as_bytes()).unwrap();
            stream.flush().unwrap();
            // read one response off the same socket
            let mut reader = BufReader::new(&stream);
            let mut status = String::new();
            reader.read_line(&mut status).unwrap();
            assert!(status.contains("200"));
            let mut len = 0;
            loop {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let t = line.trim_end();
                if t.is_empty() {
                    break;
                }
                if let Some((k, v)) = t.split_once(':') {
                    if k.eq_ignore_ascii_case("content-length") {
                        len = v.trim().parse().unwrap();
                    }
                }
            }
            let mut body = vec![0u8; len];
            reader.read_exact(&mut body).unwrap();
            assert_eq!(body, msg.as_bytes());
        }
        server.stop();
    }
}
