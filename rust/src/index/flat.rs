//! Exact (brute-force) index, with an optional deterministic SQ8 tier.
//!
//! Ground truth for the HNSW consistency tests and the recall experiments
//! (Table 3 computes Recall@k against exact top-k), and a perfectly usable
//! index in its own right for small collections. Determinism is trivial:
//! one pass in slot order, sort by `(dist, id)`.
//!
//! With a [`QuantSpec::Sq8`] config the index additionally maintains an
//! i8 *code arena* parallel to the exact arena and answers queries in two
//! phases: a blocked i8×i8→i32 scan selects `k * overscan` candidates
//! under the total order `(approx_dist, id)`, then an exact Q16.16
//! re-rank of only those candidates under the existing `(dist, id)` order
//! picks the final k. Codes are **derived state** — a pure function of
//! the stored vectors (see [`super::quant`]) — rebuilt on decode and
//! never serialized, so snapshot bytes are unchanged. When
//! `overscan * k >= live_len` the approx scan could not drop anything the
//! exact scan keeps, so search falls back to the plain exact sweep.

#![forbid(unsafe_code)]

use super::quant::{self, QuantSpec, Quantizer};
use super::store::VecStore;
use super::topk::TopK;
use super::{Hit, VectorIndex};
use crate::codec::{DecodeError, Decoder, Encoder};
use crate::distance::{Metric, Scalar};

/// Rows scored per blocked-kernel call in [`FlatIndex::search`]. Large
/// enough to amortize the call and fill the vector units, small enough
/// that the distance buffer stays in L1. Has no effect on results — the
/// block kernels are exact per row and the top-k order ignores push order.
const SCORE_BLOCK: usize = 64;

/// Brute-force exact index over a [`VecStore`], with an optional derived
/// i8 code arena for two-phase SQ8 search.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatIndex<S: Scalar> {
    metric: Metric,
    store: VecStore<S>,
    quant: QuantSpec,
    /// Derived i8 codes, slot-parallel to the exact arena (row `i` at
    /// `[i*dim, (i+1)*dim)`, tombstones included so slots stay aligned).
    /// Empty unless `quant` is `Sq8` AND `S` opts into quantization
    /// (`Scalar::as_q16_raw`). Never serialized: rebuilt from the decoded
    /// vectors, so it can never drift from them.
    codes: Vec<i8>,
}

impl<S: Scalar> FlatIndex<S> {
    pub fn new(dim: usize, metric: Metric) -> Self {
        Self::with_quant(dim, metric, QuantSpec::None)
    }

    pub fn with_quant(dim: usize, metric: Metric, quant: QuantSpec) -> Self {
        Self { metric, store: VecStore::new(dim), quant, codes: Vec::new() }
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    pub fn store(&self) -> &VecStore<S> {
        &self.store
    }

    pub fn quant(&self) -> QuantSpec {
        self.quant
    }

    /// Bytes held by the exact Q16.16 arena (tombstones included).
    pub fn exact_arena_bytes(&self) -> usize {
        self.store.arena().len() * std::mem::size_of::<S>()
    }

    /// Bytes held by the derived i8 code arena (0 when quant is off).
    pub fn code_arena_bytes(&self) -> usize {
        self.codes.len()
    }

    pub fn encode(&self, e: &mut Encoder) {
        // Codes are derived state: deliberately NOT serialized, so the
        // byte layout (and every snapshot/golden fixture) is identical
        // with and without a quantized tier.
        e.put_u8(self.metric.tag());
        self.store.encode(e);
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        Self::decode_with_quant(d, QuantSpec::None)
    }

    /// Decode the serialized form and rebuild the derived code arena for
    /// the given quant spec (the spec lives in `KernelConfig`, not in the
    /// index bytes).
    pub fn decode_with_quant(d: &mut Decoder, quant: QuantSpec) -> Result<Self, DecodeError> {
        let tag = d.get_u8()?;
        let metric = Metric::from_tag(tag)
            .ok_or(DecodeError::InvalidTag { what: "metric", tag: tag as u64 })?;
        let store = VecStore::decode(d)?;
        let mut idx = Self { metric, store, quant, codes: Vec::new() };
        if matches!(idx.quant, QuantSpec::Sq8 { .. }) {
            idx.codes.reserve(idx.store.arena().len());
            for slot in 0..idx.store.slots() as u32 {
                if !push_row_codes(&mut idx.codes, idx.store.vec_at(slot)) {
                    break; // non-quantizable scalar type: arena unused
                }
            }
        }
        Ok(idx)
    }

    /// The overscan factor iff the two-phase path is usable: quant is
    /// `Sq8`, the dimension forms rows, and the code arena is complete
    /// (i.e. `S` opted into quantization). Public so the sharded parallel
    /// scan can make the same exact-vs-two-phase decision per shard that
    /// [`VectorIndex::search`] makes sequentially.
    pub fn sq8_ready(&self) -> Option<u32> {
        match self.quant {
            QuantSpec::Sq8 { overscan }
                if self.store.dim() > 0
                    && self.codes.len() == self.store.slots() * self.store.dim() =>
            {
                Some(overscan)
            }
            _ => None,
        }
    }

    /// Forced two-phase search, ignoring the `overscan * k >= n` fallback
    /// — the equivalence tests and the bench suite use it to assert the
    /// two-phase output is bit-identical to the exact scan at covering
    /// overscan (through `search` the fallback would short-circuit that).
    /// `None` when the index has no usable code arena.
    pub fn search_sq8_two_phase(&self, query: &[S], k: usize) -> Option<Vec<Hit<S::Dist>>> {
        let dim = self.store.dim();
        assert_eq!(query.len(), dim, "query dimension mismatch: {} != {dim}", query.len());
        let overscan = self.sq8_ready()?;
        if k == 0 || self.store.live_len() == 0 {
            return Some(Vec::new());
        }
        self.search_sq8(query, k, overscan)
    }

    /// Phase 1 (blocked i8 scan, `(approx_dist, id)` order) + phase 2
    /// (exact re-rank of the candidates, `(dist, id)` order). Both phases
    /// are full-range calls into the same sub-range primitives the
    /// parallel scan chunks over, so sequential and parallel execution
    /// share one code path per phase.
    fn search_sq8(&self, query: &[S], k: usize, overscan: u32) -> Option<Vec<Hit<S::Dist>>> {
        let qcodes = Quantizer::encode_query(query)?;
        let mut approx = TopK::new((overscan as usize).saturating_mul(k));
        self.scan_sq8_range(&qcodes, 0, self.store.slots(), &mut approx);
        // Exact Q16.16 re-rank of only the surviving candidates, under
        // the same (dist, id) total order the exact scan uses.
        let mut topk = TopK::new(k);
        self.rerank_into(query, &approx.into_sorted_hits(), &mut topk);
        Some(topk.into_sorted_hits())
    }

    /// Blocked exact sweep over the contiguous slot sub-range `[lo, hi)`,
    /// alive-filtered, pushed into `out`. [`VectorIndex::search`] is this
    /// over `[0, slots)`; the sharded parallel scan runs it per claimed
    /// chunk. The block kernels are exact per row and `TopK` ignores push
    /// order, so *any* partition of the slot space into sub-ranges merges
    /// bit-identically to one sequential pass (PERFORMANCE.md §9).
    /// Requires `dim > 0` (rows must form) and `lo <= hi <= slots`.
    pub fn scan_exact_range(&self, query: &[S], lo: usize, hi: usize, out: &mut TopK<S::Dist>) {
        let dim = self.store.dim();
        debug_assert!(dim > 0, "scan_exact_range: dim must be non-zero");
        debug_assert!(lo <= hi && hi <= self.store.slots(), "scan_exact_range: bad range");
        let arena = self.store.arena();
        let alive = self.store.alive_flags();
        let ids = self.store.external_ids();
        let mut dists = vec![S::max_dist(); SCORE_BLOCK.min(hi - lo)];
        let mut base = lo;
        while base < hi {
            let rows = SCORE_BLOCK.min(hi - base);
            // One contiguous arena run per call: tombstoned rows are
            // scored too (branch-free sweep) and filtered below.
            let block = &arena[base * dim..(base + rows) * dim];
            S::distance_block(self.metric, query, block, dim, &mut dists[..rows]);
            for (r, &d) in dists[..rows].iter().enumerate() {
                let slot = base + r;
                if alive[slot] {
                    out.push(d, ids[slot]);
                }
            }
            base += rows;
        }
    }

    /// SQ8 phase-1 counterpart of [`Self::scan_exact_range`]: blocked i8
    /// scan of the code arena over `[lo, hi)` into `out` (keyed on
    /// `(approx_dist, id)`). Same partition-invariance argument. Requires
    /// a complete code arena ([`Self::sq8_ready`]) and query codes from
    /// [`Quantizer::encode_query`].
    pub fn scan_sq8_range(&self, qcodes: &[i8], lo: usize, hi: usize, out: &mut TopK<i32>) {
        let dim = self.store.dim();
        debug_assert!(dim > 0, "scan_sq8_range: dim must be non-zero");
        debug_assert!(lo <= hi && hi <= self.store.slots(), "scan_sq8_range: bad range");
        debug_assert_eq!(self.codes.len(), self.store.slots() * dim, "code arena incomplete");
        let alive = self.store.alive_flags();
        let ids = self.store.external_ids();
        let mut dists = vec![0i32; SCORE_BLOCK.min(hi - lo)];
        let mut base = lo;
        while base < hi {
            let rows = SCORE_BLOCK.min(hi - base);
            let block = &self.codes[base * dim..(base + rows) * dim];
            quant::sq8_distance_block(self.metric, qcodes, block, dim, &mut dists[..rows]);
            for (r, &d) in dists[..rows].iter().enumerate() {
                let slot = base + r;
                if alive[slot] {
                    out.push(d, ids[slot]);
                }
            }
            base += rows;
        }
    }

    /// Divergence repair (see [`crate::proof`]): overwrite one slot's
    /// exact row and/or liveness in place, keeping the derived i8 code
    /// arena slot-parallel. Slot numbering, the id map and the logical
    /// clock are untouched — this is state surgery, not a command.
    pub(crate) fn repair_slot(&mut self, slot: u32, vector: Option<&[S]>, alive: bool) {
        self.store.overwrite_slot(slot, vector, alive);
        let dim = self.store.dim();
        if let Some(v) = vector {
            // Re-derive this row's codes only when the arena is complete
            // (an incomplete arena means `S` never opted into SQ8).
            if dim > 0 && self.codes.len() == self.store.slots() * dim {
                let mut row = Vec::with_capacity(dim);
                if push_row_codes(&mut row, v) {
                    let start = slot as usize * dim;
                    self.codes[start..start + dim].copy_from_slice(&row);
                }
            }
        }
    }

    /// SQ8 phase 2: push each candidate's *exact* Q16.16 distance into
    /// `out` under the `(dist, id)` total order. Each candidate's key is
    /// a pure function of the stored vector, so a static partition of the
    /// candidate list re-ranked by parallel tasks merges bit-identically
    /// to this sequential call over the whole list. Candidates must be
    /// live ids (phase 1 only emits live slots).
    pub fn rerank_into(&self, query: &[S], cands: &[Hit<i32>], out: &mut TopK<S::Dist>) {
        for hit in cands {
            let slot = self.store.slot_of(hit.id).expect("candidate id must be live");
            out.push(S::distance(self.metric, query, self.store.vec_at(slot)), hit.id);
        }
    }
}

/// Append one row's codes; `false` (with nothing pushed) when `S` does
/// not support quantization — `as_q16_raw` is uniform per type, so the
/// first component decides for the whole row.
fn push_row_codes<S: Scalar>(codes: &mut Vec<i8>, row: &[S]) -> bool {
    for &x in row {
        let Some(raw) = x.as_q16_raw() else {
            return false;
        };
        codes.push(Quantizer::encode_component(raw));
    }
    true
}

impl<S: Scalar> VectorIndex<S> for FlatIndex<S> {
    fn insert(&mut self, id: u64, vector: Vec<S>) {
        let slot = self.store.insert(id, vector);
        if matches!(self.quant, QuantSpec::Sq8 { .. }) {
            // Keep the derived code arena slot-parallel. A non-quantizable
            // scalar type pushes nothing on the first row, so the arena
            // stays incomplete and `sq8_ready` keeps search on the exact
            // path forever.
            push_row_codes(&mut self.codes, self.store.vec_at(slot));
        }
    }

    fn delete(&mut self, id: u64) -> bool {
        // Tombstone only: codes stay slot-aligned (dead rows are scored
        // branch-free in phase 1 and filtered, exactly like the exact
        // sweep handles the Q16.16 arena).
        self.store.delete(id).is_some()
    }

    fn search(&self, query: &[S], k: usize) -> Vec<Hit<S::Dist>> {
        let dim = self.store.dim();
        // The one boundary this path has: every stored row is dim-checked
        // on insert, so this assert discharges the distance kernels'
        // equal-length contract for direct index users too (the state
        // machine validates before it ever gets here). Once per query,
        // never in the hot loop — and it fails loudly instead of the old
        // silent `min()` truncation.
        assert_eq!(query.len(), dim, "query dimension mismatch: {} != {dim}", query.len());
        let slots = self.store.slots();
        if k == 0 || self.store.live_len() == 0 {
            return Vec::new();
        }
        if let Some(overscan) = self.sq8_ready() {
            // Fallback rule: when the candidate set would cover every
            // live vector the approx phase cannot drop anything, so the
            // exact sweep is both cheaper and trivially identical.
            let cand = (overscan as u64).saturating_mul(k as u64);
            if cand < self.store.live_len() as u64 {
                if let Some(hits) = self.search_sq8(query, k, overscan) {
                    return hits;
                }
            }
        }
        // Total order on (dist, id) throughout: deterministic ranking even
        // with distance ties, and identical to the former sort + truncate.
        let mut topk = TopK::new(k);
        if dim == 0 {
            // Degenerate dimension: fall back to the per-row path (the
            // block kernels require dim > 0 to form rows).
            for (_, id, v) in self.store.iter_live() {
                topk.push(S::distance(self.metric, query, v), id);
            }
            return topk.into_sorted_hits();
        }
        self.scan_exact_range(query, 0, slots, &mut topk);
        topk.into_sorted_hits()
    }

    fn len(&self) -> usize {
        self.store.live_len()
    }

    fn get(&self, id: u64) -> Option<&[S]> {
        self.store.get(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{FixedFormat, Q16_16};

    fn q(x: f64) -> i32 {
        Q16_16::quantize(x)
    }

    fn build() -> FlatIndex<i32> {
        let mut idx = FlatIndex::new(2, Metric::L2);
        idx.insert(1, vec![q(0.0), q(0.0)]);
        idx.insert(2, vec![q(1.0), q(0.0)]);
        idx.insert(3, vec![q(0.0), q(2.0)]);
        idx
    }

    #[test]
    fn search_orders_by_distance() {
        let idx = build();
        let hits = idx.search(&[q(0.1), q(0.0)], 3);
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn search_k_truncates() {
        let idx = build();
        assert_eq!(idx.search(&[q(0.0), q(0.0)], 2).len(), 2);
        assert_eq!(idx.search(&[q(0.0), q(0.0)], 10).len(), 3);
        assert!(idx.search(&[q(0.0), q(0.0)], 0).is_empty());
    }

    #[test]
    fn delete_excludes_from_results() {
        let mut idx = build();
        assert!(idx.delete(1));
        assert!(!idx.delete(1));
        let hits = idx.search(&[q(0.0), q(0.0)], 3);
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn ties_break_by_id() {
        let mut idx = FlatIndex::new(1, Metric::L2);
        idx.insert(7, vec![q(1.0)]);
        idx.insert(3, vec![q(1.0)]); // identical vector, smaller id
        let hits = idx.search(&[q(1.0)], 2);
        assert_eq!(hits[0].id, 3);
        assert_eq!(hits[1].id, 7);
        assert_eq!(hits[0].dist, hits[1].dist);
    }

    #[test]
    fn inner_product_prefers_aligned() {
        let mut idx = FlatIndex::new(2, Metric::InnerProduct);
        idx.insert(1, vec![q(1.0), q(0.0)]);
        idx.insert(2, vec![q(-1.0), q(0.0)]);
        let hits = idx.search(&[q(1.0), q(0.0)], 2);
        assert_eq!(hits[0].id, 1);
    }

    #[test]
    fn roundtrip_preserves_results() {
        let mut idx = build();
        idx.delete(2);
        let mut e = Encoder::new();
        idx.encode(&mut e);
        let bytes = e.into_vec();
        let idx2 = FlatIndex::<i32>::decode(&mut Decoder::new(&bytes)).unwrap();
        let q0 = [q(0.3), q(0.3)];
        assert_eq!(idx.search(&q0, 5), idx2.search(&q0, 5));
    }

    #[test]
    fn f32_baseline_works() {
        let mut idx: FlatIndex<f32> = FlatIndex::new(2, Metric::L2);
        idx.insert(1, vec![0.0, 0.0]);
        idx.insert(2, vec![1.0, 1.0]);
        let hits = idx.search(&[0.9, 0.9], 2);
        assert_eq!(hits[0].id, 2);
    }

    fn corpus_vec(seed: u64, dim: usize) -> Vec<i32> {
        (0..dim)
            .map(|i| {
                let x = (seed.wrapping_add(i as u64)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                ((x % 131_072) as i64 - 65_536) as i32
            })
            .collect()
    }

    fn sq8_pair(metric: Metric, overscan: u32, n: usize) -> (FlatIndex<i32>, FlatIndex<i32>) {
        let dim = 16;
        let mut exact = FlatIndex::new(dim, metric);
        let mut q8 = FlatIndex::with_quant(dim, metric, QuantSpec::Sq8 { overscan });
        for id in 0..n as u64 {
            let v = corpus_vec(id, dim);
            exact.insert(id, v.clone());
            q8.insert(id, v);
        }
        (exact, q8)
    }

    #[test]
    fn sq8_two_phase_at_covering_overscan_is_bit_identical() {
        // overscan * k >= n ⇒ phase 1 keeps every live vector, so the
        // exact re-rank sees the full corpus and must reproduce the
        // exact scan bit for bit.
        let n = 60;
        let (exact, q8) = sq8_pair(Metric::L2, 1000, n);
        for qseed in 0..8u64 {
            let query = corpus_vec(1_000_000 + qseed, 16);
            let forced = q8.search_sq8_two_phase(&query, 10).expect("sq8 arena present");
            assert_eq!(forced, exact.search(&query, 10), "query {qseed}");
        }
    }

    #[test]
    fn sq8_search_falls_back_when_candidates_cover_n() {
        let (exact, q8) = sq8_pair(Metric::InnerProduct, 1000, 40);
        let query = corpus_vec(777, 16);
        // Through `search` the fallback takes the exact sweep directly;
        // either way the answer equals the exact index's.
        assert_eq!(q8.search(&query, 5), exact.search(&query, 5));
    }

    #[test]
    fn sq8_truncating_overscan_is_deterministic_and_exact_ranked() {
        let (exact, q8) = sq8_pair(Metric::L2, 2, 500);
        let query = corpus_vec(424_242, 16);
        let hits = q8.search(&query, 4);
        let again = q8.search(&query, 4);
        assert_eq!(hits, again, "same corpus, same query, same bits");
        assert_eq!(hits.len(), 4);
        // Every reported distance is the exact one (re-rank is exact even
        // when the candidate set truncates recall).
        let exact_hits = exact.search(&query, 500);
        for h in &hits {
            let reference = exact_hits.iter().find(|e| e.id == h.id).unwrap();
            assert_eq!(h.dist, reference.dist, "id {} must carry its exact distance", h.id);
        }
    }

    #[test]
    fn sq8_codes_rebuild_on_decode_and_are_never_serialized() {
        let (exact, q8) = sq8_pair(Metric::L2, 4, 32);
        let mut e1 = Encoder::new();
        q8.encode(&mut e1);
        let mut e2 = Encoder::new();
        exact.encode(&mut e2);
        // Identical bytes with and without the quantized tier.
        let bytes = e1.into_vec();
        assert_eq!(bytes, e2.into_vec());
        // Round-trip under the quant spec rebuilds a working code arena.
        let decoded =
            FlatIndex::<i32>::decode_with_quant(&mut Decoder::new(&bytes), q8.quant()).unwrap();
        assert_eq!(decoded.code_arena_bytes(), 32 * 16);
        let query = corpus_vec(9, 16);
        assert_eq!(
            decoded.search_sq8_two_phase(&query, 3),
            q8.search_sq8_two_phase(&query, 3)
        );
    }

    #[test]
    fn sq8_tie_heavy_corpus_breaks_ties_by_id() {
        // Many identical vectors: approx distances all tie, so phase 1
        // selection is decided purely by id — and the re-rank keeps that
        // order. Repeatedly identical across runs by construction.
        let dim = 4;
        let mut q8 = FlatIndex::with_quant(dim, Metric::L2, QuantSpec::Sq8 { overscan: 2 });
        for id in 0..64u64 {
            q8.insert(id, vec![1 << 16; dim]);
        }
        let hits = q8.search(&vec![1 << 16; dim], 5);
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert!(hits.iter().all(|h| h.dist == 0));
    }

    #[test]
    fn sq8_arena_bytes_report_the_shrink() {
        let (_, q8) = sq8_pair(Metric::L2, 4, 100);
        assert_eq!(q8.exact_arena_bytes(), 100 * 16 * 4);
        assert_eq!(q8.code_arena_bytes(), 100 * 16);
    }

    #[test]
    fn f32_index_ignores_quant_spec() {
        let mut idx: FlatIndex<f32> =
            FlatIndex::with_quant(2, Metric::L2, QuantSpec::Sq8 { overscan: 4 });
        for id in 0..50u64 {
            idx.insert(id, vec![id as f32, -(id as f32)]);
        }
        assert_eq!(idx.code_arena_bytes(), 0);
        assert!(idx.search_sq8_two_phase(&[1.0, 2.0], 3).is_none());
        // search silently stays on the exact path
        let hits = idx.search(&[10.0, -10.0], 1);
        assert_eq!(hits[0].id, 10);
    }

    #[test]
    fn repair_slot_rederives_codes() {
        let (exact, mut q8) = sq8_pair(Metric::L2, 1000, 20);
        // corrupt slot 5's row, then repair it back to the true vector:
        // both the exact arena and the derived codes must follow
        q8.repair_slot(5, Some(&corpus_vec(999, 16)), true);
        q8.repair_slot(5, Some(&corpus_vec(5, 16)), true);
        let query = corpus_vec(3, 16);
        assert_eq!(q8.search_sq8_two_phase(&query, 6).unwrap(), exact.search(&query, 6));
        assert_eq!(q8.store(), exact.store());
    }

    #[test]
    fn sq8_delete_keeps_codes_slot_aligned() {
        let (mut exact, mut q8) = sq8_pair(Metric::L2, 1000, 30);
        for id in [3u64, 17, 29] {
            assert!(q8.delete(id));
            assert!(exact.delete(id));
        }
        let query = corpus_vec(5, 16);
        assert_eq!(q8.search_sq8_two_phase(&query, 8).unwrap(), exact.search(&query, 8));
        assert!(q8.search(&query, 8).iter().all(|h| ![3, 17, 29].contains(&h.id)));
    }
}
