//! Integration: §8.1 snapshot transfer — including the real cross-process
//! leg (machine A and machine B are two OS processes; DESIGN §2).

use std::process::Command as Proc;
use valori::snapshot::Snapshot;
use valori::state::{Command, Kernel, KernelConfig};

fn build_kernel(n: usize, dim: usize) -> Kernel {
    let mut k = Kernel::new(KernelConfig::default_q16(dim));
    for i in 0..n as u64 {
        let v: Vec<f32> =
            (0..dim).map(|j| (((i * dim as u64 + j as u64) as f32) * 0.0137).sin() * 0.9).collect();
        k.apply(Command::insert(i, v)).unwrap();
    }
    k
}

#[test]
fn in_process_transfer_10k_shape() {
    // reduced from the paper's 10_000 to keep CI fast; the full size runs
    // in `cargo bench --bench snapshot_transfer`
    let k = build_kernel(2000, 64);
    let snap = Snapshot::capture(&k);
    let restored = Snapshot::from_bytes(&snap.to_bytes()).unwrap().restore().unwrap();
    assert_eq!(restored.state_hash(), k.state_hash());
    // identical k-NN ordering (the §8.1 addendum)
    for t in 0..10 {
        let q: Vec<f32> = (0..64).map(|j| ((t * 64 + j) as f32 * 0.01).cos() * 0.5).collect();
        assert_eq!(k.search_f32(&q, 10).unwrap(), restored.search_f32(&q, 10).unwrap());
    }
}

#[test]
fn snapshot_file_roundtrip() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("valori_it_snap_{}.vsnp", std::process::id()));
    let k = build_kernel(500, 32);
    let snap = Snapshot::capture(&k);
    snap.write_file(&path).unwrap();
    let loaded = Snapshot::read_file(&path).unwrap();
    assert_eq!(loaded, snap);
    assert_eq!(loaded.restore().unwrap().state_hash(), k.state_hash());
    std::fs::remove_file(&path).ok();
}

/// The real §8.1: process A (this test) writes WAL + snapshot; process B
/// (a fresh `valori` binary invocation) replays/verifies and reports the
/// hash on stdout. The hashes must match across the process boundary.
#[test]
fn cross_process_transfer_via_cli() {
    let exe = env!("CARGO_BIN_EXE_valori");
    let dir = std::env::temp_dir();
    let wal_path = dir.join(format!("valori_it_xproc_{}.wal", std::process::id()));
    let snap_path = dir.join(format!("valori_it_xproc_{}.vsnp", std::process::id()));

    // Machine A: produce the WAL and our own hash.
    let mut kernel = Kernel::new(KernelConfig::default_q16(16));
    {
        let mut wal = valori::wal::WalWriter::create(&wal_path).unwrap();
        for i in 0..200u64 {
            let v: Vec<f32> = (0..16).map(|j| ((i + j as u64) as f32 * 0.03).sin()).collect();
            let seq = kernel.seq();
            let canon = kernel.apply(Command::insert(i, v)).unwrap();
            wal.append(seq, &canon).unwrap();
        }
        wal.sync().unwrap();
    }
    let h_a = format!("{:016x}", kernel.state_hash());

    // Machine B step 1: replay WAL -> snapshot (separate process).
    let out = Proc::new(exe)
        .args(["snapshot", "--wal"])
        .arg(&wal_path)
        .args(["--out"])
        .arg(&snap_path)
        .args(["--dim", "16"])
        .output()
        .expect("run valori snapshot");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "snapshot failed: {stdout}");
    assert!(stdout.contains(&h_a), "process-B replay hash differs: {stdout} (want {h_a})");

    // Machine B step 2: restore + verify (another separate process).
    let out = Proc::new(exe)
        .args(["restore", "--snapshot"])
        .arg(&snap_path)
        .output()
        .expect("run valori restore");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "restore failed: {stdout}");
    assert!(stdout.contains("H_A == H_B"), "restore did not verify: {stdout}");
    assert!(stdout.contains(&h_a), "restored hash differs: {stdout}");

    std::fs::remove_file(&wal_path).ok();
    std::fs::remove_file(&snap_path).ok();
}

#[test]
fn snapshot_detects_every_single_byte_flip_in_sample() {
    let k = build_kernel(50, 8);
    let bytes = Snapshot::capture(&k).to_bytes();
    // flipping any byte must be detected (CRC or digest or parse error)
    let mut rng = valori::hash::XorShift64::new(3);
    for _ in 0..100 {
        let pos = rng.next_below(bytes.len() as u64) as usize;
        let mut corrupted = bytes.clone();
        corrupted[pos] ^= 0x40;
        assert!(
            Snapshot::from_bytes(&corrupted).is_err(),
            "byte flip at {pos} went undetected"
        );
    }
}

#[test]
fn restored_kernel_accepts_new_commands_identically() {
    let k = build_kernel(100, 8);
    let mut a = Snapshot::capture(&k).restore().unwrap();
    let mut b = Snapshot::capture(&k).restore().unwrap();
    for i in 100..150u64 {
        let v: Vec<f32> = (0..8).map(|j| ((i * 3 + j as u64) as f32 * 0.02).cos()).collect();
        a.apply(Command::insert(i, v.clone())).unwrap();
        b.apply(Command::insert(i, v)).unwrap();
    }
    assert_eq!(a.state_hash(), b.state_hash());
}
