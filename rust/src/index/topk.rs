//! Streaming bounded top-k selection under the total order `(dist, id)`.
//!
//! Replaces the collect-all + full-sort pattern in the search read paths:
//! a bounded binary max-heap keeps the k best candidates seen so far, so
//! selecting the top-k of N hits costs O(N log k) time and O(k) memory
//! instead of O(N log N) time and an O(N) allocation.
//!
//! Determinism: every comparison is on the total order `(dist, id)` — the
//! same key the former `sort_by(dist).then(id)` used — and external ids
//! are unique, so the kept set and its final ascending ordering are a pure
//! function of the input *multiset*. Push order (and therefore thread
//! scheduling, block size, or traversal order upstream) cannot change the
//! result: the heap output is bit-identical to sort + truncate.

#![forbid(unsafe_code)]

use super::Hit;
use std::collections::BinaryHeap;

/// Bounded max-heap over `(dist, id)` keeping the k smallest keys pushed.
#[derive(Debug, Clone)]
pub struct TopK<D: Ord + Copy> {
    k: usize,
    /// Max-heap: the *worst* kept key is on top, so a better candidate
    /// evicts it in O(log k).
    heap: BinaryHeap<(D, u64)>,
}

impl<D: Ord + Copy> TopK<D> {
    pub fn new(k: usize) -> Self {
        // k+1 so the push-then-pop in `push` never reallocates.
        Self { k, heap: BinaryHeap::with_capacity(k.saturating_add(1).min(4096)) }
    }

    /// Offer one candidate. Kept iff fewer than k candidates were seen or
    /// `(dist, id)` beats the current worst kept key.
    #[inline]
    pub fn push(&mut self, dist: D, id: u64) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((dist, id));
        } else if let Some(&worst) = self.heap.peek() {
            if (dist, id) < worst {
                self.heap.push((dist, id));
                self.heap.pop();
            }
        }
    }

    /// Absorb every candidate another `TopK` kept. Since the kept set is
    /// a pure function of the pushed multiset (module docs), folding any
    /// number of per-sub-range local heaps in *any* order equals one
    /// sequential pass over the union — the reduction that makes
    /// chunk-claiming parallel scans bit-safe (PERFORMANCE.md §9).
    pub fn merge(&mut self, other: TopK<D>) {
        for (dist, id) in other.heap {
            self.push(dist, id);
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Finish: the kept hits in ascending `(dist, id)` order — the
    /// deterministic ranking contract every index search returns.
    pub fn into_sorted_hits(self) -> Vec<Hit<D>> {
        self.heap
            .into_sorted_vec()
            .into_iter()
            .map(|(dist, id)| Hit { id, dist })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reference_topk(keys: &[(i64, u64)], k: usize) -> Vec<Hit<i64>> {
        let mut v: Vec<Hit<i64>> = keys.iter().map(|&(dist, id)| Hit { id, dist }).collect();
        v.sort_by(|a, b| a.dist.cmp(&b.dist).then(a.id.cmp(&b.id)));
        v.truncate(k);
        v
    }

    #[test]
    fn matches_sort_truncate_for_every_k() {
        // Pseudo-random keys with deliberate distance ties (unique ids).
        let keys: Vec<(i64, u64)> = (0..97u64)
            .map(|i| (((i.wrapping_mul(2654435761)) % 23) as i64, i))
            .collect();
        for k in [0, 1, 2, 5, 23, 96, 97, 200] {
            let mut topk = TopK::new(k);
            for &(d, id) in &keys {
                topk.push(d, id);
            }
            assert_eq!(topk.into_sorted_hits(), reference_topk(&keys, k), "k={k}");
        }
    }

    #[test]
    fn push_order_is_irrelevant() {
        let keys: Vec<(i64, u64)> = (0..50u64).map(|i| ((i as i64 * 7) % 13, i)).collect();
        let mut fwd = TopK::new(8);
        let mut rev = TopK::new(8);
        for &(d, id) in &keys {
            fwd.push(d, id);
        }
        for &(d, id) in keys.iter().rev() {
            rev.push(d, id);
        }
        assert_eq!(fwd.into_sorted_hits(), rev.into_sorted_hits());
    }

    #[test]
    fn eviction_keeps_the_k_best() {
        let mut t = TopK::new(2);
        t.push(10, 1);
        t.push(5, 2);
        t.push(7, 3); // evicts (10, 1)
        t.push(100, 4); // worse than the kept worst: ignored
        assert_eq!(t.len(), 2);
        let hits: Vec<u64> = t.into_sorted_hits().iter().map(|h| h.id).collect();
        assert_eq!(hits, vec![2, 3]);
    }

    #[test]
    fn merge_of_partitions_equals_single_pass_for_any_split() {
        let keys: Vec<(i64, u64)> = (0..120u64)
            .map(|i| (((i.wrapping_mul(40503)) % 31) as i64, i))
            .collect();
        let expect = reference_topk(&keys, 9);
        // Every contiguous 3-way partition point, merged in both orders.
        for a in 0..keys.len() {
            for b in (a..keys.len()).step_by(17) {
                let mut parts: Vec<TopK<i64>> = Vec::new();
                for range in [&keys[..a], &keys[a..b], &keys[b..]] {
                    let mut t = TopK::new(9);
                    for &(d, id) in range {
                        t.push(d, id);
                    }
                    parts.push(t);
                }
                let mut fwd = TopK::new(9);
                for p in parts.clone() {
                    fwd.merge(p);
                }
                let mut rev = TopK::new(9);
                for p in parts.into_iter().rev() {
                    rev.merge(p);
                }
                assert_eq!(fwd.into_sorted_hits(), expect, "split ({a},{b})");
                assert_eq!(rev.into_sorted_hits(), expect, "split ({a},{b}) reversed");
            }
        }
    }

    #[test]
    fn k_zero_keeps_nothing() {
        let mut t = TopK::new(0);
        t.push(1i64, 1);
        assert!(t.is_empty());
        assert!(t.into_sorted_hits().is_empty());
    }
}
