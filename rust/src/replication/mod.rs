//! State-machine replication with hash verification (paper §9).
//!
//! "Nodes in a distributed network can verify they hold the same 'truth'
//! by comparing memory state hashes" — because the kernel is a
//! deterministic state machine, replication is just log shipping: the
//! primary assigns a total order to canonical commands; followers apply
//! the same prefix and *must* reach bit-identical state, which both sides
//! prove by exchanging FNV/SHA-256 state hashes. A float-based store
//! cannot make this guarantee (§9 "Floating-point memory systems violate
//! this requirement").
//!
//! Two transports are provided:
//! - in-process ([`Cluster`]): N kernels fed from one log — used by tests,
//!   property tests and the consensus example;
//! - HTTP ([`sync_follower`]): pulls `/v1/log` from a primary node and
//!   pushes `/v1/apply` to a follower (see [`crate::node`]).
//!
//! Multi-tenant deployments replicate **per collection**: each
//! collection is its own replayable state machine with its own per-shard
//! feeds, so [`sync_collection`] ships one tenant over the `/v2` surface
//! and [`sync_all_collections`] discovers and mirrors a whole fleet onto
//! a fresh follower (collection-by-collection, shard-by-shard,
//! first-error-wins).
//!
//! When hashes *disagree*, the FNV root only says "diverged"; the Merkle
//! trees of [`crate::proof`] say **where**. [`merkle_diff_repair`] walks
//! the per-shard trees top-down over `GET …/proof` (two child hashes per
//! diverged node per level — O(d · log n) hashes for d diverged records,
//! never the full state), pinpoints the exact diverged slots, ships each
//! one's canonical leaf encoding from the primary, and installs it on the
//! follower via `POST …/repair` (un-logged state surgery; see
//! [`crate::state::Kernel::repair_slot`]).

#![forbid(unsafe_code)]

use crate::http::client;
use crate::node::{hex_decode, hex_encode};
use crate::state::{CanonCommand, Command, Kernel, KernelConfig, StateError};

/// Verification outcome for one follower.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyReport {
    pub node: usize,
    pub seq: u64,
    pub hash: u64,
    pub converged: bool,
}

/// An in-process replicated cluster: one primary, N-1 followers, all
/// driven by the primary's canonical log.
pub struct Cluster {
    nodes: Vec<Kernel>,
    log: Vec<CanonCommand>,
    /// How many log entries each node has applied.
    applied: Vec<usize>,
}

impl Cluster {
    /// All nodes must start from the same config (it is part of the
    /// snapshot identity).
    pub fn new(config: KernelConfig, n: usize) -> Self {
        assert!(n >= 1);
        Self {
            nodes: (0..n).map(|_| Kernel::new(config.clone())).collect(),
            log: Vec::new(),
            applied: vec![0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn log_len(&self) -> usize {
        self.log.len()
    }

    pub fn node(&self, i: usize) -> &Kernel {
        &self.nodes[i]
    }

    /// Submit an external command to the primary (node 0): it validates,
    /// canonicalizes, applies, and appends to the shared log.
    pub fn submit(&mut self, cmd: Command) -> Result<&CanonCommand, StateError> {
        let canon = self.nodes[0].apply(cmd)?;
        self.applied[0] += 1;
        self.log.push(canon);
        Ok(self.log.last().unwrap())
    }

    /// Ship the log to one follower (apply everything it hasn't seen).
    pub fn sync_node(&mut self, i: usize) -> Result<usize, StateError> {
        let mut n = 0;
        while self.applied[i] < self.log.len() {
            let canon = &self.log[self.applied[i]];
            self.nodes[i].apply_canon(canon)?;
            self.applied[i] += 1;
            n += 1;
        }
        Ok(n)
    }

    /// Ship the log to all followers.
    pub fn sync_all(&mut self) -> Result<(), StateError> {
        for i in 1..self.nodes.len() {
            self.sync_node(i)?;
        }
        Ok(())
    }

    /// Compare state hashes across nodes (paper §9's convergence check).
    pub fn verify(&self) -> Vec<VerifyReport> {
        let h0 = self.nodes[0].state_hash();
        self.nodes
            .iter()
            .enumerate()
            .map(|(i, k)| VerifyReport {
                node: i,
                seq: k.seq(),
                hash: k.state_hash(),
                converged: k.state_hash() == h0,
            })
            .collect()
    }

    /// True if every node's hash matches the primary's.
    pub fn converged(&self) -> bool {
        self.verify().iter().all(|r| r.converged)
    }

    /// Simulate a byzantine / buggy follower flipping one raw vector value
    /// (used by tests and the consensus demo to show detection).
    pub fn corrupt_node_for_test(&mut self, i: usize, id: u64) -> bool {
        // Rebuild node i from a corrupted command replay: flip one command.
        let mut tampered = self.log.clone();
        for c in tampered.iter_mut() {
            if let CanonCommand::Insert { id: cid, raw } = c {
                if *cid == id && !raw.is_empty() {
                    raw[0] ^= 1; // one bit of one component
                    let mut k = Kernel::new(self.nodes[i].config().clone());
                    for cmd in &tampered {
                        if k.apply_canon(cmd).is_err() {
                            return false;
                        }
                    }
                    self.nodes[i] = k;
                    self.applied[i] = tampered.len();
                    return true;
                }
            }
        }
        false
    }
}

/// Pull a primary's shard-0 log over HTTP and push it to a follower node;
/// returns (commands shipped, follower hash hex). Both sides are `/v1`
/// APIs from [`crate::node`]. For single-shard nodes shard 0 is the whole
/// log; sharded deployments ship every shard via
/// [`sync_follower_shard`] (the shard feeds are independent, so they can
/// be shipped in parallel by one sync driver per shard).
pub fn sync_follower(
    primary: &std::net::SocketAddr,
    follower: &std::net::SocketAddr,
    from: usize,
) -> std::io::Result<(usize, String)> {
    sync_follower_shard(primary, follower, 0, from)
}

/// Ship one shard's log feed (`/v1/log?shard=S`) from primary to follower.
/// The feed is applied replay-style to the *same shard* on the follower
/// (`/v1/apply` with a `shard` field): each shard's state is a pure
/// function of its own subsequence, so the feeds are independent and
/// convergence does not depend on how shard shipments interleave — even
/// with cross-shard links and their delete-cleanup unlink records.
pub fn sync_follower_shard(
    primary: &std::net::SocketAddr,
    follower: &std::net::SocketAddr,
    shard: u32,
    from: usize,
) -> std::io::Result<(usize, String)> {
    use crate::json::Json;

    let (status, feed) =
        client::get_json(primary, &format!("/v1/log?shard={shard}&from={from}"))?;
    if status != 200 {
        return Err(std::io::Error::other(format!("log fetch failed: {status}")));
    }
    let cmds = feed.get("commands").as_array().unwrap_or(&[]).to_vec();
    let n = cmds.len();
    if n == 0 {
        let (_, h) = client::get_json(follower, "/v1/hash")?;
        return Ok((0, h.get("fnv").as_str().unwrap_or("").to_string()));
    }
    let body = Json::object(vec![
        ("shard", Json::Int(shard as i64)),
        ("commands", Json::Array(cmds)),
    ]);
    let (status, resp) = client::post_json(follower, "/v1/apply", &body)?;
    if status != 200 {
        return Err(std::io::Error::other(format!(
            "apply failed: {status}: {resp}"
        )));
    }
    Ok((n, resp.get("hash").as_str().unwrap_or("").to_string()))
}

/// Ship every shard of a sharded primary to a follower, starting from the
/// given per-shard offsets (`from.len()` must equal the primary's shard
/// count). Returns per-shard shipped counts and the follower's final hash.
///
/// The shard feeds are independent subsequences, so catch-up is
/// pipelined: **one sync thread per shard**, each holding a pair of
/// keep-alive [`client::Connection`]s (primary + follower) so paging
/// through a long feed stops paying per-request connect cost. Threads
/// are joined before returning; the first shard error wins. Convergence
/// does not depend on how the shard shipments interleave (each shard's
/// state is a pure function of its own feed), which is exactly why this
/// parallelism cannot affect the follower's root hash.
pub fn sync_all_shards(
    primary: &std::net::SocketAddr,
    follower: &std::net::SocketAddr,
    from: &[usize],
) -> std::io::Result<(Vec<usize>, String)> {
    let results: Vec<std::io::Result<usize>> = std::thread::scope(|scope| {
        let handles: Vec<_> = from
            .iter()
            .enumerate()
            .map(|(shard, &offset)| {
                scope.spawn(move || {
                    sync_shard_to_completion(primary, follower, shard as u32, offset)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard sync thread panicked")).collect()
    });
    let mut shipped = Vec::with_capacity(results.len());
    for r in results {
        shipped.push(r?); // first-error-wins
    }
    let (status, h) = client::get_json(follower, "/v1/hash")?;
    if status != 200 {
        return Err(std::io::Error::other(format!("follower hash fetch failed: {status}")));
    }
    Ok((shipped, h.get("fnv").as_str().unwrap_or("").to_string()))
}

/// Drive one shard's feed to full catch-up over persistent connections:
/// page `/v1/log?shard=S` from the primary and replay each page onto the
/// follower's same shard until a fetch returns no new commands.
fn sync_shard_to_completion(
    primary: &std::net::SocketAddr,
    follower: &std::net::SocketAddr,
    shard: u32,
    mut from: usize,
) -> std::io::Result<usize> {
    use crate::json::Json;

    let mut pc = client::Connection::connect(primary)?;
    let mut fc = client::Connection::connect(follower)?;
    let mut shipped = 0usize;
    loop {
        let (status, feed) = pc.get_json(&format!("/v1/log?shard={shard}&from={from}"))?;
        if status != 200 {
            return Err(std::io::Error::other(format!(
                "shard {shard}: log fetch failed: {status}"
            )));
        }
        let cmds = feed.get("commands").as_array().unwrap_or(&[]).to_vec();
        if cmds.is_empty() {
            return Ok(shipped);
        }
        let n = cmds.len();
        let body = Json::object(vec![
            ("shard", Json::Int(shard as i64)),
            ("commands", Json::Array(cmds)),
        ]);
        let (status, resp) = fc.post_json("/v1/apply", &body)?;
        if status != 200 {
            return Err(std::io::Error::other(format!(
                "shard {shard}: apply failed: {status}: {resp}"
            )));
        }
        shipped += n;
        from += n;
    }
}

/// Ship one collection's shard feed to full catch-up over the `/v2`
/// surface (`GET /v2/collections/{name}/log` →
/// `POST /v2/collections/{name}/apply`), paging over persistent
/// keep-alive connections exactly like the /v1 driver. Returns commands
/// shipped.
fn sync_collection_shard_to_completion(
    primary: &std::net::SocketAddr,
    follower: &std::net::SocketAddr,
    collection: &str,
    shard: u32,
    mut from: usize,
) -> std::io::Result<usize> {
    use crate::json::Json;

    let mut pc = client::Connection::connect(primary)?;
    let mut fc = client::Connection::connect(follower)?;
    let mut shipped = 0usize;
    loop {
        let (status, feed) = pc.get_json(&format!(
            "/v2/collections/{collection}/log?shard={shard}&from={from}"
        ))?;
        if status != 200 {
            return Err(std::io::Error::other(format!(
                "collection {collection} shard {shard}: log fetch failed: {status}: {feed}"
            )));
        }
        let cmds = feed.get("data").get("commands").as_array().unwrap_or(&[]).to_vec();
        if cmds.is_empty() {
            return Ok(shipped);
        }
        let n = cmds.len();
        let body = Json::object(vec![
            ("commands", Json::Array(cmds)),
            ("shard", Json::Int(shard as i64)),
        ]);
        let (status, resp) =
            fc.post_json(&format!("/v2/collections/{collection}/apply"), &body)?;
        if status != 200 {
            return Err(std::io::Error::other(format!(
                "collection {collection} shard {shard}: apply failed: {status}: {resp}"
            )));
        }
        shipped += n;
        from += n;
    }
}

/// Ship every shard of one collection from primary to follower over the
/// `/v2` surface, starting at the given per-shard offsets (`from.len()`
/// must equal the collection's shard count; the collection must already
/// exist on the follower with the same spec). One sync thread per shard,
/// joined, first-error-wins — the shard feeds are independent
/// subsequences, so interleaving cannot affect the follower's root.
/// Returns per-shard shipped counts and the follower's final root hex.
pub fn sync_collection(
    primary: &std::net::SocketAddr,
    follower: &std::net::SocketAddr,
    collection: &str,
    from: &[usize],
) -> std::io::Result<(Vec<usize>, String)> {
    let results: Vec<std::io::Result<usize>> = std::thread::scope(|scope| {
        let handles: Vec<_> = from
            .iter()
            .enumerate()
            .map(|(shard, &offset)| {
                scope.spawn(move || {
                    sync_collection_shard_to_completion(
                        primary,
                        follower,
                        collection,
                        shard as u32,
                        offset,
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard sync thread panicked")).collect()
    });
    let mut shipped = Vec::with_capacity(results.len());
    for r in results {
        shipped.push(r?); // first-error-wins
    }
    let (status, h) = client::get_json(follower, &format!("/v2/collections/{collection}/hash"))?;
    if status != 200 {
        return Err(std::io::Error::other(format!(
            "collection {collection}: follower hash fetch failed: {status}"
        )));
    }
    Ok((shipped, h.get("data").get("root").as_str().unwrap_or("").to_string()))
}

/// Full-fleet catch-up for a **fresh** follower: discover the primary's
/// collections (`GET /v2/collections`), mirror each one's spec onto the
/// follower (`PUT`; an already-existing collection is accepted as-is),
/// and ship every shard of every collection from offset 0. Returns
/// `(collection, per-shard shipped counts)` per collection, in
/// lexicographic order. A follower that already holds conflicting
/// history fails loudly (duplicate-id rejections from `apply`) rather
/// than forking state — rerun against an empty follower or use
/// [`sync_collection`] with real offsets for incremental catch-up.
pub fn sync_all_collections(
    primary: &std::net::SocketAddr,
    follower: &std::net::SocketAddr,
) -> std::io::Result<Vec<(String, Vec<usize>)>> {
    use crate::json::Json;

    let (status, listing) = client::get_json(primary, "/v2/collections")?;
    if status != 200 {
        return Err(std::io::Error::other(format!("collection listing failed: {status}")));
    }
    let mut out = Vec::new();
    for entry in listing.get("data").get("collections").as_array().unwrap_or(&[]) {
        let name = entry
            .get("name")
            .as_str()
            .ok_or_else(|| std::io::Error::other("collection entry missing name"))?;
        let shards = entry.get("shards").as_u64().unwrap_or(1) as usize;
        let spec = Json::object(vec![
            ("dim", Json::Int(entry.get("dim").as_i64().unwrap_or(0))),
            ("index", Json::str(entry.get("index").as_str().unwrap_or("hnsw"))),
            ("shards", Json::Int(shards as i64)),
        ]);
        let (st, _) = client::request(
            follower,
            "PUT",
            &format!("/v2/collections/{name}"),
            spec.to_string().as_bytes(),
        )?;
        // 200 = created; 409 = already there (the apply path will verify
        // compatibility the hard way). Anything else is a real failure.
        if st != 200 && st != 409 {
            return Err(std::io::Error::other(format!(
                "collection {name}: follower create failed: {st}"
            )));
        }
        let (shipped, _root) = sync_collection(primary, follower, name, &vec![0; shards])?;
        out.push((name.to_string(), shipped));
    }
    Ok(out)
}

/// Outcome of one online tenant migration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MigrationReport {
    /// Stream bytes moved (header + chunk framing + payload).
    pub bytes: u64,
    /// Windowed restore PUTs issued against the destination.
    pub puts: usize,
    /// The per-collection root hash, hex — verified identical on both
    /// nodes after the restore.
    pub root: String,
}

/// Restore windows stay under the front end's 1 MiB body cap with room
/// for chunk framing.
const MIGRATE_WINDOW: usize = 512 * 1024;

/// Online tenant migration: stream `collection`'s snapshot off `src`
/// (`GET /v2/collections/{name}/snapshot`) and pipe it into `dst`
/// (`PUT /v2/collections/{name}/restore?offset=N`) in windowed PUTs,
/// then require the two nodes' per-collection root hashes to be
/// bit-identical (paper §8.1's `H_A ≡ H_B`, per tenant, over the wire).
///
/// Memory on this driver is O(window): response bytes flow from the
/// source socket into at most one 512 KiB window before being PUT
/// onward — the collection itself is never materialized here, and the
/// source node's peak is one shard frame + one chunk (see the snapshot
/// route). The destination must not already hold `collection`.
pub fn migrate_collection(
    src: &std::net::SocketAddr,
    dst: &std::net::SocketAddr,
    collection: &str,
) -> std::io::Result<MigrationReport> {
    let mut src_conn = client::Connection::connect(src)?;
    let mut dst_conn = client::Connection::connect(dst)?;

    let mut window: Vec<u8> = Vec::with_capacity(MIGRATE_WINDOW);
    let mut sent: u64 = 0;
    let mut puts: usize = 0;
    let mut final_resp: Option<crate::json::Json> = None;

    let flush = |window: &mut Vec<u8>,
                 sent: &mut u64,
                 puts: &mut usize,
                 final_resp: &mut Option<crate::json::Json>,
                 dst_conn: &mut client::Connection|
     -> std::io::Result<()> {
        if window.is_empty() {
            return Ok(());
        }
        let path = format!("/v2/collections/{collection}/restore?offset={sent}");
        let (status, body) = dst_conn.request("PUT", &path, window)?;
        let text = String::from_utf8_lossy(&body);
        let json = crate::json::parse(&text).unwrap_or(crate::json::Json::Null);
        if status != 200 {
            return Err(std::io::Error::other(format!(
                "restore PUT at offset {sent} failed: {status}: {text}"
            )));
        }
        *sent += window.len() as u64;
        *puts += 1;
        *final_resp = Some(json.get("data").clone());
        window.clear();
        Ok(())
    };

    let snapshot_path = format!("/v2/collections/{collection}/snapshot");
    let (status, total, err_body) = {
        let mut sink = |block: &[u8]| -> std::io::Result<()> {
            let mut rest = block;
            while !rest.is_empty() {
                let room = MIGRATE_WINDOW - window.len();
                let take = room.min(rest.len());
                window.extend_from_slice(&rest[..take]);
                rest = &rest[take..];
                if window.len() == MIGRATE_WINDOW {
                    flush(&mut window, &mut sent, &mut puts, &mut final_resp, &mut dst_conn)?;
                }
            }
            Ok(())
        };
        src_conn.request_streaming("GET", &snapshot_path, &[], &mut sink)?
    };
    if status != 200 {
        return Err(std::io::Error::other(format!(
            "snapshot fetch failed: {status}: {}",
            String::from_utf8_lossy(&err_body)
        )));
    }
    flush(&mut window, &mut sent, &mut puts, &mut final_resp, &mut dst_conn)?;
    if sent != total {
        return Err(std::io::Error::other(format!(
            "stream torn: source advertised {total} bytes, forwarded {sent}"
        )));
    }
    let final_resp = final_resp
        .ok_or_else(|| std::io::Error::other("empty snapshot stream (no restore PUT issued)"))?;
    if final_resp.get("complete").as_bool() != Some(true) {
        return Err(std::io::Error::other(format!(
            "destination did not complete the restore: {final_resp}"
        )));
    }

    // The §8.1 check, per tenant: both nodes must report the identical
    // per-collection root hash, bit for bit.
    let hash_path = format!("/v2/collections/{collection}/hash");
    let (st_a, ha) = src_conn.get_json(&hash_path)?;
    let (st_b, hb) = dst_conn.get_json(&hash_path)?;
    if st_a != 200 || st_b != 200 {
        return Err(std::io::Error::other(format!(
            "post-migration hash fetch failed: src {st_a}, dst {st_b}"
        )));
    }
    let root_a = ha.get("data").get("root").as_str().unwrap_or("").to_string();
    let root_b = hb.get("data").get("root").as_str().unwrap_or("").to_string();
    if root_a.is_empty() || root_a != root_b {
        return Err(std::io::Error::other(format!(
            "MIGRATION HASH MISMATCH: src root {root_a}, dst root {root_b}"
        )));
    }
    Ok(MigrationReport { bytes: sent, puts, root: root_a })
}

/// Outcome of one record-level divergence repair (paper §9's convergence
/// check, sharpened to record granularity by [`crate::proof`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RepairReport {
    /// Every diverged record the walk pinpointed: `(shard, slot, id)`.
    pub diverged: Vec<(u32, u32, u64)>,
    /// Tree hashes fetched across both nodes during the bisection —
    /// O(d · log n) for d diverged records, never O(n).
    pub hashes_transferred: usize,
    /// Canonical leaf encodings shipped primary → follower.
    pub records_transferred: usize,
    /// The follower's combined Merkle root after repair, hex — verified
    /// bit-identical to the primary's before returning.
    pub root: String,
}

/// Record-level divergence repair: compare two nodes' Merkle receipts for
/// one collection, bisect every diverged shard tree top-down to the exact
/// slots that disagree, and overwrite each one on the follower with the
/// primary's canonical leaf encoding.
///
/// The walk is the whole point: where log re-shipping moves O(n) state to
/// fix one flipped bit, this moves `2·log2(capacity)` hashes per diverged
/// record plus the one record itself. Both nodes must have applied the
/// same log prefix (equal `seq`/tree shape — slot→id assignment is a pure
/// function of the log); structural divergence fails loudly and needs a
/// real re-sync instead.
pub fn merkle_diff_repair(
    primary: &std::net::SocketAddr,
    follower: &std::net::SocketAddr,
    collection: &str,
) -> std::io::Result<RepairReport> {
    use crate::json::Json;
    use crate::proof::Receipt;

    fn get_data(
        conn: &mut client::Connection,
        path: &str,
        what: &str,
    ) -> std::io::Result<Json> {
        let (status, body) = conn.get_json(path)?;
        if status != 200 {
            return Err(std::io::Error::other(format!("{what} fetch failed: {status}: {body}")));
        }
        Ok(body.get("data").clone())
    }

    fn receipt(data: &Json, who: &str) -> std::io::Result<Receipt> {
        Receipt::from_json(data)
            .ok_or_else(|| std::io::Error::other(format!("{who} receipt: bad wire shape")))
    }

    fn hex_hashes(data: &Json) -> std::io::Result<Vec<String>> {
        data.get("hashes")
            .as_array()
            .unwrap_or(&[])
            .iter()
            .map(|h| h.as_str().map(String::from))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| std::io::Error::other("proof response: non-string hash"))
    }

    let mut pc = client::Connection::connect(primary)?;
    let mut fc = client::Connection::connect(follower)?;
    let proof = format!("/v2/collections/{collection}/proof");

    let pr = receipt(&get_data(&mut pc, &proof, "primary receipt")?, "primary")?;
    let fr = receipt(&get_data(&mut fc, &proof, "follower receipt")?, "follower")?;
    if pr.shard_roots.len() != fr.shard_roots.len() {
        return Err(std::io::Error::other(format!(
            "shard count mismatch: primary {}, follower {} — repair needs a full re-sync",
            pr.shard_roots.len(),
            fr.shard_roots.len(),
        )));
    }
    let mut report = RepairReport {
        diverged: Vec::new(),
        hashes_transferred: 0,
        records_transferred: 0,
        root: crate::hash::hex_lower(&fr.merkle_root),
    };
    if pr.merkle_root == fr.merkle_root {
        return Ok(report); // converged already; nothing moved
    }

    for shard in 0..pr.shard_roots.len() as u32 {
        if pr.shard_roots[shard as usize] == fr.shard_roots[shard as usize] {
            continue;
        }
        // Probe the tree shape on both sides (one hash each).
        let probe = format!("{proof}?shard={shard}&level=0&from=0&count=1");
        let pd = get_data(&mut pc, &probe, "primary probe")?;
        let fd = get_data(&mut fc, &probe, "follower probe")?;
        report.hashes_transferred += 2;
        let levels = pd.get("levels").as_u64().unwrap_or(0) as usize;
        let capacity = pd.get("capacity").as_u64().unwrap_or(0);
        if fd.get("levels").as_u64().unwrap_or(0) as usize != levels
            || fd.get("capacity").as_u64().unwrap_or(0) != capacity
        {
            return Err(std::io::Error::other(format!(
                "shard {shard}: tree shape mismatch (structural divergence) — \
                 repair needs a full re-sync"
            )));
        }
        // Top-down bisection: the frontier is the set of diverged node
        // indices at the current level; each step fetches only their two
        // children. The shard root already disagrees, so start from it.
        let mut frontier: Vec<usize> = vec![0];
        for level in (0..levels.saturating_sub(1)).rev() {
            let mut next = Vec::new();
            for &i in &frontier {
                let path = format!("{proof}?shard={shard}&level={level}&from={}&count=2", 2 * i);
                let ph = hex_hashes(&get_data(&mut pc, &path, "primary hashes")?)?;
                let fh = hex_hashes(&get_data(&mut fc, &path, "follower hashes")?)?;
                report.hashes_transferred += ph.len() + fh.len();
                for (j, (a, b)) in ph.iter().zip(&fh).enumerate() {
                    if a != b {
                        next.push(2 * i + j);
                    }
                }
            }
            frontier = next;
        }
        // The frontier now holds diverged *leaf slots* (a capacity-1 tree
        // has its leaf as the root, so the initial frontier already did).
        for slot in frontier {
            let slot = slot as u32;
            let leaf =
                get_data(&mut pc, &format!("{proof}?shard={shard}&slot={slot}"), "primary leaf")?;
            let hex = leaf
                .get("record")
                .as_str()
                .ok_or_else(|| std::io::Error::other("leaf response missing record"))?;
            let bytes = hex_decode(hex)
                .ok_or_else(|| std::io::Error::other("leaf response: bad record hex"))?;
            let rec = crate::proof::leaf::decode(&bytes)
                .map_err(|e| std::io::Error::other(format!("leaf response: bad encoding: {e}")))?;
            let body = Json::object(vec![
                ("record", Json::str(hex)),
                ("shard", Json::Int(shard as i64)),
                ("slot", Json::Int(slot as i64)),
            ]);
            let (status, resp) =
                fc.post_json(&format!("/v2/collections/{collection}/repair"), &body)?;
            if status != 200 {
                return Err(std::io::Error::other(format!(
                    "shard {shard} slot {slot}: repair failed: {status}: {resp}"
                )));
            }
            report.records_transferred += 1;
            report.diverged.push((shard, slot, rec.id));
        }
    }

    // The §9 convergence check, sharpened: after record-level repair the
    // follower's combined root must equal the primary's, bit for bit.
    let fr = receipt(&get_data(&mut fc, &proof, "follower receipt")?, "follower")?;
    if fr.merkle_root != pr.merkle_root {
        return Err(std::io::Error::other(format!(
            "REPAIR DID NOT CONVERGE: primary root {}, follower root {}",
            crate::hash::hex_lower(&pr.merkle_root),
            crate::hash::hex_lower(&fr.merkle_root),
        )));
    }
    report.root = crate::hash::hex_lower(&fr.merkle_root);
    Ok(report)
}

/// Round-trip helper: serialize a command log to a hex-lines string and
/// back (audit-file format used by the replay example).
pub fn log_to_text(log: &[CanonCommand]) -> String {
    let mut out = String::new();
    for c in log {
        out.push_str(&hex_encode(&c.to_bytes()));
        out.push('\n');
    }
    out
}

/// Parse an audit-file back into commands (strict).
pub fn log_from_text(text: &str) -> Result<Vec<CanonCommand>, String> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let bytes = hex_decode(l.trim()).ok_or_else(|| format!("bad hex line: {l}"))?;
            CanonCommand::from_bytes(&bytes).map_err(|e| format!("bad command: {e}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn config() -> KernelConfig {
        KernelConfig::default_q16(4)
    }

    #[test]
    fn three_node_convergence() {
        let mut c = Cluster::new(config(), 3);
        for i in 0..50u64 {
            let x = i as f32 / 50.0;
            c.submit(Command::insert(i, vec![x, 1.0 - x, 0.5, -x])).unwrap();
        }
        c.submit(Command::Delete { id: 7 }).unwrap();
        c.submit(Command::Link { from: 1, to: 2 }).unwrap();
        assert!(!c.converged()); // followers haven't synced yet
        c.sync_all().unwrap();
        assert!(c.converged());
        let reports = c.verify();
        assert_eq!(reports.len(), 3);
        assert!(reports.iter().all(|r| r.seq == 52));
    }

    #[test]
    fn incremental_sync() {
        let mut c = Cluster::new(config(), 2);
        c.submit(Command::insert(1, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
        assert_eq!(c.sync_node(1).unwrap(), 1);
        assert!(c.converged());
        c.submit(Command::insert(2, vec![0.4, 0.3, 0.2, 0.1])).unwrap();
        c.submit(Command::Link { from: 1, to: 2 }).unwrap();
        assert_eq!(c.sync_node(1).unwrap(), 2);
        assert_eq!(c.sync_node(1).unwrap(), 0); // idempotent
        assert!(c.converged());
    }

    #[test]
    fn rejected_command_does_not_enter_log() {
        let mut c = Cluster::new(config(), 2);
        c.submit(Command::insert(1, vec![0.0; 4])).unwrap();
        assert!(c.submit(Command::insert(1, vec![0.0; 4])).is_err()); // dup
        assert_eq!(c.log_len(), 1);
        c.sync_all().unwrap();
        assert!(c.converged());
    }

    #[test]
    fn single_bit_corruption_is_detected() {
        let mut c = Cluster::new(config(), 3);
        for i in 0..20u64 {
            c.submit(Command::insert(i, vec![0.25, -0.25, (i as f32) * 0.01, 0.0])).unwrap();
        }
        c.sync_all().unwrap();
        assert!(c.converged());
        assert!(c.corrupt_node_for_test(2, 13));
        let reports = c.verify();
        assert!(reports[0].converged);
        assert!(reports[1].converged);
        assert!(!reports[2].converged, "corruption must break the hash");
    }

    #[test]
    fn search_results_identical_across_replicas() {
        let mut c = Cluster::new(config(), 2);
        for i in 0..100u64 {
            let x = (i as f32 * 0.37).sin() * 0.5;
            let y = (i as f32 * 0.11).cos() * 0.5;
            c.submit(Command::insert(i, vec![x, y, x * y, 0.1])).unwrap();
        }
        c.sync_all().unwrap();
        let q = [0.2f32, -0.1, 0.05, 0.1];
        let h0 = c.node(0).search_f32(&q, 10).unwrap();
        let h1 = c.node(1).search_f32(&q, 10).unwrap();
        assert_eq!(h0, h1); // ids AND raw distances identical
    }

    #[test]
    fn log_text_roundtrip() {
        let mut c = Cluster::new(config(), 1);
        c.submit(Command::insert(1, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
        c.submit(Command::Delete { id: 1 }).unwrap();
        let text = log_to_text(&c.log);
        let back = log_from_text(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1], CanonCommand::Delete { id: 1 });
        assert!(log_from_text("zz\n").is_err());
    }
}
