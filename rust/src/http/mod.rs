//! Minimal HTTP/1.1 server substrate (tokio/axum unavailable offline).
//!
//! Two interchangeable front ends serve the same `Handler`:
//!
//! - **Epoll reactor** (`reactor.rs`, the default on Linux): a hand-rolled
//!   edge-triggered epoll event loop over nonblocking sockets with
//!   per-connection state machines, HTTP/1.1 keep-alive, a timer wheel for
//!   read/write timeouts, a bounded connection table and a small dispatch
//!   pool so kernel work never blocks the event loop.
//! - **Blocking pool** ([`Server::start_blocking`]): the original
//!   `std::net` thread-per-connection path, kept as the equivalence
//!   reference — `tests/http_equivalence.rs` proves both produce
//!   byte-identical responses.
//!
//! Either way this is the "Node ('std')" outer layer of the paper's §5.3
//! split — it wraps the kernel but never alters its logic, and it orders
//! nothing that reaches the kernel: requests are dispatched to the handler
//! exactly as parsed, one at a time per connection.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

#[cfg(target_os = "linux")]
mod reactor;

/// Maximum accepted body size (1 MiB — vectors are ~KB scale).
pub const MAX_BODY: usize = 1 << 20;
/// Maximum header section size (bytes after the request line, including
/// the terminating blank line).
pub const MAX_HEADER: usize = 16 << 10;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Raw query string (after '?'), if any.
    pub query: Option<String>,
    /// Header names lower-cased.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> Result<&str, std::str::Utf8Error> {
        std::str::from_utf8(&self.body)
    }

    /// Does the client want the connection kept open after this request?
    pub fn wants_keep_alive(&self) -> bool {
        self.headers
            .get("connection")
            .map(|v| !v.eq_ignore_ascii_case("close"))
            .unwrap_or(true)
    }
}

/// A pull-based response body for payloads too large to materialize
/// (snapshot streams). The front end writes the head with the exact
/// `content_length`, then pulls blocks as the socket drains — the event
/// loop never holds more than one block, and backpressure propagates to
/// the producer naturally (nothing is pulled while the socket is full).
/// Producers must yield exactly `content_length` bytes; yielding fewer
/// makes the front end drop the connection, so a mid-stream abort is a
/// torn response the client detects by byte count, never a silently
/// short "success".
/// The boxed pull source behind a [`StreamingBody`].
type BodySource = Box<dyn FnMut() -> Option<Vec<u8>> + Send>;

/// A transfer pacer: consulted before each block pull; `Some(wait)`
/// asks the front end to postpone the pull by roughly that long
/// (bytes/sec budgets). The blocking front end sleeps on its worker
/// thread; the reactor re-arms the connection on its timer wheel and
/// never blocks the event loop. Pacing shapes *when* bytes move, never
/// *which* bytes — a paced stream is byte-identical to an unpaced one.
type Pacer = Arc<dyn Fn() -> Option<Duration> + Send + Sync>;

#[derive(Clone)]
pub struct StreamingBody {
    pub content_length: u64,
    source: Arc<Mutex<BodySource>>,
    pacer: Option<Pacer>,
}

impl StreamingBody {
    pub fn new(
        content_length: u64,
        source: impl FnMut() -> Option<Vec<u8>> + Send + 'static,
    ) -> Self {
        Self { content_length, source: Arc::new(Mutex::new(Box::new(source))), pacer: None }
    }

    /// Attach a transfer pacer (per-tenant snapshot bytes/sec budgets).
    pub fn with_pacer(
        mut self,
        pacer: impl Fn() -> Option<Duration> + Send + Sync + 'static,
    ) -> Self {
        self.pacer = Some(Arc::new(pacer));
        self
    }

    /// How long the front end should wait before the next pull (`None`
    /// = pull now). Never blocks.
    pub fn defer_for(&self) -> Option<Duration> {
        self.pacer.as_ref().and_then(|p| p())
    }

    /// Pull the next block (`None` = exhausted). Blocks are written to
    /// the socket verbatim, in pull order.
    pub fn next_block(&self) -> Option<Vec<u8>> {
        (self.source.lock().expect("stream source poisoned"))()
    }
}

impl std::fmt::Debug for StreamingBody {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamingBody").field("content_length", &self.content_length).finish()
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// When set, `body` is ignored and the payload is pulled block by
    /// block from the source (see [`StreamingBody`]).
    pub stream: Option<StreamingBody>,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: body.into().into_bytes(),
            stream: None,
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
            stream: None,
        }
    }

    /// A streaming response (exact-length, pull-based body).
    pub fn streaming(status: u16, content_type: &'static str, stream: StreamingBody) -> Self {
        Self { status, content_type, body: Vec::new(), stream: Some(stream) }
    }

    pub fn not_found() -> Self {
        Self::json(404, r#"{"error":"not found"}"#)
    }

    pub fn bad_request(msg: &str) -> Self {
        Self::json(400, format!(r#"{{"error":{}}}"#, crate::json::Json::str(msg)))
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            501 => "Not Implemented",
            503 => "Service Unavailable",
            _ => "Status",
        }
    }

    /// Declared body length: the streaming source's exact total when
    /// present, otherwise the materialized body's.
    pub fn content_length(&self) -> u64 {
        match &self.stream {
            Some(s) => s.content_length,
            None => self.body.len() as u64,
        }
    }

    /// The status line + headers (shared by both serializers so the head
    /// bytes are identical whether the body is materialized or pulled).
    pub fn head_bytes(&self, keep_alive: bool) -> Vec<u8> {
        format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.content_length(),
            if keep_alive { "keep-alive" } else { "close" },
        )
        .into_bytes()
    }

    /// Serialize the full wire form (materialized bodies only — a
    /// streaming response is written block by block by the front ends).
    /// Both front ends emit exactly these bytes, which is what makes the
    /// blocking/reactor equivalence test a byte-for-byte comparison.
    pub fn to_bytes(&self, keep_alive: bool) -> Vec<u8> {
        debug_assert!(self.stream.is_none(), "streaming responses have no full wire form");
        let mut out = self.head_bytes(keep_alive);
        out.extend_from_slice(&self.body);
        out
    }

    fn write_to(&self, stream: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        match &self.stream {
            None => stream.write_all(&self.to_bytes(keep_alive))?,
            Some(sb) => {
                // Head first, then pull blocks until the source dries up.
                // A source that stops short of its declared length is a
                // torn response: surface an error so the caller closes
                // the connection instead of serving the next request on
                // a desynchronized socket.
                stream.write_all(&self.head_bytes(keep_alive))?;
                let mut written = 0u64;
                loop {
                    // Worker-thread serializer: honoring the pacer by
                    // sleeping is safe here (the reactor instead re-arms
                    // its timer wheel for the same budget).
                    while let Some(wait) = sb.defer_for() {
                        std::thread::sleep(wait.min(Duration::from_millis(100)));
                    }
                    let Some(block) = sb.next_block() else { break };
                    if block.is_empty() {
                        // Contract violation; erroring beats looping on it.
                        return Err(std::io::Error::other("empty stream block"));
                    }
                    written = written.saturating_add(block.len() as u64);
                    if written > sb.content_length {
                        return Err(std::io::Error::other("stream overran content-length"));
                    }
                    stream.write_all(&block)?;
                }
                if written != sb.content_length {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "stream source aborted before content-length",
                    ));
                }
            }
        }
        stream.flush()
    }
}

/// Request parse outcome.
#[derive(Debug)]
pub enum ParseError {
    Io(std::io::Error),
    /// Clean EOF before any bytes (client closed a keep-alive socket).
    Eof,
    Malformed(&'static str),
    TooLarge,
    /// Syntactically valid request using a protocol feature this server
    /// deliberately does not implement (currently: any
    /// `transfer-encoding`, chunked included). Answered `501` + close —
    /// never by misreading the body as if it were `content-length`-framed.
    Unsupported(&'static str),
}

/// The wire response for a parse failure (shared by both front ends so
/// error responses are byte-identical too).
pub(crate) fn parse_error_response(err: &ParseError) -> Option<Response> {
    match err {
        ParseError::TooLarge => Some(Response::json(413, r#"{"error":"payload too large"}"#)),
        ParseError::Malformed(what) => {
            Some(Response::bad_request(&format!("malformed request: {what}")))
        }
        ParseError::Unsupported(what) => {
            Some(Response::json(501, format!(r#"{{"error":"not implemented: {what}"}}"#)))
        }
        ParseError::Io(_) | ParseError::Eof => None,
    }
}

/// Reject any `transfer-encoding` (chunked included) once the header
/// section is complete: this server frames bodies by `content-length`
/// only, and silently misreading a chunked body as length-framed would
/// desynchronize the connection. Both parsers call this at the same
/// point — after the blank-line terminator, before the content-length
/// check — so the `501` bytes on the wire are identical front end to
/// front end.
fn reject_transfer_encoding(headers: &BTreeMap<String, String>) -> Result<(), ParseError> {
    if headers.contains_key("transfer-encoding") {
        return Err(ParseError::Unsupported("transfer-encoding"));
    }
    Ok(())
}

/// Parse one request from a buffered stream (blocking front end + tests).
pub fn parse_request(reader: &mut BufReader<impl Read>) -> Result<Request, ParseError> {
    let mut line = String::new();
    let n = reader.read_line(&mut line).map_err(ParseError::Io)?;
    if n == 0 {
        return Err(ParseError::Eof);
    }
    let (method, path, query) = parse_request_line(&line)?;

    let mut headers = BTreeMap::new();
    let mut header_bytes = 0usize;
    loop {
        let mut hline = String::new();
        let n = reader.read_line(&mut hline).map_err(ParseError::Io)?;
        if n == 0 {
            return Err(ParseError::Malformed("eof in headers"));
        }
        header_bytes += n;
        if header_bytes > MAX_HEADER {
            return Err(ParseError::TooLarge);
        }
        let t = hline.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    reject_transfer_encoding(&headers)?;
    let len = content_length(&headers)?;
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).map_err(ParseError::Io)?;

    Ok(Request { method, path, query, headers, body })
}

/// Parse `METHOD TARGET VERSION` (the shared request-line grammar).
fn parse_request_line(line: &str) -> Result<(String, String, Option<String>), ParseError> {
    let mut parts = line.trim_end().split(' ');
    let method = parts.next().filter(|s| !s.is_empty()).ok_or(ParseError::Malformed("method"))?;
    let target = parts.next().ok_or(ParseError::Malformed("target"))?;
    let version = parts.next().ok_or(ParseError::Malformed("version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(ParseError::Malformed("http version"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    Ok((method.to_string(), path, query))
}

/// Validated `content-length` (0 when absent; `TooLarge` over [`MAX_BODY`]).
fn content_length(headers: &BTreeMap<String, String>) -> Result<usize, ParseError> {
    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse().map_err(|_| ParseError::Malformed("content-length")))
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY {
        return Err(ParseError::TooLarge);
    }
    Ok(len)
}

/// Which half of a request an in-flight parse is waiting on (drives the
/// reactor's `ReadingHeaders`/`ReadingBody` connection states).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParsePhase {
    Headers,
    Body,
}

enum ParserState {
    /// Accumulating request line + headers (terminator not yet seen).
    Headers,
    /// Head parsed; waiting for `need` body bytes.
    Body { req: Request, need: usize },
}

/// Incremental, resumable HTTP/1.1 request parser for the nonblocking
/// reactor: feed raw bytes as they arrive off the socket; a complete
/// [`Request`] pops out once the header terminator and the declared body
/// have been buffered. Grammar and limits match [`parse_request`] exactly
/// (same `Malformed` labels, same `MAX_HEADER`/`MAX_BODY` boundaries, the
/// request line validated eagerly at its newline, truncated requests
/// classified via [`Self::eof_error`]), so both front ends reject the
/// same inputs with the same responses. One deliberate divergence: the
/// blocking parser reads the request line unbounded, while this parser
/// caps a newline-less request line at `MAX_HEADER` (413) so a hostile
/// client cannot grow the buffer without limit.
pub struct RequestParser {
    buf: Vec<u8>,
    state: ParserState,
    /// Resume point for the header-terminator scan (keeps feeding
    /// one-byte chunks O(total) instead of O(total²)).
    scan_pos: usize,
    /// Start of the header line currently being scanned.
    line_start: usize,
    /// Index just past the request line's newline (0 = not seen yet);
    /// lets the size-cap check run without rescanning the buffer.
    req_line_end: usize,
}

impl Default for RequestParser {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestParser {
    pub fn new() -> Self {
        Self {
            buf: Vec::new(),
            state: ParserState::Headers,
            scan_pos: 0,
            line_start: 0,
            req_line_end: 0,
        }
    }

    pub fn phase(&self) -> ParsePhase {
        match self.state {
            ParserState::Headers => ParsePhase::Headers,
            ParserState::Body { .. } => ParsePhase::Body,
        }
    }

    /// Bytes buffered beyond the last completed request. Nonzero right
    /// after [`Self::feed`] returns a request means the client pipelined.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// True while a request is partially buffered (half-read connection).
    pub fn mid_request(&self) -> bool {
        !self.buf.is_empty() || matches!(self.state, ParserState::Body { .. })
    }

    /// Append bytes and try to complete one request. `Ok(None)` means
    /// more input is needed; errors are terminal for the connection.
    pub fn feed(&mut self, data: &[u8]) -> Result<Option<Request>, ParseError> {
        self.buf.extend_from_slice(data);
        loop {
            match &mut self.state {
                ParserState::Headers => {
                    let had_req_line = self.req_line_end > 0;
                    let Some(end) = self.find_header_end() else {
                        if !had_req_line && self.req_line_end > 0 {
                            // The request line just completed: validate it
                            // eagerly, matching the moment the blocking
                            // parser reports request-line errors.
                            self.validate_request_line()?;
                        }
                        self.check_header_limits()?;
                        return Ok(None);
                    };
                    let (req, need) = parse_head(&self.buf[..end])?;
                    self.buf.drain(..end);
                    self.scan_pos = 0;
                    self.line_start = 0;
                    self.req_line_end = 0;
                    self.state = ParserState::Body { req, need };
                }
                ParserState::Body { need, .. } => {
                    let need = *need;
                    if self.buf.len() < need {
                        return Ok(None);
                    }
                    let ParserState::Body { mut req, .. } =
                        std::mem::replace(&mut self.state, ParserState::Headers)
                    else {
                        unreachable!()
                    };
                    req.body = self.buf.drain(..need).collect();
                    return Ok(Some(req));
                }
            }
        }
    }

    /// Find the end of the header section: the first line that is empty
    /// after stripping a trailing '\r' terminates the headers (exactly the
    /// blank-line rule the blocking parser's `read_line` loop applies).
    fn find_header_end(&mut self) -> Option<usize> {
        while self.scan_pos < self.buf.len() {
            if self.buf[self.scan_pos] == b'\n' {
                let line = &self.buf[self.line_start..self.scan_pos];
                if line.is_empty() || line == b"\r" {
                    let end = self.scan_pos + 1;
                    self.scan_pos = end;
                    return Some(end);
                }
                if self.line_start == 0 {
                    self.req_line_end = self.scan_pos + 1;
                }
                self.line_start = self.scan_pos + 1;
            }
            self.scan_pos += 1;
        }
        None
    }

    /// Parse-check the (complete) request line without consuming it.
    fn validate_request_line(&self) -> Result<(), ParseError> {
        let line = std::str::from_utf8(&self.buf[..self.req_line_end]).map_err(|_| {
            ParseError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "non-utf8 request line",
            ))
        })?;
        parse_request_line(line).map(|_| ())
    }

    /// Resolve end-of-stream exactly as the blocking parser would:
    /// `Ok(Some(req))` when the blocking path would still serve a request
    /// (its `read_line` treats a truncated `"\r"` tail as the blank
    /// terminator, completing a zero-body request), `Ok(None)` when it
    /// would close without a response (clean EOF, EOF mid-body, invalid
    /// UTF-8), `Err` when it would answer an error (EOF mid-headers,
    /// request-line or length errors surfaced at the truncation point).
    pub fn finish_eof(&mut self) -> Result<Option<Request>, ParseError> {
        if matches!(self.state, ParserState::Body { .. }) || self.buf.is_empty() {
            return Ok(None); // read_exact-Io / clean-EOF: no response
        }
        if self.req_line_end == 0 {
            // EOF inside the request line: the partial line either fails
            // to parse, or parses and then hits EOF in the header loop.
            let line = std::str::from_utf8(&self.buf).map_err(|_| {
                ParseError::Io(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "non-utf8 request line",
                ))
            })?;
            parse_request_line(line)?;
            return Err(ParseError::Malformed("eof in headers"));
        }
        let tail = &self.buf[self.line_start..];
        if tail != b"\r" {
            // A truncated header line (or nothing) follows the last
            // newline: the blocking header loop reports EOF.
            return Err(ParseError::Malformed("eof in headers"));
        }
        // `read_line` returns the bare "\r" tail, which trims to an empty
        // line: the header section completes. A declared body can never
        // arrive after EOF (read_exact Io → silent close); a zero-body
        // request is served.
        let (req, need) = parse_head(&self.buf[..self.line_start])?;
        if need > 0 {
            return Ok(None);
        }
        self.buf.clear();
        self.scan_pos = 0;
        self.line_start = 0;
        self.req_line_end = 0;
        Ok(Some(req))
    }

    /// Enforce `MAX_HEADER` while the terminator is still outstanding:
    /// the section can only grow, so exceeding the cap early is final.
    /// O(1) per feed — the request-line boundary is tracked by the scan.
    fn check_header_limits(&self) -> Result<(), ParseError> {
        let over = if self.req_line_end > 0 {
            // Bytes after the request line (the header section so far).
            self.buf.len() - self.req_line_end > MAX_HEADER
        } else {
            // Runaway request line with no newline at all.
            self.buf.len() > MAX_HEADER
        };
        if over {
            Err(ParseError::TooLarge)
        } else {
            Ok(())
        }
    }
}

/// Parse a complete header block (request line + headers + blank line)
/// into a body-less request plus the declared body length.
fn parse_head(head: &[u8]) -> Result<(Request, usize), ParseError> {
    // Non-UTF-8 header bytes surface as an I/O-class error (connection
    // dropped with no response) — the same outcome the blocking parser's
    // `read_line` InvalidData error produces, keeping the front ends
    // byte-equivalent on this input class too.
    let text = std::str::from_utf8(head).map_err(|_| {
        ParseError::Io(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "non-utf8 header bytes",
        ))
    })?;
    let mut lines = text.split('\n');
    let request_line = lines.next().unwrap_or("");
    // The header section (everything after the request line, including the
    // blank terminator) carries the same cap as the blocking parser.
    let section = head.len() - (request_line.len() + 1).min(head.len());
    if section > MAX_HEADER {
        return Err(ParseError::TooLarge);
    }
    let (method, path, query) = parse_request_line(request_line)?;
    let mut headers = BTreeMap::new();
    for line in lines {
        let t = line.trim_end();
        if t.is_empty() {
            continue; // the blank terminator
        }
        if let Some((k, v)) = t.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    reject_transfer_encoding(&headers)?;
    let need = content_length(&headers)?;
    let req = Request { method, path, query, headers, body: Vec::new() };
    Ok((req, need))
}

/// Boxed handler type.
pub type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync>;

/// Front-end observability counters (gauges live outside the kernel and
/// never enter the deterministic state, like [`crate::node::Metrics`]).
#[derive(Debug, Default)]
pub struct ServerMetrics {
    /// Currently open connections (gauge).
    pub connections_open: AtomicU64,
    /// Total accepted connections.
    pub connections_accepted: AtomicU64,
    /// Connections evicted by the timer wheel (slow loris, idle).
    pub connections_timed_out: AtomicU64,
    /// Connections turned away at the `max_connections` cap.
    pub connections_rejected: AtomicU64,
    /// Responses fully written.
    pub requests_served: AtomicU64,
    /// Pipelined requests rejected (the reactor serves strictly one
    /// request per connection at a time).
    pub pipelined_rejected: AtomicU64,
    /// Snapshot-stream payload bytes handed to the wire (writer side).
    pub stream_bytes_streamed: AtomicU64,
    /// Snapshot-stream chunks whose CRC verified on ingest (reader side).
    pub stream_chunks_verified: AtomicU64,
    /// Snapshot streams currently in flight (gauge: outbound streams +
    /// open restore sessions).
    pub streams_in_flight: AtomicU64,
    /// Requests rejected at admission with 1600 `rate_limited`.
    pub requests_rate_limited: AtomicU64,
    /// Requests rejected at admission with 1601 `quota_exceeded`.
    pub requests_quota_rejected: AtomicU64,
    /// Idle collections evicted (WALs closed, worker state dropped).
    pub collections_evicted: AtomicU64,
    /// Evicted collections rehydrated from disk on next touch.
    pub collections_rehydrated: AtomicU64,
}

impl ServerMetrics {
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }
}

/// Front-end tuning knobs (defaults match the historical behavior).
#[derive(Clone)]
pub struct ServerConfig {
    /// Dispatch pool size (handler threads).
    pub workers: usize,
    /// Bound on concurrently open connections; accepts beyond it are
    /// answered 503 and closed.
    pub max_connections: usize,
    /// Budget for reading one full request, and for keep-alive idle time.
    pub read_timeout: Duration,
    /// Budget for dispatching + writing one response.
    pub write_timeout: Duration,
    /// Keep-alive requests served per connection before `connection:
    /// close` (matches the blocking path's historical 1000-request loop).
    pub max_requests_per_conn: u32,
    /// Shared metrics sink (pass a clone to observe the server).
    pub metrics: Arc<ServerMetrics>,
    /// Admission hook, run after a request parses and before it reaches
    /// the handler — on the reactor, before the job is queued to the
    /// dispatch pool, so a rejected request never occupies a worker.
    /// `Some(response)` rejects with that response (same keep-alive
    /// semantics as a served request); `None` admits. Decisions must
    /// come from front-end-local state only (monotonic clocks, in-flight
    /// counters), never from the replayable state machine.
    pub admission: Option<AdmissionHook>,
}

/// See [`ServerConfig::admission`].
pub type AdmissionHook = Arc<dyn Fn(&Request) -> Option<Response> + Send + Sync>;

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            max_connections: 4096,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(30),
            max_requests_per_conn: 1000,
            metrics: Arc::new(ServerMetrics::default()),
            admission: None,
        }
    }
}

enum Backend {
    #[cfg(target_os = "linux")]
    Reactor(reactor::ReactorHandle),
    Blocking(BlockingHandle),
}

/// A running HTTP server (epoll reactor on Linux, blocking pool
/// elsewhere; [`Server::start_blocking`] forces the legacy path).
pub struct Server {
    addr: SocketAddr,
    metrics: Arc<ServerMetrics>,
    backend: Option<Backend>,
}

impl Server {
    /// Bind and serve on `addr` (use port 0 for an ephemeral port) with
    /// `n_workers` handler threads.
    pub fn start(addr: &str, n_workers: usize, handler: Handler) -> std::io::Result<Server> {
        Self::start_with(addr, ServerConfig { workers: n_workers, ..Default::default() }, handler)
    }

    /// Bind and serve with explicit front-end configuration.
    pub fn start_with(
        addr: &str,
        config: ServerConfig,
        handler: Handler,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let metrics = Arc::clone(&config.metrics);
        #[cfg(target_os = "linux")]
        let backend = Backend::Reactor(reactor::start(listener, config, handler)?);
        #[cfg(not(target_os = "linux"))]
        let backend = Backend::Blocking(start_blocking_impl(listener, config, handler)?);
        Ok(Server { addr: local, metrics, backend: Some(backend) })
    }

    /// The original blocking thread-per-connection front end, kept as the
    /// byte-equivalence reference for the reactor (see
    /// `tests/http_equivalence.rs`).
    pub fn start_blocking(
        addr: &str,
        n_workers: usize,
        handler: Handler,
    ) -> std::io::Result<Server> {
        let config = ServerConfig { workers: n_workers, ..Default::default() };
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let metrics = Arc::clone(&config.metrics);
        let backend = Backend::Blocking(start_blocking_impl(listener, config, handler)?);
        Ok(Server { addr: local, metrics, backend: Some(backend) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Which front end is serving ("epoll" or "blocking").
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            #[cfg(target_os = "linux")]
            Some(Backend::Reactor(_)) => "epoll",
            Some(Backend::Blocking(_)) => "blocking",
            None => "stopped",
        }
    }

    /// The server's metrics sink (same instance as `config.metrics`).
    pub fn metrics(&self) -> &Arc<ServerMetrics> {
        &self.metrics
    }

    /// Signal shutdown and join all threads.
    pub fn stop(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        match self.backend.take() {
            #[cfg(target_os = "linux")]
            Some(Backend::Reactor(handle)) => handle.stop(),
            Some(Backend::Blocking(handle)) => handle.stop(),
            None => {}
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Handles for the blocking front end's threads.
struct BlockingHandle {
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl BlockingHandle {
    fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // The accept loop polls a nonblocking listener, so it observes the
        // flag within one poll interval — no self-connection needed.
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn start_blocking_impl(
    listener: TcpListener,
    config: ServerConfig,
    handler: Handler,
) -> std::io::Result<BlockingHandle> {
    listener.set_nonblocking(true)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<TcpStream>();
    let rx = Arc::new(Mutex::new(rx));

    let mut workers = Vec::with_capacity(config.workers);
    for i in 0..config.workers {
        let rx = Arc::clone(&rx);
        let handler = Arc::clone(&handler);
        let shutdown = Arc::clone(&shutdown);
        let config = config.clone();
        workers.push(
            std::thread::Builder::new()
                .name(format!("valori-http-{i}"))
                .spawn(move || worker_loop(rx, handler, shutdown, config))
                .expect("spawn worker"),
        );
    }

    let accept_shutdown = Arc::clone(&shutdown);
    let metrics = Arc::clone(&config.metrics);
    let max_connections = config.max_connections;
    let read_timeout = config.read_timeout;
    let accept_thread = std::thread::Builder::new()
        .name("valori-http-accept".into())
        .spawn(move || {
            loop {
                if accept_shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((mut s, _)) => {
                        // Best-effort connection cap (the gauge lags
                        // queued-but-unserved sockets slightly; the
                        // reactor enforces the cap exactly). Rejected
                        // sockets count `connections_rejected` only —
                        // `connections_accepted` counts admissions.
                        if ServerMetrics::get(&metrics.connections_open)
                            >= max_connections as u64
                        {
                            ServerMetrics::add(&metrics.connections_rejected, 1);
                            let resp =
                                Response::json(503, r#"{"error":"too many connections"}"#);
                            let _ = s.write_all(&resp.to_bytes(false));
                            continue;
                        }
                        ServerMetrics::add(&metrics.connections_accepted, 1);
                        let _ = s.set_nonblocking(false);
                        let _ = s.set_read_timeout(Some(read_timeout));
                        let _ = tx.send(s);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(_) => continue,
                }
            }
            // dropping tx ends the workers
        })
        .expect("spawn accept");

    Ok(BlockingHandle { shutdown, accept_thread: Some(accept_thread), workers })
}

fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<TcpStream>>>,
    handler: Handler,
    shutdown: Arc<AtomicBool>,
    config: ServerConfig,
) {
    loop {
        let stream = {
            let guard = rx.lock().expect("rx poisoned");
            guard.recv()
        };
        let Ok(stream) = stream else { return }; // channel closed
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        ServerMetrics::add(&config.metrics.connections_open, 1);
        let _ = handle_connection(stream, &handler, &config);
        config.metrics.connections_open.fetch_sub(1, Ordering::Relaxed);
    }
}

fn handle_connection(
    stream: TcpStream,
    handler: &Handler,
    config: &ServerConfig,
) -> std::io::Result<()> {
    let metrics = &config.metrics;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // keep-alive loop: bounded requests per connection
    for _ in 0..config.max_requests_per_conn {
        match parse_request(&mut reader) {
            Ok(req) => {
                let keep_alive = req.wants_keep_alive();
                // Admission runs between parse and handler — the same
                // point the reactor checks before queueing to its
                // dispatch pool, so both front ends put identical bytes
                // on the wire for a rejected request.
                let resp = match config.admission.as_ref().and_then(|a| a(&req)) {
                    Some(rejection) => rejection,
                    None => handler(req),
                };
                resp.write_to(&mut writer, keep_alive)?;
                ServerMetrics::add(&metrics.requests_served, 1);
                if !keep_alive {
                    return Ok(());
                }
            }
            Err(ParseError::Eof) => return Ok(()),
            Err(err) => {
                if let Some(resp) = parse_error_response(&err) {
                    let _ = resp.write_to(&mut writer, false);
                }
                return Ok(()); // timeout/reset/malformed: drop the connection
            }
        }
    }
    Ok(())
}

/// Tiny blocking HTTP client for tests, examples and replication.
pub mod client {
    use super::*;

    /// Read a response's status line + headers: returns (status,
    /// content-length, server asked to close). The body is left on the
    /// stream for the caller to drain.
    fn read_head(reader: &mut BufReader<TcpStream>) -> std::io::Result<(u16, usize, bool)> {
        let mut status_line = String::new();
        if reader.read_line(&mut status_line)? == 0 {
            // Clean EOF before a single response byte: the server closed
            // the (stale keep-alive) socket without processing anything.
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed before response",
            ));
        }
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| std::io::Error::other("bad status line"))?;
        let mut len = 0usize;
        let mut close = false;
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            let t = line.trim_end();
            if t.is_empty() {
                break;
            }
            if let Some((k, v)) = t.split_once(':') {
                let k = k.trim();
                if k.eq_ignore_ascii_case("content-length") {
                    len = v.trim().parse().unwrap_or(0);
                } else if k.eq_ignore_ascii_case("connection") {
                    close = v.trim().eq_ignore_ascii_case("close");
                }
            }
        }
        Ok((status, len, close))
    }

    /// Largest single allocation the client makes from a peer-declared
    /// `content-length` — bodies grow chunk by chunk past this, so a
    /// corrupt or malicious length fails with `UnexpectedEof` after
    /// reading what actually arrived instead of pre-allocating the full
    /// declared size up front (the same discipline `SnapshotReader`
    /// applies to declared frame lengths).
    const MAX_PREALLOC: usize = 64 << 10;

    /// Read an exact-length body in bounded chunks (see [`MAX_PREALLOC`]).
    pub(super) fn read_body_capped(
        reader: &mut impl Read,
        len: usize,
    ) -> std::io::Result<Vec<u8>> {
        let mut body = Vec::with_capacity(len.min(MAX_PREALLOC));
        let mut chunk = vec![0u8; len.clamp(1, MAX_PREALLOC)];
        let mut remaining = len;
        while remaining > 0 {
            let n = chunk.len().min(remaining);
            reader.read_exact(&mut chunk[..n])?;
            body.extend_from_slice(&chunk[..n]);
            remaining -= n;
        }
        Ok(body)
    }

    /// Read one response off a buffered stream: returns (status, body,
    /// server asked to close).
    fn read_response(reader: &mut BufReader<TcpStream>) -> std::io::Result<(u16, Vec<u8>, bool)> {
        let (status, len, close) = read_head(reader)?;
        let body = read_body_capped(reader, len)?;
        Ok((status, body, close))
    }

    /// One-shot request (`connection: close`); returns (status, body).
    pub fn request(
        addr: &SocketAddr,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
            body.len()
        )?;
        stream.write_all(body)?;
        stream.flush()?;
        let mut reader = BufReader::new(stream);
        let (status, body, _) = read_response(&mut reader)?;
        Ok((status, body))
    }

    /// A persistent keep-alive connection: serial requests reuse one
    /// socket, so callers stop paying per-request connect cost (the
    /// replication sync drivers and `valori bench`'s HTTP row use this).
    /// Transparently reconnects when the server retires the connection
    /// (keep-alive request cap, idle timeout).
    pub struct Connection {
        addr: SocketAddr,
        stream: TcpStream,
        reader: BufReader<TcpStream>,
        /// Server sent `connection: close` (or I/O failed): reconnect
        /// before the next request.
        dead: bool,
        /// No request has succeeded on this socket yet, so a failure is a
        /// real error rather than a stale keep-alive race.
        fresh: bool,
    }

    impl Connection {
        pub fn connect(addr: &SocketAddr) -> std::io::Result<Self> {
            let stream = TcpStream::connect(addr)?;
            stream.set_read_timeout(Some(Duration::from_secs(30)))?;
            let _ = stream.set_nodelay(true);
            let reader = BufReader::new(stream.try_clone()?);
            Ok(Self { addr: *addr, stream, reader, dead: false, fresh: true })
        }

        pub fn addr(&self) -> SocketAddr {
            self.addr
        }

        fn send(&mut self, method: &str, path: &str, body: &[u8]) -> std::io::Result<()> {
            let addr = self.addr;
            write!(
                self.stream,
                "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\nconnection: keep-alive\r\n\r\n",
                body.len()
            )?;
            self.stream.write_all(body)?;
            self.stream.flush()
        }

        /// Issue one request on the persistent socket; returns (status,
        /// body). Retries once on a fresh socket if a *reused* connection
        /// fails before any response byte arrived (the server may have
        /// legitimately retired the idle socket between requests). A
        /// failure after the response started, or on a fresh socket, is
        /// surfaced rather than re-sent. Residual at-least-once window:
        /// if the server executes the handler but is evicted before
        /// writing a single response byte (dispatch exceeding
        /// `write_timeout`, or shutdown mid-dispatch), the retry re-sends
        /// a request that already ran. For this crate's mutation
        /// endpoints that is loud, not silent — re-applied canonical
        /// commands are rejected deterministically (duplicate id), so a
        /// sync fails with an error instead of forking state.
        pub fn request(
            &mut self,
            method: &str,
            path: &str,
            body: &[u8],
        ) -> std::io::Result<(u16, Vec<u8>)> {
            for _attempt in 0..2 {
                if self.dead {
                    *self = Self::connect(&self.addr)?;
                }
                let exchange = self
                    .send(method, path, body)
                    .and_then(|()| read_response(&mut self.reader));
                match exchange {
                    Ok((status, body, close)) => {
                        self.fresh = false;
                        if close {
                            self.dead = true;
                        }
                        return Ok((status, body));
                    }
                    Err(e) => {
                        // Retry only the stale-reused-socket signatures:
                        // the connection died with no response byte read
                        // (EOF/reset) or the request could not be sent at
                        // all. Anything else (timeout, torn response) may
                        // mean the server acted on the request.
                        let retryable = !self.fresh
                            && matches!(
                                e.kind(),
                                std::io::ErrorKind::UnexpectedEof
                                    | std::io::ErrorKind::ConnectionReset
                                    | std::io::ErrorKind::ConnectionAborted
                                    | std::io::ErrorKind::BrokenPipe
                            );
                        self.dead = true;
                        if !retryable {
                            return Err(e);
                        }
                    }
                }
            }
            Err(std::io::Error::other("keep-alive retry failed"))
        }

        /// Issue one request and stream a 200 response's body into
        /// `sink` in ≤ 64 KiB slices instead of materializing it —
        /// bounded memory for snapshot-sized payloads. A non-200
        /// response's (small, JSON) body is returned instead, with the
        /// sink untouched. No transparent retry: once bytes reach the
        /// sink the transfer is stateful, so failures surface to the
        /// caller, which resumes from its own offset.
        pub fn request_streaming(
            &mut self,
            method: &str,
            path: &str,
            body: &[u8],
            sink: &mut dyn FnMut(&[u8]) -> std::io::Result<()>,
        ) -> std::io::Result<(u16, u64, Vec<u8>)> {
            if self.dead {
                *self = Self::connect(&self.addr)?;
            }
            match self.stream_exchange(method, path, body, sink) {
                Ok((status, len, err_body, close)) => {
                    if close {
                        self.dead = true;
                    }
                    Ok((status, len, err_body))
                }
                Err(e) => {
                    // The socket may hold a half-read body: never reuse it.
                    self.dead = true;
                    Err(e)
                }
            }
        }

        /// The fallible half of [`Self::request_streaming`]: returns
        /// (status, content-length, non-200 body, server-close flag).
        fn stream_exchange(
            &mut self,
            method: &str,
            path: &str,
            body: &[u8],
            sink: &mut dyn FnMut(&[u8]) -> std::io::Result<()>,
        ) -> std::io::Result<(u16, u64, Vec<u8>, bool)> {
            self.send(method, path, body)?;
            let (status, len, close) = read_head(&mut self.reader)?;
            self.fresh = false;
            if status != 200 {
                let err_body = read_body_capped(&mut self.reader, len)?;
                return Ok((status, len as u64, err_body, close));
            }
            let mut remaining = len;
            let mut buf = vec![0u8; (64usize << 10).min(len.max(1))];
            while remaining > 0 {
                let n = buf.len().min(remaining);
                self.reader.read_exact(&mut buf[..n])?;
                sink(&buf[..n])?;
                remaining -= n;
            }
            Ok((status, len as u64, Vec::new(), close))
        }

        /// POST JSON; returns (status, parsed body if JSON).
        pub fn post_json(
            &mut self,
            path: &str,
            body: &crate::json::Json,
        ) -> std::io::Result<(u16, crate::json::Json)> {
            let (status, bytes) = self.request("POST", path, body.to_string().as_bytes())?;
            let text = String::from_utf8_lossy(&bytes);
            let json = crate::json::parse(&text).unwrap_or(crate::json::Json::Null);
            Ok((status, json))
        }

        /// GET; returns (status, parsed body if JSON).
        pub fn get_json(&mut self, path: &str) -> std::io::Result<(u16, crate::json::Json)> {
            let (status, bytes) = self.request("GET", path, &[])?;
            let text = String::from_utf8_lossy(&bytes);
            let json = crate::json::parse(&text).unwrap_or(crate::json::Json::Null);
            Ok((status, json))
        }
    }

    /// POST JSON; returns (status, parsed body if JSON).
    pub fn post_json(
        addr: &SocketAddr,
        path: &str,
        body: &crate::json::Json,
    ) -> std::io::Result<(u16, crate::json::Json)> {
        let (status, bytes) = request(addr, "POST", path, body.to_string().as_bytes())?;
        let text = String::from_utf8_lossy(&bytes);
        let json = crate::json::parse(&text).unwrap_or(crate::json::Json::Null);
        Ok((status, json))
    }

    /// GET; returns (status, parsed body if JSON).
    pub fn get_json(addr: &SocketAddr, path: &str) -> std::io::Result<(u16, crate::json::Json)> {
        let (status, bytes) = request(addr, "GET", path, &[])?;
        let text = String::from_utf8_lossy(&bytes);
        let json = crate::json::parse(&text).unwrap_or(crate::json::Json::Null);
        Ok((status, json))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_handler() -> Handler {
        Arc::new(|req: Request| {
            if req.path == "/echo" {
                Response::text(200, String::from_utf8_lossy(&req.body).to_string())
            } else if req.path == "/method" {
                Response::text(200, req.method.clone())
            } else if req.path == "/query" {
                Response::text(200, req.query.unwrap_or_default())
            } else {
                Response::not_found()
            }
        })
    }

    fn echo_server() -> Server {
        Server::start("127.0.0.1:0", 2, echo_handler()).unwrap()
    }

    #[test]
    fn serves_and_echoes() {
        let server = echo_server();
        let (status, body) = client::request(&server.addr(), "POST", "/echo", b"hello").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"hello");
        server.stop();
    }

    #[test]
    fn not_found_and_method() {
        let server = echo_server();
        let (status, _) = client::request(&server.addr(), "GET", "/nope", b"").unwrap();
        assert_eq!(status, 404);
        let (_, body) = client::request(&server.addr(), "PUT", "/method", b"").unwrap();
        assert_eq!(body, b"PUT");
        server.stop();
    }

    #[test]
    fn query_string_split() {
        let server = echo_server();
        let (_, body) = client::request(&server.addr(), "GET", "/query?k=10&x=1", b"").unwrap();
        assert_eq!(body, b"k=10&x=1");
        server.stop();
    }

    #[test]
    fn concurrent_requests() {
        let server = echo_server();
        let addr = server.addr();
        let threads: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    let msg = format!("msg-{i}");
                    let (s, b) = client::request(&addr, "POST", "/echo", msg.as_bytes()).unwrap();
                    assert_eq!(s, 200);
                    assert_eq!(b, msg.as_bytes());
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        server.stop();
    }

    #[test]
    fn oversized_body_rejected() {
        let server = echo_server();
        let addr = server.addr();
        let mut stream = TcpStream::connect(addr).unwrap();
        write!(stream, "POST /echo HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY + 1).unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("413"), "{line}");
        server.stop();
    }

    #[test]
    fn malformed_request_rejected() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"NONSENSE\r\n\r\n").unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("400"), "{line}");
        server.stop();
    }

    #[test]
    fn keep_alive_multiple_requests_one_connection() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        for i in 0..3 {
            let msg = format!("ka-{i}");
            write!(stream, "POST /echo HTTP/1.1\r\ncontent-length: {}\r\n\r\n", msg.len()).unwrap();
            stream.write_all(msg.as_bytes()).unwrap();
            stream.flush().unwrap();
            // read one response off the same socket
            let mut reader = BufReader::new(&stream);
            let mut status = String::new();
            reader.read_line(&mut status).unwrap();
            assert!(status.contains("200"));
            let mut len = 0;
            loop {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let t = line.trim_end();
                if t.is_empty() {
                    break;
                }
                if let Some((k, v)) = t.split_once(':') {
                    if k.eq_ignore_ascii_case("content-length") {
                        len = v.trim().parse().unwrap();
                    }
                }
            }
            let mut body = vec![0u8; len];
            reader.read_exact(&mut body).unwrap();
            assert_eq!(body, msg.as_bytes());
        }
        server.stop();
    }

    #[test]
    fn keep_alive_client_connection_reuses_socket() {
        let server = echo_server();
        let mut conn = client::Connection::connect(&server.addr()).unwrap();
        for i in 0..5 {
            let msg = format!("conn-{i}");
            let (status, body) = conn.request("POST", "/echo", msg.as_bytes()).unwrap();
            assert_eq!(status, 200);
            assert_eq!(body, msg.as_bytes());
        }
        let metrics = Arc::clone(server.metrics());
        assert_eq!(ServerMetrics::get(&metrics.connections_accepted), 1);
        assert_eq!(ServerMetrics::get(&metrics.requests_served), 5);
        server.stop();
    }

    #[test]
    fn streaming_response_bytes_match_on_both_front_ends() {
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let p = payload.clone();
        let handler: Handler = Arc::new(move |req: Request| {
            if req.path == "/stream" {
                let data = p.clone();
                let mut offset = 0usize;
                Response::streaming(
                    200,
                    "application/octet-stream",
                    StreamingBody::new(data.len() as u64, move || {
                        if offset >= data.len() {
                            return None;
                        }
                        let end = (offset + 8192).min(data.len());
                        let block = data[offset..end].to_vec();
                        offset = end;
                        Some(block)
                    }),
                )
            } else {
                Response::not_found()
            }
        });
        // Reactor (default) front end: one-shot, then keep-alive reuse
        // across two streamed responses on one socket.
        let server = Server::start("127.0.0.1:0", 2, Arc::clone(&handler)).unwrap();
        let (status, body) = client::request(&server.addr(), "GET", "/stream", b"").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, payload);
        let mut conn = client::Connection::connect(&server.addr()).unwrap();
        let (s1, b1) = conn.request("GET", "/stream", b"").unwrap();
        let (s2, b2) = conn.request("GET", "/stream", b"").unwrap();
        assert_eq!((s1, s2), (200, 200));
        assert_eq!(b1, payload);
        assert_eq!(b2, payload);
        // Streaming client read (bounded memory path).
        let mut got = Vec::new();
        let (st, len, err_body) = conn
            .request_streaming("GET", "/stream", b"", &mut |b| {
                got.extend_from_slice(b);
                Ok(())
            })
            .unwrap();
        assert_eq!(st, 200);
        assert_eq!(len, payload.len() as u64);
        assert!(err_body.is_empty());
        assert_eq!(got, payload);
        // Non-200 path leaves the sink untouched and returns the body.
        let mut untouched = true;
        let (st, _, err_body) = conn
            .request_streaming("GET", "/nope", b"", &mut |_| {
                untouched = false;
                Ok(())
            })
            .unwrap();
        assert_eq!(st, 404);
        assert!(untouched);
        assert!(!err_body.is_empty());
        server.stop();
        // Blocking front end serves the identical bytes.
        let blocking = Server::start_blocking("127.0.0.1:0", 2, handler).unwrap();
        let (bs, bb) = client::request(&blocking.addr(), "GET", "/stream", b"").unwrap();
        assert_eq!(bs, 200);
        assert_eq!(bb, payload);
        blocking.stop();
    }

    #[test]
    fn client_body_read_is_allocation_capped() {
        // A Read that serves a few bytes then EOFs, recording the
        // largest single read the client requested.
        struct Short {
            left: usize,
            max_req: usize,
        }
        impl Read for Short {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                self.max_req = self.max_req.max(buf.len());
                let n = buf.len().min(self.left);
                self.left -= n;
                buf[..n].fill(0x5a);
                Ok(n)
            }
        }
        // An absurd declared length (1 GiB) against 100 KiB of actual
        // data: the read fails cleanly instead of pre-allocating 1 GiB,
        // and no single read request exceeds the 64 KiB chunk.
        let mut short = Short { left: 100 << 10, max_req: 0 };
        let err = client::read_body_capped(&mut short, 1 << 30).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
        assert!(short.max_req <= 64 << 10, "chunk too large: {}", short.max_req);
        // Honest lengths still round-trip exactly.
        let mut ok = Short { left: 200_000, max_req: 0 };
        let body = client::read_body_capped(&mut ok, 150_000).unwrap();
        assert_eq!(body.len(), 150_000);
        assert!(body.iter().all(|&b| b == 0x5a));
        let empty = client::read_body_capped(&mut Short { left: 0, max_req: 0 }, 0).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn admission_hook_rejects_before_handler_on_both_front_ends() {
        // The handler panics if a /blocked request ever reaches it.
        let handler: Handler = Arc::new(|req: Request| {
            assert_ne!(req.path, "/blocked", "admission must reject before the handler");
            Response::text(200, "served")
        });
        let admission: AdmissionHook = Arc::new(|req: &Request| {
            (req.path == "/blocked").then(|| Response::json(429, r#"{"throttled":true}"#))
        });
        for blocking in [false, true] {
            let config = ServerConfig { workers: 2, admission: Some(Arc::clone(&admission)), ..Default::default() };
            let metrics = Arc::clone(&config.metrics);
            let server = if blocking {
                let listener = TcpListener::bind("127.0.0.1:0").unwrap();
                let local = listener.local_addr().unwrap();
                let handle = start_blocking_impl(listener, config, Arc::clone(&handler)).unwrap();
                Server { addr: local, metrics: Arc::clone(&metrics), backend: Some(Backend::Blocking(handle)) }
            } else {
                Server::start_with("127.0.0.1:0", config, Arc::clone(&handler)).unwrap()
            };
            // Rejections keep the connection alive, exactly like a
            // served response, and count toward requests_served.
            let mut conn = client::Connection::connect(&server.addr()).unwrap();
            let (status, body) = conn.request("GET", "/blocked", b"").unwrap();
            assert_eq!(status, 429, "blocking={blocking}");
            assert_eq!(body, br#"{"throttled":true}"#);
            let (status, _) = conn.request("GET", "/ok", b"").unwrap();
            assert_eq!(status, 200, "keep-alive must survive a rejection");
            assert_eq!(ServerMetrics::get(&metrics.requests_served), 2);
            server.stop();
        }
    }

    #[test]
    fn incremental_parser_single_byte_feed() {
        let raw = b"POST /echo?x=1 HTTP/1.1\r\nhost: h\r\ncontent-length: 5\r\n\r\nhello";
        let mut parser = RequestParser::new();
        for (i, &b) in raw.iter().enumerate() {
            let got = parser.feed(&[b]).unwrap();
            if i + 1 < raw.len() {
                assert!(got.is_none(), "complete after {} bytes?", i + 1);
            } else {
                let req = got.expect("request completes on final byte");
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/echo");
                assert_eq!(req.query.as_deref(), Some("x=1"));
                assert_eq!(req.headers.get("host").map(String::as_str), Some("h"));
                assert_eq!(req.body, b"hello");
            }
        }
        assert_eq!(parser.buffered(), 0);
        assert!(!parser.mid_request());
    }

    #[test]
    fn incremental_parser_detects_pipelining() {
        let two = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let mut parser = RequestParser::new();
        let req = parser.feed(two).unwrap().expect("first request parses");
        assert_eq!(req.path, "/a");
        assert!(parser.buffered() > 0, "second request must be visible as leftover");
        let req2 = parser.feed(&[]).unwrap().expect("second request parses");
        assert_eq!(req2.path, "/b");
        assert_eq!(parser.buffered(), 0);
    }

    #[test]
    fn incremental_parser_matches_blocking_errors() {
        // malformed request line
        let mut p = RequestParser::new();
        assert!(matches!(p.feed(b"NONSENSE\r\n\r\n"), Err(ParseError::Malformed("target"))));
        // bad http version
        let mut p = RequestParser::new();
        assert!(matches!(
            p.feed(b"GET / SPDY/9\r\n\r\n"),
            Err(ParseError::Malformed("http version"))
        ));
        // oversized declared body
        let mut p = RequestParser::new();
        let raw = format!("POST / HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(matches!(p.feed(raw.as_bytes()), Err(ParseError::TooLarge)));
        // unparsable content-length
        let mut p = RequestParser::new();
        assert!(matches!(
            p.feed(b"POST / HTTP/1.1\r\ncontent-length: nope\r\n\r\n"),
            Err(ParseError::Malformed("content-length"))
        ));
    }

    #[test]
    fn chunked_transfer_encoding_rejected_by_both_parsers() {
        // Any transfer-encoding is 501 territory: the server frames by
        // content-length only and must never misread a chunked body.
        let raw =
            b"POST /echo HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n5\r\nhello\r\n0\r\n\r\n";
        let mut incremental = RequestParser::new();
        assert!(matches!(
            incremental.feed(raw),
            Err(ParseError::Unsupported("transfer-encoding"))
        ));
        let mut blocking = BufReader::new(&raw[..]);
        assert!(matches!(
            parse_request(&mut blocking),
            Err(ParseError::Unsupported("transfer-encoding"))
        ));
        // Identical wire response from the shared error serializer.
        let resp = parse_error_response(&ParseError::Unsupported("transfer-encoding")).unwrap();
        assert_eq!(resp.status, 501);
        assert_eq!(resp.body, br#"{"error":"not implemented: transfer-encoding"}"#);
        // A TE header alongside content-length still rejects (TE wins,
        // checked before the length), in both parsers.
        let mixed =
            b"POST /echo HTTP/1.1\r\ncontent-length: 5\r\ntransfer-encoding: chunked\r\n\r\nhello";
        let mut incremental = RequestParser::new();
        assert!(matches!(incremental.feed(mixed), Err(ParseError::Unsupported(_))));
        let mut blocking = BufReader::new(&mixed[..]);
        assert!(matches!(parse_request(&mut blocking), Err(ParseError::Unsupported(_))));
    }

    #[test]
    fn incremental_parser_header_cap_is_exact() {
        // Header section of exactly MAX_HEADER bytes parses...
        let overhead = "x-f: \r\n".len() + "\r\n".len();
        let pad = "p".repeat(MAX_HEADER - overhead);
        let ok = format!("GET /q HTTP/1.1\r\nx-f: {pad}\r\n\r\n");
        let mut p = RequestParser::new();
        let req = p.feed(ok.as_bytes()).unwrap().expect("exact-cap header parses");
        assert_eq!(req.path, "/q");
        // ...one more byte is rejected, even when fed incrementally.
        let too_big = format!("GET /q HTTP/1.1\r\nx-f: p{pad}\r\n\r\n");
        let mut p = RequestParser::new();
        let mut err = None;
        for chunk in too_big.as_bytes().chunks(97) {
            match p.feed(chunk) {
                Ok(_) => {}
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        assert!(matches!(err, Some(ParseError::TooLarge)));
    }
}
