"""AOT compiler: lower every Layer-1/Layer-2 computation to HLO text.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's XLA
(xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to --out-dir:

  embedder_enva.hlo.txt   encoder, env A (Pallas attention, sum pooling)
  embedder_envb.hlo.txt   encoder, env B (jnp attention, cumsum pooling)
  quantize.hlo.txt        f32[B,D] -> Q16.16 i32[B,D]   (Pallas kernel)
  distance_q16_l2.hlo.txt   i32[D], i32[N,D] -> i64[N]  (Pallas kernel)
  distance_q16_dot.hlo.txt  i32[D], i32[N,D] -> i64[N]  (Pallas kernel)
  distance_f32_l2.hlo.txt   f32[D], f32[N,D] -> f32[N]  (float baseline)
  weights/<name>.bin      little-endian weight tensors (HLO params)
  manifest.json           parameter order/shapes/dtypes + model constants

Python runs ONCE at build time (make artifacts); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # i64 accumulators in the kernels

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import fixedpoint as fp  # noqa: E402

DB_ROWS = 1024  # fixed AOT shape for the distance executables (rust pads)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_embedder(env: str) -> str:
    w = model.init_weights(0)
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in w]
    ids_spec = jax.ShapeDtypeStruct((model.BATCH, model.SEQ_LEN), jnp.int32)
    lowered = jax.jit(model.embed_fn(env)).lower(*specs, ids_spec)
    return to_hlo_text(lowered)


def lower_quantize() -> str:
    spec = jax.ShapeDtypeStruct((model.BATCH, model.D_MODEL), jnp.float32)

    def fn(x):
        return (fp.quantize(x),)

    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_distance(kind: str) -> str:
    q = jax.ShapeDtypeStruct((model.D_MODEL,), jnp.int32)
    db = jax.ShapeDtypeStruct((DB_ROWS, model.D_MODEL), jnp.int32)
    kernel = fp.l2sq_q16 if kind == "l2" else fp.dot_q16

    def fn(query, database):
        return (kernel(query, database),)

    return to_hlo_text(jax.jit(fn).lower(q, db))


def lower_distance_f32() -> str:
    q = jax.ShapeDtypeStruct((model.D_MODEL,), jnp.float32)
    db = jax.ShapeDtypeStruct((DB_ROWS, model.D_MODEL), jnp.float32)

    def fn(query, database):
        diff = database - query[None, :]
        return (jnp.sum(diff * diff, axis=1),)

    return to_hlo_text(jax.jit(fn).lower(q, db))


def export_weights(out_dir: str) -> dict:
    """Write weight binaries + the parameter manifest the Rust side reads."""
    w = model.init_weights(0)
    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)
    params = []
    for name, arr in zip(model.Weights._fields, w):
        arr = np.asarray(arr, dtype=np.float32)
        path = os.path.join(wdir, f"{name}.bin")
        arr.astype("<f4").tofile(path)
        params.append({"name": name, "shape": list(arr.shape), "dtype": "f32"})
    manifest = {
        "params": params,  # HLO parameter order; token_ids is appended last
        "model": {
            "vocab": model.VOCAB,
            "d_model": model.D_MODEL,
            "n_heads": model.N_HEADS,
            "n_layers": model.N_LAYERS,
            "d_ff": model.D_FF,
            "seq_len": model.SEQ_LEN,
            "batch": model.BATCH,
            "pad_id": model.PAD_ID,
            "db_rows": DB_ROWS,
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    jobs = {
        "embedder_enva.hlo.txt": lambda: lower_embedder("a"),
        "embedder_envb.hlo.txt": lambda: lower_embedder("b"),
        "quantize.hlo.txt": lower_quantize,
        "distance_q16_l2.hlo.txt": lambda: lower_distance("l2"),
        "distance_q16_dot.hlo.txt": lambda: lower_distance("dot"),
        "distance_f32_l2.hlo.txt": lower_distance_f32,
    }
    for fname, job in jobs.items():
        text = job()
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    export_weights(args.out_dir)
    print(f"wrote {args.out_dir}/weights + manifest.json")


if __name__ == "__main__":
    main()
