//! Index structures: deterministic HNSW (paper §7) and an exact flat index.
//!
//! Both are generic over [`crate::distance::Scalar`], so the *identical*
//! code is instantiated for Q16.16 (`i32`), Q32.32 (`i64`) and the `f32`
//! baseline — which is the control the paper's Table 3 requires
//! ("identical insertion order, identical HNSW configuration parameters"):
//! recall differences can only come from the numeric representation.

#![forbid(unsafe_code)]

pub mod flat;
pub mod hnsw;
pub mod quant;
pub mod store;
pub mod topk;

pub use flat::FlatIndex;
pub use hnsw::{Hnsw, HnswParams};
pub use quant::{QuantSpec, Quantizer, SQ8_DEFAULT_OVERSCAN};
pub use store::VecStore;
pub use topk::TopK;

use crate::distance::Scalar;

/// One search hit: external id + distance (generic) — smaller = closer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hit<D> {
    pub id: u64,
    pub dist: D,
}

/// Common interface over flat and HNSW indices (used by the state machine
/// and by the consistency tests that cross-check them).
pub trait VectorIndex<S: Scalar> {
    /// Insert a vector under an external id. Ids must be unique; the state
    /// machine enforces that before calling.
    fn insert(&mut self, id: u64, vector: Vec<S>);

    /// Tombstone a vector. Returns false if the id is unknown/deleted.
    fn delete(&mut self, id: u64) -> bool;

    /// k nearest neighbours of `query`, ordered by (dist, id) ascending.
    fn search(&self, query: &[S], k: usize) -> Vec<Hit<S::Dist>>;

    /// Number of live (non-deleted) vectors.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch a stored vector by external id (None if deleted/unknown).
    fn get(&self, id: u64) -> Option<&[S]>;
}
