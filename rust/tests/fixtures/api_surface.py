#!/usr/bin/env python3
"""Exercise the whole /v2 surface (plus the /v1 adapter and the chunked
501 path) against a running node and diff every response against the
golden fixture `api_surface_golden.json`.

The golden cases are ordered and stateful, so the target must be a
FRESH `valori serve --dim 4 --shards 2 --collections 3 --no-embedder`
node (see the `server` stanza in the fixture). Placeholders in golden
bodies (`<any>`, `<int>`, `<float>`, `<str>`, `<hex16>`, `<hex64>`)
match by shape; everything else must be an exact JSON match — that is
what makes the error-code taxonomy and the deterministic payloads
(seqs, exact Q16.16 distances) a pinned wire contract.

Usage: api_surface.py [--addr 127.0.0.1:7442]
"""

import argparse
import http.client
import json
import pathlib
import socket
import sys

GOLDEN = pathlib.Path(__file__).with_name("api_surface_golden.json")

PLACEHOLDERS = {"<any>", "<int>", "<float>", "<str>", "<hex16>", "<hex64>"}


def matches(golden, actual, path="$"):
    """Structural match with placeholders; returns a list of mismatches."""
    if isinstance(golden, str) and golden in PLACEHOLDERS:
        if golden == "<any>":
            return []
        if golden == "<int>":
            ok = isinstance(actual, int) and not isinstance(actual, bool)
        elif golden == "<float>":
            ok = isinstance(actual, (int, float)) and not isinstance(actual, bool)
        elif golden == "<str>":
            ok = isinstance(actual, str)
        elif golden == "<hex16>":
            ok = isinstance(actual, str) and len(actual) == 16 and all(
                c in "0123456789abcdef" for c in actual)
        else:  # <hex64>
            ok = isinstance(actual, str) and len(actual) == 64 and all(
                c in "0123456789abcdef" for c in actual)
        return [] if ok else [f"{path}: expected {golden}, got {actual!r}"]
    if isinstance(golden, dict):
        if not isinstance(actual, dict):
            return [f"{path}: expected object, got {actual!r}"]
        errs = []
        if set(golden) != set(actual):
            return [f"{path}: keys differ: expected {sorted(golden)}, got {sorted(actual)}"]
        for k in golden:
            errs += matches(golden[k], actual[k], f"{path}.{k}")
        return errs
    if isinstance(golden, list):
        if not isinstance(actual, list):
            return [f"{path}: expected array, got {actual!r}"]
        if len(golden) != len(actual):
            return [f"{path}: expected {len(golden)} items, got {len(actual)}"]
        errs = []
        for i, (g, a) in enumerate(zip(golden, actual)):
            errs += matches(g, a, f"{path}[{i}]")
        return errs
    # exact (python == treats 0 == 0.0, matching JSON number semantics)
    if golden != actual or isinstance(golden, bool) != isinstance(actual, bool):
        return [f"{path}: expected {golden!r}, got {actual!r}"]
    return []


def run_http_case(host, port, case):
    conn = http.client.HTTPConnection(host, port, timeout=30)
    body = case.get("body")
    conn.request(case["method"], case["path"],
                 body=body.encode() if body is not None else None)
    resp = conn.getresponse()
    status = resp.status
    raw = resp.read()
    conn.close()
    try:
        parsed = json.loads(raw)
    except ValueError:
        parsed = raw.decode("utf-8", "replace")
    return status, parsed


def run_raw_case(host, port, case):
    """Send raw bytes (protocol-error cases) and parse whatever comes
    back until the server closes — also asserts it *does* close."""
    s = socket.create_connection((host, port), timeout=30)
    s.sendall(case["raw"].encode())
    data = b""
    while True:
        chunk = s.recv(65536)
        if not chunk:
            break  # server closed, as required for 501/close
        data += chunk
    s.close()
    head, _, body = data.partition(b"\r\n\r\n")
    status = int(head.split(b" ")[1].decode())
    try:
        parsed = json.loads(body)
    except ValueError:
        parsed = body.decode("utf-8", "replace")
    return status, parsed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--addr", default="127.0.0.1:7442")
    args = ap.parse_args()
    host, port = args.addr.rsplit(":", 1)
    port = int(port)

    golden = json.loads(GOLDEN.read_text())
    failures = []
    for case in golden["cases"]:
        name = case["name"]
        if "raw" in case:
            status, parsed = run_raw_case(host, port, case)
        else:
            status, parsed = run_http_case(host, port, case)
        errs = []
        if status != case["status"]:
            errs.append(f"status: expected {case['status']}, got {status}")
        errs += matches(case["response"], parsed)
        if errs:
            failures.append((name, errs, parsed))
            print(f"FAIL {name}")
            for e in errs:
                print(f"  {e}")
            print(f"  actual: {json.dumps(parsed, sort_keys=True)}")
        else:
            print(f"ok   {name}")
    if failures:
        print(f"\n{len(failures)}/{len(golden['cases'])} api-surface cases failed")
        sys.exit(1)
    print(f"\nall {len(golden['cases'])} api-surface cases match the golden fixture")


if __name__ == "__main__":
    main()
