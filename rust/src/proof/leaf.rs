//! Canonical per-slot leaf encoding.
//!
//! One leaf is one arena slot. The encoding is the *complete* auditable
//! record — `id ‖ vector bytes ‖ meta ‖ links` — so the same bytes that
//! hash into the Merkle tree can be shipped verbatim to repair a diverged
//! replica ([`crate::replication::merkle_diff_repair`]). Three shapes:
//!
//! - live record:  `0x01 ‖ id:u64 ‖ dim:u32 ‖ raw_i32×dim ‖
//!   n_meta:u32 ‖ (klen:u32 ‖ key ‖ vlen:u32 ‖ val)* ‖
//!   n_links:u32 ‖ target:u64×n_links`
//! - tombstone:    `0x02 ‖ id:u64`
//! - empty slot:   `0x00` (the fixed sentinel, see
//!   [`super::tree::EMPTY_SLOT_ENCODING`])
//!
//! All integers are fixed-width little-endian (never platform-width), meta
//! pairs are sorted by key (BTreeMap iteration order), and link targets are
//! ascending ([`crate::graph::LinkGraph::links_from`]) — the encoding of a
//! slot is a pure function of the logical record, independent of mutation
//! history.
//!
//! Only a record's **outgoing** links are encoded. Incoming links live in
//! the source record's leaf, so every link is covered by exactly one leaf
//! and no edge is double-counted.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::fmt;

/// Tag byte for a live record leaf.
pub const LEAF_LIVE: u8 = 0x01;
/// Tag byte for a tombstone leaf.
pub const LEAF_TOMBSTONE: u8 = 0x02;

/// Hostile-input caps for [`decode`] (repair bodies arrive over HTTP).
const MAX_DIM: usize = 1 << 20;
const MAX_META: usize = 1 << 16;
const MAX_STR: usize = 1 << 16;
const MAX_LINKS: usize = 1 << 20;

/// Encode a live record's canonical leaf.
pub fn encode_live(
    id: u64,
    raw: &[i32],
    meta: Option<&BTreeMap<String, String>>,
    links: &[u64],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(21 + raw.len() * 4 + links.len() * 8);
    out.push(LEAF_LIVE);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(raw.len() as u32).to_le_bytes());
    for &c in raw {
        out.extend_from_slice(&c.to_le_bytes());
    }
    let n_meta = meta.map_or(0, |m| m.len());
    out.extend_from_slice(&(n_meta as u32).to_le_bytes());
    if let Some(m) = meta {
        for (k, v) in m {
            out.extend_from_slice(&(k.len() as u32).to_le_bytes());
            out.extend_from_slice(k.as_bytes());
            out.extend_from_slice(&(v.len() as u32).to_le_bytes());
            out.extend_from_slice(v.as_bytes());
        }
    }
    out.extend_from_slice(&(links.len() as u32).to_le_bytes());
    for &t in links {
        out.extend_from_slice(&t.to_le_bytes());
    }
    out
}

/// Encode a tombstone leaf (deleted record; slot number is retired).
pub fn encode_tombstone(id: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(9);
    out.push(LEAF_TOMBSTONE);
    out.extend_from_slice(&id.to_le_bytes());
    out
}

/// A decoded leaf (the repair path parses these from the wire).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LeafRecord {
    pub id: u64,
    pub body: LeafBody,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LeafBody {
    Live { vector: Vec<i32>, meta: BTreeMap<String, String>, links: Vec<u64> },
    Tombstone,
}

impl LeafRecord {
    /// Re-encode canonically; `decode(encode(r)) == r` and
    /// `encode(decode(b)) == b` for canonical `b`.
    pub fn encode(&self) -> Vec<u8> {
        match &self.body {
            LeafBody::Live { vector, meta, links } => {
                let m = if meta.is_empty() { None } else { Some(meta) };
                encode_live(self.id, vector, m, links)
            }
            LeafBody::Tombstone => encode_tombstone(self.id),
        }
    }
}

/// Leaf decode error (closed set; maps to API code 1700).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeafError {
    Truncated,
    BadTag,
    TooLarge,
    BadUtf8,
    UnsortedMeta,
    UnsortedLinks,
    TrailingBytes,
}

impl fmt::Display for LeafError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            LeafError::Truncated => "truncated leaf encoding",
            LeafError::BadTag => "unknown leaf tag",
            LeafError::TooLarge => "leaf field exceeds size cap",
            LeafError::BadUtf8 => "meta key/value is not utf-8",
            LeafError::UnsortedMeta => "meta pairs not sorted by key",
            LeafError::UnsortedLinks => "link targets not strictly ascending",
            LeafError::TrailingBytes => "trailing bytes after leaf",
        };
        f.write_str(s)
    }
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], LeafError> {
        let end = self.pos.checked_add(n).ok_or(LeafError::Truncated)?;
        let s = self.bytes.get(self.pos..end).ok_or(LeafError::Truncated)?;
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, LeafError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, LeafError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, LeafError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn i32(&mut self) -> Result<i32, LeafError> {
        let b = self.take(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn string(&mut self) -> Result<String, LeafError> {
        let len = self.u32()? as usize;
        if len > MAX_STR {
            return Err(LeafError::TooLarge);
        }
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| LeafError::BadUtf8)
    }
}

/// Parse a canonical live/tombstone leaf encoding. Rejects the empty-slot
/// sentinel (there is no record to repair with), non-canonical ordering,
/// and trailing bytes — a decoded leaf always re-encodes to the same bytes.
pub fn decode(bytes: &[u8]) -> Result<LeafRecord, LeafError> {
    let mut c = Cursor { bytes, pos: 0 };
    let rec = match c.u8()? {
        LEAF_TOMBSTONE => LeafRecord { id: c.u64()?, body: LeafBody::Tombstone },
        LEAF_LIVE => {
            let id = c.u64()?;
            let dim = c.u32()? as usize;
            if dim > MAX_DIM {
                return Err(LeafError::TooLarge);
            }
            let mut vector = Vec::with_capacity(dim.min(4096));
            for _ in 0..dim {
                vector.push(c.i32()?);
            }
            let n_meta = c.u32()? as usize;
            if n_meta > MAX_META {
                return Err(LeafError::TooLarge);
            }
            let mut meta = BTreeMap::new();
            let mut prev_key: Option<String> = None;
            for _ in 0..n_meta {
                let k = c.string()?;
                let v = c.string()?;
                if let Some(p) = &prev_key {
                    if *p >= k {
                        return Err(LeafError::UnsortedMeta);
                    }
                }
                prev_key = Some(k.clone());
                meta.insert(k, v);
            }
            let n_links = c.u32()? as usize;
            if n_links > MAX_LINKS {
                return Err(LeafError::TooLarge);
            }
            let mut links = Vec::with_capacity(n_links.min(4096));
            for _ in 0..n_links {
                let t = c.u64()?;
                if links.last().is_some_and(|&p| p >= t) {
                    return Err(LeafError::UnsortedLinks);
                }
                links.push(t);
            }
            LeafRecord { id, body: LeafBody::Live { vector, meta, links } }
        }
        _ => return Err(LeafError::BadTag),
    };
    if c.pos != bytes.len() {
        return Err(LeafError::TrailingBytes);
    }
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> LeafRecord {
        let mut meta = BTreeMap::new();
        meta.insert("a".to_string(), "1".to_string());
        meta.insert("kind".to_string(), "doc".to_string());
        LeafRecord {
            id: 42,
            body: LeafBody::Live {
                vector: vec![65536, -32768, 0, i32::MAX],
                meta,
                links: vec![3, 7, 900],
            },
        }
    }

    #[test]
    fn roundtrip_live_and_tombstone() {
        let rec = sample();
        let enc = rec.encode();
        assert_eq!(decode(&enc).unwrap(), rec);
        assert_eq!(decode(&enc).unwrap().encode(), enc);

        let t = LeafRecord { id: 9, body: LeafBody::Tombstone };
        let enc = t.encode();
        assert_eq!(enc.len(), 9);
        assert_eq!(decode(&enc).unwrap(), t);
    }

    #[test]
    fn encoding_layout_is_pinned() {
        // Byte-for-byte pin: the Python mirror (fixtures/make_proof.py)
        // reproduces exactly this layout.
        let enc = encode_live(1, &[65536], None, &[2]);
        let expected: Vec<u8> = [
            &[0x01][..],                  // live tag
            &1u64.to_le_bytes(),          // id
            &1u32.to_le_bytes(),          // dim
            &65536i32.to_le_bytes(),      // raw component
            &0u32.to_le_bytes(),          // n_meta
            &1u32.to_le_bytes(),          // n_links
            &2u64.to_le_bytes(),          // link target
        ]
        .concat();
        assert_eq!(enc, expected);
        assert_eq!(encode_tombstone(1), [&[0x02][..], &1u64.to_le_bytes()].concat());
    }

    #[test]
    fn rejects_malformed() {
        assert_eq!(decode(&[]), Err(LeafError::Truncated));
        assert_eq!(decode(&[0x00]), Err(LeafError::BadTag)); // sentinel is not a record
        assert_eq!(decode(&[0x07, 0, 0]), Err(LeafError::BadTag));
        assert_eq!(decode(&[0x02, 1, 2]), Err(LeafError::Truncated));
        let mut enc = sample().encode();
        enc.push(0);
        assert_eq!(decode(&enc), Err(LeafError::TrailingBytes));
    }

    #[test]
    fn rejects_non_canonical_order() {
        // meta out of order: "b" before "a"
        let mut enc = Vec::new();
        enc.push(LEAF_LIVE);
        enc.extend_from_slice(&5u64.to_le_bytes());
        enc.extend_from_slice(&0u32.to_le_bytes()); // dim 0
        enc.extend_from_slice(&2u32.to_le_bytes()); // n_meta
        for (k, v) in [("b", "1"), ("a", "2")] {
            enc.extend_from_slice(&(k.len() as u32).to_le_bytes());
            enc.extend_from_slice(k.as_bytes());
            enc.extend_from_slice(&(v.len() as u32).to_le_bytes());
            enc.extend_from_slice(v.as_bytes());
        }
        enc.extend_from_slice(&0u32.to_le_bytes()); // n_links
        assert_eq!(decode(&enc), Err(LeafError::UnsortedMeta));

        // links not strictly ascending
        let dup = encode_live(5, &[], None, &[4, 4]);
        assert_eq!(decode(&dup), Err(LeafError::UnsortedLinks));
    }
}
