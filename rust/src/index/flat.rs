//! Exact (brute-force) index.
//!
//! Ground truth for the HNSW consistency tests and the recall experiments
//! (Table 3 computes Recall@k against exact top-k), and a perfectly usable
//! index in its own right for small collections. Determinism is trivial:
//! one pass in slot order, sort by `(dist, id)`.

use super::store::VecStore;
use super::{Hit, VectorIndex};
use crate::codec::{DecodeError, Decoder, Encoder};
use crate::distance::{Metric, Scalar};

/// Brute-force exact index over a [`VecStore`].
#[derive(Debug, Clone, PartialEq)]
pub struct FlatIndex<S: Scalar> {
    metric: Metric,
    store: VecStore<S>,
}

impl<S: Scalar> FlatIndex<S> {
    pub fn new(dim: usize, metric: Metric) -> Self {
        Self { metric, store: VecStore::new(dim) }
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    pub fn store(&self) -> &VecStore<S> {
        &self.store
    }

    pub fn encode(&self, e: &mut Encoder) {
        e.put_u8(self.metric.tag());
        self.store.encode(e);
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let tag = d.get_u8()?;
        let metric = Metric::from_tag(tag)
            .ok_or(DecodeError::InvalidTag { what: "metric", tag: tag as u64 })?;
        let store = VecStore::decode(d)?;
        Ok(Self { metric, store })
    }
}

impl<S: Scalar> VectorIndex<S> for FlatIndex<S> {
    fn insert(&mut self, id: u64, vector: Vec<S>) {
        self.store.insert(id, vector);
    }

    fn delete(&mut self, id: u64) -> bool {
        self.store.delete(id).is_some()
    }

    fn search(&self, query: &[S], k: usize) -> Vec<Hit<S::Dist>> {
        let mut hits: Vec<Hit<S::Dist>> = self
            .store
            .iter_live()
            .map(|(_, id, v)| Hit { id, dist: S::distance(self.metric, query, v) })
            .collect();
        // Total order on (dist, id): deterministic ranking even with ties.
        hits.sort_by(|a, b| a.dist.cmp(&b.dist).then(a.id.cmp(&b.id)));
        hits.truncate(k);
        hits
    }

    fn len(&self) -> usize {
        self.store.live_len()
    }

    fn get(&self, id: u64) -> Option<&[S]> {
        self.store.get(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{FixedFormat, Q16_16};

    fn q(x: f64) -> i32 {
        Q16_16::quantize(x)
    }

    fn build() -> FlatIndex<i32> {
        let mut idx = FlatIndex::new(2, Metric::L2);
        idx.insert(1, vec![q(0.0), q(0.0)]);
        idx.insert(2, vec![q(1.0), q(0.0)]);
        idx.insert(3, vec![q(0.0), q(2.0)]);
        idx
    }

    #[test]
    fn search_orders_by_distance() {
        let idx = build();
        let hits = idx.search(&[q(0.1), q(0.0)], 3);
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn search_k_truncates() {
        let idx = build();
        assert_eq!(idx.search(&[q(0.0), q(0.0)], 2).len(), 2);
        assert_eq!(idx.search(&[q(0.0), q(0.0)], 10).len(), 3);
        assert!(idx.search(&[q(0.0), q(0.0)], 0).is_empty());
    }

    #[test]
    fn delete_excludes_from_results() {
        let mut idx = build();
        assert!(idx.delete(1));
        assert!(!idx.delete(1));
        let hits = idx.search(&[q(0.0), q(0.0)], 3);
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn ties_break_by_id() {
        let mut idx = FlatIndex::new(1, Metric::L2);
        idx.insert(7, vec![q(1.0)]);
        idx.insert(3, vec![q(1.0)]); // identical vector, smaller id
        let hits = idx.search(&[q(1.0)], 2);
        assert_eq!(hits[0].id, 3);
        assert_eq!(hits[1].id, 7);
        assert_eq!(hits[0].dist, hits[1].dist);
    }

    #[test]
    fn inner_product_prefers_aligned() {
        let mut idx = FlatIndex::new(2, Metric::InnerProduct);
        idx.insert(1, vec![q(1.0), q(0.0)]);
        idx.insert(2, vec![q(-1.0), q(0.0)]);
        let hits = idx.search(&[q(1.0), q(0.0)], 2);
        assert_eq!(hits[0].id, 1);
    }

    #[test]
    fn roundtrip_preserves_results() {
        let mut idx = build();
        idx.delete(2);
        let mut e = Encoder::new();
        idx.encode(&mut e);
        let bytes = e.into_vec();
        let idx2 = FlatIndex::<i32>::decode(&mut Decoder::new(&bytes)).unwrap();
        let q0 = [q(0.3), q(0.3)];
        assert_eq!(idx.search(&q0, 5), idx2.search(&q0, 5));
    }

    #[test]
    fn f32_baseline_works() {
        let mut idx: FlatIndex<f32> = FlatIndex::new(2, Metric::L2);
        idx.insert(1, vec![0.0, 0.0]);
        idx.insert(2, vec![1.0, 1.0]);
        let hits = idx.search(&[0.9, 0.9], 2);
        assert_eq!(hits[0].id, 2);
    }
}
