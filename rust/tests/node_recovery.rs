//! Integration: node restart durability (WAL recovery on boot) and the
//! atomic batch-insert command (paper §7.1 fixed ordering).

use std::sync::Arc;
use valori::node::{NodeConfig, NodeState};
use valori::state::{CanonCommand, Command, Kernel, KernelConfig, StateError};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("valori_it_node_{}_{name}", std::process::id()))
}

#[test]
fn node_recovers_state_from_wal_on_restart() {
    let wal = tmp("restart.wal");
    std::fs::remove_file(&wal).ok();
    let config = NodeConfig { workers: 2, wal_path: Some(wal.clone()), ..NodeConfig::default() };

    // incarnation 1: write some state
    let hash1 = {
        let state =
            NodeState::new(Kernel::new(KernelConfig::default_q16(4)), &config, None).unwrap();
        for i in 0..40u64 {
            let x = i as f32 / 40.0;
            state.apply(Command::insert(i, vec![x, 1.0 - x, 0.5, -x])).unwrap();
        }
        state.apply(Command::Delete { id: 3 }).unwrap();
        state.apply(Command::Link { from: 1, to: 2 }).unwrap();
        state.with_kernel(|k| k.state_hash())
    }; // drop: wal closed

    // incarnation 2: fresh kernel + same wal path -> recovered state
    let state2 =
        NodeState::new(Kernel::new(KernelConfig::default_q16(4)), &config, None).unwrap();
    assert_eq!(state2.with_kernel(|k| k.state_hash()), hash1);
    assert_eq!(state2.with_kernel(|k| k.seq()), 42);
    assert_eq!(state2.log_len(), 42);

    // and it continues accepting commands, appending to the same wal
    state2.apply(Command::insert(100, vec![0.9, 0.9, 0.9, 0.9])).unwrap();
    let hash2 = state2.with_kernel(|k| k.state_hash());
    drop(state2);

    // incarnation 3 sees everything
    let state3 =
        NodeState::new(Kernel::new(KernelConfig::default_q16(4)), &config, None).unwrap();
    assert_eq!(state3.with_kernel(|k| k.state_hash()), hash2);
    std::fs::remove_file(&wal).ok();
}

#[test]
fn node_repairs_torn_wal_tail_on_restart() {
    let wal = tmp("torn.wal");
    std::fs::remove_file(&wal).ok();
    let config = NodeConfig { workers: 2, wal_path: Some(wal.clone()), ..NodeConfig::default() };
    {
        let state =
            NodeState::new(Kernel::new(KernelConfig::default_q16(4)), &config, None).unwrap();
        for i in 0..10u64 {
            state.apply(Command::insert(i, vec![0.1, 0.2, 0.3, 0.4])).unwrap();
        }
    }
    // simulate crash mid-write: chop 5 bytes
    let bytes = std::fs::read(&wal).unwrap();
    std::fs::write(&wal, &bytes[..bytes.len() - 5]).unwrap();

    let state2 =
        NodeState::new(Kernel::new(KernelConfig::default_q16(4)), &config, None).unwrap();
    assert_eq!(state2.with_kernel(|k| k.seq()), 9); // last record lost, rest intact
    // the file was repaired: a third boot agrees
    drop(state2);
    let state3 =
        NodeState::new(Kernel::new(KernelConfig::default_q16(4)), &config, None).unwrap();
    assert_eq!(state3.with_kernel(|k| k.seq()), 9);
    std::fs::remove_file(&wal).ok();
}

#[test]
fn insert_batch_is_sorted_and_atomic() {
    let mut k = Kernel::new(KernelConfig::default_q16(4));
    // submitted out of order -> canonicalized ascending
    let canon = k
        .apply(Command::InsertBatch {
            items: vec![
                (30, vec![0.3, 0.0, 0.0, 0.0]),
                (10, vec![0.1, 0.0, 0.0, 0.0]),
                (20, vec![0.2, 0.0, 0.0, 0.0]),
            ],
        })
        .unwrap();
    match &canon {
        CanonCommand::InsertBatch { items } => {
            assert_eq!(items.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![10, 20, 30]);
        }
        other => panic!("wrong canon: {other:?}"),
    }
    assert_eq!(k.len(), 3);
    assert_eq!(k.seq(), 1); // one atomic command

    // batch with a duplicate against existing state: fully rejected
    let before = k.state_hash();
    let err = k
        .apply(Command::InsertBatch {
            items: vec![(40, vec![0.4, 0.0, 0.0, 0.0]), (10, vec![0.0; 4])],
        })
        .unwrap_err();
    assert_eq!(err, StateError::DuplicateId(10));
    assert_eq!(k.state_hash(), before, "failed batch must be atomic");
    assert!(!k.contains(40));

    // duplicate INSIDE a batch: rejected at canonicalization
    let err = k
        .apply(Command::InsertBatch {
            items: vec![(50, vec![0.0; 4]), (50, vec![0.1, 0.0, 0.0, 0.0])],
        })
        .unwrap_err();
    assert_eq!(err, StateError::DuplicateId(50));
}

#[test]
fn batch_submission_order_does_not_matter() {
    // the §7.1 property: any permutation of the same batch produces the
    // same canonical command and the same state hash
    let items = |perm: &[usize]| -> Vec<(u64, Vec<f32>)> {
        let base = [
            (5u64, vec![0.5f32, 0.0, 0.0, 0.0]),
            (1, vec![0.1, 0.0, 0.0, 0.0]),
            (9, vec![0.9, 0.0, 0.0, 0.0]),
        ];
        perm.iter().map(|&i| base[i].clone()).collect()
    };
    let mut hashes = Vec::new();
    for perm in [[0usize, 1, 2], [2, 1, 0], [1, 2, 0]] {
        let mut k = Kernel::new(KernelConfig::default_q16(4));
        k.apply(Command::InsertBatch { items: items(&perm) }).unwrap();
        hashes.push(k.state_hash());
    }
    assert!(hashes.windows(2).all(|w| w[0] == w[1]));
}

#[test]
fn unsorted_batch_rejected_at_decode() {
    // a forged log with an out-of-order batch must not decode
    let good = CanonCommand::InsertBatch {
        items: vec![(1, vec![1, 2]), (2, vec![3, 4])],
    };
    let mut bytes = good.to_bytes();
    // swap the two ids (u64 LE right after tag+count)
    // layout: tag(1) count(4) id(8) vec... — easier: build a bad one manually
    let bad = CanonCommand::InsertBatch {
        items: vec![(2, vec![1, 2]), (1, vec![3, 4])],
    };
    bytes = bad.to_bytes();
    assert!(CanonCommand::from_bytes(&bytes).is_err());
}

#[test]
fn insert_batch_over_http_route() {
    let state = Arc::new(
        NodeState::new(Kernel::new(KernelConfig::default_q16(2)), &NodeConfig::default(), None)
            .unwrap(),
    );
    let server = valori::node::serve(Arc::clone(&state), "127.0.0.1:0", 2).unwrap();
    let body = valori::json::parse(
        r#"{"items":[{"id":7,"vector":[0.7,0.0]},{"id":3,"vector":[0.3,0.0]}]}"#,
    )
    .unwrap();
    let (st, resp) =
        valori::http::client::post_json(&server.addr(), "/v1/insert_batch", &body).unwrap();
    assert_eq!(st, 200, "{resp}");
    assert_eq!(resp.get("inserted").as_i64(), Some(2));
    assert_eq!(state.with_kernel(|k| k.len()), 2);
    assert_eq!(state.with_kernel(|k| k.seq()), 1);
    server.stop();
}
