//! Write-ahead command log — the audit trail (paper §9 "replaying their
//! entire command log to verify why a decision was reached").
//!
//! The WAL stores *canonical* commands (post-boundary, integer-only), so a
//! replay is a pure integer computation: any machine that replays the same
//! log from the same initial state reaches the same state hash.
//!
//! On-disk framing, per record:
//!
//! ```text
//! [ payload_len: u32 LE ][ crc32(payload): u32 LE ][ payload bytes ]
//! payload = [ seq: u64 LE ][ canonical command bytes ]
//! ```
//!
//! Recovery semantics: a torn/corrupt tail (partial last record after a
//! crash) is detected by length/CRC and the log is truncated there —
//! standard WAL recovery. Corruption *before* the tail is an error: that is
//! data loss, not a crash artifact, and must be surfaced.

#![forbid(unsafe_code)]

use crate::codec::{DecodeError, Decoder, Encoder};
use crate::hash::crc32;
use crate::state::CanonCommand;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

/// One recovered WAL entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalEntry {
    /// Sequence number the command was applied at (0-based: the command
    /// that moved the kernel from seq to seq+1).
    pub seq: u64,
    pub command: CanonCommand,
}

/// Append-only WAL writer.
#[derive(Debug)]
pub struct WalWriter {
    path: PathBuf,
    file: BufWriter<File>,
    entries_written: u64,
}

impl WalWriter {
    /// Create (truncate) a new WAL at `path`.
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<Self> {
        let file = OpenOptions::new().create(true).write(true).truncate(true).open(&path)?;
        Ok(Self {
            path: path.as_ref().to_path_buf(),
            file: BufWriter::new(file),
            entries_written: 0,
        })
    }

    /// Open an existing WAL for appending (after replay/recovery the caller
    /// knows how many entries are valid; the file should have been
    /// truncated to that point by [`recover`]).
    pub fn append_to(path: impl AsRef<Path>, entries: u64) -> std::io::Result<Self> {
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(Self {
            path: path.as_ref().to_path_buf(),
            file: BufWriter::new(file),
            entries_written: entries,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn entries_written(&self) -> u64 {
        self.entries_written
    }

    /// Append one canonical command at sequence `seq`.
    pub fn append(&mut self, seq: u64, command: &CanonCommand) -> std::io::Result<()> {
        let mut payload = Encoder::new();
        payload.put_u64(seq);
        command.encode(&mut payload);
        let payload = payload.into_vec();
        let crc = crc32(&payload);
        let mut frame = Encoder::with_capacity(payload.len() + 8);
        frame.put_u32(payload.len() as u32);
        frame.put_u32(crc);
        self.file.write_all(frame.as_slice())?;
        self.file.write_all(&payload)?;
        self.entries_written += 1;
        Ok(())
    }

    /// Flush buffered records to the OS.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.file.flush()
    }

    /// Flush + fsync (durability point).
    pub fn sync(&mut self) -> std::io::Result<()> {
        self.file.flush()?;
        self.file.get_ref().sync_all()
    }
}

/// Outcome of reading a WAL file back.
#[derive(Debug)]
pub struct Recovery {
    pub entries: Vec<WalEntry>,
    /// Byte offset of the first invalid/torn record (= valid prefix size).
    pub valid_bytes: u64,
    /// True if a torn/corrupt tail was detected and ignored.
    pub truncated_tail: bool,
}

/// WAL read/recovery errors.
#[derive(Debug)]
pub enum WalError {
    Io(std::io::Error),
    /// Corruption strictly before the tail — not recoverable by truncation.
    MidLogCorruption { offset: u64, reason: String },
    Decode(DecodeError),
}

impl std::fmt::Display for WalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WalError::Io(e) => write!(f, "io: {e}"),
            WalError::MidLogCorruption { offset, reason } => {
                write!(f, "mid-log corruption at byte {offset}: {reason}")
            }
            WalError::Decode(e) => write!(f, "decode: {e}"),
        }
    }
}

impl std::error::Error for WalError {}

impl From<std::io::Error> for WalError {
    fn from(e: std::io::Error) -> Self {
        WalError::Io(e)
    }
}

/// Read every valid record; tolerate (and report) a torn tail.
pub fn recover(path: impl AsRef<Path>) -> Result<Recovery, WalError> {
    let mut bytes = Vec::new();
    File::open(&path)?.read_to_end(&mut bytes)?;
    recover_bytes(&bytes)
}

/// Recovery over an in-memory image (separated for testability).
pub fn recover_bytes(bytes: &[u8]) -> Result<Recovery, WalError> {
    let mut entries = Vec::new();
    let mut pos: usize = 0;
    let mut truncated_tail = false;
    while pos < bytes.len() {
        let remaining = bytes.len() - pos;
        if remaining < 8 {
            truncated_tail = true; // torn header
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if remaining < 8 + len {
            truncated_tail = true; // torn payload
            break;
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            // CRC mismatch: if this is the final record it's a torn tail;
            // otherwise it's mid-log corruption.
            if pos + 8 + len == bytes.len() {
                truncated_tail = true;
                break;
            }
            return Err(WalError::MidLogCorruption {
                offset: pos as u64,
                reason: "crc mismatch".into(),
            });
        }
        let mut d = Decoder::new(payload);
        let seq = d.get_u64().map_err(WalError::Decode)?;
        let command = CanonCommand::decode(&mut d).map_err(WalError::Decode)?;
        d.finish().map_err(WalError::Decode)?;
        entries.push(WalEntry { seq, command });
        pos += 8 + len;
    }
    Ok(Recovery { entries, valid_bytes: pos as u64, truncated_tail })
}

/// Truncate a WAL file to its valid prefix (post-crash repair).
pub fn truncate_to_valid(path: impl AsRef<Path>, valid_bytes: u64) -> std::io::Result<()> {
    let f = OpenOptions::new().write(true).open(path)?;
    f.set_len(valid_bytes)?;
    f.sync_all()
}

/// Replay a recovered log into a kernel. Stops at the first command that
/// fails (which, for a log produced by a correct leader, never happens).
pub fn replay(
    kernel: &mut crate::state::Kernel,
    entries: &[WalEntry],
) -> Result<usize, crate::state::StateError> {
    let mut applied = 0;
    for entry in entries {
        kernel.apply_canon(&entry.command)?;
        applied += 1;
    }
    Ok(applied)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::{Command, Kernel, KernelConfig};

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("valori_wal_test_{}_{}", std::process::id(), name));
        p
    }

    fn sample_commands() -> Vec<CanonCommand> {
        vec![
            CanonCommand::Insert { id: 1, raw: vec![100, -200, 300, 400] },
            CanonCommand::Insert { id: 2, raw: vec![1, 2, 3, 4] },
            CanonCommand::Link { from: 1, to: 2 },
            CanonCommand::SetMeta { id: 1, key: "k".into(), value: "v".into() },
            CanonCommand::Delete { id: 2 },
        ]
    }

    #[test]
    fn write_and_recover_roundtrip() {
        let path = tmp("roundtrip");
        let cmds = sample_commands();
        {
            let mut w = WalWriter::create(&path).unwrap();
            for (i, c) in cmds.iter().enumerate() {
                w.append(i as u64, c).unwrap();
            }
            w.sync().unwrap();
        }
        let rec = recover(&path).unwrap();
        assert!(!rec.truncated_tail);
        assert_eq!(rec.entries.len(), cmds.len());
        for (i, e) in rec.entries.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
            assert_eq!(e.command, cmds[i]);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let path = tmp("torn");
        {
            let mut w = WalWriter::create(&path).unwrap();
            for (i, c) in sample_commands().iter().enumerate() {
                w.append(i as u64, c).unwrap();
            }
            w.sync().unwrap();
        }
        // chop 3 bytes off the end — simulates a crash mid-write
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let rec = recover(&path).unwrap();
        assert!(rec.truncated_tail);
        assert_eq!(rec.entries.len(), sample_commands().len() - 1);
        // repair, then appending continues cleanly
        truncate_to_valid(&path, rec.valid_bytes).unwrap();
        let mut w = WalWriter::append_to(&path, rec.entries.len() as u64).unwrap();
        w.append(rec.entries.len() as u64, &CanonCommand::Delete { id: 1 }).unwrap();
        w.sync().unwrap();
        let rec2 = recover(&path).unwrap();
        assert!(!rec2.truncated_tail);
        assert_eq!(rec2.entries.len(), sample_commands().len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mid_log_corruption_is_fatal() {
        let path = tmp("midlog");
        {
            let mut w = WalWriter::create(&path).unwrap();
            for (i, c) in sample_commands().iter().enumerate() {
                w.append(i as u64, c).unwrap();
            }
            w.sync().unwrap();
        }
        let mut bytes = std::fs::read(&path).unwrap();
        // flip a payload byte inside the FIRST record
        bytes[10] ^= 0xff;
        let err = recover_bytes(&bytes).unwrap_err();
        assert!(matches!(err, WalError::MidLogCorruption { .. }));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_final_record_is_tail_truncation() {
        let cmds = sample_commands();
        let mut bytes;
        {
            // build in memory via a temp file
            let path = tmp("tailcrc");
            let mut w = WalWriter::create(&path).unwrap();
            for (i, c) in cmds.iter().enumerate() {
                w.append(i as u64, c).unwrap();
            }
            w.sync().unwrap();
            bytes = std::fs::read(&path).unwrap();
            std::fs::remove_file(&path).ok();
        }
        let n = bytes.len();
        bytes[n - 1] ^= 0xff; // corrupt last payload byte
        let rec = recover_bytes(&bytes).unwrap();
        assert!(rec.truncated_tail);
        assert_eq!(rec.entries.len(), cmds.len() - 1);
    }

    #[test]
    fn replay_reaches_same_hash_as_original() {
        let mut live = Kernel::new(KernelConfig::default_q16(4));
        let path = tmp("replay");
        {
            let mut w = WalWriter::create(&path).unwrap();
            let cmds = vec![
                Command::insert(1, vec![0.1, 0.2, 0.3, 0.4]),
                Command::insert(2, vec![-0.1, 0.0, 0.5, 0.9]),
                Command::Link { from: 1, to: 2 },
                Command::Delete { id: 2 },
            ];
            for c in cmds {
                let seq = live.seq();
                let canon = live.apply(c).unwrap();
                w.append(seq, &canon).unwrap();
            }
            w.sync().unwrap();
        }
        let rec = recover(&path).unwrap();
        let mut replayed = Kernel::new(KernelConfig::default_q16(4));
        let n = replay(&mut replayed, &rec.entries).unwrap();
        assert_eq!(n, 4);
        assert_eq!(replayed.state_hash(), live.state_hash());
        assert_eq!(replayed.seq(), live.seq());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_wal_recovers_empty() {
        let rec = recover_bytes(&[]).unwrap();
        assert!(rec.entries.is_empty());
        assert!(!rec.truncated_tail);
        assert_eq!(rec.valid_bytes, 0);
    }
}
