//! Deterministic binary codec — Valori's "serde".
//!
//! Snapshots and WAL records must be *byte-stable*: the same logical state
//! must serialize to the same bytes on every platform, forever, because the
//! state hash is computed over those bytes (paper §5.2, §8.1). That rules
//! out formats with nondeterministic map ordering or platform-dependent
//! widths. This codec is explicit little-endian with length-prefixed
//! sequences, and decoding is strict (trailing garbage and truncation are
//! errors).

#![forbid(unsafe_code)]

use std::fmt;

/// Encoding buffer.
#[derive(Debug, Default, Clone)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    #[inline]
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// f32 stored as raw IEEE-754 bits (only used outside the determinism
    /// boundary, e.g. the float baseline index).
    // lint: float-boundary — bit-exact IEEE-754 transport, no float arithmetic
    #[inline]
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// Length-prefixed byte string (u32 length).
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Length-prefixed i32 slice.
    pub fn put_i32_slice(&mut self, v: &[i32]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_i32(x);
        }
    }

    /// Length-prefixed i64 slice.
    pub fn put_i64_slice(&mut self, v: &[i64]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_i64(x);
        }
    }

    /// Length-prefixed u64 slice.
    pub fn put_u64_slice(&mut self, v: &[u64]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_u64(x);
        }
    }

    /// Length-prefixed f32 slice (bit-exact).
    // lint: float-boundary — bit-exact IEEE-754 transport, no float arithmetic
    pub fn put_f32_slice(&mut self, v: &[f32]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_f32(x);
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }
}

/// Decoding errors. Strictness is a feature: a snapshot that decodes
/// differently on two machines is a determinism violation, so we fail loudly
/// on any irregularity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Input ended before the requested read.
    UnexpectedEof { need: usize, have: usize },
    /// A length prefix exceeded the remaining input (corruption guard).
    LengthOverflow { len: usize, have: usize },
    /// String field was not valid UTF-8.
    InvalidUtf8,
    /// Trailing bytes remained after a complete decode.
    TrailingBytes { remaining: usize },
    /// A tag/enum discriminant was out of range.
    InvalidTag { what: &'static str, tag: u64 },
    /// Magic number or version mismatch.
    BadMagic { expected: u32, found: u32 },
    /// Unsupported format version.
    BadVersion { expected: u32, found: u32 },
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::UnexpectedEof { need, have } => {
                write!(f, "unexpected EOF: need {need} bytes, have {have}")
            }
            DecodeError::LengthOverflow { len, have } => {
                write!(f, "length prefix {len} exceeds remaining {have} bytes")
            }
            DecodeError::InvalidUtf8 => write!(f, "invalid UTF-8 in string field"),
            DecodeError::TrailingBytes { remaining } => {
                write!(f, "{remaining} trailing bytes after decode")
            }
            DecodeError::InvalidTag { what, tag } => write!(f, "invalid {what} tag {tag}"),
            DecodeError::BadMagic { expected, found } => {
                write!(f, "bad magic: expected {expected:#x}, found {found:#x}")
            }
            DecodeError::BadVersion { expected, found } => {
                write!(f, "unsupported version {found} (expected <= {expected})")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

/// Cursor-based strict decoder over a byte slice.
#[derive(Debug)]
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError::UnexpectedEof { need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i32(&mut self) -> Result<i32, DecodeError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> Result<i64, DecodeError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    // lint: float-boundary — bit-exact IEEE-754 transport, no float arithmetic
    pub fn get_f32(&mut self) -> Result<f32, DecodeError> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    fn get_len(&mut self) -> Result<usize, DecodeError> {
        let len = self.get_u32()? as usize;
        if len > self.remaining() {
            return Err(DecodeError::LengthOverflow { len, have: self.remaining() });
        }
        Ok(len)
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8], DecodeError> {
        let len = self.get_len()?;
        self.take(len)
    }

    pub fn get_str(&mut self) -> Result<&'a str, DecodeError> {
        std::str::from_utf8(self.get_bytes()?).map_err(|_| DecodeError::InvalidUtf8)
    }

    pub fn get_i32_vec(&mut self) -> Result<Vec<i32>, DecodeError> {
        let n = self.get_u32()? as usize;
        if n.checked_mul(4).map_or(true, |b| b > self.remaining()) {
            return Err(DecodeError::LengthOverflow { len: n * 4, have: self.remaining() });
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_i32()?);
        }
        Ok(v)
    }

    pub fn get_i64_vec(&mut self) -> Result<Vec<i64>, DecodeError> {
        let n = self.get_u32()? as usize;
        if n.checked_mul(8).map_or(true, |b| b > self.remaining()) {
            return Err(DecodeError::LengthOverflow { len: n * 8, have: self.remaining() });
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_i64()?);
        }
        Ok(v)
    }

    pub fn get_u64_vec(&mut self) -> Result<Vec<u64>, DecodeError> {
        let n = self.get_u32()? as usize;
        if n.checked_mul(8).map_or(true, |b| b > self.remaining()) {
            return Err(DecodeError::LengthOverflow { len: n * 8, have: self.remaining() });
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_u64()?);
        }
        Ok(v)
    }

    // lint: float-boundary — bit-exact IEEE-754 transport, no float arithmetic
    pub fn get_f32_vec(&mut self) -> Result<Vec<f32>, DecodeError> {
        let n = self.get_u32()? as usize;
        if n.checked_mul(4).map_or(true, |b| b > self.remaining()) {
            return Err(DecodeError::LengthOverflow { len: n * 4, have: self.remaining() });
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_f32()?);
        }
        Ok(v)
    }

    /// Assert the input is fully consumed (strict decode).
    pub fn finish(&self) -> Result<(), DecodeError> {
        if self.remaining() != 0 {
            Err(DecodeError::TrailingBytes { remaining: self.remaining() })
        } else {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut e = Encoder::new();
        e.put_u8(7);
        e.put_u16(0xBEEF);
        e.put_u32(0xDEADBEEF);
        e.put_u64(u64::MAX);
        e.put_i32(-42);
        e.put_i64(i64::MIN);
        e.put_f32(-0.0);
        let bytes = e.into_vec();
        let mut d = Decoder::new(&bytes);
        assert_eq!(d.get_u8().unwrap(), 7);
        assert_eq!(d.get_u16().unwrap(), 0xBEEF);
        assert_eq!(d.get_u32().unwrap(), 0xDEADBEEF);
        assert_eq!(d.get_u64().unwrap(), u64::MAX);
        assert_eq!(d.get_i32().unwrap(), -42);
        assert_eq!(d.get_i64().unwrap(), i64::MIN);
        // -0.0 must round-trip bit-exactly (sign bit preserved)
        assert_eq!(d.get_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        d.finish().unwrap();
    }

    #[test]
    fn slices_roundtrip() {
        let mut e = Encoder::new();
        e.put_i32_slice(&[1, -2, 3]);
        e.put_str("hello Valori");
        e.put_f32_slice(&[1.5, f32::NAN]);
        e.put_u64_slice(&[9, 10]);
        e.put_i64_slice(&[-1]);
        let bytes = e.into_vec();
        let mut d = Decoder::new(&bytes);
        assert_eq!(d.get_i32_vec().unwrap(), vec![1, -2, 3]);
        assert_eq!(d.get_str().unwrap(), "hello Valori");
        let f = d.get_f32_vec().unwrap();
        assert_eq!(f[0], 1.5);
        assert!(f[1].is_nan());
        assert_eq!(d.get_u64_vec().unwrap(), vec![9, 10]);
        assert_eq!(d.get_i64_vec().unwrap(), vec![-1]);
        d.finish().unwrap();
    }

    #[test]
    fn truncation_is_error() {
        let mut e = Encoder::new();
        e.put_u64(123);
        let bytes = e.into_vec();
        let mut d = Decoder::new(&bytes[..4]);
        assert!(matches!(d.get_u64(), Err(DecodeError::UnexpectedEof { .. })));
    }

    #[test]
    fn length_overflow_is_error() {
        let mut e = Encoder::new();
        e.put_u32(1000); // claims 1000 bytes follow
        e.put_u8(1);
        let bytes = e.into_vec();
        let mut d = Decoder::new(&bytes);
        assert!(matches!(d.get_bytes(), Err(DecodeError::LengthOverflow { .. })));
    }

    #[test]
    fn trailing_bytes_is_error() {
        let mut e = Encoder::new();
        e.put_u8(1);
        e.put_u8(2);
        let bytes = e.into_vec();
        let mut d = Decoder::new(&bytes);
        d.get_u8().unwrap();
        assert!(matches!(d.finish(), Err(DecodeError::TrailingBytes { remaining: 1 })));
    }

    #[test]
    fn invalid_utf8_is_error() {
        let mut e = Encoder::new();
        e.put_bytes(&[0xff, 0xfe]);
        let bytes = e.into_vec();
        let mut d = Decoder::new(&bytes);
        assert_eq!(d.get_str(), Err(DecodeError::InvalidUtf8));
    }

    #[test]
    fn encoding_is_byte_stable() {
        // The exact byte layout is part of the determinism contract — pin it.
        let mut e = Encoder::new();
        e.put_u32(1);
        e.put_i32(-1);
        e.put_str("ab");
        assert_eq!(
            e.as_slice(),
            &[1, 0, 0, 0, 0xff, 0xff, 0xff, 0xff, 2, 0, 0, 0, b'a', b'b']
        );
    }

    #[test]
    fn i32_vec_length_guard() {
        // length prefix claims 2^30 elements with 4 bytes of payload
        let mut e = Encoder::new();
        e.put_u32(1 << 30);
        e.put_u32(0);
        let bytes = e.into_vec();
        let mut d = Decoder::new(&bytes);
        assert!(matches!(d.get_i32_vec(), Err(DecodeError::LengthOverflow { .. })));
    }
}
