//! Synthetic corpus generator with semantic cluster structure.
//!
//! Substitute for the paper's real-text workloads (DESIGN §2): documents
//! are generated from per-topic templates, so documents sharing a topic
//! share vocabulary and the hashing-tokenizer + encoder pipeline maps them
//! near each other in embedding space. That gives the Table 3 recall
//! experiment a meaningful neighborhood structure to preserve, and gives
//! the RAG example realistic queries ("topic words + question words").
//!
//! Everything is driven by a seeded [`XorShift64`] — corpora are
//! reproducible by construction.

#![forbid(unsafe_code)]

use crate::hash::XorShift64;

/// Topic templates: (topic name, content words, sentence frames).
const TOPICS: &[(&str, &[&str], &[&str])] = &[
    (
        "finance",
        &["revenue", "profit", "earnings", "quarter", "margin", "forecast", "budget", "audit",
          "cashflow", "dividend", "april", "fiscal"],
        &["{w0} for {w1} exceeded the {w2}", "what is the {w0} in {w1}", "{w0} {w1} summary shows {w2}",
          "total {w0} last {w1} was driven by {w2}"],
    ),
    (
        "robotics",
        &["drone", "sensor", "actuator", "lidar", "navigation", "waypoint", "gimbal", "telemetry",
          "battery", "landing", "altitude", "payload"],
        &["the {w0} calibrated its {w1} before {w2}", "{w0} {w1} drift detected during {w2}",
          "autonomous {w0} reached the {w1} {w2}", "{w0} telemetry reports {w1} {w2}"],
    ),
    (
        "medicine",
        &["patient", "dosage", "trial", "diagnosis", "symptom", "treatment", "protocol", "biopsy",
          "remission", "oncology", "cardiology", "screening"],
        &["the {w0} responded to the {w1} {w2}", "{w0} {w1} indicates early {w2}",
          "clinical {w0} for {w1} showed {w2}", "updated {w0} protocol for {w1} {w2}"],
    ),
    (
        "infrastructure",
        &["cluster", "latency", "replica", "shard", "throughput", "backlog", "failover", "quorum",
          "snapshot", "compaction", "gossip", "leader"],
        &["the {w0} elected a new {w1} after {w2}", "{w0} {w1} degraded under {w2}",
          "scaling the {w0} reduced {w1} {w2}", "{w0} replication verified by {w1} {w2}"],
    ),
    (
        "climate",
        &["rainfall", "drought", "emission", "glacier", "habitat", "temperature", "monsoon",
          "carbon", "biomass", "erosion", "wildfire", "current"],
        &["{w0} patterns shifted the {w1} {w2}", "rising {w0} accelerates {w1} {w2}",
          "the {w0} model predicts {w1} {w2}", "{w0} data from the {w1} shows {w2}"],
    ),
];

/// One generated document.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Doc {
    pub id: u64,
    pub topic: usize,
    pub text: String,
}

/// Deterministic corpus generator.
#[derive(Debug)]
pub struct CorpusGen {
    rng: XorShift64,
    next_id: u64,
}

impl CorpusGen {
    pub fn new(seed: u64) -> Self {
        Self { rng: XorShift64::new(seed), next_id: 0 }
    }

    pub fn n_topics() -> usize {
        TOPICS.len()
    }

    /// Generate one document for a given topic.
    pub fn doc_for_topic(&mut self, topic: usize) -> Doc {
        let (_, words, frames) = TOPICS[topic % TOPICS.len()];
        let frame = frames[self.rng.next_below(frames.len() as u64) as usize];
        let mut text = frame.to_string();
        for slot in ["{w0}", "{w1}", "{w2}"] {
            let w = words[self.rng.next_below(words.len() as u64) as usize];
            text = text.replacen(slot, w, 1);
        }
        let id = self.next_id;
        self.next_id += 1;
        Doc { id, topic: topic % TOPICS.len(), text }
    }

    /// Generate `n` documents, cycling topics (balanced clusters).
    pub fn docs(&mut self, n: usize) -> Vec<Doc> {
        (0..n).map(|i| self.doc_for_topic(i % TOPICS.len())).collect()
    }

    /// Generate a query about one topic (shares vocabulary with its docs).
    pub fn query_for_topic(&mut self, topic: usize) -> String {
        let (name, words, _) = TOPICS[topic % TOPICS.len()];
        let w0 = words[self.rng.next_below(words.len() as u64) as usize];
        let w1 = words[self.rng.next_below(words.len() as u64) as usize];
        format!("question about {w0} and {w1} in {name}")
    }

    /// The paper's exact Table 1 sentence set (§4.1 Listing 1).
    pub fn paper_sentences() -> Vec<&'static str> {
        vec![
            "Revenue for April",
            "What is the profit in April?",
            "April financial summary",
            "Total earnings last month",
            "Completely unrelated sentence",
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a: Vec<Doc> = CorpusGen::new(7).docs(50);
        let b: Vec<Doc> = CorpusGen::new(7).docs(50);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seed_different_corpus() {
        let a: Vec<Doc> = CorpusGen::new(1).docs(50);
        let b: Vec<Doc> = CorpusGen::new(2).docs(50);
        assert_ne!(a, b);
    }

    #[test]
    fn ids_are_sequential() {
        let docs = CorpusGen::new(3).docs(10);
        for (i, d) in docs.iter().enumerate() {
            assert_eq!(d.id, i as u64);
        }
    }

    #[test]
    fn topics_are_balanced() {
        let docs = CorpusGen::new(3).docs(100);
        for t in 0..CorpusGen::n_topics() {
            let count = docs.iter().filter(|d| d.topic == t).count();
            assert_eq!(count, 100 / CorpusGen::n_topics());
        }
    }

    #[test]
    fn templates_fully_substituted() {
        let docs = CorpusGen::new(5).docs(200);
        for d in &docs {
            assert!(!d.text.contains('{'), "unsubstituted template: {}", d.text);
            assert!(!d.text.is_empty());
        }
    }

    #[test]
    fn queries_share_topic_vocabulary() {
        let mut g = CorpusGen::new(11);
        let q = g.query_for_topic(0);
        assert!(q.contains("finance"));
    }

    #[test]
    fn paper_sentences_match_listing1() {
        let s = CorpusGen::paper_sentences();
        assert_eq!(s.len(), 5);
        assert_eq!(s[0], "Revenue for April");
        assert_eq!(s[4], "Completely unrelated sentence");
    }
}
