"""Shared test config: enable x64 before any jax computation (the integer
distance kernels accumulate in i64), and expose common fixtures."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
