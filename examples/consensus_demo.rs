//! Consensus-style multi-node convergence (paper §9: decentralized AI /
//! blockchain nodes "must converge to an identical state after processing
//! the same inputs").
//!
//! Two demonstrations:
//!   1. In-process [`valori::replication::Cluster`]: a 5-node cluster
//!      processes 1 000 commands; every node reaches the same state hash;
//!      a corrupted node is detected by hash comparison and repaired by
//!      snapshot transfer from the primary.
//!   2. The same protocol over HTTP: three real `valori` node servers in
//!      this process, log shipped with `/v1/log` → `/v1/apply`, hashes
//!      compared via `/v1/hash`.
//!
//! Run: `cargo run --release --example consensus_demo`

use std::sync::Arc;
use valori::http::client;
use valori::node::{serve, NodeConfig, NodeState};
use valori::replication::{sync_follower, Cluster};
use valori::snapshot::Snapshot;
use valori::state::{Command, Kernel, KernelConfig};

fn main() {
    in_process_cluster();
    http_cluster();
    println!("consensus_demo OK");
}

fn in_process_cluster() {
    println!("--- in-process 5-node cluster ---");
    let mut cluster = Cluster::new(KernelConfig::default_q16(16), 5);

    // the primary orders 1000 commands
    for i in 0..950u64 {
        let v: Vec<f32> = (0..16).map(|j| ((i * 16 + j) as f32 * 0.003).sin() * 0.7).collect();
        cluster.submit(Command::insert(i, v)).unwrap();
    }
    for i in 0..25u64 {
        cluster.submit(Command::Delete { id: i * 7 }).unwrap();
    }
    for i in 0..25u64 {
        let (from, to) = (i * 3 + 1, i * 5 + 2);
        // skip pairs whose endpoints were tombstoned above
        if cluster.node(0).contains(from) && cluster.node(0).contains(to) {
            cluster.submit(Command::Link { from, to }).unwrap();
        }
    }
    cluster.sync_all().unwrap();
    assert!(cluster.converged());
    let reports = cluster.verify();
    for r in &reports {
        println!("  node {}: seq {} hash {:016x} converged={}", r.node, r.seq, r.hash, r.converged);
    }

    // corrupt node 3 (single bit in one replayed vector) -> detected
    assert!(cluster.corrupt_node_for_test(3, 500));
    let reports = cluster.verify();
    assert!(!reports[3].converged);
    println!("  node 3 corrupted (1 bit) -> hash mismatch detected: {:016x}", reports[3].hash);

    // repair by snapshot transfer from the primary (paper §8.1 mechanism)
    let snap = Snapshot::capture(cluster.node(0));
    let repaired = snap.restore().unwrap();
    assert_eq!(repaired.state_hash(), cluster.node(0).state_hash());
    println!("  node 3 repaired from primary snapshot: hash {:016x}", repaired.state_hash());

    // identical queries on every node return identical raw distances
    let q: Vec<f32> = (0..16).map(|j| (j as f32 * 0.1).cos() * 0.5).collect();
    let h0 = cluster.node(0).search_f32(&q, 5).unwrap();
    for i in [1usize, 2, 4] {
        assert_eq!(cluster.node(i).search_f32(&q, 5).unwrap(), h0);
    }
    println!("  identical k-NN results (ids AND raw distances) on all live nodes");
}

fn http_cluster() {
    println!("--- 3-node HTTP cluster ---");
    let make_node = || {
        let kernel = Kernel::new(KernelConfig::default_q16(8));
        let state =
            Arc::new(NodeState::new(kernel, &NodeConfig::default(), None).unwrap());
        let server = serve(Arc::clone(&state), "127.0.0.1:0", 2).unwrap();
        (state, server)
    };
    let (primary_state, primary) = make_node();
    let (_f1_state, f1) = make_node();
    let (_f2_state, f2) = make_node();

    // clients write to the primary
    for i in 0..100u64 {
        let x = i as f32 / 100.0;
        primary_state
            .apply(Command::insert(i, vec![x, 1.0 - x, x * x, 0.5, -x, 0.1, 0.0, x / 2.0]))
            .unwrap();
    }
    primary_state.apply(Command::Link { from: 1, to: 2 }).unwrap();

    // ship the log to both followers over HTTP
    let (n1, h1) = sync_follower(&primary.addr(), &f1.addr(), 0).unwrap();
    let (n2, h2) = sync_follower(&primary.addr(), &f2.addr(), 0).unwrap();
    println!("  shipped {n1} commands to follower 1, {n2} to follower 2");

    let (_, hp) = client::get_json(&primary.addr(), "/v1/hash").unwrap();
    let hp = hp.get("fnv").as_str().unwrap().to_string();
    println!("  primary hash   = {hp}");
    println!("  follower1 hash = {h1}");
    println!("  follower2 hash = {h2}");
    assert_eq!(hp, h1);
    assert_eq!(hp, h2);
    println!("  all three nodes converged (fnv64 over canonical snapshot bytes)");

    // incremental catch-up: more writes, partial sync
    for i in 100..120u64 {
        let x = i as f32 / 120.0;
        primary_state
            .apply(Command::insert(i, vec![x, -x, 0.2, 0.3, 0.1, 0.0, x, 0.5]))
            .unwrap();
    }
    let (n1b, h1b) = sync_follower(&primary.addr(), &f1.addr(), n1).unwrap();
    let (_, hp2) = client::get_json(&primary.addr(), "/v1/hash").unwrap();
    assert_eq!(n1b, 20);
    assert_eq!(hp2.get("fnv").as_str().unwrap(), h1b);
    println!("  incremental sync of {n1b} new commands: follower 1 converged again");

    primary.stop();
    f1.stop();
    f2.stop();
}
