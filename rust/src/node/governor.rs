//! Per-tenant resource governance: token-bucket rate limiting, in-flight
//! quotas/bulkheads, idle-TTL bookkeeping and snapshot-stream transfer
//! budgets.
//!
//! ## Determinism contract
//!
//! The governor lives entirely **outside** the replayable state machine.
//! Admission decisions are made at the front end — before a job is queued
//! to the dispatch pool — from a front-end-local monotonic clock
//! ([`Instant`]). Nothing the governor computes is ever appended to a WAL,
//! folded into a root hash, or echoed into a canonical log. A client that
//! is throttled with `1600 rate_limited` / `1601 quota_exceeded` and
//! retries until accepted produces **exactly** the command sequence an
//! unthrottled client would have produced, so the resulting root hash is
//! bit-identical to an ungoverned run (pinned by
//! `tests/governance.rs::throttled_retried_workload_matches_ungoverned_mirror`).
//!
//! ## Model
//!
//! Each tenant (collection name) gets one [`TenantState`]:
//!
//! * **Rate limit** — a token bucket holding *millitokens* (1 request =
//!   1000 millitokens) refilled at `rate_limit` req/s, with a burst
//!   capacity of one second's worth of tokens (min 1 request). Millitoken
//!   precision keeps `retry_after_ms` honest at low rates.
//! * **Quota / bulkhead** — one in-flight counter checked against
//!   `min(quota, bulkhead)`. The quota caps requests a tenant may have
//!   admitted concurrently; the bulkhead caps dispatch-pool workers the
//!   tenant may occupy. Both bound the same quantity at admission time
//!   (a request admitted to the front end is the request occupying a
//!   pool worker), so the tighter knob wins.
//! * **Transfer cap** — snapshot streams accrue *debt* as blocks are
//!   produced; debt decays at `stream_bytes_per_sec`. While a tenant is
//!   in debt, its [`crate::http::StreamingBody`] defers refills (the
//!   reactor re-arms its timer wheel; the blocking front end sleeps in
//!   bounded slices) — the event loop never blocks and the stream bytes
//!   are unchanged, only their pacing.
//!
//! All counters feed [`ServerMetrics`] gauges
//! (`requests_rate_limited`, `requests_quota_rejected`) surfaced by
//! `/v1/stats` and `/v2/stats`.

#![forbid(unsafe_code)]

use crate::http::ServerMetrics;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-tenant governance knobs. `None` everywhere (the default) turns the
/// governor off entirely: no admission hook is installed, no per-request
/// bookkeeping runs, and the server behaves bit-for-bit as before.
#[derive(Debug, Clone, Default)]
pub struct GovernorConfig {
    /// Sustained request rate per tenant, requests/second.
    pub rate_limit: Option<u32>,
    /// Max requests a tenant may have in flight (admitted, not yet
    /// completed).
    pub quota: Option<u32>,
    /// Max dispatch-pool workers one tenant may occupy concurrently
    /// (bulkhead isolation). Enforced jointly with `quota`: the tighter
    /// bound wins.
    pub bulkhead: Option<u32>,
    /// Evict a collection's kernel + WAL handles after this much
    /// inactivity; rehydrated lazily from `spec.json`/`restored.snap` on
    /// next touch.
    pub idle_ttl: Option<Duration>,
    /// Per-tenant snapshot-stream budget, bytes/second.
    pub stream_bytes_per_sec: Option<u64>,
}

impl GovernorConfig {
    /// Whether any knob is set. When false the manager installs no
    /// admission hook and spawns no sweeper.
    pub fn is_active(&self) -> bool {
        self.rate_limit.is_some()
            || self.quota.is_some()
            || self.bulkhead.is_some()
            || self.idle_ttl.is_some()
            || self.stream_bytes_per_sec.is_some()
    }
}

/// Millitokens granted per admitted request.
const TOKENS_PER_REQUEST: u64 = 1000;
/// Tenants with no in-flight work and no recent touch are dropped from
/// the governor map after this long (bounds memory against scans that
/// probe many bogus collection names). The idle TTL extends this if
/// longer, so rate/stream state never outlives the collection itself.
const TENANT_STATE_TTL: Duration = Duration::from_secs(60);

/// Outcome of an admission check, decided before dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Admitted; the caller must pair this with [`Governor::release`]
    /// once the request completes.
    Admit,
    /// Token bucket empty: `1600 rate_limited`, retry after roughly this
    /// many milliseconds.
    RateLimited {
        /// Milliseconds until one full request token will have refilled.
        retry_after_ms: u64,
    },
    /// In-flight cap (quota or bulkhead) reached: `1601 quota_exceeded`.
    QuotaExceeded,
}

struct TenantState {
    /// Token bucket, in millitokens.
    tokens: u64,
    last_refill: Instant,
    /// Requests admitted and not yet released.
    in_flight: u32,
    /// Last admission/touch — drives governor-map pruning.
    last_touch: Instant,
    /// Outstanding stream debt, bytes.
    stream_debt: u64,
    stream_last: Instant,
    /// Lifetime `1600 rate_limited` rejections for this tenant.
    rate_limited: u64,
    /// Lifetime `1601 quota_exceeded` rejections for this tenant.
    quota_rejected: u64,
}

impl TenantState {
    fn new(now: Instant, burst: u64) -> Self {
        Self {
            tokens: burst,
            last_refill: now,
            in_flight: 0,
            last_touch: now,
            stream_debt: 0,
            stream_last: now,
            rate_limited: 0,
            quota_rejected: 0,
        }
    }
}

/// Point-in-time view of one tenant's governor state, surfaced on
/// `GET /v2/collections/{name}/stats`. Diagnostic only — never hashed,
/// logged or replayed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantSnapshot {
    /// Whole request tokens currently available (millitokens / 1000,
    /// refilled to `now` before reading).
    pub available_tokens: u64,
    /// Requests admitted and not yet released.
    pub in_flight: u32,
    /// Lifetime `1600 rate_limited` rejections for this tenant.
    pub rate_limited: u64,
    /// Lifetime `1601 quota_exceeded` rejections for this tenant.
    pub quota_rejected: u64,
}

/// Front-end-local admission controller. One per [`CollectionManager`];
/// shared (via `Arc`) with the admission hook, the stream pacers and the
/// idle sweeper.
///
/// [`CollectionManager`]: crate::node::CollectionManager
pub struct Governor {
    config: GovernorConfig,
    tenants: Mutex<BTreeMap<String, TenantState>>,
    metrics: Arc<ServerMetrics>,
}

impl Governor {
    pub fn new(config: GovernorConfig, metrics: Arc<ServerMetrics>) -> Self {
        Self { config, tenants: Mutex::new(BTreeMap::new()), metrics }
    }

    pub fn config(&self) -> &GovernorConfig {
        &self.config
    }

    /// Burst capacity in millitokens: one second of refill, min 1 request.
    fn burst(&self) -> u64 {
        let rate = u64::from(self.config.rate_limit.unwrap_or(0)).max(1);
        rate * TOKENS_PER_REQUEST
    }

    /// The effective in-flight cap: the tighter of quota and bulkhead.
    fn in_flight_cap(&self) -> Option<u32> {
        match (self.config.quota, self.config.bulkhead) {
            (Some(q), Some(b)) => Some(q.min(b)),
            (Some(q), None) => Some(q),
            (None, Some(b)) => Some(b),
            (None, None) => None,
        }
    }

    fn refill(&self, t: &mut TenantState, now: Instant) {
        let Some(rate) = self.config.rate_limit else { return };
        let elapsed_ms = now.saturating_duration_since(t.last_refill).as_millis() as u64;
        if elapsed_ms == 0 {
            return;
        }
        // rate req/s == rate millitokens/ms.
        let refill = elapsed_ms.saturating_mul(u64::from(rate));
        t.tokens = t.tokens.saturating_add(refill).min(self.burst());
        t.last_refill = now;
    }

    /// Admission check for one request against `name`. On `Admit` the
    /// tenant's in-flight counter is incremented — the caller MUST call
    /// [`Governor::release`] when the request completes, success or not.
    pub fn admit(&self, name: &str, now: Instant) -> Admission {
        let mut tenants = self.tenants.lock().expect("governor poisoned");
        let burst = self.burst();
        let t = tenants
            .entry(name.to_string())
            .or_insert_with(|| TenantState::new(now, burst));
        t.last_touch = now;
        if let Some(rate) = self.config.rate_limit {
            self.refill(t, now);
            if t.tokens < TOKENS_PER_REQUEST {
                let deficit = TOKENS_PER_REQUEST - t.tokens;
                // deficit millitokens at `rate` millitokens/ms, rounded up.
                let retry_after_ms = deficit.div_ceil(u64::from(rate).max(1)).max(1);
                t.rate_limited += 1;
                ServerMetrics::add(&self.metrics.requests_rate_limited, 1);
                return Admission::RateLimited { retry_after_ms };
            }
        }
        if let Some(cap) = self.in_flight_cap() {
            if t.in_flight >= cap {
                t.quota_rejected += 1;
                ServerMetrics::add(&self.metrics.requests_quota_rejected, 1);
                return Admission::QuotaExceeded;
            }
        }
        if self.config.rate_limit.is_some() {
            t.tokens -= TOKENS_PER_REQUEST;
        }
        t.in_flight += 1;
        Admission::Admit
    }

    /// Pair of a successful [`Governor::admit`]; decrements in-flight.
    pub fn release(&self, name: &str) {
        let mut tenants = self.tenants.lock().expect("governor poisoned");
        if let Some(t) = tenants.get_mut(name) {
            t.in_flight = t.in_flight.saturating_sub(1);
        }
    }

    /// Record activity on `name` without an admission check (local API
    /// calls, rehydration) so the idle sweeper sees it as recently used.
    pub fn touch(&self, name: &str, now: Instant) {
        let mut tenants = self.tenants.lock().expect("governor poisoned");
        let burst = self.burst();
        let t = tenants
            .entry(name.to_string())
            .or_insert_with(|| TenantState::new(now, burst));
        t.last_touch = now;
    }

    /// How long `name` has been idle (no admissions/touches), if the
    /// governor has ever seen it. `None` for unknown tenants.
    pub fn idle_for(&self, name: &str, now: Instant) -> Option<Duration> {
        let tenants = self.tenants.lock().expect("governor poisoned");
        let t = tenants.get(name)?;
        if t.in_flight > 0 {
            return Some(Duration::ZERO);
        }
        Some(now.saturating_duration_since(t.last_touch))
    }

    /// The state an unseen tenant would start from: a full burst bucket,
    /// nothing in flight, zero rejection counters. Used by the stats
    /// route for tenants [`Governor::tenant_snapshot`] has no entry for.
    pub fn fresh_tenant_snapshot(&self) -> TenantSnapshot {
        TenantSnapshot {
            available_tokens: self.burst() / TOKENS_PER_REQUEST,
            in_flight: 0,
            rate_limited: 0,
            quota_rejected: 0,
        }
    }

    /// Read-only view of `name`'s governor state for `stats` reporting.
    /// Refills the token bucket to `now` first so `available_tokens` is
    /// honest, but records no touch (observation must not keep a tenant
    /// alive past its idle TTL). `None` for tenants the governor has
    /// never seen (or has pruned) — their bucket is at full burst and all
    /// counters are zero.
    pub fn tenant_snapshot(&self, name: &str, now: Instant) -> Option<TenantSnapshot> {
        let mut tenants = self.tenants.lock().expect("governor poisoned");
        let t = tenants.get_mut(name)?;
        if self.config.rate_limit.is_some() {
            self.refill(t, now);
        }
        Some(TenantSnapshot {
            available_tokens: t.tokens / TOKENS_PER_REQUEST,
            in_flight: t.in_flight,
            rate_limited: t.rate_limited,
            quota_rejected: t.quota_rejected,
        })
    }

    /// Charge `bytes` of snapshot-stream transfer to `name`. Debt decays
    /// at the configured bytes/sec before the charge is added.
    pub fn stream_consume(&self, name: &str, bytes: u64, now: Instant) {
        let Some(rate) = self.config.stream_bytes_per_sec else { return };
        let mut tenants = self.tenants.lock().expect("governor poisoned");
        let burst = self.burst();
        let t = tenants
            .entry(name.to_string())
            .or_insert_with(|| TenantState::new(now, burst));
        let elapsed_ms = now.saturating_duration_since(t.stream_last).as_millis() as u64;
        let paid = elapsed_ms.saturating_mul(rate) / 1000;
        t.stream_debt = t.stream_debt.saturating_sub(paid).saturating_add(bytes);
        t.stream_last = now;
        t.last_touch = now;
    }

    /// How long `name`'s stream must pause before producing its next
    /// block, or `None` when it is within budget. Consulted by
    /// [`crate::http::StreamingBody::defer_for`] before every refill.
    pub fn stream_defer(&self, name: &str, now: Instant) -> Option<Duration> {
        let rate = self.config.stream_bytes_per_sec?;
        if rate == 0 {
            return None;
        }
        let mut tenants = self.tenants.lock().expect("governor poisoned");
        let t = tenants.get_mut(name)?;
        let elapsed_ms = now.saturating_duration_since(t.stream_last).as_millis() as u64;
        let paid = elapsed_ms.saturating_mul(rate) / 1000;
        t.stream_debt = t.stream_debt.saturating_sub(paid);
        t.stream_last = now;
        if t.stream_debt == 0 {
            return None;
        }
        // debt bytes at `rate` bytes/sec, in ms, rounded up; clamped so a
        // big debt cannot park a connection for minutes.
        let wait_ms = (t.stream_debt.saturating_mul(1000)).div_ceil(rate).max(1);
        Some(Duration::from_millis(wait_ms.min(5_000)))
    }

    /// Drop per-tenant state that is idle (no in-flight work, no stream
    /// debt) past `max(idle_ttl, TENANT_STATE_TTL)` — bounds governor
    /// memory against bogus-name scans without forgetting state the idle
    /// sweeper still needs.
    pub fn prune(&self, now: Instant) {
        let ttl = self.config.idle_ttl.unwrap_or(Duration::ZERO).max(TENANT_STATE_TTL);
        let mut tenants = self.tenants.lock().expect("governor poisoned");
        tenants.retain(|_, t| {
            t.in_flight > 0
                || t.stream_debt > 0
                || now.saturating_duration_since(t.last_touch) <= ttl
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn governor(config: GovernorConfig) -> Governor {
        Governor::new(config, Arc::new(ServerMetrics::default()))
    }

    #[test]
    fn token_bucket_admits_burst_then_rate_limits_with_honest_retry() {
        let g = governor(GovernorConfig {
            rate_limit: Some(2), // burst = 2 requests
            ..Default::default()
        });
        let t0 = Instant::now();
        assert_eq!(g.admit("a", t0), Admission::Admit);
        assert_eq!(g.admit("a", t0), Admission::Admit);
        match g.admit("a", t0) {
            Admission::RateLimited { retry_after_ms } => {
                // a full token at 2 req/s (2 millitokens/ms) is 500ms away
                assert_eq!(retry_after_ms, 500);
            }
            other => panic!("expected RateLimited, got {other:?}"),
        }
        // metrics recorded the rejection
        assert_eq!(
            g.metrics.requests_rate_limited.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        // after 500ms one token has refilled
        assert_eq!(g.admit("a", t0 + Duration::from_millis(500)), Admission::Admit);
        // …and the bucket never exceeds burst even after a long sleep
        let later = t0 + Duration::from_secs(3600);
        assert_eq!(g.admit("a", later), Admission::Admit);
        assert_eq!(g.admit("a", later), Admission::Admit);
        assert!(matches!(g.admit("a", later), Admission::RateLimited { .. }));
    }

    #[test]
    fn tenants_have_independent_buckets() {
        let g = governor(GovernorConfig { rate_limit: Some(1), ..Default::default() });
        let t0 = Instant::now();
        assert_eq!(g.admit("a", t0), Admission::Admit);
        assert!(matches!(g.admit("a", t0), Admission::RateLimited { .. }));
        // tenant b is untouched by a's exhaustion
        assert_eq!(g.admit("b", t0), Admission::Admit);
    }

    #[test]
    fn in_flight_cap_is_min_of_quota_and_bulkhead_and_release_restores() {
        let g = governor(GovernorConfig {
            quota: Some(5),
            bulkhead: Some(2),
            ..Default::default()
        });
        let t0 = Instant::now();
        assert_eq!(g.admit("a", t0), Admission::Admit);
        assert_eq!(g.admit("a", t0), Admission::Admit);
        assert_eq!(g.admit("a", t0), Admission::QuotaExceeded);
        assert_eq!(
            g.metrics.requests_quota_rejected.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        g.release("a");
        assert_eq!(g.admit("a", t0), Admission::Admit);
    }

    #[test]
    fn stream_budget_defers_proportionally_to_debt() {
        let g = governor(GovernorConfig {
            stream_bytes_per_sec: Some(1000),
            ..Default::default()
        });
        let t0 = Instant::now();
        assert_eq!(g.stream_defer("a", t0), None, "no debt yet");
        g.stream_consume("a", 500, t0);
        let wait = g.stream_defer("a", t0).expect("500B debt at 1000B/s");
        assert_eq!(wait, Duration::from_millis(500));
        // after the debt has decayed the stream resumes
        assert_eq!(g.stream_defer("a", t0 + Duration::from_millis(500)), None);
    }

    #[test]
    fn prune_drops_idle_tenants_but_keeps_in_flight_ones() {
        let g = governor(GovernorConfig { quota: Some(8), ..Default::default() });
        let t0 = Instant::now();
        assert_eq!(g.admit("busy", t0), Admission::Admit);
        g.touch("idle", t0);
        g.prune(t0 + Duration::from_secs(120));
        let tenants = g.tenants.lock().unwrap();
        assert!(tenants.contains_key("busy"), "in-flight tenant must survive prune");
        assert!(!tenants.contains_key("idle"), "idle tenant should be pruned");
    }

    #[test]
    fn tenant_snapshot_tracks_tokens_in_flight_and_rejections() {
        let g = governor(GovernorConfig {
            rate_limit: Some(2),
            quota: Some(1),
            ..Default::default()
        });
        let t0 = Instant::now();
        assert_eq!(g.tenant_snapshot("a", t0), None, "unseen tenant has no state");
        assert_eq!(g.admit("a", t0), Admission::Admit);
        assert_eq!(g.admit("a", t0), Admission::QuotaExceeded);
        assert!(matches!(
            g.admit("a", t0 + Duration::from_millis(1)),
            Admission::QuotaExceeded
        ));
        let snap = g.tenant_snapshot("a", t0 + Duration::from_millis(1)).unwrap();
        assert_eq!(snap.in_flight, 1);
        assert_eq!(snap.quota_rejected, 2);
        assert_eq!(snap.rate_limited, 0);
        assert_eq!(snap.available_tokens, 1, "burst 2, one spent, refill negligible");
        g.release("a");
        // rate-limit rejections are counted per tenant too
        assert_eq!(g.admit("a", t0 + Duration::from_millis(1)), Admission::Admit);
        assert!(matches!(
            g.admit("a", t0 + Duration::from_millis(1)),
            Admission::RateLimited { .. }
        ));
        let snap = g.tenant_snapshot("a", t0 + Duration::from_millis(1)).unwrap();
        assert_eq!(snap.rate_limited, 1);
        assert_eq!(snap.available_tokens, 0);
        // snapshotting does not touch: the tenant still prunes on schedule
        g.release("a");
        g.prune(t0 + Duration::from_secs(120));
        assert_eq!(g.tenant_snapshot("a", t0 + Duration::from_secs(120)), None);
    }

    #[test]
    fn inactive_config_short_circuits() {
        assert!(!GovernorConfig::default().is_active());
        assert!(GovernorConfig { rate_limit: Some(1), ..Default::default() }.is_active());
        assert!(
            GovernorConfig { idle_ttl: Some(Duration::from_secs(1)), ..Default::default() }
                .is_active()
        );
        // no knobs: everything admits and nothing is recorded
        let g = governor(GovernorConfig::default());
        let t0 = Instant::now();
        for _ in 0..1000 {
            assert_eq!(g.admit("a", t0), Admission::Admit);
        }
    }
}
