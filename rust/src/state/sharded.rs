//! Sharded kernel: N independent [`Kernel`] state machines behind one
//! deterministic router (the ROADMAP's horizontal-scaling step).
//!
//! # Design
//!
//! **Routing.** Every external id belongs to exactly one shard:
//! `shard_of(id) = splitmix64(id) % n_shards` (see
//! [`crate::state::kernel::ShardSpec`]). The routing function is a pure
//! function of the id and the shard count — no directory, no coordination,
//! and any two nodes with the same `n_shards` agree on placement forever.
//! splitmix64 gives avalanche-quality dispersion, so sequential client ids
//! spread evenly instead of hot-spotting one shard.
//!
//! **Determinism.** Each shard is a full [`Kernel`]: a pure state machine
//! whose state is a function of its own command subsequence. Because
//! routing is deterministic, the global command sequence induces one
//! deterministic subsequence per shard, so per-shard states — and their
//! snapshot bytes and hashes — are replayable exactly like the single
//! kernel (paper §3.1, applied per partition).
//!
//! **Search fan-out and bit-exact merge.** A query fans out to every shard
//! (scoped threads above a corpus-size threshold, inline below it); each
//! shard returns its top-k ordered by
//! `(dist_raw, id)`. Results are collected *in shard order* (never in
//! completion order) and combined by a k-way merge on the same
//! `(dist_raw, id)` key. The merge is therefore a pure function of the
//! per-shard result lists: thread scheduling cannot influence the output,
//! and with an exact (flat) index the merged top-k is bit-identical to a
//! single kernel holding all vectors (integer distances are exact and ids
//! are unique, so the total order has no ties to resolve
//! nondeterministically).
//!
//! **Cross-shard links.** A link `from → to` lives on the shard that owns
//! `from`. The router checks `to` globally before logging the command;
//! per-shard replay then accepts remote `to` ids without a local check
//! (checked-once-upstream, like boundary validation). Deleting an id emits
//! explicit `Unlink` commands to the other shards that point at it, so the
//! no-dangling-links invariant survives sharding *and* stays in the
//! per-shard logs (replay-pure; no hidden side effects).
//!
//! **Root-hash manifest.** Convergence checks compare per-shard FNV state
//! hashes plus a combined root: `root = fnv(n_shards ‖ h_0 ‖ … ‖ h_{n-1})`.
//! Two sharded nodes verify shard-by-shard (pinpointing a diverged shard)
//! and summarize with one root value (paper §8.1's `H_A ≡ H_B`, lifted to
//! the sharded deployment). [`crate::snapshot::ShardedSnapshot`] persists
//! the same manifest with audit-grade SHA-256 digests per shard.

use crate::hash::Fnv1a64;
use crate::state::command::{CanonCommand, Command};
use crate::state::kernel::{Hit, Kernel, KernelConfig, StateError};
use crate::vector::FixedVector;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One per-shard log record produced by a routed application: `command`
/// was applied on `shard` at that shard's local sequence number `seq`.
/// This is exactly what the node appends to shard `shard`'s WAL.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Routed {
    pub shard: u32,
    /// The shard's logical clock *before* the command applied (i.e. the
    /// command moved the shard from `seq` to `seq + 1`).
    pub seq: u64,
    pub command: CanonCommand,
}

/// Result of applying one external command through the router.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardApply {
    /// The canonical form of the submitted command (what a single-kernel
    /// deployment would log).
    pub canon: CanonCommand,
    /// The per-shard records actually applied, in deterministic order.
    /// Usually one; an `InsertBatch` yields one per participating shard,
    /// and a `Delete` may add cross-shard `Unlink` cleanup records.
    pub applied: Vec<Routed>,
}

/// N independent kernels behind a deterministic router. See the module
/// docs for the design; the unsharded reference contract is `n_shards = 1`,
/// where every operation degenerates to the plain [`Kernel`] behaviour.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedKernel {
    shards: Vec<Kernel>,
}

impl ShardedKernel {
    /// Build `n_shards` empty kernels from a base config (the base's own
    /// shard spec is overwritten per shard).
    pub fn new(base: KernelConfig, n_shards: u32) -> Self {
        assert!(n_shards >= 1, "need at least one shard");
        let shards = (0..n_shards)
            .map(|s| Kernel::new(base.clone().with_shard(n_shards, s)))
            .collect();
        Self { shards }
    }

    /// Wrap an existing unsharded kernel as a 1-shard deployment
    /// (bit-compatible with its previous behaviour).
    pub fn from_single(kernel: Kernel) -> Self {
        assert_eq!(
            kernel.config().shard.n_shards,
            1,
            "from_single requires an unsharded kernel config"
        );
        Self { shards: vec![kernel] }
    }

    /// Rebuild from already-sharded kernels (snapshot restore). Shard
    /// specs must form a consistent deployment.
    pub fn from_shards(shards: Vec<Kernel>) -> Self {
        assert!(!shards.is_empty(), "need at least one shard");
        let n = shards.len() as u32;
        for (i, k) in shards.iter().enumerate() {
            assert_eq!(k.config().shard.n_shards, n, "shard {i}: wrong n_shards");
            assert_eq!(k.config().shard.shard_id, i as u32, "shard {i}: wrong shard_id");
        }
        Self { shards }
    }

    pub fn n_shards(&self) -> u32 {
        self.shards.len() as u32
    }

    /// The shard an external id routes to.
    pub fn shard_of(&self, id: u64) -> u32 {
        self.shards[0].config().shard.shard_of(id)
    }

    /// Read access to one shard's kernel.
    pub fn shard(&self, i: u32) -> &Kernel {
        &self.shards[i as usize]
    }

    pub fn shards(&self) -> &[Kernel] {
        &self.shards
    }

    /// The deployment config (shard 0's view; all shards share everything
    /// but `shard.shard_id`).
    pub fn config(&self) -> &KernelConfig {
        self.shards[0].config()
    }

    /// Total live vectors across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(Kernel::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total applied commands across shards. Note: under `n_shards > 1`
    /// this counts per-shard records (a batch splits; a delete may add
    /// cleanup unlinks), so it is the sum of shard clocks, not the count
    /// of client submissions.
    pub fn seq(&self) -> u64 {
        self.shards.iter().map(Kernel::seq).sum()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.owner(id).contains(id)
    }

    pub fn get_raw(&self, id: u64) -> Option<&[i32]> {
        self.owner(id).get_raw(id)
    }

    pub fn get_f32(&self, id: u64) -> Option<Vec<f32>> {
        self.owner(id).get_f32(id)
    }

    pub fn meta_of(&self, id: u64) -> Option<&std::collections::BTreeMap<String, String>> {
        self.owner(id).meta_of(id)
    }

    /// Whether the directed link exists (links live on `from`'s shard).
    pub fn has_link(&self, from: u64, to: u64) -> bool {
        self.owner(from).links().has_link(from, to)
    }

    fn owner(&self, id: u64) -> &Kernel {
        &self.shards[self.shard_of(id) as usize]
    }

    /// Boundary + routed transition: validate/canonicalize the external
    /// command, route it, and return both the canonical command and the
    /// per-shard records (for per-shard WAL/replication logs).
    pub fn apply(&mut self, cmd: Command) -> Result<ShardApply, StateError> {
        let canon = self.shards[0].canonicalize(cmd)?;
        let applied = self.apply_canon(&canon)?;
        Ok(ShardApply { canon, applied })
    }

    /// Route an already-canonical command (replication ingest). Atomic:
    /// every failure mode is checked before any shard mutates, so an error
    /// leaves all shards untouched.
    pub fn apply_canon(&mut self, canon: &CanonCommand) -> Result<Vec<Routed>, StateError> {
        match canon {
            CanonCommand::Insert { id, .. } => {
                let s = self.shard_of(*id);
                self.route(s, canon.clone())
            }
            CanonCommand::InsertBatch { items } => self.apply_batch(items),
            CanonCommand::Delete { id } => self.apply_delete(*id),
            CanonCommand::Link { from, to } => {
                // Global precondition (single-kernel parity, same error
                // order): both endpoints must be live somewhere.
                if !self.contains(*from) {
                    return Err(StateError::UnknownId(*from));
                }
                if !self.contains(*to) {
                    return Err(StateError::UnknownId(*to));
                }
                let s = self.shard_of(*from);
                self.route(s, canon.clone())
            }
            CanonCommand::Unlink { from, .. } => {
                let s = self.shard_of(*from);
                self.route(s, canon.clone())
            }
            CanonCommand::SetMeta { id, .. } => {
                let s = self.shard_of(*id);
                self.route(s, canon.clone())
            }
        }
    }

    /// Apply a command directly to one shard, bypassing the router — the
    /// per-shard WAL replay / log-shipping ingest path. The shard's own
    /// `WrongShard` check still rejects misrouted records.
    pub fn apply_canon_to_shard(
        &mut self,
        shard: u32,
        canon: &CanonCommand,
    ) -> Result<(), StateError> {
        self.shards[shard as usize].apply_canon(canon)
    }

    fn route(&mut self, shard: u32, command: CanonCommand) -> Result<Vec<Routed>, StateError> {
        let kernel = &mut self.shards[shard as usize];
        let seq = kernel.seq();
        kernel.apply_canon(&command)?;
        Ok(vec![Routed { shard, seq, command }])
    }

    /// Split a canonical (ascending-id) batch by shard and apply the
    /// sub-batches. Pre-validates every item on its target shard first so
    /// the whole batch is atomic across shards.
    fn apply_batch(&mut self, items: &[(u64, Vec<i32>)]) -> Result<Vec<Routed>, StateError> {
        if items.is_empty() || self.shards.len() == 1 {
            // Single-shard deployments (and the degenerate empty batch)
            // keep exact single-kernel semantics: one atomic record.
            return self.route(0, CanonCommand::InsertBatch { items: items.to_vec() });
        }
        // Pre-validate in *batch order* — the same checks, in the same
        // order, a single kernel runs — so the selected error is identical
        // to the unsharded reference, and no shard mutates on rejection.
        for w in items.windows(2) {
            if w[0].0 >= w[1].0 {
                return Err(StateError::DuplicateId(w[1].0));
            }
        }
        let config = self.shards[0].config();
        for (id, raw) in items {
            config.policy.validate_raw(raw, config.dim)?;
            if self.shards[self.shard_of(*id) as usize].ever_contains(*id) {
                return Err(StateError::DuplicateId(*id));
            }
        }
        let n = self.shards.len();
        let mut per_shard: Vec<Vec<(u64, Vec<i32>)>> = vec![Vec::new(); n];
        for (id, raw) in items {
            // Splitting a sorted batch preserves per-shard sortedness.
            per_shard[self.shard_of(*id) as usize].push((*id, raw.clone()));
        }
        let mut applied = Vec::new();
        for (s, sub) in per_shard.into_iter().enumerate() {
            if sub.is_empty() {
                continue;
            }
            // Cannot fail: exactly the checks above, re-run by the kernel.
            applied.extend(self.route(s as u32, CanonCommand::InsertBatch { items: sub })?);
        }
        Ok(applied)
    }

    /// Delete an id, emitting explicit cross-shard `Unlink` cleanup for
    /// edges on other shards that point at it (deterministic order: shard
    /// index, then ascending `from` id).
    fn apply_delete(&mut self, id: u64) -> Result<Vec<Routed>, StateError> {
        let owner = self.shard_of(id);
        if !self.shards[owner as usize].contains(id) {
            return Err(StateError::UnknownId(id));
        }
        let mut applied = Vec::new();
        for s in 0..self.shards.len() as u32 {
            if s == owner {
                continue; // the owner's remove_node cleans local edges
            }
            for from in self.shards[s as usize].links().links_to(id) {
                applied.extend(self.route(s, CanonCommand::Unlink { from, to: id })?);
            }
        }
        applied.extend(self.route(owner, CanonCommand::Delete { id })?);
        Ok(applied)
    }

    /// Below this many live vectors the per-shard searches run on the
    /// calling thread: spawning OS threads costs more than the scans they
    /// would parallelize. The merge is a pure function of the per-shard
    /// results either way, so the threshold cannot affect results — only
    /// latency. (A persistent worker pool is a ROADMAP follow-on.)
    const PARALLEL_SEARCH_MIN_VECTORS: usize = 4096;

    /// k-NN over raw quantized values: fan out to every shard (scoped
    /// threads for large corpora, inline for small ones) and merge.
    /// Bit-identical to a single kernel holding all vectors when the index
    /// is exact; always identical across runs and platforms regardless of
    /// thread scheduling (results are collected in shard order and merged
    /// by the total order `(dist_raw, id)`).
    pub fn search_raw(&self, query: &[i32], k: usize) -> Result<Vec<Hit>, StateError> {
        if self.shards.len() == 1 {
            return self.shards[0].search_raw(query, k);
        }
        // Validate once up front (all shards share the contract) so the
        // fan-out below cannot fail per-shard.
        let config = self.shards[0].config();
        if query.len() != config.dim {
            return Err(StateError::DimMismatch { expected: config.dim, got: query.len() });
        }
        config.policy.validate_raw(query, config.dim)?;
        let per_shard: Vec<Vec<Hit>> = if self.len() < Self::PARALLEL_SEARCH_MIN_VECTORS {
            self.shards
                .iter()
                .map(|shard| shard.search_raw(query, k))
                .collect::<Result<Vec<_>, StateError>>()?
        } else {
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .shards
                    .iter()
                    .map(|shard| scope.spawn(move || shard.search_raw(query, k)))
                    .collect();
                // Join in shard order: reassembly is deterministic no
                // matter which thread finishes first.
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard search thread panicked"))
                    .collect::<Result<Vec<_>, StateError>>()
            })?
        };
        Ok(merge_hits(&per_shard, k))
    }

    /// k-NN over a float query (same boundary as inserts, then integer
    /// search — see [`Kernel::search_f32`]).
    pub fn search_f32(&self, query: &[f32], k: usize) -> Result<Vec<Hit>, StateError> {
        let config = self.shards[0].config();
        let fv = FixedVector::from_f32(query, config.dim, &config.policy)?;
        self.search_raw(fv.raw(), k)
    }

    /// Per-shard FNV state hashes (the manifest replicas compare
    /// shard-by-shard to pinpoint divergence).
    pub fn shard_hashes(&self) -> Vec<u64> {
        self.shards.iter().map(Kernel::state_hash).collect()
    }

    /// Combined root hash: `fnv(n_shards ‖ h_0 ‖ … ‖ h_{n-1})`. A pure
    /// function of the per-shard hashes, so two nodes that agree on every
    /// shard agree on the root, and any single-shard divergence flips it.
    pub fn root_hash(&self) -> u64 {
        root_hash_of(&self.shard_hashes())
    }
}

/// Root hash over an ordered list of per-shard state hashes (exposed so
/// snapshot manifests and remote verification can recompute it without a
/// kernel).
pub fn root_hash_of(shard_hashes: &[u64]) -> u64 {
    let mut h = Fnv1a64::new();
    h.update_u32(shard_hashes.len() as u32);
    for &hash in shard_hashes {
        h.update_u64(hash);
    }
    h.finish()
}

/// Deterministic k-way merge of per-shard hit lists (each already ordered
/// by `(dist_raw, id)`) into the global top-k under the same total order.
fn merge_hits(per_shard: &[Vec<Hit>], k: usize) -> Vec<Hit> {
    let mut heap: BinaryHeap<Reverse<(i64, u64, usize)>> = BinaryHeap::new();
    let mut cursors = vec![0usize; per_shard.len()];
    for (s, hits) in per_shard.iter().enumerate() {
        if let Some(h) = hits.first() {
            heap.push(Reverse((h.dist_raw, h.id, s)));
        }
    }
    let mut out = Vec::with_capacity(k.min(per_shard.iter().map(Vec::len).sum()));
    while out.len() < k {
        let Some(Reverse((_, _, s))) = heap.pop() else { break };
        let i = cursors[s];
        out.push(per_shard[s][i]);
        cursors[s] = i + 1;
        if let Some(h) = per_shard[s].get(i + 1) {
            heap.push(Reverse((h.dist_raw, h.id, s)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_config(dim: usize) -> KernelConfig {
        KernelConfig::default_q16(dim).with_flat_index()
    }

    fn vecs(n: u64, dim: usize) -> Vec<(u64, Vec<f32>)> {
        (0..n)
            .map(|i| {
                let v: Vec<f32> = (0..dim)
                    .map(|j| ((i * dim as u64 + j as u64) as f32 * 0.113).sin() * 0.8)
                    .collect();
                (i, v)
            })
            .collect()
    }

    #[test]
    fn routing_is_total_and_stable() {
        let sk = ShardedKernel::new(flat_config(4), 4);
        for id in 0..1000u64 {
            let s = sk.shard_of(id);
            assert!(s < 4);
            assert_eq!(s, sk.shard_of(id), "routing must be a pure function");
        }
        // splitmix64 disperses: every shard owns a decent share
        let mut counts = [0usize; 4];
        for id in 0..1000u64 {
            counts[sk.shard_of(id) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 150), "skewed routing: {counts:?}");
    }

    #[test]
    fn sharded_search_matches_single_kernel_exactly() {
        for n_shards in [1u32, 2, 4, 8] {
            let mut single = Kernel::new(flat_config(8));
            let mut sharded = ShardedKernel::new(flat_config(8), n_shards);
            for (id, v) in vecs(200, 8) {
                single.apply(Command::insert(id, v.clone())).unwrap();
                sharded.apply(Command::insert(id, v)).unwrap();
            }
            for t in 0..20 {
                let q: Vec<f32> =
                    (0..8).map(|j| ((t * 8 + j) as f32 * 0.07).cos() * 0.7).collect();
                assert_eq!(
                    sharded.search_f32(&q, 10).unwrap(),
                    single.search_f32(&q, 10).unwrap(),
                    "n_shards={n_shards} query {t}"
                );
            }
        }
    }

    #[test]
    fn merge_is_pure_function_of_shard_results() {
        let a = vec![
            Hit { id: 1, dist_raw: 5, dist: 0.0 },
            Hit { id: 9, dist_raw: 20, dist: 0.0 },
        ];
        let b = vec![
            Hit { id: 2, dist_raw: 5, dist: 0.0 },
            Hit { id: 3, dist_raw: 7, dist: 0.0 },
        ];
        let merged = merge_hits(&[a.clone(), b.clone()], 3);
        // ties on dist_raw resolve by id: 1 before 2
        assert_eq!(merged.iter().map(|h| h.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        // k larger than total yields everything, still ordered
        let all = merge_hits(&[a, b], 10);
        assert_eq!(all.iter().map(|h| h.id).collect::<Vec<_>>(), vec![1, 2, 3, 9]);
        assert!(merge_hits(&[], 5).is_empty());
    }

    #[test]
    fn batch_splits_and_stays_atomic_across_shards() {
        let mut sk = ShardedKernel::new(flat_config(2), 4);
        let items: Vec<(u64, Vec<f32>)> =
            (0..40).map(|i| (i, vec![i as f32 * 0.01, 0.5])).collect();
        let result = sk.apply(Command::InsertBatch { items }).unwrap();
        assert!(result.applied.len() > 1, "40 ids should hit several shards");
        assert_eq!(sk.len(), 40);

        // one duplicate poisons the whole batch on every shard
        let hashes_before = sk.shard_hashes();
        let err = sk
            .apply(Command::InsertBatch {
                items: vec![(100, vec![0.0, 0.0]), (7, vec![0.0, 0.0])],
            })
            .unwrap_err();
        assert_eq!(err, StateError::DuplicateId(7));
        assert_eq!(sk.shard_hashes(), hashes_before, "failed batch must not touch any shard");
        assert!(!sk.contains(100));
    }

    #[test]
    fn cross_shard_links_and_delete_cleanup() {
        let mut sk = ShardedKernel::new(flat_config(2), 4);
        // find two ids on different shards
        let a = 0u64;
        let b = (1..64).find(|&i| sk.shard_of(i) != sk.shard_of(a)).unwrap();
        sk.apply(Command::insert(a, vec![0.1, 0.2])).unwrap();
        sk.apply(Command::insert(b, vec![0.3, 0.4])).unwrap();
        sk.apply(Command::Link { from: a, to: b }).unwrap();
        assert!(sk.has_link(a, b));

        // linking to a dead id fails with single-kernel error semantics
        let err = sk.apply(Command::Link { from: a, to: 9999 }).unwrap_err();
        assert_eq!(err, StateError::UnknownId(9999));

        // deleting b emits an unlink on a's shard before the delete
        let result = sk.apply(Command::Delete { id: b }).unwrap();
        let kinds: Vec<&str> = result.applied.iter().map(|r| r.command.name()).collect();
        assert_eq!(kinds, vec!["unlink", "delete"]);
        assert!(!sk.has_link(a, b), "dangling link must be cleaned up");
        assert!(!sk.contains(b));
    }

    #[test]
    fn replaying_per_shard_logs_reproduces_root_hash() {
        let mut sk = ShardedKernel::new(flat_config(4), 4);
        let mut logs: Vec<Vec<CanonCommand>> = vec![Vec::new(); 4];
        for (id, v) in vecs(120, 4) {
            let r = sk.apply(Command::insert(id, v)).unwrap();
            for routed in r.applied {
                logs[routed.shard as usize].push(routed.command);
            }
        }
        for id in [3u64, 17, 40] {
            let r = sk.apply(Command::Delete { id }).unwrap();
            for routed in r.applied {
                logs[routed.shard as usize].push(routed.command);
            }
        }
        let mut replayed = ShardedKernel::new(flat_config(4), 4);
        for (s, log) in logs.iter().enumerate() {
            for cmd in log {
                replayed.apply_canon_to_shard(s as u32, cmd).unwrap();
            }
        }
        assert_eq!(replayed.shard_hashes(), sk.shard_hashes());
        assert_eq!(replayed.root_hash(), sk.root_hash());
        assert_eq!(replayed, sk);
    }

    #[test]
    fn misrouted_log_entry_is_rejected() {
        let mut sk = ShardedKernel::new(flat_config(2), 4);
        let id = 5u64;
        let wrong = (sk.shard_of(id) + 1) % 4;
        let canon = CanonCommand::Insert { id, raw: vec![100, 200] };
        let err = sk.apply_canon_to_shard(wrong, &canon).unwrap_err();
        assert!(matches!(err, StateError::WrongShard { .. }), "got {err:?}");
    }

    #[test]
    fn root_hash_covers_every_shard() {
        let mut a = ShardedKernel::new(flat_config(2), 4);
        let mut b = ShardedKernel::new(flat_config(2), 4);
        for (id, v) in vecs(60, 2) {
            a.apply(Command::insert(id, v.clone())).unwrap();
            b.apply(Command::insert(id, v)).unwrap();
        }
        assert_eq!(a.root_hash(), b.root_hash());
        // perturb one shard only
        let id = (0..u64::MAX).find(|&i| !b.contains(i) && b.shard_of(i) == 2).unwrap();
        b.apply(Command::insert(id, vec![0.9, 0.9])).unwrap();
        assert_ne!(a.root_hash(), b.root_hash());
        let (ha, hb) = (a.shard_hashes(), b.shard_hashes());
        let diverged: Vec<usize> =
            (0..4).filter(|&s| ha[s] != hb[s]).collect();
        assert_eq!(diverged, vec![2], "manifest must pinpoint the diverged shard");
    }

    #[test]
    fn single_shard_matches_plain_kernel_bit_for_bit() {
        let mut plain = Kernel::new(KernelConfig::default_q16(4));
        let mut sk = ShardedKernel::new(KernelConfig::default_q16(4), 1);
        for (id, v) in vecs(50, 4) {
            plain.apply(Command::insert(id, v.clone())).unwrap();
            sk.apply(Command::insert(id, v)).unwrap();
        }
        plain.apply(Command::Delete { id: 7 }).unwrap();
        sk.apply(Command::Delete { id: 7 }).unwrap();
        assert_eq!(sk.shard(0).state_hash(), plain.state_hash());
        assert_eq!(sk.shard(0).to_state_bytes(), plain.to_state_bytes());
    }
}
