"""AOT lowering smoke tests: every artifact lowers to plausible HLO text.

(The full HLO -> PJRT -> execute path is validated on the Rust side by
rust/tests/cross_impl.rs; here we check lowering succeeds and the manifest
matches the weight binaries.)
"""

import json
import os

import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    def test_quantize_lowers(self):
        text = aot.lower_quantize()
        assert "HloModule" in text
        assert "s32" in text  # integer output

    def test_distance_l2_lowers(self):
        text = aot.lower_distance("l2")
        assert "HloModule" in text
        assert "s64" in text  # i64 accumulators survived lowering

    def test_distance_dot_lowers(self):
        text = aot.lower_distance("dot")
        assert "HloModule" in text
        assert "s64" in text

    def test_distance_f32_lowers(self):
        text = aot.lower_distance_f32()
        assert "HloModule" in text

    def test_embedder_lowers_both_envs(self):
        ta = aot.lower_embedder("a")
        tb = aot.lower_embedder("b")
        assert "HloModule" in ta and "HloModule" in tb
        # weights are parameters, not constants: 16 weight params + ids
        assert ta.count("parameter(") >= 17
        # the two envs lower to different programs
        assert ta != tb


class TestWeightExport:
    def test_manifest_matches_binaries(self, tmp_path):
        manifest = aot.export_weights(str(tmp_path))
        assert [p["name"] for p in manifest["params"]] == list(model.Weights._fields)
        w = model.init_weights(0)
        for p, arr in zip(manifest["params"], w):
            path = tmp_path / "weights" / f"{p['name']}.bin"
            data = np.fromfile(path, dtype="<f4")
            assert data.size == int(np.prod(p["shape"]))
            np.testing.assert_array_equal(
                data.reshape(p["shape"]), np.asarray(arr, dtype=np.float32)
            )
        # constants block present and coherent
        m = manifest["model"]
        assert m["d_model"] == model.D_MODEL
        assert m["batch"] == model.BATCH
        # manifest.json written
        with open(tmp_path / "manifest.json") as f:
            on_disk = json.load(f)
        assert on_disk == manifest

    def test_export_is_deterministic(self, tmp_path):
        d1, d2 = tmp_path / "a", tmp_path / "b"
        aot.export_weights(str(d1))
        aot.export_weights(str(d2))
        for name in model.Weights._fields:
            b1 = (d1 / "weights" / f"{name}.bin").read_bytes()
            b2 = (d2 / "weights" / f"{name}.bin").read_bytes()
            assert b1 == b2
