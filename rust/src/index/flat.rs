//! Exact (brute-force) index.
//!
//! Ground truth for the HNSW consistency tests and the recall experiments
//! (Table 3 computes Recall@k against exact top-k), and a perfectly usable
//! index in its own right for small collections. Determinism is trivial:
//! one pass in slot order, sort by `(dist, id)`.

use super::store::VecStore;
use super::topk::TopK;
use super::{Hit, VectorIndex};
use crate::codec::{DecodeError, Decoder, Encoder};
use crate::distance::{Metric, Scalar};

/// Rows scored per blocked-kernel call in [`FlatIndex::search`]. Large
/// enough to amortize the call and fill the vector units, small enough
/// that the distance buffer stays in L1. Has no effect on results — the
/// block kernels are exact per row and the top-k order ignores push order.
const SCORE_BLOCK: usize = 64;

/// Brute-force exact index over a [`VecStore`].
#[derive(Debug, Clone, PartialEq)]
pub struct FlatIndex<S: Scalar> {
    metric: Metric,
    store: VecStore<S>,
}

impl<S: Scalar> FlatIndex<S> {
    pub fn new(dim: usize, metric: Metric) -> Self {
        Self { metric, store: VecStore::new(dim) }
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    pub fn store(&self) -> &VecStore<S> {
        &self.store
    }

    pub fn encode(&self, e: &mut Encoder) {
        e.put_u8(self.metric.tag());
        self.store.encode(e);
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let tag = d.get_u8()?;
        let metric = Metric::from_tag(tag)
            .ok_or(DecodeError::InvalidTag { what: "metric", tag: tag as u64 })?;
        let store = VecStore::decode(d)?;
        Ok(Self { metric, store })
    }
}

impl<S: Scalar> VectorIndex<S> for FlatIndex<S> {
    fn insert(&mut self, id: u64, vector: Vec<S>) {
        self.store.insert(id, vector);
    }

    fn delete(&mut self, id: u64) -> bool {
        self.store.delete(id).is_some()
    }

    fn search(&self, query: &[S], k: usize) -> Vec<Hit<S::Dist>> {
        let dim = self.store.dim();
        // The one boundary this path has: every stored row is dim-checked
        // on insert, so this assert discharges the distance kernels'
        // equal-length contract for direct index users too (the state
        // machine validates before it ever gets here). Once per query,
        // never in the hot loop — and it fails loudly instead of the old
        // silent `min()` truncation.
        assert_eq!(query.len(), dim, "query dimension mismatch: {} != {dim}", query.len());
        let slots = self.store.slots();
        if k == 0 || self.store.live_len() == 0 {
            return Vec::new();
        }
        // Total order on (dist, id) throughout: deterministic ranking even
        // with distance ties, and identical to the former sort + truncate.
        let mut topk = TopK::new(k);
        if dim == 0 {
            // Degenerate dimension: fall back to the per-row path (the
            // block kernels require dim > 0 to form rows).
            for (_, id, v) in self.store.iter_live() {
                topk.push(S::distance(self.metric, query, v), id);
            }
            return topk.into_sorted_hits();
        }
        let arena = self.store.arena();
        let alive = self.store.alive_flags();
        let ids = self.store.external_ids();
        let mut dists = vec![S::max_dist(); SCORE_BLOCK.min(slots)];
        let mut base = 0usize;
        while base < slots {
            let rows = SCORE_BLOCK.min(slots - base);
            // One contiguous arena run per call: tombstoned rows are scored
            // too (branch-free sweep) and filtered below — cheaper than
            // fragmenting the block, and invisible in the results.
            let block = &arena[base * dim..(base + rows) * dim];
            S::distance_block(self.metric, query, block, dim, &mut dists[..rows]);
            for (r, &d) in dists[..rows].iter().enumerate() {
                let slot = base + r;
                if alive[slot] {
                    topk.push(d, ids[slot]);
                }
            }
            base += rows;
        }
        topk.into_sorted_hits()
    }

    fn len(&self) -> usize {
        self.store.live_len()
    }

    fn get(&self, id: u64) -> Option<&[S]> {
        self.store.get(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{FixedFormat, Q16_16};

    fn q(x: f64) -> i32 {
        Q16_16::quantize(x)
    }

    fn build() -> FlatIndex<i32> {
        let mut idx = FlatIndex::new(2, Metric::L2);
        idx.insert(1, vec![q(0.0), q(0.0)]);
        idx.insert(2, vec![q(1.0), q(0.0)]);
        idx.insert(3, vec![q(0.0), q(2.0)]);
        idx
    }

    #[test]
    fn search_orders_by_distance() {
        let idx = build();
        let hits = idx.search(&[q(0.1), q(0.0)], 3);
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn search_k_truncates() {
        let idx = build();
        assert_eq!(idx.search(&[q(0.0), q(0.0)], 2).len(), 2);
        assert_eq!(idx.search(&[q(0.0), q(0.0)], 10).len(), 3);
        assert!(idx.search(&[q(0.0), q(0.0)], 0).is_empty());
    }

    #[test]
    fn delete_excludes_from_results() {
        let mut idx = build();
        assert!(idx.delete(1));
        assert!(!idx.delete(1));
        let hits = idx.search(&[q(0.0), q(0.0)], 3);
        assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn ties_break_by_id() {
        let mut idx = FlatIndex::new(1, Metric::L2);
        idx.insert(7, vec![q(1.0)]);
        idx.insert(3, vec![q(1.0)]); // identical vector, smaller id
        let hits = idx.search(&[q(1.0)], 2);
        assert_eq!(hits[0].id, 3);
        assert_eq!(hits[1].id, 7);
        assert_eq!(hits[0].dist, hits[1].dist);
    }

    #[test]
    fn inner_product_prefers_aligned() {
        let mut idx = FlatIndex::new(2, Metric::InnerProduct);
        idx.insert(1, vec![q(1.0), q(0.0)]);
        idx.insert(2, vec![q(-1.0), q(0.0)]);
        let hits = idx.search(&[q(1.0), q(0.0)], 2);
        assert_eq!(hits[0].id, 1);
    }

    #[test]
    fn roundtrip_preserves_results() {
        let mut idx = build();
        idx.delete(2);
        let mut e = Encoder::new();
        idx.encode(&mut e);
        let bytes = e.into_vec();
        let idx2 = FlatIndex::<i32>::decode(&mut Decoder::new(&bytes)).unwrap();
        let q0 = [q(0.3), q(0.3)];
        assert_eq!(idx.search(&q0, 5), idx2.search(&q0, 5));
    }

    #[test]
    fn f32_baseline_works() {
        let mut idx: FlatIndex<f32> = FlatIndex::new(2, Metric::L2);
        idx.insert(1, vec![0.0, 0.0]);
        idx.insert(2, vec![1.0, 1.0]);
        let hits = idx.search(&[0.9, 0.9], 2);
        assert_eq!(hits[0].id, 2);
    }
}
