//! E3 — Table 3: Recall@10 of the Q16.16 deterministic index vs the f32
//! baseline.
//!
//! Paper protocol (§8.3): build two indices with *identical* insertion
//! order and HNSW parameters — one f32, one Q16.16 — and measure the
//! Top-10 overlap per query. Our generic HNSW makes the control exact:
//! both indices are instantiations of the same code, so any difference is
//! numeric representation alone. We additionally report both indices'
//! recall against exact (flat) ground truth, which the paper omits.

#![forbid(unsafe_code)]

use crate::distance::Metric;
use crate::experiments::{recall_overlap, synthetic_embeddings};
use crate::fixed::{FixedFormat, Q16_16};
use crate::index::{FlatIndex, Hnsw, HnswParams, VectorIndex};
use crate::runtime::{artifacts_available, artifacts_dir, embedder::Env, Embedder, Engine};

/// Result of the recall experiment.
#[derive(Debug, Clone)]
pub struct RecallResult {
    pub n_docs: usize,
    pub n_queries: usize,
    pub k: usize,
    /// Table 3 row 1: f32 HNSW vs itself (tautologically 1.0, kept for the
    /// paper's table shape).
    pub recall_f32: f64,
    /// Table 3 row 2: Q16.16 HNSW overlap with the f32 HNSW baseline.
    pub recall_q16_vs_f32: f64,
    /// Extra: f32 HNSW vs exact flat ground truth.
    pub recall_f32_vs_exact: f64,
    /// Extra: Q16.16 HNSW vs exact flat ground truth.
    pub recall_q16_vs_exact: f64,
    pub source: &'static str,
}

/// Build the four indices and measure overlap.
pub fn run_with_embeddings(
    embeddings: &[Vec<f32>],
    queries: &[Vec<f32>],
    k: usize,
    source: &'static str,
) -> RecallResult {
    let dim = embeddings[0].len();
    let params = HnswParams::default();
    let metric = Metric::L2;

    let mut h_f32: Hnsw<f32> = Hnsw::new(dim, metric, params);
    let mut h_q16: Hnsw<i32> = Hnsw::new(dim, metric, params);
    let mut flat_f32: FlatIndex<f32> = FlatIndex::new(dim, metric);

    // identical insertion order — the paper's stated control
    for (id, v) in embeddings.iter().enumerate() {
        let raw: Vec<i32> = v.iter().map(|&x| Q16_16::quantize(x as f64)).collect();
        h_f32.insert(id as u64, v.clone());
        h_q16.insert(id as u64, raw);
        flat_f32.insert(id as u64, v.clone());
    }

    let (mut sum_q16_f32, mut sum_f32_exact, mut sum_q16_exact) = (0.0, 0.0, 0.0);
    for q in queries {
        let raw_q: Vec<i32> = q.iter().map(|&x| Q16_16::quantize(x as f64)).collect();
        let ids_f32: Vec<u64> = h_f32.search(q, k).iter().map(|h| h.id).collect();
        let ids_q16: Vec<u64> = h_q16.search(&raw_q, k).iter().map(|h| h.id).collect();
        let ids_exact: Vec<u64> = flat_f32.search(q, k).iter().map(|h| h.id).collect();
        sum_q16_f32 += recall_overlap(&ids_f32, &ids_q16);
        sum_f32_exact += recall_overlap(&ids_exact, &ids_f32);
        sum_q16_exact += recall_overlap(&ids_exact, &ids_q16);
    }
    let nq = queries.len() as f64;
    RecallResult {
        n_docs: embeddings.len(),
        n_queries: queries.len(),
        k,
        recall_f32: 1.0,
        recall_q16_vs_f32: sum_q16_f32 / nq,
        recall_f32_vs_exact: sum_f32_exact / nq,
        recall_q16_vs_exact: sum_q16_exact / nq,
        source,
    }
}

/// Run on real AOT-embedder embeddings over the synthetic corpus.
pub fn run_embedder(n_docs: usize, n_queries: usize, k: usize) -> crate::Result<RecallResult> {
    use crate::corpus::CorpusGen;
    let engine = Engine::cpu()?;
    let embedder = Embedder::load(&engine, artifacts_dir(), Env::A)?;
    let mut gen = CorpusGen::new(7);
    let docs = gen.docs(n_docs);
    let mut embeddings = Vec::with_capacity(n_docs);
    for chunk in docs.chunks(embedder.batch_size()) {
        let texts: Vec<&str> = chunk.iter().map(|d| d.text.as_str()).collect();
        embeddings.extend(embedder.embed_texts(&texts)?);
    }
    let mut queries = Vec::with_capacity(n_queries);
    let qtexts: Vec<String> =
        (0..n_queries).map(|i| gen.query_for_topic(i % CorpusGen::n_topics())).collect();
    for chunk in qtexts.chunks(embedder.batch_size()) {
        let texts: Vec<&str> = chunk.iter().map(|s| s.as_str()).collect();
        queries.extend(embedder.embed_texts(&texts)?);
    }
    Ok(run_with_embeddings(&embeddings, &queries, k, "aot-embedder corpus"))
}

/// Run with artifacts if available, synthetic fallback otherwise.
pub fn run(n_docs: usize, n_queries: usize, k: usize) -> RecallResult {
    if artifacts_available() {
        match run_embedder(n_docs, n_queries, k) {
            Ok(r) => return r,
            Err(e) => eprintln!("embedder recall failed ({e}); using synthetic"),
        }
    }
    let embeddings = synthetic_embeddings(n_docs, 128, 16, 11);
    let queries = synthetic_embeddings(n_queries, 128, 16, 777);
    run_with_embeddings(&embeddings, &queries, k, "synthetic clusters")
}

/// Render in the paper's Table 3 format.
pub fn print_table(r: &RecallResult) {
    println!("\n=== Table 3: Recall@{} Comparison ===", r.k);
    println!(
        "source: {} | {} docs, {} queries",
        r.source, r.n_docs, r.n_queries
    );
    println!("{:<24} {:>10}", "Index Type", "Recall@10");
    println!("{:<24} {:>10.3}", "Float32 HNSW (baseline)", r.recall_f32);
    println!("{:<24} {:>10.3}", "Valori Q16.16 HNSW", r.recall_q16_vs_f32);
    println!("(paper: 1.000 / 0.998)");
    println!(
        "vs exact ground truth: f32 HNSW {:.3}, Q16.16 HNSW {:.3}",
        r.recall_f32_vs_exact, r.recall_q16_vs_exact
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_recall_matches_paper_shape() {
        let embeddings = synthetic_embeddings(800, 64, 10, 3);
        let queries = synthetic_embeddings(40, 64, 10, 5);
        let r = run_with_embeddings(&embeddings, &queries, 10, "test");
        // paper: 0.998 — quantization noise costs at most a little
        assert!(r.recall_q16_vs_f32 > 0.95, "q16 vs f32 = {}", r.recall_q16_vs_f32);
        assert!(r.recall_f32_vs_exact > 0.9, "f32 vs exact = {}", r.recall_f32_vs_exact);
        assert!(r.recall_q16_vs_exact > 0.9, "q16 vs exact = {}", r.recall_q16_vs_exact);
    }

    #[test]
    fn identical_inputs_give_full_recall() {
        // dim-8 exact-match regime: quantization can't reorder anything
        // separated by more than the quantization noise
        let embeddings = synthetic_embeddings(100, 8, 4, 9);
        let r = run_with_embeddings(&embeddings, &embeddings[..10].to_vec(), 1, "self");
        assert_eq!(r.recall_q16_vs_f32, 1.0);
    }
}
