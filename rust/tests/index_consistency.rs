//! Integration: HNSW vs exact flat-scan consistency across scalar types,
//! metrics and workload shapes (including the clustered regime that
//! defeats naive neighbor selection).

use valori::distance::Metric;
use valori::experiments::{recall_overlap, synthetic_embeddings};
use valori::fixed::{FixedFormat, Q16_16};
use valori::index::{FlatIndex, Hnsw, HnswParams, VectorIndex};
use valori::hash::XorShift64;

fn to_q16(v: &[f32]) -> Vec<i32> {
    v.iter().map(|&x| Q16_16::quantize(x as f64)).collect()
}

fn mean_recall_q16(
    data: &[Vec<f32>],
    queries: &[Vec<f32>],
    metric: Metric,
    k: usize,
) -> f64 {
    let dim = data[0].len();
    let mut h: Hnsw<i32> = Hnsw::new(dim, metric, HnswParams::default());
    let mut f: FlatIndex<i32> = FlatIndex::new(dim, metric);
    for (id, v) in data.iter().enumerate() {
        let raw = to_q16(v);
        h.insert(id as u64, raw.clone());
        f.insert(id as u64, raw);
    }
    let mut sum = 0.0;
    for q in queries {
        let raw = to_q16(q);
        let hh: Vec<u64> = h.search(&raw, k).iter().map(|x| x.id).collect();
        let fh: Vec<u64> = f.search(&raw, k).iter().map(|x| x.id).collect();
        sum += recall_overlap(&fh, &hh);
    }
    sum / queries.len() as f64
}

#[test]
fn uniform_data_high_recall() {
    let mut rng = XorShift64::new(5);
    let data: Vec<Vec<f32>> = (0..2000)
        .map(|_| (0..32).map(|_| rng.next_f32_range(-1.0, 1.0)).collect())
        .collect();
    let queries: Vec<Vec<f32>> = (0..40)
        .map(|_| (0..32).map(|_| rng.next_f32_range(-1.0, 1.0)).collect())
        .collect();
    let r = mean_recall_q16(&data, &queries, Metric::L2, 10);
    assert!(r > 0.95, "uniform recall@10 = {r}");
}

#[test]
fn clustered_data_high_recall() {
    // the regime that collapses without the diversity heuristic
    let data = synthetic_embeddings(2000, 64, 16, 3);
    let queries = synthetic_embeddings(40, 64, 16, 99);
    let r = mean_recall_q16(&data, &queries, Metric::L2, 10);
    assert!(r > 0.9, "clustered recall@10 = {r}");
}

#[test]
fn inner_product_recall() {
    let data = synthetic_embeddings(1000, 32, 8, 7);
    let queries = synthetic_embeddings(30, 32, 8, 11);
    let r = mean_recall_q16(&data, &queries, Metric::InnerProduct, 10);
    assert!(r > 0.9, "ip recall@10 = {r}");
}

#[test]
fn recall_after_heavy_deletion() {
    let data = synthetic_embeddings(1000, 32, 8, 13);
    let dim = 32;
    let mut h: Hnsw<i32> = Hnsw::new(dim, Metric::L2, HnswParams::default());
    let mut f: FlatIndex<i32> = FlatIndex::new(dim, Metric::L2);
    for (id, v) in data.iter().enumerate() {
        let raw = to_q16(v);
        h.insert(id as u64, raw.clone());
        f.insert(id as u64, raw);
    }
    // delete 40%
    for id in 0..1000u64 {
        if id % 5 < 2 {
            assert!(h.delete(id));
            assert!(f.delete(id));
        }
    }
    let queries = synthetic_embeddings(25, 32, 8, 17);
    let mut sum = 0.0;
    for q in &queries {
        let raw = to_q16(q);
        let hh: Vec<u64> = h.search(&raw, 10).iter().map(|x| x.id).collect();
        let fh: Vec<u64> = f.search(&raw, 10).iter().map(|x| x.id).collect();
        assert!(hh.iter().all(|id| id % 5 >= 2), "returned deleted id");
        sum += recall_overlap(&fh, &hh);
    }
    let r = sum / queries.len() as f64;
    assert!(r > 0.85, "post-deletion recall@10 = {r}");
}

#[test]
fn f32_and_q16_instantiations_agree_on_clean_data() {
    // On well-separated data, quantization cannot change the ranking:
    // the two instantiations of the same generic code agree exactly.
    let mut rng = XorShift64::new(23);
    let dim = 16;
    // grid-separated points (min distance far above quantization noise)
    let data: Vec<Vec<f32>> = (0..500)
        .map(|i| {
            (0..dim)
                .map(|j| ((i * dim + j) % 17) as f32 * 0.1 + rng.next_f32_range(-0.01, 0.01))
                .collect()
        })
        .collect();
    let mut hf: Hnsw<f32> = Hnsw::new(dim, Metric::L2, HnswParams::default());
    let mut hq: Hnsw<i32> = Hnsw::new(dim, Metric::L2, HnswParams::default());
    for (id, v) in data.iter().enumerate() {
        hf.insert(id as u64, v.clone());
        hq.insert(id as u64, to_q16(v));
    }
    for i in 0..20 {
        let q = &data[i * 7];
        let ids_f: Vec<u64> = hf.search(q, 5).iter().map(|x| x.id).collect();
        let ids_q: Vec<u64> = hq.search(&to_q16(q), 5).iter().map(|x| x.id).collect();
        assert_eq!(ids_f[0], ids_q[0], "top-1 must agree on separated data");
    }
}

#[test]
fn search_k_edge_cases() {
    let data = synthetic_embeddings(50, 8, 4, 29);
    let mut h: Hnsw<i32> = Hnsw::new(8, Metric::L2, HnswParams::default());
    for (id, v) in data.iter().enumerate() {
        h.insert(id as u64, to_q16(v));
    }
    let q = to_q16(&data[0]);
    assert_eq!(h.search(&q, 0).len(), 0);
    assert_eq!(h.search(&q, 1).len(), 1);
    assert_eq!(h.search(&q, 50).len(), 50);
    assert_eq!(h.search(&q, 1000).len(), 50); // k > n
    // results are sorted by (dist, id)
    let hits = h.search(&q, 50);
    for w in hits.windows(2) {
        assert!((w[0].dist, w[0].id) < (w[1].dist, w[1].id));
    }
}

#[test]
fn duplicate_vectors_rank_by_id() {
    let mut h: Hnsw<i32> = Hnsw::new(4, Metric::L2, HnswParams::default());
    let v = vec![1000, 2000, 3000, 4000];
    for id in [9u64, 3, 7, 1] {
        h.insert(id, v.clone());
    }
    let hits = h.search(&v, 4);
    assert_eq!(hits.iter().map(|h| h.id).collect::<Vec<_>>(), vec![1, 3, 7, 9]);
    assert!(hits.iter().all(|h| h.dist == 0));
}
