//! Deterministic integer square root.
//!
//! Used by fixed-point L2 normalization: given `norm² = Σ vᵢ²` accumulated
//! as a wide Q(2m).(2n) integer, `isqrt(norm²)` is a Qm.n integer norm. The
//! algorithm is the classic digit-by-digit (binary restoring) method —
//! integer-only, loop bounds fixed by the type width, so it is bit-identical
//! on every platform (no float sqrt involved anywhere).

#![forbid(unsafe_code)]

/// Floor of the square root of a `u64`.
#[inline]
pub fn isqrt_u64(n: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    // Initial guess from leading-zero count, then Newton iterations.
    // Newton on integers converges monotonically from above; loop is
    // deterministic (no float ops).
    let mut x = 1u64 << ((64 - n.leading_zeros()).div_ceil(2));
    loop {
        let y = (x + n / x) >> 1;
        if y >= x {
            // x is floor(sqrt(n)) or one above; fix up below.
            break;
        }
        x = y;
    }
    // Fix-up: overflow of x*x means x is certainly too large.
    while x.checked_mul(x).map_or(true, |xx| xx > n) {
        x -= 1;
    }
    // x*x <= n < (x+1)^2 now holds.
    x
}

/// Floor of the square root of a `u128`.
#[inline]
pub fn isqrt_u128(n: u128) -> u128 {
    if n == 0 {
        return 0;
    }
    if n <= u64::MAX as u128 {
        return isqrt_u64(n as u64) as u128;
    }
    let mut x = 1u128 << ((128 - n.leading_zeros()).div_ceil(2));
    loop {
        let y = (x + n / x) >> 1;
        if y >= x {
            break;
        }
        x = y;
    }
    while x.checked_mul(x).map_or(true, |xx| xx > n) {
        x -= 1;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isqrt_small_values() {
        let expect = [0, 1, 1, 1, 2, 2, 2, 2, 2, 3, 3, 3, 3, 3, 3, 3, 4];
        for (n, &e) in expect.iter().enumerate() {
            assert_eq!(isqrt_u64(n as u64), e, "n={n}");
        }
    }

    #[test]
    fn isqrt_perfect_squares() {
        for k in 0u64..2000 {
            assert_eq!(isqrt_u64(k * k), k);
            if k > 0 {
                assert_eq!(isqrt_u64(k * k - 1), k - 1);
                assert_eq!(isqrt_u64(k * k + 1), k);
            }
        }
    }

    #[test]
    fn isqrt_u64_extremes() {
        assert_eq!(isqrt_u64(u64::MAX), (1u64 << 32) - 1);
        assert_eq!(isqrt_u64(1u64 << 62), 1u64 << 31);
    }

    #[test]
    fn isqrt_u128_extremes() {
        assert_eq!(isqrt_u128(u128::MAX), (1u128 << 64) - 1);
        assert_eq!(isqrt_u128((1u128 << 100) - 1), (1u128 << 50) - 1);
        assert_eq!(isqrt_u128(1u128 << 100), 1u128 << 50);
        // delegation to the u64 path
        assert_eq!(isqrt_u128(144), 12);
    }

    #[test]
    fn isqrt_invariant_floor() {
        // Pseudo-random sweep with a deterministic LCG.
        let mut s = 0x9e3779b97f4a7c15u64;
        for _ in 0..10_000 {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let n = s;
            let r = isqrt_u64(n);
            assert!(r.checked_mul(r).map(|rr| rr <= n).unwrap_or(false) || r == 0);
            let r1 = r + 1;
            assert!(r1.checked_mul(r1).map(|rr| rr > n).unwrap_or(true));
        }
    }
}
