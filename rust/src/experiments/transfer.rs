//! E4 — §8.1 snapshot-transfer test.
//!
//! Paper protocol: (1) initialize kernel on machine A, insert 10 000
//! vectors; (2) snapshot → hash H_A; (3) transfer to machine B; (4) load,
//! verify H_B. Result: H_A ≡ H_B, and k-NN result ordering is identical
//! after restore.
//!
//! Cross-*process* transfer (our stand-in for cross-machine, DESIGN §2)
//! is exercised by the `valori snapshot`/`restore` CLI and the
//! snapshot_roundtrip integration test; this driver measures the in-repo
//! protocol end-to-end and reports timings.

#![forbid(unsafe_code)]

use crate::experiments::synthetic_embeddings;
use crate::snapshot::Snapshot;
use crate::state::{Command, Kernel, KernelConfig};
use std::time::Instant;

/// Result of the snapshot-transfer experiment.
#[derive(Debug, Clone)]
pub struct TransferResult {
    pub n_vectors: usize,
    pub dim: usize,
    pub hash_a: u64,
    pub hash_b: u64,
    pub sha_a: String,
    pub sha_b: String,
    pub hashes_equal: bool,
    pub knn_identical: bool,
    pub snapshot_bytes: usize,
    pub insert_time_ms: f64,
    pub snapshot_time_ms: f64,
    pub restore_time_ms: f64,
}

/// Run the §8.1 protocol with `n` vectors of dimension `dim`.
pub fn run(n: usize, dim: usize) -> TransferResult {
    let embeddings = synthetic_embeddings(n, dim, 32, 81);

    // Machine A: build state
    let mut a = Kernel::new(KernelConfig::default_q16(dim));
    let t0 = Instant::now();
    for (id, v) in embeddings.iter().enumerate() {
        a.apply(Command::insert(id as u64, v.clone())).expect("insert");
    }
    let insert_time_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Snapshot → H_A
    let t0 = Instant::now();
    let snap_a = Snapshot::capture(&a);
    let snapshot_time_ms = t0.elapsed().as_secs_f64() * 1e3;
    let bytes = snap_a.to_bytes();

    // "Transfer" + load on machine B → H_B
    let t0 = Instant::now();
    let snap_b = Snapshot::from_bytes(&bytes).expect("snapshot parse");
    let b = snap_b.restore().expect("restore");
    let restore_time_ms = t0.elapsed().as_secs_f64() * 1e3;
    let snap_b2 = Snapshot::capture(&b);

    // identical k-NN ordering after restore (paper's added check)
    let mut knn_identical = true;
    for q in embeddings.iter().take(20) {
        let ha = a.search_f32(q, 10).expect("search a");
        let hb = b.search_f32(q, 10).expect("search b");
        if ha != hb {
            knn_identical = false;
            break;
        }
    }

    TransferResult {
        n_vectors: n,
        dim,
        hash_a: snap_a.fnv,
        hash_b: snap_b2.fnv,
        sha_a: snap_a.sha256_hex(),
        sha_b: snap_b2.sha256_hex(),
        hashes_equal: snap_a.fnv == snap_b2.fnv && snap_a.sha256 == snap_b2.sha256,
        knn_identical,
        snapshot_bytes: bytes.len(),
        insert_time_ms,
        snapshot_time_ms,
        restore_time_ms,
    }
}

/// Render the §8.1 result.
pub fn print_result(r: &TransferResult) {
    println!("\n=== §8.1 Snapshot Transfer Test ===");
    println!("{} vectors × dim {}", r.n_vectors, r.dim);
    println!("H_A (fnv64)  = {:016x}", r.hash_a);
    println!("H_B (fnv64)  = {:016x}", r.hash_b);
    println!("sha256_A     = {}", r.sha_a);
    println!("sha256_B     = {}", r.sha_b);
    println!(
        "H_A == H_B: {}   k-NN ordering identical: {}   (paper: both must hold)",
        r.hashes_equal, r.knn_identical
    );
    println!(
        "snapshot {} bytes | insert {:.1} ms | snapshot {:.1} ms | restore {:.1} ms",
        r.snapshot_bytes, r.insert_time_ms, r.snapshot_time_ms, r.restore_time_ms
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_transfer_holds() {
        let r = run(500, 32);
        assert!(r.hashes_equal);
        assert!(r.knn_identical);
        assert_eq!(r.sha_a, r.sha_b);
        assert!(r.snapshot_bytes > 500 * 32 * 4); // vectors dominate
    }

    #[test]
    fn transfer_is_reproducible() {
        let r1 = run(200, 16);
        let r2 = run(200, 16);
        assert_eq!(r1.hash_a, r2.hash_a); // whole experiment deterministic
    }
}
