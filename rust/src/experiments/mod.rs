//! Experiment drivers: one per paper table/figure (DESIGN §1 index).
//!
//! Each driver is a library function returning a structured result, so the
//! same code backs (a) the `valori experiment <id>` CLI, (b) the bench
//! targets under `rust/benches/`, and (c) assertions in integration tests.

#![forbid(unsafe_code)]

pub mod divergence;
pub mod latency;
pub mod precision;
pub mod recall;
pub mod transfer;

use crate::hash::XorShift64;

/// Deterministic synthetic "embeddings": unit vectors drawn from `k`
/// Gaussian-ish clusters. Used when the AOT embedder is not built, and by
/// benches that need volumes the real encoder would be slow to produce.
/// Cluster structure makes recall experiments meaningful (nearest
/// neighbours are mostly same-cluster).
pub fn synthetic_embeddings(n: usize, dim: usize, clusters: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = XorShift64::new(seed);
    // cluster centres
    let centres: Vec<Vec<f32>> = (0..clusters)
        .map(|_| (0..dim).map(|_| rng.next_f32_range(-1.0, 1.0)).collect())
        .collect();
    (0..n)
        .map(|i| {
            let c = &centres[i % clusters];
            let mut v: Vec<f32> =
                c.iter().map(|&x| x + rng.next_f32_range(-0.3, 0.3)).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
            for x in v.iter_mut() {
                *x /= norm;
            }
            v
        })
        .collect()
}

/// Recall@k overlap between two ranked id lists (paper §8.3 definition:
/// fraction of overlapping results).
pub fn recall_overlap(a: &[u64], b: &[u64]) -> f64 {
    if a.is_empty() {
        return 1.0;
    }
    let hits = a.iter().filter(|id| b.contains(id)).count();
    hits as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_embeddings_are_unit_norm_and_deterministic() {
        let a = synthetic_embeddings(100, 32, 5, 42);
        let b = synthetic_embeddings(100, 32, 5, 42);
        assert_eq!(a, b);
        for v in &a {
            let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn synthetic_clusters_are_tighter_than_cross_cluster() {
        let e = synthetic_embeddings(100, 32, 5, 7);
        // same-cluster pair (0, 5) vs cross-cluster pair (0, 1)
        let dot = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
        let same = dot(&e[0], &e[5]);
        let cross = dot(&e[0], &e[1]);
        assert!(same > cross, "same {same} cross {cross}");
    }

    #[test]
    fn recall_overlap_basics() {
        assert_eq!(recall_overlap(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(recall_overlap(&[1, 2, 3], &[3, 2, 1]), 1.0); // order-free
        assert_eq!(recall_overlap(&[1, 2, 3, 4], &[1, 2, 9, 9]), 0.5);
        assert_eq!(recall_overlap(&[], &[]), 1.0);
    }
}
