//! The precision contract: a trait over fixed-point formats plus the three
//! concrete formats the paper names (Table 2).
//!
//! A `Qm.n` value is stored as a signed integer `raw`; the real value it
//! denotes is `raw / 2^n`. Addition/subtraction are plain (saturating)
//! integer ops; multiplication widens to the accumulator type, shifts right
//! by `n`, and saturates back; dot products accumulate in the wide type and
//! only narrow at the very end (paper §5.1 "Accumulators use i64 (or wider)
//! intermediates").
//!
//! Determinism argument: every operation below is defined purely in terms of
//! two's-complement integer arithmetic and shifts, which the Rust language
//! defines exactly (no implementation-defined behaviour), so results are
//! bit-identical on every supported target.

#![forbid(unsafe_code)]

use core::fmt;

/// A fixed-point precision contract (paper §6).
///
/// Implementors provide the storage width, fractional bits and saturating
/// arithmetic. All methods must be pure and integer-only.
// lint: float-boundary — quantize/dequantize are the paper's single allowed float crossing (§5.3)
pub trait FixedFormat: Copy + Clone + fmt::Debug + PartialEq + Eq {
    /// Raw storage type (`i32` for Q8.24/Q16.16, `i64` for Q32.32).
    type Raw: Copy + Ord + fmt::Debug;
    /// Wide accumulator type used for products and sums.
    type Wide: Copy + Ord + fmt::Debug;

    /// Number of fractional bits (`n` in `Qm.n`).
    const FRAC_BITS: u32;
    /// Total storage bits.
    const STORAGE_BITS: u32;
    /// Human-readable name, e.g. `"Q16.16"`.
    const NAME: &'static str;

    /// Raw value denoting zero.
    fn raw_zero() -> Self::Raw;
    /// Raw value denoting one (i.e. `1 << FRAC_BITS`).
    fn raw_one() -> Self::Raw;
    /// Maximum representable raw value.
    fn raw_max() -> Self::Raw;
    /// Minimum representable raw value.
    fn raw_min() -> Self::Raw;

    /// Quantize an `f64` real value to raw fixed-point, round-ties-even,
    /// saturating at the format bounds. This is the *boundary* operation
    /// (paper §5.3): the only place float math is allowed, and it uses a
    /// single correctly-rounded multiply + round, which IEEE-754 defines
    /// exactly — hence the boundary itself is cross-platform deterministic.
    fn quantize(x: f64) -> Self::Raw;

    /// Dequantize raw fixed-point back to `f64` (exact: the storage width
    /// always fits in an f64 mantissa for Q8.24/Q16.16; Q32.32 documents
    /// the rounding in its impl).
    fn dequantize(raw: Self::Raw) -> f64;

    /// Saturating addition.
    fn sat_add(a: Self::Raw, b: Self::Raw) -> Self::Raw;
    /// Saturating subtraction.
    fn sat_sub(a: Self::Raw, b: Self::Raw) -> Self::Raw;
    /// Saturating fixed-point multiplication: `(a*b) >> FRAC_BITS` with the
    /// product computed in the wide type (arithmetic shift, rounds toward
    /// negative infinity — documented contract).
    fn sat_mul(a: Self::Raw, b: Self::Raw) -> Self::Raw;
    /// Fixed-point division `(a << FRAC_BITS) / b`, saturating; division by
    /// zero saturates to the sign of `a` (`raw_max`/`raw_min`), `0/0 == 0`.
    fn sat_div(a: Self::Raw, b: Self::Raw) -> Self::Raw;

    /// Widening product `a * b` (a Q(2m).(2n) value in the wide type).
    fn widening_mul(a: Self::Raw, b: Self::Raw) -> Self::Wide;
    /// Saturating add in the wide domain.
    fn wide_add(a: Self::Wide, b: Self::Wide) -> Self::Wide;
    /// Wide zero.
    fn wide_zero() -> Self::Wide;
    /// Narrow a wide Q(2m).(2n) value back to raw Qm.n (shift right by
    /// FRAC_BITS, saturate).
    fn narrow(w: Self::Wide) -> Self::Raw;
    /// Convert a wide value to f64 interpreting it as Q(2m).(2n).
    fn wide_to_f64(w: Self::Wide) -> f64;

    /// Dot product over raw slices: widening products, wide saturating
    /// accumulation. Returns the wide Q(2m).(2n) sum — callers decide
    /// whether to narrow. Slices must have equal length.
    fn dot_wide(a: &[Self::Raw], b: &[Self::Raw]) -> Self::Wide {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = Self::wide_zero();
        for i in 0..a.len() {
            acc = Self::wide_add(acc, Self::widening_mul(a[i], b[i]));
        }
        acc
    }

    /// Squared L2 distance over raw slices, wide accumulation.
    fn l2sq_wide(a: &[Self::Raw], b: &[Self::Raw]) -> Self::Wide {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = Self::wide_zero();
        for i in 0..a.len() {
            let d = Self::sat_sub(a[i], b[i]);
            acc = Self::wide_add(acc, Self::widening_mul(d, d));
        }
        acc
    }

    /// Resolution (smallest positive step) as f64.
    fn resolution() -> f64 {
        1.0 / (1u64 << Self::FRAC_BITS) as f64
    }
}

/// Generates a fixed-point format backed by a primitive signed integer.
// lint: float-boundary — generated impls of the quantize/dequantize boundary above
macro_rules! fixed_format {
    ($(#[$doc:meta])* $name:ident, $raw:ty, $wide:ty, $frac:expr, $bits:expr, $disp:expr) => {
        $(#[$doc])*
        #[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
        pub struct $name;

        impl FixedFormat for $name {
            type Raw = $raw;
            type Wide = $wide;
            const FRAC_BITS: u32 = $frac;
            const STORAGE_BITS: u32 = $bits;
            const NAME: &'static str = $disp;

            #[inline]
            fn raw_zero() -> $raw { 0 }
            #[inline]
            fn raw_one() -> $raw { 1 << $frac }
            #[inline]
            fn raw_max() -> $raw { <$raw>::MAX }
            #[inline]
            fn raw_min() -> $raw { <$raw>::MIN }

            #[inline]
            fn quantize(x: f64) -> $raw {
                if x.is_nan() {
                    return 0;
                }
                let scaled = x * (1u64 << $frac) as f64;
                // round half to even, matching numpy/jnp.round so the
                // Pallas quantizer bit-matches this boundary (DESIGN §6).
                let r = round_ties_even_f64(scaled);
                if r >= <$raw>::MAX as f64 {
                    <$raw>::MAX
                } else if r <= <$raw>::MIN as f64 {
                    <$raw>::MIN
                } else {
                    r as $raw
                }
            }

            #[inline]
            fn dequantize(raw: $raw) -> f64 {
                raw as f64 / (1u64 << $frac) as f64
            }

            #[inline]
            fn sat_add(a: $raw, b: $raw) -> $raw { a.saturating_add(b) }
            #[inline]
            fn sat_sub(a: $raw, b: $raw) -> $raw { a.saturating_sub(b) }

            #[inline]
            fn sat_mul(a: $raw, b: $raw) -> $raw {
                let p = (a as $wide) * (b as $wide);
                let shifted = p >> $frac;
                if shifted > <$raw>::MAX as $wide {
                    <$raw>::MAX
                } else if shifted < <$raw>::MIN as $wide {
                    <$raw>::MIN
                } else {
                    shifted as $raw
                }
            }

            #[inline]
            fn sat_div(a: $raw, b: $raw) -> $raw {
                if b == 0 {
                    return if a > 0 {
                        <$raw>::MAX
                    } else if a < 0 {
                        <$raw>::MIN
                    } else {
                        0
                    };
                }
                let n = (a as $wide) << $frac;
                let q = n / (b as $wide);
                if q > <$raw>::MAX as $wide {
                    <$raw>::MAX
                } else if q < <$raw>::MIN as $wide {
                    <$raw>::MIN
                } else {
                    q as $raw
                }
            }

            #[inline]
            fn widening_mul(a: $raw, b: $raw) -> $wide { (a as $wide) * (b as $wide) }
            #[inline]
            fn wide_add(a: $wide, b: $wide) -> $wide { a.saturating_add(b) }
            #[inline]
            fn wide_zero() -> $wide { 0 }

            #[inline]
            fn narrow(w: $wide) -> $raw {
                let shifted = w >> $frac;
                if shifted > <$raw>::MAX as $wide {
                    <$raw>::MAX
                } else if shifted < <$raw>::MIN as $wide {
                    <$raw>::MIN
                } else {
                    shifted as $raw
                }
            }

            #[inline]
            fn wide_to_f64(w: $wide) -> f64 {
                w as f64 / ((1u64 << $frac) as f64 * (1u64 << $frac) as f64)
            }
        }
    };
}

/// `f64::round_ties_even` is unstable on older toolchains; implement the
/// IEEE-754 roundTiesToEven reconstruction explicitly so behaviour is pinned.
// lint: float-boundary — the boundary rounding step itself (IEEE-754 exact)
#[inline]
pub fn round_ties_even_f64(x: f64) -> f64 {
    let r = x.round(); // round half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // exact tie: pick the even neighbour
        let down = x.trunc();
        let up = r;
        if (down as i64) % 2 == 0 {
            down
        } else {
            up
        }
    } else {
        r
    }
}

fixed_format!(
    /// Q16.16: 32-bit signed, 16 fractional bits. Range ±32768,
    /// resolution ≈ 1.5e-5. The paper's reference default (§5.1): efficient
    /// on 32-bit MCUs, sufficient for normalized embeddings in [-1, 1].
    Q16_16, i32, i64, 16, 32, "Q16.16"
);

fixed_format!(
    /// Q8.24: 32-bit signed, 24 fractional bits. Range ±128, resolution
    /// ≈ 6e-8. Same storage cost as Q16.16 with more precision for strictly
    /// normalized embeddings (an extra contract point on Table 2's axis).
    Q8_24, i32, i64, 24, 32, "Q8.24"
);

fixed_format!(
    /// Q32.32: 64-bit signed, 32 fractional bits. The paper's "future
    /// enterprise" contract (Table 2): higher dynamic range + auditability.
    Q32_32, i64, i128, 32, 64, "Q32.32"
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q16_constants() {
        assert_eq!(Q16_16::FRAC_BITS, 16);
        assert_eq!(Q16_16::raw_one(), 65536);
        assert_eq!(Q16_16::NAME, "Q16.16");
        assert!((Q16_16::resolution() - 1.0 / 65536.0).abs() < 1e-12);
    }

    #[test]
    fn quantize_roundtrip_exact_values() {
        // Values exactly representable in Q16.16 must round-trip exactly.
        for &x in &[0.0, 1.0, -1.0, 0.5, -0.5, 0.25, 123.0625, -32767.0] {
            let q = Q16_16::quantize(x);
            assert_eq!(Q16_16::dequantize(q), x, "x={x}");
        }
    }

    #[test]
    fn quantize_rounds_ties_to_even() {
        // 0.5 ulp above an even raw value must round down to the even one.
        // raw 2 denotes 2/65536; x = 2.5/65536 ties between raw 2 and 3.
        let x = 2.5 / 65536.0;
        assert_eq!(Q16_16::quantize(x), 2);
        let x = 3.5 / 65536.0; // ties between 3 and 4 -> 4
        assert_eq!(Q16_16::quantize(x), 4);
        let x = -2.5 / 65536.0;
        assert_eq!(Q16_16::quantize(x), -2);
    }

    #[test]
    fn quantize_saturates() {
        assert_eq!(Q16_16::quantize(1e30), i32::MAX);
        assert_eq!(Q16_16::quantize(-1e30), i32::MIN);
        assert_eq!(Q16_16::quantize(f64::INFINITY), i32::MAX);
        assert_eq!(Q16_16::quantize(f64::NEG_INFINITY), i32::MIN);
        assert_eq!(Q16_16::quantize(f64::NAN), 0);
    }

    #[test]
    fn sat_mul_basic() {
        let one = Q16_16::raw_one();
        let half = one / 2;
        assert_eq!(Q16_16::sat_mul(one, one), one);
        assert_eq!(Q16_16::sat_mul(half, half), one / 4);
        assert_eq!(Q16_16::sat_mul(one * 2, one * 3), one * 6);
        // negative
        assert_eq!(Q16_16::sat_mul(-one, one), -one);
    }

    #[test]
    fn sat_mul_saturates() {
        let big = Q16_16::quantize(30000.0);
        assert_eq!(Q16_16::sat_mul(big, big), i32::MAX);
        assert_eq!(Q16_16::sat_mul(big, -big), i32::MIN);
    }

    #[test]
    fn sat_div_basic() {
        let one = Q16_16::raw_one();
        assert_eq!(Q16_16::sat_div(one * 6, one * 3), one * 2);
        assert_eq!(Q16_16::sat_div(one, one * 2), one / 2);
        assert_eq!(Q16_16::sat_div(one, 0), i32::MAX);
        assert_eq!(Q16_16::sat_div(-one, 0), i32::MIN);
        assert_eq!(Q16_16::sat_div(0, 0), 0);
    }

    #[test]
    fn dot_wide_matches_manual() {
        let one = Q16_16::raw_one();
        let a = vec![one, one * 2, -one];
        let b = vec![one, one, one];
        // 1 + 2 - 1 = 2 in Q32.32
        let d = Q16_16::dot_wide(&a, &b);
        assert_eq!(Q16_16::narrow(d), one * 2);
        assert_eq!(Q16_16::wide_to_f64(d), 2.0);
    }

    #[test]
    fn l2sq_wide_matches_manual() {
        let one = Q16_16::raw_one();
        let a = vec![one, 0];
        let b = vec![0, one];
        let d = Q16_16::l2sq_wide(&a, &b);
        assert_eq!(Q16_16::wide_to_f64(d), 2.0);
    }

    #[test]
    fn q32_32_roundtrip() {
        for &x in &[0.0, 1.0, -1.0, 0.125, 1e6] {
            let q = Q32_32::quantize(x);
            assert_eq!(Q32_32::dequantize(q), x);
        }
        assert_eq!(Q32_32::raw_one(), 1i64 << 32);
    }

    #[test]
    fn q8_24_range() {
        // Q8.24 max real value ~ 127.9999...
        assert!(Q8_24::dequantize(Q8_24::raw_max()) < 128.0);
        assert_eq!(Q8_24::quantize(200.0), i32::MAX);
    }

    #[test]
    fn round_ties_even_helper() {
        assert_eq!(round_ties_even_f64(0.5), 0.0);
        assert_eq!(round_ties_even_f64(1.5), 2.0);
        assert_eq!(round_ties_even_f64(2.5), 2.0);
        assert_eq!(round_ties_even_f64(-0.5), 0.0);
        assert_eq!(round_ties_even_f64(-1.5), -2.0);
        assert_eq!(round_ties_even_f64(0.75), 1.0);
        assert_eq!(round_ties_even_f64(-0.75), -1.0);
    }

    #[test]
    fn narrow_saturates() {
        assert_eq!(Q16_16::narrow(i64::MAX), i32::MAX);
        assert_eq!(Q16_16::narrow(i64::MIN), i32::MIN);
    }
}
