//! The Valori kernel: a pure, replayable state machine over fixed-point
//! vector memory (paper §5.2).
//!
//! The kernel owns everything inside the determinism boundary: the
//! quantized vectors, the deterministic index, the link graph, metadata,
//! and the logical clock. It performs no I/O — persistence (WAL, snapshot
//! files) and networking live in outer layers (paper §5.3's kernel/node
//! split) — and it contains no randomness and no floating-point state.

#![forbid(unsafe_code)]

use crate::codec::{DecodeError, Decoder, Encoder};
use crate::distance::{Metric, Scalar};
use crate::fixed::{FixedFormat, Q16_16};
use crate::graph::LinkGraph;
use crate::hash::{splitmix64, Fnv1a64};
use crate::index::{FlatIndex, Hnsw, HnswParams, QuantSpec, VecStore, VectorIndex};
use crate::proof::{leaf, LeafBody, LeafRecord, MembershipProof, MerkleTree};
use crate::state::command::{CanonCommand, Command};
use crate::vector::{BoundaryError, FixedVector, ValidationPolicy};
use std::collections::BTreeMap;
use std::fmt;

/// Which index structure the kernel maintains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexKind {
    /// Deterministic HNSW (paper §7) — the default.
    Hnsw,
    /// Exact brute-force index.
    Flat,
}

impl IndexKind {
    pub fn tag(&self) -> u8 {
        match self {
            IndexKind::Hnsw => 0,
            IndexKind::Flat => 1,
        }
    }

    pub fn from_tag(t: u8) -> Option<Self> {
        match t {
            0 => Some(IndexKind::Hnsw),
            1 => Some(IndexKind::Flat),
            _ => None,
        }
    }
}

/// Placement of a kernel within a sharded deployment (see
/// [`crate::state::sharded`]). The unsharded reference contract is
/// `n_shards == 1`; the routing function is fixed forever as
/// `splitmix64(id) % n_shards`, so shard membership is a pure function of
/// the external id and the shard count — any two nodes agree on placement
/// without coordination.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Total shards in the deployment (>= 1).
    pub n_shards: u32,
    /// This kernel's shard index in `0..n_shards`.
    pub shard_id: u32,
}

impl Default for ShardSpec {
    fn default() -> Self {
        Self { n_shards: 1, shard_id: 0 }
    }
}

impl ShardSpec {
    /// The shard an external id routes to under this deployment size.
    pub fn shard_of(&self, id: u64) -> u32 {
        (splitmix64(id) % self.n_shards.max(1) as u64) as u32
    }

    /// Whether this kernel is the owner of `id`.
    pub fn owns(&self, id: u64) -> bool {
        self.n_shards <= 1 || self.shard_of(id) == self.shard_id
    }

    pub fn encode(&self, e: &mut Encoder) {
        e.put_u32(self.n_shards);
        e.put_u32(self.shard_id);
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let n_shards = d.get_u32()?;
        let shard_id = d.get_u32()?;
        if n_shards == 0 || shard_id >= n_shards {
            return Err(DecodeError::InvalidTag { what: "shard spec", tag: shard_id as u64 });
        }
        Ok(Self { n_shards, shard_id })
    }
}

/// Default parallel-scan task size in arena slots. A config constant —
/// sub-range task boundaries are deterministic, never load- or
/// scheduling-dependent — though results are chunk-size-invariant anyway
/// (the top-k reduction ignores how the slot space was partitioned; see
/// PERFORMANCE.md §9). 4096 slots ≈ 2 MiB of Q16.16 arena at dim 128:
/// coarse enough to amortize claim/dispatch, fine enough to balance load.
pub const SCAN_CHUNK_SLOTS: u32 = 4096;

/// Read-path execution tuning: how searches parallelize over the arena.
///
/// Deliberately **not** part of the replayable state: two kernels that
/// differ only in scan tuning hold the same truth and return the same
/// bits, so this type compares always-equal and is never serialized —
/// snapshot bytes, state hashes and every golden fixture are unchanged
/// by any setting here.
#[derive(Debug, Clone, Copy)]
pub struct ScanConfig {
    /// Scan worker threads; `0` (default) = one per available core. The
    /// effective pool size is always `min(cores, workers)`.
    pub workers: u32,
    /// Sub-range task size in slots (>= 1); see [`SCAN_CHUNK_SLOTS`].
    pub chunk: u32,
}

impl Default for ScanConfig {
    fn default() -> Self {
        Self { workers: 0, chunk: SCAN_CHUNK_SLOTS }
    }
}

impl PartialEq for ScanConfig {
    fn eq(&self, _: &Self) -> bool {
        true // runtime tuning, not state (see type docs)
    }
}

impl Eq for ScanConfig {}

/// Kernel configuration — fixed at creation, serialized into every
/// snapshot (two nodes comparing hashes are comparing configs too).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelConfig {
    /// Vector dimensionality.
    pub dim: usize,
    /// Distance metric.
    pub metric: Metric,
    /// Index structure.
    pub index: IndexKind,
    /// HNSW parameters (ignored by the flat index).
    pub hnsw: HnswParams,
    /// Boundary validation policy.
    pub policy: ValidationPolicy,
    /// Shard placement (`{1, 0}` for the unsharded reference contract).
    pub shard: ShardSpec,
    /// Quantized scan tier (flat index only; HNSW ignores it). `None`
    /// kernels serialize as STATE_VERSION 2 — byte-identical to every
    /// pre-quant snapshot — and `Sq8` kernels as version 3 with the spec
    /// appended after the shard spec (see [`Kernel::encode_state`]).
    pub quant: QuantSpec,
    /// Parallel-scan tuning. Excluded from serialization and equality
    /// (see [`ScanConfig`]): it tunes *how* the read path executes, never
    /// what it returns.
    pub scan: ScanConfig,
}

impl KernelConfig {
    /// The reference contract: Q16.16, HNSW, L2 (paper §5.1 default).
    pub fn default_q16(dim: usize) -> Self {
        Self {
            dim,
            metric: Metric::L2,
            index: IndexKind::Hnsw,
            hnsw: HnswParams::default(),
            policy: ValidationPolicy::default(),
            shard: ShardSpec::default(),
            quant: QuantSpec::None,
            scan: ScanConfig::default(),
        }
    }

    /// Cosine/IP contract for normalized embedding pipelines.
    pub fn embedding_cosine(dim: usize) -> Self {
        Self {
            dim,
            metric: Metric::Cosine,
            index: IndexKind::Hnsw,
            hnsw: HnswParams::default(),
            policy: ValidationPolicy::normalized_embeddings(),
            shard: ShardSpec::default(),
            quant: QuantSpec::None,
            scan: ScanConfig::default(),
        }
    }

    pub fn with_flat_index(mut self) -> Self {
        self.index = IndexKind::Flat;
        self
    }

    /// Enable (or disable) the quantized scan tier.
    pub fn with_quant(mut self, quant: QuantSpec) -> Self {
        self.quant = quant;
        self
    }

    /// Place this config at `shard_id` of an `n_shards`-wide deployment.
    pub fn with_shard(mut self, n_shards: u32, shard_id: u32) -> Self {
        assert!(n_shards >= 1 && shard_id < n_shards, "invalid shard spec");
        self.shard = ShardSpec { n_shards, shard_id };
        self
    }

    /// The STATE_VERSION-2 field layout. The quant spec is deliberately
    /// NOT written here: version-2 streams must stay byte-identical, so
    /// [`Kernel::encode_state`] appends it only under version 3.
    pub fn encode(&self, e: &mut Encoder) {
        e.put_u32(self.dim as u32);
        e.put_u8(self.metric.tag());
        e.put_u8(self.index.tag());
        self.hnsw.encode(e);
        e.put_f32(self.policy.max_abs);
        e.put_u8(self.policy.normalize as u8);
        self.shard.encode(e);
    }

    pub fn decode(d: &mut Decoder) -> Result<Self, DecodeError> {
        let dim = d.get_u32()? as usize;
        let mtag = d.get_u8()?;
        let metric = Metric::from_tag(mtag)
            .ok_or(DecodeError::InvalidTag { what: "metric", tag: mtag as u64 })?;
        let itag = d.get_u8()?;
        let index = IndexKind::from_tag(itag)
            .ok_or(DecodeError::InvalidTag { what: "index kind", tag: itag as u64 })?;
        let hnsw = HnswParams::decode(d)?;
        let max_abs = d.get_f32()?;
        let normalize = match d.get_u8()? {
            0 => false,
            1 => true,
            t => return Err(DecodeError::InvalidTag { what: "normalize flag", tag: t as u64 }),
        };
        let shard = ShardSpec::decode(d)?;
        Ok(Self {
            dim,
            metric,
            index,
            hnsw,
            policy: ValidationPolicy { max_abs, normalize },
            shard,
            quant: QuantSpec::None,
            scan: ScanConfig::default(),
        })
    }
}

/// State-machine errors. Every rejection is itself deterministic: the same
/// command at the same state fails identically everywhere, so error paths
/// don't fork replicas.
#[derive(Debug, Clone, PartialEq)]
pub enum StateError {
    /// Insert with an id that already exists (including tombstoned ids —
    /// ids are never reused, or replay semantics would depend on history
    /// compaction).
    DuplicateId(u64),
    /// Command references an id that does not exist (or was deleted).
    UnknownId(u64),
    /// Rejected at the quantization boundary.
    Boundary(BoundaryError),
    /// Canonical command carries a vector of the wrong dimension.
    DimMismatch { expected: usize, got: usize },
    /// Metadata key exceeds limits (keys are bounded to keep snapshots
    /// bounded; 256 bytes is generous for tag-style metadata).
    MetaKeyTooLong(usize),
    /// A sharded kernel received a command whose primary id routes to a
    /// different shard — a routing-layer bug or a forged per-shard log.
    /// Never raised when `n_shards == 1`.
    WrongShard { id: u64, expected: u32 },
    /// A pooled scan task died (panicked) while serving this query. Only
    /// the dispatching query fails — the pool respawns the worker and
    /// state is untouched (scans only read), so the next query is served
    /// normally.
    ScanPoisoned,
}

impl fmt::Display for StateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StateError::DuplicateId(id) => write!(f, "duplicate id {id}"),
            StateError::UnknownId(id) => write!(f, "unknown id {id}"),
            StateError::Boundary(e) => write!(f, "boundary: {e}"),
            StateError::DimMismatch { expected, got } => {
                write!(f, "dimension mismatch: expected {expected}, got {got}")
            }
            StateError::MetaKeyTooLong(n) => write!(f, "metadata key too long ({n} bytes)"),
            StateError::WrongShard { id, expected } => {
                write!(f, "id {id} routes to shard {expected}, not this shard")
            }
            StateError::ScanPoisoned => {
                write!(f, "scan worker pool poisoned (a scan task panicked); retry the query")
            }
        }
    }
}

impl std::error::Error for StateError {}

impl From<BoundaryError> for StateError {
    fn from(e: BoundaryError) -> Self {
        StateError::Boundary(e)
    }
}

/// A search hit as reported by the kernel: external id, the exact integer
/// distance (Q32.32 wide), and a float rendering for display/JSON.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    pub id: u64,
    /// Exact wide fixed-point distance — the value replicas compare.
    pub dist_raw: i64,
    /// `dist_raw` as a real number (display only, never ordered on).
    pub dist: f64, // lint: float-boundary — display-only rendering of dist_raw
}

#[derive(Debug, Clone, PartialEq)]
enum IndexImpl {
    Hnsw(Hnsw<i32>),
    Flat(FlatIndex<i32>),
}

/// The kernel's incrementally-maintained Merkle tree over slot digests
/// ([`crate::proof`]).
///
/// **Derived state**, like the SQ8 code arena: a pure function of the
/// replayable state, never serialized (snapshot bytes and every golden
/// fixture are unchanged), rebuilt on decode. Two kernels that compare
/// equal necessarily hold bit-identical trees, so — exactly like
/// [`ScanConfig`] — this wrapper compares always-equal rather than
/// re-hashing what `PartialEq` already compared.
#[derive(Clone)]
struct MerkleState {
    tree: MerkleTree,
}

impl PartialEq for MerkleState {
    fn eq(&self, _: &Self) -> bool {
        true // derived from the compared state (see type docs)
    }
}

impl Eq for MerkleState {}

impl fmt::Debug for MerkleState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // The full level table is noise in kernel diffs; the root is the
        // tree for all observable purposes.
        write!(f, "MerkleState(root={}, capacity={})",
            crate::hash::hex_lower(&self.tree.root()), self.tree.capacity())
    }
}

/// Why an un-logged [`Kernel::repair_slot`] was refused. Closed set,
/// mapped onto the 1700-range API codes by the node layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RepairError {
    /// Slot beyond the arena (repair never allocates slots — slot
    /// numbering is log-derived and a missing slot means a missing
    /// command, which is replication's job, not repair's).
    SlotOutOfRange,
    /// The shipped record's id differs from the id this slot has always
    /// held (slot→id is a pure function of the log; a mismatch means the
    /// two nodes diverged structurally, not in one record).
    IdMismatch,
    /// The shipped vector has the wrong dimensionality for this kernel.
    DimMismatch,
}

impl fmt::Display for RepairError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RepairError::SlotOutOfRange => f.write_str("repair slot beyond arena"),
            RepairError::IdMismatch => f.write_str("repair record id does not match slot"),
            RepairError::DimMismatch => f.write_str("repair vector has wrong dimension"),
        }
    }
}

/// The deterministic memory kernel (Q16.16 reference contract).
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    config: KernelConfig,
    index: IndexImpl,
    links: LinkGraph,
    meta: BTreeMap<u64, BTreeMap<String, String>>,
    /// Logical clock: number of successfully applied commands (paper §3.1's
    /// `t`).
    seq: u64,
    /// Derived Merkle tree over slot digests — updated in O(log n) per
    /// applied command, see [`MerkleState`].
    merkle: MerkleState,
}

const MAX_META_KEY: usize = 256;

/// Snapshot framing constants (shared with [`crate::snapshot`]).
pub(crate) const STATE_MAGIC: u32 = 0x564C_4F52; // "VLOR"
/// Version 2 added the shard spec to [`KernelConfig`] (PR: sharded kernel).
pub(crate) const STATE_VERSION: u32 = 2;
/// Version 3 appends the quantization spec after the shard spec. Emitted
/// only when a quant tier is configured — quant-free kernels keep writing
/// version-2 bytes, so every pre-quant snapshot (and the golden fixture)
/// stays byte-identical; both versions decode.
pub(crate) const STATE_VERSION_QUANT: u32 = 3;

impl Kernel {
    pub fn new(config: KernelConfig) -> Self {
        let index = match config.index {
            IndexKind::Hnsw => IndexImpl::Hnsw(Hnsw::new(config.dim, config.metric, config.hnsw)),
            IndexKind::Flat => {
                IndexImpl::Flat(FlatIndex::with_quant(config.dim, config.metric, config.quant))
            }
        };
        Self {
            config,
            index,
            links: LinkGraph::new(),
            meta: BTreeMap::new(),
            seq: 0,
            merkle: MerkleState { tree: MerkleTree::new() },
        }
    }

    pub fn config(&self) -> &KernelConfig {
        &self.config
    }

    /// Override the scan-worker count (read-path tuning; see
    /// [`ScanConfig`] — cannot change results, hashes or state bytes).
    pub fn set_scan_workers(&mut self, workers: u32) {
        self.config.scan.workers = workers;
    }

    /// Override the parallel-scan chunk size in slots (clamped to >= 1 —
    /// a zero chunk could never make claim progress).
    pub fn set_scan_chunk(&mut self, chunk: u32) {
        self.config.scan.chunk = chunk.max(1);
    }

    /// The flat index, when this kernel maintains one. The sharded
    /// parallel scan needs direct sub-range access to the contiguous
    /// arena; HNSW has no chunkable arena and falls back to whole-shard
    /// search jobs.
    pub(crate) fn flat_index(&self) -> Option<&FlatIndex<i32>> {
        match &self.index {
            IndexImpl::Flat(f) => Some(f),
            IndexImpl::Hnsw(_) => None,
        }
    }

    /// Logical time `t` — number of applied commands.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Number of live vectors.
    pub fn len(&self) -> usize {
        match &self.index {
            IndexImpl::Hnsw(h) => h.len(),
            IndexImpl::Flat(f) => f.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, id: u64) -> bool {
        self.get_raw(id).is_some()
    }

    /// Raw quantized vector for a live id.
    pub fn get_raw(&self, id: u64) -> Option<&[i32]> {
        match &self.index {
            IndexImpl::Hnsw(h) => h.get(id),
            IndexImpl::Flat(f) => f.get(id),
        }
    }

    pub fn links(&self) -> &LinkGraph {
        &self.links
    }

    pub fn meta_of(&self, id: u64) -> Option<&BTreeMap<String, String>> {
        self.meta.get(&id)
    }

    /// Boundary + transition in one step: validate/canonicalize the
    /// external command, apply it, and return the canonical record (what
    /// the WAL appends and replication ships).
    pub fn apply(&mut self, cmd: Command) -> Result<CanonCommand, StateError> {
        let canon = self.canonicalize(cmd)?;
        self.apply_canon(&canon)?;
        Ok(canon)
    }

    /// Boundary only: turn an external command into its canonical form
    /// without applying (used by leaders that order before applying).
    pub fn canonicalize(&self, cmd: Command) -> Result<CanonCommand, StateError> {
        Ok(match cmd {
            Command::Insert { id, vector } => {
                let fv = FixedVector::from_f32(&vector, self.config.dim, &self.config.policy)?;
                CanonCommand::Insert { id, raw: fv.raw().to_vec() }
            }
            Command::InsertBatch { items } => {
                // paper §7.1: canonical processing order is ascending id,
                // independent of submission order. Duplicate ids within a
                // batch are rejected up front (the batch is atomic).
                let mut canon_items = Vec::with_capacity(items.len());
                for (id, vector) in items {
                    let fv =
                        FixedVector::from_f32(&vector, self.config.dim, &self.config.policy)?;
                    canon_items.push((id, fv.raw().to_vec()));
                }
                canon_items.sort_by_key(|(id, _)| *id);
                for w in canon_items.windows(2) {
                    if w[0].0 == w[1].0 {
                        return Err(StateError::DuplicateId(w[0].0));
                    }
                }
                CanonCommand::InsertBatch { items: canon_items }
            }
            Command::Delete { id } => CanonCommand::Delete { id },
            Command::Link { from, to } => CanonCommand::Link { from, to },
            Command::Unlink { from, to } => CanonCommand::Unlink { from, to },
            Command::SetMeta { id, key, value } => CanonCommand::SetMeta { id, key, value },
        })
    }

    /// The transition function `F` (paper §3.1): integer-only, pure, total
    /// over validated commands. Errors leave the state untouched.
    ///
    /// Every arm records the slots whose canonical leaf encoding it
    /// changed; on success the Merkle tree recomputes exactly those
    /// O(log n) root paths ([`crate::proof`]) — never a full rebuild.
    pub fn apply_canon(&mut self, canon: &CanonCommand) -> Result<(), StateError> {
        // Dirty-slot set for the incremental Merkle update. Tiny (1 for
        // point commands, batch size for batches, fan-in for deletes).
        let mut dirty: Vec<u32> = Vec::new();
        match canon {
            CanonCommand::Insert { id, raw } => {
                // The contract check runs on the canonical path too: a
                // replicated/forged log cannot smuggle in raws outside the
                // accumulator contract (DESIGN §6).
                self.check_owned(*id)?;
                self.config.policy.validate_raw(raw, self.config.dim)?;
                if self.id_ever_used(*id) {
                    return Err(StateError::DuplicateId(*id));
                }
                match &mut self.index {
                    IndexImpl::Hnsw(h) => h.insert(*id, raw.clone()),
                    IndexImpl::Flat(f) => f.insert(*id, raw.clone()),
                }
                dirty.extend(self.store_ref().slot_of(*id));
            }
            CanonCommand::InsertBatch { items } => {
                // Validate the whole batch before touching the index —
                // atomicity keeps failed batches from forking replicas
                // that applied a prefix.
                for w in items.windows(2) {
                    if w[0].0 >= w[1].0 {
                        return Err(StateError::DuplicateId(w[1].0));
                    }
                }
                for (id, raw) in items {
                    self.check_owned(*id)?;
                    self.config.policy.validate_raw(raw, self.config.dim)?;
                    if self.id_ever_used(*id) {
                        return Err(StateError::DuplicateId(*id));
                    }
                }
                for (id, raw) in items {
                    match &mut self.index {
                        IndexImpl::Hnsw(h) => h.insert(*id, raw.clone()),
                        IndexImpl::Flat(f) => f.insert(*id, raw.clone()),
                    }
                    dirty.extend(self.store_ref().slot_of(*id));
                }
            }
            CanonCommand::Delete { id } => {
                self.check_owned(*id)?;
                // Capture the dirtied slots *before* mutating: slot_of is
                // live-filtered, and remove_node erases the incoming-edge
                // list whose source records lose an outgoing link (their
                // leaves encode outgoing links, so they re-hash too).
                let own_slot = self.store_ref().slot_of(*id);
                let sources = self.links.links_to(*id);
                let removed = match &mut self.index {
                    IndexImpl::Hnsw(h) => h.delete(*id),
                    IndexImpl::Flat(f) => f.delete(*id),
                };
                if !removed {
                    return Err(StateError::UnknownId(*id));
                }
                self.links.remove_node(*id);
                self.meta.remove(id);
                dirty.extend(own_slot);
                for src in sources {
                    if src != *id {
                        dirty.extend(self.store_ref().slot_of(src));
                    }
                }
            }
            CanonCommand::Link { from, to } => {
                // Links live on the shard that owns `from`. `to` can only
                // be checked locally when this shard owns it; a remote `to`
                // was checked by the sharded router before the command was
                // logged (same contract as boundary validation: checked
                // once, upstream of the log).
                self.check_owned(*from)?;
                if !self.contains(*from) {
                    return Err(StateError::UnknownId(*from));
                }
                if self.config.shard.owns(*to) && !self.contains(*to) {
                    return Err(StateError::UnknownId(*to));
                }
                self.links.link(*from, *to);
                dirty.extend(self.store_ref().slot_of(*from));
            }
            CanonCommand::Unlink { from, to } => {
                self.check_owned(*from)?;
                if !self.links.has_link(*from, *to) {
                    return Err(StateError::UnknownId(*from));
                }
                self.links.unlink(*from, *to);
                dirty.extend(self.store_ref().slot_of(*from));
            }
            CanonCommand::SetMeta { id, key, value } => {
                if key.len() > MAX_META_KEY {
                    return Err(StateError::MetaKeyTooLong(key.len()));
                }
                self.check_owned(*id)?;
                if !self.contains(*id) {
                    return Err(StateError::UnknownId(*id));
                }
                self.meta.entry(*id).or_default().insert(key.clone(), value.clone());
                dirty.extend(self.store_ref().slot_of(*id));
            }
        }
        for slot in dirty {
            self.refresh_merkle_slot(slot);
        }
        self.seq += 1;
        Ok(())
    }

    /// Ids are never reused, even after deletion (replay invariance).
    /// Public so the sharded router can pre-validate batches atomically
    /// across shards before mutating any of them.
    pub fn ever_contains(&self, id: u64) -> bool {
        match &self.index {
            IndexImpl::Hnsw(h) => h.store().ever_contains(id),
            IndexImpl::Flat(f) => f.store().ever_contains(id),
        }
    }

    fn id_ever_used(&self, id: u64) -> bool {
        self.ever_contains(id)
    }

    /// Routing-invariant check: a sharded kernel only accepts commands for
    /// ids it owns. A no-op for the unsharded (`n_shards == 1`) contract.
    fn check_owned(&self, id: u64) -> Result<(), StateError> {
        if self.config.shard.owns(id) {
            Ok(())
        } else {
            Err(StateError::WrongShard { id, expected: self.config.shard.shard_of(id) })
        }
    }

    /// k-NN over raw (already quantized) query values. The query must
    /// satisfy the same contract as stored vectors (wrapping-add exactness
    /// in the distance hot loop depends on it). The dim check below is
    /// also what discharges the distance kernels' equal-length contract
    /// (`distance::dot_q16` et al. carry only a `debug_assert`): every
    /// stored vector was dim-checked on insert, so query-vs-stored slices
    /// are always the same length by the time they reach the hot loop.
    pub fn search_raw(&self, query: &[i32], k: usize) -> Result<Vec<Hit>, StateError> {
        if query.len() != self.config.dim {
            return Err(StateError::DimMismatch { expected: self.config.dim, got: query.len() });
        }
        self.config.policy.validate_raw(query, self.config.dim)?;
        let hits = match &self.index {
            IndexImpl::Hnsw(h) => h.search(query, k),
            IndexImpl::Flat(f) => f.search(query, k),
        };
        Ok(hits
            .into_iter()
            .map(|h| Hit { id: h.id, dist_raw: h.dist, dist: <i32 as Scalar>::dist_to_f64(h.dist) })
            .collect())
    }

    /// k-NN over a float query: the query crosses the same boundary as
    /// inserts (same validation, same quantization, same normalization
    /// policy), then the search is integer-only.
    // lint: float-boundary — query entry point, floats stop at from_f32
    pub fn search_f32(&self, query: &[f32], k: usize) -> Result<Vec<Hit>, StateError> {
        let fv = FixedVector::from_f32(query, self.config.dim, &self.config.policy)?;
        self.search_raw(fv.raw(), k)
    }

    /// Canonical state serialization — the byte stream the state hash and
    /// snapshots are computed over. Fully deterministic by construction.
    pub fn encode_state(&self, e: &mut Encoder) {
        e.put_u32(STATE_MAGIC);
        // The version is a pure function of the config: no quant tier ⇒
        // version-2 bytes, identical to every pre-quant snapshot (the
        // golden fixture pins this); a quant tier ⇒ version 3 with the
        // spec appended right after the shard spec. Codes themselves are
        // derived state and never appear in either layout.
        let version = self.state_version();
        e.put_u32(version);
        self.config.encode(e);
        if version == STATE_VERSION_QUANT {
            self.config.quant.encode(e);
        }
        e.put_u64(self.seq);
        match &self.index {
            IndexImpl::Hnsw(h) => h.encode(e),
            IndexImpl::Flat(f) => f.encode(e),
        }
        self.links.encode(e);
        e.put_u32(self.meta.len() as u32);
        for (id, kv) in &self.meta {
            e.put_u64(*id);
            e.put_u32(kv.len() as u32);
            for (k, v) in kv {
                e.put_str(k);
                e.put_str(v);
            }
        }
    }

    pub fn decode_state(d: &mut Decoder) -> Result<Self, DecodeError> {
        let magic = d.get_u32()?;
        if magic != STATE_MAGIC {
            return Err(DecodeError::BadMagic { expected: STATE_MAGIC, found: magic });
        }
        let version = d.get_u32()?;
        if version != STATE_VERSION && version != STATE_VERSION_QUANT {
            return Err(DecodeError::BadVersion { expected: STATE_VERSION_QUANT, found: version });
        }
        let mut config = KernelConfig::decode(d)?;
        if version == STATE_VERSION_QUANT {
            // v2 streams have no quant field: decode() already defaulted
            // it to None, so pre-quant snapshots restore unchanged.
            config.quant = QuantSpec::decode(d)?;
        }
        let seq = d.get_u64()?;
        let index = match config.index {
            IndexKind::Hnsw => IndexImpl::Hnsw(Hnsw::decode(d)?),
            IndexKind::Flat => IndexImpl::Flat(FlatIndex::decode_with_quant(d, config.quant)?),
        };
        let links = LinkGraph::decode(d)?;
        let n = d.get_u32()? as usize;
        let mut meta = BTreeMap::new();
        for _ in 0..n {
            let id = d.get_u64()?;
            let cnt = d.get_u32()? as usize;
            let mut kv = BTreeMap::new();
            for _ in 0..cnt {
                let k = d.get_str()?.to_string();
                let v = d.get_str()?.to_string();
                kv.insert(k, v);
            }
            meta.insert(id, kv);
        }
        let mut kernel = Self {
            config,
            index,
            links,
            meta,
            seq,
            merkle: MerkleState { tree: MerkleTree::new() },
        };
        // The Merkle tree is derived state: never on the wire, rebuilt
        // here — exactly like the SQ8 code arena, it can never drift from
        // the decoded records.
        kernel.rebuild_merkle();
        Ok(kernel)
    }

    pub fn to_state_bytes(&self) -> Vec<u8> {
        let mut e = Encoder::with_capacity(4096);
        self.encode_state(&mut e);
        e.into_vec()
    }

    pub fn from_state_bytes(bytes: &[u8]) -> Result<Self, DecodeError> {
        let mut d = Decoder::new(bytes);
        let k = Self::decode_state(&mut d)?;
        d.finish()?;
        Ok(k)
    }

    /// FNV-1a 64 over the canonical state bytes — the hash replicas compare
    /// (paper §8.1's H_A ≡ H_B, §9 "comparing memory state hashes").
    pub fn state_hash(&self) -> u64 {
        let mut h = Fnv1a64::new();
        h.update(&self.to_state_bytes());
        h.finish()
    }

    /// Dequantized copy of a stored vector (observability only).
    // lint: float-boundary — observability read-out, exact dequantization
    pub fn get_f32(&self, id: u64) -> Option<Vec<f32>> {
        self.get_raw(id).map(|raw| raw.iter().map(|&r| Q16_16::dequantize(r) as f32).collect())
    }

    /// Resident heap bytes of the vector arenas: `(exact, codes)` — the
    /// exact Q16.16 arena and the derived i8 code arena (0 when no quant
    /// tier). Tombstoned slots count: this reports memory held, not live
    /// vectors. Feeds the per-collection `memory_bytes` stat.
    pub fn arena_bytes(&self) -> (usize, usize) {
        match &self.index {
            IndexImpl::Hnsw(h) => {
                (h.store().arena().len() * std::mem::size_of::<i32>(), 0)
            }
            IndexImpl::Flat(f) => (f.exact_arena_bytes(), f.code_arena_bytes()),
        }
    }

    // ------------------------------------------------------------------
    // Verifiable state receipts (PR-10, see `crate::proof`)
    // ------------------------------------------------------------------

    /// The backing slot store, independent of index kind.
    fn store_ref(&self) -> &VecStore<i32> {
        match &self.index {
            IndexImpl::Hnsw(h) => h.store(),
            IndexImpl::Flat(f) => f.store(),
        }
    }

    /// Snapshot format version this kernel serializes as (receipts pin it
    /// so a verifier knows which decoder applies).
    pub fn state_version(&self) -> u32 {
        if self.config.quant == QuantSpec::None { STATE_VERSION } else { STATE_VERSION_QUANT }
    }

    /// Canonical leaf encoding of one arena slot
    /// ([`crate::proof::leaf`]: live record, or tombstone). `None` beyond
    /// the arena — slots inside tree capacity but beyond the arena hash
    /// the fixed empty sentinel and carry no record.
    pub fn merkle_leaf_encoding(&self, slot: u32) -> Option<Vec<u8>> {
        let st = self.store_ref();
        if (slot as usize) >= st.slots() {
            return None;
        }
        let id = st.external_id(slot);
        Some(if st.is_alive(slot) {
            leaf::encode_live(id, st.vec_at(slot), self.meta.get(&id), &self.links.links_from(id))
        } else {
            leaf::encode_tombstone(id)
        })
    }

    /// Re-hash one slot's leaf and its O(log n) root path.
    fn refresh_merkle_slot(&mut self, slot: u32) {
        if let Some(enc) = self.merkle_leaf_encoding(slot) {
            self.merkle.tree.set_leaf(slot as usize, &enc);
        }
    }

    /// Full rebuild from current records — decode-time only; the command
    /// path is always the incremental per-slot update.
    fn rebuild_merkle(&mut self) {
        for slot in 0..self.store_ref().slots() as u32 {
            self.refresh_merkle_slot(slot);
        }
    }

    /// This kernel's (= this shard's) Merkle root over slot digests.
    pub fn merkle_root(&self) -> [u8; 32] {
        self.merkle.tree.root()
    }

    /// Merkle leaf capacity (`next_pow2(slots)`, ≥ 1).
    pub fn merkle_capacity(&self) -> usize {
        self.merkle.tree.capacity()
    }

    /// Number of tree levels (`log2(capacity) + 1`; level 0 = leaves).
    pub fn merkle_levels(&self) -> usize {
        self.merkle.tree.depth() + 1
    }

    /// Digest range `[from, from+count)` at one tree level — the
    /// bisection wire Merkle-diff repair walks ([`crate::replication`]).
    pub fn merkle_level(&self, level: usize, from: usize, count: usize) -> Option<Vec<[u8; 32]>> {
        self.merkle.tree.level_hashes(level, from, count).map(|s| s.to_vec())
    }

    /// Membership proof for an id this kernel ever owned (live record or
    /// tombstone — deletion is provable too). `None` for never-inserted
    /// ids.
    pub fn merkle_proof(&self, id: u64) -> Option<MembershipProof> {
        let slot = self.store_ref().any_slot_of(id)?;
        let record = self.merkle_leaf_encoding(slot)?;
        let path = self.merkle.tree.proof_path(slot as usize)?;
        Some(MembershipProof {
            id,
            shard: self.config.shard.shard_id as u64,
            slot: slot as u64,
            capacity: self.merkle.tree.capacity() as u64,
            record,
            path,
        })
    }

    /// Un-logged record-level divergence repair: overwrite one slot with
    /// the canonical record a trusted primary shipped for it.
    ///
    /// This is state *surgery*, not a command — it never advances `seq`
    /// and is never logged, because the two replicas already agree on the
    /// command history length; what diverged is one slot's contents. The
    /// slot's id must match (slot→id assignment is a pure function of the
    /// log; a mismatch means structural divergence that only replay can
    /// fix). Repairing a live record restores vector bytes, metadata and
    /// outgoing links; repairing to a tombstone kills the slot and clears
    /// its meta/outgoing links (incoming links belong to *their* source
    /// records' leaves and are repaired there).
    pub fn repair_slot(&mut self, slot: u32, rec: &LeafRecord) -> Result<(), RepairError> {
        if (slot as usize) >= self.store_ref().slots() {
            return Err(RepairError::SlotOutOfRange);
        }
        if rec.id != self.store_ref().external_id(slot) {
            return Err(RepairError::IdMismatch);
        }
        match &rec.body {
            LeafBody::Live { vector, meta, links } => {
                if vector.len() != self.config.dim {
                    return Err(RepairError::DimMismatch);
                }
                match &mut self.index {
                    IndexImpl::Hnsw(h) => h.repair_slot(slot, Some(vector), true),
                    IndexImpl::Flat(f) => f.repair_slot(slot, Some(vector), true),
                }
                for t in self.links.links_from(rec.id) {
                    self.links.unlink(rec.id, t);
                }
                for &t in links {
                    self.links.link(rec.id, t);
                }
                if meta.is_empty() {
                    self.meta.remove(&rec.id);
                } else {
                    self.meta.insert(rec.id, meta.clone());
                }
            }
            LeafBody::Tombstone => {
                match &mut self.index {
                    IndexImpl::Hnsw(h) => h.repair_slot(slot, None, false),
                    IndexImpl::Flat(f) => f.repair_slot(slot, None, false),
                }
                for t in self.links.links_from(rec.id) {
                    self.links.unlink(rec.id, t);
                }
                self.meta.remove(&rec.id);
            }
        }
        self.refresh_merkle_slot(slot);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel4() -> Kernel {
        Kernel::new(KernelConfig::default_q16(4))
    }

    fn v(a: f32, b: f32, c: f32, d: f32) -> Vec<f32> {
        vec![a, b, c, d]
    }

    #[test]
    fn insert_and_search() {
        let mut k = kernel4();
        k.apply(Command::insert(1, v(0.0, 0.0, 0.0, 0.0))).unwrap();
        k.apply(Command::insert(2, v(1.0, 0.0, 0.0, 0.0))).unwrap();
        let hits = k.search_f32(&v(0.1, 0.0, 0.0, 0.0), 2).unwrap();
        assert_eq!(hits[0].id, 1);
        assert_eq!(hits[1].id, 2);
        assert_eq!(k.len(), 2);
        assert_eq!(k.seq(), 2);
    }

    #[test]
    fn duplicate_id_rejected() {
        let mut k = kernel4();
        k.apply(Command::insert(1, v(0.0, 0.0, 0.0, 0.0))).unwrap();
        let err = k.apply(Command::insert(1, v(1.0, 0.0, 0.0, 0.0))).unwrap_err();
        assert_eq!(err, StateError::DuplicateId(1));
        assert_eq!(k.seq(), 1); // failed command does not advance the clock
    }

    #[test]
    fn id_not_reusable_after_delete() {
        let mut k = kernel4();
        k.apply(Command::insert(1, v(0.0, 0.0, 0.0, 0.0))).unwrap();
        k.apply(Command::Delete { id: 1 }).unwrap();
        let err = k.apply(Command::insert(1, v(0.0, 0.0, 0.0, 0.0))).unwrap_err();
        assert_eq!(err, StateError::DuplicateId(1));
    }

    #[test]
    fn delete_unknown_rejected() {
        let mut k = kernel4();
        assert_eq!(k.apply(Command::Delete { id: 9 }).unwrap_err(), StateError::UnknownId(9));
    }

    #[test]
    fn link_requires_both_ends() {
        let mut k = kernel4();
        k.apply(Command::insert(1, v(0.0, 0.0, 0.0, 0.0))).unwrap();
        let err = k.apply(Command::Link { from: 1, to: 2 }).unwrap_err();
        assert_eq!(err, StateError::UnknownId(2));
        k.apply(Command::insert(2, v(1.0, 0.0, 0.0, 0.0))).unwrap();
        k.apply(Command::Link { from: 1, to: 2 }).unwrap();
        assert!(k.links().has_link(1, 2));
    }

    #[test]
    fn delete_cleans_links_and_meta() {
        let mut k = kernel4();
        k.apply(Command::insert(1, v(0.0, 0.0, 0.0, 0.0))).unwrap();
        k.apply(Command::insert(2, v(1.0, 0.0, 0.0, 0.0))).unwrap();
        k.apply(Command::Link { from: 1, to: 2 }).unwrap();
        k.apply(Command::SetMeta { id: 2, key: "k".into(), value: "v".into() }).unwrap();
        k.apply(Command::Delete { id: 2 }).unwrap();
        assert_eq!(k.links().edge_count(), 0);
        assert!(k.meta_of(2).is_none());
    }

    #[test]
    fn boundary_rejection_propagates() {
        let mut k = kernel4();
        let err = k.apply(Command::insert(1, vec![f32::NAN, 0.0, 0.0, 0.0])).unwrap_err();
        assert!(matches!(err, StateError::Boundary(BoundaryError::NaN { index: 0 })));
    }

    #[test]
    fn same_commands_same_hash() {
        let cmds = |k: &mut Kernel| {
            k.apply(Command::insert(1, v(0.5, -0.5, 0.25, 0.0))).unwrap();
            k.apply(Command::insert(2, v(0.1, 0.2, 0.3, 0.4))).unwrap();
            k.apply(Command::Link { from: 1, to: 2 }).unwrap();
            k.apply(Command::SetMeta { id: 1, key: "src".into(), value: "t".into() }).unwrap();
        };
        let mut a = kernel4();
        let mut b = kernel4();
        cmds(&mut a);
        cmds(&mut b);
        assert_eq!(a.state_hash(), b.state_hash());
        assert_eq!(a.to_state_bytes(), b.to_state_bytes());
    }

    #[test]
    fn different_order_different_hash() {
        // Command order is part of the state (paper: memory is a state
        // machine over a *sequence*; HNSW slot numbering differs).
        let mut a = kernel4();
        a.apply(Command::insert(1, v(0.5, 0.0, 0.0, 0.0))).unwrap();
        a.apply(Command::insert(2, v(0.0, 0.5, 0.0, 0.0))).unwrap();
        let mut b = kernel4();
        b.apply(Command::insert(2, v(0.0, 0.5, 0.0, 0.0))).unwrap();
        b.apply(Command::insert(1, v(0.5, 0.0, 0.0, 0.0))).unwrap();
        assert_ne!(a.to_state_bytes(), b.to_state_bytes());
    }

    #[test]
    fn state_roundtrip_bit_exact() {
        let mut k = kernel4();
        for i in 0..50u64 {
            let x = (i as f32) / 50.0 - 0.5;
            k.apply(Command::insert(i, v(x, -x, x * 0.5, 0.1))).unwrap();
        }
        k.apply(Command::Delete { id: 7 }).unwrap();
        k.apply(Command::Link { from: 1, to: 2 }).unwrap();
        let bytes = k.to_state_bytes();
        let k2 = Kernel::from_state_bytes(&bytes).unwrap();
        assert_eq!(k, k2);
        assert_eq!(bytes, k2.to_state_bytes());
        assert_eq!(k.state_hash(), k2.state_hash());
        // restored kernel continues identically
        let mut k3 = k2.clone();
        let mut k4 = k.clone();
        k3.apply(Command::insert(100, v(0.9, 0.9, 0.9, 0.9))).unwrap();
        k4.apply(Command::insert(100, v(0.9, 0.9, 0.9, 0.9))).unwrap();
        assert_eq!(k3.state_hash(), k4.state_hash());
    }

    #[test]
    fn flat_kernel_matches_hnsw_on_small_data() {
        let mut h = Kernel::new(KernelConfig::default_q16(4));
        let mut f = Kernel::new(KernelConfig::default_q16(4).with_flat_index());
        for i in 0..40u64 {
            let x = (i as f32) / 40.0;
            let vec = v(x, 1.0 - x, x * x, 0.5);
            h.apply(Command::insert(i, vec.clone())).unwrap();
            f.apply(Command::insert(i, vec)).unwrap();
        }
        let q = v(0.3, 0.7, 0.1, 0.5);
        let hh = h.search_f32(&q, 5).unwrap();
        let fh = f.search_f32(&q, 5).unwrap();
        assert_eq!(
            hh.iter().map(|x| (x.id, x.dist_raw)).collect::<Vec<_>>(),
            fh.iter().map(|x| (x.id, x.dist_raw)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn search_dim_mismatch_rejected() {
        let k = kernel4();
        assert!(matches!(
            k.search_f32(&[0.0; 3], 1).unwrap_err(),
            StateError::Boundary(BoundaryError::DimensionMismatch { .. })
        ));
        assert!(matches!(
            k.search_raw(&[0; 3], 1).unwrap_err(),
            StateError::DimMismatch { expected: 4, got: 3 }
        ));
    }

    #[test]
    fn meta_key_length_enforced() {
        let mut k = kernel4();
        k.apply(Command::insert(1, v(0.0, 0.0, 0.0, 0.0))).unwrap();
        let long = "x".repeat(300);
        let err = k
            .apply(Command::SetMeta { id: 1, key: long, value: "v".into() })
            .unwrap_err();
        assert_eq!(err, StateError::MetaKeyTooLong(300));
    }

    #[test]
    fn quant_kernel_round_trips_as_version_3() {
        let cfg = KernelConfig::default_q16(4)
            .with_flat_index()
            .with_quant(QuantSpec::Sq8 { overscan: 4 });
        let mut k = Kernel::new(cfg);
        for i in 0..30u64 {
            let x = (i as f32) / 30.0 - 0.5;
            k.apply(Command::insert(i, v(x, -x, 0.25, x * 0.5))).unwrap();
        }
        k.apply(Command::Delete { id: 11 }).unwrap();
        let bytes = k.to_state_bytes();
        // magic, then version 3
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), STATE_VERSION_QUANT);
        let k2 = Kernel::from_state_bytes(&bytes).unwrap();
        assert_eq!(k, k2);
        assert_eq!(k2.config().quant, QuantSpec::Sq8 { overscan: 4 });
        assert_eq!(bytes, k2.to_state_bytes());
        // the restored kernel searches identically (codes rebuilt)
        let q = v(0.1, -0.1, 0.25, 0.05);
        assert_eq!(k.search_f32(&q, 5).unwrap(), k2.search_f32(&q, 5).unwrap());
    }

    #[test]
    fn quant_free_kernel_still_emits_version_2_bytes() {
        let mut a = Kernel::new(KernelConfig::default_q16(4).with_flat_index());
        a.apply(Command::insert(1, v(0.5, 0.0, 0.0, 0.0))).unwrap();
        let bytes = a.to_state_bytes();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), STATE_VERSION);
        // and a v2 stream decodes with quant defaulted off
        let k2 = Kernel::from_state_bytes(&bytes).unwrap();
        assert_eq!(k2.config().quant, QuantSpec::None);
    }

    #[test]
    fn quant_kernel_search_matches_exact_kernel_at_covering_overscan() {
        let exact_cfg = KernelConfig::default_q16(4).with_flat_index();
        let quant_cfg = exact_cfg.clone().with_quant(QuantSpec::Sq8 { overscan: 1000 });
        let mut e = Kernel::new(exact_cfg);
        let mut q = Kernel::new(quant_cfg);
        for i in 0..64u64 {
            let x = (i as f32) / 64.0 - 0.5;
            let vec = v(x, 1.0 - x, x * x, -x);
            e.apply(Command::insert(i, vec.clone())).unwrap();
            q.apply(Command::insert(i, vec)).unwrap();
        }
        // overscan * k >= n: the fallback (or a covering candidate set)
        // must reproduce the exact kernel's hits bit for bit.
        let query = v(0.2, 0.8, 0.05, -0.2);
        assert_eq!(e.search_f32(&query, 7).unwrap(), q.search_f32(&query, 7).unwrap());
        // quant never leaks into state bytes' payload beyond the config:
        // same commands, version differs, but index payload is identical,
        // so decoding q's bytes and re-encoding is stable
        let restored = Kernel::from_state_bytes(&q.to_state_bytes()).unwrap();
        assert_eq!(q.state_hash(), restored.state_hash());
    }

    #[test]
    fn arena_bytes_reports_both_arenas() {
        let mut k = Kernel::new(
            KernelConfig::default_q16(4).with_flat_index().with_quant(QuantSpec::sq8_default()),
        );
        for i in 0..10u64 {
            k.apply(Command::insert(i, v(0.1, 0.2, 0.3, 0.4))).unwrap();
        }
        assert_eq!(k.arena_bytes(), (10 * 4 * 4, 10 * 4));
        let plain = Kernel::new(KernelConfig::default_q16(4).with_flat_index());
        assert_eq!(plain.arena_bytes(), (0, 0));
    }

    #[test]
    fn merkle_rebuild_on_decode_matches_incremental_tree() {
        let mut k = kernel4();
        let empty_root = k.merkle_root();
        for i in 0..20u64 {
            let x = (i as f32) / 20.0 - 0.5;
            k.apply(Command::insert(i, v(x, -x, 0.25, 0.0))).unwrap();
        }
        k.apply(Command::Link { from: 1, to: 2 }).unwrap();
        k.apply(Command::SetMeta { id: 3, key: "k".into(), value: "v".into() }).unwrap();
        // deleting 2 also re-hashes 1's leaf (it loses an outgoing link)
        k.apply(Command::Delete { id: 2 }).unwrap();
        assert_ne!(k.merkle_root(), empty_root);
        assert_eq!(k.merkle_capacity(), 32);
        let restored = Kernel::from_state_bytes(&k.to_state_bytes()).unwrap();
        assert_eq!(k.merkle_root(), restored.merkle_root());
        // the incremental tree keeps matching after further commands
        let mut k2 = restored.clone();
        let mut k1 = k.clone();
        k1.apply(Command::insert(100, v(0.1, 0.2, 0.3, 0.4))).unwrap();
        k2.apply(Command::insert(100, v(0.1, 0.2, 0.3, 0.4))).unwrap();
        assert_eq!(k1.merkle_root(), k2.merkle_root());
    }

    #[test]
    fn failed_commands_leave_merkle_root_untouched() {
        let mut k = kernel4();
        k.apply(Command::insert(1, v(0.5, 0.0, 0.0, 0.0))).unwrap();
        let root = k.merkle_root();
        assert!(k.apply(Command::insert(1, v(0.1, 0.0, 0.0, 0.0))).is_err());
        assert!(k.apply(Command::Delete { id: 9 }).is_err());
        assert_eq!(k.merkle_root(), root);
    }

    #[test]
    fn merkle_proof_and_repair_round_trip() {
        let mut a = kernel4();
        let mut b = kernel4();
        for i in 0..8u64 {
            let x = (i as f32) / 8.0;
            a.apply(Command::insert(i, v(x, 0.0, 0.0, 0.0))).unwrap();
            b.apply(Command::insert(i, v(x, 0.0, 0.0, 0.0))).unwrap();
        }
        assert_eq!(a.merkle_root(), b.merkle_root());
        let proof = a.merkle_proof(3).unwrap();
        assert_eq!(proof.slot, 3);
        assert_eq!(proof.capacity as usize, a.merkle_capacity());

        // corrupt b's slot 3 via repair with a bit-flipped (id-matching)
        // record — seq stays equal, exactly one leaf diverges
        let mut rec = leaf::decode(&b.merkle_leaf_encoding(3).unwrap()).unwrap();
        if let LeafBody::Live { vector, .. } = &mut rec.body {
            vector[0] ^= 1;
        }
        b.repair_slot(3, &rec).unwrap();
        assert_ne!(a.merkle_root(), b.merkle_root());
        assert_ne!(a.state_hash(), b.state_hash());
        assert_eq!(a.seq(), b.seq()); // repair never advances the clock

        // repair back from a's canonical leaf: full convergence, both
        // the Merkle root and the flat FNV state hash
        let good = leaf::decode(&a.merkle_leaf_encoding(3).unwrap()).unwrap();
        b.repair_slot(3, &good).unwrap();
        assert_eq!(a.merkle_root(), b.merkle_root());
        assert_eq!(a.state_hash(), b.state_hash());

        assert_eq!(b.repair_slot(99, &good), Err(RepairError::SlotOutOfRange));
        let wrong_id = LeafRecord { id: 7, body: LeafBody::Tombstone };
        assert_eq!(b.repair_slot(3, &wrong_id), Err(RepairError::IdMismatch));
        let bad_dim = LeafRecord {
            id: 3,
            body: LeafBody::Live { vector: vec![1, 2], meta: BTreeMap::new(), links: vec![] },
        };
        assert_eq!(b.repair_slot(3, &bad_dim), Err(RepairError::DimMismatch));
        // deleted records still prove membership (tombstone leaf)
        a.apply(Command::Delete { id: 3 }).unwrap();
        let tomb = a.merkle_proof(3).unwrap();
        assert_eq!(leaf::decode(&tomb.record).unwrap().body, LeafBody::Tombstone);
        assert!(a.merkle_proof(999).is_none());
    }

    #[test]
    fn canonicalize_then_apply_matches_direct_apply() {
        let mut a = kernel4();
        let mut b = kernel4();
        let cmd = Command::insert(1, v(0.123, -0.456, 0.789, 0.0));
        let canon = a.canonicalize(cmd.clone()).unwrap();
        a.apply_canon(&canon).unwrap();
        b.apply(cmd).unwrap();
        assert_eq!(a.state_hash(), b.state_hash());
    }
}
