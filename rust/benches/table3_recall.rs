//! Bench/driver for **Table 3** — Recall@10 of the Q16.16 deterministic
//! HNSW vs the f32 baseline (paper §8.3). Prints the paper's table plus
//! our added exact-ground-truth columns, and times index construction.
//!
//! Run: `cargo bench --bench table3_recall`
//! Quick: `VALORI_BENCH_QUICK=1 cargo bench --bench table3_recall`

use valori::bench::{bench, BenchConfig, Report};
use valori::distance::Metric;
use valori::experiments::{recall, synthetic_embeddings};
use valori::fixed::{FixedFormat, Q16_16};
use valori::index::{Hnsw, HnswParams, VectorIndex};

fn main() {
    let quick = std::env::var("VALORI_BENCH_QUICK").is_ok();
    let (docs, queries) = if quick { (400, 20) } else { (2000, 100) };

    // Table 3 with real embeddings when artifacts are built, synthetic
    // clusters otherwise.
    let r = recall::run(docs, queries, 10);
    recall::print_table(&r);

    // Recall sensitivity: K sweep (the trade-off the paper fixes at 10).
    println!("\nrecall@k sweep (synthetic, 1000 docs):");
    let embeddings = synthetic_embeddings(1000, 128, 16, 31);
    let qs = synthetic_embeddings(50, 128, 16, 77);
    for k in [1usize, 5, 10, 20, 50] {
        let r = recall::run_with_embeddings(&embeddings, &qs, k, "sweep");
        println!(
            "  k={k:>3}  q16-vs-f32 {:.3}  f32-vs-exact {:.3}  q16-vs-exact {:.3}",
            r.recall_q16_vs_f32, r.recall_f32_vs_exact, r.recall_q16_vs_exact
        );
    }

    // Index construction throughput (identical insertion order, both
    // scalar types — the Table 3 setup cost).
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let small = synthetic_embeddings(500, 128, 16, 3);
    let mut report = Report::new("HNSW construction, 500 × dim-128 (full rebuild)");
    report.add(
        "f32 HNSW",
        bench(&cfg, || {
            let mut h: Hnsw<f32> = Hnsw::new(128, Metric::L2, HnswParams::default());
            for (id, v) in small.iter().enumerate() {
                h.insert(id as u64, v.clone());
            }
            h.len()
        }),
    );
    report.add(
        "Q16.16 HNSW",
        bench(&cfg, || {
            let mut h: Hnsw<i32> = Hnsw::new(128, Metric::L2, HnswParams::default());
            for (id, v) in small.iter().enumerate() {
                let raw: Vec<i32> = v.iter().map(|&x| Q16_16::quantize(x as f64)).collect();
                h.insert(id as u64, raw);
            }
            h.len()
        }),
    );
    report.note("identical generic code; difference is the scalar arithmetic");
    report.print();
}
